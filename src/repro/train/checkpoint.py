"""Checkpointing: atomic, step-indexed, mesh-elastic save/restore.

Design (1000+-node posture, DESIGN.md §8):
  * the state pytree is flattened to named leaves → one ``.npz`` payload +
    a msgpack manifest (tree structure, shapes, dtypes, step, data cursor);
  * writes go to a temp directory then ``os.replace`` (atomic publish) —
    a crashed writer never corrupts the latest checkpoint;
  * a background thread does the serialization so the train loop only
    blocks on device→host transfer (async checkpointing);
  * ``restore`` re-shards onto WHATEVER mesh the restarting job brings up
    (elastic restart: checkpoints are mesh-agnostic host arrays; the new
    jit re-shards on first use);
  * retention: keep the last N checkpoints, unlink older.

On a real multi-host pod each host writes its addressable shards and the
manifest records the global sharding; on the single-host dry-run harness
the leaves are full arrays (fine at laptop scale — the code path is the
same, only the shard filter differs). The multi-host shard filter is the
documented extension point.
"""
from __future__ import annotations

import os
import pathlib
import shutil
import threading
import time

import jax
import msgpack
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, treedef


_NATIVE_DTYPES = {"float64", "float32", "float16", "int64", "int32",
                  "int16", "int8", "uint64", "uint32", "uint16", "uint8",
                  "bool", "complex64", "complex128"}


def _encode(a: np.ndarray) -> np.ndarray:
    """npz-safe encoding: exotic dtypes (bfloat16, fp8) → raw uint8 bytes."""
    if a.dtype.name in _NATIVE_DTYPES:
        return a
    return np.ascontiguousarray(a).view(np.uint8).reshape(-1)


def _decode(raw: np.ndarray, dtype: str, shape) -> np.ndarray:
    if raw.dtype.name != "uint8" or dtype == "uint8":
        return raw
    return raw.view(np.dtype(dtype)).reshape(shape)


def save(ckpt_dir: str | pathlib.Path, state, step: int, *,
         data_cursor: int = 0, keep: int = 3, blocking: bool = True,
         extra: dict | None = None):
    """Atomically write ``state`` as checkpoint ``step``.

    ``extra`` is an optional msgpack-serializable dict stamped into the
    manifest verbatim (e.g. the fleet schema a tenant was evicted under,
    DESIGN.md §15); readers find it at ``manifest.get("extra", {})`` —
    older checkpoints simply lack the key.
    """
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    names, leaves, _ = _flatten_with_names(state)
    host_leaves = [np.asarray(x) for x in jax.device_get(leaves)]

    def write():
        tmp = ckpt_dir / f".tmp-{step}-{os.getpid()}"
        tmp.mkdir(parents=True, exist_ok=True)
        np.savez(tmp / "arrays.npz",
                 **{f"leaf_{i}": _encode(a)
                    for i, a in enumerate(host_leaves)})
        manifest = {
            "step": step,
            "data_cursor": data_cursor,
            "names": names,
            "shapes": [list(a.shape) for a in host_leaves],
            "dtypes": [str(a.dtype) for a in host_leaves],
            "time": time.time(),
        }
        if extra is not None:
            manifest["extra"] = extra
        (tmp / "manifest.msgpack").write_bytes(
            msgpack.packb(manifest, use_bin_type=True))
        final = ckpt_dir / f"step_{step:010d}"
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
        _retain(ckpt_dir, keep)

    if blocking:
        write()
        return None
    t = threading.Thread(target=write, daemon=True)
    t.start()
    return t


def _retain(ckpt_dir: pathlib.Path, keep: int):
    ckpts = sorted(d for d in ckpt_dir.iterdir()
                   if d.is_dir() and d.name.startswith("step_"))
    for d in ckpts[:-keep]:
        shutil.rmtree(d, ignore_errors=True)


def latest_step(ckpt_dir: str | pathlib.Path) -> int | None:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(d.name.split("_")[1]) for d in ckpt_dir.iterdir()
             if d.is_dir() and d.name.startswith("step_")]
    return max(steps) if steps else None


def restore(ckpt_dir: str | pathlib.Path, state_like, *, step: int | None = None,
            shardings=None):
    """Load checkpoint into the structure of ``state_like``.

    ``state_like`` may be a concrete pytree or ShapeDtypeStructs;
    ``shardings`` (optional pytree of NamedSharding) re-shards each leaf —
    the elastic-restart path (the saved mesh need not match).
    Returns (state, manifest).
    """
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:010d}"
    manifest = msgpack.unpackb((d / "manifest.msgpack").read_bytes(),
                               raw=False)
    arrays = np.load(d / "arrays.npz")
    leaves = [_decode(arrays[f"leaf_{i}"], manifest["dtypes"][i],
                      manifest["shapes"][i])
              for i in range(len(manifest["names"]))]

    names_now, leaves_like, treedef = _flatten_with_names(state_like)
    if names_now != manifest["names"]:
        raise ValueError("checkpoint tree mismatch: "
                         f"{set(names_now) ^ set(manifest['names'])}")
    out = []
    flat_sh = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda s: s is None) if shardings is not None
        else [None] * len(leaves))
    for arr, like, sh in zip(leaves, leaves_like, flat_sh):
        a = arr.astype(like.dtype) if str(arr.dtype) != str(like.dtype) else arr
        if sh is not None:
            out.append(jax.device_put(a, sh))
        else:
            out.append(jax.numpy.asarray(a))
    return jax.tree_util.tree_unflatten(treedef, out), manifest
