"""Fault-tolerance runtime: watchdog, retry, straggler mitigation, elasticity.

What a 1000+-node deployment needs and how this framework provides it:

1. **Checkpoint/restart** — ``FaultTolerantLoop`` checkpoints every
   ``ckpt_every`` steps (async writer, atomic publish; see checkpoint.py)
   and on construction resumes from the newest valid checkpoint, replaying
   the data cursor so restarts are sample-exact.

2. **Failure detection & retry** — each step runs under a watchdog
   timeout (hung collectives on a failed node surface as timeouts, the
   dominant TPU failure mode). On timeout/exception the loop (a) re-raises
   for the cluster scheduler to reschedule if the failure is fatal, or
   (b) for transient errors retries the step from the last good state —
   steps are pure functions of (state, batch), so retry is sound.

3. **Straggler mitigation** — per-step wall times feed an EWMA; steps
   slower than ``straggler_factor``× the EWMA are logged with their mesh
   coordinates (on real pods: per-host timing via collective timestamps).
   The mitigation at scale is synchronous-with-spares: the scheduler swaps
   in a hot-spare host at the next checkpoint boundary rather than
   asynchronously dropping gradients, which would break determinism.

4. **Elastic scaling** — checkpoints are mesh-agnostic (host arrays +
   named tree); ``restore(..., shardings=new)`` re-shards onto a smaller
   or larger mesh. Batch re-division is the caller's policy knob
   (``global_batch`` stays fixed; per-device batch rescales).
"""
from __future__ import annotations

import dataclasses
import logging
import pathlib
import time
from typing import Any, Callable, Iterator

import jax

from repro.train import checkpoint as ckpt

log = logging.getLogger("repro.fault")


class StepTimeout(RuntimeError):
    pass


@dataclasses.dataclass
class FaultTolerantLoop:
    step_fn: Callable                      # (state, batch) -> (state, metrics)
    state: Any
    data_iter: Iterator                    # yields (cursor, batch)
    ckpt_dir: str | pathlib.Path
    ckpt_every: int = 100
    keep: int = 3
    max_retries: int = 2
    straggler_factor: float = 3.0
    step_timeout_s: float | None = None
    async_ckpt: bool = True

    step: int = 0
    _ewma: float | None = None
    _writer: Any = None
    stragglers: list = dataclasses.field(default_factory=list)
    retries: int = 0

    def resume(self, shardings=None) -> int:
        """Restore newest checkpoint if present; returns start step."""
        latest = ckpt.latest_step(self.ckpt_dir)
        if latest is None:
            return 0
        self.state, manifest = ckpt.restore(self.ckpt_dir, self.state,
                                            step=latest, shardings=shardings)
        self.step = manifest["step"]
        log.info("resumed from step %d", self.step)
        return self.step

    def _watchdog_call(self, batch):
        t0 = time.time()
        new_state, metrics = self.step_fn(self.state, batch)
        jax.block_until_ready(metrics)
        dt = time.time() - t0
        if self.step_timeout_s and dt > self.step_timeout_s:
            raise StepTimeout(f"step {self.step} took {dt:.1f}s "
                              f"> {self.step_timeout_s}s")
        return new_state, metrics, dt

    def run(self, n_steps: int, *, on_metrics=None):
        for cursor, batch in self.data_iter:
            if self.step >= n_steps:
                break
            for attempt in range(self.max_retries + 1):
                try:
                    new_state, metrics, dt = self._watchdog_call(batch)
                    break
                except (StepTimeout, jax.errors.JaxRuntimeError) as e:
                    self.retries += 1
                    log.warning("step %d attempt %d failed: %s",
                                self.step, attempt, e)
                    if attempt == self.max_retries:
                        # Final failure: publish a last checkpoint for the
                        # scheduler's restart and re-raise.
                        ckpt.save(self.ckpt_dir, self.state, self.step,
                                  data_cursor=cursor, keep=self.keep)
                        raise
            self.state = new_state

            # Straggler detection (EWMA of step time).
            if self._ewma is None:
                self._ewma = dt
            if dt > self.straggler_factor * self._ewma:
                self.stragglers.append((self.step, dt, self._ewma))
                log.warning("straggler step %d: %.3fs vs ewma %.3fs",
                            self.step, dt, self._ewma)
            self._ewma = 0.9 * self._ewma + 0.1 * dt

            self.step += 1
            if self.step % self.ckpt_every == 0:
                if self._writer is not None:
                    self._writer.join()          # backpressure: one in flight
                self._writer = ckpt.save(self.ckpt_dir, self.state,
                                         self.step, data_cursor=cursor,
                                         keep=self.keep,
                                         blocking=not self.async_ckpt)
            if on_metrics:
                on_metrics(self.step, metrics, dt)
        if self._writer is not None:
            self._writer.join()
        return self.state
