"""Step factories per architecture family.

Each factory returns ``(step_fn, abstract_state, abstract_inputs)`` where the
abstract trees are ShapeDtypeStructs carrying NamedShardings — ready for
``jax.jit(step_fn).lower(state, inputs)`` (the dry-run path) or for real
initialization + execution (examples/tests path).

Train state = {"params": compute-dtype tree, "opt": AdamW state (fp32
master + moments, sharded like params), "rng": key}.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shr
from repro.models import transformer as tfm
from repro.models import gnn as gnn_mod
from repro.models import dien as dien_mod
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.schedule import wsd_schedule, cosine_schedule


def _abstract(tree, mesh: Mesh, spec_tree):
    """ShapeDtypeStruct tree with NamedShardings attached."""
    def mk(x, spec):
        return jax.ShapeDtypeStruct(x.shape, x.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.tree.map(mk, tree, spec_tree,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def _opt_specs(param_specs):
    return {"m": param_specs, "v": param_specs, "master": param_specs,
            "step": P()}


# ---------------------------------------------------------------------------
# LM
# ---------------------------------------------------------------------------

def lm_train_cell(cfg, mesh: Mesh, *, batch: int, seq: int, fsdp: bool,
                  use_wsd: bool = False, peak_lr: float = 3e-4):
    pspecs = shr.lm_param_specs(cfg, mesh, fsdp=fsdp)
    params_shape = jax.eval_shape(partial(tfm.init_params, cfg),
                                  jax.random.key(0))
    opt_shape = jax.eval_shape(adamw_init, params_shape)
    state_abs = _abstract({"params": params_shape, "opt": opt_shape},
                          mesh, {"params": pspecs,
                                 "opt": _opt_specs(pspecs)})
    inputs_abs = shr.lm_input_specs(mesh, batch, seq)

    def step_fn(state, batch_in):
        tfm.set_lm_mesh(mesh if cfg.moe_expert_axis is not None else None)

        def loss_fn(p):
            return tfm.lm_loss(cfg, p, batch_in["tokens"],
                               batch_in["targets"])
        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        if use_wsd:
            lr = wsd_schedule(state["opt"]["step"], peak_lr=peak_lr,
                              warmup=2000, stable=100_000, decay=20_000)
        else:
            lr = cosine_schedule(state["opt"]["step"], peak_lr=peak_lr,
                                 warmup=2000, total=120_000)
        new_params, new_opt, gn = adamw_update(grads, state["opt"], lr,
                                               compute_dtype=cfg.dtype)
        return ({"params": new_params, "opt": new_opt},
                {"loss": loss, "grad_norm": gn, "lr": lr})

    return step_fn, state_abs, inputs_abs


def lm_prefill_cell(cfg, mesh: Mesh, *, batch: int, seq: int, fsdp: bool):
    pspecs = shr.lm_param_specs(cfg, mesh, fsdp=fsdp)
    params_shape = jax.eval_shape(partial(tfm.init_params, cfg),
                                  jax.random.key(0))
    params_abs = _abstract(params_shape, mesh, pspecs)
    da = shr.data_axes(mesh)
    tokens_abs = {"tokens": jax.ShapeDtypeStruct(
        (batch, seq), jnp.int32, sharding=shr.ns(mesh, da, None))}

    def step_fn(params, batch_in):
        tfm.set_lm_mesh(mesh if cfg.moe_expert_axis is not None else None)
        # Serving prefill returns last-token logits (next-token head);
        # compute the unembed on the last position only.
        h = tfm.forward_hidden(cfg, params, batch_in["tokens"])
        logits = h[:, -1, :] @ tfm._unembed(cfg, params)
        return logits[:, :cfg.vocab].astype(jnp.float32)

    return step_fn, params_abs, tokens_abs


def lm_decode_cell(cfg, mesh: Mesh, *, batch: int, seq: int, fsdp: bool):
    pspecs = shr.lm_param_specs(cfg, mesh, fsdp=fsdp)
    params_shape = jax.eval_shape(partial(tfm.init_params, cfg),
                                  jax.random.key(0))
    params_abs = _abstract(params_shape, mesh, pspecs)
    cache_abs, tok_abs = shr.lm_cache_specs(cfg, mesh, batch, seq)
    inputs_abs = {"token": tok_abs["token"], "cache": cache_abs}

    def step_fn(params, inputs):
        logits, new_cache = tfm.decode_step(cfg, params, inputs["token"],
                                            inputs["cache"])
        return logits, new_cache

    return step_fn, params_abs, inputs_abs


# ---------------------------------------------------------------------------
# GNN
# ---------------------------------------------------------------------------

def _gnn_forward_and_loss(arch_id: str, cfg, params, g, labels):
    if arch_id == "gat-cora":
        logits = gnn_mod.gat_forward(cfg, params, g)
        logz = jax.nn.logsumexp(logits.astype(jnp.float32), -1)
        gold = jnp.take_along_axis(logits.astype(jnp.float32),
                                   labels[:, None], axis=-1)[:, 0]
        return jnp.mean(logz - gold)
    if arch_id == "schnet":
        e = gnn_mod.schnet_forward(cfg, params, g)
        return jnp.mean((e - labels) ** 2)
    if arch_id == "dimenet":
        e = gnn_mod.dimenet_forward(cfg, params, g)
        return jnp.mean((e - labels) ** 2)
    if arch_id == "meshgraphnet":
        out = gnn_mod.mgn_forward(cfg, params, g)
        return jnp.mean((out - labels) ** 2)
    raise KeyError(arch_id)


def gnn_label_spec(arch_id: str, mesh: Mesh, shape: dict):
    da = shr.data_axes(mesh)
    if arch_id == "gat-cora":
        return jax.ShapeDtypeStruct((shape["n_nodes"],), jnp.int32,
                                    sharding=shr.ns(mesh, da))
    if arch_id in ("schnet", "dimenet"):
        # Per-graph energies; n_graphs may be < mesh axis → replicate.
        return jax.ShapeDtypeStruct((shape["n_graphs"],), jnp.float32,
                                    sharding=shr.ns(mesh))
    if arch_id == "meshgraphnet":
        return jax.ShapeDtypeStruct((shape["n_nodes"], 3), jnp.float32,
                                    sharding=shr.ns(mesh, da, None))
    raise KeyError(arch_id)


def gnn_make_init(arch_id: str, cfg):
    return {
        "gat-cora": gnn_mod.gat_init,
        "schnet": gnn_mod.schnet_init,
        "dimenet": gnn_mod.dimenet_init,
        "meshgraphnet": gnn_mod.mgn_init,
    }[arch_id]


def gnn_train_cell(arch_id: str, cfg, mesh: Mesh, shape: dict, *,
                   peak_lr: float = 1e-3, constrain: bool = True):
    init = gnn_make_init(arch_id, cfg)
    params_shape = jax.eval_shape(partial(init, cfg), jax.random.key(0))
    pspecs = shr.gnn_param_specs(params_shape)
    opt_shape = jax.eval_shape(adamw_init, params_shape)
    state_abs = _abstract({"params": params_shape, "opt": opt_shape},
                          mesh, {"params": pspecs,
                                 "opt": _opt_specs(pspecs)})

    needs_pos = arch_id in ("schnet", "dimenet", "meshgraphnet")
    atom_types = arch_id in ("schnet", "dimenet")
    n_trip = 4 * shape["n_edges"] if arch_id == "dimenet" else 0
    g_abs = shr.gnn_input_specs(
        mesh, n_nodes=shape["n_nodes"], n_edges=shape["n_edges"],
        d_feat=shape["d_feat"], positions=needs_pos, atom_types=atom_types,
        n_graphs=shape["n_graphs"], n_triplets=n_trip)
    inputs_abs = {"graph": g_abs,
                  "labels": gnn_label_spec(arch_id, mesh, shape)}

    n_nodes = shape["n_nodes"]
    n_graphs = shape["n_graphs"]

    data_axes = shr.data_axes(mesh) if constrain else ()

    def step_fn(state, batch_in):
        gnn_mod.set_gnn_data_axes(data_axes)
        gb = batch_in["graph"]
        g = gnn_mod.GraphBatch(
            n_nodes=n_nodes, node_feat=gb["node_feat"], src=gb["src"],
            dst=gb["dst"], positions=gb.get("positions"),
            graph_id=gb["graph_id"], n_graphs=n_graphs,
            trip_in=gb.get("trip_in"), trip_out=gb.get("trip_out"))

        def loss_fn(p):
            return _gnn_forward_and_loss(arch_id, cfg, p, g,
                                         batch_in["labels"])
        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        lr = cosine_schedule(state["opt"]["step"], peak_lr=peak_lr,
                             warmup=100, total=10_000)
        new_params, new_opt, gn = adamw_update(grads, state["opt"], lr,
                                               compute_dtype=cfg.dtype)
        return ({"params": new_params, "opt": new_opt},
                {"loss": loss, "grad_norm": gn})

    return step_fn, state_abs, inputs_abs


# ---------------------------------------------------------------------------
# RecSys (DIEN)
# ---------------------------------------------------------------------------

def dien_train_cell(cfg, mesh: Mesh, *, batch: int, peak_lr: float = 1e-3):
    params_shape = jax.eval_shape(partial(dien_mod.dien_init, cfg),
                                  jax.random.key(0))
    pspecs = shr.dien_param_specs(params_shape)
    opt_shape = jax.eval_shape(adamw_init, params_shape)
    state_abs = _abstract({"params": params_shape, "opt": opt_shape},
                          mesh, {"params": pspecs,
                                 "opt": _opt_specs(pspecs)})
    inputs_abs = shr.dien_input_specs(mesh, cfg, batch)

    def step_fn(state, batch_in):
        def loss_fn(p):
            return dien_mod.dien_loss(cfg, p, batch_in)
        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        lr = cosine_schedule(state["opt"]["step"], peak_lr=peak_lr,
                             warmup=500, total=50_000)
        new_params, new_opt, gn = adamw_update(grads, state["opt"], lr,
                                               compute_dtype=cfg.dtype)
        return ({"params": new_params, "opt": new_opt},
                {"loss": loss, "grad_norm": gn})

    return step_fn, state_abs, inputs_abs


def dien_serve_cell(cfg, mesh: Mesh, *, batch: int):
    params_shape = jax.eval_shape(partial(dien_mod.dien_init, cfg),
                                  jax.random.key(0))
    pspecs = shr.dien_param_specs(params_shape, replicate_tables=True)
    params_abs = _abstract(params_shape, mesh, pspecs)
    inputs_abs = shr.dien_input_specs(mesh, cfg, batch)
    inputs_abs.pop("label")

    def step_fn(params, batch_in):
        return jax.nn.sigmoid(dien_mod.dien_forward(cfg, params, batch_in))

    return step_fn, params_abs, inputs_abs


def dien_retrieval_cell(cfg, mesh: Mesh, *, n_candidates: int):
    params_shape = jax.eval_shape(partial(dien_mod.dien_init, cfg),
                                  jax.random.key(0))
    pspecs = shr.dien_param_specs(params_shape, replicate_tables=True)
    params_abs = _abstract(params_shape, mesh, pspecs)
    inputs_abs = shr.dien_retrieval_specs(mesh, cfg, n_candidates)

    def step_fn(params, batch_in):
        return dien_mod.dien_retrieval_score(cfg, params, batch_in,
                                             cand_block=8192)

    return step_fn, params_abs, inputs_abs


# ---------------------------------------------------------------------------
# Dispatcher
# ---------------------------------------------------------------------------

def build_cell(spec, shape_name: str, mesh: Mesh, *, smoke: bool = False):
    """(step_fn, abstract_state_or_params, abstract_inputs) for one cell."""
    import dataclasses as dc

    cfg = spec.make_smoke_config() if smoke else spec.make_config()
    shape = dict(spec.shapes[shape_name])
    kind = shape["kind"]
    if spec.family == "lm":
        if not smoke and kind in ("train", "prefill"):
            # Activation sharding policy (measured in EXPERIMENTS.md §Perf):
            #   * train: sequence-parallel residual (seq over `model`) —
            #     shrinks every remat stash slice 16×; the attention
            #     KV all-gather it induces is amortized by the backward.
            #   * prefill: NO seq-sharding — prefill has no stash to save,
            #     and seq-sharded chunked attention all-gathers K/V once
            #     per q-chunk (S/q_chunk × KV bytes × L ≈ 1–2 TB/chip at
            #     32k — measured P4) while batch-sharded attention keeps
            #     heads on the model axis collective-free.
            upd = dict(act_batch_axes=shr.data_axes(mesh))
            if kind == "train":
                upd["act_seq_axis"] = "model"
                upd["remat_groups"] = {16: 4, 28: 7, 40: 8, 48: 8}.get(
                    cfg.n_layers, 0)
            if cfg.is_moe:
                upd["moe_expert_axis"] = "model"
            cfg = dc.replace(cfg, **upd)
        if kind == "train":
            return lm_train_cell(cfg, mesh, batch=shape["batch"],
                                 seq=shape["seq"], fsdp=spec.fsdp,
                                 use_wsd=spec.arch_id == "minicpm-2b")
        if kind == "prefill":
            return lm_prefill_cell(cfg, mesh, batch=shape["batch"],
                                   seq=shape["seq"], fsdp=spec.fsdp)
        if kind == "decode":
            return lm_decode_cell(cfg, mesh, batch=shape["batch"],
                                  seq=shape["seq"], fsdp=spec.fsdp)
    if spec.family == "gnn":
        if spec.arch_id == "gat-cora":
            cfg = type(cfg)(**{**cfg.__dict__, "d_in": shape["d_feat"]})
        if spec.arch_id == "meshgraphnet":
            cfg = type(cfg)(**{**cfg.__dict__, "d_in_node": shape["d_feat"]})
        return gnn_train_cell(spec.arch_id, cfg, mesh, shape,
                              constrain=not smoke)
    if spec.family == "recsys":
        if not smoke:
            cfg = dc.replace(cfg, use_embed_kernel=False)
        if kind == "train":
            return dien_train_cell(cfg, mesh, batch=shape["batch"])
        if kind == "serve":
            return dien_serve_cell(cfg, mesh, batch=shape["batch"])
        if kind == "retrieval":
            return dien_retrieval_cell(cfg, mesh,
                                       n_candidates=shape["n_candidates"])
    raise KeyError(f"{spec.arch_id}/{shape_name}")
