"""Data pipelines: synthetic graph suite, neighbor sampler, token streams."""
