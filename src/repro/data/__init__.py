"""Data pipelines: synthetic graph suite (``graphs``), edge-update stream
generators for the batch-dynamic layer (``streams``, DESIGN.md §9),
neighbor sampler, token streams."""
