"""GraphBatch builders: synthetic graphs, triplets, molecule batching,
neighbor sampling (fanout), and RST-based locality reordering.

This is where the paper's technique is wired into the GNN pipeline:
``reorder_by_rst`` runs the RST library over the input graph and relabels
nodes by tree order, improving gather locality for sharded message passing.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.models.gnn import GraphBatch


def build_triplets(src: np.ndarray, dst: np.ndarray, n_nodes: int,
                   max_triplets: int) -> tuple[np.ndarray, np.ndarray]:
    """Triplet index arrays for DimeNet: pairs of edges (k→j, j→i).

    Returns (trip_in, trip_out) of length max_triplets, padded with E
    (sentinel). trip_in[t] is the edge id of (k→j); trip_out[t] of (j→i).
    """
    e = len(src)
    in_edges: list[list[int]] = [[] for _ in range(n_nodes)]
    for eid in range(e):
        in_edges[dst[eid]].append(eid)
    ti, to = [], []
    for eid in range(e):
        j = src[eid]               # edge j→i
        for kin in in_edges[j]:    # edge k→j
            if src[kin] == dst[eid]:
                continue           # exclude backtracking k == i
            ti.append(kin)
            to.append(eid)
            if len(ti) >= max_triplets:
                break
        if len(ti) >= max_triplets:
            break
    ti = np.asarray(ti + [e] * (max_triplets - len(ti)), np.int32)
    to = np.asarray(to + [e] * (max_triplets - len(to)), np.int32)
    return ti, to


def random_graph_batch(n_nodes: int, n_edges: int, d_feat: int, *,
                       seed: int = 0, positions: bool = False,
                       atom_types: bool = False, n_graphs: int = 1,
                       max_triplets: int = 0) -> GraphBatch:
    """Random connected-ish GraphBatch with optional 3D positions/triplets."""
    rng = np.random.default_rng(seed)
    # Tree backbone + random extra edges, directed both ways.
    tree_dst = np.arange(1, n_nodes)
    tree_src = (rng.random(n_nodes - 1) * tree_dst).astype(np.int64)
    m_extra = max(n_edges // 2 - (n_nodes - 1), 0)
    ex = rng.integers(0, n_nodes, (m_extra, 2))
    und = np.concatenate([np.stack([tree_src, tree_dst], 1), ex])
    src = np.concatenate([und[:, 0], und[:, 1]])[:n_edges]
    dst = np.concatenate([und[:, 1], und[:, 0]])[:n_edges]
    if len(src) < n_edges:                       # pad with sentinels
        pad = n_edges - len(src)
        src = np.concatenate([src, np.full(pad, n_nodes)])
        dst = np.concatenate([dst, np.full(pad, n_nodes)])

    if atom_types:
        feat = rng.integers(0, 10, n_nodes)
        node_feat = jnp.asarray(feat, jnp.int32)
    else:
        node_feat = jnp.asarray(rng.standard_normal((n_nodes, d_feat)),
                                jnp.float32)
    pos = jnp.asarray(rng.standard_normal((n_nodes, 3)) * 2.0,
                      jnp.float32) if positions else None
    gid = jnp.asarray(rng.integers(0, n_graphs, n_nodes), jnp.int32) \
        if n_graphs > 1 else jnp.zeros((n_nodes,), jnp.int32)

    ti = to = None
    if max_triplets:
        ti_np, to_np = build_triplets(src.astype(np.int64),
                                      dst.astype(np.int64), n_nodes,
                                      max_triplets)
        ti, to = jnp.asarray(ti_np), jnp.asarray(to_np)

    return GraphBatch(n_nodes=n_nodes, node_feat=node_feat,
                      src=jnp.asarray(src, jnp.int32),
                      dst=jnp.asarray(dst, jnp.int32),
                      positions=pos, graph_id=gid, n_graphs=n_graphs,
                      trip_in=ti, trip_out=to)


def neighbor_sample(row_ptr: np.ndarray, col: np.ndarray,
                    seeds: np.ndarray, fanouts: list[int],
                    seed: int = 0) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Multi-hop uniform neighbor sampler (GraphSAGE-style, fanout list).

    Returns (nodes, sub_src, sub_dst): sampled node set (seeds first) and
    the sampled subgraph edges in *local* node numbering.
    """
    rng = np.random.default_rng(seed)
    nodes = list(dict.fromkeys(seeds.tolist()))
    local = {v: i for i, v in enumerate(nodes)}
    sub_src, sub_dst = [], []
    frontier = list(nodes)
    for fan in fanouts:
        nxt = []
        for v in frontier:
            lo, hi = int(row_ptr[v]), int(row_ptr[v + 1])
            deg = hi - lo
            if deg == 0:
                continue
            k = min(fan, deg)
            picks = rng.choice(deg, size=k, replace=False)
            for pk in picks:
                u = int(col[lo + pk])
                if u not in local:
                    local[u] = len(nodes)
                    nodes.append(u)
                    nxt.append(u)
                sub_src.append(local[u])
                sub_dst.append(local[v])
        frontier = nxt
    return (np.asarray(nodes, np.int64), np.asarray(sub_src, np.int64),
            np.asarray(sub_dst, np.int64))


def sampled_batch(row_ptr, col, seeds, fanouts, d_feat: int, *,
                  pad_nodes: int, pad_edges: int, seed: int = 0,
                  feats: np.ndarray | None = None) -> GraphBatch:
    """Fixed-shape GraphBatch from a neighbor sample (pads to static dims)."""
    nodes, s, d = neighbor_sample(row_ptr, col, seeds, fanouts, seed)
    nodes = nodes[:pad_nodes]
    keep = (s < pad_nodes) & (d < pad_nodes)
    s, d = s[keep][:pad_edges], d[keep][:pad_edges]
    n_pad = pad_nodes - len(nodes)
    e_pad = pad_edges - len(s)
    rng = np.random.default_rng(seed + 1)
    if feats is None:
        f = rng.standard_normal((pad_nodes, d_feat)).astype(np.float32)
    else:
        f = np.zeros((pad_nodes, d_feat), np.float32)
        f[:len(nodes)] = feats[nodes]
    src = np.concatenate([s, np.full(e_pad, pad_nodes)])
    dst = np.concatenate([d, np.full(e_pad, pad_nodes)])
    return GraphBatch(n_nodes=pad_nodes, node_feat=jnp.asarray(f),
                      src=jnp.asarray(src, jnp.int32),
                      dst=jnp.asarray(dst, jnp.int32))


def reorder_by_rst(graph_src: np.ndarray, graph_dst: np.ndarray,
                   n_nodes: int, method: str = "gconn_euler"):
    """Relabel nodes by RST order (paper technique in the data pipeline).

    Returns perm such that perm[old_id] = new_id; nodes contiguous within
    subtrees → better gather locality after sharding.
    """
    from repro.core import Graph, rooted_spanning_tree

    g = Graph(n_nodes=n_nodes, src=jnp.asarray(graph_src, jnp.int32),
              dst=jnp.asarray(graph_dst, jnp.int32))
    res = rooted_spanning_tree(g, 0, method=method)
    parent = np.asarray(res.parent)
    # Order nodes by (depth, parent) chain — stable DFS-ish labeling from
    # the parent array without host recursion.
    order = np.lexsort((np.arange(n_nodes), parent))
    perm = np.empty(n_nodes, np.int64)
    perm[order] = np.arange(n_nodes)
    return perm
