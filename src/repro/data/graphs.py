"""Synthetic graph suite with controlled diameter (paper Table II analogue).

The paper's datasets span three structural regimes the generators below
reproduce at laptop scale:
  * high-diameter sparse (road_usa, europe_osm)  → ``grid2d`` / ``chain``
  * power-law low-diameter (kron_g500, orkut)    → ``rmat``
  * mid-diameter web-ish (web-BerkStan, uk-2002) → ``pref_attach``
  * uniform random (control)                     → ``erdos_renyi``

All generators return a connected ``Graph`` (a random spanning tree is
implanted first, extra edges added on top), so RST validity is always
well-defined for any root.
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import Graph


def _implant_tree(n: int, rng: np.random.Generator) -> np.ndarray:
    """Random spanning tree edges (uniform attachment)."""
    perm = rng.permutation(n)
    attach = (rng.random(n - 1) * np.arange(1, n)).astype(np.int64)
    return np.stack([perm[1:], perm[attach]], axis=1)


def chain(n: int, seed: int = 0) -> Graph:
    """Path graph — the worst case for BFS (diameter n-1)."""
    edges = np.stack([np.arange(n - 1), np.arange(1, n)], axis=1)
    return Graph.from_numpy_undirected(n, edges)


def grid2d(side: int, seed: int = 0) -> Graph:
    """side × side grid — road-network analogue (diameter 2·(side-1))."""
    n = side * side
    ids = np.arange(n).reshape(side, side)
    horiz = np.stack([ids[:, :-1].ravel(), ids[:, 1:].ravel()], axis=1)
    vert = np.stack([ids[:-1, :].ravel(), ids[1:, :].ravel()], axis=1)
    return Graph.from_numpy_undirected(n, np.concatenate([horiz, vert]))


def erdos_renyi(n: int, avg_degree: float = 4.0, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    m = int(n * avg_degree / 2)
    extra = rng.integers(0, n, (m, 2))
    edges = np.concatenate([_implant_tree(n, rng), extra])
    return Graph.from_numpy_undirected(n, edges)


def rmat(scale: int, edge_factor: int = 8, seed: int = 0,
         a: float = 0.57, b: float = 0.19, c: float = 0.19) -> Graph:
    """Kronecker/R-MAT power-law generator (kron_g500 analogue)."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    src = np.zeros(m, np.int64)
    dst = np.zeros(m, np.int64)
    for _ in range(scale):
        r1 = rng.random(m)
        r2 = rng.random(m)
        src_bit = (r1 > a + b).astype(np.int64)
        dst_bit = (((r1 <= a + b) & (r2 > a / (a + b))) |
                   ((r1 > a + b) & (r2 > c / (1 - a - b)))).astype(np.int64)
        src = (src << 1) | src_bit
        dst = (dst << 1) | dst_bit
    edges = np.concatenate([np.stack([src, dst], 1), _implant_tree(n, rng)])
    return Graph.from_numpy_undirected(n, edges)


def pref_attach(n: int, m_per: int = 4, seed: int = 0) -> Graph:
    """Preferential attachment (Barabási–Albert) — web-graph analogue."""
    rng = np.random.default_rng(seed)
    targets = np.zeros(max((n - 1) * m_per, 1), np.int64)
    edges = []
    k = 0
    for v in range(1, n):
        lim = max(2 * k, 1)
        for _ in range(min(m_per, v)):
            if rng.random() < 0.5 or k == 0:
                t = int(rng.integers(0, v))
            else:
                t = int(targets[rng.integers(0, min(k, targets.shape[0]))] % v)
            edges.append((v, t))
            targets[k % targets.shape[0]] = t
            targets[(k + 1) % targets.shape[0]] = v
            k += 2
    return Graph.from_numpy_undirected(n, np.asarray(edges))


SUITE = {
    # name: (factory, kwargs, regime) — laptop-scale Table II analogue.
    "chain_4k": (chain, dict(n=4096), "extreme-diameter"),
    "grid_64": (grid2d, dict(side=64), "high-diameter road-like"),
    "grid_128": (grid2d, dict(side=128), "high-diameter road-like"),
    "er_16k": (erdos_renyi, dict(n=16384, avg_degree=8), "random control"),
    "rmat_14": (rmat, dict(scale=14, edge_factor=8), "power-law low-diameter"),
    "rmat_16": (rmat, dict(scale=16, edge_factor=4), "power-law low-diameter"),
    "ba_8k": (pref_attach, dict(n=8192, m_per=4), "web-like"),
}


def build_suite(names=None) -> dict[str, Graph]:
    names = names or list(SUITE)
    return {k: SUITE[k][0](**SUITE[k][1]) for k in names}


def resolve_graph(name: str, seed: int = 0) -> Graph:
    """Build a graph from a suite name or a parametric pattern.

    Accepts every ``SUITE`` key plus the patterns ``chain_<n>``,
    ``grid_<side>``, ``rmat_<scale>``, and ``er_<n>`` so fleet bucket
    specs (``--buckets chain_64:12``, DESIGN.md §15) aren't limited to
    the benchmark suite's sizes. Parametric ``rmat_<scale>`` uses
    ``edge_factor=4`` (the small-session regime buckets target); suite
    names keep their registered kwargs.
    """
    if name in SUITE:
        factory, kwargs, _ = SUITE[name]
        return factory(**kwargs)
    kind, _, arg = name.partition("_")
    if not arg.isdigit():
        raise ValueError(
            f"unknown graph {name!r}: not in SUITE ({', '.join(SUITE)}) "
            f"and not a chain_<n>/grid_<side>/rmat_<scale>/er_<n> pattern")
    k = int(arg)
    if kind == "chain":
        return chain(k, seed=seed)
    if kind == "grid":
        return grid2d(k, seed=seed)
    if kind == "rmat":
        return rmat(k, edge_factor=4, seed=seed)
    if kind == "er":
        return erdos_renyi(k, seed=seed)
    raise ValueError(
        f"unknown graph {name!r}: not in SUITE ({', '.join(SUITE)}) "
        f"and not a chain_<n>/grid_<side>/rmat_<scale>/er_<n> pattern")
