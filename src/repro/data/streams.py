"""Edge-stream workload generators over the synthetic graph suite.

Serving traffic for the batch-dynamic layer (DESIGN.md §9): each
generator turns a static ``data.graphs`` suite graph into a stream of
fixed-shape update batches — ``StreamBatch`` arrays padded with the
``n_nodes`` sentinel so every batch has identical shapes and the jitted
``dynamic.apply_batch`` compiles exactly once per stream.

Three traffic regimes (numpy-side, deterministic per seed):

  * ``sliding_window`` — batches of edges arrive in a random order and
    expire ``window`` batches later: the timestamped-graph regime
    (temporal networks, session graphs). Live set ≈ window · batch.
  * ``insert_heavy``  — the graph grows toward the full edge set with a
    small deletion rate ``p_delete``: the accretion regime (social /
    citation growth). Mostly exercises the insertion/link path.
  * ``churn``         — starts from a random half of the edges and swaps
    ``batch/2`` live edges for dead ones every step: the steady-state
    regime. Exercises cut + replacement search hardest.

Each generator returns an ``EdgeStream``: the initially-live edges (seed
state for ``dynamic.forest_from_graph`` or replay onto ``forest_empty``)
plus the batch list. Deletions are (u, v) pairs — resolve them to pool
slots with ``dynamic.edge_slots`` (multiset-aware) at apply time.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import Graph


@dataclasses.dataclass(frozen=True)
class StreamBatch:
    """One update batch; all arrays int32, ``n_nodes``-sentinel padded.

    ins_u/ins_v: [batch] edges to insert; del_u/del_v: [batch] edges to
    delete (pairs, not pool slots).
    """

    ins_u: np.ndarray
    ins_v: np.ndarray
    del_u: np.ndarray
    del_v: np.ndarray


@dataclasses.dataclass(frozen=True)
class EdgeStream:
    """A replayable edge-update workload over n_nodes vertices."""

    name: str
    n_nodes: int
    init_u: np.ndarray          # edges live before the first batch
    init_v: np.ndarray
    batches: tuple[StreamBatch, ...]

    @property
    def n_events(self) -> int:
        """Total insert + delete events across all batches."""
        n = self.n_nodes
        return int(sum((b.ins_u < n).sum() + (b.del_u < n).sum()
                       for b in self.batches))


def _edges_of(graph: Graph) -> np.ndarray:
    """The M undirected edges as an int [M, 2] array."""
    m = graph.n_edges
    return np.stack([np.asarray(graph.src[:m]), np.asarray(graph.dst[:m])],
                    axis=1).astype(np.int64)


def _pad(pairs: list[tuple[int, int]], width: int, n: int):
    u = np.full(width, n, np.int32)
    v = np.full(width, n, np.int32)
    for i, (a, b) in enumerate(pairs[:width]):
        u[i], v[i] = a, b
    return u, v


def _mk_batch(ins, dels, batch, n) -> StreamBatch:
    iu, iv = _pad(ins, batch, n)
    du, dv = _pad(dels, batch, n)
    return StreamBatch(ins_u=iu, ins_v=iv, del_u=du, del_v=dv)


def sliding_window(graph: Graph, *, batch: int = 64, window: int = 4,
                   n_batches: int | None = None, seed: int = 0) -> EdgeStream:
    """Edges arrive in random order and expire ``window`` batches later."""
    n = graph.n_nodes
    rng = np.random.default_rng(seed)
    edges = _edges_of(graph)
    order = rng.permutation(edges.shape[0])
    blocks = [edges[order[i:i + batch]]
              for i in range(0, edges.shape[0], batch)]
    if n_batches is not None:
        blocks = blocks[:n_batches]
    batches = []
    for t, blk in enumerate(blocks):
        ins = [tuple(e) for e in blk]
        dels = ([tuple(e) for e in blocks[t - window]]
                if t >= window else [])
        batches.append(_mk_batch(ins, dels, batch, n))
    return EdgeStream(name="sliding_window", n_nodes=n,
                      init_u=np.zeros(0, np.int32),
                      init_v=np.zeros(0, np.int32),
                      batches=tuple(batches))


def insert_heavy(graph: Graph, *, batch: int = 64, p_delete: float = 0.1,
                 n_batches: int | None = None, seed: int = 0) -> EdgeStream:
    """Growth regime: insert toward the full edge set, rare deletions."""
    n = graph.n_nodes
    rng = np.random.default_rng(seed)
    edges = _edges_of(graph)
    order = rng.permutation(edges.shape[0])
    live: list[tuple[int, int]] = []
    batches = []
    n_ins = max(1, batch - int(batch * p_delete))
    total = (edges.shape[0] + n_ins - 1) // n_ins
    if n_batches is not None:
        total = min(total, n_batches)
    for t in range(total):
        blk = edges[order[t * n_ins:(t + 1) * n_ins]]
        ins = [tuple(e) for e in blk]
        k = min(int(rng.binomial(batch, p_delete)), len(live))
        dels = []
        if k:
            for i in sorted(rng.choice(len(live), size=k, replace=False),
                            reverse=True):
                dels.append(live.pop(i))
        live += ins
        batches.append(_mk_batch(ins, dels, batch, n))
    return EdgeStream(name="insert_heavy", n_nodes=n,
                      init_u=np.zeros(0, np.int32),
                      init_v=np.zeros(0, np.int32),
                      batches=tuple(batches))


def churn(graph: Graph, *, batch: int = 64, n_batches: int = 16,
          seed: int = 0) -> EdgeStream:
    """Steady state: half the edges live; swap batch/2 per step."""
    n = graph.n_nodes
    rng = np.random.default_rng(seed)
    edges = _edges_of(graph)
    m = edges.shape[0]
    perm = rng.permutation(m)
    live = [tuple(edges[i]) for i in perm[:m // 2]]
    dead = [tuple(edges[i]) for i in perm[m // 2:]]
    init_u = np.asarray([e[0] for e in live], np.int32)
    init_v = np.asarray([e[1] for e in live], np.int32)
    k = max(1, batch // 2)
    batches = []
    for _ in range(n_batches):
        kk = min(k, len(live), len(dead))
        dels, ins = [], []
        for i in sorted(rng.choice(len(live), size=kk, replace=False),
                        reverse=True):
            dels.append(live.pop(i))
        for i in sorted(rng.choice(len(dead), size=kk, replace=False),
                        reverse=True):
            ins.append(dead.pop(i))
        live += ins
        dead += dels
        batches.append(_mk_batch(ins, dels, batch, n))
    return EdgeStream(name="churn", n_nodes=n,
                      init_u=init_u, init_v=init_v,
                      batches=tuple(batches))


#: name → generator, mirroring ``data.graphs.SUITE``'s shape.
STREAMS = {
    "sliding_window": sliding_window,
    "insert_heavy": insert_heavy,
    "churn": churn,
}
