"""SyncLedger: the single host-side sync-accounting path (DESIGN.md §14).

On the XLA-CPU CI backend the repo's only trustworthy perf signal is the
engine convergence-check ("sync") count — wall-clock is volume-bound.
Before this module that count was threaded by hand: every convergence
loop returns its counter when asked (``return_syncs=True``), and every
benchmark table re-derived ``sync_total`` from a different ad-hoc sum
(``seg_syncs + aux_rounds``, ``max_t(rounds) + 1``, ``build_syncs``,
...). The ``SyncLedger`` is the one place those numbers land: host-side
wrappers around the engine loops call ``record(phase, syncs)`` after the
loop returns, and consumers read per-phase totals instead of re-plumbing
counters.

The zero-sync contract (guarded by tests/test_obs.py): recording must
not change what the device computes. Two properties make that free:

  1. every engine ``while_loop`` *already* carries its sync counter —
     ``return_syncs=True`` only returns a value that exists either way,
     so instrumented wrappers request it unconditionally and the
     compiled program is identical with recording on or off;
  2. ``record`` is a no-op until a ledger is installed, and lazy
     (callable) sync values are only evaluated — i.e. the device scalar
     is only pulled to host — while one is.

Install a ledger with ``with SyncLedger() as led:`` (re-entrant: nested
ledgers all observe every record, so a benchmark ledger can sit inside a
tracing session's ledger without stealing its records).
"""
from __future__ import annotations

from typing import Callable

#: installed ledgers, innermost last; module-level on purpose — the
#: serving loops are single-threaded host drivers.
_LEDGERS: list["SyncLedger"] = []


def current_ledger() -> "SyncLedger | None":
    """The innermost installed ledger, or None (recording disabled)."""
    return _LEDGERS[-1] if _LEDGERS else None


def recording() -> bool:
    return bool(_LEDGERS)


def record(phase: str, syncs, *, tenant=None, bucket=None) -> None:
    """Report ``syncs`` convergence checks spent in ``phase``.

    No-op when no ledger is installed. ``syncs`` may be an int, a 0-d
    device scalar, or a zero-arg callable returning either — callables
    (and device→host pulls) are only evaluated while a ledger is
    installed, so uninstrumented runs pay nothing. ``tenant`` and
    ``bucket`` are optional attribution labels (stable tenant id /
    sub-fleet bucket name, DESIGN.md §15); omitting them is the
    PR-8-compatible default and changes nothing.
    """
    if not _LEDGERS:
        return
    value = int(syncs() if isinstance(syncs, Callable) else syncs)
    for led in _LEDGERS:
        led.add(phase, value, tenant=tenant, bucket=bucket)


class SyncLedger:
    """Per-phase sync totals for one scope (a run, a benchmark row).

    Context manager: entering installs the ledger so module-level
    ``record`` calls land here; exiting uninstalls it (totals remain
    readable).
    """

    def __init__(self) -> None:
        self._totals: dict[str, int] = {}
        self._counts: dict[str, int] = {}
        self._tenant_totals: dict[tuple[str, object], int] = {}
        self._bucket_totals: dict[tuple[str, object], int] = {}

    # -- recording -----------------------------------------------------------

    def add(self, phase: str, syncs: int, *, tenant=None,
            bucket=None) -> None:
        self._totals[phase] = self._totals.get(phase, 0) + int(syncs)
        self._counts[phase] = self._counts.get(phase, 0) + 1
        if tenant is not None:
            key = (phase, tenant)
            self._tenant_totals[key] = \
                self._tenant_totals.get(key, 0) + int(syncs)
        if bucket is not None:
            key = (phase, bucket)
            self._bucket_totals[key] = \
                self._bucket_totals.get(key, 0) + int(syncs)

    # -- reading -------------------------------------------------------------

    def totals(self) -> dict[str, int]:
        """{phase: total syncs}, insertion-ordered."""
        return dict(self._totals)

    def counts(self) -> dict[str, int]:
        """{phase: number of records}."""
        return dict(self._counts)

    def total(self, phase: str | None = None) -> int:
        """Total syncs — one phase's, or across every phase."""
        if phase is not None:
            return self._totals.get(phase, 0)
        return sum(self._totals.values())

    def by_tenant(self, phase: str) -> dict:
        """{tenant: syncs} for records that carried a tenant label."""
        return {t: v for (p, t), v in self._tenant_totals.items()
                if p == phase}

    def by_bucket(self, phase: str) -> dict:
        """{bucket: syncs} for records that carried a bucket label."""
        return {b: v for (p, b), v in self._bucket_totals.items()
                if p == phase}

    def clear(self) -> None:
        self._totals.clear()
        self._counts.clear()
        self._tenant_totals.clear()
        self._bucket_totals.clear()

    # -- install/uninstall ---------------------------------------------------

    def __enter__(self) -> "SyncLedger":
        _LEDGERS.append(self)
        return self

    def __exit__(self, *exc) -> None:
        # Remove *this* ledger even under exotic nesting orders.
        for i in range(len(_LEDGERS) - 1, -1, -1):
            if _LEDGERS[i] is self:
                del _LEDGERS[i]
                break
