"""Span tracing for the serving loops (DESIGN.md §14).

A ``Tracer`` records *spans* (named wall-clock intervals with sync
attribution) and *events* (instants with structured args) from the
serving loop: tick → dispatch → apply_batch → refresh_tour/bcc/tables →
query batch → audit/recover ladder rungs. Each span charges itself the
ledger delta across its body — inclusive of children, like any sampling
profiler — so a trace answers "where did the sync budget go" per phase
AND per wall-clock interval.

Two export formats from the same records:

  * JSONL (``write_jsonl``) — one record per line, schema below; the
    last line is a ``summary`` record carrying the ledger's per-phase
    totals (what ``scripts/obs_report.py`` renders).
  * Chrome trace-event JSON (``write_chrome``) — loadable in Perfetto
    (https://ui.perfetto.dev) / chrome://tracing: spans as ``ph: "X"``
    complete events, events as ``ph: "i"`` instants.

JSONL record schema (``v`` = SCHEMA_VERSION on every line)::

    {"v": 1, "type": "span",  "name": ..., "ts": µs, "dur": µs,
     "syncs": int, "step": int|null, "args": {...}}
    {"v": 1, "type": "event", "name": ..., "ts": µs,
     "step": int|null, "args": {...}}
    {"v": 1, "type": "summary", "sync_by_phase": {...},
     "sync_total": int, "span_count": int}

The round-trip ``chrome_to_records(read chrome file)`` reconstructs the
span/event records bit-for-bit (regression-tested in tests/test_obs.py).

Like the ledger, tracing is ambient: ``with Tracer() as tr:`` installs
the tracer (and its ledger); module-level ``span(...)``/``event(...)``
no-op when nothing is installed, so instrumented code paths cost nothing
in untraced runs.
"""
from __future__ import annotations

import contextlib
import json
import pathlib
import time

from repro.obs.ledger import SyncLedger

SCHEMA_VERSION = 1

_TRACERS: list["Tracer"] = []


def current_tracer() -> "Tracer | None":
    return _TRACERS[-1] if _TRACERS else None


def span(name: str, *, step: int | None = None, **args):
    """A span on the innermost tracer; a no-op context otherwise."""
    tr = current_tracer()
    if tr is None:
        return contextlib.nullcontext()
    return tr.span(name, step=step, **args)


def event(name: str, *, step: int | None = None, **args) -> None:
    """An instant event on the innermost tracer; no-op otherwise."""
    tr = current_tracer()
    if tr is not None:
        tr.event(name, step=step, **args)


class Tracer:
    """Span/event recorder with sync attribution via an owned ledger.

    Entering installs the tracer AND its ``SyncLedger``, so the engine
    wrappers' ``record(...)`` calls feed span attribution without any
    extra plumbing. ``ledger`` may be shared (pass one in) or owned.
    """

    def __init__(self, ledger: SyncLedger | None = None) -> None:
        self.ledger = ledger if ledger is not None else SyncLedger()
        self.records: list[dict] = []
        self._t0 = time.perf_counter()

    # -- recording -----------------------------------------------------------

    def _now_us(self) -> int:
        return int((time.perf_counter() - self._t0) * 1e6)

    @contextlib.contextmanager
    def span(self, name: str, *, step: int | None = None, **args):
        ts = self._now_us()
        s0 = self.ledger.total()
        try:
            yield self
        finally:
            self.records.append({
                "v": SCHEMA_VERSION, "type": "span", "name": name,
                "ts": ts, "dur": self._now_us() - ts,
                "syncs": self.ledger.total() - s0,
                "step": step, "args": args})

    def event(self, name: str, *, step: int | None = None, **args) -> None:
        self.records.append({
            "v": SCHEMA_VERSION, "type": "event", "name": name,
            "ts": self._now_us(), "step": step, "args": args})

    # -- reading -------------------------------------------------------------

    def spans(self, name: str | None = None) -> list[dict]:
        return [r for r in self.records if r["type"] == "span"
                and (name is None or r["name"] == name)]

    def events(self, name: str | None = None) -> list[dict]:
        return [r for r in self.records if r["type"] == "event"
                and (name is None or r["name"] == name)]

    def summary(self) -> dict:
        return {"v": SCHEMA_VERSION, "type": "summary",
                "sync_by_phase": self.ledger.totals(),
                "sync_total": self.ledger.total(),
                "span_count": len(self.spans())}

    # -- export --------------------------------------------------------------

    def write_jsonl(self, path) -> None:
        lines = [json.dumps(r, sort_keys=True)
                 for r in self.records + [self.summary()]]
        pathlib.Path(path).write_text("\n".join(lines) + "\n")

    def write_chrome(self, path) -> None:
        pathlib.Path(path).write_text(
            json.dumps(records_to_chrome(self.records, self.summary()),
                       indent=1) + "\n")

    # -- install/uninstall ---------------------------------------------------

    def __enter__(self) -> "Tracer":
        _TRACERS.append(self)
        self.ledger.__enter__()
        return self

    def __exit__(self, *exc) -> None:
        self.ledger.__exit__(*exc)
        for i in range(len(_TRACERS) - 1, -1, -1):
            if _TRACERS[i] is self:
                del _TRACERS[i]
                break


# -- format conversion (JSONL records ↔ Chrome trace events) ------------------

def read_jsonl(path) -> list[dict]:
    """Load a trace JSONL file back into its records (summary included)."""
    return [json.loads(line)
            for line in pathlib.Path(path).read_text().splitlines() if line]


def records_to_chrome(records: list[dict],
                      summary: dict | None = None) -> dict:
    """Span/event records → Chrome trace-event JSON (Perfetto-loadable).

    Spans become ``ph: "X"`` complete events (ts/dur in µs), events
    ``ph: "i"`` instants; the native args (incl. sync attribution and
    step) ride each event's ``args``. The summary lands in
    ``otherData`` so a renderer can recover per-phase totals.
    """
    trace_events = []
    for r in records:
        if r["type"] == "span":
            trace_events.append({
                "name": r["name"], "ph": "X", "ts": r["ts"],
                "dur": r["dur"], "pid": 0, "tid": 0,
                "args": {"syncs": r["syncs"], "step": r["step"],
                         **r["args"]}})
        elif r["type"] == "event":
            trace_events.append({
                "name": r["name"], "ph": "i", "ts": r["ts"], "s": "t",
                "pid": 0, "tid": 0,
                "args": {"step": r["step"], **r["args"]}})
    out = {"traceEvents": trace_events, "displayTimeUnit": "ms"}
    if summary is not None:
        out["otherData"] = {"sync_by_phase": summary["sync_by_phase"],
                            "sync_total": summary["sync_total"],
                            "schema_version": SCHEMA_VERSION}
    return out


def chrome_to_records(chrome: dict) -> list[dict]:
    """Chrome trace-event JSON → the native span/event records.

    Inverse of ``records_to_chrome`` for the fields the native schema
    defines (the round-trip contract tests/test_obs.py enforces).
    """
    records = []
    for ev in chrome.get("traceEvents", ()):
        args = dict(ev.get("args", {}))
        step = args.pop("step", None)
        if ev.get("ph") == "X":
            syncs = args.pop("syncs", 0)
            records.append({"v": SCHEMA_VERSION, "type": "span",
                            "name": ev["name"], "ts": ev["ts"],
                            "dur": ev["dur"], "syncs": syncs,
                            "step": step, "args": args})
        elif ev.get("ph") == "i":
            records.append({"v": SCHEMA_VERSION, "type": "event",
                            "name": ev["name"], "ts": ev["ts"],
                            "step": step, "args": args})
    return records
