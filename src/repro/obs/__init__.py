"""Unified observability layer (DESIGN.md §14).

Three jit-safe pieces, all host-side, all free when nothing is
installed:

  * ``SyncLedger`` (``ledger``) — the single sync-accounting path.
    Engine-loop host wrappers ``record(phase, syncs)`` the convergence
    counts the loops already return; benchmarks and reports read
    per-phase totals instead of re-deriving ad-hoc sums.
  * ``Tracer`` (``trace``) — span tracing of the serving loops with
    per-span wall-clock AND sync attribution; exports JSONL and Chrome
    trace-event JSON (Perfetto-loadable).
  * ``MetricsRegistry`` (``metrics``) — counters/gauges/histograms with
    per-tenant labels; ``percentile_line`` is the shared latency-report
    formatter (including the zero-sample path).

The hard contract, regression-tested in tests/test_obs.py: recording
adds ZERO engine syncs and leaves forest/tour/BCC state bit-identical
with tracing on vs off — instrumented wrappers always request the
counters that already ride every convergence loop's carry, and only the
host-side bookkeeping is conditional.
"""
from repro.obs.ledger import (SyncLedger, current_ledger, record,
                              recording)
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               METRICS_SCHEMA_VERSION, percentile_line)
from repro.obs.trace import (SCHEMA_VERSION, Tracer, chrome_to_records,
                             current_tracer, event, read_jsonl,
                             records_to_chrome, span)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "METRICS_SCHEMA_VERSION", "SCHEMA_VERSION", "SyncLedger", "Tracer",
    "chrome_to_records", "current_ledger", "current_tracer", "event",
    "percentile_line", "read_jsonl", "record", "recording",
    "records_to_chrome", "span",
]
