"""Metrics registry: counters, gauges, histograms (DESIGN.md §14).

Absorbs the hand-rolled percentile/counter reporting the serving loops
grew (``serve_stream``'s per-op latency dict, ``serve_fleet``'s
``_percentiles``, the ``ForestView`` refresh-latency lists, the
``ResilientStreamLoop`` telemetry counters) behind one registry with a
stable export schema:

  * ``Counter`` — monotonically increasing int (applied events, faults
    injected, quarantined events, ...);
  * ``Gauge``   — last-set value (live edges, components, residency);
  * ``Histogram`` — fixed log-spaced buckets plus exact sample
    percentiles (latencies; sample retention capped so a long soak
    can't grow without bound — bucket counts stay exact forever).

Metrics are keyed by (name, labels): the fleet axis labels per-tenant
series (``registry.counter("applied", tenant=3)``) without minting a
name per tenant. ``to_dict``/``write`` flush the registry as JSON
(stable sort order) for the ``--metrics-out`` flag.

``percentile_line`` is the shared latency-report formatter both serving
loops print through — including the zero-sample path ("no samples"
instead of handing ``np.percentile`` an empty list, the PR-8
regression, now a shared-path test).
"""
from __future__ import annotations

import json
import pathlib

import numpy as np

METRICS_SCHEMA_VERSION = 1

#: log-spaced default bucket upper bounds (milliseconds-oriented, but
#: unit-free): 13 buckets from 0.25 to 2^10, plus the +inf overflow.
DEFAULT_BUCKETS = tuple(0.25 * 2 ** i for i in range(13))

#: exact-percentile sample retention cap per histogram.
SAMPLE_CAP = 65536


def percentile_line(samples, *, unit: float = 1e3, width: int = 6,
                    count_suffix: bool = False,
                    empty_reason: str | None = None) -> str:
    """One p50/p95 latency line, shared by every serving report.

    ``samples`` are seconds (scaled by ``unit`` to ms). An empty sample
    list reports "no samples" (with ``empty_reason`` appended when
    given) instead of crashing the percentile math.
    """
    if not len(samples):
        return "no samples" if empty_reason is None \
            else f"no samples ({empty_reason})"
    ms = np.asarray(samples) * unit
    line = (f"p50 {np.percentile(ms, 50):{width}.2f} ms  "
            f"p95 {np.percentile(ms, 95):{width}.2f} ms")
    if count_suffix:
        line += f"  ({len(ms)} batches)"
    return line


class Counter:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def snapshot(self) -> dict:
        return {"value": self.value}


class Gauge:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v) -> None:
        self.value = float(v)

    def snapshot(self) -> dict:
        return {"value": self.value}


class Histogram:
    """Fixed-bucket histogram + capped raw samples for exact percentiles."""

    __slots__ = ("bounds", "bucket_counts", "count", "total", "min", "max",
                 "samples")

    def __init__(self, bounds=DEFAULT_BUCKETS) -> None:
        self.bounds = tuple(float(b) for b in bounds)
        self.bucket_counts = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.total = 0.0
        self.min = None
        self.max = None
        self.samples: list[float] = []

    def observe(self, x) -> None:
        x = float(x)
        i = int(np.searchsorted(self.bounds, x, side="left"))
        self.bucket_counts[i] += 1
        self.count += 1
        self.total += x
        self.min = x if self.min is None else min(self.min, x)
        self.max = x if self.max is None else max(self.max, x)
        if len(self.samples) < SAMPLE_CAP:
            self.samples.append(x)

    def percentile(self, q: float):
        if not self.samples:
            return None
        return float(np.percentile(np.asarray(self.samples), q))

    def snapshot(self) -> dict:
        return {"count": self.count, "sum": self.total,
                "min": self.min, "max": self.max,
                "buckets": {str(b): c for b, c in
                            zip(self.bounds + ("inf",),
                                self.bucket_counts)},
                "p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99)}


class MetricsRegistry:
    """Get-or-create metric instruments keyed by (name, labels)."""

    _TYPES = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}

    def __init__(self) -> None:
        self._metrics: dict[tuple, object] = {}

    def _get(self, kind: str, name: str, labels: dict, **kwargs):
        key = (name, tuple(sorted((k, str(v)) for k, v in labels.items())))
        m = self._metrics.get(key)
        if m is None:
            m = self._TYPES[kind](**kwargs)
            self._metrics[key] = m
        elif not isinstance(m, self._TYPES[kind]):
            raise TypeError(f"metric {name!r}{labels} already registered "
                            f"as {type(m).__name__}, not {kind}")
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels)

    def histogram(self, name: str, bounds=DEFAULT_BUCKETS,
                  **labels) -> Histogram:
        return self._get("histogram", name, labels, bounds=bounds)

    # -- export --------------------------------------------------------------

    def to_dict(self) -> dict:
        """The full registry as one JSON-able dict, stable-sorted."""
        out = []
        for (name, labels), m in sorted(self._metrics.items()):
            out.append({"name": name, "labels": dict(labels),
                        "type": type(m).__name__.lower(),
                        **m.snapshot()})
        return {"schema_version": METRICS_SCHEMA_VERSION, "metrics": out}

    def write(self, path) -> None:
        pathlib.Path(path).write_text(
            json.dumps(self.to_dict(), indent=1, sort_keys=True) + "\n")
