"""qwen3-1.7b [hf:Qwen/Qwen3 family] — 28L, d=2048, 16H GQA kv=8, d_ff=6144,
vocab=151936, qk_norm."""
from repro.configs import ArchSpec, LM_SHAPES
from repro.models.transformer import LMConfig


def make_config():
    return LMConfig(name="qwen3-1.7b", n_layers=28, d_model=2048, n_heads=16,
                    n_kv_heads=8, d_ff=6144, vocab=151936, qk_norm=True,
                    rope_theta=1e6, tie_embeddings=True)


def make_smoke_config():
    return LMConfig(name="qwen3-1.7b-smoke", n_layers=2, d_model=64,
                    n_heads=4, n_kv_heads=2, d_ff=96, vocab=256,
                    qk_norm=True, q_chunk=8, kv_chunk=8, tie_embeddings=True)


def get():
    return ArchSpec(arch_id="qwen3-1.7b", family="lm",
                    make_config=make_config,
                    make_smoke_config=make_smoke_config,
                    shapes=LM_SHAPES, fsdp=False,
                    notes="qk_norm on q/k heads (per-head RMSNorm)")
