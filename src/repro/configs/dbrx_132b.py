"""dbrx-132b [hf:databricks/dbrx-base] — MoE 40L, d=6144, 48H GQA kv=8,
d_ff=10752 per expert, 16 experts top-4, vocab=100352."""
from repro.configs import ArchSpec, LM_SHAPES
from repro.models.transformer import LMConfig


def make_config():
    return LMConfig(name="dbrx-132b", n_layers=40, d_model=6144, n_heads=48,
                    n_kv_heads=8, d_ff=10752, vocab=100352, n_experts=16,
                    top_k=4, rope_theta=5e5)


def make_smoke_config():
    return LMConfig(name="dbrx-smoke", n_layers=2, d_model=96, n_heads=6,
                    n_kv_heads=2, d_ff=168, vocab=256, n_experts=4, top_k=2,
                    q_chunk=8, kv_chunk=8)


def get():
    return ArchSpec(arch_id="dbrx-132b", family="lm",
                    make_config=make_config,
                    make_smoke_config=make_smoke_config,
                    shapes=LM_SHAPES, fsdp=True,
                    notes="132B params: FSDP x TP/EP mandatory (DESIGN §7)")
