"""dien [arXiv:1809.03672] — embed_dim=18, seq_len=100, gru_dim=108,
mlp=200-80, AUGRU interaction."""
from repro.configs import ArchSpec, RECSYS_SHAPES
from repro.models.dien import DIENConfig


def make_config():
    return DIENConfig(name="dien", embed_dim=18, seq_len=100, gru_dim=108,
                      mlp_dims=(200, 80), n_items=1_000_000, n_cates=10_000,
                      n_user_feats=100_000, user_hot=8)


def make_smoke_config():
    return DIENConfig(name="dien-smoke", embed_dim=8, seq_len=12, gru_dim=16,
                      mlp_dims=(24, 8), n_items=512, n_cates=32,
                      n_user_feats=128, user_hot=4)


def get():
    return ArchSpec(arch_id="dien", family="recsys", make_config=make_config,
                    make_smoke_config=make_smoke_config, shapes=RECSYS_SHAPES,
                    notes="embedding-bag substrate shared w/ RST scatter ops")
