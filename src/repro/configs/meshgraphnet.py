"""meshgraphnet [arXiv:2010.03409] — 15 layers, d=128, sum agg, 2-layer MLPs."""
from repro.configs import ArchSpec, GNN_SHAPES
from repro.models.gnn import MGNConfig


def make_config(d_in_node: int = 8):
    return MGNConfig(name="meshgraphnet", n_layers=15, d_hidden=128,
                     mlp_layers=2, d_in_node=d_in_node, d_in_edge=4, d_out=3)


def make_smoke_config():
    return MGNConfig(name="mgn-smoke", n_layers=3, d_hidden=16, mlp_layers=2,
                     d_in_node=8, d_in_edge=4, d_out=3)


def get():
    return ArchSpec(arch_id="meshgraphnet", family="gnn",
                    make_config=make_config,
                    make_smoke_config=make_smoke_config, shapes=GNN_SHAPES,
                    notes="encode-process-decode; edge+node MLP regime")
