"""minicpm-2b [arXiv:2404.06395; hf] — dense, 40L, d=2304, 36H (GQA kv=36),
d_ff=5760, vocab=122753; WSD schedule (llama-like)."""
from repro.configs import ArchSpec, LM_SHAPES
from repro.models.transformer import LMConfig


def make_config():
    return LMConfig(name="minicpm-2b", n_layers=40, d_model=2304, n_heads=36,
                    n_kv_heads=36, d_ff=5760, vocab=122753, rope_theta=1e4,
                    tie_embeddings=True)


def make_smoke_config():
    return LMConfig(name="minicpm-2b-smoke", n_layers=2, d_model=72,
                    n_heads=6, n_kv_heads=6, d_ff=144, vocab=256,
                    q_chunk=8, kv_chunk=8, tie_embeddings=True)


def get():
    return ArchSpec(arch_id="minicpm-2b", family="lm",
                    make_config=make_config,
                    make_smoke_config=make_smoke_config,
                    shapes=LM_SHAPES, fsdp=False,
                    notes="WSD schedule; tied embeddings per paper")
