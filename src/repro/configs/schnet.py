"""schnet [arXiv:1706.08566] — 3 interactions, d=64, rbf=300, cutoff=10."""
from repro.configs import ArchSpec, GNN_SHAPES
from repro.models.gnn import SchNetConfig


def make_config():
    return SchNetConfig(name="schnet", n_interactions=3, d_hidden=64,
                        n_rbf=300, cutoff=10.0)


def make_smoke_config():
    return SchNetConfig(name="schnet-smoke", n_interactions=2, d_hidden=16,
                        n_rbf=8, cutoff=5.0)


def get():
    return ArchSpec(arch_id="schnet", family="gnn", make_config=make_config,
                    make_smoke_config=make_smoke_config, shapes=GNN_SHAPES,
                    notes="triplet-free cfconv; positions synthesized for "
                          "non-molecular shapes (DESIGN §7)")
