"""dimenet [arXiv:2003.03123] — 6 blocks, d=128, bilinear=8, spherical=7,
radial=6."""
from repro.configs import ArchSpec, GNN_SHAPES
from repro.models.gnn import DimeNetConfig


def make_config():
    return DimeNetConfig(name="dimenet", n_blocks=6, d_hidden=128,
                         n_bilinear=8, n_spherical=7, n_radial=6)


def make_smoke_config():
    return DimeNetConfig(name="dimenet-smoke", n_blocks=2, d_hidden=16,
                         n_bilinear=4, n_spherical=3, n_radial=4)


def get():
    return ArchSpec(arch_id="dimenet", family="gnn", make_config=make_config,
                    make_smoke_config=make_smoke_config, shapes=GNN_SHAPES,
                    notes="triplet-gather regime; n_triplets=4*E cells")
