"""Architecture registry: ``get_arch(arch_id)`` → ArchSpec.

One module per assigned architecture; ids use dashes (CLI ``--arch``).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable

ARCH_IDS = (
    "minicpm-2b", "llama3.2-1b", "qwen3-1.7b", "moonshot-v1-16b-a3b",
    "dbrx-132b",
    "dimenet", "schnet", "meshgraphnet", "gat-cora",
    "dien",
)

_MODULES = {
    "minicpm-2b": "minicpm_2b",
    "llama3.2-1b": "llama3_2_1b",
    "qwen3-1.7b": "qwen3_1_7b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "dbrx-132b": "dbrx_132b",
    "dimenet": "dimenet",
    "schnet": "schnet",
    "meshgraphnet": "meshgraphnet",
    "gat-cora": "gat_cora",
    "dien": "dien",
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                       # "lm" | "gnn" | "recsys"
    make_config: Callable[[], Any]    # full (assigned) config
    make_smoke_config: Callable[[], Any]
    shapes: dict                      # shape_name → cell descriptor
    fsdp: bool = False                # LM only: FSDP param sharding
    notes: str = ""


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.get()


# Shared shape tables -------------------------------------------------------

LM_SHAPES = {
    "train_4k": dict(kind="train", batch=256, seq=4096),
    "prefill_32k": dict(kind="prefill", batch=32, seq=32768),
    "decode_32k": dict(kind="decode", batch=128, seq=32768),
    # Decode cost is linear in KV length (one query token); the spec's
    # full-attention skip applies to quadratic *prefill*, so we run this
    # cell with a sequence-sharded KV cache (DESIGN.md §7).
    "long_500k": dict(kind="decode", batch=1, seq=524288),
}

# GNN cells (padded to multiples of 512 so every mesh divides evenly).
GNN_SHAPES = {
    "full_graph_sm": dict(kind="train", n_nodes=2720, n_edges=10560,
                          d_feat=1433, n_graphs=1,
                          raw=dict(n_nodes=2708, n_edges=10556)),
    "minibatch_lg": dict(kind="train", n_nodes=172032, n_edges=169984,
                         d_feat=602, n_graphs=1, sampled=True,
                         raw=dict(n_nodes=232965, n_edges=114615892,
                                  batch_nodes=1024, fanout=(15, 10))),
    "ogb_products": dict(kind="train", n_nodes=2449408, n_edges=61859840,
                         d_feat=100, n_graphs=1,
                         raw=dict(n_nodes=2449029, n_edges=61859140)),
    "molecule": dict(kind="train", n_nodes=3840, n_edges=8192, d_feat=8,
                     n_graphs=128, raw=dict(n_nodes=30, n_edges=64,
                                            batch=128)),
}

RECSYS_SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="retrieval", batch=1, n_candidates=1000448,
                           raw=dict(n_candidates=1_000_000)),
}
