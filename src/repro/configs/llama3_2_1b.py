"""llama3.2-1b [hf:meta-llama/Llama-3.2-1B] — 16L, d=2048, 32H GQA kv=8,
d_ff=8192, vocab=128256."""
from repro.configs import ArchSpec, LM_SHAPES
from repro.models.transformer import LMConfig


def make_config():
    return LMConfig(name="llama3.2-1b", n_layers=16, d_model=2048, n_heads=32,
                    n_kv_heads=8, d_ff=8192, vocab=128256, rope_theta=5e5,
                    tie_embeddings=True)


def make_smoke_config():
    return LMConfig(name="llama3.2-1b-smoke", n_layers=2, d_model=64,
                    n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                    q_chunk=8, kv_chunk=8, tie_embeddings=True)


def get():
    return ArchSpec(arch_id="llama3.2-1b", family="lm",
                    make_config=make_config,
                    make_smoke_config=make_smoke_config,
                    shapes=LM_SHAPES, fsdp=False)
