"""gat-cora [arXiv:1710.10903] — 2L, d_hidden=8, 8 heads, attn aggregator."""
from repro.configs import ArchSpec, GNN_SHAPES
from repro.models.gnn import GATConfig


def make_config(d_in: int = 1433, n_classes: int = 7):
    return GATConfig(name="gat-cora", n_layers=2, d_hidden=8, n_heads=8,
                     d_in=d_in, n_classes=n_classes)


def make_smoke_config():
    return GATConfig(name="gat-smoke", n_layers=2, d_hidden=4, n_heads=2,
                     d_in=16, n_classes=3)


def get():
    return ArchSpec(arch_id="gat-cora", family="gnn", make_config=make_config,
                    make_smoke_config=make_smoke_config, shapes=GNN_SHAPES,
                    notes="SDDMM + segment-softmax regime; RST pipeline applies")
