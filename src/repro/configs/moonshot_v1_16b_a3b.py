"""moonshot-v1-16b-a3b [hf:moonshotai/Moonlight-16B-A3B] — MoE 48L, d=2048,
16H GQA kv=16, d_ff=1408 per expert, 64 experts top-6, vocab=163840."""
from repro.configs import ArchSpec, LM_SHAPES
from repro.models.transformer import LMConfig


def make_config():
    return LMConfig(name="moonshot-v1-16b-a3b", n_layers=48, d_model=2048,
                    n_heads=16, n_kv_heads=16, d_ff=1408, vocab=163840,
                    n_experts=64, top_k=6, rope_theta=5e4)


def make_smoke_config():
    return LMConfig(name="moonshot-smoke", n_layers=2, d_model=64, n_heads=4,
                    n_kv_heads=4, d_ff=48, vocab=256, n_experts=8, top_k=2,
                    q_chunk=8, kv_chunk=8)


def get():
    return ArchSpec(arch_id="moonshot-v1-16b-a3b", family="lm",
                    make_config=make_config,
                    make_smoke_config=make_smoke_config,
                    shapes=LM_SHAPES, fsdp=True,
                    notes="fine-grained MoE; FSDP x EP x TP")
