"""LLaMA-family transformer LM: dense and MoE variants.

Covers the five assigned LM architectures through one config dataclass:
  minicpm-2b     dense, GQA kv=36 (MHA-like), WSD schedule
  llama3.2-1b    dense, GQA kv=8
  qwen3-1.7b     dense, GQA kv=8, qk_norm
  moonshot-v1    MoE 64 experts top-6 (fine-grained, d_ff=1408)
  dbrx-132b      MoE 16 experts top-4

Implementation notes (all driven by the dry-run memory budget):
  * layers run under ``jax.lax.scan`` with per-layer remat
    (``jax.checkpoint``) — compact HLO, activation memory O(1) in depth;
  * attention is **chunked online-softmax** (flash-style in pure JAX):
    queries processed in blocks against the full K/V with running
    (max, sum) statistics — no S×S score materialization, which is what
    lets prefill_32k compile inside 16 GB/chip;
  * decode path takes a KV cache pytree; for ``long_500k`` the cache is
    sequence-sharded over the ``data`` mesh axis (sequence parallelism) and
    the per-step attention is a KV-chunked scan;
  * MoE dispatch is sort-free "dense top-k einsum over capacity buckets":
    tokens are bucketed per expert by cumulative position (deterministic,
    shardable over the expert axis), dropped tokens fall back to residual.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    # MoE (n_experts == 0 → dense)
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # attention
    qk_norm: bool = False
    rope_theta: float = 1e4
    # runtime
    dtype: Any = jnp.bfloat16
    q_chunk: int = 1024
    kv_chunk: int = 2048
    remat: bool = True
    remat_groups: int = 0      # 0 = flat per-layer remat; G>0 = 2-level
    tie_embeddings: bool = False
    # Activation sharding: (batch_axes, seq_axis) mesh-axis names. When set,
    # the residual stream is constrained to P(batch_axes, seq_axis, None) —
    # sequence parallelism between attention blocks — and MoE buckets to
    # P(expert_axis, None, batch_axes). Tuples of strings → hashable.
    act_batch_axes: tuple = ()
    act_seq_axis: Any = None
    moe_expert_axis: Any = None
    # Token-chunked MoE dispatch: bounds the [E, cap, d] bucket working set
    # (and the GSPMD scatter-fallback payloads) to one chunk at a time.
    moe_chunk: int = 65536

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to 256 so embedding tables shard over any mesh
        axis (standard practice; padded classes are ordinary softmax slots
        that targets never index)."""
        return -(-self.vocab // 256) * 256

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def param_count(self) -> int:
        """Total parameters (embeddings counted once if tied)."""
        d, f = self.d_model, self.d_ff
        attn = d * (self.n_heads * self.d_head) + 2 * d * (self.n_kv_heads * self.d_head) \
            + (self.n_heads * self.d_head) * d
        if self.is_moe:
            mlp = 3 * d * f * self.n_experts
        else:
            mlp = 3 * d * f
        per_layer = attn + mlp + 2 * d
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + d

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k experts only)."""
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_mlp = 3 * d * f * self.n_experts
        active_mlp = 3 * d * f * self.top_k
        return self.param_count() - self.n_layers * (dense_mlp - active_mlp)


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_params(cfg: LMConfig, key: jax.Array) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    hq = cfg.n_heads * cfg.d_head
    hkv = cfg.n_kv_heads * cfg.d_head
    k_emb, k_layers, k_out = jax.random.split(key, 3)

    def norm_init(shape, key, fan_in):
        return (jax.random.normal(key, shape, jnp.float32)
                * (fan_in ** -0.5)).astype(cfg.dtype)

    ks = jax.random.split(k_layers, 10)
    L = cfg.n_layers

    def stack(shape, key, fan_in):
        return norm_init((L,) + shape, key, fan_in)

    layer = {
        "wq": stack((d, hq), ks[0], d),
        "wk": stack((d, hkv), ks[1], d),
        "wv": stack((d, hkv), ks[2], d),
        "wo": stack((hq, d), ks[3], hq),
        "ln_attn": jnp.ones((L, d), jnp.float32),
        "ln_mlp": jnp.ones((L, d), jnp.float32),
    }
    if cfg.qk_norm:
        layer["q_norm"] = jnp.ones((L, cfg.d_head), jnp.float32)
        layer["k_norm"] = jnp.ones((L, cfg.d_head), jnp.float32)
    if cfg.is_moe:
        layer["router"] = norm_init((L, d, cfg.n_experts), ks[4], d)
        layer["w_gate"] = stack((cfg.n_experts, d, f), ks[5], d)
        layer["w_up"] = stack((cfg.n_experts, d, f), ks[6], d)
        layer["w_down"] = stack((cfg.n_experts, f, d), ks[7], f)
    else:
        layer["w_gate"] = stack((d, f), ks[5], d)
        layer["w_up"] = stack((d, f), ks[6], d)
        layer["w_down"] = stack((f, d), ks[7], f)

    params = {
        "embed": norm_init((cfg.vocab_padded, d), k_emb, d),
        "layers": layer,
        "ln_out": jnp.ones((d,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = norm_init((d, cfg.vocab_padded), k_out, d)
    return params


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def _constrain_act(cfg: "LMConfig", x: jnp.ndarray) -> jnp.ndarray:
    """Sequence-parallel residual stream: P(batch_axes, seq_axis, None)."""
    if not cfg.act_batch_axes and cfg.act_seq_axis is None:
        return x
    from jax.sharding import PartitionSpec as P
    spec = P(cfg.act_batch_axes or None, cfg.act_seq_axis, None)
    return jax.lax.with_sharding_constraint(x, spec)


def _constrain_moe_buckets(cfg: "LMConfig", b: jnp.ndarray) -> jnp.ndarray:
    """Buckets [E, cap, d]: experts over the model axis (EP), capacity over
    the data axes (all-to-all dispatch), d replicated. The FSDP-stored
    expert weights are all-gathered per layer (``_gather_moe_weight``) so
    the expert einsum contracts shard-local — gathering ~400 MB of weights
    beats psum-ing multi-GB cap×d_ff partials by ~300×."""
    if cfg.moe_expert_axis is None:
        return b
    from jax.sharding import PartitionSpec as P
    spec = P(cfg.moe_expert_axis, None, cfg.act_batch_axes or None)
    return jax.lax.with_sharding_constraint(b, spec)


def _gather_moe_weight(cfg: "LMConfig", w: jnp.ndarray) -> jnp.ndarray:
    """FSDP un-shard: [E, d, f] weight → experts sharded, d/f gathered."""
    if cfg.moe_expert_axis is None:
        return w
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        w, P(cfg.moe_expert_axis, None, None))


def _constrain_moe_tokens(cfg: "LMConfig", x: jnp.ndarray) -> jnp.ndarray:
    """Flat token table [T, d]: T = batch×seq merges the batch axes with
    the sequence-parallel axis."""
    if not cfg.act_batch_axes and cfg.act_seq_axis is None:
        return x
    from jax.sharding import PartitionSpec as P
    axes = tuple(cfg.act_batch_axes)
    if cfg.act_seq_axis is not None:
        axes = axes + (cfg.act_seq_axis,)
    return jax.lax.with_sharding_constraint(x, P(axes or None, None))


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * scale * w).astype(x.dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., S, H, Dh]; positions: [..., S]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def chunked_attention(q, k, v, *, causal: bool, q_offset, q_chunk: int,
                      kv_chunk: int) -> jnp.ndarray:
    """Flash-style online-softmax attention in pure JAX.

    q: [B, Sq, Hq, Dh]; k, v: [B, Skv, Hkv, Dh]; GQA by head replication
    factor Hq // Hkv. Never materializes Sq × Skv scores: scans KV chunks
    with running (max, sum, acc) statistics, queries processed in blocks.
    """
    b, sq, hq, dh = q.shape
    _, skv, hkv, _ = k.shape
    rep = hq // hkv
    scale = dh ** -0.5
    n_q = max(sq // q_chunk, 1)
    qc = sq // n_q
    n_kv = max(skv // kv_chunk, 1)
    kvc = skv // n_kv

    q = q.reshape(b, n_q, qc, hq, dh)
    k = k.reshape(b, n_kv, kvc, hkv, dh)
    v = v.reshape(b, n_kv, kvc, hkv, dh)

    # vmap over batch; KV chunks scanned with online-softmax statistics.
    def per_batch(qb, kb, vb):
        def scan_body(_, qi):
            def kv_step(carry, kj):
                m, l, acc = carry
                k_blk = kb[kj]
                v_blk = vb[kj]
                k_pos = kj * kvc + jnp.arange(kvc)
                krep = jnp.repeat(k_blk, rep, axis=1)
                vrep = jnp.repeat(v_blk, rep, axis=1)
                q_blk = qb[qi]
                q_pos = q_offset + qi * qc + jnp.arange(qc)
                s = jnp.einsum("qhd,khd->hqk", q_blk, krep).astype(jnp.float32) * scale
                if causal:
                    mask = q_pos[:, None] >= k_pos[None, :]
                    s = jnp.where(mask[None], s, -1e30)
                m_new = jnp.maximum(m, s.max(axis=-1))
                p = jnp.exp(s - m_new[..., None])
                alpha = jnp.exp(m - m_new)
                l_new = l * alpha + p.sum(axis=-1)
                acc_new = acc * alpha[..., None] + jnp.einsum(
                    "hqk,khd->hqd", p.astype(vrep.dtype), vrep).astype(jnp.float32)
                return (m_new, l_new, acc_new), None

            m0 = jnp.full((hq, qc), -1e30, jnp.float32)
            l0 = jnp.zeros((hq, qc), jnp.float32)
            acc0 = jnp.zeros((hq, qc, dh), jnp.float32)
            (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, acc0),
                                          jnp.arange(n_kv))
            out = acc / jnp.maximum(l[..., None], 1e-30)
            return None, out.transpose(1, 0, 2)

        _, outs = jax.lax.scan(scan_body, None, jnp.arange(n_q))
        return outs.reshape(sq, hq, dh)

    out = jax.vmap(per_batch)(q, k, v)
    return out.astype(q.dtype)


import contextvars

_LM_MESH: contextvars.ContextVar = contextvars.ContextVar("lm_mesh",
                                                          default=None)


def set_lm_mesh(mesh) -> None:
    """Mesh handle for the shard_map MoE path (set by the step factory)."""
    _LM_MESH.set(mesh)


def moe_block_shard_map(x, router_w, w_gate, w_up, w_down, cfg: LMConfig,
                        mesh):
    """Expert-parallel MoE via shard_map (beyond-paper §Perf P1-i7).

    GSPMD auto-partitioning of the scatter/gather dispatch falls back to
    replicate+all-reduce (measured: 40× einsum overcompute — every chip
    ran the FULL per-expert capacity — and ~2 TB/chip of fallback
    all-reduce on dbrx train_4k). This path expresses the parallelism
    explicitly instead:

      * tokens stay sharded over the data axes; the (sequence-parallel)
        model-axis shard of the residual is all-gathered once per layer;
      * each model rank routes all of its data-shard's tokens but buckets
        ONLY the experts it owns (E / |model| each) — dispatch is a purely
        LOCAL scatter, so no GSPMD fallback exists by construction;
      * expert weights are FSDP-stored (d over data) and all-gathered
        shard-locally before the GEMM (~400 MB/layer);
      * each rank's partial output (its experts' contributions) is
        combined with one reduce-scatter over the model axis — restoring
        the sequence-parallel layout for the next block.

    Per-chip per-layer collective: all-gather + reduce-scatter of one
    residual slice + 3 weight gathers — versus the fallback's multi-GB
    all-reduces per scatter/gather pair.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    da = tuple(cfg.act_batch_axes)
    seq_ax = cfg.act_seq_axis
    model_ax = cfg.moe_expert_axis
    e_total, k = cfg.n_experts, cfg.top_k
    model_size = mesh.shape[model_ax]
    e_per = e_total // model_size
    assert e_total % model_size == 0

    def local_fn(x_l, rw, wg_l, wu_l, wd_l):
        # x_l: [b_l, s_l, d]; w*_l: expert shard with d split over 'data'.
        wg = jax.lax.all_gather(wg_l, "data", axis=1, tiled=True)
        wu = jax.lax.all_gather(wu_l, "data", axis=1, tiled=True)
        wd = jax.lax.all_gather(wd_l, "data", axis=2, tiled=True)
        if seq_ax is not None:
            x_full = jax.lax.all_gather(x_l, seq_ax, axis=1, tiled=True)
        else:
            x_full = x_l
        bl, s, d = x_full.shape
        t = bl * s
        xf = x_full.reshape(t, d)

        logits = xf.astype(jnp.float32) @ rw.astype(jnp.float32)
        gates = jax.nn.softmax(logits, axis=-1)
        top_g, top_e = jax.lax.top_k(gates, k)
        top_g = top_g / jnp.maximum(top_g.sum(-1, keepdims=True), 1e-9)

        # This rank owns experts [ridx·e_per, (ridx+1)·e_per).
        ridx = jax.lax.axis_index(model_ax)
        local_e = top_e - ridx * e_per
        mine = (local_e >= 0) & (local_e < e_per)
        le_safe = jnp.where(mine, local_e, 0)

        cap = int(max(1, round(t * k / e_total * cfg.capacity_factor)))
        cap = -(-cap // 8) * 8
        onehot = (jax.nn.one_hot(le_safe, e_per, dtype=jnp.int32)
                  * mine[..., None])
        flat = onehot.reshape(t * k, e_per)
        pos = jnp.sum(flat * (jnp.cumsum(flat, axis=0) - flat),
                      axis=-1).reshape(t, k)
        keep = mine & (pos < cap)

        e_idx = jnp.where(keep, le_safe, e_per)          # e_per → dropped
        p_idx = jnp.where(keep, pos, 0)
        buckets = jnp.zeros((e_per, cap, d), xf.dtype)
        for j in range(k):                               # LOCAL scatter
            buckets = buckets.at[e_idx[:, j], p_idx[:, j]].add(
                xf, mode="drop")

        g = jnp.einsum("ecd,edf->ecf", buckets, wg)
        u = jnp.einsum("ecd,edf->ecf", buckets, wu)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(xf.dtype) * u
        y = jnp.einsum("ecf,efd->ecd", h, wd)            # [e_per, cap, d]

        out = jnp.zeros_like(xf)
        for j in range(k):                               # LOCAL combine
            yj = y[e_idx[:, j], p_idx[:, j]]
            yj = jnp.where(keep[:, j:j + 1], yj, 0)
            out = out + yj * top_g[:, j:j + 1].astype(xf.dtype)
        out = out.reshape(bl, s, d)
        if seq_ax is not None:
            return jax.lax.psum_scatter(out, seq_ax, scatter_dimension=1,
                                        tiled=True)
        return jax.lax.psum(out, model_ax)

    x_spec = P(da or None, seq_ax, None)
    return shard_map(
        local_fn, mesh=mesh,
        in_specs=(x_spec, P(None, None),
                  P(model_ax, "data", None), P(model_ax, "data", None),
                  P(model_ax, None, "data")),
        out_specs=x_spec,
        check_rep=False,
    )(x, router_w, w_gate, w_up, w_down)


def moe_block(x, router_w, w_gate, w_up, w_down, cfg: LMConfig):
    """Token-chunked capacity-bucketed top-k MoE (deterministic).

    x: [B, S, d]. Returns [B, S, d]. Tokens beyond an expert's per-chunk
    capacity are dropped (standard Switch behavior). Chunking bounds the
    bucket working set: at dbrx train scale the unchunked [E, cap, d]
    dispatch buffers (plus their GSPMD scatter fallbacks) peak >100 GiB.

    Chunks split the (data-sharded) batch dim into contiguous per-shard
    blocks × chunk index — i.e. ``[bc, n_ch, S, d]`` — so every chunk
    carries one batch row per data shard and the full (model-sharded)
    sequence: perfectly load-balanced, zero resharding.
    """
    b, s, d = x.shape
    rows_per_chunk = max(1, cfg.moe_chunk // s)
    n_ch = b // rows_per_chunk if rows_per_chunk else 1
    if n_ch > 1 and b % n_ch == 0:
        bc = b // n_ch
        view = x.reshape(bc, n_ch, s, d)
        xs = jnp.moveaxis(view, 1, 0)                  # [n_ch, bc, S, d]

        @jax.checkpoint
        def one_chunk(x_blk):
            flat = _constrain_moe_tokens(cfg, x_blk.reshape(bc * s, d))
            y = _moe_block_flat(flat, router_w, w_gate, w_up, w_down, cfg)
            return y.reshape(bc, s, d)

        ys = jax.lax.map(one_chunk, xs)
        return jnp.moveaxis(ys, 0, 1).reshape(b, s, d)
    flat = _moe_block_flat(x.reshape(b * s, d), router_w, w_gate, w_up,
                           w_down, cfg)
    return flat.reshape(b, s, d)


def _moe_block_flat(x, router_w, w_gate, w_up, w_down, cfg: LMConfig):
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = int(max(1, round(t * k / e * cfg.capacity_factor)))
    # Keep MXU dims aligned.
    cap = -(-cap // 8) * 8

    x = _constrain_moe_tokens(cfg, x)
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)             # [T, E]
    top_g, top_e = jax.lax.top_k(gates, k)              # [T, k]
    top_g = top_g / jnp.maximum(top_g.sum(-1, keepdims=True), 1e-9)

    # Position of each (token, slot) within its expert's bucket.
    onehot = jax.nn.one_hot(top_e, e, dtype=jnp.int32)   # [T, k, E]
    flat = onehot.reshape(t * k, e)
    pos_in_e = jnp.cumsum(flat, axis=0) - flat           # [T·k, E]
    pos = jnp.sum(flat * pos_in_e, axis=-1).reshape(t, k)
    keep = pos < cap

    # Scatter tokens into [E, cap, d] buckets — one scatter per top-k slot
    # so no [T·k, d] staging copy of x ever materializes (at dbrx scale
    # that buffer is 96 GiB/chip).
    e_idx = jnp.where(keep, top_e, e)                    # e → dropped
    p_idx = jnp.where(keep, pos, 0)
    buckets = jnp.zeros((e, cap, d), x.dtype)
    for j in range(k):
        buckets = buckets.at[e_idx[:, j], p_idx[:, j]].add(x, mode="drop")
    buckets = _constrain_moe_buckets(cfg, buckets)

    # Expert FFN on buckets (einsum over the expert axis → EP-shardable;
    # weights FSDP-gathered to shard-local-full d/f first).
    w_gate = _gather_moe_weight(cfg, w_gate)
    w_up = _gather_moe_weight(cfg, w_up)
    w_down = _gather_moe_weight(cfg, w_down)
    g = jnp.einsum("ecd,edf->ecf", buckets, w_gate)
    u = jnp.einsum("ecd,edf->ecf", buckets, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y = jnp.einsum("ecf,efd->ecd", h, w_down)            # [E, cap, d]
    y = _constrain_moe_buckets(cfg, y)

    # Combine: per-slot gather + gate-weighted accumulate (again no T·k
    # staging buffer).
    out = jnp.zeros_like(x)
    for j in range(k):
        yj = y[e_idx[:, j], p_idx[:, j]]                 # [T, d]
        yj = jnp.where(keep[:, j:j + 1], yj, 0)
        out = out + _constrain_moe_tokens(
            cfg, yj * top_g[:, j:j + 1].astype(x.dtype))
    return out


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _layer_fwd(cfg: LMConfig, x, lp, positions, kv_cache=None):
    """One transformer layer. x: [B, S, d]. Returns (x, new_kv)."""
    b, s, d = x.shape
    h = rms_norm(x, lp["ln_attn"])
    q = (h @ lp["wq"]).reshape(b, s, cfg.n_heads, cfg.d_head)
    k = (h @ lp["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    v = (h @ lp["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    if cfg.qk_norm:
        q = rms_norm(q, lp["q_norm"])
        k = rms_norm(k, lp["k_norm"])
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if kv_cache is None:
        attn = chunked_attention(q, k, v, causal=True, q_offset=0,
                                 q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
        new_kv = None
    else:
        ck, cv = kv_cache                                # [B, Skv, Hkv, Dh]
        # Decode: append is the caller's job (functional update outside);
        # here the cache already contains the new token's K/V.
        attn = chunked_attention(q, ck, cv, causal=False, q_offset=0,
                                 q_chunk=1, kv_chunk=cfg.kv_chunk)
        new_kv = (k, v)

    x = x + (attn.reshape(b, s, -1) @ lp["wo"])
    h2 = rms_norm(x, lp["ln_mlp"])
    if cfg.is_moe:
        mesh = _LM_MESH.get()
        if mesh is not None and cfg.moe_expert_axis is not None:
            y = moe_block_shard_map(h2, lp["router"], lp["w_gate"],
                                    lp["w_up"], lp["w_down"], cfg, mesh)
        else:
            y = moe_block(h2, lp["router"], lp["w_gate"],
                          lp["w_up"], lp["w_down"], cfg)
    else:
        g = jax.nn.silu((h2 @ lp["w_gate"]).astype(jnp.float32)).astype(x.dtype)
        y = (g * (h2 @ lp["w_up"])) @ lp["w_down"]
    return (x + y).astype(cfg.dtype), new_kv


def forward_hidden(cfg: LMConfig, params, tokens):
    """Backbone forward → post-ln hidden states [B, S, d].

    Layer stack runs under ``lax.scan``; with ``remat_groups = G > 0`` the
    scan is two-level (G outer groups × L/G inner layers, both
    checkpointed) which cuts the residual stash from L to G + L/G slices —
    the classic √L memory trade for one extra forward recompute.
    """
    b, s = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    x = _constrain_act(cfg, x)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def one_layer(x, lp):
        fn = lambda x_, lp_: _constrain_act(
            cfg, _layer_fwd(cfg, x_, lp_, positions)[0])
        if cfg.remat:
            fn = jax.checkpoint(fn)
        return fn(x, lp), None

    g = cfg.remat_groups
    if g and cfg.n_layers % g == 0:
        per = cfg.n_layers // g
        grouped = jax.tree.map(
            lambda a: a.reshape((g, per) + a.shape[1:]), params["layers"])

        @jax.checkpoint
        def group(x, gp):
            x, _ = jax.lax.scan(one_layer, x, gp)
            return x

        def outer(x, gp):
            return group(x, gp), None

        x, _ = jax.lax.scan(outer, x, grouped)
    else:
        x, _ = jax.lax.scan(one_layer, x, params["layers"])
    return rms_norm(x, params["ln_out"])


def _unembed(cfg: LMConfig, params):
    unemb = params.get("unembed")
    if unemb is None:
        unemb = params["embed"].T.astype(cfg.dtype)
    return unemb


def forward(cfg: LMConfig, params, tokens):
    """Training/prefill forward → logits [B, S, vocab]."""
    return forward_hidden(cfg, params, tokens) @ _unembed(cfg, params)


def lm_loss(cfg: LMConfig, params, tokens, targets, *, loss_chunk: int = 512):
    """Cross-entropy with SEQ-CHUNKED logits.

    fp32 logits for train_4k are B·S·V ≈ 0.5 TB global — materializing them
    (plus the softmax cotangent) blows the 16 GB/chip HBM budget. Chunking
    the unembed+logsumexp over sequence blocks under ``jax.checkpoint``
    keeps peak logits memory at B·chunk·V/chips and recomputes them in the
    backward pass (one extra unembed matmul — ~3% of step FLOPs).
    """
    x = forward_hidden(cfg, params, tokens)            # [B, S, d]
    unemb = _unembed(cfg, params)
    b, s, d = x.shape
    n_chunks = max(s // loss_chunk, 1)
    c = s // n_chunks
    xc = x.reshape(b, n_chunks, c, d).swapaxes(0, 1)   # [n, B, c, d]
    tc = targets.reshape(b, n_chunks, c).swapaxes(0, 1)

    @jax.checkpoint
    def chunk_loss(x_blk, t_blk):
        logits = (x_blk @ unemb).astype(jnp.float32)   # [B, c, V]
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t_blk[..., None], axis=-1)[..., 0]
        return jnp.sum(logz - gold)

    def body(acc, xs):
        x_blk, t_blk = xs
        return acc + chunk_loss(x_blk, t_blk), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, tc))
    return total / (b * s)


# ---------------------------------------------------------------------------
# Decode (serving)
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: LMConfig, batch: int, seq_len: int):
    shape = (cfg.n_layers, batch, seq_len, cfg.n_kv_heads, cfg.d_head)
    return {"k": jnp.zeros(shape, cfg.dtype), "v": jnp.zeros(shape, cfg.dtype),
            "len": jnp.zeros((), jnp.int32)}


def decode_step(cfg: LMConfig, params, token, cache):
    """One decode step. token: [B] int32 → (logits [B, vocab], new cache).

    Attention runs against the *full static cache length* with masking by
    ``cache['len']`` folded into the KV values being zero-initialized and a
    mask on positions ≥ len. The cache has static shape [L, B, S, Hkv, Dh]
    (sequence-shardable over the data axis for long_500k).
    """
    b = token.shape[0]
    x = params["embed"][token][:, None, :].astype(cfg.dtype)   # [B, 1, d]
    pos = jnp.broadcast_to(cache["len"], (b, 1))
    s_max = cache["k"].shape[2]

    def body(carry, inputs):
        x, = carry
        lp, ck, cv = inputs
        h = rms_norm(x, lp["ln_attn"])
        q = (h @ lp["wq"]).reshape(b, 1, cfg.n_heads, cfg.d_head)
        k = (h @ lp["wk"]).reshape(b, 1, cfg.n_kv_heads, cfg.d_head)
        v = (h @ lp["wv"]).reshape(b, 1, cfg.n_kv_heads, cfg.d_head)
        if cfg.qk_norm:
            q = rms_norm(q, lp["q_norm"])
            k = rms_norm(k, lp["k_norm"])
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k, cache["len"], axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v, cache["len"], axis=1)

        # Masked decode attention over the static-length cache.
        rep = cfg.n_heads // cfg.n_kv_heads
        krep = jnp.repeat(ck, rep, axis=2)               # [B, S, Hq, Dh]
        vrep = jnp.repeat(cv, rep, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, krep).astype(jnp.float32)
        s = s * (cfg.d_head ** -0.5)
        kpos = jnp.arange(s_max)
        s = jnp.where((kpos <= cache["len"])[None, None, None, :], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        attn = jnp.einsum("bhqk,bkhd->bqhd", p.astype(vrep.dtype), vrep)

        x = x + (attn.reshape(b, 1, -1) @ lp["wo"])
        h2 = rms_norm(x, lp["ln_mlp"])
        if cfg.is_moe:
            y = moe_block(h2, lp["router"], lp["w_gate"],
                          lp["w_up"], lp["w_down"], cfg)
        else:
            g = jax.nn.silu((h2 @ lp["w_gate"]).astype(jnp.float32)).astype(x.dtype)
            y = (g * (h2 @ lp["w_up"])) @ lp["w_down"]
        return (x + y,), (ck, cv)

    (x,), (new_k, new_v) = jax.lax.scan(
        body, (x,), (params["layers"], cache["k"], cache["v"]))
    x = rms_norm(x, params["ln_out"])
    unemb = params.get("unembed")
    if unemb is None:
        unemb = params["embed"].T.astype(cfg.dtype)
    logits = (x @ unemb)[:, 0, :cfg.vocab]
    new_cache = {"k": new_k, "v": new_v, "len": cache["len"] + 1}
    return logits.astype(jnp.float32), new_cache
