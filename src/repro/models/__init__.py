"""Assigned architectures: LM transformers (dense + MoE), GNNs, recsys."""
