"""GNN architectures: GAT, SchNet, DimeNet, MeshGraphNet.

Message passing is built on ``jax.ops.segment_sum``-family scatter ops over
an explicit edge index (JAX has no sparse SpMM beyond BCOO — the
scatter/gather substrate IS part of the system, shared with the RST
kernels). All shapes are static: graphs are padded to fixed (N, E[, T])
with sentinel indices == N (dropped by scatter ``mode='drop'``).

Kernel regimes per the taxonomy:
  GAT           SDDMM edge scores → segment-softmax → weighted scatter-sum
  SchNet        RBF edge filters (cfconv) → scatter-sum
  DimeNet       triplet gather (k→j→i) with angular×radial basis → bilinear
  MeshGraphNet  edge+node MLPs, encode-process-decode, sum aggregation

The RST library runs in these models' data pipeline (component detection +
RST-based node reordering — see ``repro.data.partition``).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Batched graph container (fixed shapes; pad with src == dst == n_nodes)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class GraphBatch:
    n_nodes: int                      # static (includes padding)
    node_feat: jnp.ndarray            # [N, F] float or [N] int (atom types)
    src: jnp.ndarray                  # [E] int32
    dst: jnp.ndarray                  # [E] int32
    positions: jnp.ndarray | None = None    # [N, 3]
    graph_id: jnp.ndarray | None = None     # [N] int32 (molecule batching)
    n_graphs: int = 1                 # static
    trip_in: jnp.ndarray | None = None      # [T] edge id (k→j)
    trip_out: jnp.ndarray | None = None     # [T] edge id (j→i)

    def tree_flatten(self):
        children = (self.node_feat, self.src, self.dst, self.positions,
                    self.graph_id, self.trip_in, self.trip_out)
        return children, (self.n_nodes, self.n_graphs)

    @classmethod
    def tree_unflatten(cls, aux, children):
        nf, src, dst, pos, gid, ti, to = children
        return cls(n_nodes=aux[0], node_feat=nf, src=src, dst=dst,
                   positions=pos, graph_id=gid, n_graphs=aux[1],
                   trip_in=ti, trip_out=to)


import contextvars

# Mesh axes for activation sharding constraints (set by the step factory
# for full-scale cells; unset → no constraints, e.g. smoke tests).
_GNN_DATA_AXES: contextvars.ContextVar[tuple] = contextvars.ContextVar(
    "gnn_data_axes", default=())


def set_gnn_data_axes(axes: tuple):
    _GNN_DATA_AXES.set(tuple(axes))


def _constrain_rows(x: jnp.ndarray) -> jnp.ndarray:
    """Shard the leading (node/edge/triplet) dim over the data axes."""
    axes = _GNN_DATA_AXES.get()
    if not axes:
        return x
    from jax.sharding import PartitionSpec as P
    spec = P(axes, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def scatter_sum(values: jnp.ndarray, index: jnp.ndarray, n: int) -> jnp.ndarray:
    """Σ over edges into nodes; out-of-range (padding) indices dropped."""
    out = jnp.zeros((n,) + values.shape[1:], values.dtype)
    return _constrain_rows(out.at[index].add(values, mode="drop"))


def segment_softmax(scores: jnp.ndarray, index: jnp.ndarray, n: int):
    """Softmax over incoming edges per node. scores: [E, H]."""
    neg_inf = jnp.asarray(-1e30, scores.dtype)
    mx = jnp.full((n,) + scores.shape[1:], neg_inf, scores.dtype)
    mx = mx.at[index].max(scores, mode="drop")
    ex = jnp.exp(scores - mx[jnp.clip(index, 0, n - 1)])
    ex = jnp.where((index < n)[:, None], ex, 0)
    den = scatter_sum(ex, index, n)
    return ex / jnp.maximum(den[jnp.clip(index, 0, n - 1)], 1e-16)


def _mlp(params: list, x: jnp.ndarray, act=jax.nn.relu,
         final_act: bool = False) -> jnp.ndarray:
    for i, (w, b) in enumerate(params):
        x = x @ w + b
        if i + 1 < len(params) or final_act:
            x = act(x)
    return x


def _init_mlp(key, dims, dtype=jnp.float32):
    keys = jax.random.split(key, len(dims) - 1)
    return [((jax.random.normal(k, (a, b), jnp.float32) * (a ** -0.5)
              ).astype(dtype), jnp.zeros((b,), dtype))
            for k, a, b in zip(keys, dims[:-1], dims[1:])]


# ---------------------------------------------------------------------------
# GAT  [arXiv:1710.10903] — n_layers=2, d_hidden=8, n_heads=8, attn agg
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GATConfig:
    name: str = "gat-cora"
    n_layers: int = 2
    d_hidden: int = 8
    n_heads: int = 8
    d_in: int = 1433
    n_classes: int = 7
    dtype: Any = jnp.float32


def gat_init(cfg: GATConfig, key):
    keys = jax.random.split(key, cfg.n_layers)
    layers = []
    d_in = cfg.d_in
    for i, k in enumerate(keys):
        heads = cfg.n_heads
        d_out = cfg.d_hidden if i + 1 < cfg.n_layers else cfg.n_classes
        kw, ka1, ka2 = jax.random.split(k, 3)
        layers.append({
            "w": (jax.random.normal(kw, (d_in, heads, d_out), jnp.float32)
                  * d_in ** -0.5).astype(cfg.dtype),
            "a_src": jnp.zeros((heads, d_out), cfg.dtype),
            "a_dst": jnp.zeros((heads, d_out), cfg.dtype),
        })
        d_in = heads * d_out
    return {"layers": layers}


def gat_forward(cfg: GATConfig, params, g: GraphBatch) -> jnp.ndarray:
    n = g.n_nodes
    x = g.node_feat.astype(cfg.dtype)
    for i, lp in enumerate(params["layers"]):
        h = _constrain_rows(jnp.einsum("nf,fhd->nhd", x, lp["w"]))  # [N, H, D]
        e_src = jnp.sum(h * lp["a_src"], -1)               # [N, H]
        e_dst = jnp.sum(h * lp["a_dst"], -1)
        src_safe = jnp.clip(g.src, 0, n - 1)
        dst_safe = jnp.clip(g.dst, 0, n - 1)
        scores = jax.nn.leaky_relu(
            e_src[src_safe] + e_dst[dst_safe], 0.2)        # [E, H]
        alpha = segment_softmax(scores, g.dst, n)          # [E, H]
        msg = _constrain_rows(h[src_safe] * alpha[..., None])  # [E, H, D]
        agg = scatter_sum(jnp.where((g.dst < n)[:, None, None], msg, 0),
                          g.dst, n)                        # [N, H, D]
        last = i + 1 == len(params["layers"])
        x = agg.mean(1) if last else jax.nn.elu(agg.reshape(n, -1))
    return x                                                # [N, n_classes]


# ---------------------------------------------------------------------------
# SchNet  [arXiv:1706.08566] — 3 interactions, d=64, rbf=300, cutoff=10
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SchNetConfig:
    name: str = "schnet"
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0
    n_atom_types: int = 100
    dtype: Any = jnp.float32


def _ssp(x):
    """Shifted softplus (SchNet activation)."""
    return jax.nn.softplus(x) - float(np.log(2.0))


def schnet_init(cfg: SchNetConfig, key):
    keys = jax.random.split(key, cfg.n_interactions + 3)
    d = cfg.d_hidden
    inter = []
    for k in keys[:cfg.n_interactions]:
        k1, k2, k3, k4 = jax.random.split(k, 4)
        inter.append({
            "filter": _init_mlp(k1, [cfg.n_rbf, d, d], cfg.dtype),
            "w_in": _init_mlp(k2, [d, d], cfg.dtype),
            "w_out": _init_mlp(k3, [d, d, d], cfg.dtype),
        })
    return {
        "embed": (jax.random.normal(keys[-3], (cfg.n_atom_types, d))
                  * 0.1).astype(cfg.dtype),
        "inter": inter,
        "readout": _init_mlp(keys[-2], [d, d // 2, 1], cfg.dtype),
    }


def schnet_forward(cfg: SchNetConfig, params, g: GraphBatch) -> jnp.ndarray:
    """Per-graph energy [n_graphs]."""
    n = g.n_nodes
    h = params["embed"][jnp.clip(g.node_feat.astype(jnp.int32), 0,
                                 params["embed"].shape[0] - 1)]
    src_safe = jnp.clip(g.src, 0, n - 1)
    dst_safe = jnp.clip(g.dst, 0, n - 1)
    d_ij = jnp.linalg.norm(g.positions[dst_safe] - g.positions[src_safe] + 1e-9,
                           axis=-1)

    # RBF expansion (E × n_rbf — 74 GB fp32 at ogb_products scale) is
    # recomputed INSIDE each remat'd interaction rather than stashed.
    def edge_filter(lp_filter):
        centers = jnp.linspace(0.0, cfg.cutoff, cfg.n_rbf)
        gamma = cfg.n_rbf / cfg.cutoff
        rbf = jnp.exp(-gamma * (d_ij[:, None] - centers) ** 2).astype(cfg.dtype)
        env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(d_ij / cfg.cutoff, 0, 1)) + 1.0)
        w = _mlp(lp_filter, rbf, act=_ssp, final_act=True)
        return _constrain_rows(w * env[:, None].astype(cfg.dtype))

    for lp in params["inter"]:
        @jax.checkpoint
        def interaction(h, lp=lp):
            w = edge_filter(lp["filter"])
            x = _mlp(lp["w_in"], h)
            msg = _constrain_rows(x[src_safe] * w)
            agg = scatter_sum(jnp.where((g.dst < n)[:, None], msg, 0),
                              g.dst, n)
            return h + _mlp(lp["w_out"], agg, act=_ssp)

        h = interaction(h)

    atom_e = _mlp(params["readout"], h, act=_ssp)[:, 0]     # [N]
    gid = g.graph_id if g.graph_id is not None else jnp.zeros((n,), jnp.int32)
    return scatter_sum(atom_e, gid, g.n_graphs)


# ---------------------------------------------------------------------------
# DimeNet  [arXiv:2003.03123] — 6 blocks, d=128, bilinear=8, sph=7, rad=6
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str = "dimenet"
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    cutoff: float = 5.0
    n_atom_types: int = 100
    dtype: Any = jnp.float32


def dimenet_init(cfg: DimeNetConfig, key):
    keys = jax.random.split(key, cfg.n_blocks + 4)
    d = cfg.d_hidden
    blocks = []
    for k in keys[:cfg.n_blocks]:
        k1, k2, k3, k4, k5 = jax.random.split(k, 5)
        blocks.append({
            "w_sbf": (jax.random.normal(
                k1, (cfg.n_spherical * cfg.n_radial, cfg.n_bilinear))
                * 0.1).astype(cfg.dtype),
            "bilinear": (jax.random.normal(k2, (d, cfg.n_bilinear, d))
                         * (d ** -0.5) * 0.1).astype(cfg.dtype),
            "w_kj": _init_mlp(k3, [d, d], cfg.dtype),
            "w_ji": _init_mlp(k4, [d, d], cfg.dtype),
            "update": _init_mlp(k5, [d, d, d], cfg.dtype),
        })
    return {
        "embed": (jax.random.normal(keys[-4], (cfg.n_atom_types, d)) * 0.1
                  ).astype(cfg.dtype),
        "rbf_proj": _init_mlp(keys[-3], [cfg.n_radial, d], cfg.dtype),
        "edge_init": _init_mlp(keys[-2], [3 * d, d], cfg.dtype),
        "blocks": blocks,
        "out": _init_mlp(keys[-1], [d, d // 2, 1], cfg.dtype),
    }


def dimenet_forward(cfg: DimeNetConfig, params, g: GraphBatch) -> jnp.ndarray:
    """Per-graph energy via directional message passing on edges.

    Adaptation note (DESIGN.md): the spherical-Bessel/Legendre basis is
    replaced by an equivalently-shaped Bessel-radial × Chebyshev-angular
    basis (n_radial × n_spherical features) — same tensor structure and
    cost, TPU-friendly closed forms.
    """
    n = g.n_nodes
    e = g.src.shape[0]
    src_safe = jnp.clip(g.src, 0, n - 1)
    dst_safe = jnp.clip(g.dst, 0, n - 1)
    vec = g.positions[dst_safe] - g.positions[src_safe]      # j→i per edge
    d_ij = jnp.linalg.norm(vec + 1e-9, axis=-1)

    # Radial basis: sin(kπ d / c) / d  (Bessel j0 harmonics).
    kk = jnp.arange(1, cfg.n_radial + 1, dtype=jnp.float32)
    rbf = (jnp.sin(kk * jnp.pi * (d_ij / cfg.cutoff)[:, None])
           / jnp.maximum(d_ij, 1e-6)[:, None]).astype(cfg.dtype)

    h = params["embed"][jnp.clip(g.node_feat.astype(jnp.int32), 0,
                                 params["embed"].shape[0] - 1)]
    rbf_d = _mlp(params["rbf_proj"], rbf)
    m = _constrain_rows(_mlp(params["edge_init"],
             jnp.concatenate([h[src_safe], h[dst_safe], rbf_d], -1),
             act=jax.nn.silu, final_act=True))              # [E, d]

    return _dimenet_blocks(cfg, params, g, m, rbf, vec, n)


def _pick_chunks(t: int, target: int) -> int:
    """Largest chunk count ≤ t/target that divides t (static Python)."""
    n = max(1, t // target)
    while t % n:
        n -= 1
    return n


def _dimenet_blocks(cfg, params, g, m, rbf, vec, n):
    """Interaction blocks with CHUNKED triplet processing.

    At ogb_products scale there are 247M triplets; materializing the
    angular×radial basis (T × 42 fp32) plus the bilinear messages (T × 128)
    costs ~0.5 TB/chip if stashed per block. Instead triplets stream
    through a ``lax.scan`` in chunks: basis + gather + bilinear + scatter
    per chunk, under remat, accumulating into the per-edge aggregate.
    """
    e = m.shape[0]
    d = m.shape[1]
    t = g.trip_in.shape[0]
    n_chunks = _pick_chunks(t, 4_194_304)
    tc = t // n_chunks
    ti_all = g.trip_in.reshape(n_chunks, tc)
    to_all = g.trip_out.reshape(n_chunks, tc)

    def triplet_chunk(m_kj, bp, ti_raw, to_raw):
        ti = jnp.clip(ti_raw, 0, e - 1)
        to = jnp.clip(to_raw, 0, e - 1)
        valid = (ti_raw < e) & (to_raw < e)
        v_in = -vec[ti]                                  # j→k direction
        v_out = vec[to]
        cos_a = jnp.sum(v_in * v_out, -1) / jnp.maximum(
            jnp.linalg.norm(v_in + 1e-9, -1)
            * jnp.linalg.norm(v_out + 1e-9, -1), 1e-9)
        angles = jnp.arccos(jnp.clip(cos_a, -1.0, 1.0))
        # Chebyshev angular basis T_l(cos α) × radial basis of the in-edge.
        sph = jnp.cos(angles[:, None] * jnp.arange(cfg.n_spherical))
        sbf = (sph[:, :, None] * rbf[ti].astype(jnp.float32)[:, None, :]
               ).reshape(tc, -1).astype(cfg.dtype)
        basis = sbf @ bp["w_sbf"]                        # [tc, n_bilinear]
        tmsg = jnp.einsum("td,dbe,tb->te", m_kj[ti], bp["bilinear"], basis)
        tmsg = jnp.where(valid[:, None], tmsg, 0)
        return _constrain_rows(
            jnp.zeros((e, d), m.dtype).at[to].add(tmsg, mode="drop"))

    # Inter-block carry in bf16 for huge graphs (halves the per-block
    # residual stash; block math stays in cfg.dtype).
    carry_dtype = jnp.bfloat16 if e >= (1 << 22) else m.dtype
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *params["blocks"])

    @jax.checkpoint
    def one_block(m_c, bp):
        m = m_c.astype(cfg.dtype)
        m_kj = _constrain_rows(_mlp(bp["w_kj"], m))      # [E, d]

        @jax.checkpoint
        def chunk_step(agg, idx):
            return agg + triplet_chunk(m_kj, bp, ti_all[idx],
                                       to_all[idx]), None

        agg, _ = jax.lax.scan(chunk_step, jnp.zeros((e, d), m.dtype),
                              jnp.arange(n_chunks))
        m = m + _mlp(bp["update"], _mlp(bp["w_ji"], m) + agg,
                     act=jax.nn.silu)
        return _constrain_rows(m.astype(carry_dtype))

    m, _ = jax.lax.scan(lambda c, bp: (one_block(c, bp), None),
                        m.astype(carry_dtype), stacked)
    m = m.astype(cfg.dtype)
    edge_e = _mlp(params["out"], m, act=jax.nn.silu)[:, 0]
    node_e = scatter_sum(jnp.where(g.dst < n, edge_e, 0), g.dst, n)
    gid = g.graph_id if g.graph_id is not None else jnp.zeros((n,), jnp.int32)
    return scatter_sum(node_e, gid, g.n_graphs)


# ---------------------------------------------------------------------------
# MeshGraphNet  [arXiv:2010.03409] — 15 layers, d=128, sum agg, 2-layer MLPs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MGNConfig:
    name: str = "meshgraphnet"
    n_layers: int = 15
    d_hidden: int = 128
    mlp_layers: int = 2
    d_in_node: int = 8
    d_in_edge: int = 4
    d_out: int = 3
    dtype: Any = jnp.float32


def _ln(x):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-6)


def mgn_init(cfg: MGNConfig, key):
    d = cfg.d_hidden
    keys = jax.random.split(key, cfg.n_layers + 3)
    dims_node = [2 * d] + [d] * cfg.mlp_layers
    dims_edge = [3 * d] + [d] * cfg.mlp_layers
    layers = [{"edge_mlp": _init_mlp(jax.random.fold_in(k, 0), dims_edge, cfg.dtype),
               "node_mlp": _init_mlp(jax.random.fold_in(k, 1), dims_node, cfg.dtype)}
              for k in keys[:cfg.n_layers]]
    # Stack the identical layers → scannable pytree (leading dim L).
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    return {
        "enc_node": _init_mlp(keys[-3], [cfg.d_in_node, d, d], cfg.dtype),
        "enc_edge": _init_mlp(keys[-2], [cfg.d_in_edge, d, d], cfg.dtype),
        "layers": stacked,
        "dec": _init_mlp(keys[-1], [d, d, cfg.d_out], cfg.dtype),
    }


def mgn_forward(cfg: MGNConfig, params, g: GraphBatch) -> jnp.ndarray:
    """Per-node output [N, d_out] (e.g. accelerations).

    The 15 processor layers run under ``lax.scan`` with per-layer remat —
    at ogb_products scale the edge latents are 61.8M × 128 floats per
    layer, so storing all layers' intermediates for backward is a ~180 GiB
    per-chip bill; remat trades one forward recompute for an O(1)-in-depth
    stash.
    """
    n = g.n_nodes
    src_safe = jnp.clip(g.src, 0, n - 1)
    dst_safe = jnp.clip(g.dst, 0, n - 1)
    h = _mlp(params["enc_node"], g.node_feat.astype(cfg.dtype))
    if g.positions is not None:
        rel = g.positions[dst_safe] - g.positions[src_safe]
        dist = jnp.linalg.norm(rel + 1e-9, axis=-1, keepdims=True)
        ef = jnp.concatenate([rel, dist], -1).astype(cfg.dtype)
    else:
        ef = jnp.zeros((g.src.shape[0], cfg.d_in_edge), cfg.dtype)
    he = _mlp(params["enc_edge"], ef)
    dst_ok = (g.dst < n)[:, None]

    @jax.checkpoint
    def one_layer(carry, lp):
        h, he = carry
        e_in = jnp.concatenate([he, h[src_safe], h[dst_safe]], -1)
        he = _constrain_rows(he + _ln(_mlp(lp["edge_mlp"], e_in, act=jax.nn.relu)))
        agg = scatter_sum(jnp.where(dst_ok, he, 0), g.dst, n)
        n_in = jnp.concatenate([h, agg], -1)
        h = _constrain_rows(h + _ln(_mlp(lp["node_mlp"], n_in, act=jax.nn.relu)))
        return (h, he), None

    (h, he), _ = jax.lax.scan(lambda c, lp: one_layer(c, lp), (h, he),
                              params["layers"])
    return _mlp(params["dec"], h)
