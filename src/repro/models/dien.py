"""DIEN — Deep Interest Evolution Network  [arXiv:1809.03672].

Config (assigned): embed_dim=18, seq_len=100, gru_dim=108, mlp=200-80,
interaction=augru.

Structure:
  1. sparse embeddings: item + category tables (the EmbeddingBag substrate —
     multi-hot user-profile fields go through the ``embed_bag`` Pallas
     kernel path);
  2. interest extractor: GRU over the behavior sequence (lax.scan);
  3. interest evolution: attention scores w.r.t. the target item drive an
     AUGRU (attention-gated update);
  4. prediction MLP 200→80→1 on [final_state ‖ target ‖ user ‖ sum-pool].

Serving shapes:
  serve_p99/serve_bulk — batched CTR scoring (one target per row);
  retrieval_cand       — ONE user vs 10^6 candidates: the target-independent
      interest GRU runs once, then attention+AUGRU is vmapped over candidate
      blocks (batched compute, no loop over candidates).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DIENConfig:
    name: str = "dien"
    embed_dim: int = 18
    seq_len: int = 100
    gru_dim: int = 108
    mlp_dims: tuple = (200, 80)
    n_items: int = 1_000_000
    n_cates: int = 10_000
    n_user_feats: int = 100_000
    user_hot: int = 8            # multi-hot user profile field width
    dtype: Any = jnp.float32
    # The embed_bag Pallas kernel targets TPU; its interpret-mode fallback
    # lowers to a while loop whose per-step dynamic slices GSPMD turns into
    # all-gathers (292 GB/chip artifact on serve_bulk). Dry-run/SPMD cells
    # use the pure-XLA reference path instead (same math, §Perf P5).
    use_embed_kernel: bool = True

    @property
    def d_behavior(self) -> int:
        return 2 * self.embed_dim     # item ‖ cate


def dien_init(cfg: DIENConfig, key):
    ks = jax.random.split(key, 10)
    d_in = cfg.d_behavior
    g = cfg.gru_dim

    def table(k, n, d):
        return (jax.random.normal(k, (n, d), jnp.float32) * 0.05
                ).astype(cfg.dtype)

    def gru_params(k, d_x, d_h):
        k1, k2, k3 = jax.random.split(k, 3)
        s = (d_x + d_h) ** -0.5
        return {
            "wz": (jax.random.normal(k1, (d_x + d_h, d_h)) * s).astype(cfg.dtype),
            "wr": (jax.random.normal(k2, (d_x + d_h, d_h)) * s).astype(cfg.dtype),
            "wh": (jax.random.normal(k3, (d_x + d_h, d_h)) * s).astype(cfg.dtype),
            "bz": jnp.zeros((d_h,), cfg.dtype),
            "br": jnp.zeros((d_h,), cfg.dtype),
            "bh": jnp.zeros((d_h,), cfg.dtype),
        }

    mlp_in = g + d_in + cfg.embed_dim + g   # final ‖ target ‖ user ‖ sumpool
    dims = [mlp_in, *cfg.mlp_dims, 1]
    mlp = []
    for i, k in enumerate(jax.random.split(ks[5], len(dims) - 1)):
        a, b = dims[i], dims[i + 1]
        mlp.append(((jax.random.normal(k, (a, b)) * a ** -0.5).astype(cfg.dtype),
                    jnp.zeros((b,), cfg.dtype)))

    att_in = 2 * g
    return {
        "item_table": table(ks[0], cfg.n_items, cfg.embed_dim),
        "cate_table": table(ks[1], cfg.n_cates, cfg.embed_dim),
        "user_table": table(ks[2], cfg.n_user_feats, cfg.embed_dim),
        "gru1": gru_params(ks[3], d_in, g),
        "augru": gru_params(ks[4], d_in, g),
        "att_w": (jax.random.normal(ks[6], (g, g)) * g ** -0.5).astype(cfg.dtype),
        "proj_target": (jax.random.normal(ks[7], (cfg.d_behavior, g))
                        * cfg.d_behavior ** -0.5).astype(cfg.dtype),
        "mlp": mlp,
    }


def _embed_bag_mean(cfg: DIENConfig, idx, table):
    if cfg.use_embed_kernel:
        from repro.kernels.embed_bag.ops import embed_bag
        return embed_bag(idx, table, mean=True)
    from repro.kernels.embed_bag.ref import embed_bag_ref
    w = jnp.ones(idx.shape, jnp.float32)
    return embed_bag_ref(idx, w, table, mean=True)


def _gru_cell(p, x, h):
    xh = jnp.concatenate([x, h], -1)
    z = jax.nn.sigmoid(xh @ p["wz"] + p["bz"])
    r = jax.nn.sigmoid(xh @ p["wr"] + p["br"])
    xh2 = jnp.concatenate([x, r * h], -1)
    h_tilde = jnp.tanh(xh2 @ p["wh"] + p["bh"])
    return (1 - z) * h + z * h_tilde


def _augru_cell(p, x, h, a):
    """AUGRU: attention score a scales the update gate."""
    xh = jnp.concatenate([x, h], -1)
    z = jax.nn.sigmoid(xh @ p["wz"] + p["bz"]) * a[..., None]
    r = jax.nn.sigmoid(xh @ p["wr"] + p["br"])
    xh2 = jnp.concatenate([x, r * h], -1)
    h_tilde = jnp.tanh(xh2 @ p["wh"] + p["bh"])
    return (1 - z) * h + z * h_tilde


def interest_extractor(cfg: DIENConfig, params, behavior):
    """GRU over behavior [B, T, d] → hidden states [B, T, g]."""
    b = behavior.shape[0]
    h0 = jnp.zeros((b, cfg.gru_dim), cfg.dtype)

    def step(h, x_t):
        h = _gru_cell(params["gru1"], x_t, h)
        return h, h

    _, hs = jax.lax.scan(step, h0, behavior.swapaxes(0, 1))
    return hs.swapaxes(0, 1)                             # [B, T, g]


def interest_evolution(cfg: DIENConfig, params, hs, behavior, target_vec,
                       mask):
    """Attention (vs target) + AUGRU → final state [B, g]."""
    t_proj = target_vec @ params["proj_target"]          # [B, g]
    scores = jnp.einsum("btg,gh,bh->bt", hs, params["att_w"], t_proj)
    scores = jnp.where(mask, scores, -1e30)
    alpha = jax.nn.softmax(scores.astype(jnp.float32), -1).astype(cfg.dtype)

    b = hs.shape[0]
    h0 = jnp.zeros((b, cfg.gru_dim), cfg.dtype)

    def step(h, xs):
        x_t, a_t = xs
        h = _augru_cell(params["augru"], x_t, h, a_t)
        return h, None

    h, _ = jax.lax.scan(step, h0,
                        (behavior.swapaxes(0, 1), alpha.swapaxes(0, 1)))
    return h


def dien_forward(cfg: DIENConfig, params, batch):
    """CTR logits [B].

    batch: dict with
      hist_items, hist_cates: [B, T] int32; hist_mask: [B, T] bool
      target_item, target_cate: [B] int32
      user_feats: [B, hot] int32 (multi-hot → embedding bag)
    """
    it = params["item_table"][batch["hist_items"]]
    ct = params["cate_table"][batch["hist_cates"]]
    behavior = jnp.concatenate([it, ct], -1)             # [B, T, 2e]
    mask = batch["hist_mask"]
    behavior = jnp.where(mask[..., None], behavior, 0)

    tgt = jnp.concatenate([params["item_table"][batch["target_item"]],
                           params["cate_table"][batch["target_cate"]]], -1)

    hs = interest_extractor(cfg, params, behavior)
    final = interest_evolution(cfg, params, hs, behavior, tgt, mask)

    user = _embed_bag_mean(cfg, batch["user_feats"], params["user_table"])
    sumpool = jnp.sum(jnp.where(mask[..., None], hs, 0), axis=1) / \
        jnp.maximum(mask.sum(-1, keepdims=True), 1).astype(hs.dtype)

    feat = jnp.concatenate([final, tgt, user.astype(cfg.dtype), sumpool], -1)
    x = feat
    for i, (w, b) in enumerate(params["mlp"]):
        x = x @ w + b
        if i + 1 < len(params["mlp"]):
            x = jax.nn.relu(x)
    return x[:, 0]


def dien_loss(cfg: DIENConfig, params, batch):
    logits = dien_forward(cfg, params, batch).astype(jnp.float32)
    y = batch["label"].astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * y +
                    jnp.log1p(jnp.exp(-jnp.abs(logits))))


def dien_retrieval_score(cfg: DIENConfig, params, batch, *,
                         cand_block: int = 8192):
    """One user vs n_candidates: scores [n_candidates].

    batch: hist_items/hist_cates/hist_mask [1, T]; user_feats [1, hot];
           cand_items, cand_cates: [C] int32.
    The interest GRU runs ONCE; attention+AUGRU evolve per candidate in
    vmapped blocks (the offline-retrieval-scoring workload).
    """
    it = params["item_table"][batch["hist_items"]]
    ct = params["cate_table"][batch["hist_cates"]]
    behavior = jnp.concatenate([it, ct], -1)
    mask = batch["hist_mask"]
    behavior = jnp.where(mask[..., None], behavior, 0)
    hs = interest_extractor(cfg, params, behavior)       # [1, T, g]
    user = _embed_bag_mean(cfg, batch["user_feats"], params["user_table"])
    sumpool = jnp.sum(jnp.where(mask[..., None], hs, 0), axis=1) / \
        jnp.maximum(mask.sum(-1, keepdims=True), 1).astype(hs.dtype)

    c = batch["cand_items"].shape[0]
    pad = -c % cand_block
    ci = jnp.pad(batch["cand_items"], (0, pad))
    cc = jnp.pad(batch["cand_cates"], (0, pad))
    n_blocks = (c + pad) // cand_block
    blk = cand_block
    cand_items = ci.reshape(n_blocks, blk)
    cand_cates = cc.reshape(n_blocks, blk)

    def score_block(items, cates):
        tgt = jnp.concatenate([params["item_table"][items],
                               params["cate_table"][cates]], -1)  # [blk, 2e]
        hs_b = jnp.broadcast_to(hs, (blk,) + hs.shape[1:])
        beh_b = jnp.broadcast_to(behavior, (blk,) + behavior.shape[1:])
        mask_b = jnp.broadcast_to(mask, (blk,) + mask.shape[1:])
        final = interest_evolution(cfg, params, hs_b, beh_b, tgt, mask_b)
        user_b = jnp.broadcast_to(user, (blk, user.shape[-1]))
        pool_b = jnp.broadcast_to(sumpool, (blk, sumpool.shape[-1]))
        feat = jnp.concatenate([final, tgt, user_b.astype(cfg.dtype), pool_b], -1)
        x = feat
        for i, (w, b) in enumerate(params["mlp"]):
            x = x @ w + b
            if i + 1 < len(params["mlp"]):
                x = jax.nn.relu(x)
        return x[:, 0]

    _, scores = jax.lax.scan(
        lambda _, xs: (None, score_block(*xs)), None,
        (cand_items, cand_cates))
    return scores.reshape(-1)
