"""Sharding rules per architecture family.

Axes: ``data`` = DP/FSDP (+ sequence parallel for long-context decode),
``model`` = TP (heads / d_ff / vocab) + EP (experts), ``pod`` = cross-pod
pure data parallelism (batch; gradient all-reduce crosses pods once/step).

Rules return pytrees of ``PartitionSpec`` matching the param/state trees.
Dense LMs use DP+TP (params replicated over data); MoE LMs use FSDP×TP/EP
(params sharded over BOTH axes — dbrx at 132 B params must, see DESIGN.md
§7); GNNs shard nodes/edges over data with replicated (small) params;
DIEN shards embedding-table rows over model and batch over data.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    """Batch axes: ('pod', 'data') on the multi-pod mesh, else ('data',)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def ns(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

def lm_param_specs(cfg, mesh: Mesh, *, fsdp: bool) -> dict:
    """PartitionSpec tree matching ``transformer.init_params``."""
    dp = "data" if fsdp else None
    layer = {
        "wq": P(None, dp, "model"),
        "wk": P(None, dp, "model"),
        "wv": P(None, dp, "model"),
        "wo": P(None, "model", dp),
        "ln_attn": P(None, None),
        "ln_mlp": P(None, None),
    }
    if cfg.qk_norm:
        layer["q_norm"] = P(None, None)
        layer["k_norm"] = P(None, None)
    if cfg.is_moe:
        layer["router"] = P(None, None, None)
        layer["w_gate"] = P(None, "model", dp, None)
        layer["w_up"] = P(None, "model", dp, None)
        layer["w_down"] = P(None, "model", None, dp)
    else:
        layer["w_gate"] = P(None, dp, "model")
        layer["w_up"] = P(None, dp, "model")
        layer["w_down"] = P(None, "model", dp)
    specs = {
        "embed": P("model", dp),
        "layers": layer,
        "ln_out": P(None),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = P(dp, "model")
    return specs


def lm_input_specs(mesh: Mesh, batch: int, seq: int):
    da = data_axes(mesh)
    return {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32,
                                       sharding=ns(mesh, da, None)),
        "targets": jax.ShapeDtypeStruct((batch, seq), jnp.int32,
                                        sharding=ns(mesh, da, None)),
    }


def lm_cache_specs(cfg, mesh: Mesh, batch: int, seq: int):
    """KV-cache shardings: batch over data when batch ≥ |data|; otherwise
    sequence parallelism (long_500k: one request, cache sharded on seq)."""
    da = data_axes(mesh)
    n_data = 1
    for a in da:
        n_data *= mesh.shape[a]
    if batch >= n_data:
        spec = P(None, da, "model", None, None)     # seq over model (TP)
    else:
        spec = P(None, None, da, None, None)        # sequence parallel
    shape = (cfg.n_layers, batch, seq, cfg.n_kv_heads, cfg.d_head)
    return {
        "k": jax.ShapeDtypeStruct(shape, cfg.dtype, sharding=ns(mesh, *spec)),
        "v": jax.ShapeDtypeStruct(shape, cfg.dtype, sharding=ns(mesh, *spec)),
        "len": jax.ShapeDtypeStruct((), jnp.int32, sharding=ns(mesh)),
    }, {
        "token": jax.ShapeDtypeStruct(
            (batch,), jnp.int32,
            sharding=ns(mesh, da if batch >= n_data else None)),
    }


# ---------------------------------------------------------------------------
# GNN family — nodes/edges sharded over data, params replicated
# ---------------------------------------------------------------------------

def gnn_param_specs(params) -> dict:
    return jax.tree.map(lambda _: P(), params)


def gnn_input_specs(mesh: Mesh, *, n_nodes: int, n_edges: int, d_feat: int,
                    positions: bool = False, atom_types: bool = False,
                    n_graphs: int = 1, n_triplets: int = 0):
    da = data_axes(mesh)
    node_sh = ns(mesh, da)
    edge_sh = ns(mesh, da)
    if atom_types:
        nf = jax.ShapeDtypeStruct((n_nodes,), jnp.int32, sharding=node_sh)
    else:
        nf = jax.ShapeDtypeStruct((n_nodes, d_feat), jnp.float32,
                                  sharding=ns(mesh, da, None))
    out = {
        "node_feat": nf,
        "src": jax.ShapeDtypeStruct((n_edges,), jnp.int32, sharding=edge_sh),
        "dst": jax.ShapeDtypeStruct((n_edges,), jnp.int32, sharding=edge_sh),
        "graph_id": jax.ShapeDtypeStruct((n_nodes,), jnp.int32,
                                         sharding=node_sh),
    }
    if positions:
        out["positions"] = jax.ShapeDtypeStruct((n_nodes, 3), jnp.float32,
                                                sharding=ns(mesh, da, None))
    if n_triplets:
        out["trip_in"] = jax.ShapeDtypeStruct((n_triplets,), jnp.int32,
                                              sharding=edge_sh)
        out["trip_out"] = jax.ShapeDtypeStruct((n_triplets,), jnp.int32,
                                               sharding=edge_sh)
    return out


# ---------------------------------------------------------------------------
# RecSys family — table rows over model, batch over data
# ---------------------------------------------------------------------------

def dien_param_specs(params, *, replicate_tables: bool = False) -> dict:
    """Tables row-shard over `model` for training (grad scatter locality,
    and the layout that scales to 10^8–10^9-row tables). For SERVING the
    assigned tables are ~72 MB total — replicating them removes the
    cross-shard gather fallbacks entirely (§Perf P5: serve_bulk collective
    2.9e11 → ~0 B/chip). Policy knob: replicate when table bytes < 1 GiB."""
    specs = jax.tree.map(lambda _: P(), params)
    if not replicate_tables:
        specs["item_table"] = P("model", None)
        specs["cate_table"] = P("model", None)
        specs["user_table"] = P("model", None)
    return specs


def dien_input_specs(mesh: Mesh, cfg, batch: int):
    da = data_axes(mesh)
    b = ns(mesh, da)
    bt = ns(mesh, da, None)
    t = cfg.seq_len
    return {
        "hist_items": jax.ShapeDtypeStruct((batch, t), jnp.int32, sharding=bt),
        "hist_cates": jax.ShapeDtypeStruct((batch, t), jnp.int32, sharding=bt),
        "hist_mask": jax.ShapeDtypeStruct((batch, t), jnp.bool_, sharding=bt),
        "target_item": jax.ShapeDtypeStruct((batch,), jnp.int32, sharding=b),
        "target_cate": jax.ShapeDtypeStruct((batch,), jnp.int32, sharding=b),
        "user_feats": jax.ShapeDtypeStruct((batch, cfg.user_hot), jnp.int32,
                                           sharding=bt),
        "label": jax.ShapeDtypeStruct((batch,), jnp.int32, sharding=b),
    }


def dien_retrieval_specs(mesh: Mesh, cfg, n_candidates: int):
    da = data_axes(mesh)
    rep = ns(mesh)
    rep2 = ns(mesh, None, None)
    return {
        "hist_items": jax.ShapeDtypeStruct((1, cfg.seq_len), jnp.int32,
                                           sharding=rep2),
        "hist_cates": jax.ShapeDtypeStruct((1, cfg.seq_len), jnp.int32,
                                           sharding=rep2),
        "hist_mask": jax.ShapeDtypeStruct((1, cfg.seq_len), jnp.bool_,
                                          sharding=rep2),
        "user_feats": jax.ShapeDtypeStruct((1, cfg.user_hot), jnp.int32,
                                           sharding=rep2),
        "cand_items": jax.ShapeDtypeStruct((n_candidates,), jnp.int32,
                                           sharding=ns(mesh, da)),
        "cand_cates": jax.ShapeDtypeStruct((n_candidates,), jnp.int32,
                                           sharding=ns(mesh, da)),
    }
