"""Distribution layer: mesh construction, per-family sharding rules."""
