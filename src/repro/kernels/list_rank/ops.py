"""jit'd wrappers for the list_rank kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.list_rank.list_rank import (BLOCK_ROWS, LANES, NO_SUCC,
                                               list_rank_pallas)

_TILE = BLOCK_ROWS * LANES


def _pad(succ, dist):
    n = succ.shape[0]
    n_pad = -n % _TILE
    succ2d = jnp.concatenate(
        [succ, jnp.full((n_pad,), NO_SUCC, succ.dtype)]).reshape(-1, LANES)
    dist2d = jnp.concatenate(
        [dist, jnp.zeros((n_pad,), dist.dtype)]).reshape(-1, LANES)
    return succ2d, dist2d, n


@partial(jax.jit, static_argnames=("n_steps", "interpret"))
def list_rank_k(succ: jnp.ndarray, dist: jnp.ndarray, *, n_steps: int = 5,
                interpret: bool = True):
    """One launch: (k+1)-hop chain prefix sum (see kernel docstring)."""
    succ2d, dist2d, n = _pad(succ, dist)
    s, d = list_rank_pallas(succ2d, dist2d, n_steps=n_steps,
                            interpret=interpret)
    return s.reshape(-1)[:n], d.reshape(-1)[:n]


@partial(jax.jit, static_argnames=("n_steps", "interpret"))
def list_rank(succ: jnp.ndarray, valid: jnp.ndarray, *, n_steps: int = 5,
              interpret: bool = True) -> jnp.ndarray:
    """Distance-to-end ranks via repeated multi-step launches."""
    dist = jnp.where(valid & (succ != NO_SUCC), 1, 0).astype(jnp.int32)

    def body(state):
        s, d = state
        s2, d2 = list_rank_k(s, d, n_steps=n_steps, interpret=interpret)
        return s2, d2

    def cond(state):
        s, _ = state
        return jnp.any(s != NO_SUCC)

    _, dist = jax.lax.while_loop(cond, body, (succ, dist))
    return dist
