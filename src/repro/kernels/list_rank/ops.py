"""jit'd wrappers for the list_rank kernel.

``interpret=None`` dispatches via the shared ``repro.kernels.auto_interpret``
policy. The full-convergence loop lives in the unified engine
(``core.compress.wyllie_rank``), which pads to the (8, 128) tile once,
outside the loop, and counts convergence syncs.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import auto_interpret as _auto_interpret
from repro.kernels.list_rank.list_rank import (BLOCK_ROWS, LANES, NO_SUCC,
                                               list_rank_pallas)

_TILE = BLOCK_ROWS * LANES


def pad_to_tile(succ, dist):
    """Pad (succ, dist) to the (8, 128) tile; returns (succ2d, dist2d, n).

    Pad slots are inert (succ = −1, dist = 0), so padding commutes with
    ranking and can be hoisted outside convergence loops.
    """
    n = succ.shape[0]
    n_pad = -n % _TILE
    succ2d = jnp.concatenate(
        [succ, jnp.full((n_pad,), NO_SUCC, succ.dtype)]).reshape(-1, LANES)
    dist2d = jnp.concatenate(
        [dist, jnp.zeros((n_pad,), dist.dtype)]).reshape(-1, LANES)
    return succ2d, dist2d, n


@partial(jax.jit, static_argnames=("n_steps", "interpret"))
def list_rank_k(succ: jnp.ndarray, dist: jnp.ndarray, *, n_steps: int = 5,
                interpret: bool | None = None):
    """One launch: (k+1)-hop chain prefix sum (see kernel docstring)."""
    if interpret is None:
        interpret = _auto_interpret()
    with jax.named_scope("list_rank_k"):
        succ2d, dist2d, n = pad_to_tile(succ, dist)
        s, d = list_rank_pallas(succ2d, dist2d, n_steps=n_steps,
                                interpret=interpret)
        return s.reshape(-1)[:n], d.reshape(-1)[:n]


def list_rank(succ: jnp.ndarray, valid: jnp.ndarray, *, n_steps: int = 5,
              interpret: bool | None = None) -> jnp.ndarray:
    """Distance-to-end ranks. Back-compat shim → engine convergence loop."""
    from repro.core.compress import wyllie_rank
    return wyllie_rank(succ, valid, n_jumps=n_steps, use_kernel=True,
                       interpret=interpret)
