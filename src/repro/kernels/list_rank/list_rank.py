"""Wyllie list-ranking Pallas kernel: pointer doubling with additive payload.

One launch performs k chained (succ, dist) doubling steps entirely in VMEM —
the Euler-tour analogue of the multi-jump trick. Semantics per step:

    has  = succ != -1
    dist = dist + (has ? dist[succ] : 0)
    succ = has ? succ[succ] : -1

Layout matches pointer_jump: (R, 128) int32 tiles, full tables VMEM-resident,
8-sublane-aligned blocks. Sentinel -1 terminates lists; padded slots carry
succ = -1, dist = 0 and are inert.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 8
NO_SUCC = -1


def _list_rank_kernel(succ_blk_ref, dist_blk_ref, succ_full_ref,
                      dist_full_ref, succ_out_ref, dist_out_ref,
                      *, n_steps: int):
    succ = succ_blk_ref[...]
    dist = dist_blk_ref[...]
    succ_tab = succ_full_ref[...].reshape(-1)
    dist_tab = dist_full_ref[...].reshape(-1)
    # Chained gathers against one table snapshot give (k+1)-hop chain
    # prefix sums: d'[e] = Σ_{j=0..k} d[s^j(e)], s'[e] = s^{k+1}(e). The
    # invariant d[e] = dist(e, s[e]) telescopes, so the outer convergence
    # loop (ops.py) still yields exact distance-to-end ranks.
    for _ in range(n_steps):
        has = succ != NO_SUCC
        safe = jnp.where(has, succ, 0)
        dist = dist + jnp.where(has, jnp.take(dist_tab, safe, axis=0), 0)
        succ = jnp.where(has, jnp.take(succ_tab, safe, axis=0), NO_SUCC)
    succ_out_ref[...] = succ
    dist_out_ref[...] = dist


def _list_rank_double_kernel(succ_ref, dist_ref, succ_out_ref, dist_out_ref,
                             *, n_steps: int):
    """k true Wyllie *doubling* steps on the whole VMEM-resident tables.

    Unlike the per-block chain kernel above (fixed table snapshot ⇒ k+1
    hops per launch), both tables are updated between steps, so each step
    doubles the covered distance — giving the engine's convergence loop
    its ⌈log2(n)/k⌉ + 1 sync bound. Runs grid=1 (whole-table update),
    same VMEM budget as the chain kernel which already broadcasts both
    full tables to every block.
    """
    succ = succ_ref[...].reshape(-1)
    dist = dist_ref[...].reshape(-1)
    for _ in range(n_steps):
        has = succ != NO_SUCC
        safe = jnp.where(has, succ, 0)
        dist = dist + jnp.where(has, jnp.take(dist, safe, axis=0), 0)
        succ = jnp.where(has, jnp.take(succ, safe, axis=0), NO_SUCC)
    succ_out_ref[...] = succ.reshape(succ_ref.shape)
    dist_out_ref[...] = dist.reshape(dist_ref.shape)


def list_rank_double_pallas(succ2d: jnp.ndarray, dist2d: jnp.ndarray, *,
                            n_steps: int, interpret: bool = True):
    rows = succ2d.shape[0]
    assert succ2d.shape[1] == LANES and rows % BLOCK_ROWS == 0
    kernel = functools.partial(_list_rank_double_kernel, n_steps=n_steps)
    full = pl.BlockSpec((rows, LANES), lambda i: (0, 0))
    return pl.pallas_call(
        kernel,
        out_shape=(jax.ShapeDtypeStruct(succ2d.shape, succ2d.dtype),
                   jax.ShapeDtypeStruct(dist2d.shape, dist2d.dtype)),
        in_specs=[full, full],
        out_specs=(full, full),
        grid=(1,),
        interpret=interpret,
    )(succ2d, dist2d)


def list_rank_pallas(succ2d: jnp.ndarray, dist2d: jnp.ndarray, *,
                     n_steps: int, interpret: bool = True):
    rows = succ2d.shape[0]
    assert succ2d.shape[1] == LANES and rows % BLOCK_ROWS == 0
    grid = (rows // BLOCK_ROWS,)
    kernel = functools.partial(_list_rank_kernel, n_steps=n_steps)
    blk = pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))
    full = pl.BlockSpec((rows, LANES), lambda i: (0, 0))
    return pl.pallas_call(
        kernel,
        out_shape=(jax.ShapeDtypeStruct(succ2d.shape, succ2d.dtype),
                   jax.ShapeDtypeStruct(dist2d.shape, dist2d.dtype)),
        in_specs=[blk, blk, full, full],
        out_specs=(blk, blk),
        grid=grid,
        interpret=interpret,
    )(succ2d, dist2d, succ2d, dist2d)
