"""Pure-jnp oracle for the list_rank kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NO_SUCC = -1


def list_rank_steps_ref(succ: jnp.ndarray, dist: jnp.ndarray, n_steps: int):
    """n_steps chained same-snapshot Wyllie updates (matches one launch)."""
    succ_tab, dist_tab = succ, dist
    for _ in range(n_steps):
        has = succ != NO_SUCC
        safe = jnp.where(has, succ, 0)
        dist = dist + jnp.where(has, dist_tab[safe], 0)
        succ = jnp.where(has, succ_tab[safe], NO_SUCC)
    return succ, dist


def list_rank_full_ref(succ: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Distance-to-end for every list element (full convergence oracle)."""
    dist = jnp.where(valid & (succ != NO_SUCC), 1, 0).astype(jnp.int32)

    def body(state):
        d, s = state
        has = s != NO_SUCC
        safe = jnp.where(has, s, 0)
        d = jnp.where(has, d + d[safe], d)
        s = jnp.where(has, s[safe], s)
        return d, s

    dist, _ = jax.lax.while_loop(lambda st: jnp.any(st[1] != NO_SUCC), body,
                                 (dist, succ))
    return dist
