"""Pure-jnp oracle for the hook_edges kernel."""
from __future__ import annotations

import jax.numpy as jnp


def hook_edges_ref(src, dst, rep, use_min: bool, n_nodes: int):
    ru = rep[src]
    rv = rep[dst]
    cross = ru != rv
    lo = jnp.minimum(ru, rv)
    hi = jnp.maximum(ru, rv)
    tgt = jnp.where(use_min, hi, lo)
    val = jnp.where(use_min, lo, hi)
    return jnp.where(cross, tgt, n_nodes), val
