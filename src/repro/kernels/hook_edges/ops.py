"""jit'd wrapper for the hook_edges kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import auto_interpret as _auto_interpret
from repro.kernels.hook_edges.hook_edges import (BLOCK_ROWS, LANES,
                                                 hook_edges_pallas)

_TILE = BLOCK_ROWS * LANES


@partial(jax.jit, static_argnames=("n_nodes", "interpret"))
def hook_edges(src: jnp.ndarray, dst: jnp.ndarray, rep: jnp.ndarray,
               use_min, *, n_nodes: int, interpret: bool | None = None):
    """Per-edge hook proposals (tgt == n_nodes ⇒ drop). See kernel doc."""
    if interpret is None:
        interpret = _auto_interpret()
    e = src.shape[0]
    e_pad = -e % _TILE
    # Padding edges are self-loops on node 0 → non-cross → dropped.
    src2d = jnp.concatenate([src, jnp.zeros((e_pad,), src.dtype)]).reshape(-1, LANES)
    dst2d = jnp.concatenate([dst, jnp.zeros((e_pad,), dst.dtype)]).reshape(-1, LANES)
    n = rep.shape[0]
    n_pad = -n % _TILE
    rep2d = jnp.concatenate(
        [rep, jnp.arange(n, n + n_pad, dtype=rep.dtype)]).reshape(-1, LANES)
    use_min_arr = jnp.asarray(use_min, jnp.int32).reshape(1, 1)
    tgt, val = hook_edges_pallas(src2d, dst2d, rep2d, use_min_arr,
                                 n_nodes=n_nodes, interpret=interpret)
    return tgt.reshape(-1)[:e], val.reshape(-1)[:e]
