"""Edge-centric hooking-scan Pallas kernel.

For each edge block: gather both endpoint representatives from the
VMEM-resident rep table, detect cross edges, and emit the (target, value)
hook proposal under min- or max-hooking. This fuses the two gathers and the
compare/select logic of the paper's hooking kernel; the deterministic
scatter-min/max reduction stays outside (XLA scatter), replacing CUDA
atomics (DESIGN.md §2).

Outputs per half-edge:
  tgt: root being re-pointed (hi under min-hooking, lo under max-hooking),
       or ``n`` (dropped) for non-cross edges;
  val: proposed new parent (lo resp. hi).

Edge arrays are viewed as (E/128, 128) tiles; the rep table is VMEM-resident
(same budget note as pointer_jump).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 8


def _hook_edges_kernel(src_ref, dst_ref, rep_ref, use_min_ref,
                       tgt_ref, val_ref, *, n_nodes: int):
    rep = rep_ref[...].reshape(-1)
    ru = jnp.take(rep, src_ref[...], axis=0)
    rv = jnp.take(rep, dst_ref[...], axis=0)
    cross = ru != rv
    lo = jnp.minimum(ru, rv)
    hi = jnp.maximum(ru, rv)
    use_min = use_min_ref[0, 0] != 0
    tgt = jnp.where(use_min, hi, lo)
    val = jnp.where(use_min, lo, hi)
    tgt_ref[...] = jnp.where(cross, tgt, n_nodes)
    val_ref[...] = val


def hook_edges_pallas(src2d, dst2d, rep2d, use_min, *, n_nodes: int,
                      interpret: bool = True):
    rows = src2d.shape[0]
    rep_rows = rep2d.shape[0]
    assert src2d.shape[1] == LANES and rows % BLOCK_ROWS == 0
    grid = (rows // BLOCK_ROWS,)
    kernel = functools.partial(_hook_edges_kernel, n_nodes=n_nodes)
    blk = pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))
    full = pl.BlockSpec((rep_rows, LANES), lambda i: (0, 0))
    scalar = pl.BlockSpec((1, 1), lambda i: (0, 0))
    return pl.pallas_call(
        kernel,
        out_shape=(jax.ShapeDtypeStruct(src2d.shape, jnp.int32),
                   jax.ShapeDtypeStruct(src2d.shape, jnp.int32)),
        in_specs=[blk, blk, full, scalar],
        out_specs=(blk, blk),
        grid=grid,
        interpret=interpret,
    )(src2d, dst2d, rep2d, use_min)
