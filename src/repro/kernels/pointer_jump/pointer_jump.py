"""k-step pointer-jumping Pallas kernel.

The paper's pointer-jumping optimization performs five jumps per thread
between global synchronizations to amortize kernel-launch cost. The TPU
restatement: the whole parent table is held VMEM-resident (one HBM→VMEM
fetch), each grid step processes a (ROWS, 128)-tile of vertices, and the k
gathers chain *inside* the kernel so intermediate hops never round-trip
through HBM.

Layout: vertex ids are viewed as a (n/128, 128) int32 matrix — rows of 128
lanes, the native VREG lane width — and blocks are (BLOCK_ROWS, 128) tiles,
8-sublane aligned. The gather is a flat ``jnp.take`` on the VMEM-resident
table (dynamic-gather on TPU; exact in interpret mode).

VMEM budget: the table tile is n × 4 bytes; n ≤ ~3.5M keeps table + block
under the 16 MB VMEM ceiling. Larger graphs run the same kernel over a
vertex partition with the table in ANY/HBM memory space (documented
trade-off; the multi-chip path in ``core.distributed`` shards edges
instead).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 8  # (8, 128) int32 tile = 4 KB per block


def _pointer_jump_kernel(p_block_ref, p_full_ref, out_ref, *, n_jumps: int):
    """out[i] = P^(n_jumps+1)(i): chain k gathers without leaving VMEM.

    *Chain* semantics — the table snapshot is fixed, so each gather advances
    one hop. Used for the fixed-hop primitive (``pointer_jump_k``)."""
    idx = p_block_ref[...]
    table = p_full_ref[...].reshape(-1)
    for _ in range(n_jumps):
        idx = jnp.take(table, idx, axis=0)
    out_ref[...] = idx


def _pointer_jump_double_kernel(p_ref, out_ref, *, n_jumps: int):
    """k *doubling* steps ``table = table[table]`` on the whole VMEM table.

    Each step squares the compressed distance (2^k-fold compression per
    launch vs k+1 hops for the chain kernel), which is what gives the
    convergence path its ⌈log2(depth)/k⌉ + 1 sync bound. The whole table
    must be updated between steps, so this kernel runs grid=1 with the
    table as a single block — the same VMEM-residency budget as the chain
    kernel, which already broadcasts the full table to every block.
    """
    table = p_ref[...].reshape(-1)
    for _ in range(n_jumps):
        table = jnp.take(table, table, axis=0)
    out_ref[...] = table.reshape(p_ref.shape)


def pointer_jump_pallas(p2d: jnp.ndarray, *, n_jumps: int,
                        interpret: bool = True) -> jnp.ndarray:
    """p2d: int32[R, 128] parent table (padded; pad rows self-point)."""
    rows = p2d.shape[0]
    assert p2d.shape[1] == LANES and rows % BLOCK_ROWS == 0
    grid = (rows // BLOCK_ROWS,)
    kernel = functools.partial(_pointer_jump_kernel, n_jumps=n_jumps)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(p2d.shape, p2d.dtype),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((rows, LANES), lambda i: (0, 0)),  # full table
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        grid=grid,
        interpret=interpret,
    )(p2d, p2d)


def pointer_jump_double_pallas(p2d: jnp.ndarray, *, n_jumps: int,
                               interpret: bool = True) -> jnp.ndarray:
    """k doubling steps over the whole padded table in one launch."""
    rows = p2d.shape[0]
    assert p2d.shape[1] == LANES and rows % BLOCK_ROWS == 0
    kernel = functools.partial(_pointer_jump_double_kernel, n_jumps=n_jumps)
    full = pl.BlockSpec((rows, LANES), lambda i: (0, 0))
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(p2d.shape, p2d.dtype),
        in_specs=[full],
        out_specs=full,
        grid=(1,),
        interpret=interpret,
    )(p2d)
