"""Pure-jnp oracle for the pointer_jump kernel."""
from __future__ import annotations

import jax.numpy as jnp


def pointer_jump_ref(p: jnp.ndarray, n_jumps: int) -> jnp.ndarray:
    """Apply ``idx = p[idx]`` n_jumps times, starting from idx = p."""
    idx = p
    for _ in range(n_jumps):
        idx = p[idx]
    return idx
