"""jit'd wrappers for the pointer_jump kernel (padding + convergence loop)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.pointer_jump.pointer_jump import (BLOCK_ROWS, LANES,
                                                     pointer_jump_pallas)

_TILE = BLOCK_ROWS * LANES


def _pad_to_tile(p: jnp.ndarray):
    n = p.shape[0]
    n_pad = -n % _TILE
    total = n + n_pad
    # Pad entries self-point (inert under jumping).
    pad_ids = jnp.arange(n, total, dtype=p.dtype)
    p2d = jnp.concatenate([p, pad_ids]).reshape(-1, LANES)
    return p2d, n


@partial(jax.jit, static_argnames=("n_jumps", "interpret"))
def pointer_jump_k(p: jnp.ndarray, *, n_jumps: int = 5,
                   interpret: bool = True) -> jnp.ndarray:
    """One kernel launch: follow the parent chain ``n_jumps + 1`` hops.

    Equivalent to ``ref.pointer_jump_ref(p, n_jumps)`` — the paper's
    multi-jump-per-launch trick (k+1-fold path compression per launch).
    """
    p2d, n = _pad_to_tile(p)
    out = pointer_jump_pallas(p2d, n_jumps=n_jumps, interpret=interpret)
    return out.reshape(-1)[:n]


@partial(jax.jit, static_argnames=("n_jumps", "interpret"))
def pointer_jump_until_converged(p: jnp.ndarray, *, n_jumps: int = 5,
                                 interpret: bool = True) -> jnp.ndarray:
    """Launch the multi-jump kernel until the table is fully compressed."""

    def body(state):
        p, _ = state
        p2 = pointer_jump_k(p, n_jumps=n_jumps, interpret=interpret)
        return p2, jnp.any(p2 != p)

    p, _ = jax.lax.while_loop(lambda s: s[1], body, (p, jnp.bool_(True)))
    return p
