"""jit'd wrappers for the pointer_jump kernels (padding + launch plumbing).

Convergence looping lives in ``repro.core.compress`` — the unified engine —
which calls ``pointer_jump_double_k`` on an already-padded table so the
(8, 128)-tile padding happens once per compression, not once per launch.
``interpret=None`` dispatches from ``jax.default_backend()`` (compiled on
TPU, interpreter elsewhere).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import auto_interpret as _auto_interpret
from repro.kernels.pointer_jump.pointer_jump import (
    BLOCK_ROWS, LANES, pointer_jump_double_pallas, pointer_jump_pallas)

_TILE = BLOCK_ROWS * LANES


def pad_to_tile(p: jnp.ndarray):
    """Pad a flat parent table to the (8, 128) tile; returns (p2d, n).

    Pad entries self-point (inert under jumping), so padding commutes with
    compression and can be hoisted outside convergence loops.
    """
    n = p.shape[0]
    n_pad = -n % _TILE
    total = n + n_pad
    pad_ids = jnp.arange(n, total, dtype=p.dtype)
    p2d = jnp.concatenate([p, pad_ids]).reshape(-1, LANES)
    return p2d, n


@partial(jax.jit, static_argnames=("n_jumps", "interpret"))
def pointer_jump_k(p: jnp.ndarray, *, n_jumps: int = 5,
                   interpret: bool | None = None) -> jnp.ndarray:
    """One kernel launch: follow the parent chain ``n_jumps + 1`` hops.

    Equivalent to ``ref.pointer_jump_ref(p, n_jumps)`` — the paper's
    multi-jump-per-launch trick (k+1-fold path compression per launch).
    """
    if interpret is None:
        interpret = _auto_interpret()
    with jax.named_scope("pointer_jump_k"):
        p2d, n = pad_to_tile(p)
        out = pointer_jump_pallas(p2d, n_jumps=n_jumps, interpret=interpret)
        return out.reshape(-1)[:n]


@partial(jax.jit, static_argnames=("n_jumps", "interpret"))
def pointer_jump_double_k(p2d: jnp.ndarray, *, n_jumps: int = 5,
                          interpret: bool | None = None) -> jnp.ndarray:
    """One launch: ``n_jumps`` doubling steps on a padded (R, 128) table.

    The convergence-loop building block: 2^k-fold compression per launch
    (see ``core.compress.compress_full``). Expects ``pad_to_tile`` layout.
    """
    if interpret is None:
        interpret = _auto_interpret()
    with jax.named_scope("pointer_jump_double_k"):
        return pointer_jump_double_pallas(p2d, n_jumps=n_jumps,
                                          interpret=interpret)


def pointer_jump_until_converged(p: jnp.ndarray, *, n_jumps: int = 5,
                                 interpret: bool | None = None) -> jnp.ndarray:
    """Fully compress via the kernel. Back-compat shim → engine.

    Pads once, then runs ⌈log2(depth)/n_jumps⌉ + 1 doubling launches with
    one ``jnp.any`` sync each (``core.compress`` owns the loop).
    """
    from repro.core.compress import compress_full
    return compress_full(p, n_jumps=n_jumps, use_kernel=True,
                         interpret=interpret)
