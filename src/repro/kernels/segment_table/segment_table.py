"""Doubling sparse-table Pallas kernel (the ``segment_reduce`` build).

Level k of the table holds ``T[k][i] = op over values[i : i + 2^k]`` —
payload-reduce ``jump_k`` on the shift successor ``i ↦ i + 2^k``
(DESIGN.md §4). The build is depth-oblivious (exactly ⌈log2 n⌉ chained
doubling steps, zero convergence syncs), so unlike the pointer_jump /
list_rank pair there is no chain-vs-doubling split: one launch computes
every level with the value table VMEM-resident, the same grid = 1
whole-table layout as ``pointer_jump_double_pallas``.

The shift successor is *static*, so each doubling step is a flat slice +
identity-fill concatenate — no dynamic gather at all (a whole-table
``jnp.take`` here costs quadratic interpret/compile time and buys
nothing). Correctness of the slice form relies on pad slots carrying the
op identity: boundary windows fold pad values instead of clamping to
``n − 1``, and identity folds are no-ops exactly like the XLA path's
idempotent clamp folds. The wrapper (``ops.segment_table``) owns that
padding contract.

Layout: values are viewed as a padded (rows, 128) matrix (8-sublane-
aligned, DESIGN.md §5); the output stacks the levels + 1 table rows into
a ((levels + 1) · rows, 128) matrix the wrapper reshapes to
[levels + 1, n].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 8


def _segment_table_kernel(v_ref, out_ref, *, levels: int, fill, op: str):
    combine = jnp.minimum if op == "min" else jnp.maximum
    rows = v_ref.shape[0]
    n_pad = rows * LANES
    t = v_ref[...].reshape(-1)
    out = [t]
    for k in range(levels):
        s = 1 << k
        if s < n_pad:
            shifted = jnp.concatenate(
                [t[s:], jnp.full((s,), fill, t.dtype)])
        else:
            shifted = jnp.full((n_pad,), fill, t.dtype)
        t = combine(t, shifted)
        out.append(t)
    out_ref[...] = jnp.concatenate(out).reshape((levels + 1) * rows, LANES)


def segment_table_pallas(v2d: jnp.ndarray, *, levels: int, fill, op: str,
                         interpret: bool = True) -> jnp.ndarray:
    """v2d: [R, 128] padded values → [(levels + 1) · R, 128] table.

    ``fill`` must be the op identity (max for min, min for max); pad
    slots of ``v2d`` must already carry it.
    """
    rows = v2d.shape[0]
    assert v2d.shape[1] == LANES and rows % BLOCK_ROWS == 0
    kernel = functools.partial(_segment_table_kernel, levels=levels,
                               fill=fill, op=op)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(((levels + 1) * rows, LANES),
                                       v2d.dtype),
        in_specs=[pl.BlockSpec((rows, LANES), lambda i: (0, 0))],
        out_specs=pl.BlockSpec(((levels + 1) * rows, LANES),
                               lambda i: (0, 0)),
        grid=(1,),
        interpret=interpret,
    )(v2d)
