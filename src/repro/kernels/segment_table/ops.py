"""jit'd wrapper for the segment_table kernel (padding + launch plumbing).

``interpret=None`` dispatches from ``jax.default_backend()`` (compiled on
TPU, interpreter elsewhere) via the shared ``repro.kernels.auto_interpret``
policy. The query-side fold stays in ``core.compress.segment_reduce`` —
this wrapper only builds the [levels + 1, n] sparse table.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import auto_interpret as _auto_interpret
from repro.kernels.segment_table.segment_table import (BLOCK_ROWS, LANES,
                                                       segment_table_pallas)

_TILE = BLOCK_ROWS * LANES


@partial(jax.jit, static_argnames=("levels", "op", "interpret"))
def segment_table(values: jnp.ndarray, *, levels: int, op: str,
                  interpret: bool | None = None) -> jnp.ndarray:
    """[levels + 1, n] doubling sparse table over ``values`` (one launch).

    Pad slots carry the op identity — the kernel's slice-shift doubling
    folds pad values into boundary windows, and only the identity makes
    that a no-op (the padding contract of ``segment_table_pallas``).
    """
    if interpret is None:
        interpret = _auto_interpret()
    with jax.named_scope("segment_table"):
        n = values.shape[0]
        n_pad = -n % _TILE
        if jnp.issubdtype(values.dtype, jnp.integer):
            info = jnp.iinfo(values.dtype)
        else:
            info = jnp.finfo(values.dtype)
        fill = info.max if op == "min" else info.min
        v2d = jnp.concatenate(
            [values,
             jnp.full((n_pad,), fill, values.dtype)]).reshape(-1, LANES)
        out = segment_table_pallas(v2d, levels=levels, fill=fill, op=op,
                                   interpret=interpret)
        return out.reshape(levels + 1, -1)[:, :n]
