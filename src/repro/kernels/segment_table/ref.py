"""Pure-jnp oracle for the segment_table kernel (the XLA build loop)."""
from __future__ import annotations

import jax.numpy as jnp


def segment_table_ref(values: jnp.ndarray, *, levels: int,
                      op: str) -> jnp.ndarray:
    """[levels + 1, n] table: row k holds op over values[i : i + 2^k]."""
    combine = jnp.minimum if op == "min" else jnp.maximum
    n = values.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    rows = [values]
    t = values
    for k in range(levels):
        t = combine(t, t[jnp.minimum(idx + (1 << k), n - 1)])
        rows.append(t)
    return jnp.stack(rows)
