"""Pallas TPU kernels for the paper's compute hot spots.

Each kernel lives in its own subpackage with three modules:
  <name>.py — the ``pl.pallas_call`` body with explicit BlockSpec tiling;
  ops.py    — the jit'd public wrapper (padding, grid, interpret switch);
  ref.py    — the pure-jnp oracle used by the allclose test sweeps.

Kernels (all validated in interpret mode on CPU; TPU is the target):
  pointer_jump   k-step pointer doubling with the parent table VMEM-resident
                 (the paper's "five jumps between global syncs", restated for
                 the HBM→VMEM hierarchy).
  list_rank      Wyllie list-ranking step: pointer doubling + additive payload.
  hook_edges     edge-centric hooking scan: gather both endpoint reps, emit
                 cross-edge hook proposals (min/max alternation).
  frontier_relax BFS edge relaxation: frontier/undiscovered tests per edge.
  embed_bag      gather + segment-reduce (recsys embedding bag, GNN message
                 aggregation substrate).
  segment_table  doubling sparse-table build for ``compress.segment_reduce``
                 (slice-shift successor, whole table in one launch).
"""


def auto_interpret() -> bool:
    """Shared interpret-mode dispatch: compiled Mosaic on TPU, the Pallas
    interpreter elsewhere. Every ops wrapper resolves ``interpret=None``
    through this single policy (see DESIGN.md §3)."""
    import jax

    return jax.default_backend() != "tpu"
