"""BFS edge-relaxation Pallas kernel (edge-centric Merrill baseline).

Per edge block: gather both endpoint distances from the VMEM-resident dist
table and emit the frontier-expansion mask

    active(e) = (dist[src] == level) & (dist[dst] == INF)

The deterministic parent scatter-min stays in XLA. On TPU this kernel fuses
the two gathers and both compares into one VMEM pass over the edge list —
one launch per BFS level, which is exactly the Θ(diam) launch count the
paper attributes BFS's poor high-diameter behavior to.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 8
INF32 = jnp.iinfo(jnp.int32).max


def _frontier_relax_kernel(src_ref, dst_ref, dist_ref, level_ref, out_ref):
    dist = dist_ref[...].reshape(-1)
    d_src = jnp.take(dist, src_ref[...], axis=0)
    d_dst = jnp.take(dist, dst_ref[...], axis=0)
    level = level_ref[0, 0]
    out_ref[...] = ((d_src == level) & (d_dst == INF32)).astype(jnp.int32)


def frontier_relax_pallas(src2d, dst2d, dist2d, level, *,
                          interpret: bool = True):
    rows = src2d.shape[0]
    dist_rows = dist2d.shape[0]
    assert src2d.shape[1] == LANES and rows % BLOCK_ROWS == 0
    grid = (rows // BLOCK_ROWS,)
    blk = pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))
    full = pl.BlockSpec((dist_rows, LANES), lambda i: (0, 0))
    scalar = pl.BlockSpec((1, 1), lambda i: (0, 0))
    return pl.pallas_call(
        _frontier_relax_kernel,
        out_shape=jax.ShapeDtypeStruct(src2d.shape, jnp.int32),
        in_specs=[blk, blk, full, scalar],
        out_specs=blk,
        grid=grid,
        interpret=interpret,
    )(src2d, dst2d, dist2d, level)
