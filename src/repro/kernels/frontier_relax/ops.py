"""jit'd wrapper for the frontier_relax kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import auto_interpret as _auto_interpret
from repro.kernels.frontier_relax.frontier_relax import (BLOCK_ROWS, INF32,
                                                         LANES,
                                                         frontier_relax_pallas)

_TILE = BLOCK_ROWS * LANES


@partial(jax.jit, static_argnames=("interpret",))
def frontier_relax(dist: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray,
                   level, *, interpret: bool | None = None) -> jnp.ndarray:
    """bool[E] frontier-expansion mask for one BFS level."""
    if interpret is None:
        interpret = _auto_interpret()
    e = src.shape[0]
    e_pad = -e % _TILE
    src2d = jnp.concatenate([src, jnp.zeros((e_pad,), src.dtype)]).reshape(-1, LANES)
    dst2d = jnp.concatenate([dst, jnp.zeros((e_pad,), dst.dtype)]).reshape(-1, LANES)
    n = dist.shape[0]
    n_pad = -n % _TILE
    # Pad dist with INF (never on frontier, never undiscovered-eligible as
    # src; pad edges point at node 0 whose true dist decides — then sliced off).
    dist2d = jnp.concatenate(
        [dist, jnp.full((n_pad,), INF32, dist.dtype)]).reshape(-1, LANES)
    level_arr = jnp.asarray(level, jnp.int32).reshape(1, 1)
    out = frontier_relax_pallas(src2d, dst2d, dist2d, level_arr,
                                interpret=interpret)
    return out.reshape(-1)[:e].astype(jnp.bool_)
