"""Pure-jnp oracle for the frontier_relax kernel."""
from __future__ import annotations

import jax.numpy as jnp

INF32 = jnp.iinfo(jnp.int32).max


def frontier_relax_ref(dist, src, dst, level):
    return (dist[src] == level) & (dist[dst] == INF32)
