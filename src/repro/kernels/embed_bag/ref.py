"""Pure-jnp oracle for the embed_bag kernel."""
from __future__ import annotations

import jax.numpy as jnp


def embed_bag_ref(idx, weights, table, *, mean: bool = False):
    rows = table[idx]                               # (B, hot, D)
    acc = jnp.sum(rows * weights[..., None].astype(rows.dtype), axis=1)
    if mean:
        denom = jnp.maximum(jnp.sum(weights, axis=1, keepdims=True), 1e-9)
        acc = acc / denom.astype(acc.dtype)
    return acc
