"""jit'd wrapper for the embed_bag kernel (padding, weights, custom VJP).

The Pallas forward gets a hand-written VJP (gathers/scatter-adds in XLA):
  d table[idx[b,h]] += ŵ[b,h] · g[b]        (ŵ = w, or w/Σw for mean)
  d w[b,h]          = g[b] · (r[b,h] − mean·[out])/denom   (mean case)
                    = g[b] · r[b,h]                         (sum case)
so the kernel is trainable end-to-end (DIEN path).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import auto_interpret as _auto_interpret
from repro.kernels.embed_bag.embed_bag import (BAG_BLOCK, D_TILE,
                                               embed_bag_pallas)


def _fwd_kernel(idx, table, weights, mean: bool, interpret: bool):
    b, hot = idx.shape
    v, d = table.shape
    b_pad = -b % BAG_BLOCK
    d_pad = -d % D_TILE
    idx_p = jnp.pad(idx, ((0, b_pad), (0, 0)))
    w_p = jnp.pad(weights, ((0, b_pad), (0, 0)))
    table_p = jnp.pad(table, ((0, 0), (0, d_pad)))
    out = embed_bag_pallas(idx_p, w_p, table_p, mean=mean,
                           interpret=interpret)
    return out[:b, :d]


@partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _embed_bag(idx, table, weights, mean: bool, interpret: bool):
    return _fwd_kernel(idx, table, weights, mean, interpret)


def _vjp_fwd(idx, table, weights, mean, interpret):
    out = _fwd_kernel(idx, table, weights, mean, interpret)
    return out, (idx, table, weights, out)


def _vjp_bwd(mean, interpret, res, g):
    idx, table, weights, out = res
    b, hot = idx.shape
    rows = table[idx]                                  # [B, hot, D]
    if mean:
        denom = jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)  # [B,1]
        w_eff = weights / denom
        d_w = jnp.einsum("bd,bhd->bh", g, rows) / denom \
            - jnp.einsum("bd,bd->b", g, out)[:, None] / denom
        d_rows = w_eff[..., None] * g[:, None, :]
    else:
        d_w = jnp.einsum("bd,bhd->bh", g, rows)
        d_rows = weights[..., None] * g[:, None, :]
    d_table = jnp.zeros_like(table).at[idx.reshape(-1)].add(
        d_rows.reshape(-1, table.shape[1]).astype(table.dtype))
    return None, d_table, d_w.astype(weights.dtype)


_embed_bag.defvjp(_vjp_fwd, _vjp_bwd)


@partial(jax.jit, static_argnames=("mean", "interpret"))
def embed_bag(idx: jnp.ndarray, table: jnp.ndarray,
              weights: jnp.ndarray | None = None, *, mean: bool = False,
              interpret: bool | None = None) -> jnp.ndarray:
    """EmbeddingBag: out[b] = Σ_h w[b,h] · table[idx[b,h]] (or mean)."""
    if interpret is None:
        interpret = _auto_interpret()
    b, hot = idx.shape
    if weights is None:
        weights = jnp.ones((b, hot), jnp.float32)
    return _embed_bag(idx, table, weights, mean, interpret)
