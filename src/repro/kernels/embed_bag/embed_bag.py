"""Embedding-bag Pallas kernel: fixed-hotness gather + reduce.

JAX has no native EmbeddingBag; this kernel is the TPU implementation used
by the DIEN recsys pipeline (multi-hot categorical fields) and as the dense
molecule-batch aggregation substrate for GNNs.

Tiling: grid = (bags/BAG_BLOCK, D/D_TILE). Each program gathers ``hot`` rows
for BAG_BLOCK bags restricted to one D_TILE-wide feature slice and reduces
over the hot axis — the working set is (BAG_BLOCK·hot + BAG_BLOCK) × D_TILE
floats plus the table slice. The table is streamed per D-tile (BlockSpec
partitions the feature axis), so VMEM holds only V × D_TILE of it; for
vocabularies beyond VMEM the production variant keeps the table in ANY/HBM
and double-buffers row DMAs — same body, different memory_space (documented
adaptation, cf. DESIGN.md §2).

sum/mean reduction; per-sample weights optional (weights == None → ones).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BAG_BLOCK = 8
D_TILE = 128


def _embed_bag_kernel(idx_ref, w_ref, table_ref, out_ref, *, mean: bool):
    idx = idx_ref[...]                      # (BAG_BLOCK, hot)
    w = w_ref[...]                          # (BAG_BLOCK, hot)
    table = table_ref[...]                  # (V, D_TILE)
    rows = jnp.take(table, idx.reshape(-1), axis=0)
    rows = rows.reshape(idx.shape[0], idx.shape[1], -1)
    acc = jnp.sum(rows * w[..., None].astype(rows.dtype), axis=1)
    if mean:
        denom = jnp.maximum(jnp.sum(w, axis=1, keepdims=True), 1e-9)
        acc = acc / denom.astype(acc.dtype)
    out_ref[...] = acc


def embed_bag_pallas(idx, weights, table, *, mean: bool = False,
                     interpret: bool = True):
    b, hot = idx.shape
    v, d = table.shape
    assert b % BAG_BLOCK == 0 and d % D_TILE == 0
    grid = (b // BAG_BLOCK, d // D_TILE)
    kernel = functools.partial(_embed_bag_kernel, mean=mean)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
        in_specs=[
            pl.BlockSpec((BAG_BLOCK, hot), lambda i, j: (i, 0)),
            pl.BlockSpec((BAG_BLOCK, hot), lambda i, j: (i, 0)),
            pl.BlockSpec((v, D_TILE), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((BAG_BLOCK, D_TILE), lambda i, j: (i, j)),
        grid=grid,
        interpret=interpret,
    )(idx, weights, table)
