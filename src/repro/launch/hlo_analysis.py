"""Trip-count-aware HLO cost analysis.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
empirically — a 12-iteration scan reports 1 iteration of FLOPs), which
under-counts every scanned-layer model by ~L×. This module parses the
optimized HLO text, multiplies op costs by ``known_trip_count`` from each
while op's backend_config, and accounts:

  * flops        — dot ops (2 · prod(result dims) · prod(contracting dims)),
                   descending into fusions and called computations;
  * bytes        — operand + result bytes at fusion boundaries (HBM traffic
                   proxy; fusion internals stay in registers/VMEM);
  * collectives  — per-op payload bytes (operand sizes) × trip multiplier,
                   bucketed by opcode.

Shapes are per-device (the compiled module is the SPMD-partitioned one), so
all results are *per-chip* numbers — exactly what the roofline terms need.
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")


def _type_bytes(type_str: str) -> int:
    """Bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",") if d] if dims else []


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    result_type: str
    operands: list
    attrs: str


_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")


def _split_op_line(s: str):
    """Robustly split '%name = TYPE opcode(args), attrs' (TYPE may be a
    tuple containing /*index=N*/ comments, layouts, etc.)."""
    m = _NAME_RE.match(s)
    if not m:
        return None
    name = m.group(1)
    rest = s[m.end():]
    # TYPE: either a balanced-paren tuple or a single token.
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        rtype = rest[:i + 1]
        rest = rest[i + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        rtype = rest[:sp]
        rest = rest[sp + 1:].lstrip()
    par = rest.find("(")
    if par < 0:
        return None
    opcode = rest[:par].strip()
    if not re.fullmatch(r"[\w\-]+", opcode or ""):
        return None
    # args: balanced parens from `par`.
    depth = 0
    for i in range(par, len(rest)):
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
            if depth == 0:
                break
    args = rest[par + 1:i]
    attrs = rest[i + 1:]
    return name, rtype, opcode, args, attrs


def parse_hlo(text: str):
    """→ (computations: {name: [Op]}, op_types: {comp: {opname: type}})."""
    computations: dict[str, list[Op]] = {}
    op_types: dict[str, dict[str, str]] = {}
    current = None
    for line in text.splitlines():
        s = line.rstrip()
        header = re.match(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->", s)
        if header and s.endswith("{"):
            current = header.group(1)
            computations[current] = []
            op_types[current] = {}
            continue
        if s == "}":
            current = None
            continue
        if current is None:
            continue
        parsed = _split_op_line(s)
        if parsed is None:
            continue
        name, rtype, opcode, args, attrs = parsed
        operands = re.findall(r"%([\w.\-]+)", args)
        computations[current].append(
            Op(name=name, opcode=opcode, result_type=rtype.strip(),
               operands=operands, attrs=attrs))
        op_types[current][name] = rtype.strip()
    return computations, op_types


def _trip_count(attrs: str) -> int:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', attrs)
    return int(m.group(1)) if m else 1


def _dot_flops(op: Op, types: dict[str, str]) -> float:
    out_elems = 1
    for d in _shape_dims(op.result_type):
        out_elems *= d
    lhs_type = types.get(op.operands[0], "") if op.operands else ""
    lhs_dims = _shape_dims(lhs_type)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    k = 1
    if m and lhs_dims:
        for idx in m.group(1).split(","):
            if idx:
                k *= lhs_dims[int(idx)]
    return 2.0 * out_elems * k


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    per_collective: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    while_without_trip: int = 0

    def to_json(self) -> dict:
        return {"flops": self.flops, "bytes_accessed": self.bytes_accessed,
                "collective_bytes": self.collective_bytes,
                "per_collective": dict(self.per_collective),
                "while_without_trip": self.while_without_trip}


def analyze(text: str) -> HloCost:
    comps, op_types = parse_hlo(text)
    cost = HloCost()

    def called_comp(attrs: str, key: str):
        m = re.search(rf"{key}=%([\w.\-]+)", attrs)
        return m.group(1) if m else None

    def visit(comp_name: str, mult: float, count_bytes: bool):
        types = op_types.get(comp_name, {})
        for op in comps.get(comp_name, ()):
            oc = op.opcode
            if oc == "while":
                tc = _trip_count(op.attrs)
                if tc == 1 and "known_trip_count" not in op.attrs:
                    cost.while_without_trip += 1
                body = called_comp(op.attrs, "body")
                cond = called_comp(op.attrs, "condition")
                if body:
                    visit(body, mult * tc, count_bytes)
                if cond:
                    visit(cond, mult * tc, count_bytes)
                continue
            if oc in ("fusion", "call", "custom-call"):
                callee = called_comp(op.attrs, "calls")
                if callee:
                    # Descend for FLOPs only; bytes at fusion boundary.
                    visit(callee, mult, False)
            if oc in ("dot", "dot-general"):
                cost.flops += mult * _dot_flops(op, types)
            if oc.startswith("convolution"):
                # not used by our models; approximate via result × window
                cost.flops += 0.0
            if any(oc.startswith(c) for c in COLLECTIVE_OPS):
                payload = sum(_type_bytes(types.get(o, ""))
                              for o in op.operands)
                if payload == 0:
                    payload = _type_bytes(op.result_type)
                base = oc.replace("-start", "")
                cost.per_collective[base] += mult * payload
                cost.collective_bytes += mult * payload
            if count_bytes and oc not in ("parameter", "constant",
                                          "get-tuple-element", "tuple",
                                          "bitcast"):
                b = _type_bytes(op.result_type)
                b += sum(_type_bytes(types.get(o, "")) for o in op.operands)
                cost.bytes_accessed += mult * b

    # Entry computation is the last one in scheduled modules; find by name
    # heuristics: computation referenced by none.
    referenced = set()
    for ops in comps.values():
        for op in ops:
            for key in ("calls", "body", "condition", "to_apply"):
                c = called_comp(op.attrs, key)
                if c:
                    referenced.add(c)
    entries = [c for c in comps if c not in referenced]
    for e in entries:
        visit(e, 1.0, True)
    return cost
