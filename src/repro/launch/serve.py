"""Serving driver: ``python -m repro.launch.serve --arch <id> [--smoke]``.

Batched request loop over the decode path: admits requests up to
--batch, prefills their prompts into the KV cache, then decodes
step-wise (greedy) until --max-new tokens. Reports prefill/decode
throughput. Smoke configs run on CPU; full configs are what the
decode_32k / long_500k dry-run cells lower for the pod meshes.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_arch
from repro.models import transformer as tfm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=[a for a in ARCH_IDS], default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--requests", type=int, default=2,
                    help="number of serving batches to run")
    args = ap.parse_args()

    spec = get_arch(args.arch)
    if spec.family != "lm":
        raise SystemExit(f"{args.arch} is not an LM; serving loop is for "
                         "decode-capable archs")
    cfg = spec.make_smoke_config() if args.smoke else spec.make_config()
    params = tfm.init_params(cfg, jax.random.key(0))
    decode = jax.jit(lambda p, t, c: tfm.decode_step(cfg, p, t, c))

    rng = np.random.default_rng(0)
    max_len = args.prompt_len + args.max_new
    tp, td = [], []
    for req in range(args.requests):
        prompts = jnp.asarray(
            rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)),
            jnp.int32)
        cache = tfm.init_kv_cache(cfg, args.batch, max_len)
        t0 = time.perf_counter()
        for i in range(args.prompt_len):
            logits, cache = decode(params, prompts[:, i], cache)
        jax.block_until_ready(logits)
        tp.append(time.perf_counter() - t0)

        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        t0 = time.perf_counter()
        for _ in range(args.max_new - 1):
            logits, cache = decode(params, tok, cache)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        jax.block_until_ready(tok)
        td.append(time.perf_counter() - t0)
        print(f"request batch {req}: prefill {tp[-1]*1e3:.0f} ms, "
              f"decode {td[-1]*1e3:.0f} ms "
              f"({args.batch*(args.max_new-1)/max(td[-1],1e-9):.0f} tok/s)")

    print(f"\nmedian decode throughput: "
          f"{args.batch*(args.max_new-1)/np.median(td):.0f} tok/s "
          f"(batch={args.batch}, {args.arch}"
          f"{' smoke' if args.smoke else ''})")


if __name__ == "__main__":
    main()
