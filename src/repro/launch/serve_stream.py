"""Streaming RST serving loop: sustain edge-update batches, report rates.

    PYTHONPATH=src python -m repro.launch.serve_stream \
        --graph grid_64 --stream churn --batch 64 --steps 32

The update-loop counterpart of ``repro.launch.serve`` (which drives LM
decode): admit one ``StreamBatch`` per step, apply it to the
``DynamicForest`` (deletion slot resolution + cut + link, one jitted
call each), refresh the Euler-tour numbering at ``--tour-every`` cadence
(incremental by default; ``--tour full`` is the from-scratch ablation,
``--tour off`` skips it), optionally maintain the pool's biconnectivity
at the same cadence (``--bcc incremental|full``, DESIGN.md §10), and
report sustained updates/sec plus batch latency percentiles.

The sustained rate counts *applied* updates only: insertions dropped by
pool overflow and deletions that matched no live edge are excluded (and
reported on a separate dropped-events line when nonzero) — the rate
reflects work done, not traffic offered. ``--validate`` cross-checks the
final forest against a from-scratch build (``core.validate`` oracles)
with a vectorized canonical-relabel partition comparison over *all*
vertices.
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def canonical_partition(rep: np.ndarray) -> np.ndarray:
    """Relabel a representative array to first-occurrence order.

    Two rep arrays describe the same partition iff their canonical forms
    are elementwise equal — an O(n log n) ``np.unique`` cross-check over
    every vertex (replacing the old quadratic strided double loop, which
    sampled pairs and still dominated ``--validate`` wall-clock).
    """
    _, first, inverse = np.unique(rep, return_index=True,
                                  return_inverse=True)
    # np.unique codes are sorted by value; remap them so code k is the
    # k-th distinct representative *encountered*, making labels
    # assignment-order-free.
    order = np.argsort(np.argsort(first))
    return order[inverse]


def main() -> None:
    ap = argparse.ArgumentParser(
        description="batch-dynamic RST serving loop (DESIGN.md §9–§10)")
    ap.add_argument("--graph", default="grid_64",
                    help="data.graphs.SUITE name")
    ap.add_argument("--stream", default="churn",
                    choices=("sliding_window", "insert_heavy", "churn"))
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--steps", type=int, default=32,
                    help="max update batches to apply")
    ap.add_argument("--window", type=int, default=4,
                    help="sliding_window retention (batches)")
    ap.add_argument("--tour", default="incremental",
                    choices=("incremental", "full", "off"),
                    help="tour refresh mode (full = ablation baseline)")
    ap.add_argument("--tour-every", type=int, default=4,
                    help="refresh the tour numbering every k batches")
    ap.add_argument("--bcc", default="off",
                    choices=("incremental", "full", "off"),
                    help="maintain pool biconnectivity at the tour "
                         "cadence (DESIGN.md §10)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--validate", action="store_true",
                    help="oracle-check the final forest")
    args = ap.parse_args()

    import jax

    from repro.data.graphs import SUITE
    from repro.data.streams import STREAMS
    from repro.dynamic import (init_state, refresh_bcc, refresh_tour,
                               replay_batch)

    factory, kwargs, regime = SUITE[args.graph]
    g = factory(**kwargs)
    n = g.n_nodes
    stream_kwargs = {"batch": args.batch, "seed": args.seed}
    if args.stream == "sliding_window":
        stream_kwargs["window"] = args.window
    if args.stream == "churn":
        stream_kwargs["n_batches"] = args.steps
    stream = STREAMS[args.stream](g, **stream_kwargs)
    batches = stream.batches[:args.steps]

    print(f"graph {args.graph} ({regime}): V={n} E={g.n_edges}; "
          f"stream {args.stream}, batch={args.batch}, "
          f"{len(batches)} batches, tour={args.tour}, bcc={args.bcc}")

    state = init_state(stream)
    # Warm the jits on the first batch shapes (not timed).
    if batches:
        warm, _ = replay_batch(state, batches[0])
        jax.block_until_ready(warm.parent)

    tn = None
    bcc = None
    applied = 0
    dropped_overflow = 0
    dropped_unmatched = 0
    lat, tour_lat, bcc_lat = [], [], []
    t_loop = time.perf_counter()
    for step, b in enumerate(batches):
        t0 = time.perf_counter()
        state, stats = replay_batch(state, b)
        jax.block_until_ready(state.parent)
        lat.append(time.perf_counter() - t0)
        # Applied updates only: offered insertions minus pool overflow,
        # plus deletions that actually matched a live pool slot.
        ins_offered = int((b.ins_u < n).sum())
        del_offered = int((b.del_u < n).sum())
        overflow = int(stats["overflow"])
        del_found = int(stats["deletes_found"])
        applied += (ins_offered - overflow) + del_found
        dropped_overflow += overflow
        dropped_unmatched += del_offered - del_found
        if args.tour != "off" and (step + 1) % args.tour_every == 0:
            t0 = time.perf_counter()
            tn, state = refresh_tour(
                state, tn, incremental=(args.tour == "incremental"))
            jax.block_until_ready(tn.pre)
            tour_lat.append(time.perf_counter() - t0)
        if args.bcc != "off" and (step + 1) % args.tour_every == 0:
            t0 = time.perf_counter()
            bcc = refresh_bcc(state, bcc, tour=tn,
                              incremental=(args.bcc == "incremental"))
            jax.block_until_ready(bcc.edge_bcc)
            bcc_lat.append(time.perf_counter() - t0)
        if step < 3 or (step + 1) % 8 == 0:
            line = (f"  batch {step:3d}: {lat[-1]*1e3:6.1f} ms  "
                    f"cuts={int(stats['cuts'])} links={int(stats['links'])} "
                    f"rounds={int(stats['rounds'])} "
                    f"components={int(state.n_components)}")
            if bcc is not None:
                line += (f" n_bcc={int(bcc.n_bcc)} "
                         f"bridges={int(bcc.n_bridges)}")
            print(line)
    elapsed = time.perf_counter() - t_loop

    lat_ms = np.asarray(lat) * 1e3
    print(f"\nsustained: {applied / max(elapsed, 1e-9):,.0f} updates/sec "
          f"({applied} applied events / {elapsed:.2f} s)")
    dropped = dropped_overflow + dropped_unmatched
    if dropped:
        print(f"dropped: {dropped} events excluded from the rate "
              f"(pool overflow={dropped_overflow}, "
              f"unmatched deletes={dropped_unmatched})")
    print(f"batch latency: p50 {np.percentile(lat_ms, 50):.1f} ms, "
          f"p95 {np.percentile(lat_ms, 95):.1f} ms")
    if tour_lat:
        print(f"tour refresh ({args.tour}): median "
              f"{np.median(tour_lat)*1e3:.1f} ms over {len(tour_lat)} calls")
    if bcc_lat:
        print(f"bcc refresh ({args.bcc}): median "
              f"{np.median(bcc_lat)*1e3:.1f} ms over {len(bcc_lat)} calls; "
              f"final n_bcc={int(bcc.n_bcc)} "
              f"bridges={int(bcc.n_bridges)} "
              f"articulation={int(bcc.n_articulation)}")

    if args.validate:
        from repro.core.compress import roots_of
        from repro.core.rst import rooted_spanning_tree
        from repro.core.validate import validate_rst
        from repro.dynamic import live_graph

        lg = live_graph(state)
        root = int(np.asarray(state.rep)[0])
        v = validate_rst(lg, np.asarray(state.parent), root, connected=False)
        scratch = rooted_spanning_tree(lg, root, method="gconn_euler")
        rep_d = np.asarray(state.rep)
        rep_s = np.asarray(roots_of(scratch.parent))
        same = bool(np.array_equal(canonical_partition(rep_d),
                                   canonical_partition(rep_s)))
        print(f"validate: forest {v}, partition==from-scratch: {same} "
              f"(all {n} vertices)")


if __name__ == "__main__":
    main()
