"""Streaming RST serving loop: sustain edge-update batches, report rates.

    PYTHONPATH=src python -m repro.launch.serve_stream \
        --graph grid_64 --stream churn --batch 64 --steps 32

The update-loop counterpart of ``repro.launch.serve`` (which drives LM
decode): admit one ``StreamBatch`` per step, apply it to the
``DynamicForest`` (deletion slot resolution + cut + link, one jitted
call each), refresh the Euler-tour numbering at ``--tour-every`` cadence
(incremental by default; ``--tour full`` is the from-scratch ablation,
``--tour off`` skips it), and report sustained updates/sec plus batch
latency percentiles. ``--validate`` cross-checks the final forest
against a from-scratch build (``core.validate`` oracles).
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser(
        description="batch-dynamic RST serving loop (DESIGN.md §9)")
    ap.add_argument("--graph", default="grid_64",
                    help="data.graphs.SUITE name")
    ap.add_argument("--stream", default="churn",
                    choices=("sliding_window", "insert_heavy", "churn"))
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--steps", type=int, default=32,
                    help="max update batches to apply")
    ap.add_argument("--window", type=int, default=4,
                    help="sliding_window retention (batches)")
    ap.add_argument("--tour", default="incremental",
                    choices=("incremental", "full", "off"),
                    help="tour refresh mode (full = ablation baseline)")
    ap.add_argument("--tour-every", type=int, default=4,
                    help="refresh the tour numbering every k batches")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--validate", action="store_true",
                    help="oracle-check the final forest")
    args = ap.parse_args()

    import jax

    from repro.data.graphs import SUITE
    from repro.data.streams import STREAMS
    from repro.dynamic import init_state, refresh_tour, replay_batch

    factory, kwargs, regime = SUITE[args.graph]
    g = factory(**kwargs)
    stream_kwargs = {"batch": args.batch, "seed": args.seed}
    if args.stream == "sliding_window":
        stream_kwargs["window"] = args.window
    if args.stream == "churn":
        stream_kwargs["n_batches"] = args.steps
    stream = STREAMS[args.stream](g, **stream_kwargs)
    batches = stream.batches[:args.steps]

    print(f"graph {args.graph} ({regime}): V={g.n_nodes} E={g.n_edges}; "
          f"stream {args.stream}, batch={args.batch}, "
          f"{len(batches)} batches, tour={args.tour}")

    state = init_state(stream)
    # Warm the jits on the first batch shapes (not timed).
    if batches:
        warm, _ = replay_batch(state, batches[0])
        jax.block_until_ready(warm.parent)

    tn = None
    events = 0
    lat, tour_lat = [], []
    t_loop = time.perf_counter()
    for step, b in enumerate(batches):
        t0 = time.perf_counter()
        state, stats = replay_batch(state, b)
        jax.block_until_ready(state.parent)
        lat.append(time.perf_counter() - t0)
        events += int((b.ins_u < g.n_nodes).sum())
        events += int((b.del_u < g.n_nodes).sum())
        if args.tour != "off" and (step + 1) % args.tour_every == 0:
            t0 = time.perf_counter()
            tn, state = refresh_tour(
                state, tn, incremental=(args.tour == "incremental"))
            jax.block_until_ready(tn.pre)
            tour_lat.append(time.perf_counter() - t0)
        if step < 3 or (step + 1) % 8 == 0:
            print(f"  batch {step:3d}: {lat[-1]*1e3:6.1f} ms  "
                  f"cuts={int(stats['cuts'])} links={int(stats['links'])} "
                  f"rounds={int(stats['rounds'])} "
                  f"components={int(state.n_components)}")
    elapsed = time.perf_counter() - t_loop

    lat_ms = np.asarray(lat) * 1e3
    print(f"\nsustained: {events / max(elapsed, 1e-9):,.0f} updates/sec "
          f"({events} events / {elapsed:.2f} s)")
    print(f"batch latency: p50 {np.percentile(lat_ms, 50):.1f} ms, "
          f"p95 {np.percentile(lat_ms, 95):.1f} ms")
    if tour_lat:
        print(f"tour refresh ({args.tour}): median "
              f"{np.median(tour_lat)*1e3:.1f} ms over {len(tour_lat)} calls")

    if args.validate:
        from repro.core.compress import roots_of
        from repro.core.rst import rooted_spanning_tree
        from repro.core.validate import validate_rst
        from repro.dynamic import live_graph

        lg = live_graph(state)
        root = int(np.asarray(state.rep)[0])
        v = validate_rst(lg, np.asarray(state.parent), root, connected=False)
        scratch = rooted_spanning_tree(lg, root, method="gconn_euler")
        rep_d = np.asarray(state.rep)
        rep_s = np.asarray(roots_of(scratch.parent))
        same = all((rep_d[i] == rep_d[j]) == (rep_s[i] == rep_s[j])
                   for i in range(0, g.n_nodes, 97)
                   for j in range(0, g.n_nodes, 89))
        print(f"validate: forest {v}, partition==from-scratch: {same}")


if __name__ == "__main__":
    main()
