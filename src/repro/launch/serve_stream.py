"""Streaming RST serving loop: sustain edge-update batches, report rates.

    PYTHONPATH=src python -m repro.launch.serve_stream \
        --graph grid_64 --stream churn --batch 64 --steps 32

The update-loop counterpart of ``repro.launch.serve`` (which drives LM
decode): admit one ``StreamBatch`` per step, apply it to the
``DynamicForest`` (deletion slot resolution + cut + link, one jitted
call each), refresh the Euler-tour numbering at ``--tour-every`` cadence
(incremental by default; ``--tour full`` is the from-scratch ablation,
``--tour off`` skips it), optionally maintain the pool's biconnectivity
at the same cadence (``--bcc incremental|full``, DESIGN.md §10), and
report sustained updates/sec plus batch latency percentiles.

Since the self-healing PR the loop itself is
``repro.launch.resilient.ResilientStreamLoop`` (DESIGN.md §11): batches
apply under a watchdog with retry, ``--audit-every k`` runs the
O(log n)-sync invariant audit (with scoped repair on violation),
``--chaos`` injects deterministic seeded faults to exercise that path,
``--sanitize`` quarantines malformed events in front of the forest, and
``--ckpt-dir/--ckpt-every/--resume`` give the loop crash recovery with
replay-exact resume (the stream cursor rides the checkpoint manifest).

The sustained rate counts *applied* updates only: insertions dropped by
pool overflow and deletions that matched no live edge are excluded (and
reported on a separate dropped-events line when nonzero) — the rate
reflects work done, not traffic offered. ``--validate`` cross-checks the
final forest against a from-scratch build (``core.validate`` oracles)
with a vectorized canonical-relabel partition comparison over *all*
vertices.

Since the query-layer PR the loop also serves *reads* (DESIGN.md §12):
``--read-ratio r`` interleaves query batches (LCA / connectivity /
aggregates / BCC membership, round-robin) so that reads are fraction r
of all events, answered by a ``dynamic.queries.QuerySession`` that the
loop's ``ForestView`` re-adopts at each refresh cadence.
``--query-staleness`` picks the policy between refreshes: ``stale``
(default — bounded staleness, serve the last refreshed view), ``strict``
(skip + count read batches that would see a stale view), or ``refresh``
(rebuild per stale read batch — the recompute ablation table7 measures).
Read reporting: per-op latency percentiles (ops that never fired in a
short run report "no samples" instead of crashing the percentile math)
plus the sync accounting — table builds and build-syncs amortized per
read batch.

The whole flag surface is the typed ``launch.config.ServeConfig`` schema
(shared verbatim with ``serve_fleet``); this module binds it to argparse
and hands the config object to ``ResilientStreamLoop.from_config``.

The observability layer (DESIGN.md §14) rides the same loop:
``--trace-out`` installs an ``obs.Tracer`` around the run — per-tick
spans with wall-clock AND sync attribution, JSONL plus Perfetto-loadable
Chrome JSON — and ``--metrics-out`` flushes an ``obs.MetricsRegistry``
(counters/gauges/histograms) as JSON. Instrumentation is free when off
and bit-identical when on (the zero-sync contract, tests/test_obs.py).
"""
from __future__ import annotations

import argparse
import contextlib
import time

import numpy as np

from repro import obs


def canonical_partition(rep: np.ndarray) -> np.ndarray:
    """Relabel a representative array to first-occurrence order.

    Two rep arrays describe the same partition iff their canonical forms
    are elementwise equal — an O(n log n) ``np.unique`` cross-check over
    every vertex (replacing the old quadratic strided double loop, which
    sampled pairs and still dominated ``--validate`` wall-clock).
    """
    _, first, inverse = np.unique(rep, return_index=True,
                                  return_inverse=True)
    # np.unique codes are sorted by value; remap them so code k is the
    # k-th distinct representative *encountered*, making labels
    # assignment-order-free.
    order = np.argsort(np.argsort(first))
    return order[inverse]


class _ReadDriver:
    """Interleave query batches with the write loop (DESIGN.md §12).

    Per write batch, accumulates fractional read *debt* so that reads
    make up ``read_ratio`` of all events, then drains it one query batch
    at a time: a round-robin op mix (BCC membership ops only when the
    loop maintains biconnectivity) over seeded-random vertex ids. The
    ``QuerySession`` is owned by the loop's ``ForestView`` — adoption
    (rebuild on tour-refresh, carry counters across generations) is the
    view's job; this driver only issues queries and records latencies.
    """

    def __init__(self, loop, cfg, n: int):
        import jax.numpy as jnp

        self.loop = loop
        self.policy = cfg.read.query_staleness
        self.read_batch = cfg.read.read_batch
        self.per_write = (cfg.read.read_ratio / (1.0 - cfg.read.read_ratio)
                          * cfg.stream.batch / cfg.read.read_batch)
        self.n = n
        self.rng = np.random.default_rng(cfg.stream.seed + 104729)
        self.payload = jnp.asarray(
            self.rng.integers(1, 100, n), jnp.int32)
        self.debt = 0.0
        self.lat: dict[str, list[float]] = {}
        self.batches = 0
        self.skipped_stale = 0

    def _ops(self, sess):
        ops = ["lca", "connected", "depth", "subtree_add", "path_add",
               "path_min"]
        if sess is not None and sess.bcc is not None:
            ops += ["is_bridge", "is_articulation"]
        return ops

    def serve(self, step: int) -> None:
        import jax

        from repro.dynamic.queries import StaleQueryError

        sess = self.loop.view.adopt_session(self.loop.state)
        self.debt += self.per_write
        while self.debt >= 1.0:
            self.debt -= 1.0
            ops = self._ops(sess)
            op = ops[self.batches % len(ops)]
            u = self.rng.integers(0, self.n, self.read_batch)
            v = self.rng.integers(0, self.n, self.read_batch)
            state = self.loop.state
            t0 = time.perf_counter()
            try:
                if op == "lca":
                    out = sess.lca(state, u, v)
                elif op == "connected":
                    out = sess.connected(state, u, v)
                elif op == "depth":
                    out = sess.depth(state, u)
                elif op == "subtree_add":
                    out = sess.subtree_agg(state, u, self.payload)
                elif op == "path_add":
                    out = sess.path_agg(state, u, v, self.payload)
                elif op == "path_min":
                    out = sess.path_agg(state, u, v, self.payload,
                                        "min")
                elif op == "is_bridge":
                    out = sess.is_bridge(state, u, v)
                else:
                    out = sess.is_articulation(state, u)
            except StaleQueryError:
                self.skipped_stale += 1   # strict policy between refreshes
                self.batches += 1
                continue
            jax.block_until_ready(out)
            self.lat.setdefault(op, []).append(time.perf_counter() - t0)
            self.batches += 1

    def report(self) -> None:
        served = sum(len(v) for v in self.lat.values())
        total = served * self.read_batch
        print(f"\nreads: {total} queries in {served} batches of "
              f"{self.read_batch} (staleness={self.policy}"
              + (f", {self.skipped_stale} batches skipped stale"
                 if self.skipped_stale else "") + ")")
        # Full op mix, in round-robin order: a short run may never reach
        # the later ops — obs.percentile_line reports "no samples"
        # instead of handing np.percentile an empty list (shared path
        # with serve_fleet, regression-tested in tests/test_obs.py).
        sess = self.loop.view.session
        mix = self._ops(sess)
        extras = sorted(set(self.lat) - set(mix))
        for op in mix + extras:
            line = obs.percentile_line(
                self.lat.get(op, ()), width=7, count_suffix=True,
                empty_reason=f"op never reached in {self.batches} "
                             "read batches")
            print(f"  {op:15s}: {line}")
        t = sess.sync_stats() if sess is not None else {
            "builds": 0, "build_syncs_total": 0, "stale_served": 0,
            "auto_refreshes": 0}
        amort = t["build_syncs_total"] / max(served, 1)
        print(f"query sync accounting: {t['builds']} table builds, "
              f"{t['build_syncs_total']} build syncs "
              f"({amort:.2f} amortized per read batch; queries are "
              f"sync-free gathers), stale_served={t['stale_served']}, "
              f"auto_refreshes={t['auto_refreshes']}")


def main(argv=None) -> None:
    from repro.launch.config import ServeConfig

    ap = argparse.ArgumentParser(
        description="batch-dynamic RST serving loop (DESIGN.md §9–§12)")
    ServeConfig.add_args(ap)
    args = ap.parse_args(argv)
    try:
        cfg = ServeConfig.from_args(args).check()
    except ValueError as e:
        ap.error(str(e))

    import jax

    from repro.data.graphs import SUITE
    from repro.data.streams import STREAMS
    from repro.dynamic.chaos import INJECTORS
    from repro.launch.resilient import ResilientStreamLoop

    factory, kwargs, regime = SUITE[cfg.stream.graph]
    g = factory(**kwargs)
    n = g.n_nodes
    stream = STREAMS[cfg.stream.stream](g, **cfg.stream_kwargs())
    batches = stream.batches[:cfg.stream.steps]

    try:
        chaos = cfg.injector_names(INJECTORS)
    except ValueError as e:
        ap.error(str(e))

    print(f"graph {cfg.stream.graph} ({regime}): V={n} E={g.n_edges}; "
          f"stream {cfg.stream.stream}, batch={cfg.stream.batch}, "
          f"{len(batches)} batches, tour={cfg.refresh.tour}, "
          f"bcc={cfg.refresh.bcc}"
          + (f", chaos={','.join(chaos)}@{cfg.chaos.chaos_every}" if chaos
             else "")
          + (f", audit@{cfg.chaos.audit_every}" if cfg.chaos.audit_every
             else ""))

    loop = ResilientStreamLoop.from_config(stream, cfg)
    if cfg.read.read_ratio:
        # Let the loop's view own the QuerySession at the refresh cadence.
        loop.view.policy = cfg.cadence()
    if cfg.ckpt.resume:
        start = loop.resume()
        if start:
            print(f"resumed from checkpoint at batch {start}")

    # Warm the jits on the first batch shapes (not timed).
    if batches and loop.cursor < len(batches):
        from repro.dynamic import replay_batch
        warm, _ = replay_batch(loop.state, batches[loop.cursor])
        jax.block_until_ready(warm.parent)

    reads = _ReadDriver(loop, cfg, n) if cfg.read.read_ratio else None

    def snapshot_metrics() -> "obs.MetricsRegistry":
        """The loop's cumulative telemetry as one registry (rebuilt per
        flush — every instrument reflects run-so-far totals)."""
        m = obs.MetricsRegistry()
        m.counter("applied_events").inc(loop.applied)
        m.counter("dropped_overflow").inc(loop.dropped_overflow)
        m.counter("dropped_unmatched").inc(loop.dropped_unmatched)
        m.counter("retries").inc(loop.retries)
        m.counter("faults_injected").inc(len(loop.injected))
        m.counter("recoveries").inc(len(loop.recoveries))
        for cat, count in sorted(loop.quarantine.items()):
            m.counter("quarantined", category=cat).inc(count)
        m.gauge("components").set(int(loop.state.n_components))
        for name, samples in (("batch_latency_ms", loop.lat),
                              ("tour_refresh_ms", loop.tour_lat),
                              ("bcc_refresh_ms", loop.bcc_lat)):
            h = m.histogram(name)
            for s in samples:
                h.observe(s * 1e3)
        if reads is not None:
            m.counter("read_batches").inc(reads.batches)
            m.counter("reads_skipped_stale").inc(reads.skipped_stale)
            for op, samples in sorted(reads.lat.items()):
                h = m.histogram("query_latency_ms", op=op)
                for s in samples:
                    h.observe(s * 1e3)
        return m

    def on_batch(step, stats, dt):
        if reads is not None:
            with obs.span("query_batch", step=step):
                reads.serve(step)
        if cfg.obs.metrics_out and cfg.obs.metrics_every \
                and (step + 1) % cfg.obs.metrics_every == 0:
            snapshot_metrics().write(cfg.obs.metrics_out)
        if step < 3 or (step + 1) % 8 == 0:
            line = (f"  batch {step:3d}: {dt*1e3:6.1f} ms  "
                    f"cuts={int(stats['cuts'])} links={int(stats['links'])} "
                    f"rounds={int(stats['rounds'])} "
                    f"components={int(loop.state.n_components)}")
            if loop.bcc is not None:
                line += (f" n_bcc={int(loop.bcc.n_bcc)} "
                         f"bridges={int(loop.bcc.n_bridges)}")
            print(line)

    tracer = obs.Tracer() if cfg.obs.trace_out else None
    t_loop = time.perf_counter()
    with tracer if tracer is not None else contextlib.nullcontext():
        state = loop.run(batches, on_batch=on_batch)
    elapsed = time.perf_counter() - t_loop

    if not loop.lat:
        print("\nno batches applied (empty stream or --steps 0); "
              "nothing to report")
    else:
        print(f"\nsustained: {loop.applied / max(elapsed, 1e-9):,.0f} "
              f"updates/sec ({loop.applied} applied events / "
              f"{elapsed:.2f} s)")
        dropped = loop.dropped_overflow + loop.dropped_unmatched
        if dropped:
            print(f"dropped: {dropped} events excluded from the rate "
                  f"(pool overflow={loop.dropped_overflow}, "
                  f"unmatched deletes={loop.dropped_unmatched})")
        print(f"batch latency: {obs.percentile_line(loop.lat)}")
        if loop.tour_lat:
            print(f"tour refresh ({cfg.refresh.tour}): median "
                  f"{np.median(loop.tour_lat)*1e3:.1f} ms over "
                  f"{len(loop.tour_lat)} calls")
        if loop.bcc_lat:
            print(f"bcc refresh ({cfg.refresh.bcc}): median "
                  f"{np.median(loop.bcc_lat)*1e3:.1f} ms over "
                  f"{len(loop.bcc_lat)} calls; "
                  f"final n_bcc={int(loop.bcc.n_bcc)} "
                  f"bridges={int(loop.bcc.n_bridges)} "
                  f"articulation={int(loop.bcc.n_articulation)}")
    if reads is not None:
        reads.report()
    if loop.quarantine:
        total = sum(loop.quarantine.values())
        cats = ", ".join(f"{k}={v}" for k, v in
                         sorted(loop.quarantine.items()) if v)
        print(f"quarantined: {total} malformed events rejected by the "
              f"sanitizer ({cats})" if total else
              "quarantined: 0 malformed events")
    if chaos or cfg.chaos.audit_every:
        n_rec = len(loop.recoveries)
        modes = {}
        for _, info in loop.recoveries:
            modes[info["mode"]] = modes.get(info["mode"], 0) + 1
        print(f"chaos: {len(loop.injected)} faults injected; "
              f"recoveries: {n_rec}"
              + (f" ({', '.join(f'{k}={v}' for k, v in sorted(modes.items()))})"
                 if n_rec else ""))
        if loop.last_report is not None:
            print(f"final audit: {loop.last_report.summary()}")

    if tracer is not None:
        tracer.write_jsonl(cfg.obs.trace_out)
        tracer.write_chrome(cfg.obs.trace_out + ".chrome.json")
        s = tracer.summary()
        print(f"trace: {s['span_count']} spans, "
              f"sync_total={s['sync_total']} -> {cfg.obs.trace_out} "
              f"(+ .chrome.json)")
    if cfg.obs.metrics_out:
        snapshot_metrics().write(cfg.obs.metrics_out)
        print(f"metrics -> {cfg.obs.metrics_out}")

    if cfg.validate:
        from repro.core.compress import roots_of
        from repro.core.rst import rooted_spanning_tree
        from repro.core.validate import validate_rst
        from repro.dynamic import live_graph

        lg = live_graph(state)
        root = int(np.asarray(state.rep)[0])
        v = validate_rst(lg, np.asarray(state.parent), root, connected=False)
        scratch = rooted_spanning_tree(lg, root, method="gconn_euler")
        rep_d = np.asarray(state.rep)
        rep_s = np.asarray(roots_of(scratch.parent))
        same = bool(np.array_equal(canonical_partition(rep_d),
                                   canonical_partition(rep_s)))
        print(f"validate: forest {v}, partition==from-scratch: {same} "
              f"(all {n} vertices)")
        if not (v["all_ok"] and same):
            raise SystemExit("validate: FAILED")


if __name__ == "__main__":
    main()
