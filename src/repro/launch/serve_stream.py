"""Streaming RST serving loop: sustain edge-update batches, report rates.

    PYTHONPATH=src python -m repro.launch.serve_stream \
        --graph grid_64 --stream churn --batch 64 --steps 32

The update-loop counterpart of ``repro.launch.serve`` (which drives LM
decode): admit one ``StreamBatch`` per step, apply it to the
``DynamicForest`` (deletion slot resolution + cut + link, one jitted
call each), refresh the Euler-tour numbering at ``--tour-every`` cadence
(incremental by default; ``--tour full`` is the from-scratch ablation,
``--tour off`` skips it), optionally maintain the pool's biconnectivity
at the same cadence (``--bcc incremental|full``, DESIGN.md §10), and
report sustained updates/sec plus batch latency percentiles.

Since the self-healing PR the loop itself is
``repro.launch.resilient.ResilientStreamLoop`` (DESIGN.md §11): batches
apply under a watchdog with retry, ``--audit-every k`` runs the
O(log n)-sync invariant audit (with scoped repair on violation),
``--chaos`` injects deterministic seeded faults to exercise that path,
``--sanitize`` quarantines malformed events in front of the forest, and
``--ckpt-dir/--ckpt-every/--resume`` give the loop crash recovery with
replay-exact resume (the stream cursor rides the checkpoint manifest).

The sustained rate counts *applied* updates only: insertions dropped by
pool overflow and deletions that matched no live edge are excluded (and
reported on a separate dropped-events line when nonzero) — the rate
reflects work done, not traffic offered. ``--validate`` cross-checks the
final forest against a from-scratch build (``core.validate`` oracles)
with a vectorized canonical-relabel partition comparison over *all*
vertices.

Since the query-layer PR the loop also serves *reads* (DESIGN.md §12):
``--read-ratio r`` interleaves query batches (LCA / connectivity /
aggregates / BCC membership, round-robin) so that reads are fraction r
of all events, answered by a ``dynamic.queries.QuerySession`` that
adopts the loop's tour/BCC caches at each refresh cadence.
``--query-staleness`` picks the policy between refreshes: ``stale``
(default — bounded staleness, serve the last refreshed view), ``strict``
(skip + count read batches that would see a stale view), or ``refresh``
(rebuild per stale read batch — the recompute ablation table7 measures).
Read reporting: per-op latency percentiles plus the sync accounting —
table builds and build-syncs amortized per read batch.
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def canonical_partition(rep: np.ndarray) -> np.ndarray:
    """Relabel a representative array to first-occurrence order.

    Two rep arrays describe the same partition iff their canonical forms
    are elementwise equal — an O(n log n) ``np.unique`` cross-check over
    every vertex (replacing the old quadratic strided double loop, which
    sampled pairs and still dominated ``--validate`` wall-clock).
    """
    _, first, inverse = np.unique(rep, return_index=True,
                                  return_inverse=True)
    # np.unique codes are sorted by value; remap them so code k is the
    # k-th distinct representative *encountered*, making labels
    # assignment-order-free.
    order = np.argsort(np.argsort(first))
    return order[inverse]


class _ReadDriver:
    """Interleave query batches with the write loop (DESIGN.md §12).

    Per write batch, accumulates fractional read *debt* so that reads
    make up ``read_ratio`` of all events, then drains it one query batch
    at a time: a round-robin op mix (BCC membership ops only when the
    loop maintains biconnectivity) over seeded-random vertex ids. The
    ``QuerySession`` adopts the loop's tour/BCC caches whenever the
    refresh cadence lands (object identity on ``loop.tn``) and serves
    under ``--query-staleness`` in between; sync/staleness counters are
    accumulated across session generations for the final report.
    """

    def __init__(self, loop, args, n: int):
        import jax.numpy as jnp

        self.loop = loop
        self.policy = args.query_staleness
        self.read_batch = args.read_batch
        self.per_write = (args.read_ratio / (1.0 - args.read_ratio)
                          * args.batch / args.read_batch)
        self.n = n
        self.rng = np.random.default_rng(args.seed + 104729)
        self.payload = jnp.asarray(
            self.rng.integers(1, 100, n), jnp.int32)
        self.debt = 0.0
        self.sess = None
        self.tn_seen = None
        self.lat: dict[str, list[float]] = {}
        self.batches = 0
        self.skipped_stale = 0
        self.totals = {"builds": 0, "build_syncs_total": 0,
                       "stale_served": 0, "auto_refreshes": 0}

    def _fold_stats(self):
        if self.sess is not None:
            for k, v in self.sess.sync_stats().items():
                self.totals[k] += v

    def _ensure_session(self):
        from repro.dynamic.queries import QuerySession

        refreshed = (self.loop.tn is not None
                     and self.loop.tn is not self.tn_seen)
        if self.sess is not None and not refreshed:
            return
        self._fold_stats()
        try:
            self.sess = QuerySession.from_state(
                self.loop.state, self.loop.tn, self.loop.bcc,
                policy=self.policy)
        except ValueError:
            # Loop caches don't match the live state mid-interval (e.g.
            # first batches before the first cadence refresh): build the
            # view from the state alone, without BCC membership ops.
            self.sess = QuerySession.from_state(self.loop.state,
                                                policy=self.policy)
        self.tn_seen = self.loop.tn

    def _ops(self):
        ops = ["lca", "connected", "depth", "subtree_add", "path_add",
               "path_min"]
        if self.sess.bcc is not None:
            ops += ["is_bridge", "is_articulation"]
        return ops

    def serve(self, step: int) -> None:
        import jax

        from repro.dynamic.queries import StaleQueryError

        self._ensure_session()
        self.debt += self.per_write
        while self.debt >= 1.0:
            self.debt -= 1.0
            ops = self._ops()
            op = ops[self.batches % len(ops)]
            u = self.rng.integers(0, self.n, self.read_batch)
            v = self.rng.integers(0, self.n, self.read_batch)
            state = self.loop.state
            t0 = time.perf_counter()
            try:
                if op == "lca":
                    out = self.sess.lca(state, u, v)
                elif op == "connected":
                    out = self.sess.connected(state, u, v)
                elif op == "depth":
                    out = self.sess.depth(state, u)
                elif op == "subtree_add":
                    out = self.sess.subtree_agg(state, u, self.payload)
                elif op == "path_add":
                    out = self.sess.path_agg(state, u, v, self.payload)
                elif op == "path_min":
                    out = self.sess.path_agg(state, u, v, self.payload,
                                             "min")
                elif op == "is_bridge":
                    out = self.sess.is_bridge(state, u, v)
                else:
                    out = self.sess.is_articulation(state, u)
            except StaleQueryError:
                self.skipped_stale += 1   # strict policy between refreshes
                self.batches += 1
                continue
            jax.block_until_ready(out)
            self.lat.setdefault(op, []).append(time.perf_counter() - t0)
            self.batches += 1

    def report(self) -> None:
        self._fold_stats()
        served = sum(len(v) for v in self.lat.values())
        total = served * self.read_batch
        print(f"\nreads: {total} queries in {served} batches of "
              f"{self.read_batch} (staleness={self.policy}"
              + (f", {self.skipped_stale} batches skipped stale"
                 if self.skipped_stale else "") + ")")
        for op in sorted(self.lat):
            ms = np.asarray(self.lat[op]) * 1e3
            print(f"  {op:15s}: p50 {np.percentile(ms, 50):7.2f} ms  "
                  f"p95 {np.percentile(ms, 95):7.2f} ms  "
                  f"({len(ms)} batches)")
        t = self.totals
        amort = t["build_syncs_total"] / max(served, 1)
        print(f"query sync accounting: {t['builds']} table builds, "
              f"{t['build_syncs_total']} build syncs "
              f"({amort:.2f} amortized per read batch; queries are "
              f"sync-free gathers), stale_served={t['stale_served']}, "
              f"auto_refreshes={t['auto_refreshes']}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="batch-dynamic RST serving loop (DESIGN.md §9–§11)")
    ap.add_argument("--graph", default="grid_64",
                    help="data.graphs.SUITE name")
    ap.add_argument("--stream", default="churn",
                    choices=("sliding_window", "insert_heavy", "churn"))
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--steps", type=int, default=32,
                    help="max update batches to apply")
    ap.add_argument("--window", type=int, default=4,
                    help="sliding_window retention (batches)")
    ap.add_argument("--tour", default="incremental",
                    choices=("incremental", "full", "off"),
                    help="tour refresh mode (full = ablation baseline)")
    ap.add_argument("--tour-every", type=int, default=4,
                    help="refresh the tour numbering every k batches")
    ap.add_argument("--bcc", default="off",
                    choices=("incremental", "full", "off"),
                    help="maintain pool biconnectivity at the tour "
                         "cadence (DESIGN.md §10)")
    ap.add_argument("--read-ratio", type=float, default=0.0,
                    help="fraction of events that are queries: per write "
                         "batch, issue read batches until reads/(reads+"
                         "writes) ~ r (0 = writes only)")
    ap.add_argument("--read-batch", type=int, default=64,
                    help="queries per read batch")
    ap.add_argument("--query-staleness", default="stale",
                    choices=("strict", "refresh", "stale"),
                    help="QuerySession policy between tour refreshes "
                         "(DESIGN.md §12)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--validate", action="store_true",
                    help="oracle-check the final forest")
    ap.add_argument("--audit-every", type=int, default=0,
                    help="audit invariants every k batches and run the "
                         "repair ladder on violation (DESIGN.md §11)")
    ap.add_argument("--chaos", default="",
                    help="comma-separated dynamic.chaos injector names, "
                         "or 'all' (deterministic fault injection)")
    ap.add_argument("--chaos-every", type=int, default=8,
                    help="inject one fault every k batches")
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--sanitize", action="store_true",
                    help="quarantine malformed events before apply")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint directory (enables crash recovery)")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="checkpoint every k batches")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the newest checkpoint in --ckpt-dir")
    args = ap.parse_args(argv)
    if args.read_ratio and not 0.0 < args.read_ratio < 1.0:
        ap.error("--read-ratio must be in (0, 1)")
    if args.read_ratio and args.tour == "off":
        ap.error("--read-ratio needs tour maintenance "
                 "(--tour incremental|full)")

    import jax

    from repro.data.graphs import SUITE
    from repro.data.streams import STREAMS
    from repro.dynamic.chaos import INJECTORS
    from repro.launch.resilient import ResilientStreamLoop

    factory, kwargs, regime = SUITE[args.graph]
    g = factory(**kwargs)
    n = g.n_nodes
    stream_kwargs = {"batch": args.batch, "seed": args.seed}
    if args.stream == "sliding_window":
        stream_kwargs["window"] = args.window
    if args.stream == "churn":
        stream_kwargs["n_batches"] = args.steps
    stream = STREAMS[args.stream](g, **stream_kwargs)
    batches = stream.batches[:args.steps]

    chaos = ()
    if args.chaos:
        chaos = (tuple(INJECTORS) if args.chaos == "all"
                 else tuple(args.chaos.split(",")))
        for name in chaos:
            if name not in INJECTORS:
                ap.error(f"unknown injector {name!r} "
                         f"(have: {', '.join(INJECTORS)})")

    print(f"graph {args.graph} ({regime}): V={n} E={g.n_edges}; "
          f"stream {args.stream}, batch={args.batch}, "
          f"{len(batches)} batches, tour={args.tour}, bcc={args.bcc}"
          + (f", chaos={','.join(chaos)}@{args.chaos_every}" if chaos
             else "")
          + (f", audit@{args.audit_every}" if args.audit_every else ""))

    loop = ResilientStreamLoop.from_stream(
        stream,
        tour_mode=args.tour, bcc_mode=args.bcc, tour_every=args.tour_every,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        audit_every=args.audit_every, chaos=chaos,
        chaos_every=args.chaos_every, chaos_seed=args.chaos_seed,
        sanitize=args.sanitize)
    if args.resume:
        start = loop.resume()
        if start:
            print(f"resumed from checkpoint at batch {start}")

    # Warm the jits on the first batch shapes (not timed).
    if batches and loop.cursor < len(batches):
        from repro.dynamic import replay_batch
        warm, _ = replay_batch(loop.state, batches[loop.cursor])
        jax.block_until_ready(warm.parent)

    reads = _ReadDriver(loop, args, n) if args.read_ratio else None

    def on_batch(step, stats, dt):
        if reads is not None:
            reads.serve(step)
        if step < 3 or (step + 1) % 8 == 0:
            line = (f"  batch {step:3d}: {dt*1e3:6.1f} ms  "
                    f"cuts={int(stats['cuts'])} links={int(stats['links'])} "
                    f"rounds={int(stats['rounds'])} "
                    f"components={int(loop.state.n_components)}")
            if loop.bcc is not None:
                line += (f" n_bcc={int(loop.bcc.n_bcc)} "
                         f"bridges={int(loop.bcc.n_bridges)}")
            print(line)

    t_loop = time.perf_counter()
    state = loop.run(batches, on_batch=on_batch)
    elapsed = time.perf_counter() - t_loop

    if not loop.lat:
        print("\nno batches applied (empty stream or --steps 0); "
              "nothing to report")
    else:
        lat_ms = np.asarray(loop.lat) * 1e3
        print(f"\nsustained: {loop.applied / max(elapsed, 1e-9):,.0f} "
              f"updates/sec ({loop.applied} applied events / "
              f"{elapsed:.2f} s)")
        dropped = loop.dropped_overflow + loop.dropped_unmatched
        if dropped:
            print(f"dropped: {dropped} events excluded from the rate "
                  f"(pool overflow={loop.dropped_overflow}, "
                  f"unmatched deletes={loop.dropped_unmatched})")
        print(f"batch latency: p50 {np.percentile(lat_ms, 50):.1f} ms, "
              f"p95 {np.percentile(lat_ms, 95):.1f} ms")
        if loop.tour_lat:
            print(f"tour refresh ({args.tour}): median "
                  f"{np.median(loop.tour_lat)*1e3:.1f} ms over "
                  f"{len(loop.tour_lat)} calls")
        if loop.bcc_lat:
            print(f"bcc refresh ({args.bcc}): median "
                  f"{np.median(loop.bcc_lat)*1e3:.1f} ms over "
                  f"{len(loop.bcc_lat)} calls; "
                  f"final n_bcc={int(loop.bcc.n_bcc)} "
                  f"bridges={int(loop.bcc.n_bridges)} "
                  f"articulation={int(loop.bcc.n_articulation)}")
    if reads is not None:
        reads.report()
    if loop.quarantine:
        total = sum(loop.quarantine.values())
        cats = ", ".join(f"{k}={v}" for k, v in
                         sorted(loop.quarantine.items()) if v)
        print(f"quarantined: {total} malformed events rejected by the "
              f"sanitizer ({cats})" if total else
              "quarantined: 0 malformed events")
    if chaos or args.audit_every:
        n_rec = len(loop.recoveries)
        modes = {}
        for _, info in loop.recoveries:
            modes[info["mode"]] = modes.get(info["mode"], 0) + 1
        print(f"chaos: {len(loop.injected)} faults injected; "
              f"recoveries: {n_rec}"
              + (f" ({', '.join(f'{k}={v}' for k, v in sorted(modes.items()))})"
                 if n_rec else ""))
        if loop.last_report is not None:
            print(f"final audit: {loop.last_report.summary()}")

    if args.validate:
        from repro.core.compress import roots_of
        from repro.core.rst import rooted_spanning_tree
        from repro.core.validate import validate_rst
        from repro.dynamic import live_graph

        lg = live_graph(state)
        root = int(np.asarray(state.rep)[0])
        v = validate_rst(lg, np.asarray(state.parent), root, connected=False)
        scratch = rooted_spanning_tree(lg, root, method="gconn_euler")
        rep_d = np.asarray(state.rep)
        rep_s = np.asarray(roots_of(scratch.parent))
        same = bool(np.array_equal(canonical_partition(rep_d),
                                   canonical_partition(rep_s)))
        print(f"validate: forest {v}, partition==from-scratch: {same} "
              f"(all {n} vertices)")
        if not (v["all_ok"] and same):
            raise SystemExit("validate: FAILED")


if __name__ == "__main__":
    main()
