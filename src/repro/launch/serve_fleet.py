"""Multi-tenant fleet serving loop: T session graphs, one process.

    PYTHONPATH=src python -m repro.launch.serve_fleet \
        --graph grid_64 --stream churn --batch 64 --steps 32 \
        --tenants 6 --slots 4

The fleet-wide counterpart of ``serve_stream`` (DESIGN.md §13): each
*tenant* is an independent session graph driven by its own edge stream
(same generator, per-tenant seed ``--seed + t``). Per tick the
``FleetDispatcher`` coalesces one queued batch unit per resident tenant
into a fixed-shape ``(T, B)`` event block and ``apply_batches`` applies
it with ONE vmapped §9 program — the fleet pays ``max_t(rounds_t) + 1``
convergence syncs where T sequential loops would pay
``Σ_t(rounds_t + 1)``. Cache refreshes (tour, optional BCC, the stacked
``QueryTables``) are vmapped the same way at ``--tour-every`` cadence,
and reads are served per tenant by a ``FleetQuerySession`` under the
``--query-staleness`` policy.

When ``--tenants`` exceeds ``--slots``, residency rotates round-robin:
admission evicts the least-recently-used resident through the §8
checkpoint path (forest + stream cursor, atomic publish) and
re-admission restores bit-identically, so eviction is invisible to a
tenant's stream history (tests/test_fleet.py proves equality against T
independent single-tenant loops).

Flags are the shared ``ServeConfig`` schema plus the ``FleetConfig``
group (``--tenants``, ``--slots``, ``--evict-dir``); the report prints
per-tenant applied-events/sec, batch/query latency percentiles, and the
fleet-vs-sequential sync accounting that ``benchmarks/table8_fleet.py``
turns into the §13 headline numbers.

``--buckets`` (DESIGN.md §15) replaces the single-schema fleet with
shape-bucketed sub-fleets: each ``graph:tenants[:slots[:batch]]`` spec
becomes a ``FleetBucket`` with its own ``(T_b, B_b)`` block shape,
refresh cadence, idle-LRU admission (async §8 checkpoint prefetch), and
``max_t(rounds)+1`` sync bill —

    PYTHONPATH=src python -m repro.launch.serve_fleet \
        --buckets chain_64:12:4,rmat_9:2:2:32 --stream churn \
        --batch 8 --steps 8 --validate

``benchmarks/table9_buckets.py`` turns the bucketed-vs-single-schema
comparison into the §15 headline numbers.
"""
from __future__ import annotations

import argparse
import contextlib
import tempfile
import time

import numpy as np

from repro import obs


def main(argv=None) -> None:
    from repro.launch.config import FleetConfig, ServeConfig

    ap = argparse.ArgumentParser(
        description="multi-tenant batch-dynamic serving loop "
                    "(DESIGN.md §13)")
    ServeConfig.add_args(ap)
    FleetConfig.add_args(ap)
    args = ap.parse_args(argv)
    try:
        cfg = ServeConfig.from_args(args).check()
        fcfg = FleetConfig.from_args(args).check()
    except ValueError as e:
        ap.error(str(e))

    if fcfg.buckets:
        _main_bucketed(cfg, fcfg)
        return

    import jax

    from repro.data.graphs import SUITE
    from repro.data.streams import STREAMS
    from repro.dynamic.fleet import (FleetDispatcher, FleetManager,
                                     FleetQuerySession, apply_batches,
                                     build_fleet_tables, fleet_empty,
                                     fleet_sync_cost, refresh_bccs,
                                     refresh_tours)
    from repro.dynamic.replay import stream_capacity

    factory, kwargs, regime = SUITE[cfg.stream.graph]
    g = factory(**kwargs)
    n = g.n_nodes

    # Per-tenant streams: same workload shape, decorrelated seeds. The
    # initially-live edges ride the dispatcher as batch 0 (insert-only),
    # so every tenant's history replays through the same (T, B) path.
    streams = []
    for t in range(fcfg.tenants):
        kw = dict(cfg.stream_kwargs())
        kw["seed"] = cfg.stream.seed + t
        streams.append(STREAMS[cfg.stream.stream](g, **kw))
    capacity = max(stream_capacity(s) for s in streams)
    n_slots = min(fcfg.slots, fcfg.tenants)
    steps = min(cfg.stream.steps, min(len(s.batches) for s in streams))

    evict_dir = fcfg.evict_dir or tempfile.mkdtemp(prefix="fleet_evict_")
    fleet = fleet_empty(n_slots, n, capacity)
    manager = FleetManager(fleet, evict_dir)
    dispatcher = FleetDispatcher(n, cfg.stream.batch)

    from repro.data.streams import StreamBatch
    for t, stream in enumerate(streams):
        if stream.init_u.shape[0]:
            b = cfg.stream.batch
            for off in range(0, stream.init_u.shape[0], b):
                iu = np.full(b, n, np.int32)
                iv = np.full(b, n, np.int32)
                chunk = stream.init_u[off:off + b]
                iu[:chunk.shape[0]] = chunk
                iv[:chunk.shape[0]] = stream.init_v[off:off + b]
                dispatcher.offer(t, StreamBatch(
                    ins_u=iu, ins_v=iv,
                    del_u=np.full(b, n, np.int32),
                    del_v=np.full(b, n, np.int32)))
        for batch in stream.batches[:steps]:
            dispatcher.offer(t, batch)

    print(f"graph {cfg.stream.graph} ({regime}): V={n} E={g.n_edges}; "
          f"stream {cfg.stream.stream}, batch={cfg.stream.batch}, "
          f"{steps} batches x {fcfg.tenants} tenants in {n_slots} slots "
          f"(capacity {capacity}), tour={cfg.refresh.tour}, "
          f"bcc={cfg.refresh.bcc}")

    tn = None
    bcc = None
    sess = None
    cadence = cfg.cadence()
    applied = {t: 0 for t in range(fcfg.tenants)}
    batch_lat: dict[int, list] = {t: [] for t in range(fcfg.tenants)}
    query_lat: dict[int, list] = {t: [] for t in range(fcfg.tenants)}
    sync_fleet = 0
    sync_seq_equiv = 0
    refresh_lat: list = []
    rng = np.random.default_rng(cfg.stream.seed + 104729)
    payload_reads = cfg.read.read_ratio > 0
    read_per_tick = 0.0
    if payload_reads:
        r = cfg.read.read_ratio
        read_per_tick = r / (1.0 - r) * cfg.stream.batch / cfg.read.read_batch
    read_debt = {t: 0.0 for t in range(fcfg.tenants)}

    def snapshot_metrics() -> obs.MetricsRegistry:
        """Fresh registry from the cumulative loop telemetry (rebuilt per
        flush so monotonic counters never double-count)."""
        m = obs.MetricsRegistry()
        m.gauge("tenants").set(fcfg.tenants)
        m.gauge("slots").set(n_slots)
        m.counter("fleet_syncs").inc(sync_fleet)
        m.counter("sequential_equiv_syncs").inc(sync_seq_equiv)
        m.counter("admissions").inc(manager.admissions)
        m.counter("evictions").inc(manager.evictions)
        m.counter("restores").inc(manager.restores)
        for s in refresh_lat:
            m.histogram("refresh_ms").observe(s * 1e3)
        for t in range(fcfg.tenants):
            m.counter("applied_events", tenant=t).inc(applied[t])
            for s in batch_lat[t]:
                m.histogram("batch_latency_ms", tenant=t).observe(s * 1e3)
            for s in query_lat[t]:
                m.histogram("query_latency_ms", tenant=t).observe(s * 1e3)
        return m

    tracer = obs.Tracer() if cfg.obs.trace_out else None

    t_loop = time.perf_counter()
    tick = 0
    with tracer if tracer is not None else contextlib.nullcontext():
        while dispatcher.pending():
            with obs.span("tick", step=tick):
                # Residency: every tenant with queued traffic gets a slot
                # this tick if one is free; otherwise LRU eviction rotates
                # them in — preferring IDLE victims, so a resident that
                # still has queued units is never checkpoint-round-tripped
                # just to be restored next tick.
                busy = lambda x: dispatcher.pending(x) > 0  # noqa: E731
                waiting = [t for t in range(fcfg.tenants)
                           if dispatcher.pending(t)]
                for t in waiting[:n_slots]:
                    manager.ensure(t, busy=busy)
                fleet = manager.fleet

                (iu, iv, du, dv), served = dispatcher.tick(
                    manager.tenant_at)
                t0 = time.perf_counter()
                with obs.span("apply_batch", step=tick,
                              tenants=len(served)):
                    fleet, stats = apply_batches(fleet, iu, iv, du, dv)
                    jax.block_until_ready(fleet.parent)
                dt = time.perf_counter() - t0
                manager.fleet = fleet
                manager.note_applied(served)

                rounds = np.asarray(stats["rounds"])
                sync_fleet += fleet_sync_cost(stats)
                overflow = np.asarray(stats["overflow"])
                found = np.asarray(stats["deletes_found"])
                for tenant, events in served.items():
                    slot = manager.slot_of[tenant]
                    sync_seq_equiv += int(rounds[slot]) + 1
                    ins = int((np.asarray(iu[slot]) < n).sum())
                    applied[tenant] += (ins - int(overflow[slot])
                                        + int(found[slot]))
                    batch_lat[tenant].append(dt)

                if cadence.tour != "off" and cadence.due(tick):
                    t0 = time.perf_counter()
                    with obs.span("refresh_tour", step=tick):
                        tn, fleet = refresh_tours(
                            fleet, tn,
                            incremental=(cadence.tour == "incremental"))
                    if cadence.bcc != "off":
                        with obs.span("refresh_bcc", step=tick):
                            bcc = refresh_bccs(
                                fleet, bcc, tour=tn,
                                incremental=(cadence.bcc == "incremental"))
                    jax.block_until_ready(tn.pre)
                    refresh_lat.append(time.perf_counter() - t0)
                    manager.fleet = fleet
                    if payload_reads:
                        # Telemetry is keyed on stable tenant ids, not
                        # slot indices — a rotated tenant's counters
                        # continue where they left off.
                        if sess is None:
                            sess = FleetQuerySession.from_fleet(
                                fleet, tn, bcc,
                                policy=cfg.read.query_staleness,
                                labels=[t if t is not None else s
                                        for s, t in enumerate(
                                            manager.tenant_at)])
                        else:
                            for s, tenant in enumerate(manager.tenant_at):
                                if tenant is not None:
                                    sess.set_label(s, tenant)
                            sess.restamp(fleet, tn, bcc)

                if payload_reads and sess is not None:
                    from repro.dynamic.queries import StaleQueryError
                    for tenant in served:
                        slot = manager.slot_of[tenant]
                        read_debt[tenant] += read_per_tick
                        while read_debt[tenant] >= 1.0:
                            read_debt[tenant] -= 1.0
                            u = rng.integers(0, n, cfg.read.read_batch)
                            v = rng.integers(0, n, cfg.read.read_batch)
                            t0 = time.perf_counter()
                            try:
                                with obs.span("query_batch", step=tick,
                                              tenant=tenant):
                                    out = sess.lca(fleet, slot, u, v) \
                                        if tick % 2 else sess.connected(
                                            fleet, slot, u, v)
                                    jax.block_until_ready(out)
                            except StaleQueryError:
                                continue
                            query_lat[tenant].append(
                                time.perf_counter() - t0)
            if (cfg.obs.metrics_out and cfg.obs.metrics_every
                    and (tick + 1) % cfg.obs.metrics_every == 0):
                snapshot_metrics().write(cfg.obs.metrics_out)
            tick += 1
    elapsed = time.perf_counter() - t_loop

    total_applied = sum(applied.values())
    print(f"\nfleet: {total_applied} applied events across "
          f"{fcfg.tenants} tenants in {tick} ticks / {elapsed:.2f} s "
          f"({total_applied / max(elapsed, 1e-9):,.0f} events/sec "
          f"aggregate)")
    print(f"admission: {manager.admissions} admissions, "
          f"{manager.evictions} evictions, {manager.restores} restores "
          f"(evict checkpoints under {evict_dir})")
    print(f"sync accounting: fleet={sync_fleet} convergence checks vs "
          f"sequential-equivalent={sync_seq_equiv} "
          f"({sync_fleet / max(sync_seq_equiv, 1):.2f}x); "
          f"per applied event {sync_fleet / max(total_applied, 1):.4f} "
          f"vs {sync_seq_equiv / max(total_applied, 1):.4f}")
    if refresh_lat:
        print(f"vmapped refresh ({cfg.refresh.tour}"
              + (f"+bcc {cfg.refresh.bcc}" if cadence.bcc != "off" else "")
              + f"): median {np.median(refresh_lat)*1e3:.1f} ms over "
              f"{len(refresh_lat)} calls")
    print("\nper-tenant:")
    for t in range(fcfg.tenants):
        line = (f"  tenant {t}: {applied[t]:6d} applied  "
                f"batch {obs.percentile_line(batch_lat[t])}")
        if payload_reads:
            line += f"  query {obs.percentile_line(query_lat[t])}"
        print(line)
    if payload_reads and sess is not None:
        s = sess.sync_stats()
        print(f"\nquery sync accounting (fleet totals): {s['builds']} "
              f"table builds, {s['build_syncs_total']} build syncs, "
              f"stale_served={s['stale_served']}, "
              f"auto_refreshes={s['auto_refreshes']}")

    if tracer is not None:
        tracer.write_jsonl(cfg.obs.trace_out)
        tracer.write_chrome(cfg.obs.trace_out + ".chrome.json")
        print(f"\ntrace: {len(tracer.records)} records -> "
              f"{cfg.obs.trace_out} (+ .chrome.json); "
              f"ledger sync_total={tracer.ledger.total()}")
    if cfg.obs.metrics_out:
        snapshot_metrics().write(cfg.obs.metrics_out)
        print(f"metrics -> {cfg.obs.metrics_out}")

    if cfg.validate:
        from repro.core.compress import roots_of
        from repro.core.rst import rooted_spanning_tree
        from repro.dynamic import live_graph
        from repro.launch.serve_stream import canonical_partition

        ok = True
        for t in range(fcfg.tenants):
            slot = manager.ensure(t)
            f = manager.fleet.tenant(slot)
            lg = live_graph(f)
            root = int(np.asarray(f.rep)[0])
            scratch = rooted_spanning_tree(lg, root, method="gconn_euler")
            same = bool(np.array_equal(
                canonical_partition(np.asarray(f.rep)),
                canonical_partition(np.asarray(roots_of(scratch.parent)))))
            ok = ok and same
            print(f"validate tenant {t}: partition==from-scratch: {same}")
        if not ok:
            raise SystemExit("validate: FAILED")


def _main_bucketed(cfg, fcfg) -> None:
    """Shape-bucketed serving loop (DESIGN.md §15).

    Each ``--buckets`` spec becomes a ``FleetBucket``; tenants are routed
    by exact ``FleetSchema`` and every bucket ticks with its own block
    shape and sync bill. Per-tenant/per-bucket telemetry rides stable
    ids; ``--validate`` checks every tenant's final partition against a
    from-scratch RST on its live graph.
    """
    import jax

    from repro.data.graphs import resolve_graph
    from repro.data.streams import STREAMS
    from repro.dynamic.fleet import BucketedFleet, FleetSchema
    from repro.dynamic.queries import StaleQueryError
    from repro.dynamic.replay import init_state, stream_capacity

    specs = fcfg.bucket_specs()
    cadence = cfg.cadence()
    evict_dir = fcfg.evict_dir or tempfile.mkdtemp(prefix="fleet_evict_")
    bf = BucketedFleet(evict_dir, max_drain=fcfg.drain)

    tenants: list[tuple[str, str]] = []   # (tenant id, bucket name)
    global_idx = 0
    for i, spec in enumerate(specs):
        g = resolve_graph(spec.graph, seed=cfg.stream.seed + i)
        batch = spec.batch or cfg.stream.batch
        bucket_streams = []
        for _ in range(spec.tenants):
            kw = dict(cfg.stream_kwargs())
            kw["batch"] = batch
            kw["seed"] = cfg.stream.seed + global_idx
            bucket_streams.append(STREAMS[cfg.stream.stream](g, **kw))
            global_idx += 1
        capacity = max(stream_capacity(s) for s in bucket_streams)
        schema = FleetSchema(g.n_nodes, capacity, batch)
        name = (spec.graph if spec.graph not in bf.buckets
                else f"{spec.graph}#{i}")
        bucket = bf.add_bucket(schema, min(spec.slots, spec.tenants),
                               cadence=cadence, name=name)
        steps_b = min(cfg.stream.steps,
                      min(len(s.batches) for s in bucket_streams))
        for j, s in enumerate(bucket_streams):
            tid = f"{name}.{j}"
            # The initially-live edges ride as a seed forest installed on
            # first admission, so queues hold only the update stream.
            bf.route(tid, schema, seed=init_state(s, capacity))
            for unit in s.batches[:steps_b]:
                bf.offer(tid, unit)
            tenants.append((tid, name))
        print(f"bucket {name}: schema {schema.key} "
              f"(slot_cost {schema.slot_cost} rows/slot), "
              f"{spec.tenants} tenants in "
              f"{bucket.manager.fleet.n_slots} slots, "
              f"{steps_b} units/tenant, stream {cfg.stream.stream}")

    payload_reads = cfg.read.read_ratio > 0
    rng = np.random.default_rng(cfg.stream.seed + 104729)
    read_debt = {tid: 0.0 for tid, _ in tenants}
    query_lat: dict[str, list] = {tid: [] for tid, _ in tenants}
    r = cfg.read.read_ratio

    def snapshot_metrics() -> obs.MetricsRegistry:
        m = obs.MetricsRegistry()
        m.gauge("buckets").set(len(bf.buckets))
        for bname, b in bf.buckets.items():
            mgr = b.manager
            m.gauge("slots", bucket=bname).set(mgr.fleet.n_slots)
            m.gauge("tenants", bucket=bname).set(len(b.tenants))
            m.counter("fleet_syncs", bucket=bname).inc(
                b.sync_apply + b.sync_refresh)
            m.counter("blocks", bucket=bname).inc(b.blocks)
            m.counter("padded_slot_events", bucket=bname).inc(
                b.padded_events)
            m.counter("padded_rows", bucket=bname).inc(b.padded_rows)
            m.counter("admissions", bucket=bname).inc(mgr.admissions)
            m.counter("evictions", bucket=bname).inc(mgr.evictions)
            m.counter("restores", bucket=bname).inc(mgr.restores)
            m.counter("prefetches", bucket=bname).inc(mgr.prefetches)
        for tid, bname in tenants:
            b = bf.buckets[bname]
            m.counter("applied_events", tenant=tid,
                      bucket=bname).inc(b.applied[tid])
            for s in query_lat[tid]:
                m.histogram("query_latency_ms", tenant=tid,
                            bucket=bname).observe(s * 1e3)
        return m

    tracer = obs.Tracer() if cfg.obs.trace_out else None
    t_loop = time.perf_counter()
    tick = 0
    with tracer if tracer is not None else contextlib.nullcontext():
        while bf.pending():
            served = bf.step(tick)
            if payload_reads:
                for tid in served:
                    b = bf.bucket_of(tid)
                    slot = b.manager.slot_of.get(tid)
                    if b.session is None or slot is None:
                        continue
                    n_b = b.schema.n_nodes
                    read_debt[tid] += (r / (1.0 - r) * b.schema.batch
                                       / cfg.read.read_batch)
                    while read_debt[tid] >= 1.0:
                        read_debt[tid] -= 1.0
                        u = rng.integers(0, n_b, cfg.read.read_batch)
                        v = rng.integers(0, n_b, cfg.read.read_batch)
                        t0 = time.perf_counter()
                        try:
                            with obs.span("query_batch", step=tick,
                                          tenant=tid, bucket=b.name):
                                out = (b.session.lca(
                                    b.manager.fleet, slot, u, v)
                                    if tick % 2 else b.session.connected(
                                        b.manager.fleet, slot, u, v))
                                jax.block_until_ready(out)
                        except StaleQueryError:
                            continue
                        query_lat[tid].append(time.perf_counter() - t0)
            if (cfg.obs.metrics_out and cfg.obs.metrics_every
                    and (tick + 1) % cfg.obs.metrics_every == 0):
                snapshot_metrics().write(cfg.obs.metrics_out)
            tick += 1
    bf.finalize()
    elapsed = time.perf_counter() - t_loop

    total_applied = bf.applied_events()
    print(f"\nfleet: {total_applied} applied events across "
          f"{len(tenants)} tenants / {len(bf.buckets)} buckets in "
          f"{tick} steps / {elapsed:.2f} s "
          f"({total_applied / max(elapsed, 1e-9):,.0f} events/sec "
          f"aggregate)")
    for bname, b in bf.buckets.items():
        mgr = b.manager
        print(f"bucket {bname}: {sum(b.applied.values()):6d} applied in "
              f"{b.ticks} ticks / {b.blocks} blocks; "
              f"sync apply={b.sync_apply} refresh={b.sync_refresh}; "
              f"padded slot-events={b.padded_events} "
              f"rows={b.padded_rows}; "
              f"admissions={mgr.admissions} evictions={mgr.evictions} "
              f"restores={mgr.restores} prefetches={mgr.prefetches}; "
              f"max backlog={b.max_backlog}")
    print(f"sync accounting: total={bf.sync_total()} convergence checks "
          f"(Σ buckets, each max-over-own-lanes+1); "
          f"per applied event "
          f"{bf.sync_total() / max(total_applied, 1):.4f}; "
          f"padded slot-work {bf.padded_rows()} int32-rows")
    print("\nper-tenant:")
    for tid, bname in tenants:
        b = bf.buckets[bname]
        line = f"  {tid}: {b.applied[tid]:6d} applied"
        if payload_reads:
            line += f"  query {obs.percentile_line(query_lat[tid])}"
        print(line)
    if payload_reads:
        for bname, b in bf.buckets.items():
            if b.session is None:
                continue
            s = b.session.sync_stats()
            print(f"query sync accounting [{bname}]: {s['builds']} "
                  f"table builds, {s['build_syncs_total']} build syncs, "
                  f"stale_served={s['stale_served']}, "
                  f"auto_refreshes={s['auto_refreshes']}")

    if tracer is not None:
        tracer.write_jsonl(cfg.obs.trace_out)
        tracer.write_chrome(cfg.obs.trace_out + ".chrome.json")
        print(f"\ntrace: {len(tracer.records)} records -> "
              f"{cfg.obs.trace_out} (+ .chrome.json); "
              f"ledger sync_total={tracer.ledger.total()}")
    if cfg.obs.metrics_out:
        snapshot_metrics().write(cfg.obs.metrics_out)
        print(f"metrics -> {cfg.obs.metrics_out}")

    if cfg.validate:
        from repro.core.compress import roots_of
        from repro.core.rst import rooted_spanning_tree
        from repro.dynamic import live_graph
        from repro.launch.serve_stream import canonical_partition

        ok = True
        for tid, _ in tenants:
            f = bf.tenant_forest(tid)
            lg = live_graph(f)
            root = int(np.asarray(f.rep)[0])
            scratch = rooted_spanning_tree(lg, root, method="gconn_euler")
            same = bool(np.array_equal(
                canonical_partition(np.asarray(f.rep)),
                canonical_partition(np.asarray(roots_of(scratch.parent)))))
            ok = ok and same
            print(f"validate {tid}: partition==from-scratch: {same}")
        if not ok:
            bf.close()
            raise SystemExit("validate: FAILED")
    bf.close()


if __name__ == "__main__":
    main()
