"""Multi-tenant fleet serving loop: T session graphs, one process.

    PYTHONPATH=src python -m repro.launch.serve_fleet \
        --graph grid_64 --stream churn --batch 64 --steps 32 \
        --tenants 6 --slots 4

The fleet-wide counterpart of ``serve_stream`` (DESIGN.md §13): each
*tenant* is an independent session graph driven by its own edge stream
(same generator, per-tenant seed ``--seed + t``). Per tick the
``FleetDispatcher`` coalesces one queued batch unit per resident tenant
into a fixed-shape ``(T, B)`` event block and ``apply_batches`` applies
it with ONE vmapped §9 program — the fleet pays ``max_t(rounds_t) + 1``
convergence syncs where T sequential loops would pay
``Σ_t(rounds_t + 1)``. Cache refreshes (tour, optional BCC, the stacked
``QueryTables``) are vmapped the same way at ``--tour-every`` cadence,
and reads are served per tenant by a ``FleetQuerySession`` under the
``--query-staleness`` policy.

When ``--tenants`` exceeds ``--slots``, residency rotates round-robin:
admission evicts the least-recently-used resident through the §8
checkpoint path (forest + stream cursor, atomic publish) and
re-admission restores bit-identically, so eviction is invisible to a
tenant's stream history (tests/test_fleet.py proves equality against T
independent single-tenant loops).

Flags are the shared ``ServeConfig`` schema plus the ``FleetConfig``
group (``--tenants``, ``--slots``, ``--evict-dir``); the report prints
per-tenant applied-events/sec, batch/query latency percentiles, and the
fleet-vs-sequential sync accounting that ``benchmarks/table8_fleet.py``
turns into the §13 headline numbers.
"""
from __future__ import annotations

import argparse
import contextlib
import tempfile
import time

import numpy as np

from repro import obs


def main(argv=None) -> None:
    from repro.launch.config import FleetConfig, ServeConfig

    ap = argparse.ArgumentParser(
        description="multi-tenant batch-dynamic serving loop "
                    "(DESIGN.md §13)")
    ServeConfig.add_args(ap)
    FleetConfig.add_args(ap)
    args = ap.parse_args(argv)
    try:
        cfg = ServeConfig.from_args(args).check()
        fcfg = FleetConfig.from_args(args).check()
    except ValueError as e:
        ap.error(str(e))

    import jax

    from repro.data.graphs import SUITE
    from repro.data.streams import STREAMS
    from repro.dynamic.fleet import (FleetDispatcher, FleetManager,
                                     FleetQuerySession, apply_batches,
                                     build_fleet_tables, fleet_empty,
                                     fleet_sync_cost, refresh_bccs,
                                     refresh_tours)
    from repro.dynamic.replay import stream_capacity

    factory, kwargs, regime = SUITE[cfg.stream.graph]
    g = factory(**kwargs)
    n = g.n_nodes

    # Per-tenant streams: same workload shape, decorrelated seeds. The
    # initially-live edges ride the dispatcher as batch 0 (insert-only),
    # so every tenant's history replays through the same (T, B) path.
    streams = []
    for t in range(fcfg.tenants):
        kw = dict(cfg.stream_kwargs())
        kw["seed"] = cfg.stream.seed + t
        streams.append(STREAMS[cfg.stream.stream](g, **kw))
    capacity = max(stream_capacity(s) for s in streams)
    n_slots = min(fcfg.slots, fcfg.tenants)
    steps = min(cfg.stream.steps, min(len(s.batches) for s in streams))

    evict_dir = fcfg.evict_dir or tempfile.mkdtemp(prefix="fleet_evict_")
    fleet = fleet_empty(n_slots, n, capacity)
    manager = FleetManager(fleet, evict_dir)
    dispatcher = FleetDispatcher(n, cfg.stream.batch)

    from repro.data.streams import StreamBatch
    for t, stream in enumerate(streams):
        if stream.init_u.shape[0]:
            b = cfg.stream.batch
            for off in range(0, stream.init_u.shape[0], b):
                iu = np.full(b, n, np.int32)
                iv = np.full(b, n, np.int32)
                chunk = stream.init_u[off:off + b]
                iu[:chunk.shape[0]] = chunk
                iv[:chunk.shape[0]] = stream.init_v[off:off + b]
                dispatcher.offer(t, StreamBatch(
                    ins_u=iu, ins_v=iv,
                    del_u=np.full(b, n, np.int32),
                    del_v=np.full(b, n, np.int32)))
        for batch in stream.batches[:steps]:
            dispatcher.offer(t, batch)

    print(f"graph {cfg.stream.graph} ({regime}): V={n} E={g.n_edges}; "
          f"stream {cfg.stream.stream}, batch={cfg.stream.batch}, "
          f"{steps} batches x {fcfg.tenants} tenants in {n_slots} slots "
          f"(capacity {capacity}), tour={cfg.refresh.tour}, "
          f"bcc={cfg.refresh.bcc}")

    tn = None
    bcc = None
    sess = None
    cadence = cfg.cadence()
    applied = {t: 0 for t in range(fcfg.tenants)}
    batch_lat: dict[int, list] = {t: [] for t in range(fcfg.tenants)}
    query_lat: dict[int, list] = {t: [] for t in range(fcfg.tenants)}
    sync_fleet = 0
    sync_seq_equiv = 0
    refresh_lat: list = []
    rng = np.random.default_rng(cfg.stream.seed + 104729)
    payload_reads = cfg.read.read_ratio > 0
    read_per_tick = 0.0
    if payload_reads:
        r = cfg.read.read_ratio
        read_per_tick = r / (1.0 - r) * cfg.stream.batch / cfg.read.read_batch
    read_debt = {t: 0.0 for t in range(fcfg.tenants)}

    def snapshot_metrics() -> obs.MetricsRegistry:
        """Fresh registry from the cumulative loop telemetry (rebuilt per
        flush so monotonic counters never double-count)."""
        m = obs.MetricsRegistry()
        m.gauge("tenants").set(fcfg.tenants)
        m.gauge("slots").set(n_slots)
        m.counter("fleet_syncs").inc(sync_fleet)
        m.counter("sequential_equiv_syncs").inc(sync_seq_equiv)
        m.counter("admissions").inc(manager.admissions)
        m.counter("evictions").inc(manager.evictions)
        m.counter("restores").inc(manager.restores)
        for s in refresh_lat:
            m.histogram("refresh_ms").observe(s * 1e3)
        for t in range(fcfg.tenants):
            m.counter("applied_events", tenant=t).inc(applied[t])
            for s in batch_lat[t]:
                m.histogram("batch_latency_ms", tenant=t).observe(s * 1e3)
            for s in query_lat[t]:
                m.histogram("query_latency_ms", tenant=t).observe(s * 1e3)
        return m

    tracer = obs.Tracer() if cfg.obs.trace_out else None

    t_loop = time.perf_counter()
    tick = 0
    with tracer if tracer is not None else contextlib.nullcontext():
        while dispatcher.pending():
            with obs.span("tick", step=tick):
                # Residency: every tenant with queued traffic gets a slot
                # this tick if one is free; otherwise LRU eviction rotates
                # them in.
                waiting = [t for t in range(fcfg.tenants)
                           if dispatcher.pending(t)]
                for t in waiting[:n_slots]:
                    manager.ensure(t)
                fleet = manager.fleet

                (iu, iv, du, dv), served = dispatcher.tick(
                    manager.tenant_at)
                t0 = time.perf_counter()
                with obs.span("apply_batch", step=tick,
                              tenants=len(served)):
                    fleet, stats = apply_batches(fleet, iu, iv, du, dv)
                    jax.block_until_ready(fleet.parent)
                dt = time.perf_counter() - t0
                manager.fleet = fleet
                manager.note_applied(served)

                rounds = np.asarray(stats["rounds"])
                sync_fleet += fleet_sync_cost(stats)
                overflow = np.asarray(stats["overflow"])
                found = np.asarray(stats["deletes_found"])
                for tenant, events in served.items():
                    slot = manager.slot_of[tenant]
                    sync_seq_equiv += int(rounds[slot]) + 1
                    ins = int((np.asarray(iu[slot]) < n).sum())
                    applied[tenant] += (ins - int(overflow[slot])
                                        + int(found[slot]))
                    batch_lat[tenant].append(dt)

                if cadence.tour != "off" and cadence.due(tick):
                    t0 = time.perf_counter()
                    with obs.span("refresh_tour", step=tick):
                        tn, fleet = refresh_tours(
                            fleet, tn,
                            incremental=(cadence.tour == "incremental"))
                    if cadence.bcc != "off":
                        with obs.span("refresh_bcc", step=tick):
                            bcc = refresh_bccs(
                                fleet, bcc, tour=tn,
                                incremental=(cadence.bcc == "incremental"))
                    jax.block_until_ready(tn.pre)
                    refresh_lat.append(time.perf_counter() - t0)
                    manager.fleet = fleet
                    if payload_reads:
                        if sess is None:
                            sess = FleetQuerySession.from_fleet(
                                fleet, tn, bcc,
                                policy=cfg.read.query_staleness)
                        else:
                            sess.restamp(fleet, tn, bcc)

                if payload_reads and sess is not None:
                    from repro.dynamic.queries import StaleQueryError
                    for tenant in served:
                        slot = manager.slot_of[tenant]
                        read_debt[tenant] += read_per_tick
                        while read_debt[tenant] >= 1.0:
                            read_debt[tenant] -= 1.0
                            u = rng.integers(0, n, cfg.read.read_batch)
                            v = rng.integers(0, n, cfg.read.read_batch)
                            t0 = time.perf_counter()
                            try:
                                with obs.span("query_batch", step=tick,
                                              tenant=tenant):
                                    out = sess.lca(fleet, slot, u, v) \
                                        if tick % 2 else sess.connected(
                                            fleet, slot, u, v)
                                    jax.block_until_ready(out)
                            except StaleQueryError:
                                continue
                            query_lat[tenant].append(
                                time.perf_counter() - t0)
            if (cfg.obs.metrics_out and cfg.obs.metrics_every
                    and (tick + 1) % cfg.obs.metrics_every == 0):
                snapshot_metrics().write(cfg.obs.metrics_out)
            tick += 1
    elapsed = time.perf_counter() - t_loop

    total_applied = sum(applied.values())
    print(f"\nfleet: {total_applied} applied events across "
          f"{fcfg.tenants} tenants in {tick} ticks / {elapsed:.2f} s "
          f"({total_applied / max(elapsed, 1e-9):,.0f} events/sec "
          f"aggregate)")
    print(f"admission: {manager.admissions} admissions, "
          f"{manager.evictions} evictions, {manager.restores} restores "
          f"(evict checkpoints under {evict_dir})")
    print(f"sync accounting: fleet={sync_fleet} convergence checks vs "
          f"sequential-equivalent={sync_seq_equiv} "
          f"({sync_fleet / max(sync_seq_equiv, 1):.2f}x); "
          f"per applied event {sync_fleet / max(total_applied, 1):.4f} "
          f"vs {sync_seq_equiv / max(total_applied, 1):.4f}")
    if refresh_lat:
        print(f"vmapped refresh ({cfg.refresh.tour}"
              + (f"+bcc {cfg.refresh.bcc}" if cadence.bcc != "off" else "")
              + f"): median {np.median(refresh_lat)*1e3:.1f} ms over "
              f"{len(refresh_lat)} calls")
    print("\nper-tenant:")
    for t in range(fcfg.tenants):
        line = (f"  tenant {t}: {applied[t]:6d} applied  "
                f"batch {obs.percentile_line(batch_lat[t])}")
        if payload_reads:
            line += f"  query {obs.percentile_line(query_lat[t])}"
        print(line)
    if payload_reads and sess is not None:
        s = sess.sync_stats()
        print(f"\nquery sync accounting (fleet totals): {s['builds']} "
              f"table builds, {s['build_syncs_total']} build syncs, "
              f"stale_served={s['stale_served']}, "
              f"auto_refreshes={s['auto_refreshes']}")

    if tracer is not None:
        tracer.write_jsonl(cfg.obs.trace_out)
        tracer.write_chrome(cfg.obs.trace_out + ".chrome.json")
        print(f"\ntrace: {len(tracer.records)} records -> "
              f"{cfg.obs.trace_out} (+ .chrome.json); "
              f"ledger sync_total={tracer.ledger.total()}")
    if cfg.obs.metrics_out:
        snapshot_metrics().write(cfg.obs.metrics_out)
        print(f"metrics -> {cfg.obs.metrics_out}")

    if cfg.validate:
        from repro.core.compress import roots_of
        from repro.core.rst import rooted_spanning_tree
        from repro.dynamic import live_graph
        from repro.launch.serve_stream import canonical_partition

        ok = True
        for t in range(fcfg.tenants):
            slot = manager.ensure(t)
            f = manager.fleet.tenant(slot)
            lg = live_graph(f)
            root = int(np.asarray(f.rep)[0])
            scratch = rooted_spanning_tree(lg, root, method="gconn_euler")
            same = bool(np.array_equal(
                canonical_partition(np.asarray(f.rep)),
                canonical_partition(np.asarray(roots_of(scratch.parent)))))
            ok = ok and same
            print(f"validate tenant {t}: partition==from-scratch: {same}")
        if not ok:
            raise SystemExit("validate: FAILED")


if __name__ == "__main__":
    main()
