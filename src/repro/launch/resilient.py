"""Self-healing streaming loop: watchdog, audit cadence, checkpoints.

``ResilientStreamLoop`` wraps the batch-dynamic serving path
(``dynamic.replay.replay_batch`` + cadenced tour/BCC refreshes) in the
fault-tolerance posture ``train.fault.FaultTolerantLoop`` gives the
training loop (DESIGN.md §8, §11), adapted to the dynamic-forest state:

* **Watchdog + retry** — each batch applies under a wall-clock watchdog;
  on ``StepTimeout`` / JAX runtime errors the batch retries from the
  last good state (``replay_batch`` is a pure function of
  (state, batch), so retry is sound). Final failure publishes a last
  checkpoint and re-raises for the scheduler.
* **Straggler EWMA** — per-batch wall times feed an EWMA; outliers are
  recorded with their step index.
* **Invariant auditing** (``--audit-every``) — every k batches the
  O(log n)-sync ``dynamic.audit.audit_forest`` checks the forest and its
  caches; on a violation the ``dynamic.recovery`` ladder runs (scoped
  repair, escalating to full rebuild) and the event is recorded. When
  auditing is on, one final recover runs after the last batch so the
  loop never hands back a corrupted state.
* **Chaos injection** (``--chaos``) — deterministic seeded fault
  injection (``dynamic.chaos.INJECTORS``) at its own cadence, *before*
  the batch applies: the fault rides the stream until the next audit,
  exactly like a real soft error would. Seeds derive from
  (chaos_seed, step), so a resumed run replays the same faults.
* **Sanitization** (``--sanitize``) — ``chaos.sanitize_batch`` runs in
  front of every apply; per-category quarantine counters accumulate in
  ``loop.quarantine``.
* **Checkpoint / resume** — every ``ckpt_every`` batches the full
  serving state (forest + tour numbering + BCC cache) is published
  atomically via ``train.checkpoint`` with the stream cursor in the
  manifest; ``resume()`` restores the newest checkpoint and ``run``
  continues from the recorded cursor. Everything downstream of the
  cursor is deterministic (apply, refresh, audit, repair, injection),
  so a killed-and-resumed run reaches a final state *bit-identical* to
  an uninterrupted one (tests/test_chaos_recovery.py enforces this).
"""
from __future__ import annotations

import dataclasses
import logging
import pathlib
import time
from typing import Any, Callable, Sequence

import jax
import numpy as np

from repro import obs
from repro.data.streams import EdgeStream
from repro.dynamic.audit import audit_forest
from repro.dynamic.chaos import INJECTORS, merge_quarantine, sanitize_batch
from repro.dynamic.recovery import recover
from repro.dynamic.replay import init_state, replay_batch
from repro.dynamic.view import CadencePolicy, ForestView
from repro.train import checkpoint as ckpt
from repro.train.fault import StepTimeout

log = logging.getLogger("repro.resilient")


@dataclasses.dataclass
class ResilientStreamLoop:
    """Fault-tolerant driver for one dynamic forest under an edge stream.

    Build with ``from_stream`` (which seeds the state and, when tour/BCC
    maintenance is on, forces the initial cache refreshes so the
    checkpoint pytree structure is fixed for the loop's lifetime), call
    ``resume()`` if restarts should pick up prior progress, then
    ``run(stream.batches)``.
    """

    state: Any                               # DynamicForest
    view: ForestView | None = None           # built in __post_init__

    tour_mode: str = "incremental"           # incremental | full | off
    bcc_mode: str = "off"                    # incremental | full | off
    tour_every: int = 4

    ckpt_dir: str | pathlib.Path | None = None
    ckpt_every: int = 0
    keep: int = 3
    async_ckpt: bool = True

    audit_every: int = 0
    chaos: Sequence[str] = ()
    chaos_every: int = 1
    chaos_seed: int = 0
    sanitize: bool = False

    max_retries: int = 2
    step_timeout_s: float | None = None
    straggler_factor: float = 3.0
    use_kernel: bool = False
    apply_fn: Callable = None                # (state, batch) -> (state, stats)

    # progress + telemetry
    cursor: int = 0
    applied: int = 0
    dropped_overflow: int = 0
    dropped_unmatched: int = 0
    retries: int = 0
    lat: list = dataclasses.field(default_factory=list)
    stragglers: list = dataclasses.field(default_factory=list)
    quarantine: dict = dataclasses.field(default_factory=dict)
    injected: list = dataclasses.field(default_factory=list)
    recoveries: list = dataclasses.field(default_factory=list)
    last_report: Any = None
    _ewma: float | None = None
    _writer: Any = None

    def __post_init__(self):
        if self.apply_fn is None:
            self.apply_fn = replay_batch
        if self.view is None:
            self.view = ForestView(
                CadencePolicy(tour=self.tour_mode, bcc=self.bcc_mode,
                              every=self.tour_every),
                use_kernel=self.use_kernel)

    # -- the derived caches + their telemetry live on the ForestView ---------

    @property
    def tn(self):
        return self.view.tn

    @tn.setter
    def tn(self, value):
        self.view.tn = value

    @property
    def bcc(self):
        return self.view.bcc

    @bcc.setter
    def bcc(self, value):
        self.view.bcc = value

    @property
    def tour_lat(self) -> list:
        return self.view.tour_lat

    @property
    def bcc_lat(self) -> list:
        return self.view.bcc_lat

    # ---- construction ------------------------------------------------------

    @classmethod
    def from_stream(cls, stream: EdgeStream, capacity: int | None = None,
                    **config) -> "ResilientStreamLoop":
        state = init_state(stream, capacity)
        loop = cls(state=state, **config)
        # Fix the checkpoint pytree structure up front: when maintenance
        # is on, the caches exist from step 0.
        loop.state = loop.view.prime(loop.state)
        return loop

    @classmethod
    def from_config(cls, stream: EdgeStream, cfg,
                    capacity: int | None = None,
                    **overrides) -> "ResilientStreamLoop":
        """Build from a ``launch.config.ServeConfig`` (the typed flag
        schema) instead of loose kwargs."""
        injectors = cfg.injector_names(INJECTORS)
        return cls.from_stream(
            stream, capacity,
            tour_mode=cfg.refresh.tour, bcc_mode=cfg.refresh.bcc,
            tour_every=cfg.refresh.tour_every,
            ckpt_dir=cfg.ckpt.ckpt_dir, ckpt_every=cfg.ckpt.ckpt_every,
            audit_every=cfg.chaos.audit_every, chaos=injectors,
            chaos_every=cfg.chaos.chaos_every,
            chaos_seed=cfg.chaos.chaos_seed, sanitize=cfg.chaos.sanitize,
            **overrides)

    # ---- checkpointing -----------------------------------------------------

    def _ckpt_tree(self):
        """The serving state as one pytree; {} stands in for a disabled
        cache so the tree structure never changes across the run."""
        return {"forest": self.state,
                "tour": self.tn if self.tn is not None else {},
                "bcc": self.bcc if self.bcc is not None else {}}

    def _save(self, blocking: bool | None = None):
        if self.ckpt_dir is None:
            return
        if self._writer is not None:
            self._writer.join()              # backpressure: one in flight
            self._writer = None
        self._writer = ckpt.save(
            self.ckpt_dir, self._ckpt_tree(), self.cursor,
            data_cursor=self.cursor, keep=self.keep,
            blocking=blocking if blocking is not None
            else not self.async_ckpt)

    def resume(self) -> int:
        """Restore the newest checkpoint, if any; returns the cursor."""
        if self.ckpt_dir is None or ckpt.latest_step(self.ckpt_dir) is None:
            return self.cursor
        tree, manifest = ckpt.restore(self.ckpt_dir, self._ckpt_tree())
        self.state = tree["forest"]
        if self.tn is not None:
            self.tn = tree["tour"]
        if self.bcc is not None:
            self.bcc = tree["bcc"]
        self.cursor = int(manifest["data_cursor"])
        log.info("resumed at batch %d", self.cursor)
        return self.cursor

    # ---- fault machinery ---------------------------------------------------

    def _inject(self, step: int):
        name = self.chaos[(step // max(self.chaos_every, 1))
                          % len(self.chaos)]
        rng = np.random.default_rng((self.chaos_seed, step))
        self.state, bcc2, desc = INJECTORS[name](self.state, self.bcc, rng)
        if self.bcc is not None:
            self.bcc = bcc2
        self.injected.append((step, desc))
        log.warning("chaos @%d: %s", step, desc)

    def _recover(self, step: int):
        self.state, tn2, bcc2, report, info = recover(
            self.state, self.tn, self.bcc, use_kernel=self.use_kernel)
        if self.tn is not None and tn2 is not None:
            self.tn = tn2
        if self.bcc is not None and bcc2 is not None:
            self.bcc = bcc2
        self.last_report = report
        if info["mode"] != "clean":
            self.recoveries.append((step, info))
            log.warning("recovery @%d: %s -> %s", step, report.summary(),
                        info["mode"])
        return info

    def _structural_guard(self) -> bool:
        """Bounded structural pre-check (the hot-path admission guard).

        ``apply_batch`` and the refreshes *require* the forest
        invariants: the engine's unbounded convergence loops never
        terminate on a cyclic parent table, and a corrupted ``rep``
        breaks the link loop's acyclic-overlay contract (two components
        can graft onto each other and cycle the overlay). So when chaos
        is on, every step re-verifies the structural invariants with the
        bounded audit (``audit_forest`` spends ≤ ``AUDIT_MAX_SYNCS``
        convergence checks and is total on arbitrary corruption) and
        triggers an out-of-cadence recovery on violation. Cache-only
        faults (stale BCC snapshots) pass the guard and wait for the
        cadenced audit — cheap structural invariant on the hot path,
        deep audit (incl. caches) on cadence.
        """
        return bool(audit_forest(self.state).forest_ok)

    def _watchdog_apply(self, batch):
        t0 = time.perf_counter()
        new_state, stats = self.apply_fn(self.state, batch)
        jax.block_until_ready(new_state.parent)
        dt = time.perf_counter() - t0
        if self.step_timeout_s and dt > self.step_timeout_s:
            raise StepTimeout(f"batch took {dt:.1f}s "
                              f"> {self.step_timeout_s}s")
        return new_state, stats, dt

    # ---- the loop ----------------------------------------------------------

    def step(self, step: int, batch):
        """Process one batch end to end (inject → sanitize → apply →
        refresh → audit → checkpoint); returns (stats, dt)."""
        with obs.span("tick", step=step):
            return self._step(step, batch)

    def _step(self, step: int, batch):
        n = self.state.n_nodes
        if self.chaos and (step + 1) % max(self.chaos_every, 1) == 0:
            with obs.span("inject", step=step):
                self._inject(step)
        if self.chaos and not self._structural_guard():
            with obs.span("audit_recover", step=step):
                self._recover(step)
        if self.sanitize:
            with obs.span("sanitize", step=step):
                batch, q = sanitize_batch(batch, n)
                merge_quarantine(self.quarantine, q)

        with obs.span("apply_batch", step=step):
            for attempt in range(self.max_retries + 1):
                try:
                    new_state, stats, dt = self._watchdog_apply(batch)
                    break
                except (StepTimeout, jax.errors.JaxRuntimeError) as e:
                    self.retries += 1
                    log.warning("batch %d attempt %d failed: %s",
                                step, attempt, e)
                    if attempt == self.max_retries:
                        # Publish a last checkpoint for the restart, then
                        # hand the failure to the scheduler.
                        self._save(blocking=True)
                        raise
        self.state = new_state
        self.lat.append(dt)

        # Applied-events accounting (matches the serving-rate contract:
        # work done, not traffic offered).
        ins_offered = int((np.asarray(batch.ins_u) < n).sum())
        del_offered = int((np.asarray(batch.del_u) < n).sum())
        overflow = int(stats["overflow"])
        del_found = int(stats.get("deletes_found", 0))
        self.applied += (ins_offered - overflow) + del_found
        self.dropped_overflow += overflow
        self.dropped_unmatched += del_offered - del_found

        if self._ewma is None:
            self._ewma = dt
        if dt > self.straggler_factor * self._ewma:
            self.stragglers.append((step, dt, self._ewma))
        self._ewma = 0.9 * self._ewma + 0.1 * dt

        # Cadenced cache maintenance: one ForestView entry refreshes
        # whatever the policy keeps on (tour, BCC) when the step is due.
        # (ForestView.refresh opens the refresh_tour / refresh_bcc /
        # adopt_session child spans itself.)
        self.state = self.view.refresh(self.state, step=step)

        if self.audit_every and (step + 1) % self.audit_every == 0:
            with obs.span("audit_recover", step=step):
                self._recover(step)

        self.cursor = step + 1
        if self.ckpt_every and (step + 1) % self.ckpt_every == 0:
            with obs.span("checkpoint", step=step):
                self._save()
        return stats, dt

    def run(self, batches, *, on_batch=None):
        """Drive every batch from the current cursor; returns the state.

        With auditing enabled a final recover runs after the last batch
        (a fault injected after the last cadenced audit must not leak
        out of the loop).
        """
        for step in range(self.cursor, len(batches)):
            stats, dt = self.step(step, batches[step])
            if on_batch:
                on_batch(step, stats, dt)
        if self.audit_every or self.chaos:
            with obs.span("audit_recover", step=len(batches)):
                self._recover(len(batches))
        if self._writer is not None:
            self._writer.join()
            self._writer = None
        return self.state

    def audit_now(self):
        """One out-of-cadence audit (no repair); returns the report."""
        report = audit_forest(self.state, self.tn, self.bcc)
        self.last_report = report
        return report
