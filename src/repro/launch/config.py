"""Typed serving configuration: one schema for every serving entry.

``serve_stream`` grew 20+ ad-hoc argparse flags across five concerns
(workload, cache refresh cadence, reads, chaos/self-healing,
checkpointing), and every new entry point re-plumbed them by hand.
``ServeConfig`` is the single typed schema (DESIGN.md §7, §13):

  * sub-configs group the flags — ``StreamConfig`` (graph/stream/batch),
    ``RefreshConfig`` (tour/bcc cadence), ``ReadConfig`` (query
    interleave), ``ChaosConfig`` (injection/audit/sanitize),
    ``CheckpointConfig`` (crash recovery);
  * ``add_args``/``from_args`` bind the schema to argparse once — the
    flag surface of ``serve_stream`` is unchanged, ``serve_fleet`` gets
    the identical surface for free;
  * ``to_dict``/``from_dict`` round-trip exactly (regression-tested), so
    a config can ride a checkpoint manifest or a job spec;
  * consumers take the config object: ``ResilientStreamLoop.from_config``
    and the fleet loop both read it instead of copying kwargs, ending the
    flag-plumbing duplication between the plain and resilient loops.

``FleetConfig`` adds the multi-tenant knobs (tenant count, fleet slots,
eviction checkpoint directory) on top for ``serve_fleet``.
"""
from __future__ import annotations

import argparse
import dataclasses
from typing import Any

STREAM_NAMES = ("sliding_window", "insert_heavy", "churn")
TOUR_MODES = ("incremental", "full", "off")
BCC_MODES = ("incremental", "full", "off")
STALENESS_POLICIES = ("strict", "refresh", "stale")


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """The write workload: which graph, which traffic regime, how much."""

    graph: str = "grid_64"
    stream: str = "churn"
    batch: int = 64
    steps: int = 32
    window: int = 4
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class RefreshConfig:
    """Derived-cache maintenance: tour/BCC modes + shared cadence."""

    tour: str = "incremental"
    tour_every: int = 4
    bcc: str = "off"


@dataclasses.dataclass(frozen=True)
class ReadConfig:
    """The query interleave (DESIGN.md §12)."""

    read_ratio: float = 0.0
    read_batch: int = 64
    query_staleness: str = "stale"


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Fault injection + self-healing cadence (DESIGN.md §11)."""

    chaos: str = ""
    chaos_every: int = 8
    chaos_seed: int = 0
    sanitize: bool = False
    audit_every: int = 0


@dataclasses.dataclass(frozen=True)
class CheckpointConfig:
    """Crash recovery (DESIGN.md §8)."""

    ckpt_dir: str | None = None
    ckpt_every: int = 0
    resume: bool = False


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Observability exports (DESIGN.md §14)."""

    trace_out: str | None = None
    metrics_out: str | None = None
    metrics_every: int = 0


#: (attribute on ServeConfig, sub-config class) — the schema, in flag order.
_GROUPS = (("stream", StreamConfig), ("refresh", RefreshConfig),
           ("read", ReadConfig), ("chaos", ChaosConfig),
           ("ckpt", CheckpointConfig), ("obs", ObsConfig))


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Everything a serving loop needs, as one typed object."""

    stream: StreamConfig = dataclasses.field(default_factory=StreamConfig)
    refresh: RefreshConfig = dataclasses.field(
        default_factory=RefreshConfig)
    read: ReadConfig = dataclasses.field(default_factory=ReadConfig)
    chaos: ChaosConfig = dataclasses.field(default_factory=ChaosConfig)
    ckpt: CheckpointConfig = dataclasses.field(
        default_factory=CheckpointConfig)
    obs: ObsConfig = dataclasses.field(default_factory=ObsConfig)
    validate: bool = False

    # -- argparse binding ----------------------------------------------------

    @staticmethod
    def add_args(ap: argparse.ArgumentParser) -> None:
        """Register the full flag surface (same names ``serve_stream``
        always had, so existing invocations keep working)."""
        g = ap.add_argument_group("workload")
        g.add_argument("--graph", default=StreamConfig.graph,
                       help="data.graphs.SUITE name")
        g.add_argument("--stream", default=StreamConfig.stream,
                       choices=STREAM_NAMES)
        g.add_argument("--batch", type=int, default=StreamConfig.batch)
        g.add_argument("--steps", type=int, default=StreamConfig.steps,
                       help="max update batches to apply")
        g.add_argument("--window", type=int, default=StreamConfig.window,
                       help="sliding_window retention (batches)")
        g.add_argument("--seed", type=int, default=StreamConfig.seed)

        g = ap.add_argument_group("cache refresh")
        g.add_argument("--tour", default=RefreshConfig.tour,
                       choices=TOUR_MODES,
                       help="tour refresh mode (full = ablation baseline)")
        g.add_argument("--tour-every", type=int,
                       default=RefreshConfig.tour_every,
                       help="refresh the tour numbering every k batches")
        g.add_argument("--bcc", default=RefreshConfig.bcc,
                       choices=BCC_MODES,
                       help="maintain pool biconnectivity at the tour "
                            "cadence (DESIGN.md §10)")

        g = ap.add_argument_group("reads")
        g.add_argument("--read-ratio", type=float,
                       default=ReadConfig.read_ratio,
                       help="fraction of events that are queries: per "
                            "write batch, issue read batches until "
                            "reads/(reads+writes) ~ r (0 = writes only)")
        g.add_argument("--read-batch", type=int,
                       default=ReadConfig.read_batch,
                       help="queries per read batch")
        g.add_argument("--query-staleness",
                       default=ReadConfig.query_staleness,
                       choices=STALENESS_POLICIES,
                       help="QuerySession policy between tour refreshes "
                            "(DESIGN.md §12)")

        g = ap.add_argument_group("chaos / self-healing")
        g.add_argument("--chaos", default=ChaosConfig.chaos,
                       help="comma-separated dynamic.chaos injector "
                            "names, or 'all' (deterministic fault "
                            "injection)")
        g.add_argument("--chaos-every", type=int,
                       default=ChaosConfig.chaos_every,
                       help="inject one fault every k batches")
        g.add_argument("--chaos-seed", type=int,
                       default=ChaosConfig.chaos_seed)
        g.add_argument("--sanitize", action="store_true",
                       help="quarantine malformed events before apply")
        g.add_argument("--audit-every", type=int,
                       default=ChaosConfig.audit_every,
                       help="audit invariants every k batches and run "
                            "the repair ladder on violation "
                            "(DESIGN.md §11)")

        g = ap.add_argument_group("checkpointing")
        g.add_argument("--ckpt-dir", default=CheckpointConfig.ckpt_dir,
                       help="checkpoint directory (enables crash "
                            "recovery)")
        g.add_argument("--ckpt-every", type=int,
                       default=CheckpointConfig.ckpt_every,
                       help="checkpoint every k batches")
        g.add_argument("--resume", action="store_true",
                       help="resume from the newest checkpoint in "
                            "--ckpt-dir")

        g = ap.add_argument_group("observability")
        g.add_argument("--trace-out", default=ObsConfig.trace_out,
                       help="write the span trace as JSONL here, plus "
                            "Chrome trace-event JSON (Perfetto-loadable) "
                            "at <path>.chrome.json (DESIGN.md §14)")
        g.add_argument("--metrics-out", default=ObsConfig.metrics_out,
                       help="write the metrics registry as JSON here at "
                            "loop end (and every --metrics-every batches)")
        g.add_argument("--metrics-every", type=int,
                       default=ObsConfig.metrics_every,
                       help="flush --metrics-out every k batches "
                            "(0 = only at loop end)")

        ap.add_argument("--validate", action="store_true",
                        help="oracle-check the final forest")

    @classmethod
    def from_args(cls, ns: argparse.Namespace) -> "ServeConfig":
        groups = {}
        for attr, sub in _GROUPS:
            kwargs = {f.name: getattr(ns, f.name)
                      for f in dataclasses.fields(sub)}
            groups[attr] = sub(**kwargs)
        return cls(validate=ns.validate, **groups)

    # -- serialization round-trip --------------------------------------------

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "ServeConfig":
        groups = {attr: sub(**d[attr]) for attr, sub in _GROUPS}
        return cls(validate=d["validate"], **groups)

    # -- validation ----------------------------------------------------------

    def check(self) -> "ServeConfig":
        """Cross-field validation; raises ValueError with an argparse-
        friendly message."""
        r = self.read.read_ratio
        if r and not 0.0 < r < 1.0:
            raise ValueError("--read-ratio must be in (0, 1)")
        if r and self.refresh.tour == "off":
            raise ValueError("--read-ratio needs tour maintenance "
                             "(--tour incremental|full)")
        if self.stream.stream not in STREAM_NAMES:
            raise ValueError(f"unknown stream {self.stream.stream!r}")
        return self

    # -- consumer views ------------------------------------------------------

    def injector_names(self, known=None) -> tuple[str, ...]:
        """The chaos injector tuple (validated against ``known``)."""
        if not self.chaos.chaos:
            return ()
        if self.chaos.chaos == "all":
            return tuple(known) if known is not None else ("all",)
        names = tuple(self.chaos.chaos.split(","))
        if known is not None:
            for name in names:
                if name not in known:
                    raise ValueError(
                        f"unknown injector {name!r} "
                        f"(have: {', '.join(known)})")
        return names

    def stream_kwargs(self) -> dict[str, Any]:
        """Generator kwargs for ``data.streams.STREAMS[...]``."""
        kw: dict[str, Any] = {"batch": self.stream.batch,
                              "seed": self.stream.seed}
        if self.stream.stream == "sliding_window":
            kw["window"] = self.stream.window
        if self.stream.stream == "churn":
            kw["n_batches"] = self.stream.steps
        return kw

    def cadence(self):
        """The ``dynamic.view.CadencePolicy`` this config describes."""
        from repro.dynamic.view import CadencePolicy

        return CadencePolicy(tour=self.refresh.tour,
                             bcc=self.refresh.bcc,
                             every=self.refresh.tour_every,
                             queries=self.read.read_ratio > 0,
                             staleness=self.read.query_staleness)


@dataclasses.dataclass(frozen=True)
class BucketSpec:
    """One parsed ``--buckets`` entry: a shape class + its population."""

    graph: str
    tenants: int
    slots: int
    batch: int | None = None   # None → ServeConfig.stream.batch


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Multi-tenant knobs on top of ``ServeConfig`` (DESIGN.md §13, §15)."""

    tenants: int = 4
    slots: int = 4
    evict_dir: str | None = None
    buckets: str = ""
    drain: int = 1

    @staticmethod
    def add_args(ap: argparse.ArgumentParser) -> None:
        g = ap.add_argument_group("fleet")
        g.add_argument("--tenants", type=int, default=FleetConfig.tenants,
                       help="session graphs (one edge stream each)")
        g.add_argument("--slots", type=int, default=FleetConfig.slots,
                       help="resident fleet slots T; tenants beyond this "
                            "are admitted by LRU eviction")
        g.add_argument("--evict-dir", default=FleetConfig.evict_dir,
                       help="checkpoint-on-evict directory (default: "
                            "a temp dir)")
        g.add_argument("--buckets", default=FleetConfig.buckets,
                       help="shape-bucketed sub-fleets (DESIGN.md §15): "
                            "comma-separated graph:tenants[:slots[:batch]] "
                            "specs, e.g. 'chain_64:12:4,rmat_9:2:2:32'. "
                            "Graph names may be SUITE keys or "
                            "chain_<n>/grid_<side>/rmat_<scale>/er_<n> "
                            "patterns; batch defaults to --batch. "
                            "Overrides --graph/--tenants/--slots.")
        g.add_argument("--drain", type=int, default=FleetConfig.drain,
                       help="max dispatcher blocks per serving tick "
                            "(cross-tick carryover for bursty tenants; "
                            "1 = PR-8 behavior)")

    @classmethod
    def from_args(cls, ns: argparse.Namespace) -> "FleetConfig":
        return cls(tenants=ns.tenants, slots=ns.slots,
                   evict_dir=ns.evict_dir, buckets=ns.buckets,
                   drain=ns.drain)

    def check(self) -> "FleetConfig":
        if self.tenants < 1 or self.slots < 1:
            raise ValueError("--tenants and --slots must be >= 1")
        if self.drain < 1:
            raise ValueError("--drain must be >= 1")
        if self.buckets:
            self.bucket_specs()   # raises ValueError on a bad spec
        return self

    def bucket_specs(self) -> tuple[BucketSpec, ...]:
        """Parse ``--buckets`` into ``BucketSpec``s (empty when unset)."""
        specs = []
        for part in filter(None, (p.strip()
                                  for p in self.buckets.split(","))):
            fields = part.split(":")
            if not 2 <= len(fields) <= 4:
                raise ValueError(
                    f"--buckets entry {part!r}: expected "
                    "graph:tenants[:slots[:batch]]")
            graph = fields[0]
            try:
                nums = [int(f) for f in fields[1:]]
            except ValueError:
                raise ValueError(
                    f"--buckets entry {part!r}: tenants/slots/batch "
                    "must be integers") from None
            tenants = nums[0]
            slots = nums[1] if len(nums) > 1 else tenants
            batch = nums[2] if len(nums) > 2 else None
            if tenants < 1 or slots < 1 or (batch is not None
                                            and batch < 1):
                raise ValueError(
                    f"--buckets entry {part!r}: counts must be >= 1")
            specs.append(BucketSpec(graph=graph, tenants=tenants,
                                    slots=slots, batch=batch))
        if self.buckets and not specs:
            raise ValueError("--buckets was given but parsed to no specs")
        return tuple(specs)
