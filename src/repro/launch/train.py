"""Training driver: ``python -m repro.launch.train --arch <id> [--smoke]``.

Runs real steps on the local device(s) (smoke configs on CPU; full configs
are for pods). Wires together: config registry → step factory →
fault-tolerant loop (checkpoint/restart, watchdog, straggler log) →
synthetic data pipeline per family.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_arch
from repro.launch.mesh import make_host_mesh
from repro.train.fault import FaultTolerantLoop
from repro.train.step import build_cell
from repro.optim.adamw import adamw_init


def synthetic_batches(spec, shape, cfg, seed=0):
    """Yield (cursor, batch) forever — family-appropriate synthetic data."""
    rng = np.random.default_rng(seed)
    cursor = 0
    while True:
        if spec.family == "lm":
            b, s = shape["batch"], shape["seq"]
            toks = rng.integers(0, cfg.vocab, (b, s + 1))
            batch = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
                     "targets": jnp.asarray(toks[:, 1:], jnp.int32)}
        elif spec.family == "gnn":
            from repro.data.gnn_batch import random_graph_batch
            needs_pos = spec.arch_id in ("schnet", "dimenet", "meshgraphnet")
            atom = spec.arch_id in ("schnet", "dimenet")
            nt = 4 * shape["n_edges"] if spec.arch_id == "dimenet" else 0
            g = random_graph_batch(shape["n_nodes"], shape["n_edges"],
                                   shape["d_feat"], seed=seed + cursor,
                                   positions=needs_pos, atom_types=atom,
                                   n_graphs=shape["n_graphs"],
                                   max_triplets=nt)
            gd = {"node_feat": g.node_feat, "src": g.src, "dst": g.dst,
                  "graph_id": g.graph_id}
            if g.positions is not None:
                gd["positions"] = g.positions
            if g.trip_in is not None:
                gd["trip_in"] = g.trip_in
                gd["trip_out"] = g.trip_out
            if spec.arch_id == "gat-cora":
                labels = jnp.asarray(
                    rng.integers(0, cfg.n_classes, shape["n_nodes"]), jnp.int32)
            elif spec.arch_id == "meshgraphnet":
                labels = jnp.asarray(
                    rng.standard_normal((shape["n_nodes"], 3)), jnp.float32)
            else:
                labels = jnp.asarray(
                    rng.standard_normal(shape["n_graphs"]), jnp.float32)
            batch = {"graph": gd, "labels": labels}
        else:  # recsys
            b, t = shape["batch"], cfg.seq_len
            batch = {
                "hist_items": jnp.asarray(rng.integers(0, cfg.n_items, (b, t)), jnp.int32),
                "hist_cates": jnp.asarray(rng.integers(0, cfg.n_cates, (b, t)), jnp.int32),
                "hist_mask": jnp.asarray(rng.random((b, t)) < 0.9),
                "target_item": jnp.asarray(rng.integers(0, cfg.n_items, b), jnp.int32),
                "target_cate": jnp.asarray(rng.integers(0, cfg.n_cates, b), jnp.int32),
                "user_feats": jnp.asarray(rng.integers(0, cfg.n_user_feats, (b, cfg.user_hot)), jnp.int32),
                "label": jnp.asarray(rng.integers(0, 2, b), jnp.int32),
            }
        yield cursor, batch
        cursor += 1


SMOKE_SHAPES = {
    "lm": dict(kind="train", batch=4, seq=64),
    "gnn": dict(kind="train", n_nodes=64, n_edges=256, d_feat=16, n_graphs=4),
    "recsys": dict(kind="train", batch=16),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    mesh = make_host_mesh()
    cfg = spec.make_smoke_config() if args.smoke else spec.make_config()
    if args.smoke:
        shape = dict(SMOKE_SHAPES[spec.family])
        if spec.family == "gnn":
            shape["d_feat"] = getattr(
                cfg, "d_in", getattr(cfg, "d_in_node", shape["d_feat"]))
        shape_name = "smoke"
        # Build a smoke cell by reusing the factory machinery with a
        # patched shapes table.
        import dataclasses as dc
        spec = dc.replace(spec, shapes={"smoke": shape})
    else:
        shape_name = args.shape or list(spec.shapes)[0]
        shape = spec.shapes[shape_name]

    step_fn, state_abs, _ = build_cell(spec, shape_name, mesh,
                                       smoke=args.smoke)

    # Real init matching the abstract state tree.
    from repro.train.step import gnn_make_init
    from repro.models import transformer as tfm, dien as dien_mod
    key = jax.random.key(0)
    if spec.family == "lm":
        params = tfm.init_params(cfg, key)
    elif spec.family == "gnn":
        params = gnn_make_init(spec.arch_id, cfg)(cfg, key)
    else:
        params = dien_mod.dien_init(cfg, key)
    state = {"params": params, "opt": adamw_init(params)}

    jit_step = jax.jit(step_fn)
    loop = FaultTolerantLoop(
        step_fn=jit_step, state=state,
        data_iter=synthetic_batches(spec, shape, cfg),
        ckpt_dir=f"{args.ckpt_dir}/{args.arch}",
        ckpt_every=args.ckpt_every)
    loop.resume()

    t0 = time.time()
    def on_metrics(step, metrics, dt):
        if step % 5 == 0 or step == 1:
            loss = float(metrics["loss"])
            print(f"step {step:5d}  loss {loss:.4f}  {dt*1e3:.0f} ms")

    loop.run(args.steps, on_metrics=on_metrics)
    print(f"done: {args.steps} steps in {time.time()-t0:.1f}s; "
          f"retries={loop.retries} stragglers={len(loop.stragglers)}")


if __name__ == "__main__":
    main()
