import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (arch × shape) cell on the
# production mesh and record memory / cost / collective analyses.
#
# The two lines above MUST stay first — jax locks the device count at first
# init, and the dry-run needs 512 placeholder host devices to build the
# (2, 16, 16) mesh. Smoke tests and benchmarks never import this module.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all          # 40 cells x 2 meshes
#   PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod-only
#
# Artifacts: one JSON per (arch, shape, mesh) under --out (default
# artifacts/dryrun), with cost_analysis, memory_analysis, parsed HLO costs
# (trip-count-aware flops / bytes / collective payloads) and timings.

import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs import ARCH_IDS, get_arch
from repro.launch.mesh import make_production_mesh
from repro.launch.hlo_analysis import analyze
from repro.train.step import build_cell


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
             out_dir: pathlib.Path, save_hlo: bool = False) -> dict:
    spec = get_arch(arch_id)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch_id}__{shape_name}__{mesh_name}"
    t0 = time.time()
    step_fn, state_abs, inputs_abs = build_cell(spec, shape_name, mesh)
    t_build = time.time() - t0

    t0 = time.time()
    # jax.set_mesh is post-0.4.x; the Mesh context manager is the
    # equivalent pjit-era spelling for establishing the ambient mesh.
    with getattr(jax, "set_mesh", lambda m: m)(mesh):
        lowered = jax.jit(step_fn).lower(state_abs, inputs_abs)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ca = compiled.cost_analysis() or {}
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_size_in_bytes": ma.argument_size_in_bytes,
            "output_size_in_bytes": ma.output_size_in_bytes,
            "temp_size_in_bytes": ma.temp_size_in_bytes,
            "alias_size_in_bytes": ma.alias_size_in_bytes,
            "generated_code_size_in_bytes": ma.generated_code_size_in_bytes,
        }
    except Exception as e:  # pragma: no cover
        mem = {"error": repr(e)}

    hlo_text = compiled.as_text()
    t0 = time.time()
    parsed = analyze(hlo_text)
    t_parse = time.time() - t0

    n_dev = mesh.devices.size
    result = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_name,
        "n_devices": n_dev,
        "timings_s": {"build": round(t_build, 2), "lower": round(t_lower, 2),
                      "compile": round(t_compile, 2),
                      "hlo_parse": round(t_parse, 2)},
        "cost_analysis": {k: ca.get(k) for k in
                          ("flops", "bytes accessed", "utilization")
                          if k in ca},
        "memory_analysis": mem,
        "hlo_parsed": parsed.to_json(),
        "hlo_size_chars": len(hlo_text),
        "ok": True,
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{tag}.json").write_text(json.dumps(result, indent=1))
    if save_hlo:
        import gzip
        with gzip.open(out_dir / f"{tag}.hlo.txt.gz", "wt") as f:
            f.write(hlo_text)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out)

    if args.all:
        cells = []
        for aid in ARCH_IDS:
            spec = get_arch(aid)
            for sh in spec.shapes:
                meshes = [False, True]
                if args.single_pod_only:
                    meshes = [False]
                if args.multi_pod_only:
                    meshes = [True]
                for mp in meshes:
                    cells.append((aid, sh, mp))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells = [(args.arch, args.shape, args.multi_pod)]

    failures = []
    for aid, sh, mp in cells:
        mesh_name = "2x16x16" if mp else "16x16"
        tag = f"{aid}__{sh}__{mesh_name}"
        if args.skip_existing and (out_dir / f"{tag}.json").exists():
            prev = json.loads((out_dir / f"{tag}.json").read_text())
            if prev.get("ok"):
                print(f"[skip] {tag}")
                continue
        t0 = time.time()
        try:
            res = run_cell(aid, sh, multi_pod=mp, out_dir=out_dir,
                           save_hlo=args.save_hlo)
            hp = res["hlo_parsed"]
            print(f"[ok]   {tag}  compile={res['timings_s']['compile']}s "
                  f"flops/dev={hp['flops']:.3e} "
                  f"coll/dev={hp['collective_bytes']:.3e}B "
                  f"temp={res['memory_analysis'].get('temp_size_in_bytes', 0)/2**30:.2f}GiB")
        except Exception as e:
            failures.append(tag)
            out_dir.mkdir(parents=True, exist_ok=True)
            (out_dir / f"{tag}.json").write_text(json.dumps(
                {"arch": aid, "shape": sh, "mesh": mesh_name, "ok": False,
                 "error": traceback.format_exc()}, indent=1))
            print(f"[FAIL] {tag}  {time.time()-t0:.1f}s  {e}")
    if failures:
        print(f"\n{len(failures)} FAILURES: {failures}")
        raise SystemExit(1)
    print(f"\nall {len(cells)} cells OK")


if __name__ == "__main__":
    main()
