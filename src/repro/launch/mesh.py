"""Production mesh: 16×16 single pod (256 chips) / 2×16×16 multi-pod.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (the dry-run sets
``--xla_force_host_platform_device_count=512`` before any jax import).
"""
from __future__ import annotations

import jax


def auto_axis_kwargs(n_axes: int) -> dict:
    """``axis_types=Auto`` where the jax version has it, ``{}`` otherwise.

    ``jax.sharding.AxisType`` landed after 0.4.x; Auto is the pre-AxisType
    default behavior, so omitting the kwarg on older jax is equivalent.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **auto_axis_kwargs(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(min(model, n // data), 1)
    return jax.make_mesh((data, model), ("data", "model"),
                         **auto_axis_kwargs(2))
