"""Unified refresh surface for the forest's derived caches (DESIGN.md §13).

Before this module, the three derived read structures of a
``DynamicForest`` — the Euler-tour numbering (§9), the biconnectivity
labels (§10), and the ``QuerySession`` read view (§12) — were refreshed
by three call sites with inconsistent keyword signatures, and every
serving loop re-implemented the same cadence bookkeeping ("is this the
k-th batch?") and dirty checks around them. ``ForestView`` folds that
behind one entry:

    view = ForestView(CadencePolicy(tour="incremental", bcc="incremental",
                                    every=4))
    state = view.prime(state)            # initial cache build
    ...
    state = view.refresh(state, step=i)  # cadenced: no-op off-cadence
    state = view.refresh(state)          # forced: refresh everything on

``CadencePolicy`` is the single cadence policy object: which caches are
maintained (``tour``/``bcc`` modes, ``queries``), how often (``every``),
and the query-staleness policy between refreshes. ``refresh`` accepts
per-call overrides (``tour=``, ``bcc=``, ``queries=``) for out-of-cadence
work — e.g. a recovery path forcing a tour rebuild without touching BCC.

The old entry points ``dynamic.tour.refresh_tour`` and
``dynamic.bcc.refresh_bcc`` remain as thin deprecated wrappers over the
one-shot functions here (``refresh_tour_once`` / ``refresh_bcc_once``)
so existing callers keep working; new code should hold a ``ForestView``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax

from repro import obs
from repro.core.euler import TourNumbering, tour_numbering
from repro.dynamic.bcc import (DynamicBCC, _refresh_full,
                               _refresh_incremental)
from repro.dynamic.forest import DynamicForest
from repro.dynamic.tour import _clear_dirty, _merge_dirty

_MODES = ("incremental", "full", "off")
_STALENESS = ("strict", "refresh", "stale")


@dataclasses.dataclass(frozen=True)
class CadencePolicy:
    """Which derived caches are maintained, and on what cadence.

    Attributes:
      tour:      tour-numbering mode — ``incremental`` (§9 dirty-scoped
                 merge), ``full`` (ablation), ``off``.
      bcc:       biconnectivity mode (§10), same values.
      queries:   also maintain a ``QuerySession`` at the cadence (§12).
      every:     refresh after every k-th batch (0 disables cadenced
                 refreshes; forced refreshes still work).
      staleness: ``QuerySession`` policy between refreshes.
    """

    tour: str = "incremental"
    bcc: str = "off"
    queries: bool = False
    every: int = 4
    staleness: str = "stale"

    def __post_init__(self):
        if self.tour not in _MODES:
            raise ValueError(f"tour mode {self.tour!r} not in {_MODES}")
        if self.bcc not in _MODES:
            raise ValueError(f"bcc mode {self.bcc!r} not in {_MODES}")
        if self.staleness not in _STALENESS:
            raise ValueError(
                f"staleness {self.staleness!r} not in {_STALENESS}")

    def due(self, step: int | None) -> bool:
        """True when the cadence lands at 0-based batch index ``step``
        (``None`` = forced, always due)."""
        if step is None:
            return True
        return self.every > 0 and (step + 1) % self.every == 0


def refresh_tour_once(state: DynamicForest,
                      cached: TourNumbering | None = None, *,
                      incremental: bool = True, use_kernel: bool = False):
    """One tour refresh (the §9 step; canonical home of the logic).

    ``None``/``incremental=False`` recompute from scratch; otherwise the
    dirty-scoped merge — bit-identical either way. Returns
    ``(numbering, state')`` with the dirty mask cleared.

    Sync accounting: the engine counters already ride both loops'
    carries, so this wrapper always requests them and reports the count
    to the ambient ``obs`` ledger — the compiled program is identical
    whether or not anything is recording (DESIGN.md §14).
    """
    if cached is None or not incremental:
        tn, syncs = tour_numbering(state.parent, use_kernel=use_kernel,
                                   return_syncs=True)
    else:
        tn, syncs = _merge_dirty(state.parent, state.rep, state.dirty,
                                 cached, use_kernel=use_kernel,
                                 return_syncs=True)
    obs.record("refresh_tour", syncs)
    return tn, _clear_dirty(state)


def refresh_bcc_once(state: DynamicForest,
                     cached: DynamicBCC | None = None, *,
                     tour: TourNumbering | None = None,
                     incremental: bool = True,
                     use_kernel: bool = False) -> DynamicBCC:
    """One biconnectivity refresh (the §10 step; canonical home).

    Reports the refresh's engine syncs (``seg_syncs + aux_rounds``, the
    table5 accounting) to the ambient ``obs`` ledger.
    """
    if tour is not None:
        tn = tour
    else:
        tn, tn_syncs = tour_numbering(state.parent, use_kernel=use_kernel,
                                      return_syncs=True)
        obs.record("refresh_tour", tn_syncs)
    if cached is None or not incremental:
        bcc = _refresh_full(state, tn, use_kernel=use_kernel)
    else:
        bcc = _refresh_incremental(state, tn, cached, use_kernel=use_kernel)
    obs.record("refresh_bcc",
               lambda: int(bcc.seg_syncs) + int(bcc.aux_rounds))
    return bcc


@dataclasses.dataclass
class ForestView:
    """The derived-cache bundle of one forest, refreshed as a unit.

    Owns the tour numbering, the BCC labels, and (when the policy asks)
    the ``QuerySession`` — plus the refresh-latency telemetry serving
    loops report. Host-side mutable (like the loops that hold it), NOT
    a pytree; the caches it owns are.
    """

    policy: CadencePolicy = dataclasses.field(default_factory=CadencePolicy)
    use_kernel: bool = False
    tn: TourNumbering | None = None
    bcc: DynamicBCC | None = None
    session: Any = None                   # dynamic.queries.QuerySession
    tour_lat: list = dataclasses.field(default_factory=list)
    bcc_lat: list = dataclasses.field(default_factory=list)
    _tn_adopted: Any = None               # tn the session was built over

    @property
    def maintains_caches(self) -> bool:
        return self.policy.tour != "off" or self.policy.bcc != "off"

    def prime(self, state: DynamicForest) -> DynamicForest:
        """Initial cache build — fixes the checkpoint pytree structure
        up front (a maintained cache exists from step 0). BCC-only
        policies still get a tour numbering (§10 needs one)."""
        if self.maintains_caches:
            state = self.refresh(state, tour=True)
        return state

    def refresh(self, state: DynamicForest, *, step: int | None = None,
                tour: bool | None = None, bcc: bool | None = None,
                queries: bool | None = None) -> DynamicForest:
        """Refresh every cache that is (a) on and (b) due at ``step``.

        ``step=None`` forces the refresh (cadence bypassed). ``tour`` /
        ``bcc`` / ``queries`` override the policy's on/off per call
        (``True`` forces a normally-off cache using the incremental
        mode, ``False`` skips a normally-on one). Returns the state with
        its dirty mask cleared iff the tour refreshed.
        """
        if not self.policy.due(step):
            return state
        do_tour = (self.policy.tour != "off") if tour is None else tour
        do_bcc = (self.policy.bcc != "off") if bcc is None else bcc
        do_q = self.policy.queries if queries is None else queries

        if do_tour:
            with obs.span("refresh_tour", step=step):
                t0 = time.perf_counter()
                mode = self.policy.tour if self.policy.tour != "off" \
                    else "incremental"
                self.tn, state = refresh_tour_once(
                    state, self.tn, incremental=(mode == "incremental"),
                    use_kernel=self.use_kernel)
                jax.block_until_ready(self.tn.pre)
                self.tour_lat.append(time.perf_counter() - t0)
        if do_bcc:
            with obs.span("refresh_bcc", step=step):
                t0 = time.perf_counter()
                mode = self.policy.bcc if self.policy.bcc != "off" \
                    else "incremental"
                self.bcc = refresh_bcc_once(
                    state, self.bcc, tour=self.tn,
                    incremental=(mode == "incremental"),
                    use_kernel=self.use_kernel)
                jax.block_until_ready(self.bcc.edge_bcc)
                self.bcc_lat.append(time.perf_counter() - t0)
        if do_q:
            with obs.span("adopt_session", step=step):
                self.adopt_session(state)
        return state

    # -- query-session adoption (the §12 rebuild, folded here) ---------------

    def adopt_session(self, state: DynamicForest):
        """(Re)build the ``QuerySession`` over the current caches.

        The dirty check is object identity on ``tn`` — a session adopts
        the exact numbering object the view holds; any tour refresh
        produces a new object and triggers re-adoption. Between
        refreshes the session's own staleness policy governs (that's the
        §12 contract — adoption must NOT rebuild per version bump).
        Falls back to a tour-only session when the caches don't match
        the live state mid-interval (e.g. a caller forcing a session
        before the first cadenced refresh). Sync/staleness counters
        carry across generations, so ``session.sync_stats()`` is
        cumulative for the run.
        """
        from repro.dynamic.queries import QuerySession

        if self.session is not None and self._tn_adopted is self.tn:
            return self.session
        carry = self.session.sync_stats() if self.session is not None \
            else None
        try:
            sess = QuerySession.from_state(
                state, self.tn, self.bcc, policy=self.policy.staleness,
                use_kernel=self.use_kernel)
        except ValueError:
            sess = QuerySession.from_state(
                state, policy=self.policy.staleness,
                use_kernel=self.use_kernel)
        if carry is not None:
            sess.builds += carry["builds"]
            sess.build_syncs_total += carry["build_syncs_total"]
            sess.stale_served += carry["stale_served"]
            sess.auto_refreshes += carry["auto_refreshes"]
        self.session = sess
        self._tn_adopted = self.tn
        return sess
