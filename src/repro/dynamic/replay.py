"""Replay helpers: drive a ``DynamicForest`` from an ``EdgeStream``.

Shared by tests, the streaming example, ``launch.serve_stream``, and
``benchmarks/table4_dynamic.py`` so they all apply batches identically:
deletions resolve (u, v) pairs to pool slots via ``edge_slots``, then
one jitted ``apply_batch`` call per batch.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro import obs
from repro.data.streams import EdgeStream, StreamBatch
from repro.dynamic.forest import (DynamicForest, apply_batch, edge_slots,
                                  forest_empty)


def stream_capacity(stream: EdgeStream, slack: int = 0) -> int:
    """Pool capacity that fits the stream's peak live-edge count."""
    n = stream.n_nodes
    live = int(stream.init_u.shape[0])
    peak = live
    for b in stream.batches:
        live += int((b.ins_u < n).sum()) - int((b.del_u < n).sum())
        peak = max(peak, live)
    return max(peak + slack, 1)


def init_state(stream: EdgeStream,
               capacity: int | None = None) -> DynamicForest:
    """Seed state holding the stream's initially-live edges."""
    if capacity is None:
        capacity = stream_capacity(stream)
    state = forest_empty(stream.n_nodes, capacity)
    if stream.init_u.shape[0]:
        no_del = jnp.zeros((capacity,), jnp.bool_)
        state, _ = apply_batch(state, jnp.asarray(stream.init_u),
                               jnp.asarray(stream.init_v), no_del)
    return state


def replay_batch(state: DynamicForest, b: StreamBatch, **kwargs):
    """Apply one stream batch: resolve deletions, then ``apply_batch``.

    Returns (state', stats); stats gains ``deletes_found`` (int32 count
    of delete requests that matched a live pool slot).
    """
    dmask, found = edge_slots(state, jnp.asarray(b.del_u),
                              jnp.asarray(b.del_v))
    state, stats = apply_batch(state, jnp.asarray(b.ins_u),
                               jnp.asarray(b.ins_v), dmask, **kwargs)
    stats["deletes_found"] = jnp.sum(found.astype(jnp.int32))
    # rounds + 1: GConn rounds plus the final convergence check — the
    # same per-batch sync accounting the table4/table8 baselines use.
    obs.record("apply", lambda: int(stats["rounds"]) + 1)
    return state, stats
