"""Graceful degradation + scoped repair for the dynamic forest (DESIGN.md §11).

The escalation ladder, cheapest rung first:

  1. **Audit** (``dynamic.audit.audit_forest``) — O(log n) syncs; if
     healthy, nothing else runs.
  2. **Scoped repair** (``repair_forest``) — *fragment-preserving*
     rebuild of only the violating components: clear the parent pointer
     at just the directly-violating vertices (every cycle member fails
     the reaches-root check, so clearing them breaks all cycles), keep
     every tree edge that is still a genuine parent link, re-derive
     ``rep`` with one ``compress_scoped`` pass over the violation
     closure, then drain cross edges with the same union-by-size
     ``core.reroot.link_components`` loop ``apply_batch`` uses. Intact
     components pay zero doubling work, and intact *subtrees inside the
     damaged component* survive as fragments — so the link loop runs
     O(log #fragments) rounds, scaling with the number of faults rather
     than the size of the component they landed in.
  3. **Full rebuild** (``rebuild_forest``) — if severing cannot break
     every cycle (``_post_sever_acyclic`` — the one corruption shape
     the cut-set heuristic misses) or a second audit still fails,
     re-derive
     parent / rep / tree_mask from scratch: GConn connectivity over the
     pool + Euler-tour rooting, the ``forest_from_graph`` path applied
     to the live pool in place.

The edge pool is ground truth throughout: repair never invents edges, it
re-derives the spanning structure from what the pool holds (slots with
out-of-range endpoints are quarantined — invalidated and counted — since
no spanning structure can be derived from them).

``recover`` drives the ladder end to end and then heals the caches: the
repair scope is already marked dirty, so one incremental
``refresh_tour`` / ``refresh_bcc`` restores the tour numbering and BCC
labels bit-identically to a from-scratch recompute; a full rebuild
invalidates both caches instead. Sync counts for every rung are reported
(``benchmarks/table6_robustness.py`` tracks scoped-repair vs
full-rebuild sync totals — the device-independent recovery cost).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.compress import DEFAULT_JUMPS, compress_scoped
from repro.core.connectivity import connected_components
from repro.core.euler import euler_tour_root
from repro.core.reroot import link_components
from repro.core.compress import compress_full
from repro.dynamic.audit import AUDIT_MAX_SYNCS, AuditReport, audit_forest
from repro.dynamic.bcc import refresh_bcc
from repro.dynamic.forest import DynamicForest, live_graph
from repro.dynamic.tour import refresh_tour


def _quarantine_pool(state_arrays, n):
    """Invalidate live slots with out-of-range endpoints (no truth there)."""
    src, dst, valid, tree = state_arrays
    ep_ok = (src >= 0) & (src < n) & (dst >= 0) & (dst < n)
    drop = valid & ~ep_ok
    n_dropped = jnp.sum(drop.astype(jnp.int32))
    valid = valid & ~drop
    tree = tree & ~drop
    src = jnp.where(drop, n, src)
    dst = jnp.where(drop, n, dst)
    return (src, dst, valid, tree), n_dropped


@partial(jax.jit, static_argnames=("n_jumps", "use_kernel"))
def _repair(state: DynamicForest, sever: jnp.ndarray, scope: jnp.ndarray,
            *, n_jumps: int = DEFAULT_JUMPS, use_kernel: bool = False):
    n = state.n_nodes
    verts = jnp.arange(n, dtype=jnp.int32)
    levels = max(1, (n - 1).bit_length())

    # Sever the parent pointer at exactly the audit's cut set — the
    # vertices whose own pointer is broken (redirects break the tree-
    # slot cover at their child; cycles break it at the entry vertex).
    # Everything else in the damaged component keeps its parent, so the
    # intact subtrees survive as rooted fragments the link loop below
    # stitches back together.
    in_range = (state.parent >= 0) & (state.parent < n)
    p = jnp.where(in_range & ~sever, state.parent, verts)

    (pool_src, pool_dst, pool_valid, tree_mask), n_quarantined = \
        _quarantine_pool((state.pool_src, state.pool_dst,
                          state.pool_valid, state.tree_mask), n)
    uc = jnp.clip(pool_src, 0, n - 1)
    vc = jnp.clip(pool_dst, 0, n - 1)

    # A tree bit survives iff the slot is still a genuine parent link
    # under the severed table. Forged bits aren't parent-linked, a
    # cleared vertex self-points (so the slot for its old parent edge
    # drops out), and duplicate covers mark the child violating — both
    # claimants lose the bit and the link loop re-elects one winner.
    tree_mask = tree_mask & pool_valid & ((p[uc] == vc) | (p[vc] == uc))

    # Re-derive rep over the violation closure. The closure is a union
    # of complete components (audit contract), so severed chains never
    # escape it — compress_scoped's component-closed-mask contract holds
    # even though the input was corrupted. The sync bound is a backstop:
    # callers gate on ``repair_viable`` so the severed table is acyclic
    # and the loop converges far below it.
    comp, rep_syncs = compress_scoped(p, scope, n_jumps=n_jumps,
                                      use_kernel=use_kernel,
                                      return_syncs=True,
                                      max_syncs=AUDIT_MAX_SYNCS)
    rt = jnp.where(scope, comp, state.rep)

    # Drain cross edges — the apply_batch link loop (union-by-size mover,
    # one winner per moving component, PR-RST path reversal). Candidates
    # exist only between fragments the severing created (plus any
    # spanning-violation cross edges the audit pulled into scope), so
    # the round count scales with the fault count, not component size.
    def body(carry):
        p, rt, tree_mask, rnd, links, syncs, _ = carry
        ru = rt[uc]
        rv = rt[vc]
        cand = pool_valid & (ru != rv)
        size = jnp.zeros((n,), jnp.int32).at[rt].add(1)
        su, sv = size[ru], size[rv]
        u_moves = (su < sv) | ((su == sv) & (ru > rv))
        start = jnp.where(u_moves, uc, vc)
        target = jnp.where(u_moves, vc, uc)
        p, rt, is_winner, s = link_components(
            p, rt, start, target, cand, levels=levels, n_jumps=n_jumps,
            use_kernel=use_kernel, return_syncs=True)
        tree_mask = tree_mask | is_winner
        n_won = jnp.sum(is_winner.astype(jnp.int32))
        rnd = rnd + (n_won > 0).astype(jnp.int32)
        return p, rt, tree_mask, rnd, links + n_won, syncs + s, n_won > 0

    def cond(carry):
        *_rest, rnd, _l, _s, changed = carry
        return changed & (rnd < n)

    p, rt, tree_mask, rounds, links, link_syncs, _ = jax.lax.while_loop(
        cond, body, (p, rt, tree_mask, jnp.int32(0), jnp.int32(0),
                     jnp.int32(0), jnp.bool_(True)))

    # Every component that absorbed repaired vertices needs a tour
    # refresh — mark it dirty for the incremental path.
    comp_touched = jnp.zeros((n,), jnp.bool_).at[
        jnp.where(scope, rt, n)].set(True, mode="drop")
    dirty = state.dirty | comp_touched[rt] | scope

    new_state = DynamicForest(
        n_nodes=n, parent=p, rep=rt, pool_src=pool_src, pool_dst=pool_dst,
        pool_valid=pool_valid, tree_mask=tree_mask, dirty=dirty,
        version=state.version + 1)
    stats = {"rounds": rounds, "links": links,
             "severed": jnp.sum((sever & in_range).astype(jnp.int32)),
             "repaired": jnp.sum(scope.astype(jnp.int32)),
             "quarantined_slots": n_quarantined,
             "sync_total": rep_syncs + link_syncs + rounds}
    return new_state, stats


def repair_forest(state: DynamicForest, report: AuditReport, *,
                  n_jumps: int = DEFAULT_JUMPS, use_kernel: bool = False):
    """Repair only the audit's violating components from the live pool.

    Args:
      state: the (possibly corrupted) forest.
      report: the ``audit_forest`` result naming the damage
        (``sever`` — the minimal cut set — and ``comp_violating``, the
        component closure whose ``rep`` is re-derived).

    Returns:
      (state', stats) — stats holds int32 scalars ``rounds`` / ``links``
      (link-loop work), ``severed`` (parent pointers cut), ``repaired``
      (vertices in the rebuild scope), ``quarantined_slots`` (pool slots
      dropped for out-of-range endpoints), and ``sync_total``
      (scoped-compression + overlay-compression convergence checks +
      link rounds — the scoped-recovery cost ``table6_robustness``
      compares against ``rebuild_forest``). ``sync_total`` is also
      reported to the ambient ``obs`` ledger under ``repair``.
    """
    state, stats = _repair(state, report.sever, report.comp_violating,
                           n_jumps=n_jumps, use_kernel=use_kernel)
    obs.record("repair", lambda: int(stats["sync_total"]))
    return state, stats


@jax.jit
def _post_sever_acyclic(state: DynamicForest, sever: jnp.ndarray):
    """Would cutting the audit's sever set leave an acyclic table?

    The scoped repair is only total on an acyclic severed table (its
    link loop compresses an overlay whose acyclicity rests on correct
    reps). The sever heuristic breaks every cycle our injectors can
    plant — a redirected pointer always breaks the tree-slot cover at
    its child — but a cycle whose every link carries a *forged* tree
    bit with consistent cover evades it when its length is odd (no
    self-fixed point under doubling either). One bounded compression
    answers whether severing suffices; if not, ``recover`` escalates
    straight to the full rebuild.
    """
    n = state.n_nodes
    verts = jnp.arange(n, dtype=jnp.int32)
    in_range = (state.parent >= 0) & (state.parent < n)
    p = jnp.where(in_range & ~sever, state.parent, verts)
    hop = compress_full(p, max_syncs=AUDIT_MAX_SYNCS)
    return jnp.all(p[hop] == hop)


@partial(jax.jit, static_argnames=("use_kernel",))
def _rebuild(state: DynamicForest, *, use_kernel: bool = False):
    n = state.n_nodes
    cap = state.pool_src.shape[0]

    (pool_src, pool_dst, pool_valid, _tree), n_quarantined = \
        _quarantine_pool((state.pool_src, state.pool_dst,
                          state.pool_valid, state.tree_mask), n)
    cleaned = DynamicForest(
        n_nodes=n, parent=state.parent, rep=state.rep, pool_src=pool_src,
        pool_dst=pool_dst, pool_valid=pool_valid,
        tree_mask=jnp.zeros((cap,), jnp.bool_), dirty=state.dirty,
        version=state.version)

    rep, forest_mask, cc_rounds = connected_components(
        live_graph(cleaned), use_kernel=use_kernel)

    # Winner half-edges are canonical (e < capacity), so the undirected
    # tree mask is the first half of forest_mask (forest_from_graph's
    # guarantee, regression-tested on connected_components).
    tree_mask = forest_mask[:cap] & pool_valid

    t = max(n - 1, 1)
    m2 = forest_mask.shape[0]
    slots = jnp.nonzero(forest_mask, size=t, fill_value=m2)[0]
    ok = slots < m2
    safe = jnp.clip(slots, 0, max(m2 - 1, 0))
    lg_src = jnp.concatenate([pool_src, pool_dst])
    lg_dst = jnp.concatenate([pool_dst, pool_src])
    fu = jnp.where(ok, lg_src[safe], n)
    fv = jnp.where(ok, lg_dst[safe], n)
    parent, rank_syncs = euler_tour_root(n, fu, fv, ok, rep,
                                         use_kernel=use_kernel,
                                         return_syncs=True)

    new_state = DynamicForest(
        n_nodes=n, parent=parent, rep=rep, pool_src=pool_src,
        pool_dst=pool_dst, pool_valid=pool_valid, tree_mask=tree_mask,
        dirty=jnp.ones((n,), jnp.bool_),
        version=state.version + 1)
    stats = {"cc_rounds": cc_rounds, "rank_syncs": rank_syncs,
             "quarantined_slots": n_quarantined,
             "sync_total": cc_rounds + rank_syncs}
    return new_state, stats


def rebuild_forest(state: DynamicForest, *, use_kernel: bool = False):
    """From-scratch rebuild: re-derive the forest from the live pool.

    The last rung of the ladder — GConn connectivity + Euler-tour
    rooting over the pool (each component rooted at its GConn
    representative), ignoring the existing parent / rep / tree_mask
    entirely. Everything comes back dirty (the caches must fully
    refresh).

    Returns:
      (state', stats) — ``cc_rounds`` (hook/compress rounds),
      ``rank_syncs`` (list-ranking convergence checks),
      ``quarantined_slots``, and ``sync_total = cc_rounds + rank_syncs``
      (also reported to the ambient ``obs`` ledger under ``rebuild``).
    """
    state, stats = _rebuild(state, use_kernel=use_kernel)
    obs.record("rebuild", lambda: int(stats["sync_total"]))
    return state, stats


def recover(state: DynamicForest, tn=None, bcc=None, *,
            n_jumps: int = DEFAULT_JUMPS, use_kernel: bool = False):
    """Audit and, if needed, repair the forest and heal its caches.

    The full ladder: audit → scoped repair → re-audit → full rebuild →
    final audit (raises ``RuntimeError`` if even the rebuild fails the
    audit — the pool itself must be unusable). Cache healing rides the
    scoped machinery: the repair scope lands in ``state.dirty`` (plus
    any audit-flagged staleness), so the tour refresh is incremental,
    and ``refresh_bcc``'s snapshot diff picks up exactly the repaired
    slots/components. After a full rebuild both caches recompute from
    scratch.

    Args:
      state: the forest to check/repair.
      tn: optional cached ``TourNumbering`` (refreshed and returned).
      bcc: optional cached ``DynamicBCC`` (refreshed and returned).

    Returns:
      (state', tn', bcc', report, info) — ``report`` is the *initial*
      audit; ``info`` is a host-side dict: ``mode`` in
      {"clean", "refresh", "scoped", "full"}, ``n_violating``,
      ``audit_syncs``, the repair/rebuild stats that ran
      (``repair_sync_total`` / ``rebuild_sync_total``), and — for any
      non-clean outcome — the escalation ``reason``.

    When a tracer is installed (``obs.Tracer``), every non-clean pass
    emits structured events: ``audit_violation`` (the failed verdict
    names + violation count) and ``recovery`` (the ladder outcome —
    mode + escalation reason), so a trace file is enough to reconstruct
    the recovery ladder (scripts/chaos_smoke.sh asserts exactly that).
    """
    report = audit_forest(state, tn, bcc, n_jumps=n_jumps)
    info = {"mode": "clean", "n_violating": int(report.n_violating),
            "audit_syncs": int(report.syncs)}
    if bool(report.healthy):
        return state, tn, bcc, report, info

    obs.event("audit_violation", violations=report.violations(),
              n_violating=int(report.n_violating),
              syncs=int(report.syncs))
    if not bool(report.forest_ok):
        viable = bool(_post_sever_acyclic(state, report.sever))
        if viable:
            state, rstats = repair_forest(state, report, n_jumps=n_jumps,
                                          use_kernel=use_kernel)
            info["mode"] = "scoped"
            info["reason"] = "scoped_repair"
            info["repair_sync_total"] = int(rstats["sync_total"])
            info["repaired"] = int(rstats["repaired"])
        if not viable or not bool(
                audit_forest(state, n_jumps=n_jumps).forest_ok):
            state, bstats = rebuild_forest(state, use_kernel=use_kernel)
            info["mode"] = "full"
            info["reason"] = ("sever_insufficient" if not viable
                              else "reaudit_failed")
            info["rebuild_sync_total"] = int(bstats["sync_total"])
            tn = None       # nothing cached survives a full rebuild
            bcc = None
            final = audit_forest(state, n_jumps=n_jumps)
            if not bool(final.forest_ok):
                raise RuntimeError(
                    "unrecoverable: full rebuild still fails the audit: "
                    + final.summary())
    else:
        info["mode"] = "refresh"        # structure fine, caches stale
        info["reason"] = "caches_stale"
    obs.event("recovery", mode=info["mode"], reason=info["reason"],
              n_violating=info["n_violating"])

    # Heal the caches. Staleness beyond the repair scope (a rotted
    # snapshot in an otherwise-clean component) must also land in the
    # dirty mask so the incremental tour refresh recomputes it.
    if tn is not None or bcc is not None:
        if bool(jnp.any(report.stale)):
            state = dataclasses.replace(state,
                                        dirty=state.dirty | report.stale)
    if tn is not None:
        tn, state = refresh_tour(state, tn, use_kernel=use_kernel)
    elif bcc is not None or info["mode"] == "full":
        tn, state = refresh_tour(state, None, use_kernel=use_kernel)
    if bcc is not None or (info["mode"] == "full" and tn is not None):
        bcc = refresh_bcc(state, bcc, tour=tn, use_kernel=use_kernel)
    return state, tn, bcc, report, info
