"""Multi-tenant forest fleet: T session graphs in one process (DESIGN.md §13).

``serve_stream`` drives ONE ``DynamicForest``; the north star is a
process serving many independent session graphs at once. The
``bcc_batch`` vmap pattern (§4) already showed the many-small-graphs
shape pays on this stack — and Hong et al. (PAPERS.md, arxiv 2008.11839)
show fixed-shape batched incremental updates are the right granularity
for GPU connectivity maintenance. This module lifts that pattern from a
single static call to the whole dynamic read/write loop:

  * ``ForestFleet`` stacks T tenant forests array-of-structs: one
    ``parent[T, n]`` (etc.) per field, one shared (n, capacity) schema,
    so every per-tenant array lives in one device buffer and one
    compiled program covers all tenants.
  * ``apply_batches`` applies one fixed-shape ``(T, B)`` event block —
    one vmapped ``edge_slots`` + ``apply_batch`` over the tenant axis.
    Inside, ``apply_batch``'s link ``while_loop`` runs until ALL lanes
    converge; a converged lane's body is a no-op (``link_components``
    with an all-False candidate mask changes nothing), so each tenant's
    result is bit-identical to running it alone (regression-tested in
    tests/test_fleet.py). The fleet's sync bill for a tick is therefore
    ``max_t(rounds_t) + 1`` convergence checks, against the sequential
    loop's ``Σ_t(rounds_t + 1)`` — the §13 amortization headline
    ``benchmarks/table8_fleet.py`` measures.
  * ``refresh_tours`` / ``refresh_bccs`` / ``build_fleet_tables`` vmap
    the §9/§10/§12 cache refreshes the same way; ``FleetQuerySession``
    serves per-tenant reads over the stacked tables with the per-tenant
    staleness policies of §12.
  * ``FleetDispatcher`` (host-side) coalesces each tick's incoming
    events by tenant into the ``(T, B)`` block, sentinel-padding slots
    with no traffic; batch units are atomic (never split or merged), so
    a tenant's applied-batch sequence is exactly its offered sequence.
  * ``FleetManager`` (host-side) admits sessions to slots and evicts
    least-recently-used ones when over capacity, checkpointing the
    evicted forest through the §8 path; re-admission restores it (and
    its stream cursor) bit-identically.

``launch.serve_fleet`` wires all of it behind the ``ServeConfig`` +
``FleetConfig`` CLI.
"""
from __future__ import annotations

import collections
import dataclasses
import pathlib
from functools import partial
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import queries as q
from repro.core.compress import DEFAULT_JUMPS
from repro.core.euler import TourNumbering, tour_numbering
from repro.core.queries import QueryTables, build_tables
from repro.data.streams import StreamBatch
from repro.dynamic.bcc import (DynamicBCC, _refresh_full,
                               _refresh_incremental)
from repro.dynamic.forest import (DynamicForest, apply_batch, edge_slots,
                                  forest_empty)
from repro.dynamic.queries import POLICIES, StaleQueryError
from repro.train import checkpoint as ckpt


def tenant_slice(tree, t: int):
    """Slice tenant ``t`` out of any stacked-leading-axis pytree."""
    return jax.tree_util.tree_map(lambda x: x[t], tree)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class ForestFleet:
    """T tenant forests, array-of-structs, one shared capacity schema.

    Every leaf is the single-tenant ``DynamicForest`` leaf with a
    leading tenant axis (``parent[T, n]``, ``pool_src[T, C]``, ...),
    plus ``active[T]`` marking occupied slots. An inactive slot holds an
    edgeless forest; vmapped updates still run over it (sentinel events
    on an empty forest are no-ops), keeping every program fixed-shape.
    """

    n_nodes: int
    parent: jnp.ndarray
    rep: jnp.ndarray
    pool_src: jnp.ndarray
    pool_dst: jnp.ndarray
    pool_valid: jnp.ndarray
    tree_mask: jnp.ndarray
    dirty: jnp.ndarray
    version: jnp.ndarray
    active: jnp.ndarray

    def tree_flatten(self):
        return ((self.parent, self.rep, self.pool_src, self.pool_dst,
                 self.pool_valid, self.tree_mask, self.dirty, self.version,
                 self.active), self.n_nodes)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(aux, *children)

    @property
    def n_slots(self) -> int:
        return int(self.parent.shape[0])

    @property
    def capacity(self) -> int:
        return int(self.pool_src.shape[1])

    # -- single-tenant views -------------------------------------------------

    def as_forest(self) -> DynamicForest:
        """The stacked leaves as one ``DynamicForest`` pytree — the
        vmap carrier (aux ``n_nodes`` is shared by every lane)."""
        return DynamicForest(
            n_nodes=self.n_nodes, parent=self.parent, rep=self.rep,
            pool_src=self.pool_src, pool_dst=self.pool_dst,
            pool_valid=self.pool_valid, tree_mask=self.tree_mask,
            dirty=self.dirty, version=self.version)

    def with_forest(self, forest: DynamicForest) -> "ForestFleet":
        """Re-wrap vmapped-update output, keeping the activity mask."""
        return ForestFleet(
            n_nodes=self.n_nodes, parent=forest.parent, rep=forest.rep,
            pool_src=forest.pool_src, pool_dst=forest.pool_dst,
            pool_valid=forest.pool_valid, tree_mask=forest.tree_mask,
            dirty=forest.dirty, version=forest.version, active=self.active)

    def tenant(self, t: int) -> DynamicForest:
        """Tenant ``t``'s forest, as a standalone ``DynamicForest``."""
        return tenant_slice(self.as_forest(), t)

    def set_tenant(self, t: int, forest: DynamicForest) -> "ForestFleet":
        """Install ``forest`` in slot ``t`` (marks it active)."""
        if forest.n_nodes != self.n_nodes:
            raise ValueError(f"tenant n_nodes {forest.n_nodes} != fleet "
                             f"{self.n_nodes}")
        if forest.capacity != self.capacity:
            raise ValueError(f"tenant capacity {forest.capacity} != fleet "
                             f"schema {self.capacity} (one shared "
                             "capacity per fleet)")
        stacked = jax.tree_util.tree_map(
            lambda full, new: full.at[t].set(new),
            self.as_forest(),
            DynamicForest(n_nodes=self.n_nodes,
                          **{f: getattr(forest, f) for f in (
                              "parent", "rep", "pool_src", "pool_dst",
                              "pool_valid", "tree_mask", "dirty",
                              "version")}))
        out = self.with_forest(stacked)
        return dataclasses.replace(out, active=out.active.at[t].set(True))

    def clear_tenant(self, t: int) -> "ForestFleet":
        """Reset slot ``t`` to an edgeless forest (marks it inactive)."""
        out = self.set_tenant(t, forest_empty(self.n_nodes, self.capacity))
        return dataclasses.replace(out, active=out.active.at[t].set(False))


def fleet_empty(n_slots: int, n_nodes: int, capacity: int) -> ForestFleet:
    """A fleet of T inactive, edgeless slots."""
    one = forest_empty(n_nodes, capacity)
    stacked = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (n_slots,) + x.shape), one)
    return ForestFleet(
        n_nodes=n_nodes, parent=stacked.parent, rep=stacked.rep,
        pool_src=stacked.pool_src, pool_dst=stacked.pool_dst,
        pool_valid=stacked.pool_valid, tree_mask=stacked.tree_mask,
        dirty=stacked.dirty, version=stacked.version,
        active=jnp.zeros((n_slots,), jnp.bool_))


# -- the vmapped write path ---------------------------------------------------

def _replay_one(forest: DynamicForest, ins_u, ins_v, del_u, del_v, *,
                n_jumps: int, use_kernel: bool):
    dmask, found = edge_slots(forest, del_u, del_v)
    forest, stats = apply_batch(forest, ins_u, ins_v, dmask,
                                n_jumps=n_jumps, use_kernel=use_kernel)
    stats["deletes_found"] = jnp.sum(found.astype(jnp.int32))
    return forest, stats


@partial(jax.jit, static_argnames=("n_jumps", "use_kernel"))
def _apply_batches(fleet: ForestFleet, ins_u: jnp.ndarray,
                   ins_v: jnp.ndarray, del_u: jnp.ndarray,
                   del_v: jnp.ndarray, *, n_jumps: int = DEFAULT_JUMPS,
                   use_kernel: bool = False):
    fn = partial(_replay_one, n_jumps=n_jumps, use_kernel=use_kernel)
    forest, stats = jax.vmap(fn)(fleet.as_forest(), ins_u, ins_v,
                                 del_u, del_v)
    return fleet.with_forest(forest), stats


def apply_batches(fleet: ForestFleet, ins_u: jnp.ndarray,
                  ins_v: jnp.ndarray, del_u: jnp.ndarray,
                  del_v: jnp.ndarray, *, n_jumps: int = DEFAULT_JUMPS,
                  use_kernel: bool = False):
    """Apply one ``(T, B)`` event block: one vmapped §9 batch per tenant.

    Args:
      ins_u, ins_v: int32[T, B] per-tenant insertions (``n_nodes``
        sentinel pads inactive slots — inert, like any padded event).
      del_u, del_v: int32[T, D] per-tenant deletion pairs (``edge_slots``
        resolves them to pool slots lane-wise).

    Returns:
      (fleet', stats) — stats maps the ``apply_batch`` counters (plus
      ``deletes_found``) to int32[T] arrays. The vmapped link loop runs
      ``max_t(rounds_t)`` productive rounds; each lane's result is
      bit-identical to applying its batch alone.

    Host wrapper over the jitted block apply: reports the tick's sync
    bill (``fleet_sync_cost``) to the ambient ``obs`` ledger under the
    ``fleet_apply`` phase.
    """
    fleet, stats = _apply_batches(fleet, ins_u, ins_v, del_u, del_v,
                                  n_jumps=n_jumps, use_kernel=use_kernel)
    obs.record("fleet_apply", lambda: fleet_sync_cost(stats))
    return fleet, stats


def fleet_sync_cost(stats) -> int:
    """Convergence checks one ``apply_batches`` tick paid: the vmapped
    link loop trips ``max_t(rounds_t)`` times plus the final all-lanes
    check — versus ``Σ_t(rounds_t + 1)`` for T sequential calls."""
    return int(jnp.max(stats["rounds"])) + 1


# -- vmapped cache refreshes (§9 tour, §10 BCC, §12 tables) -------------------

def refresh_tours(fleet: ForestFleet, cached: TourNumbering | None = None,
                  *, incremental: bool = True, use_kernel: bool = False):
    """Vmapped ``refresh_tour`` over the fleet.

    ``cached`` is the stacked numbering from the previous call (lane t
    of the result is bit-identical to single-tenant ``refresh_tour`` on
    tenant t). Returns ``(numbering[T], fleet')`` with all dirty masks
    cleared. Reports the vmapped refresh's sync bill (max over lanes —
    the loops run lockstep until every lane converges) to the ambient
    ``obs`` ledger under ``fleet_refresh_tour``.
    """
    from repro.dynamic.tour import _merge_dirty

    if cached is None or not incremental:
        tn, syncs = jax.vmap(lambda p: tour_numbering(
            p, use_kernel=use_kernel, return_syncs=True))(fleet.parent)
    else:
        tn, syncs = jax.vmap(lambda p, r, d, c: _merge_dirty(
            p, r, d, c, use_kernel=use_kernel, return_syncs=True))(
                fleet.parent, fleet.rep, fleet.dirty, cached)
    obs.record("fleet_refresh_tour", lambda: int(jnp.max(syncs)))
    return tn, dataclasses.replace(
        fleet, dirty=jnp.zeros_like(fleet.dirty))


def refresh_bccs(fleet: ForestFleet, cached: DynamicBCC | None = None, *,
                 tour: TourNumbering, incremental: bool = True,
                 use_kernel: bool = False) -> DynamicBCC:
    """Vmapped ``refresh_bcc`` over the fleet (stacked ``DynamicBCC``).

    Reports the refresh's sync bill (max over lanes of
    ``seg_syncs + aux_rounds``) to the ambient ``obs`` ledger under
    ``fleet_refresh_bcc``.
    """
    forest = fleet.as_forest()
    if cached is None or not incremental:
        bcc = jax.vmap(lambda f, t: _refresh_full(
            f, t, use_kernel=use_kernel))(forest, tour)
    else:
        bcc = jax.vmap(lambda f, t, c: _refresh_incremental(
            f, t, c, use_kernel=use_kernel))(forest, tour, cached)
    obs.record("fleet_refresh_bcc",
               lambda: int(jnp.max(bcc.seg_syncs + bcc.aux_rounds)))
    return bcc


def build_fleet_tables(tn: TourNumbering, *,
                       n_jumps: int = DEFAULT_JUMPS) -> QueryTables:
    """Vmapped §12 ``build_tables``: one stacked query index, built in
    one program (``build_syncs`` is per-tenant, int32[T]).

    Vmaps the jitted ``_build_tables`` (the host-recording wrapper
    cannot be vmapped) and reports the stacked build's sync bill (max
    over lanes) to the ambient ``obs`` ledger under ``fleet_tables``.
    """
    from repro.core.queries import _build_tables

    tables = jax.vmap(lambda t: _build_tables(t, n_jumps=n_jumps))(tn)
    obs.record("fleet_tables", lambda: int(jnp.max(tables.build_syncs)))
    return tables


# -- per-tenant read sessions over the stacked tables -------------------------

def _i32(x) -> jnp.ndarray:
    return jnp.atleast_1d(jnp.asarray(x, jnp.int32))


@dataclasses.dataclass
class FleetQuerySession:
    """Version-stamped read views over every fleet slot (§12, fleet-wide).

    One stacked ``QueryTables`` (built by ``build_fleet_tables`` — all
    tenants in one vmapped program), per-tenant version stamps, and a
    per-tenant staleness policy. Query methods take ``(fleet, t, ...)``;
    the staleness gate is per call and per tenant:

      * ``strict``  — raise ``StaleQueryError``;
      * ``refresh`` — rebuild ONLY tenant t's slice of the stacked
        tables (a single-lane tour + ``build_tables``), then answer;
      * ``stale``   — serve the frozen slice and count it.
    """

    tables: QueryTables                  # stacked [T, ...]
    bcc: DynamicBCC | None               # stacked, optional
    versions: np.ndarray                 # int64[T] stamped fleet versions
    policies: tuple[str, ...]
    use_kernel: bool = False
    n_jumps: int = DEFAULT_JUMPS
    # per-tenant telemetry (host-side)
    builds: np.ndarray = None
    build_syncs_total: np.ndarray = None
    stale_served: np.ndarray = None
    auto_refreshes: np.ndarray = None

    @classmethod
    def from_fleet(cls, fleet: ForestFleet,
                   tn: TourNumbering | None = None,
                   bcc: DynamicBCC | None = None, *,
                   policy: str | Sequence[str] = "strict",
                   use_kernel: bool = False,
                   n_jumps: int = DEFAULT_JUMPS) -> "FleetQuerySession":
        t_slots = fleet.n_slots
        if isinstance(policy, str):
            policies = (policy,) * t_slots
        else:
            policies = tuple(policy)
        if len(policies) != t_slots:
            raise ValueError(f"{len(policies)} policies for {t_slots} slots")
        for p in policies:
            if p not in POLICIES:
                raise ValueError(f"policy {p!r} not in {POLICIES}")
        if tn is None:
            tn, _ = refresh_tours(fleet, incremental=False,
                                  use_kernel=use_kernel)
        tables = build_fleet_tables(tn, n_jumps=n_jumps)
        sess = cls(tables=tables, bcc=bcc,
                   versions=np.asarray(fleet.version, np.int64).copy(),
                   policies=policies, use_kernel=use_kernel,
                   n_jumps=n_jumps,
                   builds=np.ones(t_slots, np.int64),
                   build_syncs_total=np.asarray(tables.build_syncs,
                                                np.int64).copy(),
                   stale_served=np.zeros(t_slots, np.int64),
                   auto_refreshes=np.zeros(t_slots, np.int64))
        return sess

    # -- lifecycle -----------------------------------------------------------

    def rebuild_tenant(self, fleet: ForestFleet, t: int) -> None:
        """Re-index ONE tenant: single-lane tour + tables, scattered
        into the stacked index with ``.at[t].set`` (other lanes frozen)."""
        tn_t, tn_syncs = tour_numbering(fleet.parent[t],
                                        use_kernel=self.use_kernel,
                                        return_syncs=True)
        obs.record("refresh_tour", tn_syncs, tenant=t)
        tab_t = build_tables(tn_t, n_jumps=self.n_jumps)
        self.tables = jax.tree_util.tree_map(
            lambda full, new: full.at[t].set(new), self.tables, tab_t)
        if self.bcc is not None:
            bcc_t = _refresh_full(fleet.tenant(t), tn_t,
                                  use_kernel=self.use_kernel)
            self.bcc = jax.tree_util.tree_map(
                lambda full, new: full.at[t].set(new), self.bcc, bcc_t)
        self.versions[t] = int(fleet.version[t])
        self.builds[t] += 1
        self.build_syncs_total[t] += int(tab_t.build_syncs)

    def restamp(self, fleet: ForestFleet, tn: TourNumbering,
                bcc: DynamicBCC | None = None) -> None:
        """Adopt freshly vmapped caches for the whole fleet (the cadence
        path: the serving loop refreshed every lane in one program)."""
        self.tables = build_fleet_tables(tn, n_jumps=self.n_jumps)
        self.bcc = bcc
        self.versions = np.asarray(fleet.version, np.int64).copy()
        self.builds += 1
        self.build_syncs_total += np.asarray(self.tables.build_syncs,
                                             np.int64)

    def is_fresh(self, fleet: ForestFleet, t: int) -> bool:
        return int(fleet.version[t]) == int(self.versions[t])

    def ensure(self, fleet: ForestFleet, t: int) -> None:
        if self.is_fresh(fleet, t):
            return
        policy = self.policies[t]
        if policy == "stale":
            self.stale_served[t] += 1
            return
        if policy == "strict":
            raise StaleQueryError(
                f"tenant {t} at version {int(fleet.version[t])}, session "
                f"slice stamped {int(self.versions[t])}: refresh the "
                "fleet caches first (or use policy='refresh' / 'stale')")
        self.auto_refreshes[t] += 1
        self.rebuild_tenant(fleet, t)

    # -- per-tenant query ops (gathers over one slice of the stack) ----------

    def _tab(self, t: int) -> QueryTables:
        return tenant_slice(self.tables, t)

    def connected(self, fleet, t: int, u, v) -> jnp.ndarray:
        self.ensure(fleet, t)
        return q.connected(self._tab(t), _i32(u), _i32(v))

    def depth(self, fleet, t: int, v) -> jnp.ndarray:
        self.ensure(fleet, t)
        return q.depth_of(self._tab(t), _i32(v))

    def lca(self, fleet, t: int, u, v) -> jnp.ndarray:
        self.ensure(fleet, t)
        return q.lca(self._tab(t), _i32(u), _i32(v))

    def is_ancestor(self, fleet, t: int, a, x) -> jnp.ndarray:
        self.ensure(fleet, t)
        return q.is_ancestor(self._tab(t), _i32(a), _i32(x))

    def is_bridge(self, fleet, t: int, u, v) -> jnp.ndarray:
        self.ensure(fleet, t)
        if self.bcc is None:
            raise ValueError("session built without biconnectivity labels "
                             "— pass bcc=refresh_bccs(...) to from_fleet")
        b = tenant_slice(self.bcc, t)
        cap = b.pool_src.shape[0]
        _hit, flagged = q.edge_membership(
            _i32(u), _i32(v), b.pool_src, b.pool_dst, b.pool_valid,
            b.bridge[:cap])
        return flagged

    def is_articulation(self, fleet, t: int, v) -> jnp.ndarray:
        self.ensure(fleet, t)
        if self.bcc is None:
            raise ValueError("session built without biconnectivity labels "
                             "— pass bcc=refresh_bccs(...) to from_fleet")
        b = tenant_slice(self.bcc, t)
        vq = _i32(v)
        n = b.articulation.shape[0]
        return ((vq >= 0) & (vq < n)
                & b.articulation[jnp.clip(vq, 0, n - 1)])

    # -- telemetry -----------------------------------------------------------

    def sync_stats(self, t: int | None = None) -> dict:
        """§12 amortization counters — one tenant's, or fleet totals."""
        pick = (lambda a: int(a[t])) if t is not None else \
            (lambda a: int(a.sum()))
        return {"builds": pick(self.builds),
                "build_syncs_total": pick(self.build_syncs_total),
                "stale_served": pick(self.stale_served),
                "auto_refreshes": pick(self.auto_refreshes)}


# -- host-side dispatch + admission -------------------------------------------

class FleetDispatcher:
    """Coalesces incoming per-tenant batches into ``(T, B)`` tick blocks.

    Host-side. Tenants ``offer`` fixed-shape ``StreamBatch`` units (the
    §9 contract: sentinel-padded, one shape per stream); each ``tick``
    pops AT MOST ONE unit per resident tenant — units are atomic, never
    split across ticks or merged within one, so every tenant's applied
    sequence equals its offered sequence (the fleet-replay equivalence
    invariant). Slots with no resident or no queued unit get all-sentinel
    rows: inert under ``apply_batches`` except the unconditional version
    bump, which the admission checkpoint path hides (an evicted tenant's
    clock restarts from its restored stamp).
    """

    def __init__(self, n_nodes: int, batch: int):
        self.n_nodes = int(n_nodes)
        self.batch = int(batch)
        self.queues: dict[Any, collections.deque] = \
            collections.defaultdict(collections.deque)
        self.offered = collections.Counter()
        self.served = collections.Counter()

    def offer(self, tenant, b: StreamBatch) -> None:
        for arr in (b.ins_u, b.ins_v, b.del_u, b.del_v):
            if arr.shape != (self.batch,):
                raise ValueError(
                    f"batch unit shape {arr.shape} != ({self.batch},) — "
                    "the fleet block is fixed-shape; regenerate the "
                    "stream with the fleet's batch size")
        self.queues[tenant].append(b)
        self.offered[tenant] += 1

    def pending(self, tenant=None) -> int:
        if tenant is not None:
            return len(self.queues[tenant])
        return sum(len(d) for d in self.queues.values())

    def tick(self, tenant_at: Sequence[Any]):
        """Build one tick block for the current residency map.

        Args:
          tenant_at: per-slot resident tenant id (``None`` = empty slot).

        Returns:
          ((ins_u, ins_v, del_u, del_v) int32[T, B] device arrays,
           served: {tenant: event count} for the units dispatched).
        """
        t_slots, n, b = len(tenant_at), self.n_nodes, self.batch
        ins_u = np.full((t_slots, b), n, np.int32)
        ins_v = np.full((t_slots, b), n, np.int32)
        del_u = np.full((t_slots, b), n, np.int32)
        del_v = np.full((t_slots, b), n, np.int32)
        served: dict[Any, int] = {}
        for s, tenant in enumerate(tenant_at):
            if tenant is None or not self.queues[tenant]:
                continue
            unit = self.queues[tenant].popleft()
            ins_u[s], ins_v[s] = unit.ins_u, unit.ins_v
            del_u[s], del_v[s] = unit.del_u, unit.del_v
            served[tenant] = int((unit.ins_u < n).sum()
                                 + (unit.del_u < n).sum())
            self.served[tenant] += 1
        return ((jnp.asarray(ins_u), jnp.asarray(ins_v),
                 jnp.asarray(del_u), jnp.asarray(del_v)), served)


class FleetManager:
    """Session admission/eviction against the fleet's slot capacity.

    Host-side bookkeeping around a ``ForestFleet``: which tenant lives
    in which slot, LRU order, and per-tenant stream cursors. When every
    slot is occupied, ``ensure`` evicts the least-recently-used resident
    through the §8 checkpoint path (forest + cursor, atomic publish);
    re-admission restores bit-identically — eviction is invisible to the
    tenant's replayed history (regression-tested).
    """

    def __init__(self, fleet: ForestFleet, ckpt_dir: str | pathlib.Path):
        self.fleet = fleet
        self.ckpt_dir = pathlib.Path(ckpt_dir)
        self.slot_of: dict[Any, int] = {}
        self.tenant_at: list[Any] = [None] * fleet.n_slots
        self.last_used = [-1] * fleet.n_slots
        self.clock = 0
        self.cursors = collections.Counter()   # tenant → applied batches
        self.admissions = 0
        self.evictions = 0
        self.restores = 0

    def _tenant_dir(self, tenant) -> pathlib.Path:
        return self.ckpt_dir / f"tenant_{tenant}"

    def touch(self, tenant) -> None:
        self.clock += 1
        self.last_used[self.slot_of[tenant]] = self.clock

    def ensure(self, tenant) -> int:
        """Make ``tenant`` resident; returns its slot (LRU-evicting if
        the fleet is full)."""
        if tenant in self.slot_of:
            self.touch(tenant)
            return self.slot_of[tenant]
        free = [s for s, occupant in enumerate(self.tenant_at)
                if occupant is None]
        if free:
            slot = free[0]
        else:
            slot = min(range(len(self.last_used)),
                       key=lambda s: self.last_used[s])
            self.evict(self.tenant_at[slot])
        self._admit(tenant, slot)
        return slot

    def evict(self, tenant) -> None:
        """Checkpoint ``tenant``'s forest + cursor and free its slot."""
        slot = self.slot_of.pop(tenant)
        ckpt.save(self._tenant_dir(tenant),
                  {"forest": self.fleet.tenant(slot)},
                  step=self.clock, data_cursor=int(self.cursors[tenant]),
                  keep=1)
        self.fleet = self.fleet.clear_tenant(slot)
        self.tenant_at[slot] = None
        self.last_used[slot] = -1
        self.evictions += 1

    def _admit(self, tenant, slot: int) -> None:
        fresh = {"forest": forest_empty(self.fleet.n_nodes,
                                        self.fleet.capacity)}
        if ckpt.latest_step(self._tenant_dir(tenant)) is not None:
            restored, manifest = ckpt.restore(self._tenant_dir(tenant),
                                              fresh)
            self.cursors[tenant] = int(manifest["data_cursor"])
            forest = restored["forest"]
            self.restores += 1
        else:
            forest = fresh["forest"]
        self.fleet = self.fleet.set_tenant(slot, forest)
        self.slot_of[tenant] = slot
        self.tenant_at[slot] = tenant
        self.admissions += 1
        self.touch(tenant)

    def note_applied(self, served: dict) -> None:
        """Advance stream cursors after a tick (one unit per tenant)."""
        for tenant in served:
            self.cursors[tenant] += 1
