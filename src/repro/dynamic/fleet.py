"""Multi-tenant forest fleet: T session graphs in one process (DESIGN.md §13).

``serve_stream`` drives ONE ``DynamicForest``; the north star is a
process serving many independent session graphs at once. The
``bcc_batch`` vmap pattern (§4) already showed the many-small-graphs
shape pays on this stack — and Hong et al. (PAPERS.md, arxiv 2008.11839)
show fixed-shape batched incremental updates are the right granularity
for GPU connectivity maintenance. This module lifts that pattern from a
single static call to the whole dynamic read/write loop:

  * ``ForestFleet`` stacks T tenant forests array-of-structs: one
    ``parent[T, n]`` (etc.) per field, one shared (n, capacity) schema,
    so every per-tenant array lives in one device buffer and one
    compiled program covers all tenants.
  * ``apply_batches`` applies one fixed-shape ``(T, B)`` event block —
    one vmapped ``edge_slots`` + ``apply_batch`` over the tenant axis.
    Inside, ``apply_batch``'s link ``while_loop`` runs until ALL lanes
    converge; a converged lane's body is a no-op (``link_components``
    with an all-False candidate mask changes nothing), so each tenant's
    result is bit-identical to running it alone (regression-tested in
    tests/test_fleet.py). The fleet's sync bill for a tick is therefore
    ``max_t(rounds_t) + 1`` convergence checks, against the sequential
    loop's ``Σ_t(rounds_t + 1)`` — the §13 amortization headline
    ``benchmarks/table8_fleet.py`` measures.
  * ``refresh_tours`` / ``refresh_bccs`` / ``build_fleet_tables`` vmap
    the §9/§10/§12 cache refreshes the same way; ``FleetQuerySession``
    serves per-tenant reads over the stacked tables with the per-tenant
    staleness policies of §12.
  * ``FleetDispatcher`` (host-side) coalesces each tick's incoming
    events by tenant into the ``(T, B)`` block, sentinel-padding slots
    with no traffic; batch units are atomic (never split or merged), so
    a tenant's applied-batch sequence is exactly its offered sequence.
  * ``FleetManager`` (host-side) admits sessions to slots and evicts
    least-recently-used ones when over capacity, checkpointing the
    evicted forest through the §8 path; re-admission restores it (and
    its stream cursor) bit-identically.

``launch.serve_fleet`` wires all of it behind the ``ServeConfig`` +
``FleetConfig`` CLI.
"""
from __future__ import annotations

import collections
import concurrent.futures
import dataclasses
import pathlib
from functools import partial
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import queries as q
from repro.core.compress import DEFAULT_JUMPS
from repro.core.euler import TourNumbering, tour_numbering
from repro.core.queries import QueryTables, build_tables
from repro.data.streams import StreamBatch
from repro.dynamic.bcc import (DynamicBCC, _refresh_full,
                               _refresh_incremental)
from repro.dynamic.forest import (DynamicForest, apply_batch, edge_slots,
                                  forest_empty)
from repro.dynamic.queries import POLICIES, StaleQueryError
from repro.dynamic.view import CadencePolicy
from repro.train import checkpoint as ckpt


def tenant_slice(tree, t: int):
    """Slice tenant ``t`` out of any stacked-leading-axis pytree."""
    return jax.tree_util.tree_map(lambda x: x[t], tree)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class ForestFleet:
    """T tenant forests, array-of-structs, one shared capacity schema.

    Every leaf is the single-tenant ``DynamicForest`` leaf with a
    leading tenant axis (``parent[T, n]``, ``pool_src[T, C]``, ...),
    plus ``active[T]`` marking occupied slots. An inactive slot holds an
    edgeless forest; vmapped updates still run over it (sentinel events
    on an empty forest are no-ops), keeping every program fixed-shape.
    """

    n_nodes: int
    parent: jnp.ndarray
    rep: jnp.ndarray
    pool_src: jnp.ndarray
    pool_dst: jnp.ndarray
    pool_valid: jnp.ndarray
    tree_mask: jnp.ndarray
    dirty: jnp.ndarray
    version: jnp.ndarray
    active: jnp.ndarray

    def tree_flatten(self):
        return ((self.parent, self.rep, self.pool_src, self.pool_dst,
                 self.pool_valid, self.tree_mask, self.dirty, self.version,
                 self.active), self.n_nodes)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(aux, *children)

    @property
    def n_slots(self) -> int:
        return int(self.parent.shape[0])

    @property
    def capacity(self) -> int:
        return int(self.pool_src.shape[1])

    # -- single-tenant views -------------------------------------------------

    def as_forest(self) -> DynamicForest:
        """The stacked leaves as one ``DynamicForest`` pytree — the
        vmap carrier (aux ``n_nodes`` is shared by every lane)."""
        return DynamicForest(
            n_nodes=self.n_nodes, parent=self.parent, rep=self.rep,
            pool_src=self.pool_src, pool_dst=self.pool_dst,
            pool_valid=self.pool_valid, tree_mask=self.tree_mask,
            dirty=self.dirty, version=self.version)

    def with_forest(self, forest: DynamicForest) -> "ForestFleet":
        """Re-wrap vmapped-update output, keeping the activity mask."""
        return ForestFleet(
            n_nodes=self.n_nodes, parent=forest.parent, rep=forest.rep,
            pool_src=forest.pool_src, pool_dst=forest.pool_dst,
            pool_valid=forest.pool_valid, tree_mask=forest.tree_mask,
            dirty=forest.dirty, version=forest.version, active=self.active)

    def tenant(self, t: int) -> DynamicForest:
        """Tenant ``t``'s forest, as a standalone ``DynamicForest``."""
        return tenant_slice(self.as_forest(), t)

    def set_tenant(self, t: int, forest: DynamicForest) -> "ForestFleet":
        """Install ``forest`` in slot ``t`` (marks it active)."""
        if forest.n_nodes != self.n_nodes:
            raise ValueError(f"tenant n_nodes {forest.n_nodes} != fleet "
                             f"{self.n_nodes}")
        if forest.capacity != self.capacity:
            raise ValueError(f"tenant capacity {forest.capacity} != fleet "
                             f"schema {self.capacity} (one shared "
                             "capacity per fleet)")
        stacked = jax.tree_util.tree_map(
            lambda full, new: full.at[t].set(new),
            self.as_forest(),
            DynamicForest(n_nodes=self.n_nodes,
                          **{f: getattr(forest, f) for f in (
                              "parent", "rep", "pool_src", "pool_dst",
                              "pool_valid", "tree_mask", "dirty",
                              "version")}))
        out = self.with_forest(stacked)
        return dataclasses.replace(out, active=out.active.at[t].set(True))

    def clear_tenant(self, t: int) -> "ForestFleet":
        """Reset slot ``t`` to an edgeless forest (marks it inactive)."""
        out = self.set_tenant(t, forest_empty(self.n_nodes, self.capacity))
        return dataclasses.replace(out, active=out.active.at[t].set(False))


def fleet_empty(n_slots: int, n_nodes: int, capacity: int) -> ForestFleet:
    """A fleet of T inactive, edgeless slots."""
    one = forest_empty(n_nodes, capacity)
    stacked = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (n_slots,) + x.shape), one)
    return ForestFleet(
        n_nodes=n_nodes, parent=stacked.parent, rep=stacked.rep,
        pool_src=stacked.pool_src, pool_dst=stacked.pool_dst,
        pool_valid=stacked.pool_valid, tree_mask=stacked.tree_mask,
        dirty=stacked.dirty, version=stacked.version,
        active=jnp.zeros((n_slots,), jnp.bool_))


# -- the vmapped write path ---------------------------------------------------

def _replay_one(forest: DynamicForest, ins_u, ins_v, del_u, del_v, *,
                n_jumps: int, use_kernel: bool):
    dmask, found = edge_slots(forest, del_u, del_v)
    forest, stats = apply_batch(forest, ins_u, ins_v, dmask,
                                n_jumps=n_jumps, use_kernel=use_kernel)
    stats["deletes_found"] = jnp.sum(found.astype(jnp.int32))
    return forest, stats


@partial(jax.jit, static_argnames=("n_jumps", "use_kernel"))
def _apply_batches(fleet: ForestFleet, ins_u: jnp.ndarray,
                   ins_v: jnp.ndarray, del_u: jnp.ndarray,
                   del_v: jnp.ndarray, *, n_jumps: int = DEFAULT_JUMPS,
                   use_kernel: bool = False):
    fn = partial(_replay_one, n_jumps=n_jumps, use_kernel=use_kernel)
    forest, stats = jax.vmap(fn)(fleet.as_forest(), ins_u, ins_v,
                                 del_u, del_v)
    return fleet.with_forest(forest), stats


def apply_batches(fleet: ForestFleet, ins_u: jnp.ndarray,
                  ins_v: jnp.ndarray, del_u: jnp.ndarray,
                  del_v: jnp.ndarray, *, n_jumps: int = DEFAULT_JUMPS,
                  use_kernel: bool = False, bucket: str | None = None):
    """Apply one ``(T, B)`` event block: one vmapped §9 batch per tenant.

    Args:
      ins_u, ins_v: int32[T, B] per-tenant insertions (``n_nodes``
        sentinel pads inactive slots — inert, like any padded event).
      del_u, del_v: int32[T, D] per-tenant deletion pairs (``edge_slots``
        resolves them to pool slots lane-wise).

    Returns:
      (fleet', stats) — stats maps the ``apply_batch`` counters (plus
      ``deletes_found``) to int32[T] arrays. The vmapped link loop runs
      ``max_t(rounds_t)`` productive rounds; each lane's result is
      bit-identical to applying its batch alone.

    Host wrapper over the jitted block apply: reports the tick's sync
    bill (``fleet_sync_cost``) to the ambient ``obs`` ledger under the
    ``fleet_apply`` phase (labeled with the sub-fleet ``bucket`` when
    one is ticking, §15).
    """
    fleet, stats = _apply_batches(fleet, ins_u, ins_v, del_u, del_v,
                                  n_jumps=n_jumps, use_kernel=use_kernel)
    obs.record("fleet_apply", lambda: fleet_sync_cost(stats),
               bucket=bucket)
    return fleet, stats


def fleet_sync_cost(stats) -> int:
    """Convergence checks one ``apply_batches`` tick paid: the vmapped
    link loop trips ``max_t(rounds_t)`` times plus the final all-lanes
    check — versus ``Σ_t(rounds_t + 1)`` for T sequential calls."""
    return int(jnp.max(stats["rounds"])) + 1


# -- vmapped cache refreshes (§9 tour, §10 BCC, §12 tables) -------------------

def refresh_tours(fleet: ForestFleet, cached: TourNumbering | None = None,
                  *, incremental: bool = True, use_kernel: bool = False,
                  bucket: str | None = None):
    """Vmapped ``refresh_tour`` over the fleet.

    ``cached`` is the stacked numbering from the previous call (lane t
    of the result is bit-identical to single-tenant ``refresh_tour`` on
    tenant t). Returns ``(numbering[T], fleet')`` with all dirty masks
    cleared. Reports the vmapped refresh's sync bill (max over lanes —
    the loops run lockstep until every lane converges) to the ambient
    ``obs`` ledger under ``fleet_refresh_tour``.
    """
    from repro.dynamic.tour import _merge_dirty

    if cached is None or not incremental:
        tn, syncs = jax.vmap(lambda p: tour_numbering(
            p, use_kernel=use_kernel, return_syncs=True))(fleet.parent)
    else:
        tn, syncs = jax.vmap(lambda p, r, d, c: _merge_dirty(
            p, r, d, c, use_kernel=use_kernel, return_syncs=True))(
                fleet.parent, fleet.rep, fleet.dirty, cached)
    obs.record("fleet_refresh_tour", lambda: int(jnp.max(syncs)),
               bucket=bucket)
    return tn, dataclasses.replace(
        fleet, dirty=jnp.zeros_like(fleet.dirty))


def refresh_bccs(fleet: ForestFleet, cached: DynamicBCC | None = None, *,
                 tour: TourNumbering, incremental: bool = True,
                 use_kernel: bool = False,
                 bucket: str | None = None) -> DynamicBCC:
    """Vmapped ``refresh_bcc`` over the fleet (stacked ``DynamicBCC``).

    Reports the refresh's sync bill (max over lanes of
    ``seg_syncs + aux_rounds``) to the ambient ``obs`` ledger under
    ``fleet_refresh_bcc``.
    """
    forest = fleet.as_forest()
    if cached is None or not incremental:
        bcc = jax.vmap(lambda f, t: _refresh_full(
            f, t, use_kernel=use_kernel))(forest, tour)
    else:
        bcc = jax.vmap(lambda f, t, c: _refresh_incremental(
            f, t, c, use_kernel=use_kernel))(forest, tour, cached)
    obs.record("fleet_refresh_bcc",
               lambda: int(jnp.max(bcc.seg_syncs + bcc.aux_rounds)),
               bucket=bucket)
    return bcc


def build_fleet_tables(tn: TourNumbering, *, n_jumps: int = DEFAULT_JUMPS,
                       bucket: str | None = None) -> QueryTables:
    """Vmapped §12 ``build_tables``: one stacked query index, built in
    one program (``build_syncs`` is per-tenant, int32[T]).

    Vmaps the jitted ``_build_tables`` (the host-recording wrapper
    cannot be vmapped) and reports the stacked build's sync bill (max
    over lanes) to the ambient ``obs`` ledger under ``fleet_tables``.
    """
    from repro.core.queries import _build_tables

    tables = jax.vmap(lambda t: _build_tables(t, n_jumps=n_jumps))(tn)
    obs.record("fleet_tables", lambda: int(jnp.max(tables.build_syncs)),
               bucket=bucket)
    return tables


# -- per-tenant read sessions over the stacked tables -------------------------

def _i32(x) -> jnp.ndarray:
    return jnp.atleast_1d(jnp.asarray(x, jnp.int32))


@dataclasses.dataclass
class FleetQuerySession:
    """Version-stamped read views over every fleet slot (§12, fleet-wide).

    One stacked ``QueryTables`` (built by ``build_fleet_tables`` — all
    tenants in one vmapped program), per-tenant version stamps, and a
    per-tenant staleness policy. Query methods take ``(fleet, t, ...)``;
    the staleness gate is per call and per tenant:

      * ``strict``  — raise ``StaleQueryError``;
      * ``refresh`` — rebuild ONLY tenant t's slice of the stacked
        tables (a single-lane tour + ``build_tables``), then answer;
      * ``stale``   — serve the frozen slice and count it.
    """

    tables: QueryTables                  # stacked [T, ...]
    bcc: DynamicBCC | None               # stacked, optional
    versions: np.ndarray                 # int64[T] stamped fleet versions
    policies: tuple[str, ...]
    use_kernel: bool = False
    n_jumps: int = DEFAULT_JUMPS
    # per-tenant telemetry (host-side), keyed by STABLE tenant label —
    # not slot index — so counters survive evict→re-admit rotation even
    # when the tenant lands in a different slot. ``labels[slot]`` maps
    # residency to label; the default identity labels reproduce PR 8's
    # slot-indexed behavior exactly.
    labels: list = None                  # slot → stable tenant id
    stats: dict = None                   # label → Counter of telemetry

    @classmethod
    def from_fleet(cls, fleet: ForestFleet,
                   tn: TourNumbering | None = None,
                   bcc: DynamicBCC | None = None, *,
                   policy: str | Sequence[str] = "strict",
                   use_kernel: bool = False,
                   n_jumps: int = DEFAULT_JUMPS,
                   labels: Sequence | None = None) -> "FleetQuerySession":
        t_slots = fleet.n_slots
        if labels is not None and len(labels) != t_slots:
            raise ValueError(f"{len(labels)} labels for {t_slots} slots")
        if isinstance(policy, str):
            policies = (policy,) * t_slots
        else:
            policies = tuple(policy)
        if len(policies) != t_slots:
            raise ValueError(f"{len(policies)} policies for {t_slots} slots")
        for p in policies:
            if p not in POLICIES:
                raise ValueError(f"policy {p!r} not in {POLICIES}")
        if tn is None:
            tn, _ = refresh_tours(fleet, incremental=False,
                                  use_kernel=use_kernel)
        tables = build_fleet_tables(tn, n_jumps=n_jumps)
        sess = cls(tables=tables, bcc=bcc,
                   versions=np.asarray(fleet.version, np.int64).copy(),
                   policies=policies, use_kernel=use_kernel,
                   n_jumps=n_jumps,
                   labels=(list(labels) if labels is not None
                           else list(range(t_slots))), stats={})
        build_syncs = np.asarray(tables.build_syncs, np.int64)
        for s in range(t_slots):
            sess._bump(sess.labels[s], builds=1,
                       build_syncs_total=int(build_syncs[s]))
        return sess

    # -- stable-label bookkeeping --------------------------------------------

    def _bump(self, label, **deltas) -> None:
        c = self.stats.setdefault(label, collections.Counter())
        for k, v in deltas.items():
            c[k] += int(v)

    def set_label(self, slot: int, label) -> None:
        """Bind ``slot`` to a stable tenant id. Telemetry for ``label``
        accumulates across rotations — a re-admitted tenant's counters
        continue from where eviction left them."""
        self.labels[slot] = label
        self.stats.setdefault(label, collections.Counter())

    # -- lifecycle -----------------------------------------------------------

    def rebuild_tenant(self, fleet: ForestFleet, t: int) -> None:
        """Re-index ONE tenant: single-lane tour + tables, scattered
        into the stacked index with ``.at[t].set`` (other lanes frozen)."""
        tn_t, tn_syncs = tour_numbering(fleet.parent[t],
                                        use_kernel=self.use_kernel,
                                        return_syncs=True)
        obs.record("refresh_tour", tn_syncs, tenant=self.labels[t])
        tab_t = build_tables(tn_t, n_jumps=self.n_jumps)
        self.tables = jax.tree_util.tree_map(
            lambda full, new: full.at[t].set(new), self.tables, tab_t)
        if self.bcc is not None:
            bcc_t = _refresh_full(fleet.tenant(t), tn_t,
                                  use_kernel=self.use_kernel)
            self.bcc = jax.tree_util.tree_map(
                lambda full, new: full.at[t].set(new), self.bcc, bcc_t)
        self.versions[t] = int(fleet.version[t])
        self._bump(self.labels[t], builds=1,
                   build_syncs_total=int(tab_t.build_syncs))

    def restamp(self, fleet: ForestFleet, tn: TourNumbering,
                bcc: DynamicBCC | None = None) -> None:
        """Adopt freshly vmapped caches for the whole fleet (the cadence
        path: the serving loop refreshed every lane in one program)."""
        self.tables = build_fleet_tables(tn, n_jumps=self.n_jumps)
        self.bcc = bcc
        self.versions = np.asarray(fleet.version, np.int64).copy()
        build_syncs = np.asarray(self.tables.build_syncs, np.int64)
        for s in range(len(self.labels)):
            self._bump(self.labels[s], builds=1,
                       build_syncs_total=int(build_syncs[s]))

    def is_fresh(self, fleet: ForestFleet, t: int) -> bool:
        return int(fleet.version[t]) == int(self.versions[t])

    def ensure(self, fleet: ForestFleet, t: int) -> None:
        if self.is_fresh(fleet, t):
            return
        policy = self.policies[t]
        if policy == "stale":
            self._bump(self.labels[t], stale_served=1)
            return
        if policy == "strict":
            raise StaleQueryError(
                f"tenant {t} at version {int(fleet.version[t])}, session "
                f"slice stamped {int(self.versions[t])}: refresh the "
                "fleet caches first (or use policy='refresh' / 'stale')")
        self._bump(self.labels[t], auto_refreshes=1)
        self.rebuild_tenant(fleet, t)

    # -- per-tenant query ops (gathers over one slice of the stack) ----------

    def _tab(self, t: int) -> QueryTables:
        return tenant_slice(self.tables, t)

    def connected(self, fleet, t: int, u, v) -> jnp.ndarray:
        self.ensure(fleet, t)
        return q.connected(self._tab(t), _i32(u), _i32(v))

    def depth(self, fleet, t: int, v) -> jnp.ndarray:
        self.ensure(fleet, t)
        return q.depth_of(self._tab(t), _i32(v))

    def lca(self, fleet, t: int, u, v) -> jnp.ndarray:
        self.ensure(fleet, t)
        return q.lca(self._tab(t), _i32(u), _i32(v))

    def is_ancestor(self, fleet, t: int, a, x) -> jnp.ndarray:
        self.ensure(fleet, t)
        return q.is_ancestor(self._tab(t), _i32(a), _i32(x))

    def is_bridge(self, fleet, t: int, u, v) -> jnp.ndarray:
        self.ensure(fleet, t)
        if self.bcc is None:
            raise ValueError("session built without biconnectivity labels "
                             "— pass bcc=refresh_bccs(...) to from_fleet")
        b = tenant_slice(self.bcc, t)
        cap = b.pool_src.shape[0]
        _hit, flagged = q.edge_membership(
            _i32(u), _i32(v), b.pool_src, b.pool_dst, b.pool_valid,
            b.bridge[:cap])
        return flagged

    def is_articulation(self, fleet, t: int, v) -> jnp.ndarray:
        self.ensure(fleet, t)
        if self.bcc is None:
            raise ValueError("session built without biconnectivity labels "
                             "— pass bcc=refresh_bccs(...) to from_fleet")
        b = tenant_slice(self.bcc, t)
        vq = _i32(v)
        n = b.articulation.shape[0]
        return ((vq >= 0) & (vq < n)
                & b.articulation[jnp.clip(vq, 0, n - 1)])

    # -- telemetry -----------------------------------------------------------

    def sync_stats(self, t=None) -> dict:
        """§12 amortization counters — one tenant's, or fleet totals.

        ``t`` is a stable tenant label; a slot index also resolves (via
        ``labels``) when no tenant carries that exact label, so PR-8
        slot-indexed callers read the same numbers as before.
        """
        keys = ("builds", "build_syncs_total", "stale_served",
                "auto_refreshes")
        if t is None:
            return {k: sum(c[k] for c in self.stats.values())
                    for k in keys}
        if t not in self.stats and isinstance(t, int) \
                and 0 <= t < len(self.labels):
            t = self.labels[t]
        c = self.stats.get(t, collections.Counter())
        return {k: int(c[k]) for k in keys}


# -- host-side dispatch + admission -------------------------------------------

class FleetDispatcher:
    """Coalesces incoming per-tenant batches into ``(T, B)`` tick blocks.

    Host-side. Tenants ``offer`` fixed-shape ``StreamBatch`` units (the
    §9 contract: sentinel-padded, one shape per stream); each ``tick``
    pops AT MOST ONE unit per resident tenant — units are atomic, never
    split across ticks or merged within one, so every tenant's applied
    sequence equals its offered sequence (the fleet-replay equivalence
    invariant). Slots with no resident or no queued unit get all-sentinel
    rows: inert under ``apply_batches`` except the unconditional version
    bump, which the admission checkpoint path hides (an evicted tenant's
    clock restarts from its restored stamp).
    """

    def __init__(self, n_nodes: int, batch: int):
        self.n_nodes = int(n_nodes)
        self.batch = int(batch)
        self.queues: dict[Any, collections.deque] = \
            collections.defaultdict(collections.deque)
        self.offered = collections.Counter()
        self.served = collections.Counter()

    def offer(self, tenant, b: StreamBatch) -> None:
        for arr in (b.ins_u, b.ins_v, b.del_u, b.del_v):
            if arr.shape != (self.batch,):
                raise ValueError(
                    f"batch unit shape {arr.shape} != ({self.batch},) — "
                    "the fleet block is fixed-shape; regenerate the "
                    "stream with the fleet's batch size")
        self.queues[tenant].append(b)
        self.offered[tenant] += 1

    def pending(self, tenant=None) -> int:
        if tenant is not None:
            return len(self.queues[tenant])
        return sum(len(d) for d in self.queues.values())

    def tick(self, tenant_at: Sequence[Any]):
        """Build one tick block for the current residency map.

        Args:
          tenant_at: per-slot resident tenant id (``None`` = empty slot).

        Returns:
          ((ins_u, ins_v, del_u, del_v) int32[T, B] device arrays,
           served: {tenant: event count} for the units dispatched).
        """
        t_slots, n, b = len(tenant_at), self.n_nodes, self.batch
        ins_u = np.full((t_slots, b), n, np.int32)
        ins_v = np.full((t_slots, b), n, np.int32)
        del_u = np.full((t_slots, b), n, np.int32)
        del_v = np.full((t_slots, b), n, np.int32)
        served: dict[Any, int] = {}
        for s, tenant in enumerate(tenant_at):
            if tenant is None or not self.queues[tenant]:
                continue
            unit = self.queues[tenant].popleft()
            ins_u[s], ins_v[s] = unit.ins_u, unit.ins_v
            del_u[s], del_v[s] = unit.del_u, unit.del_v
            served[tenant] = int((unit.ins_u < n).sum()
                                 + (unit.del_u < n).sum())
            self.served[tenant] += 1
        return ((jnp.asarray(ins_u), jnp.asarray(ins_v),
                 jnp.asarray(del_u), jnp.asarray(del_v)), served)

    def drain(self, tenant_at: Sequence[Any], max_blocks: int = 1):
        """Cross-tick carryover: up to ``max_blocks`` tick blocks in one
        serving tick, so a bursty tenant's queued backlog drains at
        ``max_blocks`` units/tick instead of silently waiting one tick
        per unit. Each block is a plain ``tick`` — at most one unit per
        tenant per block, FIFO, units never split or merged — so the
        applied sequence is exactly the offered sequence (the atomicity
        contract), just on a faster clock.

        Returns a list of ``(block, served)`` pairs; empty when no
        resident tenant has queued units.
        """
        out = []
        for _ in range(max(1, int(max_blocks))):
            if not any(tenant is not None and self.queues[tenant]
                       for tenant in tenant_at):
                break
            out.append(self.tick(tenant_at))
        return out

    def backlog(self) -> dict:
        """{tenant: queued units} for tenants with a non-empty queue —
        the carryover pressure signal (reported per bucket in §15)."""
        return {t: len(q) for t, q in self.queues.items() if q}


class FleetManager:
    """Session admission/eviction against the fleet's slot capacity.

    Host-side bookkeeping around a ``ForestFleet``: which tenant lives
    in which slot, LRU order, and per-tenant stream cursors. When every
    slot is occupied, ``ensure`` evicts a resident through the §8
    checkpoint path (forest + cursor, atomic publish); re-admission
    restores bit-identically — eviction is invisible to the tenant's
    replayed history (regression-tested).

    Victim choice prefers IDLE least-recently-used residents: evicting a
    tenant that still has pending dispatcher units round-trips a
    checkpoint for nothing (it must be restored before its very next
    tick). Pass ``busy`` (tenant → bool) to ``ensure``/``adopt_ready``;
    when every resident is busy the global LRU resident is evicted
    anyway — liveness over thrash-avoidance. Omitting ``busy``
    reproduces PR 8's plain global-LRU behavior exactly.

    Async admission (§15): ``prefetch`` starts the checkpoint restore on
    a host worker thread while the device runs the current tick;
    ``adopt_ready`` — called at a tick BOUNDARY — installs completed
    restores. A restore finishing mid-tick is never observed early.
    ``schema`` (optional ``FleetSchema``) is stamped into eviction
    manifests and validated on restore, so a tenant checkpointed under
    one bucket schema can't be silently adopted into another.
    """

    def __init__(self, fleet: ForestFleet, ckpt_dir: str | pathlib.Path,
                 *, schema: "FleetSchema | None" = None,
                 executor: concurrent.futures.Executor | None = None):
        self.fleet = fleet
        self.ckpt_dir = pathlib.Path(ckpt_dir)
        self.schema = schema
        self.slot_of: dict[Any, int] = {}
        self.tenant_at: list[Any] = [None] * fleet.n_slots
        self.last_used = [-1] * fleet.n_slots
        self.clock = 0
        self.cursors = collections.Counter()   # tenant → applied batches
        self.seeds: dict[Any, DynamicForest] = {}  # first-admission state
        self.admissions = 0
        self.evictions = 0
        self.restores = 0
        self.prefetches = 0
        self._executor = executor
        self._prefetch: dict[Any, concurrent.futures.Future] = {}

    def _tenant_dir(self, tenant) -> pathlib.Path:
        return self.ckpt_dir / f"tenant_{tenant}"

    def touch(self, tenant) -> None:
        self.clock += 1
        self.last_used[self.slot_of[tenant]] = self.clock

    def has_checkpoint(self, tenant) -> bool:
        return ckpt.latest_step(self._tenant_dir(tenant)) is not None

    def prefetching(self, tenant) -> bool:
        return tenant in self._prefetch

    def pick_victim(self, busy: Callable[[Any], bool] | None = None):
        """The tenant ``evict`` would choose: idle LRU resident if one
        exists, else the global LRU resident; ``None`` if no residents."""
        residents = [s for s, occ in enumerate(self.tenant_at)
                     if occ is not None]
        if not residents:
            return None
        if busy is not None:
            idle = [s for s in residents if not busy(self.tenant_at[s])]
            if idle:
                residents = idle
        slot = min(residents, key=lambda s: self.last_used[s])
        return self.tenant_at[slot]

    def has_room(self, busy: Callable[[Any], bool] | None = None) -> bool:
        """True when an admission would not evict a busy resident."""
        if any(occ is None for occ in self.tenant_at):
            return True
        victim = self.pick_victim(busy)
        return victim is not None and (busy is None or not busy(victim))

    def _slot_for_admit(self,
                        busy: Callable[[Any], bool] | None = None) -> int:
        free = [s for s, occupant in enumerate(self.tenant_at)
                if occupant is None]
        if free:
            return free[0]
        self.evict(self.pick_victim(busy))
        return [s for s, occ in enumerate(self.tenant_at)
                if occ is None][0]

    def ensure(self, tenant,
               busy: Callable[[Any], bool] | None = None) -> int:
        """Make ``tenant`` resident; returns its slot (evicting the
        preferred victim — idle LRU first — if the fleet is full)."""
        if tenant in self.slot_of:
            self.touch(tenant)
            return self.slot_of[tenant]
        if tenant in self._prefetch:
            # A prefetch is in flight: adopt it synchronously rather
            # than racing a second restore against it.
            forest, cursor = self._prefetch.pop(tenant).result()
            slot = self._slot_for_admit(busy)
            self._install(tenant, slot, forest, cursor=cursor,
                          restored=True)
            return slot
        slot = self._slot_for_admit(busy)
        self._admit(tenant, slot)
        return slot

    def evict(self, tenant) -> None:
        """Checkpoint ``tenant``'s forest + cursor and free its slot."""
        slot = self.slot_of.pop(tenant)
        extra = ({"schema": self.schema.to_dict()}
                 if self.schema is not None else None)
        ckpt.save(self._tenant_dir(tenant),
                  {"forest": self.fleet.tenant(slot)},
                  step=self.clock, data_cursor=int(self.cursors[tenant]),
                  keep=1, extra=extra)
        self.fleet = self.fleet.clear_tenant(slot)
        self.tenant_at[slot] = None
        self.last_used[slot] = -1
        self.evictions += 1

    def _check_manifest(self, tenant, manifest) -> None:
        saved = (manifest.get("extra") or {}).get("schema")
        if saved is None or self.schema is None:
            return
        if saved != self.schema.to_dict():
            raise ValueError(
                f"tenant {tenant!r} checkpoint written under schema "
                f"{saved} cannot be admitted into bucket schema "
                f"{self.schema.to_dict()} — route it to its own bucket")

    def _fresh_forest(self, tenant) -> DynamicForest:
        seed = self.seeds.get(tenant)
        if seed is not None:
            return seed
        return forest_empty(self.fleet.n_nodes, self.fleet.capacity)

    def _restore(self, tenant):
        """(worker-thread safe) load tenant's checkpoint → (forest,
        cursor). Pure host work: file read + np decode."""
        fresh = {"forest": forest_empty(self.fleet.n_nodes,
                                        self.fleet.capacity)}
        restored, manifest = ckpt.restore(self._tenant_dir(tenant), fresh)
        self._check_manifest(tenant, manifest)
        return restored["forest"], int(manifest["data_cursor"])

    def _install(self, tenant, slot: int, forest, *, cursor=None,
                 restored: bool = False) -> None:
        if cursor is not None:
            self.cursors[tenant] = int(cursor)
        self.fleet = self.fleet.set_tenant(slot, forest)
        self.slot_of[tenant] = slot
        self.tenant_at[slot] = tenant
        self.admissions += 1
        if restored:
            self.restores += 1
        self.touch(tenant)

    def _admit(self, tenant, slot: int) -> None:
        if self.has_checkpoint(tenant):
            forest, cursor = self._restore(tenant)
            self._install(tenant, slot, forest, cursor=cursor,
                          restored=True)
        else:
            self._install(tenant, slot, self._fresh_forest(tenant))

    # -- async admission (§15) ----------------------------------------------

    def prefetch(self, tenant) -> bool:
        """Start restoring ``tenant`` on the host worker while the
        current tick runs on device. No fleet state changes here — the
        restored forest becomes visible only when ``adopt_ready`` runs
        at a tick boundary. Returns True if a prefetch was started (or
        is already in flight)."""
        if tenant in self.slot_of:
            return False
        if tenant in self._prefetch:
            return True
        if self._executor is not None:
            fut = self._executor.submit(self._restore, tenant)
        else:
            # No executor: run inline but STILL defer adoption to the
            # next boundary — the protocol, minus the overlap.
            fut = concurrent.futures.Future()
            try:
                fut.set_result(self._restore(tenant))
            except Exception as e:          # surfaced at adopt time
                fut.set_exception(e)
        self._prefetch[tenant] = fut
        self.prefetches += 1
        return True

    def adopt_ready(self,
                    busy: Callable[[Any], bool] | None = None) -> list:
        """Tick-boundary adoption: install every COMPLETED prefetch that
        can take a slot (free, or by evicting the preferred victim).
        Unfinished restores stay in flight; restores that finished
        mid-tick land here, never earlier. Returns adopted tenants."""
        adopted = []
        for tenant in list(self._prefetch):
            fut = self._prefetch[tenant]
            if not fut.done():
                continue
            del self._prefetch[tenant]
            forest, cursor = fut.result()   # re-raises restore errors
            slot = self._slot_for_admit(busy)
            self._install(tenant, slot, forest, cursor=cursor,
                          restored=True)
            adopted.append(tenant)
        return adopted

    def note_applied(self, served: dict) -> None:
        """Advance stream cursors after a tick (one unit per tenant)."""
        for tenant in served:
            self.cursors[tenant] += 1


# -- shape-bucketed sub-fleets (DESIGN.md §15) --------------------------------

@dataclasses.dataclass(frozen=True)
class FleetSchema:
    """A fleet shape class: every tenant in a bucket shares these.

    ``ForestFleet`` vmaps all T tenants through ONE ``(n, capacity)``
    schema, so ten thousand 64-node sessions pay the padding (and the
    per-tick ``max_t(rounds)+1`` sync bill) of the single largest
    tenant. A ``FleetSchema`` names one shape class; ``BucketedFleet``
    routes each tenant to the sub-fleet whose schema it fits, so small
    sessions never ride the largest tenant's padding.
    """

    n_nodes: int
    capacity: int
    batch: int

    @property
    def key(self) -> str:
        return f"n{self.n_nodes}_c{self.capacity}_b{self.batch}"

    @property
    def slot_cost(self) -> int:
        """Device rows one resident slot pins: 3 vertex-length arrays
        (parent, rep, dirty) + 4 capacity-length pool arrays — the
        memory proxy behind equal-budget bucketed-vs-single comparisons
        (table9)."""
        return 3 * self.n_nodes + 4 * self.capacity

    def to_dict(self) -> dict:
        return {"n_nodes": int(self.n_nodes),
                "capacity": int(self.capacity), "batch": int(self.batch)}

    @classmethod
    def from_dict(cls, d: dict) -> "FleetSchema":
        return cls(n_nodes=int(d["n_nodes"]), capacity=int(d["capacity"]),
                   batch=int(d["batch"]))


class FleetBucket:
    """One sub-fleet: a ``ForestFleet`` + dispatcher + manager + caches,
    all under a single ``FleetSchema``, ticking independently.

    Each bucket pays its OWN per-tick sync bill (``max over its lanes``
    + 1) with its own ``(T_b, B_b)`` block shape and its own refresh
    cadence; a converged or small bucket never waits on a large one.
    ``tick`` is the whole serving step for the bucket:

      1. tick boundary — adopt prefetched restores that completed during
         the previous tick (``FleetManager.adopt_ready``; a restore
         finishing mid-tick is never observed early);
      2. admission — waiting tenants with traffic claim free slots
         (idle-LRU eviction when full); tenants with a checkpoint start
         an async ``prefetch`` instead of blocking the device;
      3. apply — up to ``max_drain`` dispatcher blocks (cross-tick
         carryover for bursty tenants), each one vmapped
         ``apply_batches`` labeled with the bucket name;
      4. cadenced refresh — vmapped tour/BCC (+ optional query session)
         on the bucket's own ``CadencePolicy``. Any residency change
         since the last refresh forces a full (non-incremental) rebuild:
         a rotated lane's cached numbering describes the slot's PREVIOUS
         occupant.
    """

    def __init__(self, schema: FleetSchema, n_slots: int,
                 ckpt_dir: str | pathlib.Path, *,
                 cadence: CadencePolicy | None = None,
                 name: str | None = None, use_kernel: bool = False,
                 max_drain: int = 1,
                 executor: concurrent.futures.Executor | None = None):
        self.schema = schema
        self.name = name or schema.key
        self.cadence = cadence or CadencePolicy()
        self.use_kernel = use_kernel
        self.max_drain = max(1, int(max_drain))
        self.manager = FleetManager(
            fleet_empty(n_slots, schema.n_nodes, schema.capacity),
            pathlib.Path(ckpt_dir) / self.name, schema=schema,
            executor=executor)
        self.dispatcher = FleetDispatcher(schema.n_nodes, schema.batch)
        self.tenants: list = []
        self.tn: TourNumbering | None = None
        self.bcc: DynamicBCC | None = None
        self.session: FleetQuerySession | None = None
        self.ticks = 0            # ticks that applied at least one block
        self.blocks = 0
        self.sync_apply = 0
        self.sync_refresh = 0
        self.applied = collections.Counter()   # tenant → applied events
        self.padded_events = 0    # Σ blocks · T_b · B_b (slot-rows fed)
        self.padded_rows = 0      # Σ blocks · T_b · slot_cost (memory·ticks)
        self.max_backlog = 0
        self._lanes_dirty = True  # residency changed since last refresh

    # -- routing -------------------------------------------------------------

    def route(self, tenant, seed: DynamicForest | None = None) -> None:
        """Register ``tenant`` in this bucket. ``seed`` (optional) is
        the forest its FIRST admission installs — e.g. a pre-built
        initial graph state — instead of an edgeless forest; later
        admissions restore from its eviction checkpoint as usual."""
        if tenant in self.tenants:
            return
        self.tenants.append(tenant)
        if seed is not None:
            if (seed.n_nodes != self.schema.n_nodes
                    or seed.capacity != self.schema.capacity):
                raise ValueError(
                    f"seed forest (n={seed.n_nodes}, "
                    f"capacity={seed.capacity}) does not fit bucket "
                    f"schema {self.schema.key}")
            self.manager.seeds[tenant] = seed

    def offer(self, tenant, unit: StreamBatch) -> None:
        if tenant not in self.tenants:
            raise KeyError(f"tenant {tenant!r} not routed to bucket "
                           f"{self.name}")
        self.dispatcher.offer(tenant, unit)

    def busy(self, tenant) -> bool:
        return self.dispatcher.pending(tenant) > 0

    def pending(self) -> int:
        return self.dispatcher.pending() + len(self.manager._prefetch)

    # -- the serving tick ----------------------------------------------------

    def _admit_waiting(self) -> None:
        mgr = self.manager
        room = sum(1 for occ in mgr.tenant_at
                   if occ is None or not self.busy(occ))
        room -= len(mgr._prefetch)   # in-flight restores will claim room
        for tenant in self.tenants:
            if room <= 0:
                break
            if (not self.busy(tenant) or tenant in mgr.slot_of
                    or mgr.prefetching(tenant)):
                continue
            if mgr.has_checkpoint(tenant):
                mgr.prefetch(tenant)   # lands at the NEXT tick boundary
            else:
                mgr.ensure(tenant, busy=self.busy)
                self._lanes_dirty = True
            room -= 1

    def tick(self, step: int | None = None) -> dict:
        """One serving tick; returns {tenant: applied events}."""
        mgr = self.manager
        if mgr.adopt_ready(busy=self.busy):
            self._lanes_dirty = True
        self._admit_waiting()
        served_total = collections.Counter()
        with obs.span("bucket_tick", step=step, bucket=self.name):
            for block, served in self.dispatcher.drain(
                    mgr.tenant_at, max_blocks=self.max_drain):
                mgr.fleet, stats = apply_batches(
                    mgr.fleet, *block, use_kernel=self.use_kernel,
                    bucket=self.name)
                mgr.note_applied(served)
                self.sync_apply += fleet_sync_cost(stats)
                self.blocks += 1
                self.padded_events += mgr.fleet.n_slots * self.schema.batch
                self.padded_rows += mgr.fleet.n_slots * self.schema.slot_cost
                for tenant, ev in served.items():
                    served_total[tenant] += ev
                    self.applied[tenant] += ev
            if served_total:
                if (self.cadence.tour != "off"
                        and self.cadence.due(self.ticks)):
                    self.refresh(step=step)
                self.ticks += 1
        backlog = self.dispatcher.backlog()
        if backlog:
            self.max_backlog = max(self.max_backlog,
                                   max(backlog.values()))
        return dict(served_total)

    def refresh(self, step: int | None = None) -> None:
        """Vmapped cache refresh for the whole bucket (bucket-labeled
        ledger phases + span). Forced callers (end-of-run reporting)
        call this directly, out of cadence."""
        mgr, cad = self.manager, self.cadence
        inc = not self._lanes_dirty
        with obs.span("fleet_refresh", step=step, bucket=self.name), \
                obs.SyncLedger() as led:
            inc_t = cad.tour == "incremental" and self.tn is not None \
                and inc
            self.tn, mgr.fleet = refresh_tours(
                mgr.fleet, self.tn if inc_t else None,
                incremental=inc_t, use_kernel=self.use_kernel,
                bucket=self.name)
            if cad.bcc != "off":
                inc_b = cad.bcc == "incremental" and self.bcc is not None \
                    and inc
                self.bcc = refresh_bccs(
                    mgr.fleet, self.bcc if inc_b else None, tour=self.tn,
                    incremental=inc_b, use_kernel=self.use_kernel,
                    bucket=self.name)
            if cad.queries:
                if self.session is None:
                    self.session = FleetQuerySession.from_fleet(
                        mgr.fleet, self.tn, self.bcc,
                        policy=cad.staleness, use_kernel=self.use_kernel,
                        labels=[t if t is not None else s for s, t
                                in enumerate(mgr.tenant_at)])
                else:
                    for s, tenant in enumerate(mgr.tenant_at):
                        if tenant is not None:
                            self.session.set_label(s, tenant)
                    self.session.restamp(mgr.fleet, self.tn, self.bcc)
        self.sync_refresh += led.total()
        self._lanes_dirty = False

    def slot(self, tenant) -> int:
        """The tenant's resident slot (admitting it if needed)."""
        return self.manager.ensure(tenant, busy=self.busy)


class BucketedFleet:
    """Shape-bucketed sub-fleets behind one serving surface (§15).

    Tenants are routed by ``FleetSchema`` into ``FleetBucket``s; each
    bucket ticks independently with its own block shape, cadence, and
    sync bill. A ``BucketedFleet`` with exactly one bucket is PR 8's
    single-schema fleet, bit-identically (regression-tested) — the
    refactor's compatibility anchor. All buckets share one host worker
    thread for async admission restores.
    """

    def __init__(self, ckpt_dir: str | pathlib.Path, *,
                 use_kernel: bool = False, max_drain: int = 1):
        self.ckpt_dir = pathlib.Path(ckpt_dir)
        self.use_kernel = use_kernel
        self.max_drain = max_drain
        self.buckets: dict[str, FleetBucket] = {}
        self._bucket_of: dict[Any, str] = {}
        self._executor = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="fleet-admit")

    def add_bucket(self, schema: FleetSchema, n_slots: int, *,
                   cadence: CadencePolicy | None = None,
                   name: str | None = None,
                   max_drain: int | None = None) -> FleetBucket:
        name = name or schema.key
        if name in self.buckets:
            raise ValueError(f"bucket {name!r} already exists")
        b = FleetBucket(schema, n_slots, self.ckpt_dir, cadence=cadence,
                        name=name, use_kernel=self.use_kernel,
                        max_drain=(self.max_drain if max_drain is None
                                   else max_drain),
                        executor=self._executor)
        self.buckets[name] = b
        return b

    def route(self, tenant, schema: FleetSchema, *,
              seed: DynamicForest | None = None) -> FleetBucket:
        """Bind ``tenant`` to the bucket matching ``schema`` exactly."""
        if tenant in self._bucket_of:
            b = self.buckets[self._bucket_of[tenant]]
            if b.schema != schema:
                raise ValueError(
                    f"tenant {tenant!r} already routed to bucket "
                    f"{b.name} ({b.schema.key}); cannot re-route to "
                    f"{schema.key}")
            return b
        for b in self.buckets.values():
            if b.schema == schema:
                b.route(tenant, seed=seed)
                self._bucket_of[tenant] = b.name
                return b
        raise KeyError(f"no bucket with schema {schema.key} — "
                       f"add_bucket first (have: "
                       f"{', '.join(self.buckets) or 'none'})")

    def bucket_of(self, tenant) -> FleetBucket:
        return self.buckets[self._bucket_of[tenant]]

    def offer(self, tenant, unit: StreamBatch) -> None:
        self.bucket_of(tenant).offer(tenant, unit)

    def pending(self) -> int:
        return sum(b.pending() for b in self.buckets.values())

    def step(self, step: int | None = None) -> dict:
        """One serving tick: every bucket with traffic ticks once."""
        served: dict = {}
        for b in self.buckets.values():
            if b.pending():
                served.update(b.tick(step))
        return served

    def run(self, max_steps: int = 1_000_000) -> int:
        """Drain every queue; returns the number of steps taken."""
        steps = 0
        while self.pending():
            if steps >= max_steps:
                raise RuntimeError(
                    f"BucketedFleet.run did not drain in {max_steps} "
                    "steps — admission livelock?")
            self.step(steps)
            steps += 1
        return steps

    def finalize(self) -> None:
        """Force a final refresh in every bucket that applied work."""
        for b in self.buckets.values():
            if b.blocks:
                b.refresh()

    def tenant_forest(self, tenant) -> DynamicForest:
        """Tenant's current forest (re-admitting it if evicted)."""
        b = self.bucket_of(tenant)
        slot = b.slot(tenant)          # may evict + restore, swaps fleet
        return b.manager.fleet.tenant(slot)

    # -- fleet-wide accounting ------------------------------------------------

    def sync_total(self) -> int:
        return sum(b.sync_apply + b.sync_refresh
                   for b in self.buckets.values())

    def applied_events(self) -> int:
        return sum(sum(b.applied.values()) for b in self.buckets.values())

    def padded_rows(self) -> int:
        return sum(b.padded_rows for b in self.buckets.values())

    def padded_events(self) -> int:
        return sum(b.padded_events for b in self.buckets.values())

    def close(self) -> None:
        self._executor.shutdown(wait=True)

    def __enter__(self) -> "BucketedFleet":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
