"""Incremental Euler-tour refresh for the batch-dynamic forest.

``euler.tour_numbering`` is the downstream substrate (preorder intervals
for biconnectivity, subtree queries) and its dominant cost is the Wyllie
list-ranking pass: ⌈log2(longest tour)/k⌉ + 1 doubling syncs over 2n
slots. A batch usually touches a few components; re-ranking the whole
forest wastes exactly the amortization the dynamic layer exists for.

``refresh_tour`` recomputes the numbering only for *dirty* components
(the component-closed mask ``DynamicForest.dirty`` maintained by
``apply_batch``), JaJa-style (DESIGN.md §9):

  1. mask the parent array so every clean vertex is a singleton — their
     Euler lists are empty, so the ranking pass converges in
     ⌈log2(longest *dirty* tour)/k⌉ + 1 syncs;
  2. take per-vertex preorder keys from the fresh numbering for dirty
     vertices and from the cached numbering for clean ones (relative
     order within a clean component is unchanged by definition of clean);
  3. re-densify globally with one (component, key) lexsort — cheap, no
     doubling syncs — and carry sizes over the same split.

The result is *bit-identical* to a full ``tour_numbering(parent)``
recompute (both sort the same per-component preorders by the same
component blocks; regression-tested in tests/test_dynamic.py), so
consumers cannot tell incremental and full refreshes apart.

``incremental=False`` forces the full recompute — the ablation switch
``benchmarks/table4_dynamic.py`` uses to measure the crossover.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.euler import TourNumbering, tour_numbering
from repro.dynamic.forest import DynamicForest


def _clear_dirty(state: DynamicForest) -> DynamicForest:
    return dataclasses.replace(
        state, dirty=jnp.zeros((state.n_nodes,), jnp.bool_))


@partial(jax.jit, static_argnames=("use_kernel", "return_syncs"))
def _merge_dirty(parent, rep, dirty, cached: TourNumbering, *,
                 use_kernel: bool = False,
                 return_syncs: bool = False) -> TourNumbering:
    n = parent.shape[0]
    verts = jnp.arange(n, dtype=jnp.int32)

    # Rank only the dirty sub-forest: clean vertices become singletons,
    # whose Euler lists are empty (zero doubling work).
    masked = jnp.where(dirty, parent, verts)
    fresh, syncs = tour_numbering(masked, use_kernel=use_kernel,
                                  return_syncs=True)

    # Per-component preorder keys: fresh where dirty, cached where clean.
    # Keys are only ever compared within one component (lexsort is
    # component-major), and both sources are injective there.
    key = jnp.where(dirty, fresh.pre, cached.pre)
    order = jnp.lexsort((key, rep)).astype(jnp.int32)
    pre = jnp.zeros((n,), jnp.int32).at[order].set(verts)
    size = jnp.where(dirty, fresh.size, cached.size)
    tn = TourNumbering(pre=pre, size=size, last=pre + size - 1,
                       comp=rep, parent=parent)
    if return_syncs:
        return tn, syncs
    return tn


def refresh_tour(state: DynamicForest,
                 cached: TourNumbering | None = None, *,
                 incremental: bool = True, use_kernel: bool = False):
    """Refresh the tour numbering after one or more ``apply_batch`` calls.

    Deprecated thin wrapper: the canonical entry is
    ``dynamic.view.refresh_tour_once`` (or, for cadenced serving loops,
    ``dynamic.view.ForestView.refresh``). Kept so existing callers and
    the table4 ablation keep working unchanged.

    Args:
      state: the dynamic forest (its ``dirty`` mask names the components
        whose tree changed since ``cached`` was computed).
      cached: the numbering from the previous refresh. ``None`` forces a
        full recompute (e.g. the first call after ``forest_from_graph``).
      incremental: ablation flag — ``False`` always recomputes from
        scratch (the ``table4_dynamic`` baseline).
      use_kernel: route list ranking through the Pallas list_rank kernel.

    Returns:
      (numbering, state') — state' has its dirty mask cleared; pass it
      (and the numbering) to the next refresh.
    """
    from repro.dynamic.view import refresh_tour_once

    return refresh_tour_once(state, cached, incremental=incremental,
                             use_kernel=use_kernel)
