"""Batch-dynamic rooted-spanning-forest maintenance (DESIGN.md §9–§10).

State + update application (``forest``), incremental tour refresh
(``tour``), incremental biconnectivity (``bcc``). Edge-stream workloads
live in ``repro.data.streams``; the serving loop in
``repro.launch.serve_stream``.
"""
from repro.dynamic.bcc import DynamicBCC, refresh_bcc
from repro.dynamic.forest import (DynamicForest, apply_batch, edge_slots,
                                  forest_empty, forest_from_graph,
                                  live_graph)
from repro.dynamic.replay import init_state, replay_batch, stream_capacity
from repro.dynamic.tour import refresh_tour

__all__ = [
    "DynamicBCC", "DynamicForest", "apply_batch", "edge_slots",
    "forest_empty", "forest_from_graph", "init_state", "live_graph",
    "replay_batch", "refresh_bcc", "refresh_tour", "stream_capacity",
]
