"""Batch-dynamic rooted-spanning-forest maintenance (DESIGN.md §9–§10).

State + update application (``forest``), incremental tour refresh
(``tour``), incremental biconnectivity (``bcc``), and the self-healing
layer (DESIGN.md §11): fault injection (``chaos``), O(log n) invariant
auditing (``audit``), and the scoped-repair/rebuild ladder
(``recovery``). The read path is ``queries``: a version-stamped
``QuerySession`` serving LCA / connectivity / aggregates / BCC
membership from the cached tour intervals (DESIGN.md §12).
Edge-stream workloads live in ``repro.data.streams``;
the resilient serving loop in ``repro.launch.resilient`` /
``repro.launch.serve_stream``.
"""
from repro.dynamic.audit import AuditReport, audit_forest
from repro.dynamic.bcc import DynamicBCC, refresh_bcc
from repro.dynamic.chaos import (INJECTORS, POLLUTERS, inject,
                                 merge_quarantine, pollute_stream,
                                 sanitize_batch)
from repro.dynamic.forest import (DynamicForest, apply_batch, edge_slots,
                                  forest_empty, forest_from_graph,
                                  live_graph)
from repro.dynamic.queries import QuerySession, StaleQueryError
from repro.dynamic.recovery import rebuild_forest, recover, repair_forest
from repro.dynamic.replay import init_state, replay_batch, stream_capacity
from repro.dynamic.tour import refresh_tour

__all__ = [
    "AuditReport", "DynamicBCC", "DynamicForest", "INJECTORS", "POLLUTERS",
    "apply_batch", "audit_forest", "edge_slots", "forest_empty",
    "forest_from_graph", "init_state", "inject", "live_graph",
    "merge_quarantine", "pollute_stream", "QuerySession", "rebuild_forest",
    "recover", "refresh_bcc", "refresh_tour", "repair_forest",
    "replay_batch", "sanitize_batch", "StaleQueryError", "stream_capacity",
]
