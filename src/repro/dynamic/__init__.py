"""Batch-dynamic rooted-spanning-forest maintenance (DESIGN.md §9–§10).

State + update application (``forest``), incremental tour refresh
(``tour``), incremental biconnectivity (``bcc``), and the self-healing
layer (DESIGN.md §11): fault injection (``chaos``), O(log n) invariant
auditing (``audit``), and the scoped-repair/rebuild ladder
(``recovery``). The read path is ``queries``: a version-stamped
``QuerySession`` serving LCA / connectivity / aggregates / BCC
membership from the cached tour intervals (DESIGN.md §12). ``view``
unifies the derived-cache refreshes behind ``ForestView`` + one
``CadencePolicy``; ``fleet`` lifts the whole loop to T tenants in one
vmapped program (DESIGN.md §13) and routes mixed-shape tenant
populations into shape-bucketed sub-fleets (``FleetSchema`` /
``BucketedFleet``, DESIGN.md §15). Edge-stream workloads live in
``repro.data.streams``; the serving loops in ``repro.launch.resilient``
/ ``repro.launch.serve_stream`` / ``repro.launch.serve_fleet``.
"""
from repro.dynamic.audit import AuditReport, audit_forest
from repro.dynamic.bcc import DynamicBCC, refresh_bcc
from repro.dynamic.chaos import (INJECTORS, POLLUTERS, inject,
                                 merge_quarantine, pollute_stream,
                                 sanitize_batch)
from repro.dynamic.fleet import (BucketedFleet, FleetBucket,
                                 FleetDispatcher, FleetManager,
                                 FleetQuerySession, FleetSchema,
                                 ForestFleet, apply_batches,
                                 build_fleet_tables, fleet_empty,
                                 fleet_sync_cost, refresh_bccs,
                                 refresh_tours, tenant_slice)
from repro.dynamic.forest import (DynamicForest, apply_batch, edge_slots,
                                  forest_empty, forest_from_graph,
                                  live_graph)
from repro.dynamic.queries import QuerySession, StaleQueryError
from repro.dynamic.recovery import rebuild_forest, recover, repair_forest
from repro.dynamic.replay import init_state, replay_batch, stream_capacity
from repro.dynamic.tour import refresh_tour
from repro.dynamic.view import (CadencePolicy, ForestView,
                                refresh_bcc_once, refresh_tour_once)

__all__ = [
    "AuditReport", "BucketedFleet", "CadencePolicy", "DynamicBCC",
    "DynamicForest", "FleetBucket", "FleetDispatcher", "FleetManager",
    "FleetQuerySession", "FleetSchema", "ForestFleet",
    "ForestView", "INJECTORS", "POLLUTERS", "apply_batch", "apply_batches",
    "audit_forest", "build_fleet_tables", "edge_slots", "fleet_empty",
    "fleet_sync_cost", "forest_empty", "forest_from_graph", "init_state",
    "inject", "live_graph", "merge_quarantine", "pollute_stream",
    "QuerySession", "rebuild_forest", "recover", "refresh_bcc",
    "refresh_bcc_once", "refresh_bccs", "refresh_tour", "refresh_tour_once",
    "refresh_tours", "repair_forest", "replay_batch", "sanitize_batch",
    "StaleQueryError", "stream_capacity", "tenant_slice",
]
