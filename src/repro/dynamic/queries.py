"""QuerySession: the read path of the batch-dynamic forest (DESIGN.md §12).

``apply_batch`` is the write path; this module serves reads between
writes. A ``QuerySession`` freezes one consistent view of the forest —
the ``core.queries.QueryTables`` index built from a tour refresh, plus
(optionally) the ``DynamicBCC`` labels — and answers query batches with
zero further engine syncs until the forest moves on.

Staleness is a first-class contract, not an accident (the satellite
hazard this module exists to close): every structural mutation bumps
``DynamicForest.version``, the session stamps the version it was built
against, and each query re-checks the stamp. ``from_state``/``rebuild``
additionally snapshot-diff any caller-provided caches against the live
state (the §10 pattern ``refresh_bcc`` uses for dirty detection) so a
session can never be *constructed* over stale intervals either. On a
stamp mismatch the ``policy`` decides:

  * ``"strict"``  — raise ``StaleQueryError`` (default: reads after an
                    un-refreshed edit are a bug, never silent);
  * ``"refresh"`` — transparently rebuild from the current state (full
                    tour + tables + BCC recompute, syncs counted in
                    ``build_syncs_total``), then answer;
  * ``"stale"``   — serve the frozen view and count it
                    (``stale_served``) — bounded-staleness serving for
                    read-heavy loops that refresh on a cadence.

The session is a host-side mutable object (like
``launch.resilient.ResilientStreamLoop``), deliberately NOT a pytree:
it owns amortization counters (``builds``, ``build_syncs_total``) that
``benchmarks/table7_queries`` and ``serve_stream --read-ratio`` report.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import queries as q
from repro.core.compress import DEFAULT_JUMPS
from repro.core.euler import TourNumbering, tour_numbering
from repro.dynamic.bcc import DynamicBCC, refresh_bcc
from repro.dynamic.forest import DynamicForest

POLICIES = ("strict", "refresh", "stale")


class StaleQueryError(RuntimeError):
    """A query hit a session whose caches no longer match the forest."""


def _i32(x) -> jnp.ndarray:
    return jnp.atleast_1d(jnp.asarray(x, jnp.int32))


def _same(a: jnp.ndarray, b: jnp.ndarray) -> bool:
    return bool(np.array_equal(np.asarray(a), np.asarray(b)))


@dataclasses.dataclass
class QuerySession:
    """One consistent, version-stamped read view over a ``DynamicForest``.

    Build with ``from_state`` (reusing the caller's refreshed ``tn`` /
    ``bcc`` caches when available — the build then costs only the
    ancestor/depth tables); re-stamp after each refresh cadence with
    ``rebuild``. All query methods take the *current* state first so the
    staleness check is per-call, batched int32 ids after.
    """

    tables: q.QueryTables
    tn: TourNumbering
    bcc: DynamicBCC | None
    state_version: int
    policy: str = "strict"
    use_kernel: bool = False
    n_jumps: int = DEFAULT_JUMPS
    # amortization / staleness telemetry (host-side counters)
    builds: int = 0
    build_syncs_total: int = 0
    stale_served: int = 0
    auto_refreshes: int = 0

    @classmethod
    def from_state(cls, state: DynamicForest,
                   tn: TourNumbering | None = None,
                   bcc: DynamicBCC | None = None, *,
                   policy: str = "strict", use_kernel: bool = False,
                   n_jumps: int = DEFAULT_JUMPS) -> "QuerySession":
        if policy not in POLICIES:
            raise ValueError(f"policy {policy!r} not in {POLICIES}")
        sess = cls(tables=None, tn=None, bcc=None, state_version=-1,
                   policy=policy, use_kernel=use_kernel, n_jumps=n_jumps)
        sess.rebuild(state, tn=tn, bcc=bcc)
        return sess

    # -- lifecycle ----------------------------------------------------------

    def rebuild(self, state: DynamicForest, *,
                tn: TourNumbering | None = None,
                bcc: DynamicBCC | None = None) -> "QuerySession":
        """(Re)build the index against ``state`` and stamp its version.

        Caller-provided caches are snapshot-diffed against the live
        state before being trusted — a ``tn`` whose parent table is not
        bit-identical to ``state.parent``, or a ``bcc`` whose §10
        snapshots disagree with the live pool, is rejected rather than
        silently serving somebody else's intervals.
        """
        if tn is not None and not _same(tn.parent, state.parent):
            raise ValueError(
                "stale TourNumbering: tn.parent != state.parent — run "
                "refresh_tour(state, tn) before building a QuerySession")
        if bcc is not None and not (
                _same(bcc.parent, state.parent)
                and _same(bcc.pool_src, state.pool_src)
                and _same(bcc.pool_dst, state.pool_dst)
                and _same(bcc.pool_valid, state.pool_valid)
                and _same(bcc.tree_mask, state.tree_mask)):
            raise ValueError(
                "stale DynamicBCC: its §10 snapshots disagree with the "
                "live forest — run refresh_bcc before building a "
                "QuerySession")
        if tn is None:
            tn = tour_numbering(state.parent, use_kernel=self.use_kernel)
        self.tables = q.build_tables(tn, n_jumps=self.n_jumps)
        self.tn = tn
        self.bcc = bcc
        self.state_version = int(state.version)
        self.builds += 1
        self.build_syncs_total += int(self.tables.build_syncs)
        return self

    def is_fresh(self, state: DynamicForest) -> bool:
        return int(state.version) == self.state_version

    def ensure(self, state: DynamicForest) -> None:
        """Per-query staleness gate — the policy dispatch."""
        if self.is_fresh(state):
            return
        if self.policy == "stale":
            self.stale_served += 1
            return
        if self.policy == "strict":
            raise StaleQueryError(
                f"forest at version {int(state.version)}, session built "
                f"at {self.state_version}: refresh_tour/refresh_bcc and "
                "session.rebuild(...) first (or use policy='refresh' / "
                "'stale')")
        # policy == "refresh": recompute the view from the current state.
        self.auto_refreshes += 1
        bcc = None
        if self.bcc is not None:
            bcc = refresh_bcc(state, None,
                              tour=tour_numbering(
                                  state.parent, use_kernel=self.use_kernel),
                              use_kernel=self.use_kernel)
        self.rebuild(state, bcc=bcc)

    # -- tree queries (tour-interval + doubling tables) ----------------------

    def connected(self, state: DynamicForest, u, v) -> jnp.ndarray:
        self.ensure(state)
        return q.connected(self.tables, _i32(u), _i32(v))

    def depth(self, state: DynamicForest, v) -> jnp.ndarray:
        self.ensure(state)
        return q.depth_of(self.tables, _i32(v))

    def lca(self, state: DynamicForest, u, v) -> jnp.ndarray:
        self.ensure(state)
        return q.lca(self.tables, _i32(u), _i32(v))

    def is_ancestor(self, state: DynamicForest, a, x) -> jnp.ndarray:
        self.ensure(state)
        return q.is_ancestor(self.tables, _i32(a), _i32(x))

    def subtree_agg(self, state: DynamicForest, v, payload,
                    op: str = "add") -> jnp.ndarray:
        self.ensure(state)
        return q.subtree_agg(self.tables, _i32(v), jnp.asarray(payload), op)

    def path_agg(self, state: DynamicForest, u, v, payload,
                 op: str = "add") -> jnp.ndarray:
        self.ensure(state)
        return q.path_agg(self.tables, _i32(u), _i32(v),
                          jnp.asarray(payload), op)

    # -- biconnectivity membership (DynamicBCC labels) ------------------------

    def _require_bcc(self) -> DynamicBCC:
        if self.bcc is None:
            raise ValueError(
                "session built without biconnectivity labels — pass "
                "bcc=refresh_bcc(...) to from_state/rebuild to answer "
                "is_bridge / is_articulation")
        return self.bcc

    def is_bridge(self, state: DynamicForest, u, v) -> jnp.ndarray:
        """bool[B] — some live (u, v) pool copy is a bridge.

        Matched against the session's *snapshot* pool (self-consistent
        with the bridge flags under the ``stale`` policy). A pair with
        parallel copies is never a bridge — the copies form a cycle —
        and a pair with no live copy answers False.
        """
        self.ensure(state)
        bcc = self._require_bcc()
        cap = bcc.pool_src.shape[0]
        _hit, flagged = q.edge_membership(
            _i32(u), _i32(v), bcc.pool_src, bcc.pool_dst, bcc.pool_valid,
            bcc.bridge[:cap])
        return flagged

    def is_articulation(self, state: DynamicForest, v) -> jnp.ndarray:
        self.ensure(state)
        bcc = self._require_bcc()
        vq = _i32(v)
        n = bcc.articulation.shape[0]
        return ((vq >= 0) & (vq < n)
                & bcc.articulation[jnp.clip(vq, 0, n - 1)])

    # -- telemetry ------------------------------------------------------------

    def sync_stats(self) -> dict:
        """Amortization counters for benchmarks / the serving loop."""
        return {"builds": self.builds,
                "build_syncs_total": self.build_syncs_total,
                "stale_served": self.stale_served,
                "auto_refreshes": self.auto_refreshes}
