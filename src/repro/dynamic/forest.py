"""Batch-dynamic rooted spanning forest: state + update application.

The static pipelines rebuild a tree from a frozen edge list; this module
maintains one under an *edge-update stream* (DESIGN.md §9). State is a
``DynamicForest`` pytree: the rooted parent array, its component
representatives (the PR-RST incremental invariant ``rep == roots_of(parent)``
carried across batches), and a fixed-capacity undirected edge pool — the
live multigraph, of which the parent array is always a spanning forest.

``apply_batch`` processes one batch of insertions + deletions in O(log n)
compress-engine steps:

  * **Deletions** cut deleted tree edges in one masked scatter (the child
    endpoint becomes the root of its severed subtree) and re-establish
    representatives with a *scoped* compression masked to the components
    that had a cut (``compress.compress_scoped`` — untouched components
    cost zero syncs).
  * **Insertions** land in free pool slots; slot assignment is one
    cumsum + gather, overflow (pool full) is counted, never silent.
  * **The link loop** then restores the spanning invariant: while any
    pool edge crosses two components, each *smaller* component (strict
    (size, root-id) order — union-by-size, so a component is re-rooted
    O(log n) times over its lifetime) picks one winning edge, re-roots
    itself at that edge's endpoint via the shared PR-RST path-reversal
    primitive (``core.reroot.link_components``) and grafts. Winning slots
    become tree edges. This one loop serves both roles: freshly inserted
    cross edges are the *insertion* case, surviving pool edges that cross
    a cut are the *replacement search* after a tree-edge deletion — a
    batched re-run of GConn hooking restricted to affected components.

Deletions address pool slots (``delete_mask``); ``edge_slots`` resolves a
batch of (u, v) pairs to slots, multiset-aware: k requests for the same
pair claim k distinct parallel copies. The pool is honestly a multigraph —
parallel edges occupy distinct slots and at most one copy per vertex pair
is ever a tree edge (the invariant ``connected_components``' edge-id-level
dedupe establishes for ``forest_from_graph``).

``dirty`` marks vertices whose component's *tree structure* changed since
the last tour refresh (cuts, re-roots, grafts — not non-tree pool edits);
``dynamic.tour`` consumes and clears it.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.compress import DEFAULT_JUMPS, compress_scoped
from repro.core.connectivity import connected_components
from repro.core.euler import euler_tour_root
from repro.core.graph import Graph
from repro.core.reroot import link_components

INF32 = jnp.iinfo(jnp.int32).max


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class DynamicForest:
    """Rooted spanning forest of a dynamic edge multiset.

    Attributes:
      n_nodes:    static vertex count n.
      parent:     int32[n] rooted forest; roots (and isolated vertices)
                  self-point. Always spans the pool graph's components.
      rep:        int32[n] component representative per vertex — the
                  incremental invariant ``rep == roots_of(parent)``.
      pool_src, pool_dst: int32[capacity] live undirected edge pool;
                  empty slots carry the ``n_nodes`` sentinel.
      pool_valid: bool[capacity] slot occupancy.
      tree_mask:  bool[capacity] — slot is a spanning-forest edge (exactly
                  n − n_components slots set; ≤ 1 per vertex pair).
      dirty:      bool[n] — vertex's component tree changed since the last
                  tour refresh (component-closed by construction).
      version:    int32 scalar, bumped by every structural mutation
                  (``apply_batch``, repair, rebuild). Derived-cache
                  consumers (``dynamic.queries.QuerySession``) stamp the
                  version they were built against and refuse/refresh on
                  mismatch (DESIGN.md §12).
    """

    n_nodes: int
    parent: jnp.ndarray
    rep: jnp.ndarray
    pool_src: jnp.ndarray
    pool_dst: jnp.ndarray
    pool_valid: jnp.ndarray
    tree_mask: jnp.ndarray
    dirty: jnp.ndarray
    version: jnp.ndarray

    def tree_flatten(self):
        return ((self.parent, self.rep, self.pool_src, self.pool_dst,
                 self.pool_valid, self.tree_mask, self.dirty, self.version),
                self.n_nodes)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(aux, *children)

    @property
    def capacity(self) -> int:
        return int(self.pool_src.shape[0])

    @property
    def n_components(self) -> jnp.ndarray:
        return jnp.sum((self.rep == jnp.arange(self.n_nodes)).astype(
            jnp.int32))

    @property
    def n_live_edges(self) -> jnp.ndarray:
        return jnp.sum(self.pool_valid.astype(jnp.int32))


def forest_empty(n_nodes: int, capacity: int) -> DynamicForest:
    """Edgeless forest over n vertices with an empty pool."""
    verts = jnp.arange(n_nodes, dtype=jnp.int32)
    sent = jnp.full((capacity,), n_nodes, jnp.int32)
    off = jnp.zeros((capacity,), jnp.bool_)
    return DynamicForest(
        n_nodes=n_nodes, parent=verts, rep=verts,
        pool_src=sent, pool_dst=sent, pool_valid=off, tree_mask=off,
        dirty=jnp.zeros((n_nodes,), jnp.bool_),
        version=jnp.int32(0))


def forest_from_graph(graph: Graph, capacity: int | None = None,
                      root: int = 0, *, batch_hint: int = 16,
                      use_kernel: bool = False) -> DynamicForest:
    """Seed the dynamic state from a static graph (GConn + Euler build).

    The pool holds the graph's M undirected edges in its first M slots.
    ``capacity`` must be ≥ M; the default leaves insertion headroom —
    ``max(M + 4 * batch_hint, ceil(1.25 * M))`` — so a freshly seeded
    forest absorbs insert-only batches instead of overflowing on the
    first one (pass ``capacity=M`` explicitly for a zero-headroom pool).
    The forest is the GConn spanning forest rooted at ``root`` (its
    component) / component reps (others).
    """
    n = graph.n_nodes
    m = graph.n_edges
    if capacity is None:
        capacity = max(m + 4 * batch_hint, -(-5 * m // 4))
    if capacity < m:
        raise ValueError(f"capacity {capacity} < graph edges {m}")

    rep, forest_mask, _ = connected_components(graph, use_kernel=use_kernel)
    t = max(n - 1, 1)
    m2 = graph.src.shape[0]
    slots = jnp.nonzero(forest_mask, size=t, fill_value=m2)[0]
    in_range = slots < m2
    safe = jnp.clip(slots, 0, max(m2 - 1, 0))
    fu = jnp.where(in_range, graph.src[safe], n)
    fv = jnp.where(in_range, graph.dst[safe], n)
    root_arr = jnp.asarray(root, jnp.int32)
    comp_root = jnp.where(rep == rep[root_arr], root_arr, rep)
    parent = euler_tour_root(n, fu, fv, in_range, comp_root,
                             use_kernel=use_kernel)

    pad = capacity - m
    sent = jnp.full((pad,), n, jnp.int32)
    # Winner half-edges are always canonical (e < M), so the undirected
    # tree mask is exactly the first half of forest_mask (the regression
    # test on connected_components enforces the canonical-half guarantee).
    tree = forest_mask[:m]
    return DynamicForest(
        n_nodes=n,
        parent=parent,
        rep=comp_root,
        pool_src=jnp.concatenate([graph.src[:m], sent]),
        pool_dst=jnp.concatenate([graph.dst[:m], sent]),
        pool_valid=jnp.concatenate([jnp.ones((m,), jnp.bool_),
                                    jnp.zeros((pad,), jnp.bool_)]),
        tree_mask=jnp.concatenate([tree, jnp.zeros((pad,), jnp.bool_)]),
        dirty=jnp.zeros((n,), jnp.bool_),
        version=jnp.int32(0))


def live_graph(state: DynamicForest) -> Graph:
    """The pool as a (sentinel-padded) ``Graph`` — the from-scratch view."""
    u = jnp.where(state.pool_valid, state.pool_src, state.n_nodes)
    v = jnp.where(state.pool_valid, state.pool_dst, state.n_nodes)
    return Graph.from_undirected(state.n_nodes, u, v)


@jax.jit
def edge_slots(state: DynamicForest, del_u: jnp.ndarray,
               del_v: jnp.ndarray):
    """Resolve (u, v) deletion requests to pool slots, multiset-aware.

    One lexsort over pool slots + requests keyed by the sorted endpoint
    pair (two int32 keys — no packed 64-bit key, so any n fits): within
    each equal-pair segment, pool copies sort before requests, and the
    r-th request for a pair claims the r-th parallel copy. Requests with
    no remaining copy (or sentinel padding ``u == n``) report not-found.

    Args:
      del_u, del_v: int32[D] endpoints; ``n_nodes`` marks padding slots.

    Returns:
      (delete_mask: bool[capacity] — one True per matched request,
       found: bool[D] — request matched a live pool slot).
    """
    n = state.n_nodes
    cap = state.pool_src.shape[0]
    d = del_u.shape[0]
    total = cap + d

    q_ok = (del_u >= 0) & (del_v >= 0) & (del_u < n) & (del_v < n)
    plo = jnp.minimum(state.pool_src, state.pool_dst)
    phi = jnp.maximum(state.pool_src, state.pool_dst)
    qlo = jnp.where(q_ok, jnp.minimum(del_u, del_v), n)
    qhi = jnp.where(q_ok, jnp.maximum(del_u, del_v), n)

    lo = jnp.concatenate([jnp.where(state.pool_valid, plo, n), qlo])
    hi = jnp.concatenate([jnp.where(state.pool_valid, phi, n), qhi])
    is_query = jnp.concatenate([jnp.zeros((cap,), jnp.bool_),
                                jnp.ones((d,), jnp.bool_)])
    idx = jnp.arange(total, dtype=jnp.int32)

    order = jnp.lexsort((idx, is_query, hi, lo)).astype(jnp.int32)
    slo, shi, squery = lo[order], hi[order], is_query[order]

    # Segment machinery over sorted (lo, hi) groups.
    pos = jnp.arange(total, dtype=jnp.int32)
    seg_start = jnp.concatenate([
        jnp.ones((1,), jnp.bool_),
        (slo[1:] != slo[:-1]) | (shi[1:] != shi[:-1])])
    first_pos = jax.lax.cummax(jnp.where(seg_start, pos, 0))
    # Pool copies occupy ranks [0, c) of their segment; the r-th query
    # (rank c + r) claims the copy at sorted position first_pos + r.
    seg_id = jnp.cumsum(seg_start.astype(jnp.int32)) - 1
    pool_in_seg = jnp.zeros((total,), jnp.int32).at[seg_id].add(
        (~squery).astype(jnp.int32))
    c = pool_in_seg[seg_id]
    rank = pos - first_pos
    claim_pos = jnp.clip(first_pos + (rank - c), 0, total - 1)
    matched = (squery & (rank - c < c)
               & (slo[claim_pos] == slo) & (shi[claim_pos] == shi)
               & ~squery[claim_pos] & (slo < n))

    claimed_slot = jnp.where(matched, order[claim_pos], cap)
    delete_mask = jnp.zeros((cap,), jnp.bool_).at[claimed_slot].set(
        True, mode="drop")
    found = jnp.zeros((d,), jnp.bool_).at[
        jnp.where(matched, order - cap, d)].set(True, mode="drop")
    return delete_mask, found


@partial(jax.jit, static_argnames=("max_rounds", "n_jumps", "use_kernel"))
def apply_batch(state: DynamicForest, insert_src: jnp.ndarray,
                insert_dst: jnp.ndarray, delete_mask: jnp.ndarray, *,
                max_rounds: int | None = None,
                n_jumps: int = DEFAULT_JUMPS, use_kernel: bool = False):
    """Apply one batch of edge deletions + insertions.

    Args:
      state: current forest (its invariants are the precondition).
      insert_src, insert_dst: int32[B] inserted undirected edges; slots
        with ``u == v`` or endpoints outside [0, n) are inert padding
        (use the ``n_nodes`` sentinel).
      delete_mask: bool[capacity] pool slots to delete (``edge_slots``
        resolves (u, v) pairs; already-empty slots are ignored).
      max_rounds: optional static bound on *productive* link rounds. If
        it truncates the loop, the spanning invariant is not restored —
        ``stats["pending"]`` reports the cross edges left unlinked.

    Returns:
      (state', stats) — stats is a dict of int32 scalars: ``cuts``
      (tree edges severed), ``links`` (components re-linked: insertions
      that merged + replacements found), ``rounds`` (productive link
      rounds), ``overflow`` (insertions dropped because the pool was
      full), ``pending`` (cross edges still unlinked — nonzero only
      when ``max_rounds`` cut the loop short).
    """
    n = state.n_nodes
    cap = state.pool_src.shape[0]
    verts = jnp.arange(n, dtype=jnp.int32)
    levels = max(1, (n - 1).bit_length())

    p = state.parent
    rt = state.rep
    pool_src, pool_dst = state.pool_src, state.pool_dst
    pool_valid, tree_mask = state.pool_valid, state.tree_mask
    touched = jnp.zeros((n,), jnp.bool_)

    # ---- deletions: cut tree edges, invalidate slots -----------------------
    del_mask = delete_mask & pool_valid
    del_tree = del_mask & tree_mask
    u_ = jnp.clip(pool_src, 0, n - 1)
    v_ = jnp.clip(pool_dst, 0, n - 1)
    child_is_v = p[v_] == u_
    child = jnp.where(child_is_v, v_, u_)
    other = jnp.where(child_is_v, u_, v_)
    do_cut = del_tree & (child_is_v | (p[u_] == v_))
    cut_idx = jnp.where(do_cut, child, n)
    p = p.at[cut_idx].set(jnp.where(do_cut, child, 0), mode="drop")
    touched = touched.at[cut_idx].set(True, mode="drop")
    touched = touched.at[jnp.where(do_cut, other, n)].set(True, mode="drop")
    n_cuts = jnp.sum(do_cut.astype(jnp.int32))

    pool_valid = pool_valid & ~del_mask
    tree_mask = tree_mask & ~del_mask
    pool_src = jnp.where(del_mask, n, pool_src)
    pool_dst = jnp.where(del_mask, n, pool_dst)

    # Representatives after cuts: scoped compression over the components
    # that lost a tree edge (component-closed mask ⇒ contract satisfied;
    # untouched components pay zero doubling syncs).
    comp_cut = jnp.zeros((n,), jnp.bool_).at[
        jnp.where(do_cut, rt[child], n)].set(True, mode="drop")
    active = comp_cut[rt]
    rt = jnp.where(active,
                   compress_scoped(p, active, n_jumps=n_jumps,
                                   use_kernel=use_kernel),
                   rt)

    # ---- insertions: append to free pool slots -----------------------------
    b = insert_src.shape[0]
    overflow = jnp.int32(0)
    if b > 0:
        ins_ok = ((insert_src != insert_dst)
                  & (insert_src >= 0) & (insert_src < n)
                  & (insert_dst >= 0) & (insert_dst < n))
        free = jnp.nonzero(~pool_valid, size=b, fill_value=cap)[0].astype(
            jnp.int32)
        rank = jnp.cumsum(ins_ok.astype(jnp.int32)) - 1
        slot = jnp.where(ins_ok, free[jnp.clip(rank, 0, b - 1)], cap)
        overflow = jnp.sum((ins_ok & (slot >= cap)).astype(jnp.int32))
        pool_src = pool_src.at[slot].set(insert_src, mode="drop")
        pool_dst = pool_dst.at[slot].set(insert_dst, mode="drop")
        pool_valid = pool_valid.at[slot].set(True, mode="drop")
        tree_mask = tree_mask.at[slot].set(False, mode="drop")

    # ---- link loop: restore the spanning invariant -------------------------
    # Any pool edge crossing two components is either a fresh insertion or
    # a replacement candidate exposed by a cut; the loop drains them all.
    def body(carry):
        p, rt, tree_mask, touched, rnd, links, _ = carry
        pu = jnp.clip(pool_src, 0, n - 1)
        pv = jnp.clip(pool_dst, 0, n - 1)
        ru = rt[pu]
        rv = rt[pv]
        cand = pool_valid & (ru != rv)

        # Union-by-size mover choice: the smaller component re-roots.
        # (size, root id) is a strict total order fixed for the round, so
        # the graft overlay inside link_components stays acyclic.
        size = jnp.zeros((n,), jnp.int32).at[rt].add(1)
        su, sv = size[ru], size[rv]
        u_moves = (su < sv) | ((su == sv) & (ru > rv))
        start = jnp.where(u_moves, pu, pv)
        target = jnp.where(u_moves, pv, pu)

        p, rt, is_winner = link_components(
            p, rt, start, target, cand, levels=levels, n_jumps=n_jumps,
            use_kernel=use_kernel)
        tree_mask = tree_mask | is_winner
        touched = touched.at[jnp.where(is_winner, start, n)].set(
            True, mode="drop")
        touched = touched.at[jnp.where(is_winner, target, n)].set(
            True, mode="drop")
        n_won = jnp.sum(is_winner.astype(jnp.int32))
        rnd = rnd + (n_won > 0).astype(jnp.int32)   # productive rounds only
        return p, rt, tree_mask, touched, rnd, links + n_won, n_won > 0

    def cond(carry):
        _p, _rt, _tm, _t, rnd, _l, changed = carry
        bound = n if max_rounds is None else max_rounds
        return changed & (rnd < bound)

    p, rt, tree_mask, touched, rounds, links, _ = jax.lax.while_loop(
        cond, body,
        (p, rt, tree_mask, touched, jnp.int32(0), jnp.int32(0),
         jnp.bool_(True)))

    # Cross edges still pending = 0 unless ``max_rounds`` truncated the
    # loop (in which case the spanning invariant is NOT restored — the
    # caller asked for a bounded round budget and must check this).
    pending = jnp.sum((pool_valid
                       & (rt[jnp.clip(pool_src, 0, n - 1)]
                          != rt[jnp.clip(pool_dst, 0, n - 1)])
                       ).astype(jnp.int32))

    # ---- dirty propagation: whole components containing touched vertices ---
    comp_touched = jnp.zeros((n,), jnp.bool_).at[
        jnp.where(touched, rt, n)].set(True, mode="drop")
    dirty = state.dirty | comp_touched[rt]

    new_state = DynamicForest(
        n_nodes=n, parent=p, rep=rt, pool_src=pool_src, pool_dst=pool_dst,
        pool_valid=pool_valid, tree_mask=tree_mask, dirty=dirty,
        version=state.version + 1)
    stats = {"cuts": n_cuts, "links": links, "rounds": rounds,
             "overflow": overflow, "pending": pending}
    return new_state, stats
