"""Incremental biconnectivity on the batch-dynamic forest (DESIGN.md §10).

The static layer (``core/bcc.py``) decomposes a frozen graph; this module
maintains per-half-edge BCC labels, bridges, and articulation points of
the ``DynamicForest``'s live edge pool *across* ``apply_batch`` calls,
scoped to dirty components the same way ``dynamic.tour.refresh_tour``
scopes the tour re-ranking. Dong et al. (*Provably Fast and
Space-Efficient Parallel Biconnectivity*) reduce BCC to a skeleton over
the spanning tree; Hong et al. show incremental variants of exactly
these connectivity primitives win on GPUs — so labels are maintained
under batches, not recomputed.

Why caching is sound (the §10 contract):

  * **Dirty detection is snapshot-diff, not flag-plumbing.** A
    ``DynamicBCC`` carries the parent array and pool arrays it was
    computed against. At refresh time, a vertex is *changed* if its
    parent link differs or it is an endpoint (old or new) of any pool
    slot whose (src, dst, valid, tree) content differs; a component is
    BCC-dirty iff it contains a changed vertex (closure over the new
    ``state.rep``). This catches what the tour's ``dirty`` mask
    deliberately ignores — non-tree pool edits change the decomposition
    without changing the tree — and is robust to any refresh cadence.
  * **Clean components are bit-stable.** GConn labels the aux graph
    with pure-min hooking, so a block's label is its minimum member id
    — content-determined, not history-determined. A clean component has
    the identical vertex set, edge multiset, and tree, hence the
    identical aux subgraph and identical labels/bridges/articulation.
  * **low/high shift by a per-component δ.** Clean components keep
    their relative preorder but their dense block may slide when other
    components change size or representative; low/high are preorder
    values *within* the component, so the cached values are re-based by
    ``δ[v] = pre_new[v] − pre_cached[v]`` (constant per clean comp).

The scoped recompute itself is one ``core.bcc.bcc_from_tour`` call with
``scope=dirty``: clean components' edges are masked to padding, the
low/high sparse tables build only to the longest dirty component
(``compress.segment_reduce_scoped``), and the aux GConn pass hooks
nothing clean — so clean components pay zero doubling syncs.

``refresh_bcc(state, cached, incremental=...)`` is bit-identical to a
full recompute (regression-tested in tests/test_dynamic_bcc.py);
``incremental=False`` is the ablation baseline
``benchmarks/table5_dynamic_bcc.py`` measures against.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.bcc import bcc_from_tour
from repro.core.euler import TourNumbering, tour_numbering
from repro.dynamic.forest import DynamicForest, live_graph


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class DynamicBCC:
    """Biconnectivity of the live pool + the snapshots that validate it.

    Attributes (C = pool capacity; half-edge arrays follow the pool's
    ``Graph`` view: slot e < C is pool direction src→dst, e + C its
    reverse):
      n_nodes:      static vertex count n.
      parent:       int32[n] — parent snapshot the decomposition is for.
      pool_src, pool_dst: int32[C] pool snapshot (sentinel-padded).
      pool_valid:   bool[C] occupancy snapshot.
      tree_mask:    bool[C] tree-slot snapshot.
      pre:          int32[n] tour preorder the low/high values live in.
      rep:          int32[n] aux-component label per vertex — the BCC
                    label of the tree edge above v (min member id;
                    garbage at roots).
      low, high:    int32[n] subtree preorder extremes (DESIGN.md §4).
      articulation: bool[n] cut vertices.
      bridge:       bool[2C] per half-edge (both directions marked).
      edge_bcc:     int32[2C] BCC label per half-edge (−1 on padding).
      n_bcc:        int32 — number of biconnected components.
      aux_rounds:   int32 — GConn rounds of the last refresh.
      seg_syncs:    int32 — low/high doubling levels of the last refresh.
      dirty_count:  int32 — vertices recomputed by the last refresh
                    (== n for a full recompute).
    """

    n_nodes: int
    parent: jnp.ndarray
    pool_src: jnp.ndarray
    pool_dst: jnp.ndarray
    pool_valid: jnp.ndarray
    tree_mask: jnp.ndarray
    pre: jnp.ndarray
    rep: jnp.ndarray
    low: jnp.ndarray
    high: jnp.ndarray
    articulation: jnp.ndarray
    bridge: jnp.ndarray
    edge_bcc: jnp.ndarray
    n_bcc: jnp.ndarray
    aux_rounds: jnp.ndarray
    seg_syncs: jnp.ndarray
    dirty_count: jnp.ndarray

    def tree_flatten(self):
        return ((self.parent, self.pool_src, self.pool_dst,
                 self.pool_valid, self.tree_mask, self.pre, self.rep,
                 self.low, self.high, self.articulation, self.bridge,
                 self.edge_bcc, self.n_bcc, self.aux_rounds,
                 self.seg_syncs, self.dirty_count), self.n_nodes)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(aux, *children)

    @property
    def n_bridges(self) -> jnp.ndarray:
        """Undirected bridge count (each bridge marks both halves)."""
        return jnp.sum(self.bridge.astype(jnp.int32)) // 2

    @property
    def n_articulation(self) -> jnp.ndarray:
        return jnp.sum(self.articulation.astype(jnp.int32))


def _snapshot(state: DynamicForest, tn: TourNumbering, out, dirty_count):
    return DynamicBCC(
        n_nodes=state.n_nodes, parent=state.parent,
        pool_src=state.pool_src, pool_dst=state.pool_dst,
        pool_valid=state.pool_valid, tree_mask=state.tree_mask,
        pre=tn.pre, rep=out["rep"], low=out["low"], high=out["high"],
        articulation=out["articulation"], bridge=out["bridge"],
        edge_bcc=out["edge_bcc"], n_bcc=out["n_bcc"],
        aux_rounds=out["aux_rounds"], seg_syncs=out["seg_syncs"],
        dirty_count=dirty_count)


def _pool_tree_mask(state: DynamicForest) -> jnp.ndarray:
    """Per-half-edge tree classification of the pool's Graph view."""
    return jnp.concatenate([state.tree_mask, state.tree_mask])


@partial(jax.jit, static_argnames=("use_kernel",))
def _refresh_full(state: DynamicForest, tn: TourNumbering, *,
                  use_kernel: bool = False) -> DynamicBCC:
    out = bcc_from_tour(live_graph(state), state.parent, tn,
                        tree_mask=_pool_tree_mask(state),
                        use_kernel=use_kernel)
    return _snapshot(state, tn, out, jnp.int32(state.n_nodes))


@partial(jax.jit, static_argnames=("use_kernel",))
def _refresh_incremental(state: DynamicForest, tn: TourNumbering,
                         cached: DynamicBCC, *,
                         use_kernel: bool = False) -> DynamicBCC:
    n = state.n_nodes
    verts = jnp.arange(n, dtype=jnp.int32)

    # ---- dirty detection: diff against the cached snapshots ---------------
    changed = state.parent != cached.parent
    slot_changed = ((state.pool_src != cached.pool_src)
                    | (state.pool_dst != cached.pool_dst)
                    | (state.pool_valid != cached.pool_valid)
                    | (state.tree_mask != cached.tree_mask))
    for ends in (cached.pool_src, cached.pool_dst,
                 state.pool_src, state.pool_dst):
        changed = changed.at[jnp.where(slot_changed, ends, n)].set(
            True, mode="drop")
    # Closure over the *new* components: merges/splits both leave a
    # changed vertex in every affected new component.
    comp_changed = jnp.zeros((n,), jnp.bool_).at[
        jnp.where(changed, state.rep, n)].set(True, mode="drop")
    dirty = comp_changed[state.rep]
    dirty_count = jnp.sum(dirty.astype(jnp.int32))

    # ---- scoped recompute + merge with the cache --------------------------
    out = bcc_from_tour(live_graph(state), state.parent, tn,
                        tree_mask=_pool_tree_mask(state), scope=dirty,
                        use_kernel=use_kernel)

    # Per-vertex merges. Clean low/high re-base by the per-component
    # block shift δ = pre_new − pre_cached.
    delta = tn.pre - cached.pre
    rep = jnp.where(dirty, out["rep"], cached.rep)
    low = jnp.where(dirty, out["low"], cached.low + delta)
    high = jnp.where(dirty, out["high"], cached.high + delta)
    articulation = jnp.where(dirty, out["articulation"],
                             cached.articulation)

    # Per-half-edge merges: a slot that is live and clean keeps its
    # cached values (its content is untouched by construction); dirty
    # and padding slots take the scoped result (which already emits the
    # −1/False padding values a full recompute would).
    src2 = jnp.concatenate([state.pool_src, state.pool_dst])
    valid2 = jnp.concatenate([state.pool_valid, state.pool_valid])
    clean_slot = valid2 & ~dirty[jnp.clip(src2, 0, n - 1)]
    edge_bcc = jnp.where(clean_slot, cached.edge_bcc, out["edge_bcc"])
    bridge = jnp.where(clean_slot, cached.bridge, out["bridge"])

    # Global count from the merged labels (the scoped run's own count
    # would treat every clean vertex as a singleton block).
    nonroot = tn.parent != verts
    n_bcc = jnp.sum((nonroot & (rep == verts)).astype(jnp.int32))

    out = dict(rep=rep, low=low, high=high, articulation=articulation,
               bridge=bridge, edge_bcc=edge_bcc, n_bcc=n_bcc,
               aux_rounds=out["aux_rounds"], seg_syncs=out["seg_syncs"])
    return _snapshot(state, tn, out, dirty_count)


def refresh_bcc(state: DynamicForest, cached: DynamicBCC | None = None, *,
                tour: TourNumbering | None = None, incremental: bool = True,
                use_kernel: bool = False) -> DynamicBCC:
    """Refresh the pool's biconnectivity after ``apply_batch`` calls.

    Deprecated thin wrapper: the canonical entry is
    ``dynamic.view.refresh_bcc_once`` (or ``ForestView.refresh`` for
    cadenced loops). Kept so existing callers keep working unchanged.

    Args:
      state: the dynamic forest (spanning invariant restored — i.e. not
        mid-``max_rounds``-truncation).
      cached: the ``DynamicBCC`` from the previous refresh. ``None``
        forces a full recompute (the first call).
      tour: the current ``TourNumbering`` of ``state.parent`` — pass the
        one ``refresh_tour`` maintains; ``None`` computes a fresh full
        numbering here.
      incremental: ablation flag — ``False`` always recomputes from
        scratch (the ``table5_dynamic_bcc`` baseline). The result is
        bit-identical either way.
      use_kernel: route engine phases through their Pallas kernels.

    Returns:
      DynamicBCC — pass it back as ``cached`` next time. Unlike
      ``refresh_tour`` this does not touch ``state.dirty`` (the tour
      refresh owns that mask); dirty tracking here is snapshot-diff.
    """
    from repro.dynamic.view import refresh_bcc_once

    return refresh_bcc_once(state, cached, tour=tour,
                            incremental=incremental, use_kernel=use_kernel)
