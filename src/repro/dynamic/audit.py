"""O(log n)-sync invariant auditing for the dynamic forest (DESIGN.md §11).

``audit_forest`` checks every structural invariant a healthy
``DynamicForest`` maintains — entirely device-side, built from the same
engine primitives the read path uses (one bounded ``compress_full`` plus
masked scatters/reductions), so a full audit costs
⌈log2(depth)/k⌉ + 1 convergence syncs like any other engine phase:

  * **acyclicity / rooted-ness** — every parent chain must reach a fixed
    point *of the original table* (the ``validate.reaches_root``
    technique: bounded compression, then re-check against the uncompressed
    table so even-length cycles cannot fake a root);
  * **root fixed-point** — every claimed representative is in range and
    self-parented;
  * **rep-partition consistency** — ``rep == roots_of(parent)``, the
    invariant all scoped primitives rely on;
  * **tree cover** — every non-root vertex is the child endpoint of
    exactly one live tree slot, and roots of none;
  * **tree-slot sanity** — ``tree_mask ⊆ pool_valid``, tree endpoints in
    range, parent-linked, and in one claimed component;
  * **spanning** — no live pool edge crosses two claimed components
    (the forest must span the pool graph: a cross edge is a link the
    maintenance loop would never have left behind);
  * **tree-edge count** — #live tree slots == n − #parent self-loops (the
    global n − c redundancy check);
  * **snapshot freshness** (optional) — a ``TourNumbering`` must agree
    with the live parent array outside ``state.dirty``; a ``DynamicBCC``'s
    snapshots must agree with the live parent/pool arrays. A mismatch is
    exactly the fault ``chaos.inject_stale_bcc`` plants: a cache whose
    labels no snapshot-diff will ever invalidate.

The returned ``AuditReport`` is a pytree: scalar verdicts for the ladder
in ``dynamic.recovery``, plus the per-vertex ``comp_violating`` mask —
the violation set closed over *both* the claimed (``rep``) and actual
(compressed-root) components, which is the scope the repair path rebuilds.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.compress import DEFAULT_JUMPS, compress_full
from repro.core.euler import TourNumbering
from repro.dynamic.bcc import DynamicBCC
from repro.dynamic.forest import DynamicForest

#: Sync bound for the audit compression: 64 checks × k doublings covers
#: any real chain (2^320); cycles are the only inputs that hit the bound.
AUDIT_MAX_SYNCS = 64


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class AuditReport:
    """Verdicts + violation masks from one ``audit_forest`` call.

    Scalars are 0-d jnp arrays; ``bool(report.healthy)`` is host-safe.

    Attributes:
      n_nodes:        static vertex count.
      acyclic:        every chain reaches a true fixed point.
      roots_fixed:    claimed reps are in range and self-parented.
      rep_consistent: rep matches the compressed root everywhere.
      tree_cover_ok:  non-roots covered by exactly one tree slot.
      tree_slots_ok:  no tree bit on dead/unlinked/cross-component slots.
      spanning_ok:    no live pool edge crosses two claimed components.
      counts_ok:      #tree slots == n − #roots.
      forest_ok:      conjunction of the seven structural verdicts.
      tour_fresh:     tour numbering consistent with live parent (True
                      when no tour was passed).
      bcc_fresh:      BCC snapshots consistent with live state (True when
                      no cache was passed).
      healthy:        forest_ok & tour_fresh & bcc_fresh.
      violating:      bool[n] per-vertex structural violations.
      comp_violating: bool[n] — ``violating`` closed over claimed AND
                      actual components (the repair scope).
      sever:          bool[n] — the minimal cut set for the repair: a
                      vertex whose parent pointer itself is broken
                      (out of range, not backed by exactly one live
                      tree slot, or a spurious cycle fixed point).
                      Inherited damage — a subtree dragged along by an
                      ancestor's flip, or a stale ``rep`` — is NOT in
                      this mask: severing the one broken ancestor frees
                      the subtree intact, and ``rep`` is re-derived
                      over ``comp_violating`` regardless.
      stale:          bool[n] — snapshot-staleness, component-closed (the
                      cache-refresh scope; disjoint concern from repair).
      bad_slots:      bool[capacity] pool slots violating tree-slot sanity.
      n_violating:    int32 vertex count of ``comp_violating``.
      syncs:          int32 engine convergence checks spent auditing.
    """

    n_nodes: int
    acyclic: jnp.ndarray
    roots_fixed: jnp.ndarray
    rep_consistent: jnp.ndarray
    tree_cover_ok: jnp.ndarray
    tree_slots_ok: jnp.ndarray
    spanning_ok: jnp.ndarray
    counts_ok: jnp.ndarray
    forest_ok: jnp.ndarray
    tour_fresh: jnp.ndarray
    bcc_fresh: jnp.ndarray
    healthy: jnp.ndarray
    violating: jnp.ndarray
    comp_violating: jnp.ndarray
    sever: jnp.ndarray
    stale: jnp.ndarray
    bad_slots: jnp.ndarray
    n_violating: jnp.ndarray
    syncs: jnp.ndarray

    def tree_flatten(self):
        return ((self.acyclic, self.roots_fixed, self.rep_consistent,
                 self.tree_cover_ok, self.tree_slots_ok, self.spanning_ok,
                 self.counts_ok,
                 self.forest_ok, self.tour_fresh, self.bcc_fresh,
                 self.healthy, self.violating, self.comp_violating,
                 self.sever, self.stale, self.bad_slots, self.n_violating,
                 self.syncs), self.n_nodes)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(aux, *children)

    #: the individually-reportable verdicts, in report order.
    VERDICTS = ("acyclic", "roots_fixed", "rep_consistent",
                "tree_cover_ok", "tree_slots_ok", "spanning_ok",
                "counts_ok", "tour_fresh", "bcc_fresh")

    def violations(self) -> list[str]:
        """Names of the failed verdicts (host-side; empty when healthy)."""
        return [k for k in self.VERDICTS if not bool(getattr(self, k))]

    def summary(self) -> str:
        """One-line human verdict (host-side)."""
        if bool(self.healthy):
            return f"healthy (syncs={int(self.syncs)})"
        return (f"FAULT {'+'.join(self.violations())} "
                f"({int(self.n_violating)} vertices in scope, "
                f"syncs={int(self.syncs)})")


def _close_over_components(mask, rep_key, hop, n):
    """Close a vertex mask over claimed (rep) and actual (hop) components."""
    out = mask
    for key in (rep_key, hop):
        comp_bad = jnp.zeros((n,), jnp.bool_).at[
            jnp.where(mask, key, n)].set(True, mode="drop")
        out = out | comp_bad[key]
    return out


@partial(jax.jit, static_argnames=("n_jumps",))
def _audit(state: DynamicForest, tn, bcc, *, n_jumps: int = DEFAULT_JUMPS):
    n = state.n_nodes
    verts = jnp.arange(n, dtype=jnp.int32)
    p = state.parent
    rep = state.rep

    # ---- acyclicity + rooted-ness (reaches_root technique) ----------------
    in_range = (p >= 0) & (p < n)
    mapped = jnp.where(in_range, p, verts)
    hop, syncs = compress_full(mapped, n_jumps=n_jumps,
                               max_syncs=AUDIT_MAX_SYNCS, return_syncs=True)
    reach = mapped[hop] == hop          # true fixed point of the original
    viol = ~reach | ~in_range
    acyclic = jnp.all(reach)

    # ---- root fixed-point + rep partition ---------------------------------
    rep_in_range = (rep >= 0) & (rep < n)
    safe_rep = jnp.clip(rep, 0, n - 1)
    root_fixed_v = rep_in_range & (mapped[safe_rep] == safe_rep)
    rep_ok_v = rep_in_range & (rep == hop)
    viol = viol | ~root_fixed_v | ~rep_ok_v
    roots_fixed = jnp.all(root_fixed_v)
    rep_consistent = jnp.all(rep_ok_v)

    # ---- tree-slot sanity --------------------------------------------------
    u, v = state.pool_src, state.pool_dst
    live, tree = state.pool_valid, state.tree_mask
    ep_ok = (u >= 0) & (u < n) & (v >= 0) & (v < n)
    uc = jnp.clip(u, 0, n - 1)
    vc = jnp.clip(v, 0, n - 1)
    linked = (mapped[uc] == vc) | (mapped[vc] == uc)
    same_rep = rep[uc] == rep[vc]
    bad_slots = ((tree & ~live)
                 | (tree & live & (~ep_ok | ~linked | ~same_rep))
                 | (live & ~ep_ok))
    tree_slots_ok = ~jnp.any(bad_slots)
    # Spanning: a live in-range edge between two claimed components is a
    # link the maintenance loop would never have left pending — either an
    # injected endpoint redirect or a corrupted rep. Its endpoints join
    # the violation set so the repair scope covers (and relinks) both
    # sides; the slot itself is *good* data, not quarantined.
    cross = live & ep_ok & (rep[uc] != rep[vc])
    spanning_ok = ~jnp.any(cross)
    for ends in (u, v):
        viol = viol.at[jnp.where((bad_slots | cross) & ep_ok, ends, n)].set(
            True, mode="drop")

    # ---- tree cover: each non-root child of exactly one tree slot ---------
    slot_tree = tree & live & linked & ep_ok
    child_is_v = mapped[vc] == uc
    child = jnp.where(child_is_v, vc, uc)
    count = jnp.zeros((n,), jnp.int32).at[
        jnp.where(slot_tree, child, n)].add(1, mode="drop")
    nonroot = in_range & (p != verts)
    cover_ok_v = jnp.where(nonroot, count == 1, count == 0)
    viol = viol | ~cover_ok_v
    tree_cover_ok = jnp.all(cover_ok_v)

    # Minimal cut set for the scoped repair: vertices whose OWN parent
    # pointer is unusable. A redirected/forged pointer always breaks the
    # one-tree-slot cover at its child; a cycle whose every link is
    # tree-backed evades cover, but even-length cycles collapse to
    # self-fixed points under bounded compression — sever those too.
    # (Inherited rep/reach damage below a broken ancestor heals itself
    # once the ancestor is cut.)
    sever = ~in_range | ~cover_ok_v | (~reach & (hop == verts))

    # ---- global n − c redundancy ------------------------------------------
    n_tree = jnp.sum((tree & live).astype(jnp.int32))
    n_roots = jnp.sum((in_range & (p == verts)).astype(jnp.int32))
    counts_ok = n_tree == (n - n_roots)

    # ---- snapshot freshness -----------------------------------------------
    stale = jnp.zeros((n,), jnp.bool_)
    tour_fresh = jnp.bool_(True)
    if tn is not None:
        tour_stale_v = (tn.parent != mapped) & ~state.dirty
        tour_fresh = ~jnp.any(tour_stale_v)
        stale = stale | tour_stale_v
    bcc_fresh = jnp.bool_(True)
    if bcc is not None:
        bcc_stale_v = bcc.parent != p
        slot_mism = ((bcc.pool_src != u) | (bcc.pool_dst != v)
                     | (bcc.pool_valid != live) | (bcc.tree_mask != tree))
        for ends in (bcc.pool_src, bcc.pool_dst, u, v):
            bcc_stale_v = bcc_stale_v.at[
                jnp.where(slot_mism, ends, n)].set(True, mode="drop")
        bcc_fresh = ~jnp.any(bcc_stale_v)
        stale = stale | bcc_stale_v

    # ---- closures + verdicts ----------------------------------------------
    rep_key = jnp.where(rep_in_range, rep, verts)
    comp_violating = _close_over_components(viol, rep_key, hop, n)
    stale = _close_over_components(stale, rep_key, hop, n)
    forest_ok = (acyclic & roots_fixed & rep_consistent & tree_cover_ok
                 & tree_slots_ok & spanning_ok & counts_ok)
    healthy = forest_ok & tour_fresh & bcc_fresh
    return AuditReport(
        n_nodes=n, acyclic=acyclic, roots_fixed=roots_fixed,
        rep_consistent=rep_consistent, tree_cover_ok=tree_cover_ok,
        tree_slots_ok=tree_slots_ok, spanning_ok=spanning_ok,
        counts_ok=counts_ok,
        forest_ok=forest_ok, tour_fresh=tour_fresh, bcc_fresh=bcc_fresh,
        healthy=healthy, violating=viol, comp_violating=comp_violating,
        sever=sever, stale=stale, bad_slots=bad_slots,
        n_violating=jnp.sum(comp_violating.astype(jnp.int32)), syncs=syncs)


def audit_forest(state: DynamicForest, tn: TourNumbering | None = None,
                 bcc: DynamicBCC | None = None, *,
                 n_jumps: int = DEFAULT_JUMPS) -> AuditReport:
    """Audit every invariant of ``state`` (and optional caches) on device.

    Args:
      state: the dynamic forest to audit (may be arbitrarily corrupted —
        no check here assumes any invariant holds).
      tn: optional tour numbering to freshness-check against ``state``
        (``state.dirty`` components are exempt: they are *known* stale
        until the next ``refresh_tour``).
      bcc: optional BCC cache to freshness-check (snapshot equality — a
        cache that drifted from the state it claims to describe can
        never be healed by its own snapshot diff, so the audit is the
        only detector for it).
      n_jumps: doubling steps per convergence sync (engine contract).

    Returns:
      AuditReport; ``report.healthy`` is the single go/no-go bit,
      ``report.comp_violating`` the scope ``recovery.repair_forest``
      rebuilds, ``report.stale`` the scope whose caches must refresh.

    Host wrapper over the jitted audit: reports ``report.syncs`` to the
    ambient ``obs`` ledger under the ``audit`` phase.
    """
    from repro import obs

    report = _audit(state, tn, bcc, n_jumps=n_jumps)
    obs.record("audit", lambda: int(report.syncs))
    return report
