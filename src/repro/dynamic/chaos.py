"""Deterministic fault injection for the dynamic serving path (DESIGN.md §11).

Two fault families, matching how a live ``DynamicForest`` deployment
actually breaks:

* **State corruption** (``INJECTORS``): a bit flips in device memory or a
  bug writes a bad slot — the parent array gains a cycle or a dangling
  pointer, ``tree_mask`` desyncs from the pool, a representative goes
  stale, a ``DynamicBCC`` cache keeps labels for a state it no longer
  matches. Each injector takes ``(state, bcc, rng)`` and returns the
  corrupted ``(state, bcc, description)``; all randomness flows through
  the caller's ``numpy`` generator, so a seed reproduces the fault
  exactly. Every injector produces a fault that
  ``dynamic.audit.audit_forest`` provably detects (the chaos soak in
  tests/test_chaos_recovery.py enforces this per injector × seed).

* **Stream pollution** (``POLLUTERS``): malformed traffic — out-of-range
  vertex ids, self-loop insertions, duplicated or reordered batches,
  deletions of edges that were never inserted. Polluters rewrite a batch
  list; ``sanitize_batch`` is the defense that runs *in front of*
  ``apply_batch``: it rejects malformed events by rewriting them to the
  inert ``n_nodes`` sentinel and returns per-category quarantine
  counters, so garbage traffic becomes an observable metric instead of
  undefined behavior.

The corruption here is honest about what is and is not recoverable: the
edge pool is the system's ground truth, so injectors corrupt the *derived*
structures (parent / rep / tree_mask / caches) or redirect pool endpoints
to other live vertices — faults a pool-driven repair can heal — never the
existence of the truth itself.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.data.streams import EdgeStream, StreamBatch
from repro.dynamic.bcc import DynamicBCC
from repro.dynamic.forest import DynamicForest


# ---------------------------------------------------------------------------
# state corruption
# ---------------------------------------------------------------------------

def _np_state(state: DynamicForest):
    return {f: np.asarray(getattr(state, f)).copy()
            for f in ("parent", "rep", "pool_src", "pool_dst",
                      "pool_valid", "tree_mask", "dirty", "version")}


def _mk_state(state: DynamicForest, arrs) -> DynamicForest:
    return DynamicForest(n_nodes=state.n_nodes,
                         **{k: jnp.asarray(v) for k, v in arrs.items()})


def _nonroot(parent: np.ndarray, rng: np.random.Generator) -> int:
    """A uniformly random non-root vertex (falls back to 0 on edgeless)."""
    cand = np.nonzero(parent != np.arange(parent.shape[0]))[0]
    return int(rng.choice(cand)) if cand.size else 0


def inject_parent_bitflip(state: DynamicForest, bcc, rng):
    """Flip one bit of one parent entry — the classic soft-error model.

    The flipped pointer either leaves [0, n) (dangling) or lands on some
    other vertex, in which case v's claimed parent edge no longer matches
    any tree slot (the audit's coverage check) and usually crosses
    components (rep consistency).
    """
    arrs = _np_state(state)
    p = arrs["parent"]
    n = state.n_nodes
    v = _nonroot(p, rng)
    old = int(p[v])
    bit = int(rng.integers(0, max(n.bit_length(), 1)))
    new = old ^ (1 << bit)
    if new == old:          # unreachable, but stay total
        new = old + 1
    p[v] = new
    return (_mk_state(state, arrs), bcc,
            f"parent_bitflip: parent[{v}] {old} -> {new} (bit {bit})")


def inject_parent_cycle(state: DynamicForest, bcc, rng):
    """Point a component's root back at one of its descendants.

    Turns the root path of every vertex above the cycle into a trap:
    pointer chasing never reaches a fixed point of the original table
    (the acyclicity check's definition of failure).
    """
    arrs = _np_state(state)
    p = arrs["parent"]
    v = _nonroot(p, rng)
    # Walk to v's root, then close the cycle root -> v.
    r = v
    for _ in range(state.n_nodes):
        if p[r] == r:
            break
        r = int(p[r])
    p[r] = v
    return (_mk_state(state, arrs), bcc,
            f"parent_cycle: parent[{r}] -> {v} (root re-entry)")


def inject_rep_corrupt(state: DynamicForest, bcc, rng):
    """Write a wrong representative — the incremental invariant breaks.

    ``rep == roots_of(parent)`` is what lets every scoped primitive skip
    clean components; a stale entry silently mis-scopes all of them.
    """
    arrs = _np_state(state)
    n = state.n_nodes
    v = int(rng.integers(0, n))
    old = int(arrs["rep"][v])
    new = int(rng.integers(0, n))
    if new == old:
        new = (new + 1) % n
    arrs["rep"][v] = new
    return (_mk_state(state, arrs), bcc,
            f"rep_corrupt: rep[{v}] {old} -> {new}")


def inject_tree_mask_desync(state: DynamicForest, bcc, rng):
    """Desync ``tree_mask`` from the forest: drop a tree slot or forge one.

    Dropping leaves a non-root vertex with no covering tree slot; forging
    marks a live non-tree slot (or a dead slot) as a tree edge whose
    endpoints are not parent-linked.
    """
    arrs = _np_state(state)
    tm, pv = arrs["tree_mask"], arrs["pool_valid"]
    tree_slots = np.nonzero(tm & pv)[0]
    nontree_slots = np.nonzero(pv & ~tm)[0]
    if not tree_slots.size and not nontree_slots.size:
        # Empty pool: forge a tree bit on a dead slot (tree ⊆ valid breaks).
        tm[0] = True
        return (_mk_state(state, arrs), bcc,
                "tree_mask_desync: forged tree bit on dead slot 0")
    drop = bool(rng.integers(0, 2)) if tree_slots.size and \
        nontree_slots.size else bool(tree_slots.size)
    if drop:
        s = int(rng.choice(tree_slots))
        tm[s] = False
        desc = f"tree_mask_desync: dropped tree slot {s}"
    else:
        s = int(rng.choice(nontree_slots))
        tm[s] = True
        desc = f"tree_mask_desync: forged tree slot {s}"
    return _mk_state(state, arrs), bcc, desc


def inject_pool_desync(state: DynamicForest, bcc, rng):
    """Redirect one endpoint of a live tree slot to another vertex.

    The pool is ground truth, so this *changes the graph* — but the
    parent array still encodes the old edge, so state and pool disagree:
    the forged slot fails the parent-link check and the orphaned child
    loses its cover. Repair must re-derive the forest from the new pool.
    """
    arrs = _np_state(state)
    n = state.n_nodes
    slots = np.nonzero(arrs["tree_mask"] & arrs["pool_valid"])[0]
    if slots.size == 0:
        slots = np.nonzero(arrs["pool_valid"])[0]
    if slots.size == 0:                  # empty pool: fall back to rep fault
        return inject_rep_corrupt(state, bcc, rng)
    s = int(rng.choice(slots))
    side = "pool_src" if rng.integers(0, 2) else "pool_dst"
    old = int(arrs[side][s])
    other = int(arrs["pool_dst" if side == "pool_src" else "pool_src"][s])
    new = int(rng.integers(0, n))
    while new in (old, other):
        new = (new + 1) % n
    arrs[side][s] = new
    return (_mk_state(state, arrs), bcc,
            f"pool_desync: {side}[{s}] {old} -> {new}")


def inject_stale_bcc(state: DynamicForest, bcc: DynamicBCC | None, rng):
    """Corrupt a BCC cache *and* its snapshot — the stale-cache fault.

    ``refresh_bcc``'s snapshot diff heals honest staleness by itself; the
    dangerous fault is a cache whose labels rotted while its snapshots
    drifted (e.g. a partial write). Scramble the labels of one component
    and perturb the parent snapshot inside it: the audit's freshness
    check (snapshot == live state outside ``state.dirty``) flags it, and
    recovery re-derives the component from the live pool.
    """
    if bcc is None:
        return inject_rep_corrupt(state, bcc, rng)
    n = state.n_nodes
    rep = np.asarray(state.rep)
    v = int(rng.integers(0, n))
    comp = rep == rep[v]
    parent_snap = np.asarray(bcc.parent).copy()
    labels = np.asarray(bcc.rep).copy()
    arti = np.asarray(bcc.articulation).copy()
    # Drift the snapshot at one in-component vertex and rot the labels.
    w = int(rng.choice(np.nonzero(comp)[0]))
    parent_snap[w] = (parent_snap[w] + 1) % n
    labels[comp] = (labels[comp] + 1) % n
    arti[comp] = ~arti[comp]
    bcc2 = dataclasses.replace(bcc, parent=jnp.asarray(parent_snap),
                               rep=jnp.asarray(labels),
                               articulation=jnp.asarray(arti))
    return state, bcc2, f"stale_bcc: component of {v} rotted (snap at {w})"


#: name -> injector(state, bcc, rng) -> (state, bcc, description)
INJECTORS = {
    "parent_bitflip": inject_parent_bitflip,
    "parent_cycle": inject_parent_cycle,
    "rep_corrupt": inject_rep_corrupt,
    "tree_mask_desync": inject_tree_mask_desync,
    "pool_desync": inject_pool_desync,
    "stale_bcc": inject_stale_bcc,
}


def inject(name: str, state: DynamicForest, bcc=None, seed: int = 0):
    """Run one named injector with a seeded generator (test entry point)."""
    rng = np.random.default_rng(seed)
    return INJECTORS[name](state, bcc, rng)


# ---------------------------------------------------------------------------
# stream pollution
# ---------------------------------------------------------------------------

def pollute_out_of_range(batches, n, rng):
    """Sprinkle ids outside [0, n) over insert/delete slots."""
    out = []
    for b in batches:
        iu, iv = b.ins_u.copy(), b.ins_v.copy()
        du, dv = b.del_u.copy(), b.del_v.copy()
        for arr in (iu, du):
            k = int(rng.integers(1, 3))
            idx = rng.integers(0, arr.shape[0], size=k)
            arr[idx] = rng.choice([-7, -1, n + 1, n + 13], size=k)
        out.append(StreamBatch(ins_u=iu, ins_v=iv, del_u=du, del_v=dv))
    return out


def pollute_self_loops(batches, n, rng):
    """Turn some insertions into self-loops (u, u)."""
    out = []
    for b in batches:
        iu, iv = b.ins_u.copy(), b.ins_v.copy()
        live = np.nonzero(iu < n)[0]
        if live.size:
            idx = rng.choice(live, size=max(1, live.size // 8),
                             replace=False)
            iv[idx] = iu[idx]
        out.append(StreamBatch(ins_u=iu, ins_v=iv, del_u=b.del_u,
                               del_v=b.del_v))
    return out


def pollute_duplicate_batches(batches, n, rng):
    """Replay a batch twice in a row (at-least-once delivery)."""
    if not batches:
        return list(batches)
    i = int(rng.integers(0, len(batches)))
    out = list(batches)
    out.insert(i, out[i])
    return out


def pollute_reordered_batches(batches, n, rng):
    """Swap two adjacent batches (out-of-order delivery)."""
    out = list(batches)
    if len(out) >= 2:
        i = int(rng.integers(0, len(out) - 1))
        out[i], out[i + 1] = out[i + 1], out[i]
    return out


def pollute_phantom_deletes(batches, n, rng):
    """Request deletions of edges that were never inserted."""
    out = []
    for b in batches:
        du, dv = b.del_u.copy(), b.del_v.copy()
        pad = np.nonzero(du >= n)[0]
        if pad.size:
            k = min(int(rng.integers(1, 3)), pad.size)
            idx = pad[:k]
            du[idx] = rng.integers(0, n, size=k)
            dv[idx] = rng.integers(0, n, size=k)
        out.append(StreamBatch(ins_u=b.ins_u, ins_v=b.ins_v, del_u=du,
                               del_v=dv))
    return out


#: name -> polluter(batches, n, rng) -> batches
POLLUTERS = {
    "out_of_range": pollute_out_of_range,
    "self_loops": pollute_self_loops,
    "duplicate_batches": pollute_duplicate_batches,
    "reordered_batches": pollute_reordered_batches,
    "phantom_deletes": pollute_phantom_deletes,
}


def pollute_stream(stream: EdgeStream, kinds, seed: int = 0) -> EdgeStream:
    """Apply named polluters to a stream's batch list, deterministically."""
    rng = np.random.default_rng(seed)
    batches = list(stream.batches)
    for kind in kinds:
        batches = POLLUTERS[kind](batches, stream.n_nodes, rng)
    return dataclasses.replace(stream, batches=tuple(batches))


# ---------------------------------------------------------------------------
# sanitizer
# ---------------------------------------------------------------------------

def sanitize_batch(b: StreamBatch, n_nodes: int):
    """Reject malformed events in front of ``apply_batch`` (DESIGN.md §11).

    Classification per event (an event is padding iff both endpoints are
    exactly the ``n_nodes`` sentinel — padding is never counted):

      * ``ins_out_of_range`` / ``del_out_of_range`` — an endpoint outside
        [0, n) that is not the sentinel;
      * ``ins_self_loop`` / ``del_self_loop`` — u == v (a self-loop can
        never be a pool edge, so deleting one can never match).

    Rejected events are rewritten to sentinel padding, so the sanitized
    batch is shape-identical and safe for the jitted ``apply_batch``.
    Deletions of never-inserted edges are *well-formed* traffic and pass
    through — ``edge_slots`` counts them as unmatched downstream.

    Returns:
      (StreamBatch sanitized, quarantine: dict[str, int]).
    """
    n = n_nodes
    out = {}
    arrs = {}
    for kind, (u, v) in (("ins", (b.ins_u, b.ins_v)),
                         ("del", (b.del_u, b.del_v))):
        u = np.asarray(u)
        v = np.asarray(v)
        padding = (u == n) & (v == n)
        in_range = (u >= 0) & (u < n) & (v >= 0) & (v < n)
        oor = ~padding & ~in_range
        self_loop = ~padding & in_range & (u == v)
        bad = oor | self_loop
        out[f"{kind}_out_of_range"] = int(oor.sum())
        out[f"{kind}_self_loop"] = int(self_loop.sum())
        arrs[f"{kind}_u"] = np.where(bad, n, u).astype(np.int32)
        arrs[f"{kind}_v"] = np.where(bad, n, v).astype(np.int32)
    clean = StreamBatch(ins_u=arrs["ins_u"], ins_v=arrs["ins_v"],
                        del_u=arrs["del_u"], del_v=arrs["del_v"])
    return clean, out


def merge_quarantine(total: dict, delta: dict) -> dict:
    """Accumulate per-category quarantine counters across batches."""
    for k, v in delta.items():
        total[k] = total.get(k, 0) + v
    return total
