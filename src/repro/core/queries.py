"""Batched tree queries answered from the Euler-tour numbering (DESIGN.md §12).

The tour numbering the pipelines already maintain is a complete query
index: ``pre``/``last`` give every vertex a preorder interval with
``subtree(v) = [pre[v], last[v]]``, ``comp`` answers connectivity, and
one ancestor-doubling table over the canonicalized parent array turns
interval containment into O(log n) LCA and exact-distance path
decomposition ("Euler Meets GPU", PAPERS.md arxiv 2103.15217).

The split mirrors ``compress.segment_reduce``: ``build_tables`` pays all
engine syncs ONCE per tour refresh — one ``rank_to_root`` depth pass plus
⌈log2 n⌉ sync-free doubling levels — and every query below is a fixed
shape of gathers over the frozen ``QueryTables``, costing zero additional
convergence checks no matter how many query batches run before the next
refresh. ``QueryTables.build_syncs`` carries the build cost so consumers
(``dynamic.queries.QuerySession``, ``benchmarks/table7_queries``) can
amortize it honestly across read batches.

Conventions shared by every op:

  * queries are batched int32 arrays; out-of-range ids (including the
    ``n`` padding sentinel) are valid *inputs* that yield the op's
    failure value — ``False`` for predicates, ``-1`` for ``lca`` /
    ``depth_of``, the combine identity for aggregates;
  * cross-component pairs are not errors: ``connected`` says False,
    ``lca`` says ``-1``, ``path_agg`` says identity.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.compress import (DEFAULT_JUMPS, _COMBINE, rank_to_root,
                                 segment_reduce)
from repro.core.euler import TourNumbering

INVALID = -1  # sentinel for "no such vertex" answers (lca / depth_of)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class QueryTables:
    """Frozen per-refresh query index over one rooted forest.

    Attributes:
      pre, last, comp, parent: the ``TourNumbering`` arrays the tables
        were built from (``subtree(v) = [pre[v], last[v]]`` inclusive).
      depth: int32[n] edges from v to its root (roots at 0).
      up:    int32[levels+1, n] ancestor doubling table —
             ``up[k, v]`` is v's 2^k-th ancestor, clamped at the root
             (roots self-loop, so over-shooting jumps are no-ops).
      build_syncs: int32 scalar — engine syncs spent building (the
        ``rank_to_root`` convergence checks + ``levels`` doubling
        steps); amortized across query batches by the serving layer.
    """

    pre: jnp.ndarray
    last: jnp.ndarray
    comp: jnp.ndarray
    parent: jnp.ndarray
    depth: jnp.ndarray
    up: jnp.ndarray
    build_syncs: jnp.ndarray

    def tree_flatten(self):
        return ((self.pre, self.last, self.comp, self.parent, self.depth,
                 self.up, self.build_syncs), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)

    @property
    def n_nodes(self) -> int:
        return int(self.pre.shape[0])

    @property
    def levels(self) -> int:
        return int(self.up.shape[0]) - 1


@partial(jax.jit, static_argnames=("n_jumps",))
def _build_tables(tn: TourNumbering, *,
                  n_jumps: int = DEFAULT_JUMPS) -> QueryTables:
    """Jitted table build — vmap-safe (no host recording). Batched
    callers (``dynamic.fleet.build_fleet_tables``) vmap THIS and report
    to the ledger themselves at host level."""
    par = tn.parent
    n = par.shape[0]
    depth, _root, syncs = rank_to_root(par, n_jumps=n_jumps,
                                       return_syncs=True)
    levels = max(1, (n - 1).bit_length())
    rows = [par]
    hop = par
    for _ in range(levels):
        hop = hop[hop]
        rows.append(hop)
    return QueryTables(pre=tn.pre, last=tn.last, comp=tn.comp, parent=par,
                       depth=depth, up=jnp.stack(rows),
                       build_syncs=syncs + jnp.int32(levels))


def build_tables(tn: TourNumbering, *,
                 n_jumps: int = DEFAULT_JUMPS) -> QueryTables:
    """Build the query index from a (fresh) tour numbering.

    One ``rank_to_root`` pass for depths plus ``levels = ⌈log2 n⌉``
    sync-free ``p = p[p]`` doublings for the ancestor table — after
    this, every query in the module is gathers only.

    Host wrapper over the jitted build: reports ``build_syncs`` to the
    ambient ``obs`` ledger (phase ``build_tables``) — lazily, so
    unrecorded runs never pull the scalar to host (DESIGN.md §14).
    """
    from repro import obs

    tables = _build_tables(tn, n_jumps=n_jumps)
    obs.record("build_tables", lambda: int(tables.build_syncs))
    return tables


def _ok(x: jnp.ndarray, n: int) -> jnp.ndarray:
    return (x >= 0) & (x < n)


def _clip(x: jnp.ndarray, n: int) -> jnp.ndarray:
    return jnp.clip(x, 0, n - 1)


def _identity(op: str, dtype) -> jnp.ndarray:
    """The combine identity ``op`` is absorbed by (aggregate failure value)."""
    dtype = jnp.dtype(dtype)
    if op == "add":
        return jnp.zeros((), dtype)
    info = (jnp.iinfo(dtype) if jnp.issubdtype(dtype, jnp.integer)
            else jnp.finfo(dtype))
    return jnp.asarray(info.max if op == "min" else info.min, dtype)


@jax.jit
def connected(tables: QueryTables, u: jnp.ndarray,
              v: jnp.ndarray) -> jnp.ndarray:
    """bool[B] — u and v in the same component (False on invalid ids)."""
    n = tables.pre.shape[0]
    return (_ok(u, n) & _ok(v, n)
            & (tables.comp[_clip(u, n)] == tables.comp[_clip(v, n)]))


@jax.jit
def depth_of(tables: QueryTables, v: jnp.ndarray) -> jnp.ndarray:
    """int32[B] — edges from v to its component root (-1 on invalid ids)."""
    n = tables.pre.shape[0]
    return jnp.where(_ok(v, n), tables.depth[_clip(v, n)],
                     jnp.int32(INVALID))


@jax.jit
def is_ancestor(tables: QueryTables, a: jnp.ndarray,
                x: jnp.ndarray) -> jnp.ndarray:
    """bool[B] — a lies on x's root path (inclusive: a == x counts).

    Pure interval containment: a is an ancestor of x iff
    ``pre[a] <= pre[x] <= last[a]`` — subtree(a)'s preorder block holds
    exactly a's descendants (DESIGN.md §4), and component blocks are
    disjoint so no cross-component pair can satisfy it.
    """
    n = tables.pre.shape[0]
    ac, xc = _clip(a, n), _clip(x, n)
    cov = ((tables.pre[ac] <= tables.pre[xc])
           & (tables.pre[xc] <= tables.last[ac]))
    return _ok(a, n) & _ok(x, n) & cov


@jax.jit
def lca(tables: QueryTables, u: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """int32[B] — lowest common ancestor; -1 for cross-component/invalid.

    Binary lifting against the interval test: climb u from the highest
    power of two downward, taking each jump only while the landing
    ancestor still does *not* cover v. That greedy walk stops exactly at
    the deepest ancestor of u outside v's root path — its parent is the
    LCA. Depth-oblivious and fixed-shape: levels+1 gathers per batch,
    zero syncs.
    """
    n = tables.pre.shape[0]
    uc, vc = _clip(u, n), _clip(v, n)
    pre, last = tables.pre, tables.last
    pv = pre[vc]

    def covers(a):
        return (pre[a] <= pv) & (pv <= last[a])

    x = uc
    for k in range(tables.up.shape[0] - 1, -1, -1):
        cand = tables.up[k][x]
        x = jnp.where(covers(cand), x, cand)
    res = jnp.where(covers(uc), uc, tables.parent[x])
    same = (_ok(u, n) & _ok(v, n)
            & (tables.comp[uc] == tables.comp[vc]))
    return jnp.where(same, res, jnp.int32(INVALID))


@partial(jax.jit, static_argnames=("op",))
def subtree_agg(tables: QueryTables, v: jnp.ndarray, payload: jnp.ndarray,
                op: str = "add") -> jnp.ndarray:
    """out[q] = op over payload[x] for every x in subtree(v[q]).

    The payload is scattered once into preorder layout, where every
    subtree is the contiguous interval ``[pre[v], last[v]]``: ``add``
    becomes a prefix-sum difference, ``min``/``max`` route through the
    ``segment_reduce`` sparse table. Invalid v yields the op identity.
    """
    n = tables.pre.shape[0]
    vc = _clip(v, n)
    arr = jnp.zeros((n,), payload.dtype).at[tables.pre].set(payload)
    lo, hi = tables.pre[vc], tables.last[vc]
    if op == "add":
        pref = jnp.cumsum(arr)
        out = pref[hi] - jnp.where(lo > 0, pref[_clip(lo - 1, n)],
                                   jnp.zeros((), pref.dtype))
    else:
        out = segment_reduce(arr, lo, hi, op)
    return jnp.where(_ok(v, n), out, _identity(op, payload.dtype))


@partial(jax.jit, static_argnames=("op",))
def path_agg(tables: QueryTables, u: jnp.ndarray, v: jnp.ndarray,
             payload: jnp.ndarray, op: str = "add") -> jnp.ndarray:
    """op over payload on the unique tree path u..v, endpoints inclusive.

    Exact-distance decomposition, safe for the non-idempotent ``add``:
    per-call payload doubling tables ``pv[k][x]`` = op over the 2^k
    vertices starting at x going rootward (aligned with ``up``), then
    each endpoint climbs exactly ``depth[endpoint] - depth[lca]`` steps
    by that distance's binary digits. The two climbs cover disjoint
    vertex sets meeting only at the LCA, which seeds the accumulator —
    every path vertex is combined exactly once. Cross-component or
    invalid pairs yield the op identity.
    """
    n = tables.pre.shape[0]
    combine = _COMBINE[op]
    w = lca(tables, u, v)
    valid = w >= 0
    uc, vc, wc = _clip(u, n), _clip(v, n), _clip(w, n)
    levels = tables.up.shape[0] - 1

    pv = [payload]
    t = payload
    for k in range(levels):
        t = combine(t, t[tables.up[k]])
        pv.append(t)

    def climb(acc, x, d):
        for k in range(levels + 1):
            take = ((d >> k) & 1) == 1
            acc = jnp.where(take, combine(acc, pv[k][x]), acc)
            x = jnp.where(take, tables.up[k][x], x)
        return acc

    acc = payload[wc]
    acc = climb(acc, uc, tables.depth[uc] - tables.depth[wc])
    acc = climb(acc, vc, tables.depth[vc] - tables.depth[wc])
    return jnp.where(valid, acc, _identity(op, payload.dtype))


@jax.jit
def edge_membership(qu: jnp.ndarray, qv: jnp.ndarray, e_src: jnp.ndarray,
                    e_dst: jnp.ndarray, e_valid: jnp.ndarray,
                    flags: jnp.ndarray):
    """Match query pairs against a flagged undirected edge set.

    The shared kernel behind ``is_bridge``-style membership queries:
    for each (qu, qv) pair, scan the live slots whose unordered
    endpoints equal {qu, qv} (a B×E broadcast compare — fixed shape, no
    syncs; fine for pool-sized E).

    Returns:
      (hit: bool[B] — some live slot matches the pair,
       flagged: bool[B] — some matching live slot has its flag set).
    """
    qlo, qhi = jnp.minimum(qu, qv), jnp.maximum(qu, qv)
    elo, ehi = jnp.minimum(e_src, e_dst), jnp.maximum(e_src, e_dst)
    match = ((qlo[:, None] == elo[None, :])
             & (qhi[:, None] == ehi[None, :]) & e_valid[None, :])
    return jnp.any(match, axis=1), jnp.any(match & flags[None, :], axis=1)
