"""Shiloach–Vishkin / GConn-style connectivity with spanning-forest extraction.

Implements the paper's §III-B: alternating *hooking* and *pointer jumping*
(shortcutting). Per Shiloach & Vishkin (1982), the union phase marks one
*spanning edge* per successful hook, so connectivity yields an (unrooted)
spanning forest for free. Rooting is done separately by the Euler tour
(``repro.core.euler``), mirroring the paper's GConn + Euler pipeline.

TPU adaptation (see DESIGN.md §2):
  * CUDA ``atomicMin`` hooking → deterministic ``.at[].min`` scatter.
  * Winner-edge selection is two-stage so it stays int32-exact: first
    scatter-min the candidate representative per hook target, then
    scatter-min the half-edge id among edges that achieved that rep.
  * Hooking is pure-min by default: the paper's min/max alternation (a
    CAS-era optimization) pathologically funnels to one hook per round on
    hub graphs under deterministic scatter-hooking (measured: 812 vs 3
    rounds on rmat-13; see EXPERIMENTS.md §Perf). ``alternate_hooking=True``
    keeps the paper-faithful variant for ablation. Each round hooks *roots
    only*, monotonically, so no cycles can form within a round.
  * Pointer jumping runs to full convergence between hooking rounds and can
    be routed through the multi-jump Pallas kernel.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.compress import compress_full
from repro.core.graph import Graph

INF32 = jnp.iinfo(jnp.int32).max


def pointer_jump_full(p: jnp.ndarray, *, use_kernel: bool = False) -> jnp.ndarray:
    """Jump ``p[i] = p[p[i]]`` until convergence (full path compression).

    Routed through the unified engine (``core.compress``): amortized
    convergence checks on both the XLA and Pallas paths.
    """
    return compress_full(p, use_kernel=use_kernel)


@partial(jax.jit, static_argnames=("max_rounds", "use_kernel", "alternate_hooking"))
def connected_components(graph: Graph, *, max_rounds: int | None = None,
                         use_kernel: bool = False,
                         alternate_hooking: bool = False):
    """Connectivity + spanning forest via alternating hook / compress rounds.

    Multigraph-honest: inputs may carry parallel edges and self-loops
    (``Graph.from_undirected`` does not dedupe — the dynamic layer's edge
    pool is exactly such a multigraph). Winner-edge selection is deduped
    at undirected-edge-id level, so at most one half-edge per vertex pair
    is ever marked and self-loops never claim a slot.

    Returns:
      rep:         int32[n] component representative per vertex (a root id).
      forest_mask: bool[2M] — True for half-edges selected as spanning-forest
                   edges (only the canonical half e < M of an undirected edge
                   can be set; exactly n - n_components are set in total).
      rounds:      int32 scalar — hook/compress rounds executed (the paper's
                   O(log n) step count).
    """
    n = graph.n_nodes
    src, dst = graph.src, graph.dst
    m2 = src.shape[0]
    edge_id = jnp.arange(m2, dtype=jnp.int32)
    # Canonical *undirected* edge id: both halves e and e + M of the same
    # undirected edge share min(e, rev(e)) = e % M. Winner selection runs
    # on canonical ids so the forest scatter can never admit two
    # half-edges of one undirected edge — the multigraph honesty the
    # batch-dynamic deletion path depends on (DESIGN.md §9). Self-loops
    # are excluded by ``cross`` (their endpoint reps are always equal).
    m = m2 // 2
    eid_canon = jnp.where(edge_id < m, edge_id, edge_id - m)

    p0 = jnp.arange(n, dtype=jnp.int32)
    forest0 = jnp.zeros((m2,), jnp.bool_)

    def body(state):
        p, forest, rnd, _ = state
        ru = p[src]
        rv = p[dst]
        cross = ru != rv

        # Hooking direction. The paper alternates min/max per round (an
        # optimization for CAS-based hooking); under DETERMINISTIC
        # scatter-hooking the alternation re-creates a single-hook funnel
        # whenever the merged component's root is the extreme id of every
        # cross edge (hub graphs: measured 812 rounds vs 3 on rmat-13) —
        # pure min-hooking flips the funnel into a broadcast every other
        # round instead. Default: pure-min; the paper-faithful alternation
        # stays available for the ablation benchmark.
        use_min = ((rnd % 2) == 0) if alternate_hooking else jnp.bool_(True)
        lo = jnp.minimum(ru, rv)
        hi = jnp.maximum(ru, rv)
        tgt = jnp.where(use_min, hi, lo)     # root being re-pointed
        val = jnp.where(use_min, lo, hi)     # new parent for that root

        # Stage 1: deterministic scatter (min- or max-hooking).
        hooked_min = jnp.full((n,), INF32, jnp.int32).at[tgt].min(
            jnp.where(cross, val, INF32))
        hooked_max = jnp.full((n,), -1, jnp.int32).at[tgt].max(
            jnp.where(cross, val, -1))
        new_parent = jnp.where(use_min, hooked_min, hooked_max)
        got_hook = jnp.where(use_min, new_parent != INF32, new_parent >= 0)
        p_next = jnp.where(got_hook, new_parent, p)

        # Stage 2: winner half-edge per successful hook → spanning edge.
        # Deduped at undirected-edge-id level: the scatter-min runs on
        # canonical ids and only the canonical half may win, so parallel
        # slots and the two halves of one edge can never both be marked.
        achieved = cross & (new_parent[tgt] == val)
        win_eid = jnp.full((n,), INF32, jnp.int32).at[tgt].min(
            jnp.where(achieved, eid_canon, INF32))
        is_winner = (achieved & (win_eid[tgt] == eid_canon)
                     & (edge_id == eid_canon))
        forest = forest | is_winner

        # Compress to full convergence before the next round.
        p_next = pointer_jump_full(p_next, use_kernel=use_kernel)
        changed = jnp.any(got_hook)
        return p_next, forest, rnd + 1, changed

    def cond(state):
        _p, _f, rnd, changed = state
        bound = n if max_rounds is None else max_rounds
        return changed & (rnd < bound)

    p, forest, rounds, _ = jax.lax.while_loop(
        cond, body, (p0, forest0, jnp.int32(0), jnp.bool_(True)))
    return p, forest, rounds - 1


def count_components(rep: jnp.ndarray) -> jnp.ndarray:
    """Number of distinct representatives (components), jit-friendly."""
    n = rep.shape[0]
    is_root = rep == jnp.arange(n, dtype=rep.dtype)
    return jnp.sum(is_root.astype(jnp.int32))
