"""Path-Reversal Rooted Spanning Tree (PR-RST, Cong & Bader), paper §III-C.

PR-RST unifies connectivity and rooting: it maintains a *valid rooted forest*
``P`` at all times. Each round every component picks one cross edge (u, v)
(v in another component), re-roots its own tree at u by reversing the
parent path u → r, then grafts via ``P[u] = v``.

GPU→TPU adaptation of the paper's three optimizations (DESIGN.md §2):

* **Hooking** — min/max alternation on root ids picks the graft direction;
  one winning edge per component chosen by two-stage deterministic
  scatter-min (the atomic-free winner selection).

* **Special ancestors / onPath history** — the paper records pointer-jumping
  history in an ``onPath`` array. We keep the equivalent doubling tables
  ``anc[k][v]`` (ancestor at distance exactly 2^k) *and* ``pred[k][v]`` (the
  path node immediately below ``anc[k][v]``), plus a validity table so
  saturated chains (beyond the root) never write. Marking all u→r path
  vertices then takes ⌈log n⌉ rounds: processing k = 0..K in ascending
  order marks every ancestor distance via its binary decomposition, and each
  mark carries the on-path predecessor needed for reversal.

* **Path reversal** — one masked scatter flips ``P[x] = pred(x)`` for every
  marked vertex, and a second scatter grafts ``P[u] = v``. Fully
  data-parallel, no serial chain walk.

Two memory-traffic optimizations on top (DESIGN.md §3):

* **Incremental representatives** — instead of recomputing ``roots_of(P)``
  from scratch each round (O(log depth) gathers over the *tree*), the
  compressed representative array ``rt`` is carried across rounds. A round
  only changes the root of components that graft, and each moving root m
  lands in the component of its graft target t — so the per-round update is
  one pointer compression of the *component-level* overlay
  ``q[m] = rt[t]`` (chains only as long as this round's graft chains)
  followed by one gather ``rt' = compress(q)[rt]``. Hook direction is
  monotone within a round, so the overlay is acyclic.

* **Adaptive doubling tables** — ``_ancestor_tables`` stops as soon as the
  validity mask saturates (no vertex has depth ≥ 2^k), so each round builds
  only the ⌈log2(max depth)⌉ levels it actually needs instead of a static
  ⌈log n⌉ × n × 3 rebuild; ``_mark_paths`` runs its marking loop over the
  same dynamic level count. Early rounds (shallow forests) build ~0 levels.

The returned P is a spanning tree rooted wherever the last surviving
component root happened to be; a final path reversal re-roots it at the
designated root (a one-round reuse of the same machinery).

The doubling-table marking, masked-scatter reversal, and per-component
link round live in ``core.reroot`` (shared with the batch-dynamic layer,
DESIGN.md §9); this module keeps only the hooking policy and the round /
convergence loop.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.compress import DEFAULT_JUMPS
from repro.core.graph import Graph
from repro.core.reroot import link_components, mark_paths, reverse_and_graft

INF32 = jnp.iinfo(jnp.int32).max


def _pr_rst_round(p, rt, rnd, src, dst, *, levels: int,
                  alternate_hooking: bool = False,
                  n_jumps: int = DEFAULT_JUMPS, use_kernel: bool = False):
    """One hook / mark / reverse / graft round.

    Precondition: ``rt == roots_of(p)`` (the incremental-representative
    invariant; checked by tests/test_compress.py).

    The mover side of each cross edge is chosen by root-id order (min- or
    max-hooking); the shared link primitive (``core.reroot``, DESIGN.md §9)
    does winner selection, path reversal, grafting, and the incremental
    representative update. Returns (p_next, rt_next, hooked).
    """
    ru = rt[src]
    rv = rt[dst]
    cross = ru != rv

    # Hook direction (see connectivity.py: pure-min by default; the
    # paper's alternation kept for ablation). Root-id order is strict
    # within a round, so the component overlay stays acyclic.
    use_min = ((rnd % 2) == 0) if alternate_hooking else jnp.bool_(True)
    mover = jnp.where(use_min, jnp.maximum(ru, rv), jnp.minimum(ru, rv))
    is_u_mover = mover == ru
    start = jnp.where(is_u_mover, src, dst)    # u_i — grafted vertex
    target = jnp.where(is_u_mover, dst, src)   # v_i — graft destination

    p_next, rt_next, is_winner = link_components(
        p, rt, start, target, cross, levels=levels, n_jumps=n_jumps,
        use_kernel=use_kernel)
    return p_next, rt_next, jnp.any(is_winner)


@partial(jax.jit, static_argnames=("max_rounds", "alternate_hooking",
                                   "use_kernel", "n_jumps"))
def pr_rst(graph: Graph, root, *, max_rounds: int | None = None,
           alternate_hooking: bool = False, use_kernel: bool = False,
           n_jumps: int = DEFAULT_JUMPS):
    """PR-RST: build a rooted spanning tree in O(log² n) parallel depth.

    Returns:
      parent: int32[n] — valid rooted tree per component; the component of
              ``root`` is rooted at ``root``; other components at an
              arbitrary vertex. Isolated vertices: parent = self.
      rounds: int32 — hook/reverse rounds executed.
    """
    n = graph.n_nodes
    src, dst = graph.src, graph.dst
    levels = max(1, (n - 1).bit_length())
    root = jnp.asarray(root, jnp.int32)

    p0 = jnp.arange(n, dtype=jnp.int32)

    def body(state):
        p, rt, rnd, _ = state
        p, rt, hooked = _pr_rst_round(
            p, rt, rnd, src, dst, levels=levels,
            alternate_hooking=alternate_hooking, n_jumps=n_jumps,
            use_kernel=use_kernel)
        return p, rt, rnd + 1, hooked

    def cond(state):
        _p, _rt, rnd, changed = state
        bound = n if max_rounds is None else max_rounds
        return changed & (rnd < bound)

    p, _rt, rounds, _ = jax.lax.while_loop(
        cond, body, (p0, p0, jnp.int32(0), jnp.bool_(True)))

    # Final re-root at the designated root: one more path reversal.
    start = jnp.full((n,), -1, jnp.int32).at[0].set(root)
    active = jnp.zeros((n,), jnp.bool_).at[0].set(True)
    # Re-index: mark_paths expects per-slot starts; use slot 0 only.
    mark, prednode = mark_paths(p, start, active, levels)
    p = reverse_and_graft(p, mark, prednode, start,
                          jnp.broadcast_to(root, (n,)), active)
    return p, rounds - 1
