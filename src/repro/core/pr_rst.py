"""Path-Reversal Rooted Spanning Tree (PR-RST, Cong & Bader), paper §III-C.

PR-RST unifies connectivity and rooting: it maintains a *valid rooted forest*
``P`` at all times. Each round every component picks one cross edge (u, v)
(v in another component), re-roots its own tree at u by reversing the
parent path u → r, then grafts via ``P[u] = v``.

GPU→TPU adaptation of the paper's three optimizations (DESIGN.md §2):

* **Hooking** — min/max alternation on root ids picks the graft direction;
  one winning edge per component chosen by two-stage deterministic
  scatter-min (the atomic-free winner selection).

* **Special ancestors / onPath history** — the paper records pointer-jumping
  history in an ``onPath`` array. We keep the equivalent doubling tables
  ``anc[k][v]`` (ancestor at distance exactly 2^k) *and* ``pred[k][v]`` (the
  path node immediately below ``anc[k][v]``), plus a validity table so
  saturated chains (beyond the root) never write. Marking all u→r path
  vertices then takes ⌈log n⌉ rounds: processing k = 0..K in ascending
  order marks every ancestor distance via its binary decomposition, and each
  mark carries the on-path predecessor needed for reversal.

* **Path reversal** — one masked scatter flips ``P[x] = pred(x)`` for every
  marked vertex, and a second scatter grafts ``P[u] = v``. Fully
  data-parallel, no serial chain walk.

The returned P is a spanning tree rooted wherever the last surviving
component root happened to be; a final path reversal re-roots it at the
designated root (a one-round reuse of the same machinery).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.graph import Graph

INF32 = jnp.iinfo(jnp.int32).max


def _ancestor_tables(p: jnp.ndarray, levels: int):
    """Doubling tables (anc, pred, valid), each [levels, n].

    anc[k][v]  = ancestor of v at distance exactly 2^k (if valid[k][v]).
    pred[k][v] = the path vertex immediately below anc[k][v] on v's root path.
    valid[k][v] = depth(v) >= 2^k.
    """
    n = p.shape[0]
    v0 = jnp.arange(n, dtype=jnp.int32)
    anc0 = p
    pred0 = v0
    valid0 = p != v0

    def step(carry, _):
        anc, pred, valid = carry
        anc2 = anc[anc]
        pred2 = pred[anc]
        valid2 = valid & valid[anc]
        return (anc2, pred2, valid2), (anc, pred, valid)

    (_, _, _), (ancs, preds, valids) = jax.lax.scan(
        step, (anc0, pred0, valid0), None, length=levels)
    return ancs, preds, valids


def _mark_paths(p: jnp.ndarray, starts: jnp.ndarray, active: jnp.ndarray,
                levels: int):
    """Mark every vertex on the P-root-path of each active start vertex.

    Returns (mark: bool[n], prednode: int32[n]) — prednode[w] is the path
    vertex immediately below w (valid where mark & w is not a start).
    """
    n = p.shape[0]
    ancs, preds, valids = _ancestor_tables(p, levels)

    mark = jnp.zeros((n,), jnp.bool_)
    start_idx = jnp.where(active, starts, n)
    mark = mark.at[start_idx].set(True, mode="drop")
    prednode = jnp.full((n,), -1, jnp.int32)

    def body(k, state):
        mark, prednode = state
        anc_k = ancs[k]
        pred_k = preds[k]
        ok = mark & valids[k]
        tgt = jnp.where(ok, anc_k, n)
        mark = mark.at[tgt].set(True, mode="drop")
        prednode = prednode.at[tgt].set(pred_k, mode="drop")
        return mark, prednode

    mark, prednode = jax.lax.fori_loop(0, levels, body, (mark, prednode))
    return mark, prednode


def _reverse_and_graft(p, mark, prednode, starts, grafts, active):
    """Flip parent pointers along marked paths; set P[start] = graft."""
    n = p.shape[0]
    verts = jnp.arange(n, dtype=jnp.int32)
    is_start = jnp.zeros((n,), jnp.bool_).at[
        jnp.where(active, starts, n)].set(True, mode="drop")
    flip = mark & ~is_start & (prednode >= 0)
    p = jnp.where(flip, prednode, p)
    p = p.at[jnp.where(active, starts, n)].set(
        jnp.where(active, grafts, 0), mode="drop")
    del verts
    return p


@partial(jax.jit, static_argnames=("max_rounds", "alternate_hooking"))
def pr_rst(graph: Graph, root, *, max_rounds: int | None = None,
           alternate_hooking: bool = False):
    """PR-RST: build a rooted spanning tree in O(log² n) parallel depth.

    Returns:
      parent: int32[n] — valid rooted tree per component; the component of
              ``root`` is rooted at ``root``; other components at an
              arbitrary vertex. Isolated vertices: parent = self.
      rounds: int32 — hook/reverse rounds executed.
    """
    n = graph.n_nodes
    src, dst = graph.src, graph.dst
    m2 = src.shape[0]
    edge_id = jnp.arange(m2, dtype=jnp.int32)
    levels = max(1, (n - 1).bit_length())
    root = jnp.asarray(root, jnp.int32)

    p0 = jnp.arange(n, dtype=jnp.int32)

    def roots_of(p):
        """Root of every vertex's tree (non-destructive pointer jumping)."""
        def body(state):
            r, _ = state
            r2 = r[r]
            return r2, jnp.any(r2 != r)
        r, _ = jax.lax.while_loop(lambda s: s[1], body, (p, jnp.bool_(True)))
        return r

    def body(state):
        p, rnd, _ = state
        rt = roots_of(p)
        ru = rt[src]
        rv = rt[dst]
        cross = ru != rv

        # Hook direction (see connectivity.py: pure-min by default; the
        # paper's alternation kept for ablation).
        use_min = ((rnd % 2) == 0) if alternate_hooking else jnp.bool_(True)
        mover = jnp.where(use_min, jnp.maximum(ru, rv), jnp.minimum(ru, rv))
        is_u_mover = mover == ru
        start = jnp.where(is_u_mover, src, dst)    # u_i — grafted vertex
        target = jnp.where(is_u_mover, dst, src)   # v_i — graft destination

        # One winning edge per moving component (two-stage scatter-min).
        key = jnp.where(cross, edge_id, INF32)
        win = jnp.full((n,), INF32, jnp.int32).at[mover].min(key)
        is_winner = cross & (win[mover] == edge_id)

        # Per-component (indexed by moving root): start + graft vertices.
        comp_start = jnp.full((n,), -1, jnp.int32).at[
            jnp.where(is_winner, mover, n)].set(start, mode="drop")
        comp_graft = jnp.full((n,), -1, jnp.int32).at[
            jnp.where(is_winner, mover, n)].set(target, mode="drop")
        comp_active = comp_start >= 0

        # Mark each moving component's start→root path, reverse, graft.
        mark, prednode = _mark_paths(p, comp_start, comp_active, levels)
        p = _reverse_and_graft(p, mark, prednode, comp_start, comp_graft,
                               comp_active)
        return p, rnd + 1, jnp.any(is_winner)

    def cond(state):
        _p, rnd, changed = state
        bound = n if max_rounds is None else max_rounds
        return changed & (rnd < bound)

    p, rounds, _ = jax.lax.while_loop(
        cond, body, (p0, jnp.int32(0), jnp.bool_(True)))

    # Final re-root at the designated root: one more path reversal.
    start = jnp.full((n,), -1, jnp.int32).at[0].set(root)
    active = jnp.zeros((n,), jnp.bool_).at[0].set(True)
    # Re-index: _mark_paths expects per-slot starts; use slot 0 only.
    mark, prednode = _mark_paths(p, start, active, levels)
    p = _reverse_and_graft(p, mark, prednode, start,
                           jnp.broadcast_to(root, (n,)), active)
    return p, rounds - 1
