"""Path-Reversal Rooted Spanning Tree (PR-RST, Cong & Bader), paper §III-C.

PR-RST unifies connectivity and rooting: it maintains a *valid rooted forest*
``P`` at all times. Each round every component picks one cross edge (u, v)
(v in another component), re-roots its own tree at u by reversing the
parent path u → r, then grafts via ``P[u] = v``.

GPU→TPU adaptation of the paper's three optimizations (DESIGN.md §2):

* **Hooking** — min/max alternation on root ids picks the graft direction;
  one winning edge per component chosen by two-stage deterministic
  scatter-min (the atomic-free winner selection).

* **Special ancestors / onPath history** — the paper records pointer-jumping
  history in an ``onPath`` array. We keep the equivalent doubling tables
  ``anc[k][v]`` (ancestor at distance exactly 2^k) *and* ``pred[k][v]`` (the
  path node immediately below ``anc[k][v]``), plus a validity table so
  saturated chains (beyond the root) never write. Marking all u→r path
  vertices then takes ⌈log n⌉ rounds: processing k = 0..K in ascending
  order marks every ancestor distance via its binary decomposition, and each
  mark carries the on-path predecessor needed for reversal.

* **Path reversal** — one masked scatter flips ``P[x] = pred(x)`` for every
  marked vertex, and a second scatter grafts ``P[u] = v``. Fully
  data-parallel, no serial chain walk.

Two memory-traffic optimizations on top (DESIGN.md §3):

* **Incremental representatives** — instead of recomputing ``roots_of(P)``
  from scratch each round (O(log depth) gathers over the *tree*), the
  compressed representative array ``rt`` is carried across rounds. A round
  only changes the root of components that graft, and each moving root m
  lands in the component of its graft target t — so the per-round update is
  one pointer compression of the *component-level* overlay
  ``q[m] = rt[t]`` (chains only as long as this round's graft chains)
  followed by one gather ``rt' = compress(q)[rt]``. Hook direction is
  monotone within a round, so the overlay is acyclic.

* **Adaptive doubling tables** — ``_ancestor_tables`` stops as soon as the
  validity mask saturates (no vertex has depth ≥ 2^k), so each round builds
  only the ⌈log2(max depth)⌉ levels it actually needs instead of a static
  ⌈log n⌉ × n × 3 rebuild; ``_mark_paths`` runs its marking loop over the
  same dynamic level count. Early rounds (shallow forests) build ~0 levels.

The returned P is a spanning tree rooted wherever the last surviving
component root happened to be; a final path reversal re-roots it at the
designated root (a one-round reuse of the same machinery).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.compress import DEFAULT_JUMPS, compress_full
from repro.core.graph import Graph

INF32 = jnp.iinfo(jnp.int32).max


def _ancestor_tables(p: jnp.ndarray, levels: int):
    """Doubling tables (anc, pred, valid), each [levels, n], plus ``used``.

    anc[k][v]  = ancestor of v at distance exactly 2^k (if valid[k][v]).
    pred[k][v] = the path vertex immediately below anc[k][v] on v's root path.
    valid[k][v] = depth(v) >= 2^k.

    Only the first ``used`` levels are populated: the build loop exits as
    soon as ``valid`` saturates all-false (no vertex is that deep), so a
    forest of maximum depth D costs ⌈log2(D)⌉ + 1 levels of 3 gathers each
    rather than the static ⌈log n⌉. Levels ≥ ``used`` are all-invalid and
    must not be consulted (``_mark_paths`` bounds its loop by ``used``).
    """
    n = p.shape[0]
    v0 = jnp.arange(n, dtype=jnp.int32)
    anc0 = p
    pred0 = v0
    valid0 = p != v0

    bufs0 = (jnp.zeros((levels, n), jnp.int32),
             jnp.zeros((levels, n), jnp.int32),
             jnp.zeros((levels, n), jnp.bool_))

    def cond(state):
        k, _anc, _pred, valid, _bufs = state
        return (k < levels) & jnp.any(valid)

    def body(state):
        k, anc, pred, valid, (ab, pb, vb) = state
        ab = ab.at[k].set(anc)
        pb = pb.at[k].set(pred)
        vb = vb.at[k].set(valid)
        anc2 = anc[anc]
        pred2 = pred[anc]
        valid2 = valid & valid[anc]
        return k + 1, anc2, pred2, valid2, (ab, pb, vb)

    used, _, _, _, (ancs, preds, valids) = jax.lax.while_loop(
        cond, body, (jnp.int32(0), anc0, pred0, valid0, bufs0))
    return ancs, preds, valids, used


def _mark_paths(p: jnp.ndarray, starts: jnp.ndarray, active: jnp.ndarray,
                levels: int):
    """Mark every vertex on the P-root-path of each active start vertex.

    Returns (mark: bool[n], prednode: int32[n]) — prednode[w] is the path
    vertex immediately below w (valid where mark & w is not a start).
    """
    n = p.shape[0]
    ancs, preds, valids, used = _ancestor_tables(p, levels)

    mark = jnp.zeros((n,), jnp.bool_)
    start_idx = jnp.where(active, starts, n)
    mark = mark.at[start_idx].set(True, mode="drop")
    prednode = jnp.full((n,), -1, jnp.int32)

    def body(k, state):
        mark, prednode = state
        anc_k = ancs[k]
        pred_k = preds[k]
        ok = mark & valids[k]
        tgt = jnp.where(ok, anc_k, n)
        mark = mark.at[tgt].set(True, mode="drop")
        prednode = prednode.at[tgt].set(pred_k, mode="drop")
        return mark, prednode

    mark, prednode = jax.lax.fori_loop(0, used, body, (mark, prednode))
    return mark, prednode


def _reverse_and_graft(p, mark, prednode, starts, grafts, active):
    """Flip parent pointers along marked paths; set P[start] = graft."""
    n = p.shape[0]
    is_start = jnp.zeros((n,), jnp.bool_).at[
        jnp.where(active, starts, n)].set(True, mode="drop")
    flip = mark & ~is_start & (prednode >= 0)
    p = jnp.where(flip, prednode, p)
    p = p.at[jnp.where(active, starts, n)].set(
        jnp.where(active, grafts, 0), mode="drop")
    return p


def _pr_rst_round(p, rt, rnd, src, dst, *, levels: int,
                  alternate_hooking: bool = False,
                  n_jumps: int = DEFAULT_JUMPS, use_kernel: bool = False):
    """One hook / mark / reverse / graft round.

    Precondition: ``rt == roots_of(p)`` (the incremental-representative
    invariant; checked by tests/test_compress.py).

    Returns (p_next, rt_next, hooked) with the invariant re-established
    incrementally: one engine compression of the component-level graft
    overlay instead of a from-scratch ``roots_of`` over the tree.
    """
    n = p.shape[0]
    m2 = src.shape[0]
    edge_id = jnp.arange(m2, dtype=jnp.int32)
    verts = jnp.arange(n, dtype=jnp.int32)

    ru = rt[src]
    rv = rt[dst]
    cross = ru != rv

    # Hook direction (see connectivity.py: pure-min by default; the
    # paper's alternation kept for ablation).
    use_min = ((rnd % 2) == 0) if alternate_hooking else jnp.bool_(True)
    mover = jnp.where(use_min, jnp.maximum(ru, rv), jnp.minimum(ru, rv))
    is_u_mover = mover == ru
    start = jnp.where(is_u_mover, src, dst)    # u_i — grafted vertex
    target = jnp.where(is_u_mover, dst, src)   # v_i — graft destination

    # One winning edge per moving component (two-stage scatter-min).
    key = jnp.where(cross, edge_id, INF32)
    win = jnp.full((n,), INF32, jnp.int32).at[mover].min(key)
    is_winner = cross & (win[mover] == edge_id)

    # Per-component (indexed by moving root): start + graft vertices.
    comp_start = jnp.full((n,), -1, jnp.int32).at[
        jnp.where(is_winner, mover, n)].set(start, mode="drop")
    comp_graft = jnp.full((n,), -1, jnp.int32).at[
        jnp.where(is_winner, mover, n)].set(target, mode="drop")
    comp_active = comp_start >= 0

    # Mark each moving component's start→root path, reverse, graft.
    mark, prednode = _mark_paths(p, comp_start, comp_active, levels)
    p_next = _reverse_and_graft(p, mark, prednode, comp_start, comp_graft,
                                comp_active)

    # Incremental representative update: moving root m joins the component
    # of rt[t]; graft chains within a round are monotone in root id, so the
    # overlay is an acyclic forest over the (much shallower) component graph.
    graft_root = rt[jnp.clip(comp_graft, 0, n - 1)]
    overlay = jnp.where(comp_active, graft_root, verts)
    comp_rt = compress_full(overlay, n_jumps=n_jumps, use_kernel=use_kernel)
    rt_next = comp_rt[rt]
    return p_next, rt_next, jnp.any(is_winner)


@partial(jax.jit, static_argnames=("max_rounds", "alternate_hooking",
                                   "use_kernel", "n_jumps"))
def pr_rst(graph: Graph, root, *, max_rounds: int | None = None,
           alternate_hooking: bool = False, use_kernel: bool = False,
           n_jumps: int = DEFAULT_JUMPS):
    """PR-RST: build a rooted spanning tree in O(log² n) parallel depth.

    Returns:
      parent: int32[n] — valid rooted tree per component; the component of
              ``root`` is rooted at ``root``; other components at an
              arbitrary vertex. Isolated vertices: parent = self.
      rounds: int32 — hook/reverse rounds executed.
    """
    n = graph.n_nodes
    src, dst = graph.src, graph.dst
    levels = max(1, (n - 1).bit_length())
    root = jnp.asarray(root, jnp.int32)

    p0 = jnp.arange(n, dtype=jnp.int32)

    def body(state):
        p, rt, rnd, _ = state
        p, rt, hooked = _pr_rst_round(
            p, rt, rnd, src, dst, levels=levels,
            alternate_hooking=alternate_hooking, n_jumps=n_jumps,
            use_kernel=use_kernel)
        return p, rt, rnd + 1, hooked

    def cond(state):
        _p, _rt, rnd, changed = state
        bound = n if max_rounds is None else max_rounds
        return changed & (rnd < bound)

    p, _rt, rounds, _ = jax.lax.while_loop(
        cond, body, (p0, p0, jnp.int32(0), jnp.bool_(True)))

    # Final re-root at the designated root: one more path reversal.
    start = jnp.full((n,), -1, jnp.int32).at[0].set(root)
    active = jnp.zeros((n,), jnp.bool_).at[0].set(True)
    # Re-index: _mark_paths expects per-slot starts; use slot 0 only.
    mark, prednode = _mark_paths(p, start, active, levels)
    p = _reverse_and_graft(p, mark, prednode, start,
                           jnp.broadcast_to(root, (n,)), active)
    return p, rounds - 1
