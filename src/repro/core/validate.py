"""Validity checks for rooted spanning trees (used by tests and examples).

A parent array P is a valid RST of G rooted at r iff:
  1. P[r] == r;
  2. every reachable vertex v != r has (v, P[v]) ∈ E(G);
  3. following parents from any reachable vertex terminates at r
     (acyclicity + connectivity);
  4. unreachable vertices are marked (-1 for BFS) or self-rooted in their
     own component (connectivity-based methods).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compress import compress_full
from repro.core.graph import Graph


def reaches_root(parent: jnp.ndarray) -> jnp.ndarray:
    """bool[n]: following parents reaches a self-loop (a root)."""
    mapped = jnp.where(parent < 0,
                       jnp.arange(parent.shape[0], dtype=parent.dtype),
                       parent)
    # Engine compression, bounded: odd cycles never converge (64 syncs ×
    # 5 doublings covers depth 2^320 — any real chain), and even cycles
    # collapse to spurious fixed points. A vertex reaches a root iff its
    # fixed point is a self-loop of the ORIGINAL table — checking against
    # ``mapped`` (not the compressed hop) rejects cycle-collapse artifacts.
    hop = compress_full(mapped, max_syncs=64)
    return mapped[hop] == hop


def validate_rst(graph: Graph, parent, root, *, connected: bool = True) -> dict:
    """Thorough validation, fully vectorized. Returns dict of named booleans.

    Historically this walked ``while parent[x] != x`` per vertex and
    probed a Python edge set per parent link — O(n·depth) interpreter
    time that dominated ``serve_stream --validate`` and the oracle tests
    at rmat scale. Now acyclicity rides the engine (the ``reaches_root``
    bounded-compression technique: one O(log depth)-sync device pass),
    and edge membership is one ``np.isin`` over packed int64 endpoint
    keys (both orientations) — robust to arbitrarily corrupted input:
    negative parents are self-rooted singletons (BFS's unreachable
    marker), out-of-range parents fail the edge check, cycles fail the
    acyclicity check.
    """
    parent = np.asarray(parent)
    n = graph.n_nodes
    root = int(root)
    src = np.asarray(graph.src).astype(np.int64)
    dst = np.asarray(graph.dst).astype(np.int64)
    verts = np.arange(n, dtype=np.int64)

    ok_root = parent[root] == root

    # Parent edges exist in G: pack (a, b) as a·(n+1)+b — endpoints are
    # ≤ n (the sentinel), so keys are collision-free — and membership-test
    # both orientations at once against the graph's half-edge keys.
    pclip = np.clip(parent, 0, n).astype(np.int64)
    edge_keys = np.concatenate([src * (n + 1) + dst, dst * (n + 1) + src])
    need = (parent >= 0) & (parent != verts) & (verts != root)
    present = np.isin(verts * (n + 1) + pclip, edge_keys)
    ok_edges = bool(np.all(present[need])) if need.any() else True

    # Acyclic & reaches a root: bounded engine compression, fixed points
    # re-checked against the ORIGINAL table (cycle collapse is spurious).
    in_range = (parent >= 0) & (parent < n)
    mapped = np.where(in_range, parent, verts).astype(np.int32)
    hop = np.asarray(compress_full(jnp.asarray(mapped), max_syncs=64))
    reach = mapped[hop] == hop
    ok_acyclic = bool(np.all(reach | (parent < 0)))

    reach_root_count = int(np.sum((parent >= 0) & reach & (hop == root)))
    ok_connected = (not connected) or (reach_root_count == n)
    return {
        "root_fixed": bool(ok_root),
        "parent_edges_in_graph": bool(ok_edges),
        "acyclic": bool(ok_acyclic),
        "spans": bool(ok_connected),
        "all_ok": bool(ok_root and ok_edges and ok_acyclic and ok_connected),
    }


def bfs_depths_reference(graph: Graph, root: int) -> np.ndarray:
    """Reference BFS distances via numpy/deque (oracle for tests)."""
    from collections import deque

    n = graph.n_nodes
    src = np.asarray(graph.src)
    dst = np.asarray(graph.dst)
    adj: list[list[int]] = [[] for _ in range(n)]
    for u, v in zip(src.tolist(), dst.tolist()):
        adj[u].append(v)
    dist = np.full(n, -1, np.int64)
    dist[root] = 0
    q = deque([root])
    while q:
        u = q.popleft()
        for v in adj[u]:
            if dist[v] < 0:
                dist[v] = dist[u] + 1
                q.append(v)
    return dist


def components_reference(graph: Graph) -> np.ndarray:
    """Union-find component labels (oracle for connectivity tests)."""
    n = graph.n_nodes
    parent = list(range(n))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for u, v in zip(np.asarray(graph.src).tolist(),
                    np.asarray(graph.dst).tolist()):
        ru, rv = find(u), find(v)
        if ru != rv:
            parent[max(ru, rv)] = min(ru, rv)
    return np.asarray([find(v) for v in range(n)], np.int64)
