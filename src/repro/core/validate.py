"""Validity checks for rooted spanning trees (used by tests and examples).

A parent array P is a valid RST of G rooted at r iff:
  1. P[r] == r;
  2. every reachable vertex v != r has (v, P[v]) ∈ E(G);
  3. following parents from any reachable vertex terminates at r
     (acyclicity + connectivity);
  4. unreachable vertices are marked (-1 for BFS) or self-rooted in their
     own component (connectivity-based methods).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compress import compress_full
from repro.core.graph import Graph


def reaches_root(parent: jnp.ndarray) -> jnp.ndarray:
    """bool[n]: following parents reaches a self-loop (a root)."""
    mapped = jnp.where(parent < 0,
                       jnp.arange(parent.shape[0], dtype=parent.dtype),
                       parent)
    # Engine compression, bounded: odd cycles never converge (64 syncs ×
    # 5 doublings covers depth 2^320 — any real chain), and even cycles
    # collapse to spurious fixed points. A vertex reaches a root iff its
    # fixed point is a self-loop of the ORIGINAL table — checking against
    # ``mapped`` (not the compressed hop) rejects cycle-collapse artifacts.
    hop = compress_full(mapped, max_syncs=64)
    return mapped[hop] == hop


def validate_rst(graph: Graph, parent, root, *, connected: bool = True) -> dict:
    """Numpy-side thorough validation. Returns dict of named booleans."""
    parent = np.asarray(parent)
    n = graph.n_nodes
    root = int(root)
    src = np.asarray(graph.src)
    dst = np.asarray(graph.dst)
    edge_set = set(zip(src.tolist(), dst.tolist()))

    ok_root = parent[root] == root

    # Parent edges exist in G.
    ok_edges = True
    for v in range(n):
        p = int(parent[v])
        if v == root or p == v or p < 0:
            continue
        if (v, p) not in edge_set and (p, v) not in edge_set:
            ok_edges = False
            break

    # Acyclic & reaches a root.
    ok_acyclic = True
    reach_root_count = 0
    for v in range(n):
        if parent[v] < 0:
            continue
        seen = 0
        x = v
        while parent[x] != x and seen <= n:
            x = int(parent[x])
            seen += 1
        if seen > n:
            ok_acyclic = False
            break
        if x == root:
            reach_root_count += 1

    ok_connected = (not connected) or (reach_root_count == n)
    return {
        "root_fixed": bool(ok_root),
        "parent_edges_in_graph": bool(ok_edges),
        "acyclic": bool(ok_acyclic),
        "spans": bool(ok_connected),
        "all_ok": bool(ok_root and ok_edges and ok_acyclic and ok_connected),
    }


def bfs_depths_reference(graph: Graph, root: int) -> np.ndarray:
    """Reference BFS distances via numpy/deque (oracle for tests)."""
    from collections import deque

    n = graph.n_nodes
    src = np.asarray(graph.src)
    dst = np.asarray(graph.dst)
    adj: list[list[int]] = [[] for _ in range(n)]
    for u, v in zip(src.tolist(), dst.tolist()):
        adj[u].append(v)
    dist = np.full(n, -1, np.int64)
    dist[root] = 0
    q = deque([root])
    while q:
        u = q.popleft()
        for v in adj[u]:
            if dist[v] < 0:
                dist[v] = dist[u] + 1
                q.append(v)
    return dist


def components_reference(graph: Graph) -> np.ndarray:
    """Union-find component labels (oracle for connectivity tests)."""
    n = graph.n_nodes
    parent = list(range(n))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for u, v in zip(np.asarray(graph.src).tolist(),
                    np.asarray(graph.dst).tolist()):
        ru, rv = find(u), find(v)
        if ru != rv:
            parent[max(ru, rv)] = min(ru, rv)
    return np.asarray([find(v) for v in range(n)], np.int64)
