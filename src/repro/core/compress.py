"""Unified pointer-compression engine for every O(log n) jump phase.

All three RST pipelines bottom out in pointer doubling over a parent /
successor table: GConn's shortcutting between hook rounds, PR-RST's
``roots_of``, Euler/Wyllie list ranking, and the tree-depth diagnostic.
The seed code paid for each instance separately with a hand-rolled
``while_loop(any(p[p] != p))`` loop — one device↔host convergence sync per
*single* doubling step, which is exactly the per-launch overhead the
paper's 5-jump-per-launch optimization exists to amortize.

This engine is the single home for those loops (DESIGN.md §3):

  * ``jump_k(p, k)``      — k chained doubling steps, zero convergence syncs;
  * ``compress_full(p)``  — full path compression; ``n_jumps`` doubling steps
                            are chained between ``jnp.any`` checks, so
                            convergence costs ⌈log2(depth)/k⌉ + 1 syncs
                            instead of ⌈log2(depth)⌉ + 1 — in the pure-XLA
                            path as well as the Pallas-kernel path;
  * ``roots_of(p)``       — alias of ``compress_full`` (non-destructive:
                            both are functional);
  * ``reduce_to_root(p, x, op)`` — doubling with a payload combine (add /
                            min / max) → (op over each v→root path, root);
  * ``rank_to_root(p)``   — the ``op="add"``, unit-payload instance →
                            (depth, root) per vertex;
  * ``segment_reduce(a, lo, hi, op)`` — idempotent range reduction via a
                            doubling sparse table (payload-reduce ``jump_k``
                            on the shift successor i ↦ i + 2^k) — the
                            subtree low/high primitive for biconnectivity
                            (DESIGN.md §4);
  * ``segment_reduce_scoped(a, lo, hi, active, op)`` — the activity-masked
                            variant (the BCC analogue of
                            ``compress_scoped``): the table build stops as
                            soon as every *active* query is covered, so
                            clean components pay zero doubling steps
                            (DESIGN.md §10);
  * ``wyllie_rank(s, v)`` — list ranking (−1-sentinel successor convention)
                            with the same amortization.

``interpret=None`` everywhere dispatches from ``jax.default_backend()``:
compiled Mosaic on TPU, the Pallas interpreter elsewhere. The kernel path
pads to the (8, 128) tile once, *outside* the convergence loop, and runs
the whole loop on the padded 2-D table.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

NO_SUCC = jnp.int32(-1)

#: Doubling steps chained between convergence checks (paper's 5-jump trick).
DEFAULT_JUMPS = 5


def default_interpret() -> bool:
    """Pallas interpret-mode dispatch: compiled on TPU, interpreter elsewhere.

    Single policy shared with every kernel ops wrapper
    (``repro.kernels.auto_interpret``)."""
    from repro.kernels import auto_interpret
    return auto_interpret()


def jump_k(p: jnp.ndarray, n_jumps: int = DEFAULT_JUMPS) -> jnp.ndarray:
    """Apply ``p = p[p]`` ``n_jumps`` times — no convergence check, no sync.

    Each application *doubles* the compressed distance, so ``jump_k``
    covers chains of depth up to ``2**n_jumps`` (DESIGN.md §3).

    Args:
      p: int32[n] parent table (roots self-point).
      n_jumps: number of chained doubling steps.

    Returns:
      int32[n] jumped table (functional — ``p`` is unchanged).
    """
    for _ in range(n_jumps):
        p = p[p]
    return p


@partial(jax.jit, static_argnames=("n_jumps", "use_kernel", "interpret",
                                   "return_syncs", "max_syncs"))
def compress_full(p: jnp.ndarray, *, n_jumps: int = DEFAULT_JUMPS,
                  use_kernel: bool = False, interpret: bool | None = None,
                  return_syncs: bool = False, max_syncs: int | None = None):
    """Fully compress ``p`` (every entry ends on its chain's fixed point).

    Amortization contract: the convergence loop performs ``n_jumps``
    doubling steps per ``jnp.any`` sync, so a table of maximum depth d
    costs ⌈log2(d)/n_jumps⌉ + 1 syncs (the +1 confirms convergence).

    Args:
      p: int32[n] parent table; roots self-point. (Cyclic inputs are not
         trees: odd cycles never converge — pass ``max_syncs`` to bound the
         loop — and even cycles collapse to *spurious* fixed points that
         are not roots of the original table; callers validating arbitrary
         inputs must re-check fixed points against the original ``p``, see
         ``validate.reaches_root``.)
      n_jumps: doubling steps chained between convergence checks.
      use_kernel: route each chained-jump group through the Pallas doubling
         kernel (one launch per sync); padding is hoisted out of the loop.
      interpret: Pallas interpret mode; None → ``default_interpret()``.
      return_syncs: also return the number of ``jnp.any`` convergence
         checks executed (int32) — the counting hook for tests/benchmarks.
      max_syncs: optional static bound on convergence checks.

    Returns:
      compressed table, or ``(compressed, syncs)`` if ``return_syncs``.
    """
    if use_kernel:
        if interpret is None:
            interpret = default_interpret()
        from repro.kernels.pointer_jump.ops import (pad_to_tile,
                                                    pointer_jump_double_k)
        p2d, n = pad_to_tile(p)

        def step(q):
            return pointer_jump_double_k(q, n_jumps=n_jumps,
                                         interpret=interpret)
    else:
        p2d, n = p, p.shape[0]

        def step(q):
            return jump_k(q, n_jumps)

    def body(state):
        q, _, syncs = state
        q2 = step(q)
        return q2, jnp.any(q2 != q), syncs + 1

    def cond(state):
        _q, changed, syncs = state
        if max_syncs is not None:
            changed = changed & (syncs < max_syncs)
        return changed

    out, _, syncs = jax.lax.while_loop(
        cond, body, (p2d, jnp.bool_(True), jnp.int32(0)))
    if use_kernel:
        out = out.reshape(-1)[:n]
    return (out, syncs) if return_syncs else out


def roots_of(p: jnp.ndarray, **kwargs):
    """int32[n] root of every vertex's chain (DESIGN.md §3).

    Alias of ``compress_full`` (functional, hence non-destructive —
    callers keep their original ``p``); same kwargs and sync contract.
    """
    return compress_full(p, **kwargs)


def compress_scoped(p: jnp.ndarray, active: jnp.ndarray, **kwargs):
    """Scoped compression: compress ``active`` rows, freeze the rest.

    The ``jump_k``-based dirty-vertex variant for the batch-dynamic layer
    (DESIGN.md §9): inactive rows are masked to self-loops *before* the
    convergence loop, so they are fixed points from the first step and the
    sync count is ⌈log2(max depth among active chains)/n_jumps⌉ + 1 —
    independent of how deep the untouched components are. Same kwargs and
    kernel path as ``compress_full``.

    Args:
      p: int32[n] parent table (roots self-point).
      active: bool[n] scope mask. Must be closed under ``p`` — every chain
        starting at an active vertex stays inside ``active`` (component-
        closed masks, e.g. "every vertex whose component had a cut",
        satisfy this; a chain that escapes the mask stops at the first
        inactive vertex instead of its true root).

    Returns:
      int32[n]: chain roots where ``active``, identity elsewhere (merge
      with the caller's cached representative array via ``jnp.where``).
    """
    n = p.shape[0]
    verts = jnp.arange(n, dtype=p.dtype)
    return compress_full(jnp.where(active, p, verts), **kwargs)


_COMBINE = {"add": jnp.add, "min": jnp.minimum, "max": jnp.maximum}


@partial(jax.jit, static_argnames=("op", "n_jumps", "return_syncs"))
def reduce_to_root(parent: jnp.ndarray, payload: jnp.ndarray,
                   op: str = "add", *, n_jumps: int = DEFAULT_JUMPS,
                   return_syncs: bool = False):
    """Pointer doubling with a payload combine along every v→root path.

    The payload-reduce generalization of ``rank_to_root`` (DESIGN.md §3):
    the same ⌈log2(depth)/n_jumps⌉ + 1 sync contract, but each doubling
    step also folds the payload of the jumped-over segment, so the result
    is ``op`` over all vertices on the path from v to its root
    (inclusive of both endpoints).

    Args:
      parent: int32[n] self-rooted parent table (roots self-point; must be
        acyclic — this is a forest primitive, not a validator).
      payload: [n] per-vertex values, any dtype ``op`` supports. For
        ``op="add"`` the payload at roots must be the additive identity
        (0): doubling steps past convergence re-fold ``payload[root]``,
        which is a no-op only for idempotent ops (min/max) or identity
        payloads. ``rank_to_root`` satisfies this by construction.
      op: "add" | "min" | "max".
      n_jumps: doubling steps chained between convergence checks.
      return_syncs: also return the ``jnp.any`` convergence-check count.

    Returns:
      ``(red, root)`` — red[v] = op over payload on v's root path,
      root[v] = the chain's fixed point; plus ``syncs`` if requested.
    """
    combine = _COMBINE[op]

    def body(state):
        red, hop, _, syncs = state
        for _ in range(n_jumps):
            red = combine(red, red[hop])
            hop = hop[hop]
        return red, hop, jnp.any(hop != hop[hop]), syncs + 1

    red, hop, _, syncs = jax.lax.while_loop(
        lambda s: s[2], body,
        (payload, parent, jnp.bool_(True), jnp.int32(0)))
    # Uniform inclusive-of-root semantics: the loop may exit with red[v]
    # covering [v, root) only; one more fold of red[hop] (= payload[root],
    # stable at the fixed point) closes the interval for every vertex.
    red = combine(red, red[hop])
    return (red, hop, syncs) if return_syncs else (red, hop)


@partial(jax.jit, static_argnames=("n_jumps", "return_syncs"))
def rank_to_root(parent: jnp.ndarray, *, n_jumps: int = DEFAULT_JUMPS,
                 return_syncs: bool = False):
    """Pointer doubling with additive payload on a self-rooted parent array.

    The unit-payload ``op="add"`` instance of ``reduce_to_root``
    (DESIGN.md §3). Returns ``(depth, root)``: depth[v] = int32 #edges
    from v to its root, root[v] = the chain's fixed point. Roots carry
    depth 0 and hop = self, so extra chained steps past convergence are
    exact no-ops (``depth += depth[root] == 0``).

    Args:
      parent: int32[n] self-rooted acyclic parent table.
    """
    n = parent.shape[0]
    depth0 = (parent != jnp.arange(n, dtype=parent.dtype)).astype(jnp.int32)
    return reduce_to_root(parent, depth0, "add", n_jumps=n_jumps,
                          return_syncs=return_syncs)


@partial(jax.jit, static_argnames=("op", "use_kernel", "interpret"))
def segment_reduce(values: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray,
                   op: str = "min", *, use_kernel: bool = False,
                   interpret: bool | None = None):
    """Idempotent range reduction: out[q] = op over values[lo[q] .. hi[q]].

    The payload-reduce analogue of ``jump_k`` on the shift successor
    ``i ↦ i + 2^k``: level k of the doubling (sparse) table holds
    ``T[k][i] = op over values[i : i + 2^k]``, built in ⌈log2 n⌉ chained
    doubling steps with zero convergence syncs (the table is
    depth-oblivious). Each query folds the two power-of-two segments
    covering [lo, hi] — which double-count their overlap, hence the
    idempotency requirement. This is the subtree low/high primitive for
    the biconnectivity layer (DESIGN.md §4): with ``values`` laid out in
    preorder, subtree(v) is the contiguous query
    ``[pre[v], pre[v] + size[v] - 1]``.

    Args:
      values: [n] array, any dtype ``op`` supports.
      lo, hi: int32[q] inclusive query bounds, ``0 <= lo <= hi < n``.
      op: "min" | "max" (idempotent ops only — "add" would double-count).
      use_kernel: build the sparse table in one whole-table Pallas launch
        (``kernels.segment_table``; the query fold stays XLA-side).
      interpret: Pallas interpret mode; None → ``default_interpret()``.

    Returns:
      [q] array of per-query reductions, same dtype as ``values``.
    """
    if op not in ("min", "max"):
        raise ValueError(f"segment_reduce needs an idempotent op, got {op!r}")
    combine = _COMBINE[op]
    n = values.shape[0]
    levels = max(1, (n - 1).bit_length())
    if use_kernel:
        if interpret is None:
            interpret = default_interpret()
        from repro.kernels.segment_table.ops import segment_table
        table = segment_table(values, levels=levels, op=op,
                              interpret=interpret)  # [levels+1, n]
    else:
        rows = [values]
        t = values
        for k in range(levels):
            # The shift successor i ↦ i + 2^k is static: a slice beats a
            # gather (chained whole-table gathers cost XLA quadratic
            # compile time — measured 37 s at n = 2000). Off-the-end
            # positions fold T[k][n-1], which covers {n-1} ⊆ any suffix,
            # so the fold is an idempotent no-op (add would be wrong
            # here).
            s = 1 << k
            if s < n:
                shifted = jnp.concatenate(
                    [t[s:], jnp.broadcast_to(t[n - 1], (s,))])
            else:
                shifted = jnp.broadcast_to(t[n - 1], (n,))
            t = combine(t, shifted)
            rows.append(t)
        table = jnp.stack(rows)                  # [levels+1, n]

    return _fold_queries(table, lo, hi, levels, combine)


def _fold_queries(table, lo, hi, levels, combine):
    """Fold the two power-of-two segments covering each [lo, hi] query."""
    length = hi - lo + 1
    # k = floor(log2(length)), int-exact (no float log at segment bounds).
    k = jnp.zeros_like(length)
    for j in range(1, levels + 1):
        k = k + (length >= (1 << j)).astype(length.dtype)
    span = jnp.left_shift(jnp.int32(1), k)       # 2^k <= length < 2^(k+1)
    return combine(table[k, lo], table[k, jnp.maximum(hi - span + 1, lo)])


@partial(jax.jit, static_argnames=("op", "return_syncs"))
def segment_reduce_scoped(values: jnp.ndarray, lo: jnp.ndarray,
                          hi: jnp.ndarray, active: jnp.ndarray,
                          op: str = "min", *, return_syncs: bool = False):
    """Activity-masked ``segment_reduce``: only *active* queries matter.

    The BCC analogue of ``compress_scoped`` (DESIGN.md §10): where
    ``segment_reduce`` builds the full ⌈log2 n⌉-level doubling sparse
    table unconditionally (depth-oblivious, zero convergence syncs),
    this variant builds levels in a ``while_loop`` that stops as soon as
    ``2^k`` covers the longest *active* query — so when a batch dirties
    only small components, the table build costs
    ⌈log2(max active length)⌉ doubling steps instead of ⌈log2 n⌉
    regardless of how large the clean remainder is. The per-level shift
    is a clamped gather (the dynamic shift amount rules out the static
    slice trick, but there is exactly one gather in the loop body, so
    the chained-gather XLA compile blowup the static path dodges cannot
    occur here). No kernel path: the Pallas ``segment_table`` build has
    a static grid, which is incompatible with the dynamic level count —
    the scoped variant exists precisely to make that count dynamic.

    Args:
      values: [n] array, any dtype ``op`` supports.
      lo, hi: int32[q] inclusive query bounds, ``0 <= lo <= hi < n``.
      active: bool[q] — queries that must be answered exactly. Inactive
        queries return a *defined but arbitrary* value (a partial fold
        over however many levels were built); callers merge them with a
        cached answer (`jnp.where(active, out, cached)`).
      op: "min" | "max" (idempotent ops only).
      return_syncs: also return the number of doubling levels built
        (int32) — the device-independent cost the dynamic-BCC
        benchmarks track (DESIGN.md §10).

    Returns:
      [q] per-query reductions (exact where ``active``), plus the level
      count if ``return_syncs``.
    """
    if op not in ("min", "max"):
        raise ValueError(f"segment_reduce needs an idempotent op, got {op!r}")
    combine = _COMBINE[op]
    n = values.shape[0]
    levels = max(1, (n - 1).bit_length())
    idx = jnp.arange(n, dtype=jnp.int32)
    max_len = jnp.max(jnp.where(active, hi - lo + 1, 1)).astype(jnp.int32)

    # Unbuilt rows are initialized to row 0 (= values) so inactive
    # queries index defined data; built rows overwrite in place.
    table0 = jnp.broadcast_to(values, (levels + 1, n))

    def body(state):
        table, t, k = state
        s = jnp.left_shift(jnp.int32(1), k)
        t = combine(t, t[jnp.minimum(idx + s, n - 1)])
        return table.at[k + 1].set(t), t, k + 1

    def cond(state):
        _table, _t, k = state
        return (jnp.left_shift(jnp.int32(1), k) < max_len) & (k < levels)

    table, _, built = jax.lax.while_loop(cond, body,
                                         (table0, values, jnp.int32(0)))
    out = _fold_queries(table, lo, hi, levels, combine)
    return (out, built) if return_syncs else out


@partial(jax.jit, static_argnames=("n_jumps", "use_kernel", "interpret",
                                   "return_syncs"))
def wyllie_rank(succ: jnp.ndarray, valid: jnp.ndarray, *,
                n_jumps: int = DEFAULT_JUMPS, use_kernel: bool = False,
                interpret: bool | None = None, return_syncs: bool = False):
    """Wyllie list ranking: d[e] = #list elements after e (DESIGN.md §3).

    −1-sentinel successor convention (Euler tour lists). The pure-XLA path
    chains ``n_jumps`` (dist, succ) doubling steps per ``jnp.any`` sync;
    the kernel path launches the multi-step list_rank Pallas kernel on
    once-padded 2-D tables. ``return_syncs`` counts convergence checks on
    both paths.

    Args:
      succ: int32[n] successor table; −1 terminates a list. Disjoint lists
        (one per Euler-tour component) rank independently.
      valid: bool[n] slot validity (padding slots rank 0).

    Returns:
      int32[n] distances to each element's own list end, or ``(d, syncs)``.
    """
    d0 = jnp.where(valid & (succ != NO_SUCC), 1, 0).astype(jnp.int32)

    if use_kernel:
        if interpret is None:
            interpret = default_interpret()
        from repro.kernels.list_rank.list_rank import list_rank_double_pallas
        from repro.kernels.list_rank.ops import pad_to_tile
        succ2d, dist2d, n = pad_to_tile(succ, d0)

        def kbody(state):
            s, d, syncs = state
            s2, d2 = list_rank_double_pallas(s, d, n_steps=n_jumps,
                                             interpret=interpret)
            return s2, d2, syncs + 1

        def kcond(state):
            s, _d, _syncs = state
            return jnp.any(s != NO_SUCC)

        _, dist2d, syncs = jax.lax.while_loop(
            kcond, kbody, (succ2d, dist2d, jnp.int32(0)))
        d = dist2d.reshape(-1)[:n]
        return (d, syncs) if return_syncs else d

    def body(state):
        d, s, syncs = state
        for _ in range(n_jumps):
            has = s != NO_SUCC
            safe = jnp.where(has, s, 0)
            d = jnp.where(has, d + d[safe], d)
            s = jnp.where(has, s[safe], s)
        return d, s, syncs + 1

    def cond(state):
        _d, s, _syncs = state
        return jnp.any(s != NO_SUCC)

    d, _, syncs = jax.lax.while_loop(cond, body, (d0, succ, jnp.int32(0)))
    return (d, syncs) if return_syncs else d
