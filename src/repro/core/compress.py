"""Unified pointer-compression engine for every O(log n) jump phase.

All three RST pipelines bottom out in pointer doubling over a parent /
successor table: GConn's shortcutting between hook rounds, PR-RST's
``roots_of``, Euler/Wyllie list ranking, and the tree-depth diagnostic.
The seed code paid for each instance separately with a hand-rolled
``while_loop(any(p[p] != p))`` loop — one device↔host convergence sync per
*single* doubling step, which is exactly the per-launch overhead the
paper's 5-jump-per-launch optimization exists to amortize.

This engine is the single home for those loops (DESIGN.md §3):

  * ``jump_k(p, k)``      — k chained doubling steps, zero convergence syncs;
  * ``compress_full(p)``  — full path compression; ``n_jumps`` doubling steps
                            are chained between ``jnp.any`` checks, so
                            convergence costs ⌈log2(depth)/k⌉ + 1 syncs
                            instead of ⌈log2(depth)⌉ + 1 — in the pure-XLA
                            path as well as the Pallas-kernel path;
  * ``roots_of(p)``       — alias of ``compress_full`` (non-destructive:
                            both are functional);
  * ``rank_to_root(p)``   — doubling with additive payload on self-rooted
                            parent arrays → (depth, root) per vertex;
  * ``wyllie_rank(s, v)`` — list ranking (−1-sentinel successor convention)
                            with the same amortization.

``interpret=None`` everywhere dispatches from ``jax.default_backend()``:
compiled Mosaic on TPU, the Pallas interpreter elsewhere. The kernel path
pads to the (8, 128) tile once, *outside* the convergence loop, and runs
the whole loop on the padded 2-D table.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

NO_SUCC = jnp.int32(-1)

#: Doubling steps chained between convergence checks (paper's 5-jump trick).
DEFAULT_JUMPS = 5


def default_interpret() -> bool:
    """Pallas interpret-mode dispatch: compiled on TPU, interpreter elsewhere.

    Single policy shared with every kernel ops wrapper
    (``repro.kernels.auto_interpret``)."""
    from repro.kernels import auto_interpret
    return auto_interpret()


def jump_k(p: jnp.ndarray, n_jumps: int = DEFAULT_JUMPS) -> jnp.ndarray:
    """Apply ``p = p[p]`` ``n_jumps`` times — no convergence check, no sync.

    Each application *doubles* the compressed distance, so ``jump_k``
    covers chains of depth up to ``2**n_jumps``.
    """
    for _ in range(n_jumps):
        p = p[p]
    return p


@partial(jax.jit, static_argnames=("n_jumps", "use_kernel", "interpret",
                                   "return_syncs", "max_syncs"))
def compress_full(p: jnp.ndarray, *, n_jumps: int = DEFAULT_JUMPS,
                  use_kernel: bool = False, interpret: bool | None = None,
                  return_syncs: bool = False, max_syncs: int | None = None):
    """Fully compress ``p`` (every entry ends on its chain's fixed point).

    Amortization contract: the convergence loop performs ``n_jumps``
    doubling steps per ``jnp.any`` sync, so a table of maximum depth d
    costs ⌈log2(d)/n_jumps⌉ + 1 syncs (the +1 confirms convergence).

    Args:
      p: int32[n] parent table; roots self-point. (Cyclic inputs are not
         trees: odd cycles never converge — pass ``max_syncs`` to bound the
         loop — and even cycles collapse to *spurious* fixed points that
         are not roots of the original table; callers validating arbitrary
         inputs must re-check fixed points against the original ``p``, see
         ``validate.reaches_root``.)
      n_jumps: doubling steps chained between convergence checks.
      use_kernel: route each chained-jump group through the Pallas doubling
         kernel (one launch per sync); padding is hoisted out of the loop.
      interpret: Pallas interpret mode; None → ``default_interpret()``.
      return_syncs: also return the number of ``jnp.any`` convergence
         checks executed (int32) — the counting hook for tests/benchmarks.
      max_syncs: optional static bound on convergence checks.

    Returns:
      compressed table, or ``(compressed, syncs)`` if ``return_syncs``.
    """
    if use_kernel:
        if interpret is None:
            interpret = default_interpret()
        from repro.kernels.pointer_jump.ops import (pad_to_tile,
                                                    pointer_jump_double_k)
        p2d, n = pad_to_tile(p)

        def step(q):
            return pointer_jump_double_k(q, n_jumps=n_jumps,
                                         interpret=interpret)
    else:
        p2d, n = p, p.shape[0]

        def step(q):
            return jump_k(q, n_jumps)

    def body(state):
        q, _, syncs = state
        q2 = step(q)
        return q2, jnp.any(q2 != q), syncs + 1

    def cond(state):
        _q, changed, syncs = state
        if max_syncs is not None:
            changed = changed & (syncs < max_syncs)
        return changed

    out, _, syncs = jax.lax.while_loop(
        cond, body, (p2d, jnp.bool_(True), jnp.int32(0)))
    if use_kernel:
        out = out.reshape(-1)[:n]
    return (out, syncs) if return_syncs else out


def roots_of(p: jnp.ndarray, **kwargs):
    """Root of every vertex's chain. Alias of ``compress_full`` (functional,
    hence non-destructive — callers keep their original ``p``)."""
    return compress_full(p, **kwargs)


@partial(jax.jit, static_argnames=("n_jumps", "return_syncs"))
def rank_to_root(parent: jnp.ndarray, *, n_jumps: int = DEFAULT_JUMPS,
                 return_syncs: bool = False):
    """Pointer doubling with additive payload on a self-rooted parent array.

    Returns ``(depth, root)``: depth[v] = #edges from v to its root,
    root[v] = the chain's fixed point. Roots carry depth 0 and hop = self,
    so extra chained steps past convergence are exact no-ops
    (``depth += depth[root] == 0``).
    """
    n = parent.shape[0]
    depth0 = (parent != jnp.arange(n, dtype=parent.dtype)).astype(jnp.int32)

    def body(state):
        depth, hop, _, syncs = state
        for _ in range(n_jumps):
            depth = depth + depth[hop]
            hop = hop[hop]
        return depth, hop, jnp.any(hop != hop[hop]), syncs + 1

    depth, hop, _, syncs = jax.lax.while_loop(
        lambda s: s[2], body,
        (depth0, parent, jnp.bool_(True), jnp.int32(0)))
    return (depth, hop, syncs) if return_syncs else (depth, hop)


@partial(jax.jit, static_argnames=("n_jumps", "use_kernel", "interpret",
                                   "return_syncs"))
def wyllie_rank(succ: jnp.ndarray, valid: jnp.ndarray, *,
                n_jumps: int = DEFAULT_JUMPS, use_kernel: bool = False,
                interpret: bool | None = None, return_syncs: bool = False):
    """Wyllie list ranking: d[e] = #list elements after e.

    −1-sentinel successor convention (Euler tour lists). The pure-XLA path
    chains ``n_jumps`` (dist, succ) doubling steps per ``jnp.any`` sync;
    the kernel path launches the multi-step list_rank Pallas kernel on
    once-padded 2-D tables. ``return_syncs`` counts convergence checks on
    both paths.
    """
    d0 = jnp.where(valid & (succ != NO_SUCC), 1, 0).astype(jnp.int32)

    if use_kernel:
        if interpret is None:
            interpret = default_interpret()
        from repro.kernels.list_rank.list_rank import list_rank_double_pallas
        from repro.kernels.list_rank.ops import pad_to_tile
        succ2d, dist2d, n = pad_to_tile(succ, d0)

        def kbody(state):
            s, d, syncs = state
            s2, d2 = list_rank_double_pallas(s, d, n_steps=n_jumps,
                                             interpret=interpret)
            return s2, d2, syncs + 1

        def kcond(state):
            s, _d, _syncs = state
            return jnp.any(s != NO_SUCC)

        _, dist2d, syncs = jax.lax.while_loop(
            kcond, kbody, (succ2d, dist2d, jnp.int32(0)))
        d = dist2d.reshape(-1)[:n]
        return (d, syncs) if return_syncs else d

    def body(state):
        d, s, syncs = state
        for _ in range(n_jumps):
            has = s != NO_SUCC
            safe = jnp.where(has, s, 0)
            d = jnp.where(has, d + d[safe], d)
            s = jnp.where(has, s[safe], s)
        return d, s, syncs + 1

    def cond(state):
        _d, s, _syncs = state
        return jnp.any(s != NO_SUCC)

    d, _, syncs = jax.lax.while_loop(cond, body, (d0, succ, jnp.int32(0)))
    return (d, syncs) if return_syncs else d
