"""Multi-chip rooted spanning tree: the paper's algorithm at pod scale.

The paper runs on one GPU. To make RST construction a first-class primitive
of a 1000+-node framework, this module maps the hooking / pointer-jumping
rounds onto a device mesh with ``shard_map``:

  * **edges are sharded** across the mesh axis (the O(E) side scales out);
  * **the parent table is replicated** (O(V) per chip) — hook proposals are
    combined across chips with an elementwise min-reduction
    (``lax.pmin``-style via ``psum``/min tricks), the multi-chip analogue of
    the single-GPU atomicMin;
  * pointer jumping is purely local (replicated table ⇒ zero collectives),
    so each round costs exactly **two all-reduce-min collectives** (hook +
    winner-edge), independent of graph diameter.

Communication cost per round: 2 × n × 4 bytes all-reduce. Total rounds
O(log n) ⇒ collective volume O(n log n) — versus BFS whose level loop costs
one frontier all-reduce *per level*, i.e. O(diam) rounds. The paper's
diameter argument strengthens at scale (DESIGN.md §2).

For V beyond per-chip memory the design extends to vertex-partitioned
tables with all-to-all rep exchange; the replicated variant is what the
256-chip dry-run exercises (n=16M table = 64 MB replicated, fine).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.compress import compress_full

INF32 = jnp.iinfo(jnp.int32).max


def _allmin(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Elementwise min across devices (all-reduce-min)."""
    neg = -x
    m = jax.lax.pmax(neg, axis_name)
    return -m


def distributed_cc_spanning_forest(mesh: Mesh, axis: str = "data"):
    """Build the sharded connectivity + spanning-forest step function.

    Returns a jit'd function ``f(src, dst, n_nodes) -> (rep, forest_mask,
    rounds)`` where src/dst are GLOBAL edge arrays sharded over ``axis``
    (callers pass arrays whose leading dim divides the axis size) and
    forest_mask is sharded the same way.
    """
    axis_size = mesh.shape[axis]

    def step_fn(src, dst, edge_gid, p0):
        n = p0.shape[0]

        # Pointer jumping on the replicated table is purely local — route
        # it through the shared engine (amortized convergence syncs).
        pointer_jump_full = compress_full

        def body(state):
            p, forest, rnd, _ = state
            ru = p[src]
            rv = p[dst]
            cross = ru != rv
            use_min = jnp.bool_(True)   # pure min-hooking (see connectivity.py)
            lo = jnp.minimum(ru, rv)
            hi = jnp.maximum(ru, rv)
            tgt = jnp.where(use_min, hi, lo)
            val = jnp.where(use_min, lo, hi)

            # Local hook proposal (min-encoded for both directions) ...
            enc = jnp.where(use_min, val, n - 1 - val)
            local = jnp.full((n,), INF32, jnp.int32).at[tgt].min(
                jnp.where(cross, enc, INF32))
            # ... combined across chips: ONE all-reduce-min.
            glob = _allmin(local, axis)
            got = glob != INF32
            new_parent = jnp.where(use_min, glob, n - 1 - glob)
            p_next = jnp.where(got, new_parent, p)

            # Winner edge (global edge id): second all-reduce-min.
            achieved = cross & got[tgt] & (new_parent[tgt] == val)
            local_win = jnp.full((n,), INF32, jnp.int32).at[tgt].min(
                jnp.where(achieved, edge_gid, INF32))
            glob_win = _allmin(local_win, axis)
            is_winner = achieved & (glob_win[tgt] == edge_gid)
            forest = forest | is_winner

            p_next = pointer_jump_full(p_next)
            changed = jnp.any(got)
            return p_next, forest, rnd + 1, changed

        def cond(state):
            _p, _f, rnd, changed = state
            return changed & (rnd < n)

        forest0 = jnp.zeros(src.shape, jnp.bool_)
        p, forest, rounds, _ = jax.lax.while_loop(
            cond, body, (p0, forest0, jnp.int32(0), jnp.bool_(True)))
        return p, forest, rounds - 1

    sharded = shard_map(
        step_fn, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P()),
        out_specs=(P(), P(axis), P()),
        check_rep=False,
    )

    @partial(jax.jit, static_argnames=("n_nodes",))
    def run(src, dst, *, n_nodes: int):
        m = src.shape[0]
        assert m % axis_size == 0, (
            f"edge count {m} must divide mesh axis {axis}={axis_size}; "
            "pad with self-loop edges (0, 0)")
        gid = jnp.arange(m, dtype=jnp.int32)
        p0 = jnp.arange(n_nodes, dtype=jnp.int32)
        return sharded(src, dst, gid, p0)

    return run


def input_specs_rst(n_nodes: int, n_half_edges: int, mesh: Mesh,
                    axis: str = "data"):
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    ns_e = NamedSharding(mesh, P(axis))
    return dict(
        src=jax.ShapeDtypeStruct((n_half_edges,), jnp.int32, sharding=ns_e),
        dst=jax.ShapeDtypeStruct((n_half_edges,), jnp.int32, sharding=ns_e),
    )
