"""Core library: the paper's rooted-spanning-tree primitives in JAX."""
from repro.core.graph import Graph, build_csr
from repro.core.bcc import (BCCResult, bcc_batch, bcc_from_parent,
                            bcc_from_tour, biconnectivity)
from repro.core.bfs import bfs_rst
from repro.core.compress import (DEFAULT_JUMPS, compress_full,
                                 compress_scoped, jump_k, rank_to_root,
                                 reduce_to_root, roots_of, segment_reduce,
                                 segment_reduce_scoped, wyllie_rank)
from repro.core.connectivity import connected_components, pointer_jump_full
from repro.core.euler import (TourNumbering, euler_tour_root,
                              list_rank_dist_to_end, tour_numbering)
from repro.core.pr_rst import pr_rst
from repro.core.queries import (QueryTables, build_tables, connected,
                                depth_of, edge_membership, is_ancestor,
                                lca, path_agg, subtree_agg)
from repro.core.reroot import link_components, mark_paths, reverse_and_graft
from repro.core.rst import (METHODS, RSTResult, gconn_euler_rst,
                            rooted_spanning_tree, tree_depth)

__all__ = [
    "Graph", "build_csr", "bfs_rst", "connected_components",
    "pointer_jump_full", "euler_tour_root", "list_rank_dist_to_end",
    "TourNumbering", "tour_numbering",
    "BCCResult", "bcc_batch", "bcc_from_parent", "bcc_from_tour",
    "biconnectivity",
    "pr_rst", "METHODS", "RSTResult", "gconn_euler_rst",
    "rooted_spanning_tree", "tree_depth",
    "DEFAULT_JUMPS", "compress_full", "compress_scoped", "jump_k",
    "rank_to_root", "reduce_to_root", "roots_of", "segment_reduce",
    "segment_reduce_scoped", "wyllie_rank",
    "link_components", "mark_paths", "reverse_and_graft",
    "QueryTables", "build_tables", "connected", "depth_of",
    "edge_membership", "is_ancestor", "lca", "path_agg", "subtree_agg",
]
