"""Graph containers for the RST library.

Graphs are stored as fixed-shape, jit-friendly COO edge lists. An undirected
graph with M undirected edges is stored as 2M directed half-edges arranged so
that ``rev(e) = (e + M) % 2M`` — half-edge ``i`` and ``i + M`` are the two
directions of the same undirected edge. This is exactly the pairing the paper
uses for the Euler tour ("compute the corresponding reverse edge
((last[r] + E/2) mod E)").

All arrays are int32; vertex ids in ``[0, n)``. Padding (for ragged batches)
uses ``src == dst == n_nodes`` sentinel rows which every algorithm masks out.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Graph:
    """COO undirected graph as paired directed half-edges.

    Attributes:
      n_nodes: static int, number of vertices.
      src, dst: int32[2M] directed half-edges; ``rev(e) = (e + M) % 2M``.
    """

    n_nodes: int
    src: jnp.ndarray
    dst: jnp.ndarray

    # -- pytree plumbing (n_nodes is static) --------------------------------
    def tree_flatten(self):
        return (self.src, self.dst), self.n_nodes

    @classmethod
    def tree_unflatten(cls, aux, children):
        src, dst = children
        return cls(n_nodes=aux, src=src, dst=dst)

    # -- properties ----------------------------------------------------------
    @property
    def n_half_edges(self) -> int:
        return int(self.src.shape[0])

    @property
    def n_edges(self) -> int:
        """Number of undirected edges M."""
        return self.n_half_edges // 2

    def rev(self, e: jnp.ndarray) -> jnp.ndarray:
        """Index of the reverse half-edge."""
        m = self.n_edges
        return (e + m) % (2 * m)

    # -- constructors ---------------------------------------------------------
    @staticmethod
    def from_undirected(n_nodes: int, u: jnp.ndarray, v: jnp.ndarray) -> "Graph":
        """Build from M undirected edges (u[i], v[i])."""
        u = jnp.asarray(u, jnp.int32)
        v = jnp.asarray(v, jnp.int32)
        return Graph(n_nodes=n_nodes, src=jnp.concatenate([u, v]),
                     dst=jnp.concatenate([v, u]))

    @staticmethod
    def from_numpy_undirected(n_nodes: int, edges: np.ndarray) -> "Graph":
        """edges: int array [M, 2]. Removes self-loops and duplicates."""
        edges = np.asarray(edges, dtype=np.int64)
        if edges.size == 0:
            e = np.zeros((0,), np.int32)
            return Graph(n_nodes=n_nodes, src=jnp.asarray(e), dst=jnp.asarray(e))
        lo = np.minimum(edges[:, 0], edges[:, 1])
        hi = np.maximum(edges[:, 0], edges[:, 1])
        keep = lo != hi
        lo, hi = lo[keep], hi[keep]
        key = lo * n_nodes + hi
        _, idx = np.unique(key, return_index=True)
        u = lo[idx].astype(np.int32)
        v = hi[idx].astype(np.int32)
        return Graph.from_undirected(n_nodes, jnp.asarray(u), jnp.asarray(v))


def build_csr(graph: Graph) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """CSR over directed half-edges: (row_ptr[n+1], col[2M], half_edge_id[2M]).

    ``col`` / ``half_edge_id`` are sorted by (src, dst) lexicographically —
    the "circular adjacency list" ordering the Euler tour needs.
    """
    n = graph.n_nodes
    order = jnp.lexsort((graph.dst, graph.src))
    col = graph.dst[order]
    counts = jnp.bincount(graph.src, length=n)
    row_ptr = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts).astype(jnp.int32)])
    return row_ptr, col, order.astype(jnp.int32)


@partial(jax.jit, static_argnames=("n_nodes",))
def degrees(src: jnp.ndarray, n_nodes: int) -> jnp.ndarray:
    return jnp.bincount(src, length=n_nodes)
