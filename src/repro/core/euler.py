"""Euler-tour rooting of a spanning forest (paper §III-D).

Given an (unrooted) spanning forest as an edge list, orient every edge toward
a designated root per component in O(log n) parallel depth:

  1. materialize both directions of every forest edge with the pairing
     ``rev(e) = (e + T) % 2T``;
  2. lexicographically sort directed edges by (from, to) — the XLA-sort
     replacement for the paper's CUB radix sort — inducing a deterministic
     circular adjacency ordering with ``first[v]`` / ``next[e]`` implicit in
     the sorted permutation;
  3. compute the Euler successor
        succ(e) = next(rev(e))            if it exists,
                  first(from(rev(e)))     otherwise (wrap-around);
  4. break each component's Euler *cycle* into a linear list at that
     component's root (cut the reverse of the root's last outgoing edge —
     the generalization to disconnected forests from the paper);
  5. Wyllie pointer-doubling list ranking (multi-jump Pallas kernel
     optional) — we keep ``d[e] =`` #edges *after* e, which is enough to
     order e against rev(e) without per-tree totals;
  6. the earlier-traversed direction of each edge is the discovery edge
     (x → y) ⇒ ``parent[y] = x``.

All shapes are static: the forest is padded to ``n - 1`` slots with
``from = n`` sentinels which sort to the tail and stay inert.

Besides rooting (``euler_tour_root``), the module exposes the tour's
*numbering* (``tour_numbering``): dense first-visit (preorder) numbers and
subtree sizes for an already-rooted parent array — the substrate the
biconnectivity layer's subtree-interval queries stand on (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.compress import roots_of, wyllie_rank

NO_SUCC = jnp.int32(-1)


def _lexsort_edges(frm: jnp.ndarray, to: jnp.ndarray) -> jnp.ndarray:
    """Sort directed edges by (from, to); returns permutation ``ord``."""
    return jnp.lexsort((to, frm)).astype(jnp.int32)


def list_rank_dist_to_end(succ: jnp.ndarray, valid: jnp.ndarray,
                          *, use_kernel: bool = False,
                          return_syncs: bool = False) -> jnp.ndarray:
    """Wyllie list ranking: d[e] = number of list elements after e.

    Routed through the unified engine (``core.compress.wyllie_rank``):
    amortized convergence checks, optional list_rank Pallas kernel.
    """
    return wyllie_rank(succ, valid, use_kernel=use_kernel,
                       return_syncs=return_syncs)


def _tour_successors(n: int, fu: jnp.ndarray, fv: jnp.ndarray,
                     valid: jnp.ndarray, comp_root: jnp.ndarray):
    """Steps 1–4 shared by rooting and numbering: build the Euler lists.

    Returns ``(succ, dvalid)`` over the 2T directed slots (slot e < T is
    direction fu[e]→fv[e], slot e + T its reverse): the −1-terminated Euler
    successor lists, one per component, each cut at its ``comp_root``.
    """
    t = fu.shape[0]
    sentinel = jnp.int32(n)

    fu = jnp.where(valid, fu, sentinel)
    fv = jnp.where(valid, fv, sentinel)

    # Both directions; rev(e) = (e + t) % 2t.
    frm = jnp.concatenate([fu, fv])
    to = jnp.concatenate([fv, fu])
    m2 = 2 * t
    eid = jnp.arange(m2, dtype=jnp.int32)
    rev = (eid + t) % m2
    dvalid = jnp.concatenate([valid, valid])

    # Sorted circular adjacency ordering (first/next are implicit).
    order = _lexsort_edges(frm, to)
    ipos = jnp.zeros((m2,), jnp.int32).at[order].set(eid)
    sfrom = frm[order]
    first_pos = jnp.searchsorted(sfrom, jnp.arange(n + 1, dtype=jnp.int32),
                                 side="left").astype(jnp.int32)
    last_pos = jnp.searchsorted(sfrom, jnp.arange(n + 1, dtype=jnp.int32),
                                side="right").astype(jnp.int32) - 1

    # succ(e) = next(rev(e)) or wrap to first(from(rev(e))).
    p = ipos[rev]
    p_next = jnp.minimum(p + 1, m2 - 1)
    has_next = (p + 1 < m2) & (sfrom[p_next] == sfrom[p])
    wrap = order[first_pos[jnp.clip(sfrom[p], 0, n)]]
    succ = jnp.where(has_next, order[p_next], wrap)
    succ = jnp.where(dvalid, succ, NO_SUCC)

    # Break each component's cycle at its root: cut rev(last-out-edge(root)).
    verts = jnp.arange(n, dtype=jnp.int32)
    is_root = comp_root == verts
    has_out = last_pos[:-1] >= first_pos[:-1]
    do_cut = is_root & has_out
    last_edge = order[jnp.clip(last_pos[:-1], 0, m2 - 1)]
    cut_edge = rev[last_edge]
    cut_idx = jnp.where(do_cut, cut_edge, m2)  # m2 → dropped
    succ = succ.at[cut_idx].set(NO_SUCC, mode="drop")
    return succ, dvalid


@partial(jax.jit, static_argnums=(0,),
         static_argnames=("use_kernel", "return_syncs"))
def euler_tour_root(n_nodes: int, fu: jnp.ndarray, fv: jnp.ndarray,
                    valid: jnp.ndarray, comp_root: jnp.ndarray,
                    *, use_kernel: bool = False, return_syncs: bool = False):
    """Root a spanning forest by Euler tour.

    Args:
      n_nodes: number of vertices n (static via shapes).
      fu, fv: int32[T] forest edge endpoints (T slots, typically n-1);
              padding slots must carry ``fu == fv == n_nodes``.
      valid: bool[T] slot validity.
      comp_root: int32[n] — the vertex every component should be rooted at
              (constant within a component; ``comp_root[v] == v`` iff v is
              that component's root).
      use_kernel: route list ranking through the Pallas list_rank kernel.
      return_syncs: also return the list-ranking convergence-check count
              (int32) — the dominant engine cost of a from-scratch
              rooting, tracked by the recovery benchmarks (DESIGN.md §11).

    Returns:
      parent: int32[n]; ``parent[root] == root`` per component, every other
              vertex in a non-trivial component points at its tree parent;
              isolated vertices point at themselves. With ``return_syncs``:
              ``(parent, syncs)``.
    """
    n = n_nodes
    t = fu.shape[0]
    sentinel = jnp.int32(n)
    succ, dvalid = _tour_successors(n, fu, fv, valid, comp_root)

    # Rank; earlier-traversed direction has the larger distance-to-end.
    d, rank_syncs = list_rank_dist_to_end(succ, dvalid,
                                          use_kernel=use_kernel,
                                          return_syncs=True)

    # Discovery edge (x → y) ⇒ parent[y] = x.
    de = d[:t]
    dr = d[t:]
    disc_u_to_v = de > dr          # (u→v) earlier ⇒ parent[v] = u
    child = jnp.where(disc_u_to_v, fv, fu)
    par = jnp.where(disc_u_to_v, fu, fv)
    child = jnp.where(valid, child, sentinel)

    parent = jnp.arange(n, dtype=jnp.int32)
    parent = parent.at[child].set(par, mode="drop")
    if return_syncs:
        return parent, rank_syncs
    return parent


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class TourNumbering:
    """Euler-tour first/last-visit numbering of a rooted forest.

    Attributes (all int32[n], DESIGN.md §4):
      pre:    dense preorder — components occupy contiguous index blocks,
              and subtree(v) is exactly the interval
              ``[pre[v], pre[v] + size[v])``.
      size:   |subtree(v)| including v.
      last:   ``pre[v] + size[v] - 1`` — preorder number of v's last
              (deepest-last-visited) descendant.
      comp:   component root of every vertex (``comp[v] == v`` iff root).
      parent: the canonicalized parent table the numbering was built from
              (negative entries replaced by self-loops).
    """

    pre: jnp.ndarray
    size: jnp.ndarray
    last: jnp.ndarray
    comp: jnp.ndarray
    parent: jnp.ndarray

    def tree_flatten(self):
        return (self.pre, self.size, self.last, self.comp, self.parent), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


@partial(jax.jit, static_argnames=("use_kernel", "return_syncs"))
def tour_numbering(parent: jnp.ndarray, *, use_kernel: bool = False,
                   return_syncs: bool = False) -> TourNumbering:
    """First/last-visit numbering of a rooted forest's Euler tour.

    Consumes the parent array of *any* RST pipeline (BFS / GConn+Euler /
    PR-RST) and exposes the tour positions the rooting path discards: the
    tour of each component, started at its root, visits vertices in DFS
    preorder, so ranking the 2n directed tree-edge slots (one slot per
    vertex, invalid at roots) once (engine ``wyllie_rank``) yields
    discovery order, and the gap between a vertex's discovery edge and
    its closing edge yields its subtree size —
    ``size[v] = (d_down − d_up + 1) / 2`` (DESIGN.md §4).

    Args:
      parent: int32[n] parent table. Roots self-point; negative entries
        (BFS's unreachable −1) are treated as self-rooted singletons.
      use_kernel: route list ranking through the Pallas list_rank kernel.
      return_syncs: also return the engine convergence-check count
        (rooting compression + list ranking). The counters already ride
        both loops' carries, so requesting them is free — the obs-layer
        wrappers always do (DESIGN.md §14).

    Returns:
      TourNumbering (pre / size / last / comp / parent, all int32[n]).
      With ``return_syncs``: (numbering, int32 sync count).
    """
    n = parent.shape[0]
    verts = jnp.arange(n, dtype=jnp.int32)
    par = jnp.where(parent < 0, verts, parent.astype(jnp.int32))
    nonroot = par != verts
    comp, root_syncs = roots_of(par, return_syncs=True)

    # One tree-edge slot per vertex: slot v = (v, parent[v]), invalid at
    # roots. Directed slot v is the closing edge v→parent ("up"), slot
    # n + v the discovery edge parent→v ("down").
    sentinel = jnp.int32(n)
    fu = jnp.where(nonroot, verts, sentinel)
    fv = jnp.where(nonroot, par, sentinel)
    succ, dvalid = _tour_successors(n, fu, fv, nonroot, comp)
    d, rank_syncs = wyllie_rank(succ, dvalid, use_kernel=use_kernel,
                                return_syncs=True)
    d_up, d_down = d[:n], d[n:]

    # Subtree size: the tour segment [discovery(v), closing(v)] holds both
    # directions of every edge inside subtree(v) — 2·size(v) slots.
    comp_size = jnp.zeros((n,), jnp.int32).at[comp].add(1)
    size = jnp.where(nonroot, (d_down - d_up + 1) // 2, comp_size)

    # Dense preorder: sort by (component, discovery position). Within a
    # list, earlier discovery = larger distance-to-end; roots (no
    # discovery edge) sort first in their component block.
    key = jnp.where(nonroot, -d_down, jnp.iinfo(jnp.int32).min)
    order = jnp.lexsort((key, comp)).astype(jnp.int32)
    pre = jnp.zeros((n,), jnp.int32).at[order].set(verts)

    tn = TourNumbering(pre=pre, size=size, last=pre + size - 1,
                       comp=comp, parent=par)
    if return_syncs:
        return tn, root_syncs + rank_syncs
    return tn
