"""Unified rooted-spanning-tree API — the paper's three strategies.

``rooted_spanning_tree(graph, root, method=...)`` returns a parent array plus
per-method diagnostics (the step counts the paper's analysis revolves
around). All methods are jit-compiled with fixed shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax.numpy as jnp

from repro.core.bfs import bfs_rst as _bfs_rst
from repro.core.connectivity import connected_components as _connected_components
from repro.core.euler import euler_tour_root as _euler_tour_root
from repro.core.pr_rst import pr_rst as _pr_rst
from repro.core.graph import Graph

Method = Literal["bfs", "gconn_euler", "pr_rst"]
METHODS: tuple[str, ...] = ("bfs", "gconn_euler", "pr_rst")


import jax


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class RSTResult:
    parent: jnp.ndarray          # int32[n]
    method: str                  # static
    steps: jnp.ndarray           # parallel step count (levels or rounds)
    dist: jnp.ndarray | None = None      # BFS only: hop distances
    rep: jnp.ndarray | None = None       # gconn only: component reps

    def tree_flatten(self):
        return (self.parent, self.steps, self.dist, self.rep), self.method

    @classmethod
    def tree_unflatten(cls, aux, children):
        parent, steps, dist, rep = children
        return cls(parent=parent, method=aux, steps=steps, dist=dist,
                   rep=rep)


def gconn_euler_rst(graph: Graph, root, *, use_kernel: bool = False):
    """Paper's winning pipeline: connectivity → spanning forest → Euler rooting.

    ``use_kernel`` reaches both jump phases: GConn's shortcutting (multi-jump
    pointer_jump kernel) and the Euler list ranking (list_rank kernel).
    """
    n = graph.n_nodes
    rep, forest_mask, rounds = _connected_components(graph,
                                                     use_kernel=use_kernel)

    # Compact marked half-edges into n-1 fixed slots.
    t = max(n - 1, 1)
    slots = jnp.nonzero(forest_mask, size=t, fill_value=graph.src.shape[0])[0]
    in_range = slots < graph.src.shape[0]
    fu = jnp.where(in_range, graph.src[jnp.clip(slots, 0, graph.src.shape[0] - 1)], n)
    fv = jnp.where(in_range, graph.dst[jnp.clip(slots, 0, graph.src.shape[0] - 1)], n)
    valid = in_range

    # Component containing ``root`` is rooted at ``root``; others at their rep.
    root = jnp.asarray(root, jnp.int32)
    comp_root = jnp.where(rep == rep[root], root, rep)

    parent = _euler_tour_root(n, fu, fv, valid, comp_root,
                              use_kernel=use_kernel)
    return parent, rep, rounds


def rooted_spanning_tree(graph: Graph, root, method: Method = "gconn_euler",
                         *, use_kernel: bool = False, **kwargs) -> RSTResult:
    """Build a rooted spanning tree with the chosen strategy.

    ``use_kernel`` routes every jump/relax phase of the chosen pipeline
    through its Pallas kernel (interpret mode off-TPU); the default pure-XLA
    path shares the same amortized convergence engine (``core.compress``).
    """
    if method == "bfs":
        parent, dist, levels = _bfs_rst(graph, root, use_kernel=use_kernel,
                                        **kwargs)
        return RSTResult(parent=parent, method=method, steps=levels, dist=dist)
    if method == "gconn_euler":
        parent, rep, rounds = gconn_euler_rst(graph, root,
                                              use_kernel=use_kernel)
        return RSTResult(parent=parent, method=method, steps=rounds, rep=rep)
    if method == "pr_rst":
        parent, rounds = _pr_rst(graph, root, use_kernel=use_kernel, **kwargs)
        return RSTResult(parent=parent, method=method, steps=rounds)
    raise ValueError(f"unknown method {method!r}; choose from {METHODS}")


def tree_depth(parent: jnp.ndarray) -> jnp.ndarray:
    """Max depth of the rooted forest (engine-routed pointer doubling)."""
    from repro.core.compress import rank_to_root

    depth, _root = rank_to_root(parent)
    return jnp.max(depth)
