"""Tarjan–Vishkin biconnectivity on top of any RST pipeline (DESIGN.md §4).

The paper motivates rooted spanning trees as the substrate for
biconnectivity; this module is that consumer, extending the three-way RST
comparison one level up the stack. The algorithm is the Euler-tour
formulation (Tarjan & Vishkin 1985; JaJa §5.3; cf. Polak, *Euler Meets
GPU*, and Dong et al.'s low/high characterization):

  1. **Tour numbering** — ``euler.tour_numbering`` turns the flavor's
     parent array into dense preorder numbers and subtree sizes, so
     subtree(v) is the contiguous interval ``[pre[v], pre[v] + size[v])``.
  2. **low/high** — per-vertex extremes of preorder reachable from the
     subtree through one non-tree edge, as idempotent payload-reduce
     doubling over the preorder-ordered array (engine
     ``compress.segment_reduce``).
  3. **Auxiliary graph** — one vertex per tree edge (identified with its
     child endpoint); two tree edges share a biconnected component iff
     connected under the three Tarjan–Vishkin rules (below). The final
     components pass reuses GConn (``connectivity.connected_components``).
  4. **Readout** — per-half-edge BCC labels (deeper endpoint's aux
     representative), bridges (subtree with no escaping non-tree edge),
     and articulation points (vertex incident to ≥ 2 distinct blocks).

Aux-graph edge rules, for tree edge aux(v) := (parent(v), v) (DESIGN.md §4):
  R1  non-tree edge {u, w}, u, w unrelated (disjoint preorder intervals):
      aux(u) — aux(w);
  R2  tree edge (w = parent(v), v) with low(v) < pre(w):   aux(v) — aux(w);
  R3  tree edge (w, v) with high(v) ≥ pre(w) + size(w):    aux(v) — aux(w).

Everything is jit-compatible and fixed-shape: the aux edge list has
exactly 2M + 2n slots (one per non-tree half-edge candidate, two per tree
edge), padded with the usual ``src = dst = n`` sentinels. ``bcc_batch``
vmaps the whole stack for the many-small-graphs serving scenario.

The module is layered so the static and incremental paths share one
auxiliary-graph construction (DESIGN.md §10): ``bcc_from_tour`` is the
tour-driven core — it consumes an existing ``TourNumbering`` instead of
recomputing one, takes an optional explicit per-half-edge ``tree_mask``
(the multigraph-honest classification the dynamic edge pool maintains),
and an optional component-closed ``scope`` mask that restricts every
phase (low/high via ``segment_reduce_scoped``, aux rules, GConn
labeling) to dirty components. ``bcc_from_parent`` / ``biconnectivity``
/ ``bcc_batch`` are the static entry points on top of it;
``repro.dynamic.bcc`` is the incremental one.

Multigraph caveat (static entry points only): parent arrays cannot
distinguish parallel copies of a tree edge, so *inferred* tree
classification requires simple graphs — which
``Graph.from_numpy_undirected`` (dedup + self-loop removal) guarantees.
Callers that know the classification (the dynamic layer's ``tree_mask``)
may pass it explicitly and feed multigraphs to ``bcc_from_tour``.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.compress import segment_reduce, segment_reduce_scoped
from repro.core.connectivity import connected_components
from repro.core.euler import tour_numbering
from repro.core.graph import Graph
from repro.core.rst import METHODS, rooted_spanning_tree

INF32 = jnp.iinfo(jnp.int32).max


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class BCCResult:
    """Biconnectivity decomposition of a graph (all shapes fixed).

    Attributes:
      articulation: bool[n] — cut vertices.
      bridge:       bool[2M] per half-edge (both directions of a bridge
                    are marked; padding rows are False).
      edge_bcc:     int32[2M] biconnected-component label per half-edge
                    (an aux-graph representative id; −1 on padding rows).
                    Both directions of an edge carry the same label.
      n_bcc:        int32 scalar — number of biconnected components.
      pre, size:    int32[n] tour numbering diagnostics (DESIGN.md §4).
      low, high:    int32[n] subtree preorder extremes through one
                    non-tree edge.
      rst_steps:    int32 — parallel steps of the upstream RST build
                    (levels or rounds; the paper's Table I counts).
      aux_rounds:   int32 — GConn hook/compress rounds on the aux graph.
      seg_syncs:    int32 — doubling levels built for the low/high
                    sparse tables (both builds; the device-independent
                    cost the dynamic benchmarks compare, DESIGN.md §10).
      method:       static str — the ``rst_flavor`` that built the tree.
    """

    articulation: jnp.ndarray
    bridge: jnp.ndarray
    edge_bcc: jnp.ndarray
    n_bcc: jnp.ndarray
    pre: jnp.ndarray
    size: jnp.ndarray
    low: jnp.ndarray
    high: jnp.ndarray
    rst_steps: jnp.ndarray
    aux_rounds: jnp.ndarray
    seg_syncs: jnp.ndarray
    method: str = "gconn_euler"

    def tree_flatten(self):
        children = (self.articulation, self.bridge, self.edge_bcc,
                    self.n_bcc, self.pre, self.size, self.low, self.high,
                    self.rst_steps, self.aux_rounds, self.seg_syncs)
        return children, self.method

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, method=aux)


def bcc_from_tour(graph: Graph, parent: jnp.ndarray, tn, *,
                  tree_mask: jnp.ndarray | None = None,
                  scope: jnp.ndarray | None = None,
                  use_kernel: bool = False):
    """Tarjan–Vishkin core driven by an existing ``TourNumbering``.

    The shared auxiliary-graph construction under every entry point
    (DESIGN.md §10): the static wrappers below call it with a freshly
    computed numbering and no scope; ``repro.dynamic.bcc`` calls it with
    the maintained numbering, the pool's explicit tree classification,
    and the dirty-component scope.

    Traced through the caller's jit (optional arrays resolve to code
    paths at trace time, so this function is not jitted itself).

    Args:
      graph: Graph (paired half-edges; padding rows ``src == dst == n``;
        may be a multigraph iff ``tree_mask`` is explicit).
      parent: int32[n] rooted forest ``tn`` was built from (roots
        self-point; negative entries mark unspanned vertices).
      tn: ``euler.TourNumbering`` of ``parent`` — NOT recomputed here.
      tree_mask: optional bool[2M] — explicit per-half-edge tree
        classification (both halves of a tree edge True; at most one
        pool copy per vertex pair, the ``DynamicForest.tree_mask``
        invariant). ``None`` infers tree edges from ``parent`` endpoint
        adjacency, which is only sound on simple graphs.
      scope: optional bool[n] component-closed activity mask. When
        given, edges and vertices outside ``scope`` are treated as
        padding everywhere: their low/high/labels/articulation outputs
        are *garbage to be merged from a cache by the caller*, the
        low/high tables build only to the longest scoped component
        (``segment_reduce_scoped``), and the aux GConn pass hooks
        nothing outside the scope — clean components cost zero doubling
        work. ``n_bcc`` is only meaningful for ``scope=None``.
      use_kernel: route engine phases through their Pallas kernels
        (the scoped low/high build is XLA-only, see
        ``segment_reduce_scoped``).

    Returns:
      dict with keys articulation, bridge, edge_bcc, rep (int32[n]
      aux-component label per vertex — the label of the tree edge above
      v), n_bcc, low, high, aux_rounds, seg_syncs.
    """
    n = graph.n_nodes
    verts = jnp.arange(n, dtype=jnp.int32)
    pre, size, par = tn.pre, tn.size, tn.parent
    nonroot = par != verts
    spanned = parent >= 0

    src, dst = graph.src, graph.dst
    pad = (src >= n) | (dst >= n) | (src < 0) | (dst < 0)
    sc = jnp.clip(src, 0, n - 1)
    dc = jnp.clip(dst, 0, n - 1)
    # Edges touching unspanned vertices sit outside the decomposed
    # subgraph — treat them exactly like padding.
    pad = pad | ~spanned[sc] | ~spanned[dc]
    if scope is None:
        in_scope = jnp.ones((n,), jnp.bool_)
    else:
        # Component-closed: ``scope[sc] == scope[dc]`` on real edges.
        in_scope = scope
        pad = pad | ~in_scope[sc] | ~in_scope[dc]
    if tree_mask is None:
        is_tree = ~pad & ((par[dc] == sc) | (par[sc] == dc))
    else:
        is_tree = ~pad & tree_mask
    nontree = ~pad & ~is_tree

    # loc extremes: own preorder plus preorder over one non-tree edge.
    tgt = jnp.where(nontree, sc, n)
    loc_low = pre.at[tgt].min(jnp.where(nontree, pre[dc], INF32),
                              mode="drop")
    loc_high = pre.at[tgt].max(jnp.where(nontree, pre[dc], -1), mode="drop")

    # Subtree reduction = contiguous-interval reduction in preorder layout
    # (engine payload-reduce doubling table, DESIGN.md §4). Scoped
    # components occupy contiguous preorder blocks, so the scoped build
    # covers every active query with ⌈log2(max scoped comp size)⌉ levels.
    a_low = jnp.zeros((n,), jnp.int32).at[pre].set(loc_low)
    a_high = jnp.zeros((n,), jnp.int32).at[pre].set(loc_high)
    if scope is None:
        low = segment_reduce(a_low, pre, tn.last, "min",
                             use_kernel=use_kernel)
        high = segment_reduce(a_high, pre, tn.last, "max",
                              use_kernel=use_kernel)
        seg_syncs = jnp.int32(2 * max(1, (n - 1).bit_length()))
    else:
        low, s_lo = segment_reduce_scoped(a_low, pre, tn.last, in_scope,
                                          "min", return_syncs=True)
        high, s_hi = segment_reduce_scoped(a_high, pre, tn.last, in_scope,
                                           "max", return_syncs=True)
        seg_syncs = s_lo + s_hi

    # Aux edges. R1: unrelated non-tree edges (order by preorder so each
    # undirected edge contributes once; the reverse half-edge is inert).
    src_anc = (pre[sc] <= pre[dc]) & (pre[dc] < pre[sc] + size[sc])
    r1 = nontree & (pre[sc] < pre[dc]) & ~src_anc
    # R2/R3: tree edge (w = parent(v), v) joins its grandparent edge when
    # subtree(v) escapes below (low) or beyond (high) w's interval.
    w = par
    w_nonroot = par[w] != w
    r2 = nonroot & in_scope & w_nonroot & (low < pre[w])
    r3 = nonroot & in_scope & w_nonroot & (high >= pre[w] + size[w])

    aux_src = jnp.concatenate([jnp.where(r1, sc, n),
                               jnp.where(r2, verts, n),
                               jnp.where(r3, verts, n)])
    aux_dst = jnp.concatenate([jnp.where(r1, dc, n),
                               jnp.where(r2, w, n),
                               jnp.where(r3, w, n)])
    aux = Graph(n_nodes=n, src=aux_src, dst=aux_dst)
    rep, _forest, aux_rounds = connected_components(aux,
                                                    use_kernel=use_kernel)

    # Per-half-edge labels: every edge belongs to the block of the tree
    # edge above its deeper (larger-preorder) endpoint.
    deeper = jnp.where(pre[dc] > pre[sc], dc, sc)
    edge_bcc = jnp.where(pad, -1, rep[deeper])

    # Bridges: no non-tree edge escapes subtree(v) in either direction.
    bridge_v = nonroot & (low >= pre) & (high < pre + size)
    bridge = is_tree & bridge_v[deeper]

    # Articulation: ≥ 2 distinct block labels incident. Non-tree edges
    # never contribute a label their endpoint's tree edges don't already
    # carry, so it suffices to compare each vertex's own tree-edge label
    # with its children's. (Children share their parent's component, so
    # a scoped vertex only ever aggregates scoped children.)
    ptgt = jnp.where(nonroot, par, n)
    child_lab = jnp.where(nonroot, rep, INF32)
    mn = jnp.full((n,), INF32, jnp.int32).at[ptgt].min(child_lab,
                                                       mode="drop")
    mx = jnp.full((n,), -1, jnp.int32).at[ptgt].max(
        jnp.where(nonroot, rep, -1), mode="drop")
    has_child = mn != INF32
    articulation = jnp.where(nonroot,
                             has_child & ((mn != rep) | (mx != rep)),
                             has_child & (mn != mx))

    # One BCC per aux component that contains a tree edge; every block's
    # representative is one of its (non-root) members. (Pure-min hooking
    # makes labels content-determined — the minimum member id — which is
    # what lets the incremental path reuse cached clean-component labels
    # bit-identically, DESIGN.md §10.)
    n_bcc = jnp.sum((nonroot & (rep == verts)).astype(jnp.int32))

    return dict(articulation=articulation, bridge=bridge,
                edge_bcc=edge_bcc, rep=rep, n_bcc=n_bcc,
                low=low, high=high, aux_rounds=aux_rounds,
                seg_syncs=seg_syncs)


@partial(jax.jit, static_argnames=("use_kernel",))
def bcc_from_parent(graph: Graph, parent: jnp.ndarray, *,
                    use_kernel: bool = False):
    """Tarjan–Vishkin biconnectivity from an already-built parent array.

    Computes the tour numbering, then delegates to the shared
    ``bcc_from_tour`` core. The decomposition covers exactly the
    subgraph the forest spans: vertices the parent array leaves
    unspanned (BFS's unreachable −1) contribute no aux vertices, their
    incident edges carry label −1 and are never bridges, and they are
    never articulation points. Forest flavors (gconn_euler, pr_rst)
    span every component, so they decompose the whole graph; BFS
    decomposes the root's component only.

    Args:
      graph: Graph (paired half-edges; padding rows ``src == dst == n``).
      parent: int32[n] rooted spanning forest of ``graph`` (roots
        self-point; negative entries mark unspanned vertices).
      use_kernel: route engine phases through their Pallas kernels.

    Returns:
      dict with the BCCResult fields except ``rst_steps``/``method``.
    """
    tn = tour_numbering(parent, use_kernel=use_kernel)
    out = bcc_from_tour(graph, parent, tn, use_kernel=use_kernel)
    out.pop("rep")
    return dict(pre=tn.pre, size=tn.size, **out)


def biconnectivity(graph: Graph, root=0, *, rst_flavor: str = "gconn_euler",
                   use_kernel: bool = False, **rst_kwargs) -> BCCResult:
    """Biconnected components / bridges / articulation points of ``graph``.

    The ``rst_flavor`` knob selects which of the paper's three RST
    pipelines builds the spanning tree the Tarjan–Vishkin layer consumes
    (``"bfs"`` | ``"gconn_euler"`` | ``"pr_rst"``) — the decomposition is
    flavor-invariant, but the cost profile is not, which is what
    ``benchmarks/table3_bcc.py`` measures. Caveat on disconnected
    graphs: ``bfs`` spans (hence decomposes) only the root's component —
    edges elsewhere carry label −1; the forest flavors decompose every
    component, so flavor-invariance holds graph-wide only for connected
    inputs (see ``bcc_from_parent``).

    Args:
      graph: Graph (simple; paired half-edges).
      root: scalar int root vertex for the spanning tree.
      rst_flavor: RST pipeline name (see ``core.rst.METHODS``).
      use_kernel: route jump/relax/rank phases through Pallas kernels.
      **rst_kwargs: forwarded to the flavor (e.g. ``max_rounds``).

    Returns:
      BCCResult.
    """
    if rst_flavor not in METHODS:
        raise ValueError(
            f"unknown rst_flavor {rst_flavor!r}; choose from {METHODS}")
    res = rooted_spanning_tree(graph, root, method=rst_flavor,
                               use_kernel=use_kernel, **rst_kwargs)
    out = bcc_from_parent(graph, res.parent, use_kernel=use_kernel)
    return BCCResult(rst_steps=res.steps, method=rst_flavor, **out)


@partial(jax.jit, static_argnames=("n_nodes", "rst_flavor", "use_kernel"))
def bcc_batch(src: jnp.ndarray, dst: jnp.ndarray, roots: jnp.ndarray,
              *, n_nodes: int, rst_flavor: str = "gconn_euler",
              use_kernel: bool = False) -> BCCResult:
    """vmap-batched biconnectivity for many small same-shape graphs.

    The serving-scenario entry point: one compiled program decomposes a
    whole batch (recsys session graphs, molecule batches, ...) without
    host round-trips between graphs.

    Args:
      src, dst: int32[B, 2M] stacked half-edge lists sharing one padded
        shape (padding rows ``src == dst == n_nodes``).
      roots: int32[B] root vertex per graph.
      n_nodes: static vertex count shared by the batch.
      rst_flavor: RST pipeline name (see ``core.rst.METHODS``).

    Returns:
      BCCResult with every array field carrying a leading batch axis.
    """

    def one(s, d, r):
        return biconnectivity(Graph(n_nodes=n_nodes, src=s, dst=d), r,
                              rst_flavor=rst_flavor, use_kernel=use_kernel)

    return jax.vmap(one)(src, dst, roots)
