"""Shared path-reversal re-rooting machinery (the PR-RST primitive).

PR-RST's insight (paper §III-C) is that *re-rooting a tree at vertex u*
is one O(log n)-depth data-parallel operation: mark every vertex on the
u → root parent path with doubling tables, then flip the marked parent
pointers in one masked scatter. The seed kept that machinery private to
``core.pr_rst``; the batch-dynamic layer (``repro.dynamic``) needs the
identical primitive for incremental edge insertion — an insertion that
merges two components re-roots one tree at its endpoint and grafts it
onto the other (DESIGN.md §9) — so it lives here, importable, instead of
being copied.

Three layers:

* ``ancestor_tables`` / ``mark_paths`` / ``reverse_and_graft`` — the
  doubling-table path marking and masked-scatter reversal, verbatim from
  the original PR-RST implementation (adaptive level count included,
  DESIGN.md §3).
* ``link_components`` — one batched *link round*: every moving component
  picks one winning candidate edge (deterministic scatter-min), re-roots
  itself at that edge's ``start`` vertex and grafts onto ``target``,
  with the representative array maintained incrementally via one
  component-overlay compression. ``pr_rst`` rounds and the dynamic
  forest's insertion/replacement loop are both thin wrappers over it —
  they differ only in how the per-edge mover side is chosen (root-id
  order vs smaller-component order).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.compress import DEFAULT_JUMPS, compress_full

INF32 = jnp.iinfo(jnp.int32).max


def ancestor_tables(p: jnp.ndarray, levels: int):
    """Doubling tables (anc, pred, valid), each [levels, n], plus ``used``.

    anc[k][v]  = ancestor of v at distance exactly 2^k (if valid[k][v]).
    pred[k][v] = the path vertex immediately below anc[k][v] on v's root path.
    valid[k][v] = depth(v) >= 2^k.

    Only the first ``used`` levels are populated: the build loop exits as
    soon as ``valid`` saturates all-false (no vertex is that deep), so a
    forest of maximum depth D costs ⌈log2(D)⌉ + 1 levels of 3 gathers each
    rather than the static ⌈log n⌉. Levels ≥ ``used`` are all-invalid and
    must not be consulted (``mark_paths`` bounds its loop by ``used``).
    """
    n = p.shape[0]
    v0 = jnp.arange(n, dtype=jnp.int32)
    anc0 = p
    pred0 = v0
    valid0 = p != v0

    bufs0 = (jnp.zeros((levels, n), jnp.int32),
             jnp.zeros((levels, n), jnp.int32),
             jnp.zeros((levels, n), jnp.bool_))

    def cond(state):
        k, _anc, _pred, valid, _bufs = state
        return (k < levels) & jnp.any(valid)

    def body(state):
        k, anc, pred, valid, (ab, pb, vb) = state
        ab = ab.at[k].set(anc)
        pb = pb.at[k].set(pred)
        vb = vb.at[k].set(valid)
        anc2 = anc[anc]
        pred2 = pred[anc]
        valid2 = valid & valid[anc]
        return k + 1, anc2, pred2, valid2, (ab, pb, vb)

    used, _, _, _, (ancs, preds, valids) = jax.lax.while_loop(
        cond, body, (jnp.int32(0), anc0, pred0, valid0, bufs0))
    return ancs, preds, valids, used


def mark_paths(p: jnp.ndarray, starts: jnp.ndarray, active: jnp.ndarray,
               levels: int):
    """Mark every vertex on the P-root-path of each active start vertex.

    Returns (mark: bool[n], prednode: int32[n]) — prednode[w] is the path
    vertex immediately below w (valid where mark & w is not a start).
    """
    n = p.shape[0]
    ancs, preds, valids, used = ancestor_tables(p, levels)

    mark = jnp.zeros((n,), jnp.bool_)
    start_idx = jnp.where(active, starts, n)
    mark = mark.at[start_idx].set(True, mode="drop")
    prednode = jnp.full((n,), -1, jnp.int32)

    def body(k, state):
        mark, prednode = state
        anc_k = ancs[k]
        pred_k = preds[k]
        ok = mark & valids[k]
        tgt = jnp.where(ok, anc_k, n)
        mark = mark.at[tgt].set(True, mode="drop")
        prednode = prednode.at[tgt].set(pred_k, mode="drop")
        return mark, prednode

    mark, prednode = jax.lax.fori_loop(0, used, body, (mark, prednode))
    return mark, prednode


def reverse_and_graft(p, mark, prednode, starts, grafts, active):
    """Flip parent pointers along marked paths; set P[start] = graft."""
    n = p.shape[0]
    is_start = jnp.zeros((n,), jnp.bool_).at[
        jnp.where(active, starts, n)].set(True, mode="drop")
    flip = mark & ~is_start & (prednode >= 0)
    p = jnp.where(flip, prednode, p)
    p = p.at[jnp.where(active, starts, n)].set(
        jnp.where(active, grafts, 0), mode="drop")
    return p


def link_components(p: jnp.ndarray, rt: jnp.ndarray, start: jnp.ndarray,
                    target: jnp.ndarray, cand: jnp.ndarray, *, levels: int,
                    n_jumps: int = DEFAULT_JUMPS, use_kernel: bool = False,
                    return_syncs: bool = False):
    """One batched link round: re-root + graft one winning edge per mover.

    For every candidate edge e, the component of ``start[e]`` is the
    *mover*: it wants to re-root itself at ``start[e]`` and graft onto
    ``target[e]``. Each moving component gets exactly one winner
    (deterministic scatter-min on edge slot id), its start→root path is
    reversed, and ``P[start] = target`` grafts it.

    Preconditions (caller's contract):
      * ``rt == roots_of(p)`` — the incremental-representative invariant;
      * ``rt[start[e]] != rt[target[e]]`` for every candidate e;
      * the per-round move relation (mover component → target component)
        follows a strict total order on components, fixed for the round —
        root id in PR-RST's hooking, (size, root id) in the dynamic
        forest — so the component-level graft overlay is acyclic.

    Returns ``(p', rt', is_winner)`` with ``rt' == roots_of(p')``
    re-established incrementally: one engine compression of the
    component-level overlay plus one gather (DESIGN.md §3), never a
    from-scratch ``roots_of`` over the tree. With ``return_syncs`` the
    overlay compression's convergence-check count is appended — the
    device-independent per-round cost the recovery benchmarks track
    (DESIGN.md §11).
    """
    n = p.shape[0]
    m = start.shape[0]
    eid = jnp.arange(m, dtype=jnp.int32)
    verts = jnp.arange(n, dtype=jnp.int32)

    mover = rt[jnp.clip(start, 0, n - 1)]

    # One winning edge per moving component (deterministic scatter-min).
    key = jnp.where(cand, eid, INF32)
    win = jnp.full((n,), INF32, jnp.int32).at[
        jnp.where(cand, mover, n)].min(key, mode="drop")
    is_winner = cand & (win[mover] == eid)

    # Per-component (indexed by moving root): start + graft vertices.
    comp_start = jnp.full((n,), -1, jnp.int32).at[
        jnp.where(is_winner, mover, n)].set(start, mode="drop")
    comp_graft = jnp.full((n,), -1, jnp.int32).at[
        jnp.where(is_winner, mover, n)].set(target, mode="drop")
    comp_active = comp_start >= 0

    # Mark each moving component's start→root path, reverse, graft.
    mark, prednode = mark_paths(p, comp_start, comp_active, levels)
    p_next = reverse_and_graft(p, mark, prednode, comp_start, comp_graft,
                               comp_active)

    # Incremental representative update: moving root m joins the component
    # of rt[t]; the move order is strict within a round, so the overlay is
    # an acyclic forest over the (much shallower) component graph.
    graft_root = rt[jnp.clip(comp_graft, 0, n - 1)]
    overlay = jnp.where(comp_active, graft_root, verts)
    comp_rt, syncs = compress_full(overlay, n_jumps=n_jumps,
                                   use_kernel=use_kernel, return_syncs=True)
    rt_next = comp_rt[rt]
    if return_syncs:
        return p_next, rt_next, is_winner, syncs
    return p_next, rt_next, is_winner
