"""Edge-centric BFS rooted spanning tree (the paper's baseline, §III-A).

TPU adaptation of Merrill et al.'s edge-centric BFS: instead of warp-level
frontier queues we relax *all* half-edges each level with dense vector ops —
gather both endpoint distances, propose ``parent[dst] = src`` for edges whose
src is on the current frontier and whose dst is undiscovered, and resolve
write conflicts with a deterministic scatter-min. A ``lax.while_loop`` runs
one iteration per BFS level, reproducing the Θ(diam(G)) step complexity the
paper measures.

Returns (parent, dist, levels): ``parent[root] == root``; unreachable
vertices keep ``parent == -1`` and ``dist == INF32``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.graph import Graph

INF32 = jnp.iinfo(jnp.int32).max


@partial(jax.jit, static_argnames=("max_levels", "use_kernel"))
def bfs_rst(graph: Graph, root, *, max_levels: int | None = None,
            use_kernel: bool = False):
    """Level-synchronous edge-centric BFS spanning tree.

    Args:
      graph: Graph (paired half-edges).
      root: scalar int vertex id.
      max_levels: optional static bound on levels (defaults to n_nodes).
      use_kernel: route the per-level edge relaxation through the Pallas
        ``frontier_relax`` kernel (interpret mode on CPU).

    Returns:
      parent: int32[n] parent array (-1 = unreachable, parent[root] = root).
      dist:   int32[n] hop distance (INF32 = unreachable).
      levels: int32 scalar, number of BFS levels executed (= tree depth).
    """
    n = graph.n_nodes
    src, dst = graph.src, graph.dst
    root = jnp.asarray(root, jnp.int32)

    dist0 = jnp.full((n,), INF32, jnp.int32).at[root].set(0)
    parent0 = jnp.full((n,), -1, jnp.int32).at[root].set(root)

    if use_kernel:
        from repro.kernels.frontier_relax.ops import frontier_relax
    else:
        frontier_relax = None

    def relax(dist, level):
        """One edge-centric relaxation: returns per-edge (proposes, src)."""
        if frontier_relax is not None:
            return frontier_relax(dist, src, dst, level)
        d_src = dist[src]
        d_dst = dist[dst]
        active = (d_src == level) & (d_dst == INF32)
        return active

    def body(state):
        dist, parent, level, _changed = state
        active = relax(dist, level)
        # Deterministic conflict resolution: the minimum src id wins each dst.
        prop_parent = jnp.where(active, src, INF32)
        winner = jnp.full((n,), INF32, jnp.int32).at[dst].min(prop_parent)
        discovered = winner != INF32
        parent = jnp.where(discovered, winner, parent)
        dist = jnp.where(discovered, level + 1, dist)
        return dist, parent, level + 1, jnp.any(discovered)

    def cond(state):
        _dist, _parent, level, changed = state
        bound = n if max_levels is None else max_levels
        return changed & (level < bound)

    dist, parent, levels, _ = jax.lax.while_loop(
        cond, body, (dist0, parent0, jnp.int32(0), jnp.bool_(True)))
    return parent, dist, levels - 1
