"""Downstream tree analytics on rooted spanning trees.

The paper motivates RSTs as the substrate for biconnectivity, ear
decomposition, etc. This module provides the two classic Euler-tour /
pointer-doubling consumers, built on the engine primitives
(DESIGN.md §3); the full biconnectivity consumer they anticipate lives in
``core/bcc.py`` (DESIGN.md §4):

  * ``subtree_sizes(parent)`` — |subtree(v)| for every v (the
    Tarjan–Vishkin low/high building block; ``bcc.py`` obtains the same
    quantity in O(log n) depth from ``euler.tour_numbering``, this
    level-synchronous variant exists for the depth-cost comparison);
  * ``depths(parent)`` — exact depth of every vertex (not just the max).

Both are jit-compatible and fixed-shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.compress import rank_to_root


def depths(parent: jnp.ndarray) -> jnp.ndarray:
    """Depth of each vertex from its root.

    Engine pointer doubling (``compress.rank_to_root``, DESIGN.md §3):
    O(log depth) parallel steps with amortized convergence syncs.

    Args:
      parent: int32[n] self-rooted acyclic parent table.

    Returns:
      int32[n] depths; roots (and isolated vertices) carry 0.
    """
    d, _root = rank_to_root(parent)
    return d


def subtree_sizes(parent: jnp.ndarray) -> jnp.ndarray:
    """Number of vertices in v's subtree (including v itself).

    Level-synchronous bottom-up aggregation driven by depths: vertices are
    processed from the deepest level upward; each level is one masked
    scatter-add into the parents. O(depth) steps like BFS — the
    depth-performance trade-off the paper measures (Fig. 2) applies to
    downstream consumers too, which is why we report tree depth per
    method in fig2_depth. The biconnectivity layer needs the same
    quantity in O(log n) depth regardless of tree shape and gets it from
    the Euler tour instead (``euler.tour_numbering``, DESIGN.md §4).

    Args:
      parent: int32[n] self-rooted acyclic parent table.

    Returns:
      int32[n] subtree sizes; leaves carry 1, a root its component size.
    """
    n = parent.shape[0]
    dep = depths(parent)
    max_d = jnp.max(dep)
    sizes = jnp.ones((n,), jnp.int32)
    verts = jnp.arange(n, dtype=parent.dtype)
    is_root = parent == verts

    def body(state):
        level, sizes = state
        at = (dep == level) & ~is_root
        tgt = jnp.where(at, parent, n)
        sizes = sizes.at[tgt].add(jnp.where(at, sizes, 0), mode="drop")
        return level - 1, sizes

    _, sizes = jax.lax.while_loop(lambda s: s[0] > 0, body, (max_d, sizes))
    return sizes
