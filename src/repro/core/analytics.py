"""Downstream tree analytics on rooted spanning trees.

The paper motivates RSTs as the substrate for biconnectivity, ear
decomposition, etc. This module provides the two classic Euler-tour /
pointer-doubling consumers, built on the same primitives:

  * ``subtree_sizes(parent)`` — |subtree(v)| for every v, via pointer
    doubling with additive payload (the Tarjan–Vishkin building block for
    low/high computation in biconnectivity);
  * ``depths(parent)`` — exact depth of every vertex (not just the max).

Both are O(log n) parallel depth, jit-compatible, fixed-shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.compress import rank_to_root


def depths(parent: jnp.ndarray) -> jnp.ndarray:
    """int32[n] depth of each vertex (roots = 0). Engine pointer doubling."""
    d, _root = rank_to_root(parent)
    return d


def subtree_sizes(parent: jnp.ndarray) -> jnp.ndarray:
    """int32[n]: number of vertices in v's subtree (incl. v).

    Level-synchronous bottom-up aggregation driven by depths: vertices are
    processed from the deepest level upward; each level is one masked
    scatter-add into the parents. O(depth) steps like BFS — the
    depth-performance trade-off the paper measures (Fig. 2) applies to
    downstream consumers too, which is why we report tree depth per
    method in fig2_depth.
    """
    n = parent.shape[0]
    dep = depths(parent)
    max_d = jnp.max(dep)
    sizes = jnp.ones((n,), jnp.int32)
    verts = jnp.arange(n, dtype=parent.dtype)
    is_root = parent == verts

    def body(state):
        level, sizes = state
        at = (dep == level) & ~is_root
        tgt = jnp.where(at, parent, n)
        sizes = sizes.at[tgt].add(jnp.where(at, sizes, 0), mode="drop")
        return level - 1, sizes

    _, sizes = jax.lax.while_loop(lambda s: s[0] > 0, body, (max_d, sizes))
    return sizes
