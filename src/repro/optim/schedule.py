"""LR schedules: WSD (minicpm's Warmup-Stable-Decay) and cosine."""
from __future__ import annotations

import jax.numpy as jnp


def wsd_schedule(step, *, peak_lr: float, warmup: int, stable: int,
                 decay: int, final_frac: float = 0.1):
    """Warmup-Stable-Decay  [arXiv:2404.06395 §4].

    Linear warmup → constant plateau → exponential-ish (linear here) decay
    to final_frac · peak over the decay window.
    """
    s = step.astype(jnp.float32)
    warm = peak_lr * s / max(warmup, 1)
    stab = jnp.asarray(peak_lr, jnp.float32)
    t = jnp.clip((s - warmup - stable) / max(decay, 1), 0.0, 1.0)
    dec = peak_lr * (1.0 - (1.0 - final_frac) * t)
    lr = jnp.where(s < warmup, warm, jnp.where(s < warmup + stable, stab, dec))
    return lr


def cosine_schedule(step, *, peak_lr: float, warmup: int, total: int,
                    final_frac: float = 0.1):
    s = step.astype(jnp.float32)
    warm = peak_lr * s / max(warmup, 1)
    t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(s < warmup, warm, peak_lr * cos)
