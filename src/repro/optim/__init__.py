"""Optimizers, schedules, gradient transformations."""
from repro.optim.adamw import adamw_init, adamw_update, clip_by_global_norm
from repro.optim.schedule import wsd_schedule, cosine_schedule
from repro.optim.compression import compress_int8, decompress_int8
