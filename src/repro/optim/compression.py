"""int8 gradient compression with error feedback (cross-pod wire format).

The cross-pod gradient all-reduce is the slowest collective on the 2-pod
mesh (DCN-class links). Compressing the pod-axis reduction payload 4×
(fp32→int8 per-block scaling) with an error-feedback residual keeps
convergence intact (1-bit Adam lineage). Used by ``train.step`` when
``grad_compression='int8'``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def compress_int8(x: jnp.ndarray):
    """x: float array → (int8 payload, per-block fp32 scales, pad)."""
    flat = x.reshape(-1).astype(jnp.float32)
    pad = -flat.shape[0] % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)), -127, 127)
    return q.astype(jnp.int8), scale, pad


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray, pad: int, shape,
                    dtype=jnp.float32):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape).astype(dtype)


def compress_with_error_feedback(grad: jnp.ndarray, residual: jnp.ndarray):
    """Returns (quantized-roundtrip grad, new residual)."""
    g = grad.astype(jnp.float32) + residual
    q, s, pad = compress_int8(g)
    deq = decompress_int8(q, s, pad, g.shape)
    return deq.astype(grad.dtype), g - deq
