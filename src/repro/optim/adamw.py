"""AdamW with bf16 compute params / fp32 master + moments.

State layout mirrors the param pytree so every leaf inherits the param's
sharding (FSDP states shard identically to their weights).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, zeros),
            "master": master, "step": jnp.zeros((), jnp.int32)}


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


def adamw_update(grads, opt_state, lr, *, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1, max_norm: float | None = 1.0,
                 compute_dtype=jnp.bfloat16):
    """Returns (new_params_compute, new_opt_state, grad_norm)."""
    if max_norm is not None:
        grads, gn = clip_by_global_norm(grads, max_norm)
    else:
        gn = jnp.zeros(())
    step = opt_state["step"] + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, master):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / c1
        vhat = v / c2
        new_master = master - lr * (mhat / (jnp.sqrt(vhat) + eps)
                                    + weight_decay * master)
        return m, v, new_master

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    flat_ma = jax.tree.leaves(opt_state["master"])
    out = [upd(g, m, v, ma) for g, m, v, ma in
           zip(flat_g, flat_m, flat_v, flat_ma)]
    new_m = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_master = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_params = jax.tree.map(lambda p: p.astype(compute_dtype), new_master)
    return new_params, {"m": new_m, "v": new_v, "master": new_master,
                        "step": step}, gn
