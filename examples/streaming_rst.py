"""Streaming RST: maintain a spanning forest under edge updates.

    PYTHONPATH=src python examples/streaming_rst.py

The batch-dynamic counterpart of quickstart.py: instead of rebuilding a
tree per graph, a ``DynamicForest`` absorbs insert/delete batches — an
insertion that merges two components re-roots the smaller tree with
PR-RST's path-reversal primitive, a deleted tree edge triggers a
replacement search over the surviving pool (one scoped GConn round) —
and the Euler-tour numbering refreshes incrementally, only for
components a batch actually touched (DESIGN.md §9). On top of the tour,
the biconnectivity decomposition is *maintained* the same way: bridges
and articulation points update per batch under dirty-component scoping
instead of being recomputed (DESIGN.md §10). A ``QuerySession`` then
*serves* the maintained forest — batched LCA / connectivity / aggregate
reads from one cached index, version-guarded against silent staleness
(DESIGN.md §12). The final act breaks the forest on purpose and lets
the self-healing ladder repair it (DESIGN.md §11).
"""
import time

import jax
import numpy as np

from repro.core.compress import roots_of
from repro.core.euler import tour_numbering
from repro.core.validate import validate_rst
from repro.data.graphs import grid2d
from repro.data.streams import churn, sliding_window
from repro.dynamic import (init_state, live_graph, refresh_bcc,
                           refresh_tour, replay_batch)


def run_stream(name, stream, tour_every=4):
    print(f"\n=== {name}: {len(stream.batches)} batches "
          f"of {stream.batches[0].ins_u.shape[0]} ===")
    state = init_state(stream)
    tn = None
    for step, b in enumerate(stream.batches):
        t0 = time.perf_counter()
        state, stats = replay_batch(state, b)
        jax.block_until_ready(state.parent)
        dt = (time.perf_counter() - t0) * 1e3
        if (step + 1) % tour_every == 0:
            tn, state = refresh_tour(state, tn)
        if step % max(1, len(stream.batches) // 4) == 0:
            print(f"  batch {step:3d}: {dt:6.1f} ms  "
                  f"cuts={int(stats['cuts']):3d} "
                  f"links={int(stats['links']):3d} "
                  f"rounds={int(stats['rounds'])}  "
                  f"live={int(state.n_live_edges)} "
                  f"components={int(state.n_components)}")
    return state, tn


def main() -> None:
    g = grid2d(48)  # road-like; deletions force real replacement searches

    state, tn = run_stream(
        "sliding_window over grid 48x48",
        sliding_window(g, batch=64, window=8, seed=0))
    state2, tn2 = run_stream(
        "churn over grid 48x48",
        churn(g, batch=64, n_batches=16, seed=1))

    # The maintained forest is indistinguishable from a rebuilt one.
    lg = live_graph(state2)
    root = int(np.asarray(state2.rep)[0])
    checks = validate_rst(lg, np.asarray(state2.parent), root,
                          connected=False)
    print(f"\nfinal churn forest valid: {checks}")
    assert bool(np.all(np.asarray(roots_of(state2.parent))
                       == np.asarray(state2.rep)))

    # ... and the incrementally refreshed tour numbering is bit-identical
    # to a full recompute.
    tn2, state2 = refresh_tour(state2, tn2)
    full = tour_numbering(state2.parent)
    same = all(bool(np.array_equal(np.asarray(getattr(tn2, f)),
                                   np.asarray(getattr(full, f))))
               for f in ("pre", "size", "last", "comp"))
    print(f"incremental tour == full recompute: {same}")

    track_biconnectivity()
    serve_queries()
    survive_faults()
    observe_everything()


def track_biconnectivity():
    """Bridge / articulation tracking: maintain BCC labels under churn.

    Every deleted edge can promote survivors to bridges (its cycle
    broke) and mint new cut vertices; every insertion can fuse blocks.
    ``refresh_bcc`` keeps the decomposition current by recomputing only
    the components a batch touched — clean components keep their cached
    labels bit-for-bit (DESIGN.md §10).
    """
    g = grid2d(24)
    stream = churn(g, batch=48, n_batches=12, seed=2)
    print("\n=== bridge/articulation tracking: churn over grid 24x24 ===")
    state = init_state(stream)
    tn, state = refresh_tour(state, None)
    bcc = refresh_bcc(state, None, tour=tn)
    print(f"  start: n_bcc={int(bcc.n_bcc)} "
          f"bridges={int(bcc.n_bridges)} "
          f"articulation={int(bcc.n_articulation)}")
    for step, b in enumerate(stream.batches):
        state, _ = replay_batch(state, b)
        tn, state = refresh_tour(state, tn)
        t0 = time.perf_counter()
        bcc = refresh_bcc(state, bcc, tour=tn)
        jax.block_until_ready(bcc.edge_bcc)
        dt = (time.perf_counter() - t0) * 1e3
        if step % 3 == 0 or step == len(stream.batches) - 1:
            print(f"  batch {step:3d}: {dt:6.1f} ms  "
                  f"dirty={int(bcc.dirty_count):4d}/{state.n_nodes}  "
                  f"n_bcc={int(bcc.n_bcc):4d} "
                  f"bridges={int(bcc.n_bridges):4d} "
                  f"articulation={int(bcc.n_articulation):4d}")

    # The maintained decomposition is indistinguishable from scratch.
    full = refresh_bcc(state, None, tour=tn, incremental=False)
    same = all(bool(np.array_equal(np.asarray(getattr(bcc, f)),
                                   np.asarray(getattr(full, f))))
               for f in ("rep", "low", "high", "articulation",
                         "bridge", "edge_bcc", "n_bcc"))
    print(f"incremental bcc == full recompute: {same}")


def serve_queries():
    """Read path: batched tree queries over the maintained forest.

    One ``QueryTables`` index per refresh answers whole query batches —
    LCA, connectivity, subtree/path aggregates, bridge membership —
    with zero additional engine syncs (DESIGN.md §12). The session is
    version-stamped: mutate the forest without refreshing and a strict
    session refuses, while ``policy="refresh"`` recomputes on demand.
    """
    import jax.numpy as jnp

    from repro.dynamic import QuerySession, StaleQueryError

    g = grid2d(24)
    stream = churn(g, batch=48, n_batches=8, seed=4)
    print("\n=== query serving: churn over grid 24x24 ===")
    state = init_state(stream)
    for b in stream.batches[:-1]:
        state, _ = replay_batch(state, b)
    tn, state = refresh_tour(state, None)
    bcc = refresh_bcc(state, None, tour=tn)
    sess = QuerySession.from_state(state, tn, bcc, policy="strict")

    rng = np.random.default_rng(0)
    u = jnp.asarray(rng.integers(0, g.n_nodes, 8), jnp.int32)
    v = jnp.asarray(rng.integers(0, g.n_nodes, 8), jnp.int32)
    payload = jnp.ones(g.n_nodes, jnp.int32)
    conn = sess.connected(state, u, v)
    lcas = sess.lca(state, u, v)
    hops = sess.path_agg(state, u, v, payload, "add") - 1  # nodes → edges
    print(f"  connected: {np.asarray(conn).tolist()}")
    print(f"  lca:       {np.asarray(lcas).tolist()}")
    print(f"  path hops: {np.asarray(hops).tolist()}  (-1 = disconnected)")
    print(f"  subtree sizes at lca: "
          f"{np.asarray(sess.subtree_agg(state, lcas, payload, 'add')).tolist()}")

    # Mutate without refreshing: the strict session refuses to serve a
    # view of a forest that has moved on...
    state, _ = replay_batch(state, stream.batches[-1])
    try:
        sess.connected(state, u, v)
        raise AssertionError("stale read served")
    except StaleQueryError as e:
        print(f"  strict session after un-refreshed batch: raised ({e})")
    # ...while a refresh-policy session recomputes the index on demand.
    sess.policy = "refresh"
    sess.connected(state, u, v)
    print(f"  refresh policy: {sess.sync_stats()}")


def survive_faults():
    """Self-healing: inject faults, audit in O(log n), repair in place.

    ``audit_forest`` checks every forest invariant on device with a
    bounded sync schedule; ``recover`` escalates refresh → scoped
    fragment-preserving repair → full rebuild, and certifies the result
    with a final audit (DESIGN.md §11). ``serve_stream --chaos`` runs
    this ladder continuously inside the serving loop.
    """
    from repro.dynamic import audit_forest, inject, recover

    g = grid2d(24)
    stream = churn(g, batch=48, n_batches=8, seed=3)
    print("\n=== self-healing: injected faults over grid 24x24 ===")
    state = init_state(stream)
    for b in stream.batches:
        state, _ = replay_batch(state, b)
    tn, state = refresh_tour(state, None)
    bcc = refresh_bcc(state, None, tour=tn)

    for fault in ("parent_bitflip", "rep_corrupt", "parent_cycle"):
        state, bcc, what = inject(fault, state, bcc, seed=11)
        state, tn, bcc, report, info = recover(state, tn, bcc)
        print(f"  {fault:15s} ({what}): audit -> {report.summary()}")
        print(f"  {'':15s}  healed via {info['mode']!r}, "
              f"final audit: {audit_forest(state, tn, bcc).summary()}")
        assert bool(audit_forest(state, tn, bcc).healthy)


def observe_everything():
    """Observability: the same stream, now with the §14 layer watching.

    A ``SyncLedger`` is ambient — install it, run unchanged library
    code, and every convergence loop's sync bill lands per phase.
    A ``Tracer`` adds wall-clock spans on top (and exports JSONL +
    Perfetto-loadable Chrome JSON via ``--trace-out`` in the serving
    loops). Instrumentation is free: the counters already ride the
    compiled loops' carries, so the forest is bit-identical with the
    tracer on or off (DESIGN.md §14).
    """
    from repro import obs

    g = grid2d(24)
    stream = churn(g, batch=48, n_batches=8, seed=4)
    print("\n=== observability: ledger + spans over grid 24x24 ===")

    tracer = obs.Tracer()
    with tracer:
        state = init_state(stream)
        tn = None
        for step, b in enumerate(stream.batches):
            with obs.span("tick", step=step):
                state, _ = replay_batch(state, b)
                if (step + 1) % 4 == 0:
                    tn, state = refresh_tour(state, tn)

    budget = tracer.summary()["sync_by_phase"]
    print(f"  sync budget per phase: {budget}")
    ticks = tracer.spans("tick")
    ms = sorted(t["dur"] / 1e3 for t in ticks)
    print(f"  {len(ticks)} ticks, p50 {ms[len(ms) // 2]:.1f} ms, "
          f"total syncs {tracer.ledger.total()}")
    assert budget["apply"] > 0 and budget["refresh_tour"] > 0


if __name__ == "__main__":
    main()
