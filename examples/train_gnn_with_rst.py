"""End-to-end driver: train a GNN whose data pipeline uses the paper's RST
library for locality-aware node reordering, with fault-tolerant training.

    PYTHONPATH=src python examples/train_gnn_with_rst.py --steps 200

Pipeline: synthetic power-law graph → connectivity check (RST library) →
RST-based node relabeling (gather locality) → GAT training with the
fault-tolerant loop (checkpoint/resume every 50 steps).
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Graph, connected_components
from repro.data.gnn_batch import reorder_by_rst
from repro.data.graphs import rmat
from repro.models.gnn import GATConfig, GraphBatch, gat_forward, gat_init
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule
from repro.train.fault import FaultTolerantLoop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--nodes", type=int, default=4096)
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt/gnn_rst_example")
    args = ap.parse_args()

    # --- data pipeline: graph → RST reorder -------------------------------
    g = rmat(int(np.log2(args.nodes)), edge_factor=8, seed=0)
    n = g.n_nodes
    rep, _, rounds = connected_components(g)
    n_comp = int(jnp.sum(rep == jnp.arange(n)))
    print(f"graph: V={n} E={g.n_edges}; components={n_comp} "
          f"(connectivity in {int(rounds)} rounds)")

    perm = reorder_by_rst(np.asarray(g.src), np.asarray(g.dst), n)
    src = jnp.asarray(perm[np.asarray(g.src)], jnp.int32)
    dst = jnp.asarray(perm[np.asarray(g.dst)], jnp.int32)

    rng = np.random.default_rng(0)
    d_feat, n_classes = 64, 7
    feats = jnp.asarray(rng.standard_normal((n, d_feat)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, n_classes, n), jnp.int32)
    gb = GraphBatch(n_nodes=n, node_feat=feats, src=src, dst=dst)

    # --- model + fault-tolerant training loop -----------------------------
    cfg = GATConfig(d_in=d_feat, n_classes=n_classes, d_hidden=16, n_heads=4)
    params = gat_init(cfg, jax.random.key(0))
    state = {"params": params, "opt": adamw_init(params)}

    @jax.jit
    def step(state, batch):
        def loss_fn(p):
            logits = gat_forward(cfg, p, gb).astype(jnp.float32)
            logz = jax.nn.logsumexp(logits, -1)
            gold = jnp.take_along_axis(logits, labels[:, None], -1)[:, 0]
            return jnp.mean(logz - gold)
        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        lr = cosine_schedule(state["opt"]["step"], peak_lr=3e-3, warmup=20,
                             total=args.steps)
        p, opt, gn = adamw_update(grads, state["opt"], lr,
                                  compute_dtype=jnp.float32)
        return {"params": p, "opt": opt}, {"loss": loss, "grad_norm": gn}

    def data():
        c = 0
        while True:
            yield c, {}
            c += 1

    loop = FaultTolerantLoop(step_fn=step, state=state, data_iter=data(),
                             ckpt_dir=args.ckpt_dir, ckpt_every=50)
    start = loop.resume()
    if start:
        print(f"resumed from checkpoint at step {start}")

    t0 = time.time()
    losses = []
    loop.run(args.steps, on_metrics=lambda s, m, dt: (
        losses.append(float(m["loss"])),
        print(f"step {s:4d}  loss {float(m['loss']):.4f}  {dt*1e3:.1f} ms")
        if s % 25 == 0 else None))
    print(f"\n{args.steps} steps in {time.time()-t0:.1f}s; "
          f"loss {losses[0]:.3f} → {losses[-1]:.3f}; "
          f"stragglers={len(loop.stragglers)}")
    assert losses[-1] < losses[0], "training should reduce loss"


if __name__ == "__main__":
    main()
