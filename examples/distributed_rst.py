"""Multi-device RST: the paper's algorithm sharded over a device mesh.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/distributed_rst.py

Edges are sharded across devices; hook proposals combine with one
all-reduce-min per round (the multi-chip analogue of the GPU atomicMin);
pointer jumping stays local. See core/distributed.py for the cost model.
"""
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    from repro.core import Graph
    from repro.core.distributed import distributed_cc_spanning_forest
    from repro.core.validate import components_reference
    from repro.data.graphs import grid2d

    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    run = distributed_cc_spanning_forest(mesh, "data")

    g = grid2d(48)
    m2 = g.n_half_edges
    pad = -m2 % n_dev
    src = jnp.concatenate([g.src, jnp.zeros(pad, jnp.int32)])
    dst = jnp.concatenate([g.dst, jnp.zeros(pad, jnp.int32)])

    rep, forest, rounds = run(src, dst, n_nodes=g.n_nodes)
    ref = components_reference(g)
    n_comp = len(set(ref.tolist()))
    n_forest = int(np.asarray(forest).sum())
    print(f"devices={n_dev}  V={g.n_nodes} E={g.n_edges}")
    print(f"rounds={int(rounds)} (O(log n)); forest edges={n_forest} "
          f"(expected {g.n_nodes - n_comp})")
    assert n_forest == g.n_nodes - n_comp
    print("distributed spanning forest OK")


if __name__ == "__main__":
    main()
