"""Biconnectivity walkthrough: RSTs as a substrate, not an endpoint.

    PYTHONPATH=src python examples/bcc_analysis.py

The paper motivates rooted spanning trees because they "underpin
algorithms such as biconnected components"; this example runs that
downstream consumer (``core/bcc.py``, DESIGN.md §4) three ways — one per
RST flavor — and shows (a) the decomposition is flavor-invariant, (b) the
cost is not, and (c) the vmap-batched ``bcc_batch`` path for the
many-small-graphs serving scenario.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Graph, bcc_batch, biconnectivity
from repro.core.rst import METHODS
from repro.data.graphs import grid2d, pref_attach


def summarize(res, g) -> str:
    n_art = int(np.asarray(res.articulation).sum())
    n_bridge = int(np.asarray(res.bridge).sum()) // 2
    return (f"blocks={int(res.n_bcc):4d} cuts={n_art:4d} "
            f"bridges={n_bridge:4d} rst_steps={int(res.rst_steps):4d} "
            f"aux_rounds={int(res.aux_rounds):2d}")


def main() -> None:
    # -- a graph with visible structure: two meshes joined by a bridge ----
    side = 6
    mesh = grid2d(side)
    n = 2 * mesh.n_nodes
    u = np.asarray(mesh.src[: mesh.n_half_edges // 2])
    v = np.asarray(mesh.dst[: mesh.n_half_edges // 2])
    edges = np.concatenate([
        np.stack([u, v], 1),
        np.stack([u + mesh.n_nodes, v + mesh.n_nodes], 1),
        np.asarray([[mesh.n_nodes - 1, mesh.n_nodes]]),   # the bridge
    ])
    g = Graph.from_numpy_undirected(n, edges)
    print(f"two {side}x{side} grids + 1 bridge: V={g.n_nodes} E={g.n_edges}")
    for flavor in METHODS:
        res = biconnectivity(g, 0, rst_flavor=flavor)
        print(f"  {flavor:12s} {summarize(res, g)}")
    res = biconnectivity(g, 0)
    cuts = np.flatnonzero(np.asarray(res.articulation))
    src_np, dst_np = np.asarray(g.src), np.asarray(g.dst)
    bridge_ends = sorted({int(x) for e in
                          np.flatnonzero(np.asarray(res.bridge))
                          for x in (src_np[e], dst_np[e])})
    print(f"  cut vertices {cuts.tolist()} = the bridge endpoints "
          f"{bridge_ends}")

    # -- flavor cost comparison on the paper's structural regimes --------
    print("\ndownstream cost by rst_flavor (compiled, best of 3):")
    for gname, gg in [("grid 48x48 (high diameter)", grid2d(48)),
                      ("pref-attach 4k (web-like)", pref_attach(4096, 4))]:
        print(f"  {gname}: V={gg.n_nodes} E={gg.n_edges}")
        for flavor in METHODS:
            fn = jax.jit(lambda x, f=flavor: biconnectivity(
                x, 0, rst_flavor=f).n_bcc)
            jax.block_until_ready(fn(gg))            # compile
            dt = min(_timed(fn, gg) for _ in range(3))
            print(f"    {flavor:12s} {dt * 1e3:8.1f} ms")

    # -- batched serving path --------------------------------------------
    b, nn = 8, 24
    base = [(i, i + 1) for i in range(nn - 1)]
    graphs = [Graph.from_numpy_undirected(nn, np.asarray(base + [(0, j)]))
              for j in range(2, 2 + b)]
    src = jnp.stack([x.src for x in graphs])
    dst = jnp.stack([x.dst for x in graphs])
    out = bcc_batch(src, dst, jnp.zeros((b,), jnp.int32), n_nodes=nn)
    print(f"\nbcc_batch over {b} session graphs (one compiled program):")
    print(f"  blocks per graph: "
          f"{[int(x) for x in out.n_bcc]} (chord position sweeps the "
          f"cycle/bridge split)")


def _timed(fn, *args) -> float:
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    return time.perf_counter() - t0


if __name__ == "__main__":
    main()
