"""Quickstart: build rooted spanning trees three ways and compare.

    PYTHONPATH=src python examples/quickstart.py

Reproduces the paper's core comparison on a high-diameter road-like grid:
BFS needs Θ(diameter) steps; GConn+Euler and PR-RST need O(log n) rounds;
the connectivity-based trees come out deeper (the Fig. 2 trade-off).
"""
import time

import jax
import jax.numpy as jnp

from repro.core import rooted_spanning_tree, tree_depth
from repro.core.validate import validate_rst
from repro.data.graphs import grid2d, rmat


def main() -> None:
    for gname, g in [("grid 96x96 (road-like, high diameter)", grid2d(96)),
                     ("rmat scale-13 (power-law, low diameter)", rmat(13, 8))]:
        print(f"\n=== {gname}: V={g.n_nodes} E={g.n_edges} ===")
        root = 0
        for method in ("bfs", "gconn_euler", "pr_rst"):
            fn = jax.jit(lambda gg, m=method: rooted_spanning_tree(
                gg, root, method=m))
            res = fn(g)                      # compile
            jax.block_until_ready(res.parent)
            t0 = time.perf_counter()
            res = fn(g)
            jax.block_until_ready(res.parent)
            dt = (time.perf_counter() - t0) * 1e3
            parent = jnp.where(res.parent < 0, jnp.arange(g.n_nodes),
                               res.parent)
            depth = int(tree_depth(parent))
            ok = validate_rst(g, res.parent, root)["all_ok"]
            print(f"  {method:12s} steps={int(res.steps):5d} "
                  f"depth={depth:5d} time={dt:7.1f} ms valid={ok}")


if __name__ == "__main__":
    main()
