"""Serve a small LM with batched requests: prefill + batched decode.

    PYTHONPATH=src python examples/serve_lm.py --batch 8 --decode 32

Demonstrates the serving path the decode_32k / long_500k dry-run cells
lower: KV-cache prefill, then step-wise batched decode with greedy
sampling — on the qwen3 smoke config so it runs on CPU.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import transformer as tfm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--decode", type=int, default=32)
    args = ap.parse_args()

    cfg = get_arch("qwen3-1.7b").make_smoke_config()
    params = tfm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)

    max_len = args.prompt_len + args.decode
    cache = tfm.init_kv_cache(cfg, args.batch, max_len)

    # Prefill: feed prompt tokens through the decode path to fill the cache
    # (token-by-token here; the prefill_32k dry-run cell lowers the fused
    # chunked-attention prefill instead).
    decode = jax.jit(lambda p, t, c: tfm.decode_step(cfg, p, t, c))
    t0 = time.time()
    for i in range(args.prompt_len):
        logits, cache = decode(params, prompts[:, i], cache)
    print(f"prefill {args.prompt_len} tokens x{args.batch} "
          f"in {time.time()-t0:.2f}s")

    # Batched greedy decode.
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for _ in range(args.decode - 1):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    toks = np.stack([np.asarray(t) for t in out], 1)
    print(f"decoded {args.decode} tokens x{args.batch} in {dt:.2f}s "
          f"({args.batch*args.decode/dt:.0f} tok/s)")
    print("first sequence:", toks[0][:16], "...")
    assert int(cache["len"]) == args.prompt_len + args.decode - 1


if __name__ == "__main__":
    main()
