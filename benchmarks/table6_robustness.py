"""Table VI (extension): self-healing cost — audit, scoped repair, rebuild.

The robustness layer (DESIGN.md §11) claims two things worth tracking in
the perf trajectory: (1) the invariant audit is an O(log n)-sync engine
pass, cheap enough to run on a serving cadence; (2) when faults hit,
the fragment-preserving scoped repair (``dynamic.recovery.
repair_forest`` — sever the broken pointers, keep intact subtrees as
fragments, relink) costs fewer engine syncs than the from-scratch
rebuild (``rebuild_forest``), because its round count scales with the
fault count while the rebuild pays GConn + list-ranking over the whole
pool. XLA-CPU wall-clock is volume-bound, so — as with table4/table5 —
the sync counts are the device-independent signal;
``scripts/bench_smoke.sh`` asserts scoped < full on the single-fault
(f1) rows.

Rows (steady-state churn states: naturally multi-component with deep
live components — the regime a serving deployment actually audits; on
trivially shallow states the rebuild sits at its 2-sync floor and
nothing can beat it):

  table6_robustness/{graph}/audit
      one ``audit_forest`` on the healthy state (with tour + BCC caches
      attached); derived: engine convergence checks spent.
  table6_robustness/{graph}/{injector}/f{K}/scoped
      K seeded faults injected, then audit + ``repair_forest``; derived:
      ``sync_total`` = scoped rep recompute + link-loop overlay syncs +
      link rounds (detection cost reported separately as
      ``audit_syncs``), plus ``severed`` (pointers cut).
  table6_robustness/{graph}/{injector}/f{K}/full
      the same corrupted state through ``rebuild_forest``; derived:
      ``sync_total`` = GConn rounds + list-ranking syncs.

Each scoped/full pair is cross-checked for agreement: the repaired and
rebuilt forests must induce the same component partition and pass a
fresh audit.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import csv_row, time_fn
from repro import obs
from repro.data.graphs import build_suite
from repro.data.streams import STREAMS
from repro.dynamic import (audit_forest, init_state, inject, rebuild_forest,
                           refresh_bcc, refresh_tour, repair_forest,
                           replay_batch)

#: injectors whose damage stays inside one component per injection — the
#: regime the f1 scoped-vs-full assertion in bench_smoke.sh targets.
_INJECTORS = ("parent_bitflip", "rep_corrupt", "tree_mask_desync")
_FAULT_COUNTS = (1, 4)


def _canon(rep: np.ndarray) -> np.ndarray:
    _, first, inverse = np.unique(rep, return_index=True,
                                  return_inverse=True)
    return np.argsort(np.argsort(first))[inverse]


def _steady_state(g):
    batch = 16 if g.n_nodes <= 1024 else 64
    stream = STREAMS["churn"](g, batch=batch, seed=0)
    state = init_state(stream)
    for b in stream.batches[:min(6, len(stream.batches))]:
        state, _ = replay_batch(state, b)
    tn, state = refresh_tour(state, None)
    bcc = refresh_bcc(state, None, tour=tn)
    return state, tn, bcc


def run(suite=None) -> list[str]:
    rows = []
    suite = suite or build_suite(["grid_64", "rmat_14"])
    for name, g in suite.items():
        state, tn, bcc = _steady_state(g)
        base = f"table6_robustness/{name}"

        # The audit row's sync column derives from the obs ledger; the
        # report's own counter is the regression oracle.
        with obs.SyncLedger() as led:
            report = jax.block_until_ready(audit_forest(state, tn, bcc))
        assert bool(report.healthy), f"{name}: steady state unhealthy"
        assert led.total("audit") == int(report.syncs), \
            (led.total("audit"), int(report.syncs))
        t_audit = time_fn(lambda: jax.block_until_ready(
            audit_forest(state, tn, bcc)))
        rows.append(csv_row(f"{base}/audit", t_audit * 1e6,
                            f"syncs={led.total('audit')};healthy=1"))

        for injector in _INJECTORS:
            for k in _FAULT_COUNTS:
                # K *effective* injections: a later fault can cancel an
                # earlier one (e.g. re-forging a dropped tree bit), so
                # re-audit after each and bump the seed until damage
                # sticks (deterministic: the seed sequence is fixed).
                bad, bad_bcc = state, bcc
                seed, landed, tries = 1000 * k, 0, 0
                while landed < k and tries < 16 * k:
                    nxt, nxt_bcc, _ = inject(injector, bad, bad_bcc,
                                             seed=seed)
                    seed += 1
                    tries += 1
                    if not bool(audit_forest(nxt).forest_ok):
                        bad, bad_bcc = nxt, nxt_bcc
                        landed += 1
                rep_bad = jax.block_until_ready(audit_forest(bad))
                assert not bool(rep_bad.forest_ok), (name, injector, k)

                with obs.SyncLedger() as led_s:
                    fixed, rstats = jax.block_until_ready(
                        repair_forest(bad, rep_bad))
                assert led_s.total("repair") == int(rstats["sync_total"])
                t_scoped = time_fn(lambda: jax.block_until_ready(
                    repair_forest(bad, rep_bad)))
                with obs.SyncLedger() as led_f:
                    rebuilt, bstats = jax.block_until_ready(
                        rebuild_forest(bad))
                assert led_f.total("rebuild") == int(bstats["sync_total"])
                t_full = time_fn(lambda: jax.block_until_ready(
                    rebuild_forest(bad)))

                # Agreement: both restore the pool's component partition
                # and a fresh audit passes on each.
                assert bool(audit_forest(fixed).forest_ok), \
                    (name, injector, k, "scoped repair failed re-audit")
                assert bool(audit_forest(rebuilt).forest_ok), \
                    (name, injector, k, "full rebuild failed re-audit")
                assert np.array_equal(_canon(np.asarray(fixed.rep)),
                                      _canon(np.asarray(rebuilt.rep))), \
                    (name, injector, k, "partition mismatch")

                kbase = f"{base}/{injector}/f{k}"
                rows.append(csv_row(
                    f"{kbase}/scoped", t_scoped * 1e6,
                    f"sync_total={led_s.total('repair')};"
                    f"rounds={int(rstats['rounds'])};"
                    f"severed={int(rstats['severed'])};"
                    f"repaired={int(rstats['repaired'])};"
                    f"audit_syncs={int(rep_bad.syncs)}"))
                rows.append(csv_row(
                    f"{kbase}/full", t_full * 1e6,
                    f"sync_total={led_f.total('rebuild')};"
                    f"cc_rounds={int(bstats['cc_rounds'])};"
                    f"rank_syncs={int(bstats['rank_syncs'])}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
