"""Fig. 2 analogue: spanning-tree depth, BFS vs GConn(+Euler) vs PR-RST.

Reproduces the depth–performance trade-off: connectivity-based methods
produce (much) deeper trees; BFS trees are depth-minimal by construction.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.core import rooted_spanning_tree, tree_depth
from repro.data.graphs import build_suite


def run(suite=None) -> list[str]:
    rows = []
    suite = suite or build_suite()
    for name, g in suite.items():
        depths = {}
        for method in ("bfs", "gconn_euler", "pr_rst"):
            res = rooted_spanning_tree(g, 0, method=method)
            parent = jnp.where(res.parent < 0,
                               jnp.arange(g.n_nodes), res.parent)
            depths[method] = int(tree_depth(parent))
        rows.append(csv_row(
            f"fig2/{name}", 0.0,
            f"bfs={depths['bfs']};gconn={depths['gconn_euler']};"
            f"prrst={depths['pr_rst']};"
            f"ratio={depths['gconn_euler']/max(depths['bfs'],1):.1f}x"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
