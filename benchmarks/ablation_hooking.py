"""Ablation: paper's min/max-alternating hooking vs pure min-hooking.

The paper alternates hook direction per round as a convergence/load-balance
optimization for CAS-based GPU hooking. Under this framework's
deterministic scatter-hooking the alternation re-creates a one-hook-per-
round funnel on hub-dominated graphs; pure min-hooking converges in
O(log n). This benchmark measures both (rounds + wall time).
"""
from __future__ import annotations

import jax

from benchmarks.common import csv_row, time_fn
from repro.core.connectivity import connected_components
from repro.data.graphs import build_suite


def run(suite=None) -> list[str]:
    rows = []
    suite = suite or build_suite(["grid_64", "rmat_14", "ba_8k", "er_16k"])
    for name, g in suite.items():
        for label, alt in (("paper_alternating", True), ("pure_min", False)):
            fn = jax.jit(lambda gg, a=alt: connected_components(
                gg, alternate_hooking=a)[2])
            t = time_fn(fn, g, n_runs=3)
            rounds = int(fn(g))
            rows.append(csv_row(f"ablation_hooking/{name}/{label}", t * 1e6,
                                f"rounds={rounds}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
