"""Shared benchmark utilities — paper methodology: 1 warm-up + 5 timed
runs, report the median (§IV)."""
from __future__ import annotations

import subprocess
import time

import jax
import numpy as np

#: bump when the record layout changes (stamped into every JSON row).
BENCH_SCHEMA_VERSION = 1


def bench_meta() -> dict:
    """Provenance stamped onto every BENCH_rst.json record: without the
    producing commit + backend a perf trajectory point is unattributable."""
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10).stdout.strip()
    except (OSError, subprocess.SubprocessError):
        sha = ""
    dev = jax.devices()[0]
    return {"git_sha": sha or "unknown",
            "jax_version": jax.__version__,
            "backend": jax.default_backend(),
            "device_kind": dev.device_kind,
            "schema_version": BENCH_SCHEMA_VERSION}


def time_fn(fn, *args, n_runs: int = 5, warmup: int = 1, **kwargs):
    """Median wall-time (seconds) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kwargs))
    times = []
    for _ in range(n_runs):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kwargs))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def rows_to_records(rows: list[str], meta: dict | None = None) -> list[dict]:
    """Parse ``name,us_per_call,derived`` CSV rows into JSON-able records.

    With ``meta`` (see :func:`bench_meta`), every record carries the same
    provenance dict and the list is sorted by name — a stable order so
    two runs of the same tree diff cleanly."""
    records = []
    for row in rows:
        name, us, derived = row.split(",", 2)
        rec = {"name": name, "us_per_call": float(us), "derived": derived}
        if meta is not None:
            rec["meta"] = meta
        records.append(rec)
    if meta is not None:
        records.sort(key=lambda r: r["name"])
    return records
