"""Shared benchmark utilities — paper methodology: 1 warm-up + 5 timed
runs, report the median (§IV)."""
from __future__ import annotations

import time

import jax
import numpy as np


def time_fn(fn, *args, n_runs: int = 5, warmup: int = 1, **kwargs):
    """Median wall-time (seconds) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kwargs))
    times = []
    for _ in range(n_runs):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kwargs))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def rows_to_records(rows: list[str]) -> list[dict]:
    """Parse ``name,us_per_call,derived`` CSV rows into JSON-able records."""
    records = []
    for row in rows:
        name, us, derived = row.split(",", 2)
        records.append({"name": name, "us_per_call": float(us),
                        "derived": derived})
    return records
