"""Benchmark runner — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  table2/*   graph statistics (Table II analogue)
  fig1/*     runtime comparison BFS / PR-RST / GConn+Euler (Fig. 1)
  fig2/*     spanning-tree depth comparison (Fig. 2)
  table1/*   measured step counts vs theory (Table I)
  kernels/*  Pallas kernel micro-benchmarks (interpret mode)
  roofline/* dry-run roofline terms, if artifacts/dryrun exists (§Roofline)
"""
from __future__ import annotations

import pathlib
import sys


def kernel_microbench() -> list[str]:
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import csv_row, time_fn
    from repro.kernels.pointer_jump.ops import pointer_jump_k
    from repro.kernels.list_rank.ops import list_rank_k
    from repro.kernels.embed_bag.ops import embed_bag

    rng = np.random.default_rng(0)
    rows = []
    n = 1 << 16
    p = jnp.asarray(rng.integers(0, n, n), jnp.int32)
    rows.append(csv_row("kernels/pointer_jump_64k_x5",
                        time_fn(pointer_jump_k, p) * 1e6))
    succ = jnp.asarray(np.roll(np.arange(n), -1), jnp.int32).at[-1].set(-1)
    d0 = jnp.ones(n, jnp.int32).at[-1].set(0)
    rows.append(csv_row("kernels/list_rank_64k_x5",
                        time_fn(list_rank_k, succ, d0) * 1e6))
    idx = jnp.asarray(rng.integers(0, 10_000, (4096, 8)), jnp.int32)
    tab = jnp.asarray(rng.standard_normal((10_000, 64)), jnp.float32)
    rows.append(csv_row("kernels/embed_bag_4096x8x64",
                        time_fn(embed_bag, idx, tab) * 1e6))
    return rows


def main() -> None:
    from benchmarks import (ablation_hooking, fig1_runtime, fig2_depth,
                            table1_steps, table2_stats)

    rows: list[str] = []
    print("name,us_per_call,derived")
    for mod in (table2_stats, table1_steps, fig2_depth, fig1_runtime,
                ablation_hooking):
        for row in mod.run():
            print(row)
            sys.stdout.flush()
    for row in kernel_microbench():
        print(row)
    if pathlib.Path("artifacts/dryrun").exists():
        from benchmarks import roofline
        for row in roofline.run():
            print(row)


if __name__ == "__main__":
    main()
