"""Benchmark runner — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  table2/*            graph statistics (Table II analogue)
  fig1/*              runtime comparison BFS / PR-RST / GConn+Euler (Fig. 1)
  fig2/*              spanning-tree depth comparison (Fig. 2)
  table1/*            measured step counts vs theory (Table I)
  table3/*            downstream biconnectivity cost per RST flavor
                      (the Tarjan–Vishkin layer, DESIGN.md §4)
  table4_dynamic/*    batch-dynamic maintenance vs from-scratch rebuild per
                      stream × batch size (DESIGN.md §9)
  table5_dynamic_bcc/* incremental vs recomputed biconnectivity on the
                      dynamic pool, with sync/round counts (DESIGN.md §10)
  table6_robustness/* self-healing cost: audit syncs, scoped repair vs
                      full rebuild on injected faults (DESIGN.md §11)
  table7_queries/*    batched tree-query serving: amortized QueryTables
                      vs per-read-batch recompute (DESIGN.md §12)
  table8_fleet/*      multi-tenant fleet: vmapped T-tenant apply vs T
                      sequential loops, sync accounting (DESIGN.md §13)
  table9_buckets/*    shape-bucketed sub-fleets vs one wide schema at
                      equal device-memory budget: sync + padded-slot
                      work on a mixed tenant population (DESIGN.md §15)
  kernels/*           Pallas kernel micro-benchmarks (incl. compress_* engine
                      rows; interpret mode off-TPU)
  ablation_compress/* amortized vs per-hop convergence checks (engine k=5
                      vs k=1, with measured ``jnp.any`` sync counts)
  ablation_hooking/*  paper's min/max alternation vs pure-min hooking
  roofline/*          dry-run roofline terms, if artifacts/dryrun exists

Flags:
  --json PATH   also write all rows as JSON records (machine-readable perf
                trajectory, e.g. ``--json BENCH_rst.json``); each record
                is stamped with a ``meta`` provenance dict (git sha, jax
                version, backend/device kind, schema version) and the
                list is sorted by name for stable diffs
  --smoke       one tiny graph per fig/table + small microbenches — fast
                enough for CI, exercises every perf path
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys


def kernel_microbench(n: int = 1 << 16) -> list[str]:
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import csv_row, time_fn
    from repro.kernels.pointer_jump.ops import pointer_jump_k
    from repro.kernels.list_rank.ops import list_rank_k
    from repro.kernels.embed_bag.ops import embed_bag

    rng = np.random.default_rng(0)
    rows = []
    tag = f"{n >> 10}k"
    p = jnp.asarray(rng.integers(0, n, n), jnp.int32)
    rows.append(csv_row(f"kernels/pointer_jump_{tag}_x5",
                        time_fn(pointer_jump_k, p) * 1e6))
    succ = jnp.asarray(np.roll(np.arange(n), -1), jnp.int32).at[-1].set(-1)
    d0 = jnp.ones(n, jnp.int32).at[-1].set(0)
    rows.append(csv_row(f"kernels/list_rank_{tag}_x5",
                        time_fn(list_rank_k, succ, d0) * 1e6))
    b = min(4096, max(256, n // 16))   # smoke shrinks this bench too
    v = min(10_000, 4 * n)
    idx = jnp.asarray(rng.integers(0, v, (b, 8)), jnp.int32)
    tab = jnp.asarray(rng.standard_normal((v, 64)), jnp.float32)
    rows.append(csv_row(f"kernels/embed_bag_{b}x8x64",
                        time_fn(embed_bag, idx, tab) * 1e6))
    return rows


def compress_microbench(n: int = 1 << 16) -> list[str]:
    """Engine rows: full compression on the worst case (a depth-n chain),
    XLA vs Pallas path, plus the amortized-vs-per-hop sync-count ablation."""
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import csv_row, time_fn
    from repro.core.compress import compress_full

    rows = []
    tag = f"{n >> 10}k"
    chain = jnp.asarray(np.maximum(np.arange(n) - 1, 0), jnp.int32)

    for label, kwargs in (
            (f"kernels/compress_full_{tag}_xla", dict()),
            (f"kernels/compress_full_{tag}_kernel", dict(use_kernel=True)),
    ):
        _, syncs = compress_full(chain, return_syncs=True, **kwargs)
        t = time_fn(compress_full, chain, **kwargs)
        rows.append(csv_row(label, t * 1e6, f"syncs={int(syncs)}"))

    # Ablation: per-hop (k=1, the seed's hand-rolled loops) vs amortized k=5.
    for label, k in (("per_hop_k1", 1), ("amortized_k5", 5)):
        _, syncs = compress_full(chain, n_jumps=k, return_syncs=True)
        t = time_fn(compress_full, chain, n_jumps=k)
        rows.append(csv_row(f"ablation_compress/chain_{tag}/{label}",
                            t * 1e6, f"syncs={int(syncs)}"))
    return rows


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--json", metavar="PATH", default=None,
                        help="also write rows as JSON records")
    parser.add_argument("--smoke", action="store_true",
                        help="tiny graphs + small microbenches (CI path)")
    args = parser.parse_args(argv)

    from benchmarks import (ablation_hooking, fig1_runtime, fig2_depth,
                            table1_steps, table2_stats, table3_bcc,
                            table4_dynamic, table5_dynamic_bcc,
                            table6_robustness, table7_queries,
                            table8_fleet, table9_buckets)
    from benchmarks.common import bench_meta, rows_to_records
    from repro.data import graphs as G

    if args.smoke:
        suite = {"smoke_chain_256": G.chain(256),
                 "smoke_rmat_6": G.rmat(6, edge_factor=4, seed=0)}
        # The scoped-vs-full comparison needs a state deep enough that
        # the full rebuild is off its sync floor — one mid-size grid
        # instead of the micro graphs (still < 10 s on CI).
        t6_suite = {"grid_32": G.grid2d(32)}
        micro_n = 1 << 12
    else:
        suite = None  # modules build the full Table-II suite
        t6_suite = None
        micro_n = 1 << 16

    rows: list[str] = []

    def emit(new_rows):
        for row in new_rows:
            rows.append(row)
            print(row)
            sys.stdout.flush()

    print("name,us_per_call,derived")
    emit(table2_stats.run(suite))
    emit(table1_steps.run(suite))
    emit(fig2_depth.run(suite))
    emit(fig1_runtime.run(suite))
    emit(table3_bcc.run(suite))
    emit(table4_dynamic.run(suite))
    emit(table5_dynamic_bcc.run(suite))
    emit(table6_robustness.run(t6_suite))
    emit(table7_queries.run(suite))
    emit(table8_fleet.run(suite))
    emit(table9_buckets.run(smoke=args.smoke))
    emit(ablation_hooking.run(suite))
    emit(kernel_microbench(micro_n))
    emit(compress_microbench(micro_n))
    if pathlib.Path("artifacts/dryrun").exists():
        from benchmarks import roofline
        emit(roofline.run())

    if args.json:
        pathlib.Path(args.json).write_text(
            json.dumps(rows_to_records(rows, meta=bench_meta()), indent=1)
            + "\n")
        print(f"# wrote {len(rows)} rows to {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
