"""Table I analogue: measured parallel-step counts vs the theory table.

BFS: Θ(diam) level steps. GConn: O(log V) hook/compress rounds.
PR-RST: O(log V) hook/reverse rounds. The measured counts are the
empirical side of the paper's complexity table.
"""
from __future__ import annotations

import math

from benchmarks.common import csv_row
from repro.core import rooted_spanning_tree
from repro.data.graphs import build_suite


def run(suite=None) -> list[str]:
    rows = []
    suite = suite or build_suite()
    for name, g in suite.items():
        steps = {}
        for method in ("bfs", "gconn_euler", "pr_rst"):
            res = rooted_spanning_tree(g, 0, method=method)
            steps[method] = int(res.steps)
        logv = math.log2(max(g.n_nodes, 2))
        rows.append(csv_row(
            f"table1/{name}", 0.0,
            f"bfs_steps={steps['bfs']};gconn_rounds={steps['gconn_euler']};"
            f"prrst_rounds={steps['pr_rst']};log2V={logv:.1f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
