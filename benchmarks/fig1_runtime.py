"""Fig. 1 analogue: running-time comparison BFS vs PR-RST vs GConn+Euler.

The paper's headline: GConn+Euler is up to 300× faster than BFS on
high-diameter graphs and roughly flat across diameters, while BFS runtime
scales with the BFS-tree depth. At laptop scale on CPU the absolute gap is
smaller (no 10k-thread latency hiding), but the SHAPE of the result — BFS
cost ∝ diameter, connectivity-based cost ~flat — is the reproduced claim.
"""
from __future__ import annotations

import jax

from benchmarks.common import csv_row, time_fn
from repro.core import rooted_spanning_tree
from repro.data.graphs import build_suite


def run(suite=None) -> list[str]:
    rows = []
    suite = suite or build_suite()
    for name, g in suite.items():
        times = {}
        for method in ("bfs", "gconn_euler", "pr_rst"):
            fn = jax.jit(lambda graph, m=method: rooted_spanning_tree(
                graph, 0, method=m).parent)
            t = time_fn(fn, g)
            times[method] = t
            rows.append(csv_row(f"fig1/{name}/{method}", t * 1e6))
        speedup = times["bfs"] / times["gconn_euler"]
        rows.append(csv_row(f"fig1/{name}/speedup_gconn_over_bfs", 0.0,
                            f"{speedup:.1f}x"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
