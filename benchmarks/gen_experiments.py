"""Regenerate the auto tables in EXPERIMENTS.md from dry-run artifacts +
benchmark runs. Manual narrative sections are kept; content between
``<!-- AUTO:name -->`` and ``<!-- /AUTO:name -->`` markers is replaced.

    PYTHONPATH=src python -m benchmarks.gen_experiments
"""
from __future__ import annotations

import json
import pathlib
import re

from benchmarks.roofline import load_rows, markdown_table


def dryrun_table(mesh: str) -> str:
    rows = []
    for f in sorted(pathlib.Path("artifacts/dryrun").glob(f"*__{mesh}.json")):
        d = json.loads(f.read_text())
        if not d.get("ok"):
            rows.append(f"| {d['arch']} | {d['shape']} | FAILED | | | |")
            continue
        ma = d["memory_analysis"]
        hp = d["hlo_parsed"]
        rows.append(
            f"| {d['arch']} | {d['shape']} | "
            f"{d['timings_s']['compile']:.0f}s | "
            f"{(ma.get('argument_size_in_bytes',0))/2**30:.2f} | "
            f"{(ma.get('temp_size_in_bytes',0))/2**30:.2f} | "
            f"{hp['collective_bytes']/2**30:.2f} |")
    head = ("| arch | shape | compile | args GiB/chip | temp GiB/chip | "
            "collective GiB/chip |\n|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


def splice(text: str, name: str, content: str) -> str:
    pat = re.compile(rf"(<!-- AUTO:{name} -->).*?(<!-- /AUTO:{name} -->)",
                     re.S)
    return pat.sub(lambda m: m.group(1) + "\n" + content + "\n" + m.group(2),
                   text)


def main() -> None:
    p = pathlib.Path("EXPERIMENTS.md")
    text = p.read_text()
    text = splice(text, "dryrun_single", dryrun_table("16x16"))
    text = splice(text, "dryrun_multi", dryrun_table("2x16x16"))
    text = splice(text, "roofline", markdown_table())
    p.write_text(text)
    print("EXPERIMENTS.md tables regenerated")


if __name__ == "__main__":
    main()
