"""Table II analogue: graph statistics for the laptop-scale suite.

Diameter is approximated by the depth of the BFS spanning tree from vertex
0 — the same approximation the paper uses ("diameter is approximated by
the depth of the BFS spanning tree").
"""
from __future__ import annotations

from repro.core import bfs_rst
from repro.data.graphs import SUITE, build_suite


def run(suite=None) -> list[str]:
    rows = []
    suite = suite or build_suite()
    for name, g in suite.items():
        _, _, levels = bfs_rst(g, 0)
        regime = SUITE[name][2] if name in SUITE else "smoke"
        rows.append(f"table2/{name},0,V={g.n_nodes};E={g.n_edges};"
                    f"diam~{int(levels)};{regime}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
