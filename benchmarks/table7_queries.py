"""Table VII (extension): amortized vs recomputed tree-query serving.

The query layer's whole bet (DESIGN.md §12): build the ``QueryTables``
index ONCE per tour refresh — one ``rank_to_root`` pass + ⌈log2 n⌉
sync-free doubling levels — then answer every query batch until the next
refresh with fixed-shape gathers costing zero additional engine syncs.
This table measures that amortization against the naive alternative that
rebuilds the tour + tables per read batch, for a read-heavy and a
write-heavy interleave:

  table7_queries/{graph}/{scenario}/amortized
      one ``build_tables`` + R mixed read batches (lca / connected /
      subtree add / path min over Q random pairs); reported per batch
  table7_queries/{graph}/{scenario}/recompute
      per read batch: full ``tour_numbering`` + ``build_tables`` + the
      same mixed bundle

scenario: read_heavy = 8 read batches between refreshes, write_heavy = 1.

derived: ``sync_per_read`` — engine syncs charged per read batch
(amortized: build_syncs / R, then 0 for the queries themselves;
recompute: the full build_syncs every batch, and that *excludes* the
tour's list-ranking syncs, so it is a lower bound that already loses).
``scripts/bench_smoke.sh`` asserts amortized < recompute on the
read_heavy rows.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, time_fn
from repro import obs
from repro.core.euler import tour_numbering
from repro.core.queries import (build_tables, connected, lca, path_agg,
                                subtree_agg)
from repro.data.graphs import build_suite
from repro.data.streams import STREAMS
from repro.dynamic import init_state, refresh_tour, replay_batch

#: read batches per refresh interval.
SCENARIOS = {"read_heavy": 8, "write_heavy": 1}

#: query pairs per read batch.
N_QUERIES = 256


def _bundle(tables, u, v, payload):
    """One mixed read batch: the four op families, Q queries each."""
    return (lca(tables, u, v), connected(tables, u, v),
            subtree_agg(tables, u, payload, "add"),
            path_agg(tables, u, v, payload, "min"))


def run(suite=None) -> list[str]:
    rows = []
    suite = suite or build_suite(["grid_64", "rmat_14"])
    for name, g in suite.items():
        n = g.n_nodes
        stream = STREAMS["churn"](g, batch=32, seed=0, n_batches=4)
        state = init_state(stream)
        for b in stream.batches:
            state, _ = replay_batch(state, b)
        tn, state = refresh_tour(state, None)

        rng = np.random.default_rng(0)
        u = jnp.asarray(rng.integers(0, n, N_QUERIES), jnp.int32)
        v = jnp.asarray(rng.integers(0, n, N_QUERIES), jnp.int32)
        payload = jnp.asarray(rng.integers(1, 100, n), jnp.int32)
        # sync_per_read derives from the obs ledger ("build_tables" is
        # the only phase a query-serving interval pays); the tables'
        # own build_syncs field is the regression oracle.
        with obs.SyncLedger() as led:
            build_syncs = int(build_tables(tn).build_syncs)
        assert led.total("build_tables") == build_syncs, \
            (led.total("build_tables"), build_syncs)
        build_syncs = led.total("build_tables")

        for scen, reads in SCENARIOS.items():
            def amortized():
                tables = build_tables(tn)
                return [_bundle(tables, u, v, payload)
                        for _ in range(reads)]

            t_amort = time_fn(
                lambda: jax.block_until_ready(amortized())) / reads

            def recompute():
                tn2 = tour_numbering(state.parent)
                return _bundle(build_tables(tn2), u, v, payload)

            t_rec = time_fn(lambda: jax.block_until_ready(recompute()))

            base = f"table7_queries/{name}/{scen}"
            rows.append(csv_row(
                f"{base}/amortized", t_amort * 1e6,
                f"reads_per_refresh={reads};queries={N_QUERIES};"
                f"sync_per_read={build_syncs / reads:.2f};"
                f"serve_syncs=0;build_syncs={build_syncs}"))
            rows.append(csv_row(
                f"{base}/recompute", t_rec * 1e6,
                f"reads_per_refresh={reads};queries={N_QUERIES};"
                f"sync_per_read={build_syncs:.2f};"
                f"serve_syncs={build_syncs};build_syncs={build_syncs}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
