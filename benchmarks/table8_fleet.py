"""Table VIII (extension): multi-tenant fleet vs T sequential loops.

The §13 headline (DESIGN.md): serving T session graphs as ONE vmapped
``ForestFleet`` amortizes the engine's convergence checks across
tenants. Each ``apply_batches`` tick pays ``max_t(rounds_t) + 1``
sync-point checks (the vmapped link ``while_loop`` trips until the
slowest lane converges; converged lanes ride along as no-op bodies),
where T independent single-tenant loops pay ``Σ_t(rounds_t + 1)`` — the
same wall-clock-free, device-independent sync accounting tables 5–7 use
on the XLA-CPU CI backend.

Rows (one fleet/sequential pair per graph × stream, T tenants with
decorrelated per-tenant seeds, identical event streams on both sides):

  table8_fleet/{graph}/{stream}/T{T}/b{B}/fleet
      the vmapped fleet: one (T, B) ``apply_batches`` per tick +
      cadenced vmapped ``refresh_tours``
  table8_fleet/{graph}/{stream}/T{T}/b{B}/sequential
      T single-tenant ``replay_batch`` loops + per-tenant
      ``refresh_tour`` at the same cadence

derived: events_per_sec (aggregate applied events over the measured
run), sync_total, sync_per_event. The bench asserts the two sides end
bit-identical per tenant (parents, reps, versions) before reporting —
a fleet row that drifted from its sequential twin is a bug, not a
datapoint; ``scripts/bench_smoke.sh`` asserts the fleet's
sync_per_event stays below the sequential twin's.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import csv_row
from repro import obs
from repro.data.graphs import build_suite
from repro.data.streams import STREAMS
from repro.dynamic.fleet import (apply_batches, fleet_empty,
                                 fleet_sync_cost, refresh_tours)
from repro.dynamic.replay import init_state, replay_batch, stream_capacity
from repro.dynamic.tour import refresh_tour

_TENANTS = 4
_N_BATCHES = 6
_CADENCE = 2
_STREAM_NAMES = ("sliding_window", "churn")


def _tick_block(streams, i):
    return tuple(np.stack([np.asarray(getattr(s.batches[i], f))
                           for s in streams])
                 for f in ("ins_u", "ins_v", "del_u", "del_v"))


def _run_fleet(streams, capacity, n_nodes, steps):
    fleet = fleet_empty(len(streams), n_nodes, capacity)
    for t, s in enumerate(streams):
        fleet = fleet.set_tenant(t, init_state(s, capacity=capacity))
    tn = None
    sync = 0
    with obs.SyncLedger() as led:
        for i in range(steps):
            iu, iv, du, dv = _tick_block(streams, i)
            fleet, stats = apply_batches(fleet, iu, iv, du, dv)
            sync += fleet_sync_cost(stats)
            if (i + 1) % _CADENCE == 0:
                tn, fleet = refresh_tours(fleet, tn)
        tn, fleet = refresh_tours(fleet, tn)
    jax.block_until_ready(fleet.parent)
    # The ledger is the reporting path; the hand-summed fleet_sync_cost
    # is the regression oracle — both count the same while_loop carries.
    assert led.total("fleet_apply") == sync, \
        (led.total("fleet_apply"), sync)
    return fleet, led.total("fleet_apply")


def _run_sequential(streams, capacity, steps):
    states = [init_state(s, capacity=capacity) for s in streams]
    tns = [None] * len(streams)
    sync = 0
    events = 0
    with obs.SyncLedger() as led:
        for i in range(steps):
            for t, s in enumerate(streams):
                states[t], stats = replay_batch(states[t], s.batches[i])
                sync += int(stats["rounds"]) + 1
                n = s.n_nodes
                ins = int((np.asarray(s.batches[i].ins_u) < n).sum())
                events += (ins - int(stats["overflow"])
                           + int(stats["deletes_found"]))
                if (i + 1) % _CADENCE == 0:
                    tns[t], states[t] = refresh_tour(states[t], tns[t])
        for t in range(len(streams)):
            tns[t], states[t] = refresh_tour(states[t], tns[t])
    jax.block_until_ready(states[0].parent)
    assert led.total("apply") == sync, (led.total("apply"), sync)
    return states, led.total("apply"), events


def _assert_equal(fleet, states):
    for t, s in enumerate(states):
        f = fleet.tenant(t)
        for field in ("parent", "rep", "pool_valid", "tree_mask",
                      "version"):
            a = np.asarray(getattr(f, field))
            b = np.asarray(getattr(s, field))
            assert np.array_equal(a, b), \
                f"fleet/sequential divergence: tenant {t} field {field}"


def run(suite=None) -> list[str]:
    rows = []
    suite = suite or build_suite(["grid_64", "rmat_14"])
    for name, g in suite.items():
        batch = 16 if g.n_nodes <= 1024 else 64
        for stream_name in _STREAM_NAMES:
            streams = [STREAMS[stream_name](g, batch=batch,
                                            n_batches=_N_BATCHES, seed=t)
                       for t in range(_TENANTS)]
            steps = min(_N_BATCHES, min(len(s.batches) for s in streams))
            if steps < 2:
                continue
            capacity = max(stream_capacity(s) for s in streams)

            # Warm both paths (compile), then time one full replay each.
            _run_fleet(streams, capacity, g.n_nodes, steps)
            t0 = time.perf_counter()
            fleet, sync_fleet = _run_fleet(streams, capacity, g.n_nodes,
                                           steps)
            t_fleet = time.perf_counter() - t0

            _run_sequential(streams, capacity, steps)
            t0 = time.perf_counter()
            states, sync_seq, events = _run_sequential(streams, capacity,
                                                       steps)
            t_seq = time.perf_counter() - t0

            _assert_equal(fleet, states)

            base = f"table8_fleet/{name}/{stream_name}/T{_TENANTS}/b{batch}"
            rows.append(csv_row(
                f"{base}/fleet", t_fleet * 1e6,
                f"events_per_sec={events / max(t_fleet, 1e-9):.0f};"
                f"sync_total={sync_fleet};"
                f"sync_per_event={sync_fleet / max(events, 1):.4f}"))
            rows.append(csv_row(
                f"{base}/sequential", t_seq * 1e6,
                f"events_per_sec={events / max(t_seq, 1e-9):.0f};"
                f"sync_total={sync_seq};"
                f"sync_per_event={sync_seq / max(events, 1):.4f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
