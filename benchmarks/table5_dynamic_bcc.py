"""Table V (extension): incremental vs recomputed biconnectivity per batch.

``table3_bcc`` measures the static Tarjan–Vishkin layer; ``table4_dynamic``
measures forest maintenance vs rebuild. This table closes the loop the
paper's motivation opens — RST as the *substrate for biconnectivity* — in
the streaming regime (DESIGN.md §10): per stream × batch size, is
maintaining the pool's BCC labels under dirty-component scoping cheaper
than recomputing the decomposition from scratch?

Rows (median over the paper's 1 + 5 methodology, steady-state batch):

  table5_dynamic_bcc/{graph}/{stream}/b{B}/incremental
      one ``dynamic.replay_batch`` + incremental ``refresh_tour`` +
      incremental ``refresh_bcc`` (snapshot-diff dirty scoping)
  table5_dynamic_bcc/{graph}/{stream}/b{B}/recompute
      the same batch + full ``tour_numbering`` + full ``refresh_bcc``
      over the same live pool

derived: ``sync_total`` = low/high doubling levels built + aux-graph
GConn rounds — the device-independent step counts. XLA-CPU wall-clock is
volume-bound (every array op touches all n vertices regardless of
scope), so the sync counts are the tracked advantage for device
backends; ``scripts/bench_smoke.sh`` asserts incremental < recompute on
the chain-regime sliding_window rows, where dirty components are a small
fraction of the graph.
"""
from __future__ import annotations

import jax

from benchmarks.common import csv_row, time_fn
from repro import obs
from repro.core.euler import tour_numbering
from repro.data.graphs import build_suite
from repro.data.streams import STREAMS
from repro.dynamic import init_state, refresh_bcc, refresh_tour, replay_batch

#: streams measured: sliding_window keeps components small (the scoped
#: sweet spot), churn dirties large fractions (the honest worst case).
_STREAM_NAMES = ("sliding_window", "churn")


def _batches_for(n: int) -> tuple[int, ...]:
    return (4, 16) if n <= 1024 else (16, 256)


def _steady_state(stream, warm_batches: int):
    """Advance a few batches so timing sees steady state, not cold start."""
    state = init_state(stream)
    for b in stream.batches[:warm_batches]:
        state, _ = replay_batch(state, b)
    tn, state = refresh_tour(state, None)
    bcc = refresh_bcc(state, None, tour=tn)
    return state, tn, bcc


def run(suite=None) -> list[str]:
    rows = []
    suite = suite or build_suite(["grid_64", "rmat_14"])
    for name, g in suite.items():
        for stream_name in _STREAM_NAMES:
            for batch in _batches_for(g.n_nodes):
                stream = STREAMS[stream_name](g, batch=batch, seed=0,
                                              n_batches=6)
                if len(stream.batches) < 2:
                    continue
                state, tn, bcc = _steady_state(stream,
                                               len(stream.batches) - 1)
                b = stream.batches[-1]
                events = int((b.ins_u < g.n_nodes).sum()
                             + (b.del_u < g.n_nodes).sum())

                # replay_batch / refresh_* are functional: timing repeats
                # the same batch from the same pre-state.
                def incr():
                    s2, _ = replay_batch(state, b)
                    tn2, s2 = refresh_tour(s2, tn, incremental=True)
                    b2 = refresh_bcc(s2, bcc, tour=tn2, incremental=True)
                    return b2

                # One instrumented pass per variant: the reported
                # sync_total derives from the obs ledger's refresh_bcc
                # phase; the DynamicBCC counters are the oracle.
                with obs.SyncLedger() as led_i:
                    bcc_i = jax.block_until_ready(incr())
                t_incr = time_fn(lambda: jax.block_until_ready(incr()))

                def scratch():
                    s2, _ = replay_batch(state, b)
                    tn2 = tour_numbering(s2.parent)
                    b2 = refresh_bcc(s2, None, tour=tn2,
                                     incremental=False)
                    return b2

                with obs.SyncLedger() as led_f:
                    bcc_f = jax.block_until_ready(scratch())
                t_scr = time_fn(lambda: jax.block_until_ready(scratch()))
                assert int(bcc_i.n_bcc) == int(bcc_f.n_bcc)  # bit-identity

                base = f"table5_dynamic_bcc/{name}/{stream_name}/b{batch}"
                for tag, t, bc, led in (("incremental", t_incr, bcc_i,
                                         led_i),
                                        ("recompute", t_scr, bcc_f,
                                         led_f)):
                    sync_total = led.total("refresh_bcc")
                    oracle = int(bc.seg_syncs) + int(bc.aux_rounds)
                    assert sync_total == oracle, (tag, sync_total, oracle)
                    rows.append(csv_row(
                        f"{base}/{tag}", t * 1e6,
                        f"updates_per_sec={events / max(t, 1e-9):.0f};"
                        f"sync_total={sync_total};"
                        f"seg_syncs={int(bc.seg_syncs)};"
                        f"aux_rounds={int(bc.aux_rounds)};"
                        f"dirty={int(bc.dirty_count)};"
                        f"n_bcc={int(bc.n_bcc)};"
                        f"bridges={int(bc.n_bridges)}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
