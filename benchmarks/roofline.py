"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape) on the single-pod 16×16 mesh:

    T_compute    = flops_per_dev / PEAK_FLOPS
    T_memory     = bytes_per_dev / HBM_BW
    T_collective = collective_bytes_per_dev / LINK_BW

flops/bytes/collective_bytes come from the trip-count-aware HLO parse
(``repro.launch.hlo_analysis``) of the compiled per-device module. The
dominant term is the bottleneck; MODEL_FLOPS = 6·N·D (dense) or
6·N_active·D (MoE) gives the useful-compute ratio.

Hardware constants (v5e-like, from the assignment): 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI.

Methodology caveat (documented in EXPERIMENTS.md): the CPU-backend HLO
upcasts bf16 dots to f32 and fuses differently than the TPU backend, so
T_memory is an upper-bound proxy; relative movement across perf iterations
is the signal, and FLOPs counts are exact.
"""
from __future__ import annotations

import json
import pathlib

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link (conservative: 1 link)


def model_flops(arch: str, shape: str) -> float | None:
    """6·N·D (dense) / 6·N_active·D (MoE) per device — useful compute."""
    from repro.configs import get_arch

    spec = get_arch(arch)
    sh = spec.shapes[shape]
    if spec.family == "lm":
        cfg = spec.make_config()
        n = cfg.active_param_count()
        if sh["kind"] == "train":
            d = sh["batch"] * sh["seq"]
            return 6.0 * n * d / 256
        if sh["kind"] == "prefill":
            d = sh["batch"] * sh["seq"]
            return 2.0 * n * d / 256
        # decode: one token per sequence
        return 2.0 * n * sh["batch"] / 256
    return None


def load_rows(dryrun_dir="artifacts/dryrun", mesh="16x16"):
    rows = []
    for f in sorted(pathlib.Path(dryrun_dir).glob(f"*__{mesh}.json")):
        d = json.loads(f.read_text())
        if not d.get("ok"):
            rows.append({"arch": d["arch"], "shape": d["shape"],
                         "ok": False})
            continue
        hp = d["hlo_parsed"]
        t_c = hp["flops"] / PEAK_FLOPS
        t_m = hp["bytes_accessed"] / HBM_BW
        t_x = hp["collective_bytes"] / LINK_BW
        dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
                  key=lambda kv: kv[1])
        mf = model_flops(d["arch"], d["shape"])
        rows.append({
            "arch": d["arch"], "shape": d["shape"], "ok": True,
            "t_compute": t_c, "t_memory": t_m, "t_collective": t_x,
            "dominant": dom[0],
            "bound_s": dom[1],
            "model_flops": mf,
            "useful_ratio": (mf / hp["flops"]) if mf else None,
            "temp_gib": d["memory_analysis"].get("temp_size_in_bytes", 0) / 2**30,
            "flops": hp["flops"], "bytes": hp["bytes_accessed"],
            "coll": hp["collective_bytes"],
            "per_collective": hp.get("per_collective", {}),
        })
    return rows


def run(dryrun_dir="artifacts/dryrun") -> list[str]:
    out = []
    for r in load_rows(dryrun_dir):
        if not r.get("ok"):
            out.append(f"roofline/{r['arch']}/{r['shape']},0,FAILED")
            continue
        ur = f";useful={r['useful_ratio']:.2f}" if r["useful_ratio"] else ""
        out.append(
            f"roofline/{r['arch']}/{r['shape']},{r['bound_s']*1e6:.1f},"
            f"dom={r['dominant']};tc={r['t_compute']*1e3:.2f}ms;"
            f"tm={r['t_memory']*1e3:.2f}ms;tx={r['t_collective']*1e3:.2f}ms"
            f"{ur};temp={r['temp_gib']:.1f}GiB")
    return out


def markdown_table(dryrun_dir="artifacts/dryrun", mesh="16x16") -> str:
    rows = load_rows(dryrun_dir, mesh)
    lines = [
        "| arch | shape | T_comp (ms) | T_mem (ms) | T_coll (ms) | dominant "
        "| useful | temp GiB |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | FAILED | — | — |")
            continue
        ur = f"{r['useful_ratio']:.2f}" if r["useful_ratio"] else "n/a"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']*1e3:.2f} | "
            f"{r['t_memory']*1e3:.2f} | {r['t_collective']*1e3:.2f} | "
            f"**{r['dominant']}** | {ur} | {r['temp_gib']:.1f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print("\n".join(run()))
