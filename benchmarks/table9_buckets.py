"""Table IX (extension): shape-bucketed sub-fleets vs one wide schema.

The §15 headline (DESIGN.md): a single ``ForestFleet`` forces every
tenant through ONE ``(n, capacity)`` schema, so a mixed population —
many tiny sessions plus a few large ones — pays the largest tenant's
padding on every lane. A ``BucketedFleet`` routes tenants by
``FleetSchema`` into independently-ticking sub-fleets, each with its own
``(T_b, B_b)`` block, refresh cadence, and ``max_t(rounds)+1`` sync
bill.

The comparison holds the DEVICE MEMORY BUDGET equal, not the slot
count: the single-schema side gets as many wide slots as the bucketed
side's total slot footprint buys (``Σ_b slots_b · slot_cost_b`` over
the wide ``slot_cost``, ≥ 1). At equal memory the wide fleet fits only
a couple of residents, so the mixed population rotates through
idle-LRU eviction and pays far more ticks — more convergence syncs AND
more padded slot-work — while the bucketed side runs the tiny tenants
wide-in-parallel in their own cheap bucket.

Rows (one mix per line, identical logical event streams on both sides):

  table9_buckets/{mix}/T{total}/bucketed
  table9_buckets/{mix}/T{total}/single_schema

derived: events_per_sec, sync_total, sync_per_event, padded_rows
(Σ blocks · T_b · slot_cost_b — int32-rows of slot state ticked), and
pad_ratio (padded slot-events per applied event).

Before any row is reported, EVERY tenant on BOTH sides is checked
bit-identical against an independent single-tenant ``replay_batch``
loop under the tenant's own schema (parents/reps on the tenant's
vertices, plus the live-edge set on the wide side, whose pool layout
may legitimately differ). A fleet row that drifted from its replay
twin is a bug, not a datapoint. ``scripts/bench_smoke.sh`` asserts the
bucketed side's sync_per_event AND padded_rows stay strictly below the
single-schema side's.
"""
from __future__ import annotations

import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro import obs
from repro.data.graphs import resolve_graph
from repro.data.streams import STREAMS, StreamBatch
from repro.dynamic.fleet import BucketedFleet, FleetSchema
from repro.dynamic.forest import apply_batch, forest_empty
from repro.dynamic.replay import init_state, replay_batch, stream_capacity
from repro.dynamic.view import CadencePolicy

# (graph, tenants, slots, batch, units) per shape group. The smoke mix
# is the same SHAPE of population as the full mix (many tiny + few
# large) at CI scale.
_SMOKE_MIX = (("chain_64", 8, 4, 8, 6), ("rmat_8", 2, 2, 32, 3))
_FULL_MIX = (("chain_64", 12, 6, 8, 8), ("rmat_14", 2, 2, 64, 4))
_STREAM = "churn"
_CADENCE = CadencePolicy(tour="full", bcc="off", every=2, queries=False)


def _build_groups(mix):
    """Materialize streams + schemas for each shape group in the mix."""
    groups = []
    seed = 0
    for graph, tenants, slots, batch, units in mix:
        g = resolve_graph(graph)
        streams = []
        for _ in range(tenants):
            streams.append(STREAMS[_STREAM](g, batch=batch,
                                            n_batches=units, seed=seed))
            seed += 1
        units = min(units, min(len(s.batches) for s in streams))
        capacity = max(stream_capacity(s) for s in streams)
        groups.append({
            "name": graph,
            "schema": FleetSchema(g.n_nodes, capacity, batch),
            "slots": min(slots, tenants),
            "streams": streams,
            "units": units,
        })
    return groups


def _pad_unit(unit: StreamBatch, n_small: int,
              schema: FleetSchema) -> StreamBatch:
    """Re-shape a narrow tenant's unit to the wide schema's block width.

    The §9 sentinel is the tenant's OWN ``n`` — under the wide schema
    that id is a real vertex, so sentinel entries are remapped to the
    wide ``n`` before padding (an unremapped pad would count as an
    applied event and hook a phantom vertex).
    """
    def pad(a):
        a = np.asarray(a)
        out = np.full(schema.batch, schema.n_nodes, np.int32)
        out[:a.shape[0]] = np.where(a == n_small, schema.n_nodes, a)
        return out
    return StreamBatch(ins_u=pad(unit.ins_u), ins_v=pad(unit.ins_v),
                       del_u=pad(unit.del_u), del_v=pad(unit.del_v))


def _wide_seed(stream, schema: FleetSchema):
    """The tenant's initial live edges as a wide-schema seed forest."""
    state = forest_empty(schema.n_nodes, schema.capacity)
    if stream.init_u.shape[0]:
        no_del = jnp.zeros((schema.capacity,), jnp.bool_)
        state, _ = apply_batch(state, jnp.asarray(stream.init_u),
                               jnp.asarray(stream.init_v), no_del)
    return state


def _oracle(stream, capacity: int, units: int):
    """Independent single-tenant replay under the tenant's own schema."""
    state = init_state(stream, capacity=capacity)
    for i in range(units):
        state, _ = replay_batch(state, stream.batches[i])
    return state


def _tenant_ids(groups):
    return [(f"{grp['name']}.{j}", gi, j)
            for gi, grp in enumerate(groups)
            for j in range(len(grp["streams"]))]


def _run_bucketed(groups):
    bf = BucketedFleet(tempfile.mkdtemp(prefix="t9_bucketed_"))
    for grp in groups:
        bf.add_bucket(grp["schema"], grp["slots"], cadence=_CADENCE,
                      name=grp["name"])
        for j, s in enumerate(grp["streams"]):
            tid = f"{grp['name']}.{j}"
            bf.route(tid, grp["schema"],
                     seed=init_state(s, capacity=grp["schema"].capacity))
            for unit in s.batches[:grp["units"]]:
                bf.offer(tid, unit)
    with obs.SyncLedger() as led:
        bf.run()
        bf.finalize()
    for b in bf.buckets.values():
        jax.block_until_ready(b.manager.fleet.parent)
    # The ledger is the reporting path; the per-bucket counters are the
    # regression oracle — both count the same while_loop carries, and
    # the bucket labels must attribute every record.
    apply_sum = sum(b.sync_apply for b in bf.buckets.values())
    assert led.total("fleet_apply") == apply_sum, \
        (led.total("fleet_apply"), apply_sum)
    assert led.by_bucket("fleet_apply") == {
        b.name: b.sync_apply for b in bf.buckets.values()
        if b.sync_apply}, led.by_bucket("fleet_apply")
    return bf


def _run_single(groups, wide: FleetSchema, n_slots: int):
    bf = BucketedFleet(tempfile.mkdtemp(prefix="t9_single_"))
    bf.add_bucket(wide, n_slots, cadence=_CADENCE, name="single")
    for grp in groups:
        n_small = grp["schema"].n_nodes
        for j, s in enumerate(grp["streams"]):
            tid = f"{grp['name']}.{j}"
            bf.route(tid, wide, seed=_wide_seed(s, wide))
            for unit in s.batches[:grp["units"]]:
                bf.offer(tid, _pad_unit(unit, n_small, wide))
    bf.run()
    bf.finalize()
    jax.block_until_ready(bf.buckets["single"].manager.fleet.parent)
    return bf


def _live_edges(forest) -> set:
    valid = np.asarray(forest.pool_valid)
    src = np.asarray(forest.pool_src)[valid]
    dst = np.asarray(forest.pool_dst)[valid]
    return {(min(int(u), int(v)), max(int(u), int(v)))
            for u, v in zip(src, dst)}


def _assert_equal(groups, bucketed: BucketedFleet, single: BucketedFleet):
    for tid, gi, j in _tenant_ids(groups):
        grp = groups[gi]
        n = grp["schema"].n_nodes
        oracle = _oracle(grp["streams"][j], grp["schema"].capacity,
                         grp["units"])
        own = bucketed.tenant_forest(tid)
        for field in ("parent", "rep", "pool_valid", "tree_mask"):
            assert np.array_equal(np.asarray(getattr(own, field)),
                                  np.asarray(getattr(oracle, field))), \
                f"bucketed/replay divergence: {tid} field {field}"
        wide = single.tenant_forest(tid)
        for field in ("parent", "rep"):
            assert np.array_equal(np.asarray(getattr(wide, field))[:n],
                                  np.asarray(getattr(oracle, field))), \
                f"single-schema/replay divergence: {tid} field {field}"
        assert _live_edges(wide) == _live_edges(oracle), \
            f"single-schema/replay divergence: {tid} live-edge set"


def _measure(run_fn):
    bf = run_fn()             # warm (compile); discarded
    bf.close()
    t0 = time.perf_counter()
    bf = run_fn()
    dt = time.perf_counter() - t0
    return bf, dt


def run(smoke: bool = True) -> list[str]:
    mix = _SMOKE_MIX if smoke else _FULL_MIX
    groups = _build_groups(mix)
    total_tenants = sum(len(g["streams"]) for g in groups)
    mix_tag = "+".join(f"{len(g['streams'])}x{g['name']}" for g in groups)

    # Equal-memory-budget sizing: the wide fleet gets the number of
    # slots the bucketed side's total footprint pays for.
    wide = FleetSchema(
        n_nodes=max(g["schema"].n_nodes for g in groups),
        capacity=max(g["schema"].capacity for g in groups),
        batch=max(g["schema"].batch for g in groups))
    budget = sum(g["slots"] * g["schema"].slot_cost for g in groups)
    n_wide_slots = min(total_tenants, max(1, budget // wide.slot_cost))

    bucketed, t_bucketed = _measure(lambda: _run_bucketed(groups))
    single, t_single = _measure(
        lambda: _run_single(groups, wide, n_wide_slots))

    _assert_equal(groups, bucketed, single)
    events = bucketed.applied_events()
    assert events == single.applied_events(), \
        (events, single.applied_events())

    rows = []
    base = f"table9_buckets/{mix_tag}/T{total_tenants}"
    for label, bf, dt in (("bucketed", bucketed, t_bucketed),
                          ("single_schema", single, t_single)):
        sync = bf.sync_total()
        rows.append(csv_row(
            f"{base}/{label}", dt * 1e6,
            f"events_per_sec={events / max(dt, 1e-9):.0f};"
            f"sync_total={sync};"
            f"sync_per_event={sync / max(events, 1):.4f};"
            f"padded_rows={bf.padded_rows()};"
            f"pad_ratio={bf.padded_events() / max(events, 1):.2f}"))
        bf.close()
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
