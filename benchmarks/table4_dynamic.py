"""Table IV (extension): batch-dynamic maintenance vs from-scratch rebuild.

The paper's tables freeze the graph; this table measures the workload
the batch-dynamic layer (DESIGN.md §9) opens — edge-update streams —
and the comparison the static tables can't express: per batch size, is
*maintaining* the rooted forest (cut + scoped rep update + link loop +
incremental tour refresh) cheaper than *rebuilding* it (GConn + Euler +
full tour numbering on the live graph)?

Rows (median over the paper's 1 + 5 methodology, steady-state batch):

  table4_dynamic/{graph}/{stream}/b{B}/incremental
      one ``dynamic.replay_batch`` + incremental ``refresh_tour``
  table4_dynamic/{graph}/{stream}/b{B}/recompute
      from-scratch ``rooted_spanning_tree`` (gconn_euler) + full
      ``tour_numbering`` over the same live graph

derived: updates/sec at that batch size, link rounds, live edges. Small
batches should favor incremental (touched components ≪ graph); the
crossover batch size is the quantity of interest.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import csv_row, time_fn
from repro.core.euler import tour_numbering
from repro.core.rst import rooted_spanning_tree
from repro.data.graphs import build_suite
from repro.data.streams import STREAMS
from repro.dynamic import init_state, live_graph, refresh_tour, replay_batch

#: streams measured (insert_heavy behaves like sliding_window's insert
#: half; two regimes keep the row count honest).
_STREAM_NAMES = ("sliding_window", "churn")


def _batches_for(n: int) -> tuple[int, ...]:
    return (4, 16) if n <= 1024 else (16, 256)


def _steady_state(stream, warm_batches: int):
    """Advance a few batches so timing sees steady state, not cold start."""
    state = init_state(stream)
    tn = None
    for b in stream.batches[:warm_batches]:
        state, _ = replay_batch(state, b)
    tn, state = refresh_tour(state, tn)
    return state, tn


def run(suite=None) -> list[str]:
    rows = []
    suite = suite or build_suite(["grid_64", "rmat_14"])
    for name, g in suite.items():
        for stream_name in _STREAM_NAMES:
            for batch in _batches_for(g.n_nodes):
                stream = STREAMS[stream_name](g, batch=batch, seed=0,
                                              n_batches=6)
                if len(stream.batches) < 2:
                    continue
                state, tn = _steady_state(stream, len(stream.batches) - 1)
                b = stream.batches[-1]
                events = int((b.ins_u < g.n_nodes).sum()
                             + (b.del_u < g.n_nodes).sum())

                # replay_batch / refresh_tour are functional: timing
                # repeats the same batch from the same pre-state.
                def incr():
                    s2, stats = replay_batch(state, b)
                    tn2, s2 = refresh_tour(s2, tn, incremental=True)
                    return s2.parent, tn2.pre, stats

                parent, _, stats = jax.block_until_ready(incr())
                t_incr = time_fn(lambda: jax.block_until_ready(incr()))

                s_after, _ = replay_batch(state, b)
                lg = live_graph(s_after)
                root = int(np.asarray(s_after.rep)[0])

                def scratch():
                    res = rooted_spanning_tree(lg, root,
                                               method="gconn_euler")
                    tn2 = tour_numbering(res.parent)
                    return res.parent, tn2.pre

                jax.block_until_ready(scratch())
                t_scr = time_fn(lambda: jax.block_until_ready(scratch()))

                live = int(s_after.n_live_edges)
                rounds = int(stats["rounds"])
                base = f"table4_dynamic/{name}/{stream_name}/b{batch}"
                rows.append(csv_row(
                    f"{base}/incremental", t_incr * 1e6,
                    f"updates_per_sec={events / max(t_incr, 1e-9):.0f};"
                    f"rounds={rounds};live={live}"))
                rows.append(csv_row(
                    f"{base}/recompute", t_scr * 1e6,
                    f"updates_per_sec={events / max(t_scr, 1e-9):.0f};"
                    f"live={live}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
