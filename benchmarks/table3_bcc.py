"""Table III (extension): downstream biconnectivity cost per RST flavor.

The paper compares the three RST pipelines in isolation; this table
extends the comparison one level up the stack to the workload the paper
cites as the motivation — Tarjan–Vishkin biconnectivity (``core/bcc.py``,
DESIGN.md §4). Rows:

  table3/{graph}/{flavor} — end-to-end biconnectivity runtime with the
  given ``rst_flavor`` building the spanning tree, plus derived counts
  (n_bcc / articulation points / bridges / rst steps / aux GConn rounds).

Tree shape feeds the downstream cost two ways: the tour numbering ranks
the same 2(n−1) slots regardless, but deeper trees push more work into
the aux-graph GConn pass, and BFS's Θ(diameter) build dominates on
high-diameter graphs — the Fig. 1/Fig. 2 trade-off, measured downstream.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, time_fn
from repro.core.bcc import biconnectivity
from repro.core.rst import METHODS
from repro.data.graphs import build_suite


def run(suite=None) -> list[str]:
    rows = []
    suite = suite or build_suite()
    for name, g in suite.items():
        for flavor in METHODS:
            res = biconnectivity(g, 0, rst_flavor=flavor)
            t = time_fn(biconnectivity, g, 0, rst_flavor=flavor)
            n_art = int(np.asarray(res.articulation).sum())
            n_bridge = int(np.asarray(res.bridge).sum()) // 2
            rows.append(csv_row(
                f"table3/{name}/{flavor}", t * 1e6,
                f"n_bcc={int(res.n_bcc)};n_art={n_art};"
                f"n_bridge={n_bridge};rst_steps={int(res.rst_steps)};"
                f"aux_rounds={int(res.aux_rounds)}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
