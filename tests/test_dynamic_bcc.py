"""Incremental biconnectivity on the dynamic forest (DESIGN.md §10):
networkx-oracle replay across all stream generators, incremental-vs-full
bit-identity, dirty scoping, multigraph tree-mask semantics."""
import numpy as np
import jax.numpy as jnp
import pytest
from numpy.testing import assert_array_equal

from oracles import edge_key as _edge
from oracles import nx_bcc_reference
from repro.core import biconnectivity
from repro.core.graph import Graph
from repro.data import graphs as G
from repro.data.streams import STREAMS
from repro.dynamic import (apply_batch, forest_empty, init_state,
                           live_graph, refresh_bcc, refresh_tour,
                           replay_batch)

#: every DynamicBCC decomposition field (the bit-identity surface).
_FIELDS = ("rep", "low", "high", "articulation", "bridge", "edge_bcc",
           "n_bcc")


def _decompose_dynamic(state, bcc):
    """DynamicBCC → (art set, bridge set, edge partition) over the pool."""
    n = state.n_nodes
    src = np.concatenate([np.asarray(state.pool_src),
                          np.asarray(state.pool_dst)])
    dst = np.concatenate([np.asarray(state.pool_dst),
                          np.asarray(state.pool_src)])
    real = (src < n) & (dst < n)
    art = {v for v in range(n) if bool(np.asarray(bcc.articulation)[v])}
    bridge_mask = np.asarray(bcc.bridge)
    bridges = {_edge(u, v) for u, v, e, ok in
               zip(src, dst, bridge_mask, real) if ok and e}
    labels = np.asarray(bcc.edge_bcc)
    blocks: dict[int, set] = {}
    for u, v, lab, ok in zip(src, dst, labels, real):
        if ok:
            blocks.setdefault(int(lab), set()).add(_edge(u, v))
    partition = frozenset(frozenset(b) for b in blocks.values())
    return art, bridges, partition, int(bcc.n_bcc)


def _assert_oracle(state, bcc, tag):
    """bcc matches networkx AND a from-scratch static biconnectivity."""
    lg = live_graph(state)
    art_ref, bridges_ref, partition_ref = nx_bcc_reference(lg)
    art, bridges, partition, n_bcc = _decompose_dynamic(state, bcc)
    assert art == art_ref, (tag, art ^ art_ref)
    assert bridges == bridges_ref, (tag, bridges ^ bridges_ref)
    assert partition == partition_ref, tag
    assert n_bcc == len(partition_ref), tag

    # The static path on the same live graph agrees mask-for-mask (the
    # streams never create parallel edges, so inferred classification
    # is sound and the slot layouts coincide).
    res = biconnectivity(lg, int(np.asarray(state.rep)[0]),
                         rst_flavor="gconn_euler")
    assert_array_equal(np.asarray(res.articulation),
                       np.asarray(bcc.articulation), err_msg=str(tag))
    assert_array_equal(np.asarray(res.bridge),
                       np.asarray(bcc.bridge), err_msg=str(tag))
    assert int(res.n_bcc) == n_bcc, tag


def _assert_bit_identical(incr, full, tag):
    for field in _FIELDS:
        assert_array_equal(np.asarray(getattr(incr, field)),
                           np.asarray(getattr(full, field)),
                           err_msg=f"{tag}: {field}")


@pytest.mark.parametrize("stream_name", list(STREAMS))
@pytest.mark.parametrize("graph_name", ["grid", "rmat"])
def test_incremental_bcc_matches_oracle_and_full(stream_name, graph_name):
    """Acceptance: replaying any generator, after every refresh the
    maintained decomposition (a) equals a from-scratch full recompute
    bit-for-bit and (b) matches networkx on the live graph."""
    g = G.grid2d(9) if graph_name == "grid" else G.rmat(6, 4, seed=2)
    stream = STREAMS[stream_name](g, batch=12, seed=3, n_batches=8)
    state = init_state(stream)
    tn, state = refresh_tour(state, None)
    bcc = refresh_bcc(state, None, tour=tn)
    for step, b in enumerate(stream.batches):
        state, _ = replay_batch(state, b)
        tn, state = refresh_tour(state, tn)
        bcc = refresh_bcc(state, bcc, tour=tn, incremental=True)
        full = refresh_bcc(state, None, tour=tn, incremental=False)
        tag = f"{stream_name}/{graph_name}@{step}"
        _assert_bit_identical(bcc, full, tag)
        if step % 3 == 2 or step == len(stream.batches) - 1:
            _assert_oracle(state, bcc, tag)


def test_incremental_ablation_flag_is_bit_identical():
    """``incremental=False`` with a cache behaves exactly like no cache
    (the table5 ablation contract)."""
    g = G.grid2d(8)
    stream = STREAMS["churn"](g, batch=16, seed=1, n_batches=4)
    state = init_state(stream)
    tn, state = refresh_tour(state, None)
    bcc = refresh_bcc(state, None, tour=tn)
    for b in stream.batches:
        state, _ = replay_batch(state, b)
        tn, state = refresh_tour(state, tn)
        ablated = refresh_bcc(state, bcc, tour=tn, incremental=False)
        fresh = refresh_bcc(state, None, tour=tn)
        _assert_bit_identical(ablated, fresh, "ablation")
        bcc = ablated


def test_refresh_scoping_leaves_clean_components_cheap():
    """A batch touching one component recomputes only it: dirty_count
    covers that component, and the scoped low/high build is shallower
    than the full one."""
    # Two far-apart triangles; churn only the second.
    edges = ([(0, 1), (1, 2), (2, 0)]
             + [(40 + i, 40 + (i + 1) % 24) for i in range(24)])
    n = 64
    g = Graph.from_numpy_undirected(n, np.asarray(edges))
    st = forest_empty(n, capacity=40)
    iu = jnp.asarray([e[0] for e in edges], jnp.int32)
    iv = jnp.asarray([e[1] for e in edges], jnp.int32)
    st, _ = apply_batch(st, iu, iv, jnp.zeros((40,), jnp.bool_))
    tn, st = refresh_tour(st, None)
    bcc = refresh_bcc(st, None, tour=tn)
    full_syncs = int(bcc.seg_syncs)

    # Insert a chord into the triangle component only.
    st, _ = apply_batch(st, jnp.asarray([0], jnp.int32),
                        jnp.asarray([2], jnp.int32),
                        jnp.zeros((40,), jnp.bool_))
    tn, st = refresh_tour(st, tn)
    bcc2 = refresh_bcc(st, bcc, tour=tn, incremental=True)
    assert int(bcc2.dirty_count) == 3            # just the triangle
    assert int(bcc2.seg_syncs) < full_syncs
    full = refresh_bcc(st, None, tour=tn, incremental=False)
    _assert_bit_identical(bcc2, full, "scoped")


def test_no_op_refresh_is_free_and_stable():
    """Refreshing with zero changes recomputes nothing and returns the
    cached decomposition unchanged."""
    g = G.grid2d(6)
    stream = STREAMS["churn"](g, batch=8, seed=0, n_batches=2)
    state = init_state(stream)
    tn, state = refresh_tour(state, None)
    bcc = refresh_bcc(state, None, tour=tn)
    again = refresh_bcc(state, bcc, tour=tn, incremental=True)
    assert int(again.dirty_count) == 0
    assert int(again.seg_syncs) == 0
    _assert_bit_identical(again, bcc, "noop")


def test_parallel_tree_copy_is_not_a_bridge():
    """Multigraph semantics via the explicit pool tree_mask: a parallel
    copy of a tree edge forms a 2-cycle, so the edge is not a bridge
    (the static inferred-classification path cannot express this)."""
    n = 3
    st = forest_empty(n, capacity=4)
    # Path 0-1-2 plus a duplicate copy of (0, 1).
    iu = jnp.asarray([0, 1, 0], jnp.int32)
    iv = jnp.asarray([1, 2, 1], jnp.int32)
    st, _ = apply_batch(st, iu, iv, jnp.zeros((4,), jnp.bool_))
    assert int(st.n_live_edges) == 3
    assert int(jnp.sum(st.tree_mask.astype(jnp.int32))) == 2
    bcc = refresh_bcc(st, None)
    src = np.concatenate([np.asarray(st.pool_src),
                          np.asarray(st.pool_dst)])
    dst = np.concatenate([np.asarray(st.pool_dst),
                          np.asarray(st.pool_src)])
    bridge = np.asarray(bcc.bridge)
    for e in range(len(src)):
        if src[e] >= n:
            continue
        pair = _edge(src[e], dst[e])
        assert bool(bridge[e]) == (pair == _edge(1, 2)), (e, pair)
    assert int(bcc.n_bcc) == 2                   # {(0,1)×2} and {(1,2)}
    art = np.asarray(bcc.articulation)
    assert art[1] and not art[0] and not art[2]
