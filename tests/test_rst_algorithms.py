"""Deterministic correctness tests for the three RST algorithms."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (Graph, bfs_rst, connected_components, pr_rst,
                        rooted_spanning_tree, tree_depth)
from repro.core.validate import (bfs_depths_reference, components_reference,
                                 validate_rst)
from repro.data import graphs as G

METHODS = ("bfs", "gconn_euler", "pr_rst")


def _check_all_methods(g, root, connected=True):
    for method in METHODS:
        res = rooted_spanning_tree(g, root, method=method)
        v = validate_rst(g, res.parent, root, connected=connected)
        assert v["all_ok"], (method, v)


def test_single_edge():
    g = Graph.from_numpy_undirected(2, np.array([[0, 1]]))
    _check_all_methods(g, 0)
    _check_all_methods(g, 1)


def test_triangle():
    g = Graph.from_numpy_undirected(3, np.array([[0, 1], [1, 2], [2, 0]]))
    _check_all_methods(g, 2)


def test_chain_step_counts():
    """The paper's core claim in miniature: BFS steps = diameter,
    connectivity methods = O(log n)."""
    n = 512
    g = G.chain(n)
    bfs = rooted_spanning_tree(g, 0, method="bfs")
    gce = rooted_spanning_tree(g, 0, method="gconn_euler")
    prr = rooted_spanning_tree(g, 0, method="pr_rst")
    assert int(bfs.steps) == n - 1
    assert int(gce.steps) <= 12          # << diameter
    assert int(prr.steps) <= 12


def test_grid():
    g = G.grid2d(12)
    _check_all_methods(g, 0)
    _check_all_methods(g, 77)


def test_rmat_power_law():
    g = G.rmat(8, edge_factor=8, seed=3)
    _check_all_methods(g, 0)


def test_bfs_distances_match_reference():
    g = G.erdos_renyi(300, avg_degree=6, seed=1)
    root = 17
    _, dist, _ = bfs_rst(g, root)
    ref = bfs_depths_reference(g, root)
    got = np.asarray(dist).astype(np.int64)
    got[got == np.iinfo(np.int32).max] = -1
    assert np.array_equal(got, ref)


def test_connectivity_matches_union_find():
    rng = np.random.default_rng(5)
    edges = np.stack([rng.integers(0, 200, 150),
                      rng.integers(0, 200, 150)], 1)
    g = Graph.from_numpy_undirected(200, edges)
    rep, forest, _ = connected_components(g)
    ref = components_reference(g)
    rep_np = np.asarray(rep)
    for i in range(0, 200, 7):
        for j in range(0, 200, 11):
            assert (rep_np[i] == rep_np[j]) == (ref[i] == ref[j])
    ncomp = len(set(ref.tolist()))
    assert int(np.asarray(forest).sum()) == 200 - ncomp


def test_disconnected_graph():
    edges = np.array([(0, 1), (1, 2), (4, 5)])
    g = Graph.from_numpy_undirected(7, edges)
    for method in ("gconn_euler", "pr_rst"):
        res = rooted_spanning_tree(g, 1, method=method)
        v = validate_rst(g, res.parent, 1, connected=False)
        assert v["all_ok"], (method, v)
        parent = np.asarray(res.parent)
        assert parent[1] == 1            # designated root
        assert parent[3] == 3            # isolated vertex self-rooted
        assert parent[6] == 6            # second-component root exists
    # BFS marks unreachable as -1
    res = rooted_spanning_tree(g, 1, method="bfs")
    parent = np.asarray(res.parent)
    assert parent[1] == 1 and (parent[[3, 4, 5, 6]] == -1).all()


def test_depth_tradeoff_direction():
    """Fig. 2's trade-off: connectivity trees are ≥ as deep as BFS trees."""
    g = G.grid2d(16, seed=0)
    bfs = rooted_spanning_tree(g, 0, method="bfs")
    gce = rooted_spanning_tree(g, 0, method="gconn_euler")
    d_bfs = int(tree_depth(bfs.parent))
    d_gce = int(tree_depth(gce.parent))
    assert d_bfs == int(bfs.steps)
    assert d_gce >= d_bfs                # deeper (or equal), never shallower


def test_rooted_at_requested_root():
    for seed in range(3):
        g = G.erdos_renyi(100, avg_degree=4, seed=seed)
        for method in METHODS:
            root = 41
            res = rooted_spanning_tree(g, root, method=method)
            assert int(res.parent[root]) == root


def test_connectivity_multigraph_honesty():
    """Multigraph regression (parallel edges + self-loops, no dedupe):
    forest_mask never marks two half-edges of one vertex pair, never a
    self-loop, and always exactly n - n_components slots."""
    rng = np.random.default_rng(13)
    for trial in range(8):
        n = int(rng.integers(3, 40))
        m = int(rng.integers(1, 120))
        u = rng.integers(0, n, m)
        v = np.where(rng.random(m) < 0.2, u, rng.integers(0, n, m))  # loops
        dup = rng.integers(0, m, m // 3)                 # parallel copies
        u = np.concatenate([u, u[dup]])
        v = np.concatenate([v, v[dup]])
        for alt in (False, True):
            g = Graph.from_undirected(n, jnp.asarray(u, jnp.int32),
                                      jnp.asarray(v, jnp.int32))
            rep, forest, _ = connected_components(g, alternate_hooking=alt)
            fm = np.asarray(forest)
            src = np.asarray(g.src)
            dst = np.asarray(g.dst)
            marked = [(min(src[e], dst[e]), max(src[e], dst[e]))
                      for e in np.nonzero(fm)[0]]
            ncomp = len(set(components_reference(g).tolist()))
            assert len(marked) == n - ncomp, (trial, alt)
            assert len(marked) == len(set(marked)), (trial, alt, marked)
            assert all(a != b for a, b in marked), (trial, alt, marked)
            # Canonical-half guarantee: winners live in slots [0, M).
            assert (np.nonzero(fm)[0] < g.n_edges).all(), (trial, alt)


def test_use_kernel_paths_agree():
    g = G.erdos_renyi(256, avg_degree=5, seed=9)
    p1, d1, l1 = bfs_rst(g, 3, use_kernel=False)
    p2, d2, l2 = bfs_rst(g, 3, use_kernel=True)
    assert np.array_equal(np.asarray(p1), np.asarray(p2))
    r1, f1, _ = connected_components(g, use_kernel=False)
    r2, f2, _ = connected_components(g, use_kernel=True)
    assert np.array_equal(np.asarray(r1), np.asarray(r2))
    assert np.array_equal(np.asarray(f1), np.asarray(f2))
