"""Differential test harness for the batched tree-query layer (DESIGN.md §12).

Every query op — lca / connected / depth / is_ancestor / subtree_agg /
path_agg / is_bridge / is_articulation — is checked bit-exact against
the slow networkx oracles in ``tests/oracles.py``:

  * statically, on trees from **all three RST flavors** over the
    generator suite (including disconnected graphs, where bfs covers
    only the root component and the oracle sees the same parent array);
  * dynamically, **after every ``apply_batch``** across stream
    generators, including forced cross-component pairs after cuts
    (connected=False, lca=-1 sentinel) and multigraph parallel-edge
    bridge semantics;
  * under a deterministic seeded-numpy property sweep (tier-1 slice +
    the full ``slow``-marked sweep), plus a hypothesis-driven variant
    when hypothesis is installed (profile pinned in conftest.py).

The staleness contract (DynamicForest.version ↔ QuerySession stamp) has
its own regression tests: a query after an un-refreshed pool edit must
recompute or raise — never silently serve stale intervals.
"""
import numpy as np
import jax.numpy as jnp
import pytest

import oracles
from oracles import TreeOracle, edge_key, query_identity
from repro.core import queries as q
from repro.core import rooted_spanning_tree, tour_numbering
from repro.core.graph import Graph
from repro.core.rst import METHODS
from repro.data import graphs as G
from repro.data.streams import STREAMS
from repro.dynamic import (QuerySession, StaleQueryError, apply_batch,
                           edge_slots, forest_empty, init_state, live_graph,
                           refresh_bcc, refresh_tour, replay_batch)

OPS = ("add", "min", "max")


def _pairs(rng, n, k, comp=None):
    """Query-pair sample: random, identical, adjacent, invalid, and —
    when the forest is disconnected — forced cross-component pairs."""
    u = rng.integers(0, n, k).tolist()
    v = rng.integers(0, n, k).tolist()
    w = int(rng.integers(0, n))
    u += [w, 0, n, -1]            # identical pair + invalid ids
    v += [w, n, 0, 2 % n]
    if comp is not None:
        comp = np.asarray(comp)
        labels = np.unique(comp)
        if labels.size >= 2:
            a = np.nonzero(comp == labels[0])[0]
            b = np.nonzero(comp == labels[1])[0]
            for _ in range(4):    # forced cross-component pairs
                u.append(int(rng.choice(a)))
                v.append(int(rng.choice(b)))
    return (np.asarray(u, np.int32), np.asarray(v, np.int32))


def _check_tree_ops(tables, payload, u, v, tag):
    """Every tour-interval op vs the TreeOracle, bit-exact per query."""
    ref = TreeOracle(tables.parent)
    got_lca = np.asarray(q.lca(tables, u, v))
    got_conn = np.asarray(q.connected(tables, u, v))
    got_depth = np.asarray(q.depth_of(tables, u))
    got_anc = np.asarray(q.is_ancestor(tables, u, v))
    sub = {op: np.asarray(q.subtree_agg(tables, u, payload, op))
           for op in OPS}
    pth = {op: np.asarray(q.path_agg(tables, u, v, payload, op))
           for op in OPS}
    for i in range(u.shape[0]):
        a, b = int(u[i]), int(v[i])
        at = (tag, i, a, b)
        assert int(got_lca[i]) == ref.lca(a, b), at
        assert bool(got_conn[i]) == ref.connected(a, b), at
        assert int(got_depth[i]) == ref.depth_of(a), at
        assert bool(got_anc[i]) == ref.is_ancestor(a, b), at
        for op in OPS:
            assert int(sub[op][i]) == ref.subtree_agg(payload, a, op), \
                (at, op)
            assert int(pth[op][i]) == ref.path_agg(payload, a, b, op), \
                (at, op)


def _check_membership(sess, state, rng, tag):
    """is_bridge / is_articulation vs networkx on the live multigraph."""
    nx, nxg = oracles.nx_live_multigraph(live_graph(state))
    bridges = oracles.oracle_bridges(nxg)
    art_ref = oracles.oracle_articulation(nxg)
    n = state.n_nodes
    # Half the pairs from live slots (hits), half random (mostly misses).
    src = np.asarray(state.pool_src)
    dst = np.asarray(state.pool_dst)
    live = np.nonzero((src < n) & (dst < n))[0]
    k = min(12, live.size)
    picks = rng.choice(live, size=k, replace=False) if k else []
    u = [int(src[e]) for e in picks] + rng.integers(0, n, 8).tolist()
    v = [int(dst[e]) for e in picks] + rng.integers(0, n, 8).tolist()
    u, v = np.asarray(u, np.int32), np.asarray(v, np.int32)
    got = np.asarray(sess.is_bridge(state, u, v))
    for i in range(u.shape[0]):
        want = edge_key(u[i], v[i]) in bridges
        assert bool(got[i]) == want, (tag, int(u[i]), int(v[i]))
    verts = np.asarray(rng.integers(0, n, 16), np.int32)
    got_art = np.asarray(sess.is_articulation(state, verts))
    for i, x in enumerate(verts):
        assert bool(got_art[i]) == (int(x) in art_ref), (tag, int(x))


# ------------------------------------------------------------ static trees

STATIC_GRAPHS = {
    "chain": lambda: G.chain(17),
    "grid": lambda: G.grid2d(5),
    "erdos": lambda: G.erdos_renyi(48, avg_degree=3, seed=2),
    "rmat": lambda: G.rmat(5, edge_factor=2, seed=3),
}


@pytest.mark.parametrize("flavor", METHODS)
@pytest.mark.parametrize("graph_name", sorted(STATIC_GRAPHS))
def test_static_queries_match_oracle(flavor, graph_name):
    """All ops on all three flavors' trees match networkx bit-exactly."""
    g = STATIC_GRAPHS[graph_name]()
    res = rooted_spanning_tree(g, 0, method=flavor)
    tn = tour_numbering(res.parent)
    tables = q.build_tables(tn)
    rng = np.random.default_rng(7)
    payload = jnp.asarray(rng.integers(1, 100, g.n_nodes), jnp.int32)
    u, v = _pairs(rng, g.n_nodes, 24, comp=tn.comp)
    _check_tree_ops(tables, payload, u, v, (flavor, graph_name))


def test_lca_goldens():
    """Hand-checkable answers on a star and a path."""
    # Path 0-1-2-3-4 rooted at 0: lca = the closer-to-root endpoint.
    par = jnp.asarray([0, 0, 1, 2, 3], jnp.int32)
    t = q.build_tables(tour_numbering(par))
    assert np.asarray(q.lca(t, jnp.asarray([4, 2, 0]),
                            jnp.asarray([2, 3, 4]))).tolist() == [2, 2, 0]
    assert np.asarray(q.depth_of(t, jnp.arange(5))).tolist() == [
        0, 1, 2, 3, 4]
    # Star rooted at 0: any two distinct leaves meet at the hub.
    par = jnp.asarray([0, 0, 0, 0, 0], jnp.int32)
    t = q.build_tables(tour_numbering(par))
    assert np.asarray(q.lca(t, jnp.asarray([1, 2, 3]),
                            jnp.asarray([2, 3, 3]))).tolist() == [0, 0, 3]


def test_build_tables_sync_accounting():
    """The build pays rank syncs + levels; queries after it pay zero
    (fixed-shape gathers only — nothing to count, the contract table7
    amortizes)."""
    g = G.grid2d(8)
    res = rooted_spanning_tree(g, 0, method="gconn_euler")
    tables = q.build_tables(tour_numbering(res.parent))
    levels = tables.levels
    assert int(tables.build_syncs) >= levels
    assert tables.up.shape == (levels + 1, g.n_nodes)


# --------------------------------------------------------- dynamic replay

def _dyn_case(graph_name):
    return G.grid2d(7) if graph_name == "grid" else G.rmat(5, 4, seed=2)


@pytest.mark.parametrize("stream_name", ["churn", "sliding_window"])
@pytest.mark.parametrize("graph_name", ["grid", "rmat"])
def test_dynamic_queries_match_oracle_every_batch(stream_name, graph_name):
    """After every apply_batch + refresh, the session's answers match
    networkx on the maintained tree AND the live multigraph."""
    g = _dyn_case(graph_name)
    stream = STREAMS[stream_name](g, batch=12, seed=3, n_batches=6)
    state = init_state(stream)
    tn, state = refresh_tour(state, None)
    bcc = refresh_bcc(state, None, tour=tn)
    sess = QuerySession.from_state(state, tn, bcc)
    rng = np.random.default_rng(5)
    payload = jnp.asarray(rng.integers(1, 100, g.n_nodes), jnp.int32)
    for step, b in enumerate(stream.batches):
        state, _ = replay_batch(state, b)
        tn, state = refresh_tour(state, tn)
        bcc = refresh_bcc(state, bcc, tour=tn)
        sess.rebuild(state, tn=tn, bcc=bcc)
        tag = f"{stream_name}/{graph_name}@{step}"
        u, v = _pairs(rng, g.n_nodes, 16, comp=tn.comp)
        _check_tree_ops(sess.tables, payload, u, v, tag)
        if step % 2 == 1 or step == len(stream.batches) - 1:
            _check_membership(sess, state, rng, tag)
    assert sess.builds == len(stream.batches) + 1
    assert sess.stale_served == 0 and sess.auto_refreshes == 0


@pytest.mark.parametrize("flavor", METHODS)
def test_dynamic_snapshots_all_flavors(flavor):
    """Each flavor's tree over evolving live-graph snapshots answers
    queries oracle-exactly (the 3-flavor leg of the dynamic sweep)."""
    g = G.grid2d(6)
    stream = STREAMS["churn"](g, batch=10, seed=1, n_batches=4)
    state = init_state(stream)
    rng = np.random.default_rng(11)
    payload = jnp.asarray(rng.integers(1, 100, g.n_nodes), jnp.int32)
    for step, b in enumerate(stream.batches):
        state, _ = replay_batch(state, b)
        lg = live_graph(state)
        root = int(np.asarray(state.rep)[0])
        res = rooted_spanning_tree(lg, root, method=flavor)
        tables = q.build_tables(tour_numbering(res.parent))
        u, v = _pairs(rng, g.n_nodes, 12, comp=tables.comp)
        _check_tree_ops(tables, payload, u, v, (flavor, step))


def test_cross_component_pairs_after_cut():
    """Severing the only connecting edge flips the query answers: the
    sentinel contract for cross-component pairs."""
    n = 6
    st = forest_empty(n, capacity=8)
    iu = jnp.asarray([0, 1, 2, 3, 4], jnp.int32)
    iv = jnp.asarray([1, 2, 3, 4, 5], jnp.int32)
    st, _ = apply_batch(st, iu, iv, jnp.zeros((8,), jnp.bool_))
    tn, st = refresh_tour(st, None)
    sess = QuerySession.from_state(st, tn)
    assert bool(sess.connected(st, 0, 5)[0])
    assert int(sess.lca(st, 0, 5)[0]) >= 0

    dm, found = edge_slots(st, jnp.asarray([2], jnp.int32),
                           jnp.asarray([3], jnp.int32))
    assert bool(found[0])
    st, stats = apply_batch(st, jnp.zeros((0,), jnp.int32),
                            jnp.zeros((0,), jnp.int32), dm)
    assert int(stats["cuts"]) == 1
    tn, st = refresh_tour(st, tn)
    sess.rebuild(st, tn=tn)
    payload = jnp.ones((n,), jnp.int32)
    assert not bool(sess.connected(st, 0, 5)[0])
    assert int(sess.lca(st, 0, 5)[0]) == -1
    assert int(sess.path_agg(st, 0, 5, payload, "add")[0]) == \
        query_identity("add")
    assert int(sess.path_agg(st, 0, 5, payload, "min")[0]) == \
        query_identity("min")
    # Within each surviving component everything still answers.
    assert bool(sess.connected(st, 0, 2)[0])
    assert int(sess.path_agg(st, 0, 2, payload, "add")[0]) == 3
    assert bool(sess.connected(st, 3, 5)[0])


def test_parallel_edge_bridge_membership():
    """Multigraph semantics: a doubled edge is a cycle, never a bridge —
    and an absent pair answers False, not an error."""
    n = 3
    st = forest_empty(n, capacity=4)
    iu = jnp.asarray([0, 1, 0], jnp.int32)   # path 0-1-2 + copy of (0,1)
    iv = jnp.asarray([1, 2, 1], jnp.int32)
    st, _ = apply_batch(st, iu, iv, jnp.zeros((4,), jnp.bool_))
    tn, st = refresh_tour(st, None)
    bcc = refresh_bcc(st, None, tour=tn)
    sess = QuerySession.from_state(st, tn, bcc)
    got = np.asarray(sess.is_bridge(st, jnp.asarray([0, 1, 0]),
                                    jnp.asarray([1, 2, 2])))
    assert got.tolist() == [False, True, False]
    art = np.asarray(sess.is_articulation(st, jnp.arange(3)))
    assert art.tolist() == [False, True, False]


# ------------------------------------------------------ staleness contract

def test_stale_query_strict_raises():
    """Regression (the staleness hazard): a query after an un-refreshed
    pool edit must raise — even when the edit didn't move the tree."""
    g = G.grid2d(4)
    stream = STREAMS["churn"](g, batch=8, seed=0, n_batches=2)
    state = init_state(stream)
    tn, state = refresh_tour(state, None)
    sess = QuerySession.from_state(state, tn)
    sess.lca(state, 0, 1)                      # fresh: fine
    # Insert a cycle edge: parent may not move, but the pool did — the
    # version bump must still invalidate the session.
    state2, _ = apply_batch(state, jnp.asarray([0], jnp.int32),
                            jnp.asarray([5], jnp.int32),
                            jnp.zeros((state.capacity,), jnp.bool_))
    assert int(state2.version) == int(state.version) + 1
    with pytest.raises(StaleQueryError):
        sess.lca(state2, 0, 1)
    with pytest.raises(StaleQueryError):
        sess.subtree_agg(state2, 0, jnp.ones(g.n_nodes, jnp.int32))
    # The old state still matches the stamp.
    sess.lca(state, 0, 1)


def test_stale_query_refresh_policy_recomputes():
    g = G.grid2d(4)
    stream = STREAMS["churn"](g, batch=8, seed=0, n_batches=3)
    state = init_state(stream)
    tn, state = refresh_tour(state, None)
    bcc = refresh_bcc(state, None, tour=tn)
    sess = QuerySession.from_state(state, tn, bcc, policy="refresh")
    state, _ = replay_batch(state, stream.batches[0])
    got = sess.lca(state, 2, 3)
    assert sess.auto_refreshes == 1 and sess.is_fresh(state)
    tn_full = tour_numbering(state.parent)
    assert int(got[0]) == oracles.oracle_lca(tn_full.parent, 2, 3)
    # BCC labels refreshed too (snapshot-diff would reject stale ones).
    sess.is_bridge(state, 0, 1)


def test_stale_query_stale_policy_serves_and_counts():
    g = G.grid2d(4)
    stream = STREAMS["churn"](g, batch=8, seed=0, n_batches=3)
    state = init_state(stream)
    tn, state = refresh_tour(state, None)
    sess = QuerySession.from_state(state, tn, policy="stale")
    before = np.asarray(sess.lca(state, jnp.arange(4), jnp.arange(1, 5)))
    state2, _ = replay_batch(state, stream.batches[0])
    served = np.asarray(sess.lca(state2, jnp.arange(4), jnp.arange(1, 5)))
    assert sess.stale_served == 1
    assert np.array_equal(before, served)     # frozen view, by design


def test_session_rejects_stale_caches_on_build():
    """The §10 snapshot-diff at construction: somebody else's tn/bcc
    cannot seed a session."""
    g = G.grid2d(4)
    stream = STREAMS["churn"](g, batch=8, seed=0, n_batches=2)
    state = init_state(stream)
    tn, state = refresh_tour(state, None)
    bcc = refresh_bcc(state, None, tour=tn)
    state2, _ = replay_batch(state, stream.batches[0])
    with pytest.raises(ValueError, match="stale TourNumbering"):
        QuerySession.from_state(state2, tn)
    with pytest.raises(ValueError, match="stale DynamicBCC"):
        QuerySession.from_state(
            state2, None, bcc)
    with pytest.raises(ValueError, match="policy"):
        QuerySession.from_state(state, tn, policy="yolo")


def test_bcc_ops_require_bcc():
    g = G.grid2d(3)
    stream = STREAMS["churn"](g, batch=4, seed=0, n_batches=2)
    state = init_state(stream)
    tn, state = refresh_tour(state, None)
    sess = QuerySession.from_state(state, tn)
    with pytest.raises(ValueError, match="without biconnectivity"):
        sess.is_bridge(state, 0, 1)
    with pytest.raises(ValueError, match="without biconnectivity"):
        sess.is_articulation(state, 0)


def test_version_survives_chaos_roundtrip():
    """Injectors copy state through numpy and back; the version stamp
    must survive, or staleness checks silently disarm."""
    from repro.dynamic import inject
    g = G.grid2d(4)
    stream = STREAMS["churn"](g, batch=8, seed=0, n_batches=2)
    state = init_state(stream)
    bad, _, _ = inject("rep_corrupt", state, None, seed=1)
    assert int(bad.version) == int(state.version)


# -------------------------------------------------- property sweeps

def _random_stream_case(seed):
    rng = np.random.default_rng(seed)
    kind = ("grid", "erdos", "rmat")[seed % 3]
    if kind == "grid":
        g = G.grid2d(int(rng.integers(4, 8)))
    elif kind == "erdos":
        g = G.erdos_renyi(int(rng.integers(24, 64)),
                          avg_degree=float(rng.uniform(2, 4)), seed=seed)
    else:
        g = G.rmat(int(rng.integers(4, 6)), edge_factor=3, seed=seed)
    name = sorted(STREAMS)[seed % len(STREAMS)]
    stream = STREAMS[name](g, batch=int(rng.integers(6, 16)), seed=seed,
                           n_batches=4)
    return g, stream


def _sweep_one(seed, n_batches_checked):
    g, stream = _random_stream_case(seed)
    state = init_state(stream)
    tn, state = refresh_tour(state, None)
    bcc = refresh_bcc(state, None, tour=tn)
    sess = QuerySession.from_state(state, tn, bcc)
    rng = np.random.default_rng(seed + 1)
    payload = jnp.asarray(rng.integers(1, 100, g.n_nodes), jnp.int32)
    for step, b in enumerate(stream.batches[:n_batches_checked]):
        state, _ = replay_batch(state, b)
        tn, state = refresh_tour(state, tn)
        bcc = refresh_bcc(state, bcc, tour=tn)
        sess.rebuild(state, tn=tn, bcc=bcc)
        u, v = _pairs(rng, g.n_nodes, 12, comp=tn.comp)
        _check_tree_ops(sess.tables, payload, u, v, (seed, step))
        _check_membership(sess, state, rng, (seed, step))


@pytest.mark.parametrize("seed", [0, 1])
def test_property_sweep_tier1_slice(seed):
    """Deterministic seeded sweep — the tier-1 slice of the full
    property suite (runs with or without hypothesis installed)."""
    _sweep_one(seed, n_batches_checked=2)


@pytest.mark.slow
@pytest.mark.parametrize("seed", list(range(12)))
def test_property_sweep_full(seed):
    """The full sweep: every stream generator × graph family × seed,
    every batch checked (scripts/test_full.sh)."""
    _sweep_one(seed, n_batches_checked=4)


@pytest.mark.slow
def test_property_sweep_hypothesis():
    """Hypothesis-driven variant (skipped when hypothesis is absent;
    profile pinned deterministic in conftest.py)."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(seed=st.integers(min_value=0, max_value=10_000))
    def run(seed):
        g, stream = _random_stream_case(seed % 64)
        state = init_state(stream)
        state, _ = replay_batch(state, stream.batches[0])
        tn, state = refresh_tour(state, None)
        tables = q.build_tables(tn)
        rng = np.random.default_rng(seed)
        payload = jnp.asarray(rng.integers(1, 100, g.n_nodes), jnp.int32)
        u, v = _pairs(rng, g.n_nodes, 8, comp=tn.comp)
        _check_tree_ops(tables, payload, u, v, seed)

    run()
