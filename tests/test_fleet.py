"""Multi-tenant fleet (DESIGN.md §13): replay equivalence vs independent
single-tenant loops, evict/re-admit bit-identity, dispatcher/manager
contracts, per-tenant staleness policies, the unified ServeConfig
schema, and the ForestView refresh surface."""
import dataclasses

import numpy as np
import pytest
from numpy.testing import assert_array_equal

from repro.core import queries as q
from repro.core.queries import build_tables
from repro.data import graphs as G
from repro.data.streams import STREAMS, StreamBatch
from repro.dynamic.bcc import refresh_bcc
from repro.dynamic.fleet import (FleetDispatcher, FleetManager,
                                 FleetQuerySession, apply_batches,
                                 fleet_empty, fleet_sync_cost,
                                 refresh_bccs, refresh_tours, tenant_slice)
from repro.dynamic.forest import forest_empty
from repro.dynamic.queries import StaleQueryError
from repro.dynamic.replay import init_state, replay_batch, stream_capacity
from repro.dynamic.tour import refresh_tour
from repro.dynamic.view import (CadencePolicy, ForestView,
                                refresh_bcc_once, refresh_tour_once)
from repro.launch.config import FleetConfig, ServeConfig

_T = 3          # tenants in the equivalence fleets
_CADENCE = 2    # mid-run incremental refresh cadence


def _streams(g, stream_name, batch=16, n=4):
    kw = {"batch": batch, "seed": 0}
    if stream_name == "sliding_window":
        kw["window"] = 2
    if stream_name == "churn":
        kw["n_batches"] = n
    return [STREAMS[stream_name](g, **{**kw, "seed": t})
            for t in range(_T)]


def _tick_block(streams, i):
    return tuple(np.stack([np.asarray(getattr(s.batches[i], f))
                           for s in streams])
                 for f in ("ins_u", "ins_v", "del_u", "del_v"))


def _assert_forest_equal(fleet, t, state, tag=""):
    for field in ("parent", "rep", "pool_src", "pool_dst", "pool_valid",
                  "tree_mask", "dirty", "version"):
        assert_array_equal(
            np.asarray(getattr(fleet.tenant(t), field)),
            np.asarray(getattr(state, field)),
            err_msg=f"{tag}: tenant {t} field {field}")


def _assert_tree_equal(stacked, t, single, tag=""):
    import jax
    a = jax.tree_util.tree_leaves(tenant_slice(stacked, t))
    b = jax.tree_util.tree_leaves(single)
    assert len(a) == len(b)
    for i, (x, y) in enumerate(zip(a, b)):
        assert_array_equal(np.asarray(x), np.asarray(y),
                           err_msg=f"{tag}: tenant {t} leaf {i}")


@pytest.mark.parametrize("stream_name", sorted(STREAMS))
def test_fleet_replay_matches_independent_loops(stream_name):
    """T-tenant vmapped fleet == T single-tenant loops, bit for bit:
    forests, tour numberings, BCC labels, and query answers — with
    cadenced incremental refreshes interleaved mid-run on both sides."""
    g = G.grid2d(8)
    streams = _streams(g, stream_name)
    steps = min(4, min(len(s.batches) for s in streams))
    assert steps >= 2
    capacity = max(stream_capacity(s) for s in streams)

    # Fleet side: one (T, B) block per tick, vmapped refreshes.
    fleet = fleet_empty(_T, g.n_nodes, capacity)
    for t, s in enumerate(streams):
        fleet = fleet.set_tenant(t, init_state(s, capacity=capacity))
    tn_f = None
    sync_fleet = 0
    sync_seq_equiv = 0
    for i in range(steps):
        fleet, stats = apply_batches(fleet, *_tick_block(streams, i))
        sync_fleet += fleet_sync_cost(stats)
        sync_seq_equiv += int(np.asarray(stats["rounds"]).sum()) + _T
        if (i + 1) % _CADENCE == 0:
            tn_f, fleet = refresh_tours(fleet, tn_f)
    tn_f, fleet = refresh_tours(fleet, tn_f)
    bcc_f = refresh_bccs(fleet, tour=tn_f, incremental=False)

    # Sequential reference: per-tenant replay with the same cadence.
    for t, s in enumerate(streams):
        state = init_state(s, capacity=capacity)
        tn = None
        for i in range(steps):
            state, _ = replay_batch(state, s.batches[i])
            if (i + 1) % _CADENCE == 0:
                tn, state = refresh_tour(state, tn)
        tn, state = refresh_tour(state, tn)
        bcc = refresh_bcc(state, tour=tn, incremental=False)

        _assert_forest_equal(fleet, t, state, stream_name)
        _assert_tree_equal(tn_f, t, tn, f"{stream_name}/tour")
        _assert_tree_equal(bcc_f, t, bcc, f"{stream_name}/bcc")

        # Query answers through the fleet session == core-op oracle.
        sess = FleetQuerySession.from_fleet(fleet, tn_f, bcc_f,
                                            policy="strict")
        tab = build_tables(tn)
        rng = np.random.default_rng(7 * (t + 1))
        u = rng.integers(0, g.n_nodes, 32).astype(np.int32)
        v = rng.integers(0, g.n_nodes, 32).astype(np.int32)
        assert_array_equal(np.asarray(sess.connected(fleet, t, u, v)),
                           np.asarray(q.connected(tab, u, v)))
        assert_array_equal(np.asarray(sess.lca(fleet, t, u, v)),
                           np.asarray(q.lca(tab, u, v)))
        assert_array_equal(np.asarray(sess.depth(fleet, t, v)),
                           np.asarray(q.depth_of(tab, v)))

    # The §13 headline must hold on this workload: one vmapped tick
    # bills max+1 checks, not sum+T.
    assert sync_fleet < sync_seq_equiv


def test_evict_readmit_replay_equivalence(tmp_path):
    """3 tenants rotating through 2 slots: every tenant's final forest is
    bit-identical to replaying its unit sequence alone, even though each
    was evicted (checkpoint) and re-admitted (restore) mid-history."""
    g = G.grid2d(8)
    n = g.n_nodes
    batch = 16
    streams = _streams(g, "churn", batch=batch, n=4)
    capacity = max(stream_capacity(s) for s in streams)

    # Per-tenant unit sequences: init edges as insert-only units, then
    # the stream batches — every event rides the dispatcher.
    units = {t: [] for t in range(_T)}
    for t, s in enumerate(streams):
        for off in range(0, s.init_u.shape[0], batch):
            iu = np.full(batch, n, np.int32)
            iv = np.full(batch, n, np.int32)
            chunk = s.init_u[off:off + batch]
            iu[:chunk.shape[0]] = chunk
            iv[:chunk.shape[0]] = s.init_v[off:off + batch]
            units[t].append(StreamBatch(
                ins_u=iu, ins_v=iv, del_u=np.full(batch, n, np.int32),
                del_v=np.full(batch, n, np.int32)))
        units[t].extend(s.batches)

    manager = FleetManager(fleet_empty(2, n, capacity), tmp_path)
    dispatcher = FleetDispatcher(n, batch)
    for t, seq in units.items():
        for b in seq:
            dispatcher.offer(t, b)

    tick = 0
    while dispatcher.pending():
        waiting = [t for t in range(_T) if dispatcher.pending(t)]
        # Rotate admission so tenants keep displacing each other — the
        # serve_fleet loop's first-come policy would never restore.
        rot = tick % max(len(waiting), 1)
        for t in (waiting[rot:] + waiting[:rot])[:2]:
            manager.ensure(t)
        block, served = dispatcher.tick(manager.tenant_at)
        manager.fleet, _ = apply_batches(manager.fleet, *block)
        manager.note_applied(served)
        tick += 1

    assert manager.evictions > 0
    assert manager.restores > 0, \
        "rotation never exercised the checkpoint-restore path"

    for t, seq in units.items():
        assert manager.cursors[t] == len(seq)
        slot = manager.ensure(t)
        state = forest_empty(n, capacity)
        for b in seq:
            state, _ = replay_batch(state, b)
        _assert_forest_equal(manager.fleet, slot, state, "evict/readmit")


# -- dispatcher ---------------------------------------------------------------

def test_dispatcher_units_are_atomic_and_fifo():
    n, b = 16, 4
    d = FleetDispatcher(n, b)
    mk = lambda lo: StreamBatch(
        ins_u=np.arange(lo, lo + b, dtype=np.int32) % n,
        ins_v=(np.arange(lo, lo + b, dtype=np.int32) + 1) % n,
        del_u=np.full(b, n, np.int32), del_v=np.full(b, n, np.int32))
    first, second = mk(0), mk(8)
    d.offer("a", first)
    d.offer("a", second)
    for expect in (first, second):
        (iu, iv, _du, _dv), served = d.tick(["a", None])
        assert_array_equal(np.asarray(iu[0]), expect.ins_u)
        assert_array_equal(np.asarray(iv[0]), expect.ins_v)
        # Empty slot rows are all-sentinel (inert under apply_batches).
        assert np.all(np.asarray(iu[1]) == n)
        assert served == {"a": b}
    assert d.pending() == 0
    (iu, _, du, _), served = d.tick(["a", None])
    assert served == {} and np.all(np.asarray(iu) == n)
    assert np.all(np.asarray(du) == n)


def test_dispatcher_rejects_wrong_shape():
    d = FleetDispatcher(16, 4)
    bad = StreamBatch(ins_u=np.zeros(8, np.int32),
                      ins_v=np.zeros(8, np.int32),
                      del_u=np.zeros(8, np.int32),
                      del_v=np.zeros(8, np.int32))
    with pytest.raises(ValueError, match="fixed-shape"):
        d.offer("a", bad)


# -- manager ------------------------------------------------------------------

def test_manager_lru_eviction_order(tmp_path):
    manager = FleetManager(fleet_empty(2, 16, 8), tmp_path)
    assert manager.ensure("a") == 0
    assert manager.ensure("b") == 1
    manager.touch("a")                      # b is now least-recently-used
    slot_c = manager.ensure("c")
    assert slot_c == 1 and "b" not in manager.slot_of
    assert manager.evictions == 1 and manager.restores == 0
    # b returns via the restore path, displacing the LRU resident (a).
    slot_b = manager.ensure("b")
    assert slot_b == 0 and manager.restores == 1
    assert manager.tenant_at == ["b", "c"]


# -- per-tenant staleness policies --------------------------------------------

def _two_tenant_fleet():
    g = G.grid2d(4)
    streams = [STREAMS["churn"](g, batch=8, n_batches=3, seed=t)
               for t in range(2)]
    capacity = max(stream_capacity(s) for s in streams)
    fleet = fleet_empty(2, g.n_nodes, capacity)
    for t, s in enumerate(streams):
        fleet = fleet.set_tenant(t, init_state(s, capacity=capacity))
    return fleet, streams, g.n_nodes


def test_fleet_session_policies_per_tenant():
    fleet, streams, n = _two_tenant_fleet()
    sess = FleetQuerySession.from_fleet(fleet, policy=("strict", "stale"))
    u = np.arange(4, dtype=np.int32)
    sess.connected(fleet, 0, u, u)          # fresh: fine on both
    sess.connected(fleet, 1, u, u)

    fleet, _ = apply_batches(fleet, *_tick_block(streams, 0))
    with pytest.raises(StaleQueryError):
        sess.connected(fleet, 0, u, u)
    sess.connected(fleet, 1, u, u)          # stale lane serves + counts
    assert sess.sync_stats(1)["stale_served"] == 1
    assert sess.sync_stats(0)["stale_served"] == 0


def test_fleet_session_refresh_rebuilds_one_lane():
    fleet, streams, n = _two_tenant_fleet()
    sess = FleetQuerySession.from_fleet(fleet, policy="refresh")
    fleet, _ = apply_batches(fleet, *_tick_block(streams, 0))
    u = np.arange(n, dtype=np.int32)
    out = np.asarray(sess.connected(fleet, 0, u, u))
    assert out.all()                        # v~v, answered post-rebuild
    assert sess.sync_stats(0)["auto_refreshes"] == 1
    assert sess.sync_stats(1)["auto_refreshes"] == 0
    assert sess.is_fresh(fleet, 0) and not sess.is_fresh(fleet, 1)
    # The rebuilt lane now matches a from-scratch single-tenant oracle.
    from repro.core.euler import tour_numbering
    tab = build_tables(tour_numbering(fleet.parent[0]))
    rng = np.random.default_rng(3)
    a = rng.integers(0, n, 16).astype(np.int32)
    b = rng.integers(0, n, 16).astype(np.int32)
    assert_array_equal(np.asarray(sess.lca(fleet, 0, a, b)),
                       np.asarray(q.lca(tab, a, b)))


def test_fleet_session_rejects_bad_policy():
    fleet, _, _ = _two_tenant_fleet()
    with pytest.raises(ValueError, match="policy"):
        FleetQuerySession.from_fleet(fleet, policy="yolo")
    with pytest.raises(ValueError, match="policies"):
        FleetQuerySession.from_fleet(fleet, policy=("strict",) * 3)


# -- fleet container contracts ------------------------------------------------

def test_set_tenant_rejects_schema_mismatch():
    fleet = fleet_empty(2, 16, 8)
    with pytest.raises(ValueError, match="n_nodes"):
        fleet.set_tenant(0, forest_empty(32, 8))
    with pytest.raises(ValueError, match="capacity"):
        fleet.set_tenant(0, forest_empty(16, 4))


def test_clear_tenant_roundtrip():
    fleet, _, n = _two_tenant_fleet()
    assert bool(fleet.active[0]) and bool(fleet.active[1])
    cleared = fleet.clear_tenant(0)
    assert not bool(cleared.active[0]) and bool(cleared.active[1])
    _assert_forest_equal(cleared, 0, forest_empty(n, fleet.capacity))
    # Lane 1 untouched by the clear.
    _assert_forest_equal(cleared, 1, fleet.tenant(1))


# -- ServeConfig / FleetConfig (the unified CLI schema) -----------------------

def _parse(argv):
    import argparse
    ap = argparse.ArgumentParser()
    ServeConfig.add_args(ap)
    return ServeConfig.from_args(ap.parse_args(argv))


def test_serve_config_roundtrip_and_defaults():
    cfg = _parse([])
    assert cfg == ServeConfig()             # flag defaults == schema defaults
    cfg = _parse(["--graph", "chain_4k", "--stream", "sliding_window",
                  "--batch", "32", "--steps", "7", "--window", "3",
                  "--tour", "full", "--tour-every", "2", "--bcc",
                  "incremental", "--read-ratio", "0.25", "--read-batch",
                  "16", "--query-staleness", "refresh", "--chaos",
                  "drop_edges", "--audit-every", "4", "--ckpt-every", "5",
                  "--validate"])
    assert ServeConfig.from_dict(cfg.to_dict()) == cfg
    assert cfg.stream_kwargs() == {"batch": 32, "seed": 0, "window": 3}
    pol = cfg.cadence()
    assert isinstance(pol, CadencePolicy)
    assert (pol.tour, pol.bcc, pol.every) == ("full", "incremental", 2)
    assert pol.queries and pol.staleness == "refresh"


def test_serve_config_check_rejects_bad_combos():
    with pytest.raises(ValueError, match="read-ratio"):
        dataclasses.replace(
            _parse(["--read-ratio", "1.5"]),).check()
    with pytest.raises(ValueError, match="tour maintenance"):
        _parse(["--read-ratio", "0.5", "--tour", "off"]).check()
    assert _parse(["--read-ratio", "0.5"]).check()


def test_serve_config_injector_names():
    assert _parse([]).injector_names(("a", "b")) == ()
    assert _parse(["--chaos", "all"]).injector_names(("a", "b")) == \
        ("a", "b")
    assert _parse(["--chaos", "b,a"]).injector_names(("a", "b")) == \
        ("b", "a")
    with pytest.raises(ValueError, match="unknown injector"):
        _parse(["--chaos", "nope"]).injector_names(("a", "b"))


def test_fleet_config_binding():
    import argparse
    ap = argparse.ArgumentParser()
    FleetConfig.add_args(ap)
    fcfg = FleetConfig.from_args(ap.parse_args(
        ["--tenants", "6", "--slots", "2"]))
    assert fcfg == FleetConfig(tenants=6, slots=2)
    with pytest.raises(ValueError):
        FleetConfig(tenants=0).check()


# -- ForestView / CadencePolicy (the unified refresh surface) -----------------

def test_cadence_policy_due_and_validation():
    pol = CadencePolicy(every=4)
    assert [pol.due(s) for s in range(8)] == \
        [False, False, False, True, False, False, False, True]
    assert pol.due(None)                    # forced is always due
    assert not CadencePolicy(every=0).due(3)
    assert CadencePolicy(every=0).due(None)
    with pytest.raises(ValueError):
        CadencePolicy(tour="sometimes")
    with pytest.raises(ValueError):
        CadencePolicy(staleness="fresh-ish")


def _one_tenant_state():
    g = G.grid2d(4)
    s = STREAMS["churn"](g, batch=8, n_batches=4, seed=0)
    return init_state(s), s


def test_deprecated_wrappers_match_canonical():
    state, s = _one_tenant_state()
    state, _ = replay_batch(state, s.batches[0])
    tn_a, st_a = refresh_tour(state, None)
    tn_b, st_b = refresh_tour_once(state, None)
    _assert_tree_equal_flat(tn_a, tn_b)
    assert_array_equal(np.asarray(st_a.dirty), np.asarray(st_b.dirty))
    assert_array_equal(np.asarray(refresh_bcc(state, tour=tn_a).edge_bcc),
                       np.asarray(refresh_bcc_once(state,
                                                   tour=tn_b).edge_bcc))


def _assert_tree_equal_flat(a, b):
    import jax
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        assert_array_equal(np.asarray(x), np.asarray(y))


def test_forest_view_cadence_and_prime():
    state, s = _one_tenant_state()
    view = ForestView(CadencePolicy(tour="incremental", bcc="full",
                                    every=2))
    state = view.prime(state)
    assert view.tn is not None and view.bcc is not None
    tn0 = view.tn
    state, _ = replay_batch(state, s.batches[0])
    state = view.refresh(state, step=0)     # off-cadence: untouched
    assert view.tn is tn0
    state, _ = replay_batch(state, s.batches[1])
    state = view.refresh(state, step=1)     # (1+1) % 2 == 0: refreshed
    assert view.tn is not tn0
    assert not np.asarray(state.dirty).any()
    assert len(view.tour_lat) == 2 and len(view.bcc_lat) == 2
    # Per-call override: force queries only, tour/bcc skipped.
    tn1 = view.tn
    view.refresh(state, tour=False, bcc=False, queries=True)
    assert view.tn is tn1 and view.session is not None


def test_forest_view_session_adoption_carries_counters():
    state, s = _one_tenant_state()
    view = ForestView(CadencePolicy(tour="incremental", every=1,
                                    queries=True, staleness="stale"))
    state = view.prime(state)
    sess0 = view.adopt_session(state)
    assert view.adopt_session(state) is sess0   # same tn → same session
    sess0.stale_served += 3
    state, _ = replay_batch(state, s.batches[0])
    state = view.refresh(state, step=0)         # new tn → re-adoption
    sess1 = view.session
    assert sess1 is not sess0
    assert sess1.stale_served == 3              # counters carried over
    assert sess1.builds >= sess0.builds


def test_forest_view_bcc_only_policy_still_primes_tour():
    state, _ = _one_tenant_state()
    view = ForestView(CadencePolicy(tour="off", bcc="full"))
    view.prime(state)
    assert view.tn is not None and view.bcc is not None


# -- serving entry points (smoke, tiny monkeypatched graph) -------------------

@pytest.fixture
def tiny_suite(monkeypatch):
    from repro.data.graphs import SUITE
    monkeypatch.setitem(SUITE, "tiny_grid8",
                        (G.grid2d, dict(side=8), "tiny test graph"))
    return "tiny_grid8"


def test_serve_stream_report_handles_zero_sample_ops(tiny_suite, capsys):
    """Regression: ops the read mix never reached must report 'no
    samples' instead of np.percentile crashing on an empty list."""
    from repro.launch import serve_stream
    serve_stream.main(["--graph", tiny_suite, "--stream", "churn",
                       "--batch", "16", "--steps", "4", "--tour-every",
                       "2", "--read-ratio", "0.05", "--read-batch", "64",
                       "--seed", "1"])
    out = capsys.readouterr().out
    assert "no samples" in out
    assert "Traceback" not in out


def test_serve_fleet_end_to_end(tiny_suite, tmp_path, capsys):
    from repro.launch import serve_fleet
    serve_fleet.main(["--graph", tiny_suite, "--stream", "churn",
                      "--batch", "16", "--steps", "3", "--tenants", "3",
                      "--slots", "2", "--tour-every", "2", "--bcc",
                      "incremental", "--read-ratio", "0.3",
                      "--read-batch", "8", "--evict-dir", str(tmp_path),
                      "--validate"])
    out = capsys.readouterr().out
    assert "sync accounting: fleet=" in out
    assert out.count("partition==from-scratch: True") == 3
    assert "evictions" in out
