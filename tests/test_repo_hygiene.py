"""Repo hygiene guards: no build artifacts in the tree, and .gitignore
keeps covering the artifact patterns so they can't sneak back in."""
import pathlib
import shutil
import subprocess

import pytest

_ROOT = pathlib.Path(__file__).resolve().parent.parent

# Patterns .gitignore must carry — dropping one silently reopens the
# door to committed bytecode/caches.
_REQUIRED_IGNORES = ("__pycache__/", "*.pyc", ".pytest_cache/",
                     "artifacts/")


def _tracked_files():
    if shutil.which("git") is None:
        pytest.skip("git not available")
    proc = subprocess.run(["git", "ls-files"], cwd=_ROOT,
                          capture_output=True, text=True)
    if proc.returncode != 0:
        pytest.skip(f"not a git checkout: {proc.stderr.strip()}")
    return proc.stdout.splitlines()


def test_no_bytecode_or_caches_tracked():
    offenders = [f for f in _tracked_files()
                 if "__pycache__" in f or f.endswith((".pyc", ".pyo"))
                 or ".pytest_cache" in f]
    assert not offenders, \
        f"build artifacts tracked in git: {offenders[:10]}"


def test_gitignore_covers_artifact_patterns():
    gitignore = (_ROOT / ".gitignore").read_text().splitlines()
    patterns = {line.strip() for line in gitignore
                if line.strip() and not line.startswith("#")}
    missing = [p for p in _REQUIRED_IGNORES if p not in patterns]
    assert not missing, f".gitignore lost required patterns: {missing}"
