"""Optimizer, schedules, gradient compression, data pipeline tests."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.optim.adamw import adamw_init, adamw_update, clip_by_global_norm
from repro.optim.schedule import wsd_schedule, cosine_schedule
from repro.optim.compression import (compress_int8, decompress_int8,
                                     compress_with_error_feedback)


def test_adamw_converges_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(params)
    for _ in range(300):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, opt, _ = adamw_update(g, opt, lr=0.05, weight_decay=0.0,
                                      compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_clip_global_norm():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == 200.0
    np.testing.assert_allclose(
        float(jnp.linalg.norm(clipped["a"])), 1.0, rtol=1e-5)


def test_wsd_phases():
    lr = lambda s: float(wsd_schedule(jnp.int32(s), peak_lr=1.0, warmup=10,
                                      stable=20, decay=10))
    assert lr(5) == 0.5               # warmup
    assert lr(15) == 1.0              # stable
    assert lr(25) == 1.0
    assert 0.1 <= lr(35) < 1.0        # decay
    np.testing.assert_allclose(lr(40), 0.1, rtol=1e-5)


def test_cosine_monotone_decay():
    vals = [float(cosine_schedule(jnp.int32(s), peak_lr=1.0, warmup=5,
                                  total=50)) for s in range(5, 50, 5)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_int8_roundtrip_accuracy():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(10_000), jnp.float32)
    q, s, pad = compress_int8(x)
    y = decompress_int8(q, s, pad, x.shape)
    rel = float(jnp.linalg.norm(x - y) / jnp.linalg.norm(x))
    assert rel < 0.01                 # blockwise int8 ≈ 0.4% error
    assert q.dtype == jnp.int8


def test_error_feedback_reduces_bias():
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal(512), jnp.float32) * 1e-3
    res = jnp.zeros_like(g)
    acc_plain = jnp.zeros_like(g)
    acc_ef = jnp.zeros_like(g)
    for _ in range(50):
        q, s, pad = compress_int8(g)
        acc_plain = acc_plain + decompress_int8(q, s, pad, g.shape)
        deq, res = compress_with_error_feedback(g, res)
        acc_ef = acc_ef + deq
    true = g * 50
    err_ef = float(jnp.linalg.norm(acc_ef - true))
    # error feedback keeps accumulated error bounded (≤ one quantization step)
    assert err_ef <= float(jnp.linalg.norm(acc_plain - true)) + 1e-5


def test_graph_generators_connected():
    from repro.data import graphs as G
    from repro.core.validate import components_reference
    for g in [G.chain(50), G.grid2d(8), G.erdos_renyi(100, 4, 1),
              G.rmat(7, 4, 2), G.pref_attach(100, 3, 3)]:
        ref = components_reference(g)
        assert len(set(ref.tolist())) == 1, "generator must yield connected"


def test_neighbor_sampler_fanout():
    from repro.data import graphs as G
    from repro.core.graph import build_csr
    from repro.data.gnn_batch import neighbor_sample
    import numpy as np
    g = G.erdos_renyi(500, avg_degree=10, seed=4)
    row_ptr, col, _ = build_csr(g)
    seeds = np.arange(8)
    nodes, s, d = neighbor_sample(np.asarray(row_ptr), np.asarray(col),
                                  seeds, [5, 3], seed=0)
    assert (nodes[:8] == seeds).all()
    assert len(s) <= 8 * 5 + 8 * 5 * 3
    assert len(s) == len(d)
    assert s.max() < len(nodes) and d.max() < len(nodes)


def test_triplet_builder():
    from repro.data.gnn_batch import build_triplets
    # path 0-1-2 both directions: edges (0→1),(1→2),(1→0),(2→1)
    src = np.asarray([0, 1, 1, 2])
    dst = np.asarray([1, 2, 0, 1])
    ti, to = build_triplets(src, dst, 3, 8)
    e = 4
    valid = [(a, b) for a, b in zip(ti.tolist(), to.tolist()) if a < e]
    for kin, eout in valid:
        # (k→j) followed by (j→i): dst of in == src of out, no backtrack
        assert dst[kin] == src[eout]
        assert src[kin] != dst[eout]
    assert len(valid) == 2  # (0→1,1→2) and (2→1,1→0)


def test_rst_reorder_perm():
    from repro.data.gnn_batch import reorder_by_rst
    from repro.data import graphs as G
    g = G.erdos_renyi(64, 4, 7)
    perm = reorder_by_rst(np.asarray(g.src), np.asarray(g.dst), 64)
    assert sorted(perm.tolist()) == list(range(64))
