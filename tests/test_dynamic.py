"""Batch-dynamic forest: stream equivalence vs from-scratch, invariants,
incremental tour refresh, multiset deletion resolution."""
import numpy as np
import jax.numpy as jnp
import pytest
from numpy.testing import assert_array_equal

from repro.core.compress import roots_of
from repro.core.euler import tour_numbering
from repro.core.graph import Graph
from repro.core.rst import rooted_spanning_tree
from repro.core.validate import components_reference, validate_rst
from repro.data import graphs as G
from repro.data.streams import STREAMS
from repro.dynamic import (apply_batch, edge_slots, forest_empty,
                           forest_from_graph, init_state, live_graph,
                           refresh_tour, replay_batch)


def _partitions_equal(rep_a, rep_b, n, stride=1):
    """Label-agnostic partition equality via canonical first-member maps."""
    canon_a, canon_b = {}, {}
    for v in range(0, n, stride):
        ka, kb = int(rep_a[v]), int(rep_b[v])
        if (ka in canon_a) != (kb in canon_b):
            return False
        if ka in canon_a:
            if canon_a[ka] != canon_b[kb]:
                return False
        else:
            canon_a[ka] = v
            canon_b[kb] = v
    return True


def _check_state(state, live_pairs, tag=""):
    """Full oracle check: invariants + equivalence with a rebuilt tree."""
    n = state.n_nodes
    parent = np.asarray(state.parent)
    rep = np.asarray(state.rep)

    # rep == roots_of(parent): the incremental-representative invariant.
    assert_array_equal(rep, np.asarray(roots_of(state.parent)),
                       err_msg=f"{tag}: rep invariant")

    # Oracle graph from the python-side live multiset (no sentinel pad —
    # the numpy union-find walks every edge row).
    og = Graph.from_undirected(
        n, np.asarray([e[0] for e in live_pairs], np.int32),
        np.asarray([e[1] for e in live_pairs], np.int32))
    ref = components_reference(og) if live_pairs else np.arange(n)
    assert _partitions_equal(rep, ref, n), f"{tag}: component partition"

    # Forest validity on the live graph (root of vertex 0's component).
    lg = live_graph(state)
    root = int(rep[0])
    v = validate_rst(lg, parent, root, connected=False)
    assert v["all_ok"], (tag, v)

    # Tree-edge bookkeeping: exactly n - n_components marked slots.
    ncomp = len(set(ref.tolist())) if live_pairs else n
    assert int(np.asarray(state.tree_mask).sum()) == n - ncomp, tag

    # Acceptance: spans the same components as a from-scratch build.
    scratch = rooted_spanning_tree(lg, root, method="gconn_euler")
    rep_s = np.asarray(roots_of(scratch.parent))
    assert _partitions_equal(rep, rep_s, n), f"{tag}: vs from-scratch"


def _live_oracle(stream):
    """Replay the stream's batches over a python multiset."""
    n = stream.n_nodes
    live = [(int(a), int(b))
            for a, b in zip(stream.init_u, stream.init_v)]
    for b in stream.batches:
        for a, c in zip(b.del_u, b.del_v):
            if a < n:
                key = (int(a), int(c))
                if key in live:
                    live.remove(key)
                else:
                    live.remove((int(c), int(a)))
        for a, c in zip(b.ins_u, b.ins_v):
            if a < n:
                live.append((int(a), int(c)))
        yield live


@pytest.mark.parametrize("stream_name", list(STREAMS))
@pytest.mark.parametrize("graph_name", ["grid", "rmat"])
def test_stream_equivalence(stream_name, graph_name):
    """Acceptance: after any batch sequence from any generator, the
    maintained parent spans the same components as a from-scratch build
    on the final live graph."""
    g = G.grid2d(12) if graph_name == "grid" else G.rmat(7, 4, seed=2)
    stream = STREAMS[stream_name](g, batch=16, seed=3, n_batches=8)
    state = init_state(stream)
    oracle = _live_oracle(stream)
    for step, b in enumerate(stream.batches):
        state, stats = replay_batch(state, b)
        live = next(oracle)
        assert int(stats["overflow"]) == 0
        if step % 3 == 2 or step == len(stream.batches) - 1:
            _check_state(state, live, f"{stream_name}/{graph_name}@{step}")


def test_insertions_from_empty_match_reference():
    """Pure-insert replay from the empty forest tracks union-find."""
    rng = np.random.default_rng(11)
    n = 80
    st = forest_empty(n, capacity=128)
    edges = []
    for step in range(8):
        iu = rng.integers(0, n, 8).astype(np.int32)
        iv = rng.integers(0, n, 8).astype(np.int32)
        st, _ = apply_batch(st, jnp.asarray(iu), jnp.asarray(iv),
                            jnp.zeros((128,), jnp.bool_))
        edges += [(int(a), int(b)) for a, b in zip(iu, iv) if a != b]
        _check_state(st, edges, f"insert@{step}")


def test_tree_edge_deletion_finds_replacement():
    """Deleting a tree edge on a cycle keeps the component connected."""
    n = 6
    ring = [(i, (i + 1) % n) for i in range(n)]
    g = Graph.from_numpy_undirected(n, np.asarray(ring))
    st = forest_from_graph(g, capacity=n + 2)
    tree_slots = np.nonzero(np.asarray(st.tree_mask))[0]
    # Delete one tree edge: the remaining ring edge must replace it.
    du = np.asarray([int(np.asarray(st.pool_src)[tree_slots[0]])], np.int32)
    dv = np.asarray([int(np.asarray(st.pool_dst)[tree_slots[0]])], np.int32)
    dmask, found = edge_slots(st, jnp.asarray(du), jnp.asarray(dv))
    assert bool(np.asarray(found)[0])
    st, stats = apply_batch(st, jnp.full(1, n, jnp.int32),
                            jnp.full(1, n, jnp.int32), dmask)
    assert int(stats["cuts"]) == 1
    assert int(stats["links"]) == 1              # replacement found
    assert int(st.n_components) == 1
    _check_state(st, ring[1:], "ring-delete")

    # Delete a second edge: the ring is now a path; cutting disconnects.
    live = [(int(a), int(b)) for a, b in
            zip(np.asarray(st.pool_src), np.asarray(st.pool_dst))
            if a < n]
    dmask2, found2 = edge_slots(
        st, jnp.asarray([live[0][0]], jnp.int32),
        jnp.asarray([live[0][1]], jnp.int32))
    assert bool(np.asarray(found2)[0])
    st, stats = apply_batch(st, jnp.full(1, n, jnp.int32),
                            jnp.full(1, n, jnp.int32), dmask2)
    assert int(st.n_components) == 2
    _check_state(st, live[1:], "path-delete")


def test_forest_from_graph_matches_static():
    g = G.erdos_renyi(200, avg_degree=4, seed=5)
    st = forest_from_graph(g, capacity=g.n_edges)
    live = [(int(a), int(b)) for a, b in
            zip(np.asarray(g.src[:g.n_edges]), np.asarray(g.dst[:g.n_edges]))]
    _check_state(st, live, "from_graph")
    # Connected suite graph, default root 0 ⇒ rooted at the request.
    assert int(np.asarray(st.parent)[0]) == 0
    assert (np.asarray(st.rep) == 0).all()


def test_edge_slots_multiset_resolution():
    """k delete requests for one pair claim k distinct parallel copies."""
    n = 10
    st = forest_empty(n, capacity=8)
    # Insert three parallel (2, 7) copies and one (1, 2).
    iu = jnp.asarray([2, 7, 2, 1, n, n], jnp.int32)
    iv = jnp.asarray([7, 2, 7, 2, n, n], jnp.int32)
    st, _ = apply_batch(st, iu, iv, jnp.zeros((8,), jnp.bool_))
    assert int(st.n_live_edges) == 4

    du = jnp.asarray([7, 2, 2, 2], jnp.int32)   # (7,2) ×1 + (2,7) ×3
    dv = jnp.asarray([2, 7, 7, 7], jnp.int32)
    dmask, found = edge_slots(st, du, dv)
    # Only three parallel copies exist: 3 found, 1 not, distinct slots.
    assert int(np.asarray(found).sum()) == 3
    assert int(np.asarray(dmask).sum()) == 3
    st, stats = apply_batch(st, jnp.full(4, n, jnp.int32),
                            jnp.full(4, n, jnp.int32), dmask)
    # (1, 2) survives; 2 and 7 are now disconnected.
    rep = np.asarray(st.rep)
    assert rep[1] == rep[2] and rep[2] != rep[7]


def test_delete_nonexistent_is_noop():
    g = G.grid2d(5)
    st = forest_from_graph(g, capacity=g.n_edges + 4)
    dmask, found = edge_slots(st, jnp.asarray([0, 3], jnp.int32),
                              jnp.asarray([24, 3], jnp.int32))
    assert not bool(np.asarray(found).any())     # non-edge + self-loop
    st2, stats = apply_batch(st, jnp.full(2, 25, jnp.int32),
                             jnp.full(2, 25, jnp.int32), dmask)
    assert int(stats["cuts"]) == 0
    assert_array_equal(np.asarray(st2.parent), np.asarray(st.parent))


def test_default_capacity_has_insert_headroom():
    """Regression: a default-capacity forest absorbs a full insert-only
    batch without overflow (the old default of exactly M overflowed on
    the first insertion)."""
    g = G.grid2d(6)
    st = forest_from_graph(g)                    # default capacity
    assert st.capacity >= g.n_edges + 64         # 4 * batch_hint floor
    stream = STREAMS["insert_heavy"](g, batch=16, seed=0, n_batches=1)
    b = stream.batches[0]
    no_del = jnp.zeros((st.capacity,), jnp.bool_)
    st, stats = apply_batch(st, jnp.asarray(b.ins_u),
                            jnp.asarray(b.ins_v), no_del)
    assert int(stats["overflow"]) == 0
    # Explicit zero-headroom capacity still overflows — the knob works.
    tight = forest_from_graph(g, capacity=g.n_edges)
    no_del = jnp.zeros((tight.capacity,), jnp.bool_)
    _, stats = apply_batch(tight, jnp.asarray(b.ins_u),
                           jnp.asarray(b.ins_v), no_del)
    assert int(stats["overflow"]) == int((b.ins_u < g.n_nodes).sum())


def test_pool_overflow_is_counted():
    st = forest_empty(4, capacity=2)
    iu = jnp.asarray([0, 1, 2], jnp.int32)
    iv = jnp.asarray([1, 2, 3], jnp.int32)
    st, stats = apply_batch(st, iu, iv, jnp.zeros((2,), jnp.bool_))
    assert int(stats["overflow"]) == 1
    assert int(st.n_live_edges) == 2


@pytest.mark.parametrize("stream_name", ["sliding_window", "churn"])
def test_incremental_tour_matches_full(stream_name):
    """Acceptance: the dirty-component refresh is bit-identical to a full
    ``tour_numbering`` recompute after every refresh."""
    g = G.grid2d(9)
    stream = STREAMS[stream_name](g, batch=12, seed=7, n_batches=9)
    state = init_state(stream)
    tn, state = refresh_tour(state, None)
    for step, b in enumerate(stream.batches):
        state, _ = replay_batch(state, b)
        if step % 2 == 1:
            tn, state = refresh_tour(state, tn, incremental=True)
            full = tour_numbering(state.parent)
            for field in ("pre", "size", "last", "comp"):
                assert_array_equal(
                    np.asarray(getattr(tn, field)),
                    np.asarray(getattr(full, field)),
                    err_msg=f"{stream_name}@{step}: {field}")
            assert not bool(np.asarray(state.dirty).any())


def test_dirty_marks_are_component_closed_and_scoped():
    """A batch touching one component leaves others clean."""
    # Two separate triangles; update only the second.
    edges = np.asarray([(0, 1), (1, 2), (2, 0), (3, 4), (4, 5), (5, 3)])
    g = Graph.from_numpy_undirected(6, edges)
    st = forest_from_graph(g, capacity=8)
    _, st = refresh_tour(st, None)
    dmask, found = edge_slots(st, jnp.asarray([3], jnp.int32),
                              jnp.asarray([4], jnp.int32))
    assert bool(np.asarray(found)[0])
    st, _ = apply_batch(st, jnp.full(1, 6, jnp.int32),
                        jnp.full(1, 6, jnp.int32), dmask)
    dirty = np.asarray(st.dirty)
    assert not dirty[[0, 1, 2]].any()            # first triangle untouched
    assert dirty[[3, 4, 5]].all()                # whole touched component


def test_stream_generators_shapes_and_conservation():
    """Batches have fixed shapes; deletes only reference live edges."""
    g = G.grid2d(8)
    n = g.n_nodes
    for name, gen in STREAMS.items():
        stream = gen(g, batch=16, seed=0, n_batches=5)
        live = {(int(a), int(b))
                for a, b in zip(stream.init_u, stream.init_v)}
        for b in stream.batches:
            assert b.ins_u.shape == (16,) and b.del_u.shape == (16,)
            for a, c in zip(b.del_u, b.del_v):
                if a < n:
                    pair = (int(a), int(c))
                    assert pair in live or pair[::-1] in live, (name, pair)
                    live.discard(pair)
                    live.discard(pair[::-1])
            for a, c in zip(b.ins_u, b.ins_v):
                if a < n:
                    live.add((int(a), int(c)))
        assert stream.n_events > 0
