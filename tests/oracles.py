"""Shared networkx reference oracles for the test suite.

One home for the exact-reference helpers that were previously duplicated
across test_bcc / test_dynamic_bcc / test_chaos_recovery, plus the tree
*query* oracles test_queries.py checks the batched query layer against.
Everything here is deliberately slow-and-obviously-correct python/networkx;
the library under test must match it bit-for-bit.
"""
import numpy as np
import pytest


def edge_key(u, v):
    """Unordered edge identity."""
    return frozenset((int(u), int(v)))


def require_nx():
    return pytest.importorskip("networkx")


# ---------------------------------------------------------------------------
# graph builders
# ---------------------------------------------------------------------------

def nx_simple_graph(g):
    """``core.graph.Graph`` → nx.Graph (sentinel-padding + self-loop aware)."""
    nx = require_nx()
    nxg = nx.Graph()
    nxg.add_nodes_from(range(g.n_nodes))
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    real = (src < g.n_nodes) & (dst < g.n_nodes)
    nxg.add_edges_from((int(u), int(v)) for u, v, ok in
                       zip(src, dst, real) if ok and u != v)
    return nxg


def nx_live_multigraph(lg):
    """``live_graph(state)`` → (nx, nx.MultiGraph) over the pool slots.

    MultiGraph: streams can re-insert a live edge, and a doubled edge is
    a cycle (never a bridge) — a simple Graph would collapse it.
    ``live_graph`` symmetrizes (both directions); one slot = first half.
    """
    nx = require_nx()
    nxg = nx.MultiGraph()
    nxg.add_nodes_from(range(lg.n_nodes))
    src = np.asarray(lg.src)[: len(lg.src) // 2]
    dst = np.asarray(lg.dst)[: len(lg.dst) // 2]
    real = (src < lg.n_nodes) & (dst < lg.n_nodes)
    nxg.add_edges_from((int(u), int(v)) for u, v, ok in
                       zip(src, dst, real) if ok and u != v)
    return nx, nxg


def nx_forest(parent):
    """Self-rooted parent array → (nx, DiGraph parent→child, root set)."""
    nx = require_nx()
    parent = np.asarray(parent)
    t = nx.DiGraph()
    t.add_nodes_from(range(parent.shape[0]))
    roots = set()
    for v in range(parent.shape[0]):
        if int(parent[v]) == v:
            roots.add(v)
        else:
            t.add_edge(int(parent[v]), v)
    return nx, t, roots


# ---------------------------------------------------------------------------
# biconnectivity reference
# ---------------------------------------------------------------------------

def nx_bcc_reference(g):
    """(articulation set, bridge set, edge partition) via networkx."""
    nx = require_nx()
    nxg = nx_simple_graph(g)
    art = set(nx.articulation_points(nxg))
    bridges = {edge_key(u, v) for u, v in nx.bridges(nxg)}
    partition = frozenset(
        frozenset(edge_key(u, v) for u, v in comp)
        for comp in nx.biconnected_component_edges(nxg))
    return art, bridges, partition


# ---------------------------------------------------------------------------
# partitions
# ---------------------------------------------------------------------------

def canonical_partition(rep):
    """Order-of-first-appearance canonical labels — partition identity."""
    rep = np.asarray(rep)
    _, first, inverse = np.unique(rep, return_index=True,
                                  return_inverse=True)
    return np.argsort(np.argsort(first))[inverse]


# ---------------------------------------------------------------------------
# tree-query oracles (the differential reference for core/dynamic queries)
# ---------------------------------------------------------------------------

_IDENTITY = {"add": 0,
             "min": np.iinfo(np.int32).max,
             "max": np.iinfo(np.int32).min}
_FOLD = {"add": lambda a, b: a + b, "min": min, "max": max}


def query_identity(op):
    """The combine identity ``core.queries`` returns for empty/invalid."""
    return _IDENTITY[op]


class TreeOracle:
    """Prebuilt networkx reference for one rooted forest.

    Answers every op of the batched query layer (``core.queries``) the
    slow, obviously-correct way — against the *same* parent array the
    library built its tables from, so answers must be bit-exact. Ids
    outside [0, n) (the padding sentinel) get each op's failure value,
    matching the library contract.
    """

    def __init__(self, parent):
        self.parent = np.asarray(parent)
        self.n = self.parent.shape[0]
        self.nx, self.t, self.roots = nx_forest(self.parent)
        self.und = self.t.to_undirected()
        self.depths = np.full(self.n, -1, np.int64)
        for r in self.roots:
            for v, d in self.nx.single_source_shortest_path_length(
                    self.t, r).items():
                self.depths[v] = d

    def _ok(self, *vs):
        return all(0 <= int(v) < self.n for v in vs)

    def lca(self, u, v):
        if not self._ok(u, v):
            return -1
        w = self.nx.lowest_common_ancestor(self.t, int(u), int(v),
                                           default=None)
        return -1 if w is None else int(w)

    def connected(self, u, v):
        return (self._ok(u, v)
                and self.nx.has_path(self.und, int(u), int(v)))

    def depth_of(self, v):
        return int(self.depths[int(v)]) if self._ok(v) else -1

    def is_ancestor(self, a, x):
        if not self._ok(a, x):
            return False
        return (int(a) == int(x)
                or int(x) in self.nx.descendants(self.t, int(a)))

    def subtree_agg(self, payload, v, op="add"):
        if not self._ok(v):
            return query_identity(op)
        payload = np.asarray(payload)
        acc = query_identity(op)
        for x in (set(self.nx.descendants(self.t, int(v))) | {int(v)}):
            acc = _FOLD[op](acc, int(payload[x]))
        return acc

    def path_agg(self, payload, u, v, op="add"):
        if not self.connected(u, v):
            return query_identity(op)
        payload = np.asarray(payload)
        acc = query_identity(op)
        for x in self.nx.shortest_path(self.und, int(u), int(v)):
            acc = _FOLD[op](acc, int(payload[x]))
        return acc


def oracle_lca(parent, u, v):
    """One-shot LCA in the rooted forest; -1 across trees."""
    return TreeOracle(parent).lca(u, v)


def oracle_depths(parent):
    """int depth per vertex: BFS from every root of the parent DiGraph."""
    return TreeOracle(parent).depths


def oracle_bridges(nxg):
    """Bridge edge-key set of a (Multi)Graph — parallel-edge aware."""
    nx = require_nx()
    return {edge_key(u, v) for u, v in nx.bridges(nxg)}


def oracle_articulation(nxg):
    nx = require_nx()
    return set(nx.articulation_points(nxg))
