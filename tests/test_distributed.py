"""Multi-device tests (subprocess: needs 8 fake host devices, which must be
set before jax initializes — the main test process keeps 1 device)."""
import json
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import Graph
from repro.core.distributed import distributed_cc_spanning_forest
from repro.core.validate import components_reference
from repro.data.graphs import grid2d, rmat

out = {}

# --- distributed connectivity + spanning forest --------------------------
from repro.launch.mesh import auto_axis_kwargs

mesh = jax.make_mesh((8,), ("data",), **auto_axis_kwargs(1))
run = distributed_cc_spanning_forest(mesh, "data")
for name, g in [("grid", grid2d(20)), ("rmat", rmat(9, 4, seed=2))]:
    m2 = g.n_half_edges
    pad = -m2 % 8
    src = jnp.concatenate([g.src, jnp.zeros(pad, jnp.int32)])
    dst = jnp.concatenate([g.dst, jnp.zeros(pad, jnp.int32)])
    rep, forest, rounds = run(src, dst, n_nodes=g.n_nodes)
    ref = components_reference(g)
    ncomp = len(set(ref.tolist()))
    rep_np = np.asarray(rep)
    part_ok = True
    rng = np.random.default_rng(0)
    for i, j in rng.integers(0, g.n_nodes, (500, 2)):
        if (rep_np[i] == rep_np[j]) != (ref[i] == ref[j]):
            part_ok = False
    out[name] = dict(part_ok=part_ok,
                     forest=int(np.asarray(forest).sum()),
                     expected=g.n_nodes - ncomp,
                     rounds=int(rounds))

# --- sharded smoke train step (2x4 mesh, LM smoke config) ----------------
import dataclasses as dc
from repro.configs import get_arch
from repro.train.step import build_cell
from repro.models import transformer as tfm
from repro.optim.adamw import adamw_init
from repro.launch.train import SMOKE_SHAPES, synthetic_batches

mesh2 = jax.make_mesh((2, 4), ("data", "model"), **auto_axis_kwargs(2))
spec = get_arch("qwen3-1.7b")
cfg = spec.make_smoke_config()
shape = dict(SMOKE_SHAPES["lm"])
spec = dc.replace(spec, shapes={"smoke": shape})
step_fn, state_abs, _ = build_cell(spec, "smoke", mesh2, smoke=True)
params = tfm.init_params(cfg, jax.random.key(0))
state = {"params": params, "opt": adamw_init(params)}
_, batch = next(synthetic_batches(spec, shape, cfg))
# jax.set_mesh is post-0.4.x; the Mesh context manager is the equivalent
# pjit-era spelling for establishing the ambient mesh.
with getattr(jax, "set_mesh", lambda m: m)(mesh2):
    new_state, metrics = jax.jit(step_fn)(state, batch)
out["sharded_train"] = dict(loss=float(metrics["loss"]),
                            finite=bool(jnp.isfinite(metrics["loss"])))
print("RESULT:" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def multi_device_results():
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        timeout=600, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                          "HOME": "/root"})
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")]
    assert line, proc.stdout
    return json.loads(line[0][len("RESULT:"):])


def test_distributed_connectivity_partition(multi_device_results):
    for name in ("grid", "rmat"):
        r = multi_device_results[name]
        assert r["part_ok"], r
        assert r["forest"] == r["expected"], r
        assert r["rounds"] <= 20


def test_sharded_train_step(multi_device_results):
    r = multi_device_results["sharded_train"]
    assert r["finite"], r
