"""Unified pointer-compression engine: equivalence, sync bounds, PR-RST
incremental-representative regression."""
import math

import numpy as np
import jax.numpy as jnp
import pytest
from numpy.testing import assert_array_equal

from repro.core.compress import (DEFAULT_JUMPS, compress_full,
                                 compress_scoped, jump_k, rank_to_root,
                                 reduce_to_root, roots_of, segment_reduce,
                                 segment_reduce_scoped, wyllie_rank)

rng = np.random.default_rng(7)


def naive_compress(p: np.ndarray) -> np.ndarray:
    """The seed's per-hop loop: p = p[p] until fixpoint (numpy oracle)."""
    p = p.copy()
    while (p[p] != p).any():
        p = p[p]
    return p


def naive_depths(p: np.ndarray) -> np.ndarray:
    d = np.zeros(p.shape[0], np.int64)
    for v in range(p.shape[0]):
        x = v
        while p[x] != x:
            x = p[x]
            d[v] += 1
    return d


def _forests(n=1000):
    """Parent forests covering the engine's edge cases."""
    ids = np.arange(n)
    chain = np.maximum(ids - 1, 0).astype(np.int32)
    star = np.zeros(n, np.int32)
    self_loops = ids.astype(np.int32)
    random_forest = np.where(ids == 0, 0,
                             rng.integers(0, np.maximum(ids, 1))).astype(np.int32)
    # Padded tail: forest in the first half, inert self-pointing pad after.
    padded = random_forest.copy()
    padded[n // 2:] = ids[n // 2:]
    return {"chain": chain, "star": star, "self_loops": self_loops,
            "random_forest": random_forest, "padded_tail": padded}


@pytest.mark.parametrize("case", list(_forests(8)))
@pytest.mark.parametrize("k", [1, 2, 5])
def test_compress_full_matches_naive(case, k):
    p_np = _forests(1000)[case]
    p = jnp.asarray(p_np)
    expect = naive_compress(p_np)
    assert_array_equal(np.asarray(compress_full(p, n_jumps=k)), expect)
    assert_array_equal(np.asarray(roots_of(p, n_jumps=k)), expect)


@pytest.mark.parametrize("case", ["chain", "random_forest", "padded_tail"])
def test_compress_full_kernel_matches_naive(case):
    # Non-tile-multiple sizes exercise the hoisted padding.
    for n in (129, 1025):
        p_np = _forests(n)[case]
        expect = naive_compress(p_np)
        out = compress_full(jnp.asarray(p_np), use_kernel=True)
        assert_array_equal(np.asarray(out), expect)


@pytest.mark.parametrize("k", [1, 3, 5])
def test_jump_k_is_k_doubling_steps(k):
    p_np = _forests(1000)["random_forest"]
    expect = p_np.copy()
    for _ in range(k):
        expect = expect[expect]
    assert_array_equal(np.asarray(jump_k(jnp.asarray(p_np), k)), expect)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_sync_count_bound(use_kernel):
    """Acceptance: ≤ ⌈log2(depth)/k⌉ + 1 convergence syncs, both paths."""
    n = 4096
    k = DEFAULT_JUMPS
    chain = jnp.asarray(np.maximum(np.arange(n) - 1, 0), jnp.int32)
    out, syncs = compress_full(chain, use_kernel=use_kernel,
                               return_syncs=True)
    assert (np.asarray(out) == 0).all()
    bound = math.ceil(math.log2(n - 1) / k) + 1
    assert int(syncs) <= bound, (int(syncs), bound)
    # Amortization is real: the per-hop (k=1) loop needs ~k× more syncs.
    _, syncs_perhop = compress_full(chain, n_jumps=1, return_syncs=True)
    assert int(syncs) < int(syncs_perhop)


def test_compress_already_converged_costs_one_sync():
    p = jnp.arange(512, dtype=jnp.int32)
    out, syncs = compress_full(p, return_syncs=True)
    assert_array_equal(np.asarray(out), np.arange(512))
    assert int(syncs) == 1


def test_rank_to_root_matches_naive():
    for case, p_np in _forests(700).items():
        depth, root = rank_to_root(jnp.asarray(p_np))
        assert_array_equal(np.asarray(depth), naive_depths(p_np), err_msg=case)
        assert_array_equal(np.asarray(root), naive_compress(p_np),
                           err_msg=case)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_wyllie_rank_counts_syncs(use_kernel):
    n = 1024
    perm = rng.permutation(n)
    succ = np.full(n, -1, np.int32)
    for a, b in zip(perm[:-1], perm[1:]):
        succ[a] = b
    d, syncs = wyllie_rank(jnp.asarray(succ), jnp.ones(n, bool),
                           use_kernel=use_kernel, return_syncs=True)
    expect = np.empty(n, np.int64)
    expect[perm] = n - 1 - np.arange(n)
    assert_array_equal(np.asarray(d), expect)
    assert 0 < int(syncs) <= math.ceil(math.log2(n) / DEFAULT_JUMPS) + 1


def test_reaches_root_rejects_cycles():
    from repro.core.validate import reaches_root
    # 0↔1 is an even cycle (collapses to spurious fixed points under
    # doubling), 3→4→5→3 an odd cycle (never converges); 2 is a root and
    # 6 hangs off it; -1 marks an unreachable vertex (treated as root).
    parent = jnp.asarray([1, 0, 2, 4, 5, 3, 2, -1], jnp.int32)
    got = np.asarray(reaches_root(parent))
    assert_array_equal(got, [False, False, True, False, False, False,
                             True, True])


@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("n", [2, 129, 2000])
def test_wyllie_rank_random_list(use_kernel, n):
    perm = rng.permutation(n)
    succ = np.full(n, -1, np.int32)
    for a, b in zip(perm[:-1], perm[1:]):
        succ[a] = b
    d = wyllie_rank(jnp.asarray(succ), jnp.ones(n, bool),
                    use_kernel=use_kernel)
    expect = np.empty(n, np.int64)
    expect[perm] = n - 1 - np.arange(n)
    assert_array_equal(np.asarray(d), expect)


def _path_to_root(p: np.ndarray, v: int) -> list[int]:
    path = [v]
    while p[path[-1]] != path[-1]:
        path.append(int(p[path[-1]]))
    return path


@pytest.mark.parametrize("case", ["chain", "star", "self_loops",
                                  "random_forest", "padded_tail"])
@pytest.mark.parametrize("op", ["min", "max"])
def test_reduce_to_root_idempotent_ops(case, op):
    """Payload-reduce doubling: red[v] = op over v's root path, inclusive."""
    p_np = _forests(257)[case]
    payload = rng.integers(-100, 100, p_np.shape[0]).astype(np.int32)
    red, root = reduce_to_root(jnp.asarray(p_np), jnp.asarray(payload), op)
    npop = np.min if op == "min" else np.max
    for v in range(0, p_np.shape[0], 13):
        path = _path_to_root(p_np, v)
        assert int(red[v]) == npop(payload[path]), (v, path)
        assert int(root[v]) == path[-1]


@pytest.mark.parametrize("n_jumps", [1, 3, DEFAULT_JUMPS])
def test_rank_to_root_routes_through_reduce_to_root(n_jumps):
    p_np = _forests(500)["random_forest"]
    depth, root, syncs = rank_to_root(jnp.asarray(p_np), n_jumps=n_jumps,
                                      return_syncs=True)
    assert_array_equal(np.asarray(depth), naive_depths(p_np))
    assert_array_equal(np.asarray(root), naive_compress(p_np))
    max_depth = int(naive_depths(p_np).max())
    assert int(syncs) <= math.ceil(math.log2(max(max_depth, 2)) / n_jumps) + 1


@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("op", ["min", "max"])
@pytest.mark.parametrize("n", [1, 2, 64, 257])
def test_segment_reduce_matches_numpy(op, n, use_kernel):
    values = rng.integers(-1000, 1000, n).astype(np.int32)
    lo = rng.integers(0, n, 4 * n).astype(np.int32)
    hi = np.asarray([rng.integers(l, n) for l in lo], np.int32)
    out = segment_reduce(jnp.asarray(values), jnp.asarray(lo),
                         jnp.asarray(hi), op, use_kernel=use_kernel)
    npop = np.min if op == "min" else np.max
    expect = np.asarray([npop(values[l:h + 1]) for l, h in zip(lo, hi)])
    assert_array_equal(np.asarray(out), expect)


@pytest.mark.parametrize("op", ["min", "max"])
@pytest.mark.parametrize("n", [5, 129, 1025, 2000])
def test_segment_table_kernel_matches_ref(op, n):
    """The Pallas sparse-table build equals the jnp oracle, every level
    (non-tile-multiple sizes exercise the identity padding contract)."""
    from repro.kernels.segment_table.ops import segment_table
    from repro.kernels.segment_table.ref import segment_table_ref

    values = jnp.asarray(rng.integers(-1000, 1000, n).astype(np.int32))
    levels = max(1, (n - 1).bit_length())
    tab = segment_table(values, levels=levels, op=op)
    ref = segment_table_ref(values, levels=levels, op=op)
    assert tab.shape == (levels + 1, n)
    assert_array_equal(np.asarray(tab), np.asarray(ref))


@pytest.mark.parametrize("op", ["min", "max"])
def test_segment_reduce_boundary_windows(op):
    """Suffix queries near n exercise the off-the-end fold on both paths."""
    n = 130                                     # just past one (8,128) tile
    values = rng.integers(-50, 50, n).astype(np.int32)
    lo = jnp.asarray([0, n - 1, n - 2, 1], jnp.int32)
    hi = jnp.asarray([n - 1, n - 1, n - 1, n - 2], jnp.int32)
    npop = np.min if op == "min" else np.max
    expect = np.asarray([npop(values[l:h + 1])
                         for l, h in zip(np.asarray(lo), np.asarray(hi))])
    for use_kernel in (False, True):
        out = segment_reduce(jnp.asarray(values), lo, hi, op,
                             use_kernel=use_kernel)
        assert_array_equal(np.asarray(out), expect, err_msg=str(use_kernel))


@pytest.mark.parametrize("use_kernel", [False, True])
def test_compress_scoped_matches_full_on_active(use_kernel):
    """Scoped compression equals full compression on component-closed
    masks and freezes everything else to identity."""
    p_np = _forests(600)["random_forest"]
    full = naive_compress(p_np)
    # Component-closed mask: activate the components of roots 0..9.
    active = np.isin(full, np.arange(10))
    out = np.asarray(compress_scoped(jnp.asarray(p_np),
                                     jnp.asarray(active),
                                     use_kernel=use_kernel))
    assert_array_equal(out[active], full[active])
    assert_array_equal(out[~active], np.arange(600)[~active])


def test_compress_scoped_sync_count_is_scoped():
    """Syncs track the *active* sub-forest depth, not the global one."""
    n = 2048
    ids = np.arange(n)
    chain = np.maximum(ids - 1, 0).astype(np.int32)  # depth n-1 chain
    # Activate only the depth-≤3 prefix at the root end (closed under p).
    active = np.zeros(n, bool)
    active[:4] = True
    _, syncs_scoped = compress_scoped(jnp.asarray(chain),
                                      jnp.asarray(active),
                                      return_syncs=True)
    _, syncs_full = compress_full(jnp.asarray(chain), return_syncs=True)
    assert int(syncs_scoped) < int(syncs_full)
    assert int(syncs_scoped) <= 2


def test_segment_reduce_rejects_non_idempotent_op():
    v = jnp.zeros((4,), jnp.int32)
    with pytest.raises(ValueError, match="idempotent"):
        segment_reduce(v, v[:1], v[:1], "add")
    with pytest.raises(ValueError, match="idempotent"):
        segment_reduce_scoped(v, v[:1], v[:1], jnp.ones((1,), bool), "add")


@pytest.mark.parametrize("op", ["min", "max"])
@pytest.mark.parametrize("n", [1, 2, 64, 257])
def test_segment_reduce_scoped_matches_full_on_active(op, n):
    """The activity-masked build answers every active query exactly as
    the full static table does (DESIGN.md §10)."""
    values = rng.integers(-1000, 1000, n).astype(np.int32)
    lo = rng.integers(0, n, 4 * n).astype(np.int32)
    hi = np.asarray([rng.integers(l, n) for l in lo], np.int32)
    active = rng.random(4 * n) < 0.5
    full = segment_reduce(jnp.asarray(values), jnp.asarray(lo),
                          jnp.asarray(hi), op)
    scoped = segment_reduce_scoped(jnp.asarray(values), jnp.asarray(lo),
                                   jnp.asarray(hi), jnp.asarray(active),
                                   op)
    assert_array_equal(np.asarray(scoped)[active], np.asarray(full)[active])


def test_segment_reduce_scoped_level_count_tracks_active_span():
    """Doubling levels built = ⌈log2(max active length)⌉, independent of
    n and of how long the *inactive* queries are."""
    n = 1024
    values = jnp.asarray(rng.integers(-50, 50, n), jnp.int32)
    lo = jnp.asarray([0, 10, 0], jnp.int32)
    hi = jnp.asarray([n - 1, 16, n - 1], jnp.int32)   # one huge inactive
    active = jnp.asarray([False, True, False])
    out, built = segment_reduce_scoped(values, lo, hi, active, "min",
                                       return_syncs=True)
    assert int(built) == 3                       # 2^3 >= length 7
    assert int(out[1]) == int(np.min(np.asarray(values)[10:17]))
    # All-inactive: zero levels built.
    _, built0 = segment_reduce_scoped(values, lo, hi,
                                      jnp.zeros((3,), bool), "min",
                                      return_syncs=True)
    assert int(built0) == 0
    # A full-span active query degrades to the static cost.
    _, built_full = segment_reduce_scoped(values, lo, hi,
                                          jnp.asarray([True] * 3), "min",
                                          return_syncs=True)
    assert int(built_full) == 10                 # ceil(log2(1024))


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("alternate_hooking", [False, True])
def test_pr_rst_incremental_reps_match_roots_of(seed, alternate_hooking):
    """Regression: the incrementally maintained representative array equals
    a from-scratch ``roots_of(p)`` after every hook/reverse round."""
    from repro.core.graph import Graph
    from repro.core.pr_rst import _pr_rst_round

    r = np.random.default_rng(seed)
    n = 120
    edges = np.stack([r.integers(0, n, 300), r.integers(0, n, 300)], 1)
    g = Graph.from_numpy_undirected(n, edges)
    levels = max(1, (n - 1).bit_length())

    p = jnp.arange(n, dtype=jnp.int32)
    rt = p
    for rnd in range(n):
        assert_array_equal(np.asarray(rt), np.asarray(roots_of(p)),
                           err_msg=f"round {rnd}")
        p, rt, hooked = _pr_rst_round(p, rt, jnp.int32(rnd), g.src, g.dst,
                                      levels=levels,
                                      alternate_hooking=alternate_hooking)
        if not bool(hooked):
            break
    assert_array_equal(np.asarray(rt), np.asarray(roots_of(p)))
