"""Downstream tree analytics (subtree sizes, depths) on RST outputs."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import Graph, rooted_spanning_tree
from repro.core.analytics import depths, subtree_sizes
from repro.data.graphs import erdos_renyi, grid2d


def _ref_subtree_sizes(parent: np.ndarray) -> np.ndarray:
    n = len(parent)
    sizes = np.ones(n, np.int64)
    order = np.argsort([-_depth(parent, v) for v in range(n)])
    for v in order:
        if parent[v] != v:
            sizes[parent[v]] += sizes[v]
    return sizes


def _depth(parent, v):
    d = 0
    while parent[v] != v:
        v = parent[v]
        d += 1
    return d


@pytest.mark.parametrize("method", ["bfs", "gconn_euler", "pr_rst"])
def test_subtree_sizes_on_rst(method):
    g = erdos_renyi(80, avg_degree=4, seed=11)
    res = rooted_spanning_tree(g, 5, method=method)
    parent = np.asarray(res.parent)
    parent = np.where(parent < 0, np.arange(len(parent)), parent)
    sizes = np.asarray(subtree_sizes(jnp.asarray(parent, jnp.int32)))
    ref = _ref_subtree_sizes(parent)
    assert np.array_equal(sizes, ref)
    assert sizes[5] == 80                    # root's subtree spans the graph


def test_depths_match_bfs_dist():
    g = grid2d(10)
    res = rooted_spanning_tree(g, 0, method="bfs")
    d = np.asarray(depths(res.parent))
    assert np.array_equal(d, np.asarray(res.dist))


def test_depths_random_tree():
    rng = np.random.default_rng(3)
    n = 200
    parent = np.zeros(n, np.int64)
    for v in range(1, n):
        parent[v] = rng.integers(0, v)
    d = np.asarray(depths(jnp.asarray(parent, jnp.int32)))
    for v in [0, 1, 50, 199]:
        assert d[v] == _depth(parent, v)
