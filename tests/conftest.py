"""Suite-wide pytest configuration: slow-marker gating + hypothesis pinning.

Tier-1 (``scripts/tier1.sh``, plain ``pytest``) must stay fast and
deterministic, so tests marked ``slow`` — the full property sweeps —
are auto-skipped unless ``--run-slow`` is passed
(``scripts/test_full.sh`` does).

If hypothesis is installed, a deterministic profile is pinned: fixed
derandomized example generation, with CI-vs-local example counts
(override with HYPOTHESIS_PROFILE / HYPOTHESIS_EXAMPLES). The container
may not ship hypothesis at all; tests that *require* it must
``pytest.importorskip("hypothesis")`` — the deterministic numpy-seeded
sweeps in test_queries.py carry the differential coverage either way.
"""
import os

import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--run-slow", action="store_true", default=False,
        help="run tests marked slow (the full property sweeps)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--run-slow"):
        return
    skip = pytest.mark.skip(
        reason="slow: pass --run-slow (scripts/test_full.sh)")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)


try:
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "repro-deterministic",
        max_examples=int(os.environ.get(
            "HYPOTHESIS_EXAMPLES", "20" if os.environ.get("CI") else "50")),
        derandomize=True,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    settings.load_profile(
        os.environ.get("HYPOTHESIS_PROFILE", "repro-deterministic"))
except ImportError:
    pass
