"""Per-arch smoke tests: reduced configs, one forward/train step on CPU,
assert output shapes + no NaNs. (Full configs are exercised only via the
dry-run.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.launch.mesh import make_host_mesh
from repro.launch.train import SMOKE_SHAPES, synthetic_batches
from repro.optim.adamw import adamw_init
from repro.train.step import build_cell, gnn_make_init


def _init_state(spec, cfg):
    key = jax.random.key(0)
    if spec.family == "lm":
        from repro.models import transformer as tfm
        params = tfm.init_params(cfg, key)
    elif spec.family == "gnn":
        params = gnn_make_init(spec.arch_id, cfg)(cfg, key)
    else:
        from repro.models import dien as dien_mod
        params = dien_mod.dien_init(cfg, key)
    return {"params": params, "opt": adamw_init(params)}


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_smoke_train_step(arch_id):
    import dataclasses as dc
    spec = get_arch(arch_id)
    cfg = spec.make_smoke_config()
    shape = dict(SMOKE_SHAPES[spec.family])
    if spec.family == "gnn":
        shape["d_feat"] = getattr(cfg, "d_in",
                                  getattr(cfg, "d_in_node", shape["d_feat"]))
    spec = dc.replace(spec, shapes={"smoke": shape})
    mesh = make_host_mesh()
    step_fn, _, _ = build_cell(spec, "smoke", mesh, smoke=True)
    state = _init_state(spec, cfg)
    _, batch = next(synthetic_batches(spec, shape, cfg))
    new_state, metrics = jax.jit(step_fn)(state, batch)
    loss = np.asarray(metrics["loss"])
    assert loss.shape == ()
    assert np.isfinite(loss), f"{arch_id} loss NaN"
    # params updated & finite
    leaf = jax.tree.leaves(new_state["params"])[0]
    assert np.isfinite(np.asarray(leaf, np.float32)).all()
    # loss decreases over a few steps (sanity of the full update path)
    s = new_state
    for i in range(2):
        s, metrics = jax.jit(step_fn)(s, batch)
    assert np.isfinite(np.asarray(metrics["loss"]))


@pytest.mark.parametrize("arch_id", ["llama3.2-1b", "qwen3-1.7b",
                                     "moonshot-v1-16b-a3b"])
def test_lm_smoke_decode(arch_id):
    from repro.models import transformer as tfm
    spec = get_arch(arch_id)
    cfg = spec.make_smoke_config()
    params = tfm.init_params(cfg, jax.random.key(1))
    cache = tfm.init_kv_cache(cfg, 2, 16)
    tok = jnp.array([1, 2], jnp.int32)
    for _ in range(3):
        logits, cache = tfm.decode_step(cfg, params, tok, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    assert int(cache["len"]) == 3


def test_dien_smoke_retrieval():
    from repro.models.dien import dien_init, dien_retrieval_score
    spec = get_arch("dien")
    cfg = spec.make_smoke_config()
    params = dien_init(cfg, jax.random.key(2))
    rng = np.random.default_rng(0)
    batch = dict(
        hist_items=jnp.asarray(rng.integers(0, cfg.n_items, (1, cfg.seq_len)), jnp.int32),
        hist_cates=jnp.asarray(rng.integers(0, cfg.n_cates, (1, cfg.seq_len)), jnp.int32),
        hist_mask=jnp.ones((1, cfg.seq_len), bool),
        user_feats=jnp.asarray(rng.integers(0, cfg.n_user_feats, (1, cfg.user_hot)), jnp.int32),
        cand_items=jnp.asarray(rng.integers(0, cfg.n_items, 128), jnp.int32),
        cand_cates=jnp.asarray(rng.integers(0, cfg.n_cates, 128), jnp.int32),
    )
    scores = dien_retrieval_score(cfg, params, batch, cand_block=32)
    assert scores.shape == (128,)
    assert bool(jnp.isfinite(scores).all())


def test_full_configs_param_counts():
    """Assigned configs carry the advertised scale (guard vs typos)."""
    expected = {
        "minicpm-2b": (2.0e9, 3.3e9),
        "llama3.2-1b": (1.0e9, 1.6e9),
        "qwen3-1.7b": (1.3e9, 2.2e9),
        # The ASSIGNED config (48L × 64e × d_ff 1408) yields 28 B total —
        # more than the HF card's 16 B (which has 27 layers); the assigned
        # numbers are authoritative. Active ≈ 4 B ≈ "A3B" ✓.
        "moonshot-v1-16b-a3b": (24e9, 30e9),
        "dbrx-132b": (125e9, 140e9),
    }
    for arch_id, (lo, hi) in expected.items():
        cfg = get_arch(arch_id).make_config()
        n = cfg.param_count()
        assert lo <= n <= hi, f"{arch_id}: {n/1e9:.2f}B params out of range"
    moon = get_arch("moonshot-v1-16b-a3b").make_config()
    assert moon.active_param_count() < 0.35 * moon.param_count()
