"""Observability layer (DESIGN.md §14): sync ledger, span tracing,
metrics registry — and the two contracts the whole subsystem stands on:
the ledger's totals are bit-equal to the engine's own ``return_syncs``
counters (single sync-accounting path), and instrumentation is FREE —
tracing on vs off leaves forest/tour/BCC state bit-identical and adds
zero engine syncs, across all three stream generators, the fleet tick,
and the full recovery ladder."""
import json

import numpy as np
import pytest
from numpy.testing import assert_array_equal

from repro import obs
from repro.data import graphs as G
from repro.data.streams import STREAMS
from repro.dynamic.chaos import inject
from repro.dynamic.fleet import (apply_batches, fleet_empty,
                                 fleet_sync_cost, refresh_tours)
from repro.dynamic.recovery import recover
from repro.dynamic.replay import init_state, replay_batch
from repro.dynamic.tour import refresh_tour
from repro.launch.resilient import ResilientStreamLoop

_STREAMS = ("sliding_window", "insert_heavy", "churn")


def _stream(name, g, batch=16, n=4, seed=0):
    kw = {"batch": batch, "seed": seed}
    if name == "sliding_window":
        kw["window"] = 2
    if name == "churn":
        kw["n_batches"] = n
    return STREAMS[name](g, **kw)


# ---- SyncLedger --------------------------------------------------------------

class TestSyncLedger:
    def test_record_accumulates_per_phase(self):
        with obs.SyncLedger() as led:
            obs.record("apply", 3)
            obs.record("apply", 2)
            obs.record("audit", 7)
        assert led.totals() == {"apply": 5, "audit": 7}
        assert led.counts() == {"apply": 2, "audit": 1}
        assert led.total() == 12
        assert led.total("apply") == 5
        assert led.total("missing") == 0

    def test_no_ledger_is_a_noop(self):
        assert obs.current_ledger() is None
        obs.record("apply", 3)  # nothing installed: must not raise

    def test_lazy_callable_only_evaluated_when_recording(self):
        calls = []

        def cost():
            calls.append(1)
            return 5

        obs.record("apply", cost)          # no ledger: never evaluated
        assert calls == []
        with obs.SyncLedger() as led:
            obs.record("apply", cost)
        assert calls == [1]
        assert led.total("apply") == 5

    def test_nested_ledgers_both_receive(self):
        with obs.SyncLedger() as outer:
            obs.record("apply", 1)
            with obs.SyncLedger() as inner:
                obs.record("apply", 2)
            obs.record("audit", 4)
        assert inner.totals() == {"apply": 2}
        assert outer.totals() == {"apply": 3, "audit": 4}
        assert obs.current_ledger() is None

    def test_tenant_labels(self):
        with obs.SyncLedger() as led:
            obs.record("apply", 3, tenant=0)
            obs.record("apply", 4, tenant=1)
            obs.record("apply", 5, tenant=0)
        assert led.by_tenant("apply") == {0: 8, 1: 4}
        assert led.total("apply") == 12


# ---- percentile_line (the shared serve_stream/serve_fleet helper) ------------

class TestPercentileLine:
    def test_zero_samples_shared_path(self):
        # The PR-8 regression, now on the single shared path: an op
        # that never ran must render a reason, not crash or fake a p50.
        assert obs.percentile_line([]) == "no samples"
        assert (obs.percentile_line((), empty_reason="op never reached")
                == "no samples (op never reached)")

    def test_fleet_format(self):
        line = obs.percentile_line([0.010, 0.020, 0.030])
        assert line == "p50  20.00 ms  p95  29.00 ms"

    def test_stream_per_op_format(self):
        line = obs.percentile_line([0.010] * 4, width=7,
                                   count_suffix=True)
        assert line == "p50   10.00 ms  p95   10.00 ms  (4 batches)"


# ---- Tracer: JSONL <-> Chrome round trip -------------------------------------

class TestTracer:
    def _traced(self):
        tracer = obs.Tracer()
        with tracer:
            with obs.span("tick", step=0):
                obs.record("apply", 3)
                with obs.span("apply_batch", step=0, tenants=2):
                    obs.record("apply", 2)
            obs.event("recovery", mode="scoped", reason="scoped_repair",
                      n_violating=4)
        return tracer

    def test_span_sync_attribution_is_inclusive(self):
        tracer = self._traced()
        tick, = tracer.spans("tick")
        inner, = tracer.spans("apply_batch")
        assert tick["syncs"] == 5      # includes the child's 2
        assert inner["syncs"] == 2
        assert tracer.summary()["sync_by_phase"] == {"apply": 5}

    def test_jsonl_round_trip(self, tmp_path):
        tracer = self._traced()
        path = tmp_path / "trace.jsonl"
        tracer.write_jsonl(path)
        records = obs.read_jsonl(path)
        assert records == tracer.records + [tracer.summary()]

    def test_chrome_round_trip(self, tmp_path):
        tracer = self._traced()
        path = tmp_path / "trace.chrome.json"
        tracer.write_chrome(path)
        chrome = json.loads(path.read_text())
        assert {e["ph"] for e in chrome["traceEvents"]} == {"X", "i"}
        assert chrome["otherData"]["sync_total"] == 5
        assert chrome["otherData"]["schema_version"] == obs.SCHEMA_VERSION
        assert obs.chrome_to_records(chrome) == tracer.records

    def test_no_tracer_span_is_noop(self):
        with obs.span("tick", step=0):
            obs.event("recovery", mode="full")  # must not raise


# ---- MetricsRegistry ---------------------------------------------------------

class TestMetrics:
    def test_counter_gauge_histogram(self):
        m = obs.MetricsRegistry()
        m.counter("applied").inc(3)
        m.counter("applied").inc(2)
        m.gauge("tenants").set(4)
        h = m.histogram("lat_ms")
        for v in (1.0, 2.0, 3.0, 100.0):
            h.observe(v)
        assert m.counter("applied").value == 5
        snap = h.snapshot()
        assert snap["count"] == 4
        assert snap["max"] == 100.0
        assert snap["p50"] == pytest.approx(2.0, rel=0.5)

    def test_labels_key_series_and_kind_conflicts_raise(self):
        m = obs.MetricsRegistry()
        m.counter("applied", tenant=0).inc(1)
        m.counter("applied", tenant=1).inc(2)
        assert m.counter("applied", tenant=0).value == 1
        assert m.counter("applied", tenant=1).value == 2
        with pytest.raises(TypeError):
            m.gauge("applied", tenant=0)

    def test_to_dict_stable_sorted(self, tmp_path):
        m = obs.MetricsRegistry()
        m.counter("b").inc(1)
        m.counter("a", tenant=1).inc(1)
        m.counter("a", tenant=0).inc(1)
        d = m.to_dict()
        keys = [(r["name"], tuple(sorted(r["labels"].items())))
                for r in d["metrics"]]
        assert keys == sorted(keys)
        assert d["schema_version"] == obs.METRICS_SCHEMA_VERSION
        m.write(tmp_path / "m.json")
        assert json.loads((tmp_path / "m.json").read_text()) == d


# ---- ledger == return_syncs (single sync-accounting path) --------------------

class TestLedgerBitEquality:
    def test_apply_phase_equals_replay_stats(self):
        stream = _stream("churn", G.grid2d(8))
        state = init_state(stream)
        hand = 0
        with obs.SyncLedger() as led:
            for b in stream.batches:
                state, stats = replay_batch(state, b)
                hand += int(stats["rounds"]) + 1
        assert led.total("apply") == hand

    def test_fleet_apply_phase_equals_fleet_sync_cost(self):
        g = G.grid2d(8)
        streams = [_stream("churn", g, seed=t) for t in range(2)]
        capacity = max(s.init_u.shape[0] + 64 for s in streams)
        fleet = fleet_empty(2, g.n_nodes, capacity)
        for t, s in enumerate(streams):
            fleet = fleet.set_tenant(t, init_state(s, capacity=capacity))
        hand = 0
        with obs.SyncLedger() as led:
            for i in range(len(streams[0].batches)):
                blk = tuple(
                    np.stack([np.asarray(getattr(s.batches[i], f))
                              for s in streams])
                    for f in ("ins_u", "ins_v", "del_u", "del_v"))
                fleet, stats = apply_batches(fleet, *blk)
                hand += fleet_sync_cost(stats)
        assert led.total("fleet_apply") == hand


# ---- instrumentation is free -------------------------------------------------

def _run_loop(stream, batches, traced):
    loop = ResilientStreamLoop.from_stream(
        stream, tour_mode="incremental", bcc_mode="incremental",
        tour_every=2, audit_every=2, chaos=("parent_bitflip",),
        chaos_every=3, sanitize=True)
    if traced:
        tracer = obs.Tracer()
        with tracer:
            state = loop.run(batches)
        return loop, state, tracer
    return loop, loop.run(batches), None


class TestInstrumentationIsFree:
    @pytest.mark.parametrize("stream_name", _STREAMS)
    def test_traced_run_bit_identical(self, stream_name):
        g = G.grid2d(8)
        stream = _stream(stream_name, g)
        batches = stream.batches[:4]
        loop_a, state_a, _ = _run_loop(stream, batches, traced=False)
        loop_b, state_b, tracer = _run_loop(stream, batches, traced=True)

        for field in ("parent", "rep", "pool_valid", "tree_mask",
                      "version"):
            assert_array_equal(np.asarray(getattr(state_a, field)),
                               np.asarray(getattr(state_b, field)),
                               err_msg=f"{stream_name}: {field}")
        assert_array_equal(np.asarray(loop_a.tn.pre),
                           np.asarray(loop_b.tn.pre))
        if loop_a.bcc is not None:
            assert_array_equal(np.asarray(loop_a.bcc.edge_bcc),
                               np.asarray(loop_b.bcc.edge_bcc))
        # The traced run actually observed the loop.
        assert tracer.spans("tick")
        assert tracer.summary()["sync_total"] > 0

    def test_fleet_tick_bit_identical(self):
        g = G.grid2d(8)
        streams = [_stream("churn", g, seed=t) for t in range(2)]
        capacity = max(s.init_u.shape[0] + 64 for s in streams)

        def run(traced):
            fleet = fleet_empty(2, g.n_nodes, capacity)
            for t, s in enumerate(streams):
                fleet = fleet.set_tenant(
                    t, init_state(s, capacity=capacity))
            tn = None
            ctx = obs.Tracer() if traced else None
            with ctx if ctx is not None else obs.span("noop"):
                for i in range(len(streams[0].batches)):
                    blk = tuple(
                        np.stack([np.asarray(getattr(s.batches[i], f))
                                  for s in streams])
                        for f in ("ins_u", "ins_v", "del_u", "del_v"))
                    fleet, _ = apply_batches(fleet, *blk)
                    tn, fleet = refresh_tours(fleet, tn)
            return fleet, tn

        fleet_a, tn_a = run(traced=False)
        fleet_b, tn_b = run(traced=True)
        assert_array_equal(np.asarray(fleet_a.parent),
                           np.asarray(fleet_b.parent))
        assert_array_equal(np.asarray(fleet_a.rep),
                           np.asarray(fleet_b.rep))
        assert_array_equal(np.asarray(tn_a.pre), np.asarray(tn_b.pre))

    def test_recover_ladder_bit_identical_and_emits_events(self):
        stream = _stream("churn", G.grid2d(8))
        state = init_state(stream)
        for b in stream.batches:
            state, _ = replay_batch(state, b)
        tn, state = refresh_tour(state, None)
        bad, _, _ = inject("parent_bitflip", state, seed=7)

        state_a, tn_a, _, _, info_a = recover(bad, tn)
        tracer = obs.Tracer()
        with tracer:
            state_b, tn_b, _, _, info_b = recover(bad, tn)

        assert info_a == info_b
        assert_array_equal(np.asarray(state_a.parent),
                           np.asarray(state_b.parent))
        assert_array_equal(np.asarray(state_a.rep),
                           np.asarray(state_b.rep))
        assert_array_equal(np.asarray(tn_a.pre), np.asarray(tn_b.pre))
        violation, = tracer.events("audit_violation")
        assert violation["args"]["violations"]
        recovery, = tracer.events("recovery")
        assert recovery["args"]["mode"] == info_b["mode"]
        assert recovery["args"]["reason"] == info_b["reason"]

    def test_traced_ledger_matches_untraced_hand_count(self):
        # Zero-added-syncs: the ledger only *reads* counters the compiled
        # program already carries, so the traced run's apply total equals
        # the untraced run's hand-summed rounds+1.
        stream = _stream("sliding_window", G.grid2d(8))
        state = init_state(stream)
        hand = 0
        for b in stream.batches[:4]:
            state, stats = replay_batch(state, b)
            hand += int(stats["rounds"]) + 1

        state2 = init_state(stream)
        tracer = obs.Tracer()
        with tracer:
            for b in stream.batches[:4]:
                state2, _ = replay_batch(state2, b)
        assert tracer.ledger.total("apply") == hand
        assert_array_equal(np.asarray(state.parent),
                           np.asarray(state2.parent))
