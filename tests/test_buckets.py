"""Shape-bucketed sub-fleets (DESIGN.md §15): routing bit-equality vs
independent single-tenant loops, the single-bucket == PR-8 ForestFleet
compatibility anchor, async-admission adoption boundaries, idle-LRU
eviction, stable-label telemetry continuity, dispatcher carryover,
schema-stamped checkpoints, and the ``--buckets`` CLI spec surface."""
import concurrent.futures

import numpy as np
import pytest
from numpy.testing import assert_array_equal

from repro.core import queries as q
from repro.core.queries import build_tables
from repro.data import graphs as G
from repro.data.graphs import resolve_graph
from repro.data.streams import STREAMS, StreamBatch
from repro.dynamic.bcc import refresh_bcc
from repro.dynamic.fleet import (BucketedFleet, FleetDispatcher,
                                 FleetManager, FleetQuerySession,
                                 FleetSchema, apply_batches, fleet_empty,
                                 fleet_sync_cost, refresh_tours,
                                 tenant_slice)
from repro.dynamic.forest import forest_empty
from repro.dynamic.replay import init_state, replay_batch, stream_capacity
from repro.dynamic.tour import refresh_tour
from repro.dynamic.view import CadencePolicy
from repro.launch.config import BucketSpec, FleetConfig

_FOREST_FIELDS = ("parent", "rep", "pool_src", "pool_dst", "pool_valid",
                  "tree_mask")


def _group(graph, tenants, stream_name, batch, n_units=3, seed0=0):
    kw = {"batch": batch}
    if stream_name == "sliding_window":
        kw["window"] = 2
    if stream_name == "churn":
        kw["n_batches"] = n_units
    streams = [STREAMS[stream_name](graph, **{**kw, "seed": seed0 + t})
               for t in range(tenants)]
    units = min(n_units, min(len(s.batches) for s in streams))
    capacity = max(stream_capacity(s) for s in streams)
    return streams, units, FleetSchema(graph.n_nodes, capacity, batch)


def _oracle(stream, capacity, units):
    state = init_state(stream, capacity=capacity)
    for i in range(units):
        state, _ = replay_batch(state, stream.batches[i])
    return state


def _assert_forest_fields(got, want, fields=_FOREST_FIELDS, tag=""):
    for field in fields:
        assert_array_equal(np.asarray(getattr(got, field)),
                           np.asarray(getattr(want, field)),
                           err_msg=f"{tag}: field {field}")


def _assert_tree_equal(stacked, t, single, tag=""):
    import jax
    a = jax.tree_util.tree_leaves(tenant_slice(stacked, t))
    b = jax.tree_util.tree_leaves(single)
    assert len(a) == len(b)
    for i, (x, y) in enumerate(zip(a, b)):
        assert_array_equal(np.asarray(x), np.asarray(y),
                           err_msg=f"{tag}: leaf {i}")


# -- routing bit-equality (the tentpole invariant) ----------------------------

@pytest.mark.parametrize("stream_name", sorted(STREAMS))
def test_bucketed_matches_independent_loops(stream_name, tmp_path):
    """Tenants across 2 shape buckets (one under eviction pressure) end
    bit-identical — forests, and on the stable bucket tours, BCC labels,
    and query answers — to independent single-tenant replay loops."""
    ga, gb = G.grid2d(4), G.chain(32)
    sa, units_a, schema_a = _group(ga, 3, stream_name, batch=8, seed0=0)
    sb, units_b, schema_b = _group(gb, 2, stream_name, batch=16, seed0=7)

    bf = BucketedFleet(tmp_path)
    # Bucket A: 3 tenants in 2 slots — rotation, checkpoints, prefetch.
    bf.add_bucket(schema_a, 2, name="a",
                  cadence=CadencePolicy(tour="incremental", every=2))
    # Bucket B: slots == tenants — stable lanes for cache comparisons.
    bf.add_bucket(schema_b, 2, name="b",
                  cadence=CadencePolicy(tour="incremental", bcc="full",
                                        every=2, queries=True,
                                        staleness="strict"))
    for name, schema, streams, units in (("a", schema_a, sa, units_a),
                                         ("b", schema_b, sb, units_b)):
        for j, s in enumerate(streams):
            tid = f"{name}{j}"
            bf.route(tid, schema,
                     seed=init_state(s, capacity=schema.capacity))
            for unit in s.batches[:units]:
                bf.offer(tid, unit)
    bf.run()
    bf.finalize()

    # Forests: every tenant, both buckets, vs its own replay loop.
    for name, schema, streams, units in (("a", schema_a, sa, units_a),
                                         ("b", schema_b, sb, units_b)):
        for j, s in enumerate(streams):
            want = _oracle(s, schema.capacity, units)
            got = bf.tenant_forest(f"{name}{j}")
            _assert_forest_fields(got, want,
                                  tag=f"{stream_name}/{name}{j}")

    # Bucket A saw admission pressure (3 tenants, 2 slots).
    assert bf.buckets["a"].manager.evictions > 0

    # Derived caches + query answers on the stable bucket: the bucket's
    # vmapped tn/bcc/session lanes == from-scratch single-tenant oracles.
    bb = bf.buckets["b"]
    assert bb.tn is not None and bb.bcc is not None
    assert bb.session is not None
    for j, s in enumerate(sb):
        tid = f"b{j}"
        slot = bb.manager.slot_of[tid]
        state = _oracle(s, schema_b.capacity, units_b)
        tn, state = refresh_tour(state, None)
        bcc = refresh_bcc(state, tour=tn, incremental=False)
        _assert_tree_equal(bb.tn, slot, tn, f"{stream_name}/{tid}/tour")
        _assert_tree_equal(bb.bcc, slot, bcc, f"{stream_name}/{tid}/bcc")

        tab = build_tables(tn)
        rng = np.random.default_rng(11 * (j + 1))
        u = rng.integers(0, gb.n_nodes, 32).astype(np.int32)
        v = rng.integers(0, gb.n_nodes, 32).astype(np.int32)
        fleet = bb.manager.fleet
        assert_array_equal(
            np.asarray(bb.session.connected(fleet, slot, u, v)),
            np.asarray(q.connected(tab, u, v)))
        assert_array_equal(
            np.asarray(bb.session.lca(fleet, slot, u, v)),
            np.asarray(q.lca(tab, u, v)))
        # Telemetry rode the stable tenant id, not the slot index.
        assert bb.session.sync_stats(tid)["builds"] >= 1


def test_single_bucket_matches_pr8_forestfleet(tmp_path):
    """The compatibility anchor: one bucket, slots == tenants, is the
    PR-8 single-schema ForestFleet loop bit for bit — forests, tour
    numbering, and the per-tick sync bill."""
    g = G.grid2d(8)
    streams, units, schema = _group(g, 3, "churn", batch=16, n_units=4)
    cadence = CadencePolicy(tour="incremental", every=2)

    # PR-8 style manual loop.
    fleet = fleet_empty(3, g.n_nodes, schema.capacity)
    for t, s in enumerate(streams):
        fleet = fleet.set_tenant(t, init_state(s,
                                               capacity=schema.capacity))
    tn = None
    sync = 0
    for i in range(units):
        block = tuple(np.stack([np.asarray(getattr(s.batches[i], f))
                                for s in streams])
                      for f in ("ins_u", "ins_v", "del_u", "del_v"))
        fleet, stats = apply_batches(fleet, *block)
        sync += fleet_sync_cost(stats)
        if cadence.due(i):
            tn, fleet = refresh_tours(
                fleet, tn, incremental=(tn is not None))
    tn, fleet = refresh_tours(fleet, tn, incremental=True)

    bf = BucketedFleet(tmp_path)
    b = bf.add_bucket(schema, 3, cadence=cadence, name="only")
    for t, s in enumerate(streams):
        bf.route(t, schema, seed=init_state(s, capacity=schema.capacity))
        for unit in s.batches[:units]:
            bf.offer(t, unit)
    bf.run()
    b.refresh()

    assert b.sync_apply == sync
    for t in range(3):
        slot = b.manager.slot_of[t]
        _assert_forest_fields(
            b.manager.fleet.tenant(slot), fleet.tenant(t),
            fields=_FOREST_FIELDS + ("dirty", "version"), tag=f"t{t}")
        _assert_tree_equal(b.tn, slot, tenant_slice(tn, t), f"t{t}/tour")


# -- async admission (§15 adoption boundary) ----------------------------------

def test_prefetch_adopts_only_at_boundary(tmp_path):
    """A restore that has already COMPLETED is not observed until
    ``adopt_ready`` runs at a tick boundary — even with no executor
    (inline restore), and even across many busy mid-tick checks."""
    mgr = FleetManager(fleet_empty(1, 16, 8), tmp_path,
                       schema=FleetSchema(16, 8, 4))
    mgr.ensure("a")
    mgr.evict("a")
    mgr.ensure("b")

    assert mgr.prefetch("a") is True
    assert mgr._prefetch["a"].done()        # restore finished "mid-tick"
    assert "a" not in mgr.slot_of           # ...but not visible yet
    assert mgr.prefetching("a")
    assert mgr.prefetch("a") is True        # idempotent while in flight

    adopted = mgr.adopt_ready()
    assert adopted == ["a"]
    assert mgr.slot_of["a"] == 0 and "b" not in mgr.slot_of
    assert mgr.restores == 1 and mgr.prefetches == 1


def test_prefetch_threaded_restore_and_unfinished_future(tmp_path):
    """With a real worker thread the protocol is the same; an UNFINISHED
    restore stays in flight across adopt_ready calls."""
    with concurrent.futures.ThreadPoolExecutor(1) as ex:
        mgr = FleetManager(fleet_empty(2, 16, 8), tmp_path, executor=ex)
        mgr.ensure("a")
        mgr.evict("a")
        mgr.prefetch("a")
        mgr._prefetch["a"].result()         # wait for the worker
        assert "a" not in mgr.slot_of
        # An unfinished future is skipped, not installed.
        mgr._prefetch["slow"] = concurrent.futures.Future()
        assert mgr.adopt_ready() == ["a"]
        assert mgr.prefetching("slow")
        del mgr._prefetch["slow"]

    # prefetch on a resident tenant is a no-op.
    assert mgr.prefetch("a") is False


def test_ensure_joins_inflight_prefetch(tmp_path):
    """ensure() during an in-flight prefetch adopts that restore instead
    of racing a second one."""
    mgr = FleetManager(fleet_empty(1, 16, 8), tmp_path)
    mgr.ensure("a")
    mgr.evict("a")
    mgr.prefetch("a")
    slot = mgr.ensure("a")
    assert slot == 0 and not mgr.prefetching("a")
    assert mgr.restores == 1


# -- idle-LRU eviction (satellite: don't evict busy tenants) ------------------

def test_pick_victim_prefers_idle_over_lru(tmp_path):
    mgr = FleetManager(fleet_empty(3, 16, 8), tmp_path)
    for t in ("a", "b", "c"):
        mgr.ensure(t)
    mgr.touch("b")
    mgr.touch("c")                          # LRU order now a < b < c
    busy = {"a": True, "b": False, "c": True}

    # PR-8 regression: without busy info, plain global LRU.
    assert mgr.pick_victim() == "a"
    # Idle resident beats the busy global-LRU resident.
    assert mgr.pick_victim(busy=lambda t: busy[t]) == "b"
    # All busy → fall back to global LRU (liveness over thrash).
    assert mgr.pick_victim(busy=lambda t: True) == "a"
    assert mgr.has_room(busy=lambda t: busy[t])
    assert not mgr.has_room(busy=lambda t: True)

    mgr.ensure("d", busy=lambda t: busy.get(t, False))
    assert "b" not in mgr.slot_of           # the idle one was evicted
    assert set(mgr.slot_of) == {"a", "c", "d"}


def test_bucket_rotation_never_evicts_busy_when_idle_exists(tmp_path):
    """Serving-loop regression: with queues offered up front, rotation
    only ever evicts tenants whose queues have drained — no checkpoint
    round-trips for still-busy residents."""
    g = G.grid2d(4)
    streams, units, schema = _group(g, 4, "churn", batch=8, n_units=2)
    bf = BucketedFleet(tmp_path)
    b = bf.add_bucket(schema, 2, name="only")
    for t, s in enumerate(streams):
        bf.route(t, schema, seed=init_state(s, capacity=schema.capacity))
        for unit in s.batches[:units]:
            bf.offer(t, unit)
    bf.run()
    assert b.manager.evictions > 0
    # Idle-LRU policy: every evicted tenant was already drained, so its
    # checkpoint never needed restoring.
    assert b.manager.restores == 0


# -- stable-label telemetry (satellite: counters survive rotation) ------------

def test_session_labels_survive_rotation():
    g = G.grid2d(4)
    streams, _, schema = _group(g, 2, "churn", batch=8, n_units=3)
    fleet = fleet_empty(2, g.n_nodes, schema.capacity)
    for t, s in enumerate(streams):
        fleet = fleet.set_tenant(t, init_state(s,
                                               capacity=schema.capacity))
    sess = FleetQuerySession.from_fleet(fleet, policy="stale",
                                        labels=["a", "b"])
    assert sess.sync_stats("a")["builds"] == 1

    block = tuple(np.stack([np.asarray(getattr(s.batches[0], f))
                            for s in streams])
                  for f in ("ins_u", "ins_v", "del_u", "del_v"))
    fleet, _ = apply_batches(fleet, *block)
    u = np.arange(4, dtype=np.int32)
    sess.connected(fleet, 0, u, u)          # stale lane, label "a"
    assert sess.sync_stats("a")["stale_served"] == 1
    assert sess.sync_stats("b")["stale_served"] == 0

    # Rotation: slot 0 now hosts tenant "c"; its counters start fresh
    # while "a" keeps its history.
    sess.set_label(0, "c")
    sess.rebuild_tenant(fleet, 0)
    assert sess.sync_stats("c") == {"builds": 1, "build_syncs_total":
                                    sess.sync_stats("c")
                                    ["build_syncs_total"],
                                    "stale_served": 0,
                                    "auto_refreshes": 0}
    assert sess.sync_stats("a")["stale_served"] == 1

    # "a" re-admitted into the OTHER slot: counters continue, not reset.
    sess.set_label(1, "a")
    sess.rebuild_tenant(fleet, 1)
    assert sess.sync_stats("a")["stale_served"] == 1
    assert sess.sync_stats("a")["builds"] == 2
    # Fleet totals sum labels; slot ints still resolve when unclaimed.
    assert sess.sync_stats()["builds"] == \
        sum(sess.sync_stats(t)["builds"] for t in ("a", "b", "c"))


def test_session_default_labels_keep_pr8_slot_indexing():
    g = G.grid2d(4)
    streams, _, schema = _group(g, 2, "churn", batch=8, n_units=2)
    fleet = fleet_empty(2, g.n_nodes, schema.capacity)
    for t, s in enumerate(streams):
        fleet = fleet.set_tenant(t, init_state(s,
                                               capacity=schema.capacity))
    sess = FleetQuerySession.from_fleet(fleet, policy="stale")
    assert sess.labels == [0, 1]
    assert sess.sync_stats(0)["builds"] == 1
    with pytest.raises(ValueError, match="labels"):
        FleetQuerySession.from_fleet(fleet, labels=["only-one"])


# -- dispatcher carryover (satellite: cross-tick coalescing) ------------------

def test_dispatcher_drain_carryover_fifo_and_backlog():
    n, width = 16, 4
    d = FleetDispatcher(n, width)
    mk = lambda lo: StreamBatch(
        ins_u=np.arange(lo, lo + width, dtype=np.int32) % n,
        ins_v=(np.arange(lo, lo + width, dtype=np.int32) + 1) % n,
        del_u=np.full(width, n, np.int32),
        del_v=np.full(width, n, np.int32))
    units_a = [mk(0), mk(4), mk(8)]
    for u in units_a:
        d.offer("a", u)
    d.offer("b", mk(12))

    blocks = d.drain(["a", "b"], max_blocks=2)
    assert len(blocks) == 2
    (iu0, _, _, _), served0 = blocks[0]
    (iu1, _, _, _), served1 = blocks[1]
    # Block 1: one unit per tenant (atomic, never merged)...
    assert set(served0) == {"a", "b"}
    assert_array_equal(np.asarray(iu0[0]), units_a[0].ins_u)
    # ...block 2 carries a's backlog forward in FIFO order; b's empty
    # slot rides as sentinels.
    assert set(served1) == {"a"}
    assert_array_equal(np.asarray(iu1[0]), units_a[1].ins_u)
    assert np.all(np.asarray(iu1[1]) == n)
    assert d.backlog() == {"a": 1}
    # Drain stops early when no resident has queued units.
    assert len(d.drain(["a", "b"], max_blocks=5)) == 1
    assert d.backlog() == {}


# -- schema-stamped checkpoints -----------------------------------------------

def test_checkpoint_schema_mismatch_rejected(tmp_path):
    s1 = FleetSchema(16, 8, 4)
    s2 = FleetSchema(16, 8, 8)              # same arrays, different block
    m1 = FleetManager(fleet_empty(1, 16, 8), tmp_path, schema=s1)
    m1.ensure("x")
    m1.evict("x")

    m2 = FleetManager(fleet_empty(1, 16, 8), tmp_path, schema=s2)
    with pytest.raises(ValueError, match="cannot be admitted"):
        m2.ensure("x")
    # Same schema (fresh manager) restores fine.
    m3 = FleetManager(fleet_empty(1, 16, 8), tmp_path, schema=s1)
    m3.ensure("x")
    assert m3.restores == 1
    # PR-8 managers (no schema) ignore the stamp entirely.
    m4 = FleetManager(fleet_empty(1, 16, 8), tmp_path)
    m4.ensure("x")
    assert m4.restores == 1


def test_bucketed_routing_contracts(tmp_path):
    bf = BucketedFleet(tmp_path)
    s1, s2 = FleetSchema(16, 8, 4), FleetSchema(32, 8, 4)
    bf.add_bucket(s1, 1, name="small")
    with pytest.raises(ValueError, match="already exists"):
        bf.add_bucket(s1, 1, name="small")
    with pytest.raises(KeyError, match="no bucket"):
        bf.route("t", s2)
    bf.add_bucket(s2, 1, name="big")
    assert bf.route("t", s2).name == "big"
    assert bf.route("t", s2).name == "big"  # idempotent re-route
    with pytest.raises(ValueError, match="cannot re-route"):
        bf.route("t", s1)
    with pytest.raises(ValueError, match="does not fit"):
        bf.buckets["small"].route("u", seed=forest_empty(32, 8))
    with pytest.raises(KeyError, match="not routed"):
        bf.buckets["small"].offer("ghost", StreamBatch(
            ins_u=np.full(4, 16, np.int32), ins_v=np.full(4, 16, np.int32),
            del_u=np.full(4, 16, np.int32), del_v=np.full(4, 16, np.int32)))
    bf.close()


def test_fleet_schema_contract():
    s = FleetSchema(64, 40, 8)
    assert s.key == "n64_c40_b8"
    assert s.slot_cost == 3 * 64 + 4 * 40
    assert FleetSchema.from_dict(s.to_dict()) == s


# -- the --buckets CLI surface ------------------------------------------------

def test_bucket_specs_parse_and_defaults():
    fcfg = FleetConfig(buckets="chain_64:12,rmat_9:2:2:32, grid_8:3:1 ")
    assert fcfg.bucket_specs() == (
        BucketSpec("chain_64", 12, 12, None),
        BucketSpec("rmat_9", 2, 2, 32),
        BucketSpec("grid_8", 3, 1, None))
    assert FleetConfig().bucket_specs() == ()
    assert fcfg.check() is fcfg

    for bad in ("chain_64", "chain_64:0", "g:1:2:3:4", "g:x",
                "chain_64:2:0"):
        with pytest.raises(ValueError):
            FleetConfig(buckets=bad).bucket_specs()
    with pytest.raises(ValueError):
        FleetConfig(buckets="chain_64:0").check()
    with pytest.raises(ValueError, match="--drain"):
        FleetConfig(drain=0).check()


def test_fleet_config_bucket_flags_bind():
    import argparse
    ap = argparse.ArgumentParser()
    FleetConfig.add_args(ap)
    fcfg = FleetConfig.from_args(ap.parse_args(
        ["--buckets", "chain_16:4:2", "--drain", "3"]))
    assert fcfg.buckets == "chain_16:4:2" and fcfg.drain == 3
    assert FleetConfig.from_args(ap.parse_args([])) == FleetConfig()


def test_resolve_graph_patterns():
    assert resolve_graph("chain_32").n_nodes == 32
    assert resolve_graph("grid_6").n_nodes == 36
    assert resolve_graph("rmat_5").n_nodes == 32
    assert resolve_graph("er_64").n_nodes == 64
    assert resolve_graph("grid_64").n_nodes == 64 * 64   # SUITE name wins
    for bad in ("mystery_7", "chain_x", "chain"):
        with pytest.raises(ValueError, match="unknown graph"):
            resolve_graph(bad)


# -- the bucketed serving entry point -----------------------------------------

def test_serve_fleet_bucketed_end_to_end(tmp_path, capsys):
    from repro.launch import serve_fleet
    serve_fleet.main(["--buckets", "chain_16:3:2,grid_4:2:2:16",
                      "--stream", "churn", "--batch", "8", "--steps", "3",
                      "--drain", "2", "--tour-every", "2",
                      "--evict-dir", str(tmp_path), "--validate"])
    out = capsys.readouterr().out
    assert "bucket chain_16" in out and "bucket grid_4" in out
    assert "sync accounting: total=" in out
    assert out.count("partition==from-scratch: True") == 5
    assert "Traceback" not in out
