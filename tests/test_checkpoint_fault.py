"""Checkpoint save/restore, atomicity, retention, elastic restore, and the
fault-tolerant loop (resume + straggler log, and driving the dynamic
forest: retry soundness + kill/resume bit-identity, DESIGN.md §11)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.data import graphs as G
from repro.data.streams import STREAMS
from repro.dynamic import init_state, replay_batch
from repro.train import checkpoint as ckpt
from repro.train.fault import FaultTolerantLoop, StepTimeout


def _state(seed=0):
    k = jax.random.key(seed)
    return {"params": {"w": jax.random.normal(k, (8, 8)),
                       "b": jnp.zeros((8,), jnp.bfloat16)},
            "opt": {"step": jnp.int32(7)}}


def test_save_restore_roundtrip(tmp_path):
    s = _state()
    ckpt.save(tmp_path, s, step=3, data_cursor=42)
    restored, manifest = ckpt.restore(tmp_path, jax.eval_shape(lambda: s))
    assert manifest["step"] == 3 and manifest["data_cursor"] == 42
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_retention_keeps_last_n(tmp_path):
    s = _state()
    for step in range(6):
        ckpt.save(tmp_path, s, step=step, keep=2)
    assert ckpt.latest_step(tmp_path) == 5
    dirs = sorted(d.name for d in tmp_path.iterdir() if d.is_dir())
    assert dirs == ["step_0000000004", "step_0000000005"]


def test_tree_mismatch_rejected(tmp_path):
    ckpt.save(tmp_path, _state(), step=0)
    bad = {"params": {"w": jnp.zeros((8, 8))}, "opt": {"step": jnp.int32(0)}}
    with pytest.raises(ValueError, match="tree mismatch"):
        ckpt.restore(tmp_path, bad)


def test_async_save(tmp_path):
    t = ckpt.save(tmp_path, _state(), step=9, blocking=False)
    t.join()
    assert ckpt.latest_step(tmp_path) == 9


def test_elastic_restore_resharding(tmp_path):
    """Restore onto explicit (trivial-mesh) shardings — the elastic path."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import auto_axis_kwargs
    mesh = jax.make_mesh((1,), ("data",), **auto_axis_kwargs(1))
    s = _state()
    ckpt.save(tmp_path, s, step=1)
    shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), s)
    restored, _ = ckpt.restore(tmp_path, jax.eval_shape(lambda: s),
                               shardings=shardings)
    w = restored["params"]["w"]
    assert isinstance(w.sharding, NamedSharding)
    np.testing.assert_array_equal(np.asarray(w), np.asarray(s["params"]["w"]))


def test_fault_tolerant_loop_resume(tmp_path):
    """Train 10 steps w/ ckpt_every=4, kill, resume — continues from 8."""
    def step_fn(state, batch):
        s = state["opt"]["step"] + 1
        return ({"params": state["params"], "opt": {"step": s}},
                {"loss": jnp.float32(1.0) / s.astype(jnp.float32)})

    def data():
        c = 0
        while True:
            yield c, {"x": jnp.zeros(())}
            c += 1

    s0 = {"params": {"w": jnp.zeros((2,))}, "opt": {"step": jnp.int32(0)}}
    loop = FaultTolerantLoop(step_fn=step_fn, state=s0, data_iter=data(),
                             ckpt_dir=tmp_path, ckpt_every=4,
                             async_ckpt=False)
    loop.run(10)
    assert int(loop.state["opt"]["step"]) == 10

    loop2 = FaultTolerantLoop(step_fn=step_fn, state=s0, data_iter=data(),
                              ckpt_dir=tmp_path, ckpt_every=4,
                              async_ckpt=False)
    start = loop2.resume()
    assert start == 8                      # last multiple of ckpt_every
    loop2.run(12)
    assert int(loop2.state["opt"]["step"]) == 12


def test_straggler_detection(tmp_path):
    import time

    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        if calls["n"] == 5:
            time.sleep(0.25)               # synthetic straggler
        return state, {"loss": jnp.float32(0.0)}

    def data():
        c = 0
        while True:
            yield c, {}
            c += 1

    loop = FaultTolerantLoop(step_fn=step_fn, state={"x": jnp.zeros(())},
                             data_iter=data(), ckpt_dir=tmp_path,
                             ckpt_every=1000, straggler_factor=3.0)
    loop.run(8)
    assert len(loop.stragglers) >= 1
    assert loop.stragglers[0][0] == 4      # 0-indexed step of the slow call


# ---------------------------------------------------------------------------
# FaultTolerantLoop driving the dynamic forest (DESIGN.md §11): steps are
# pure functions of (state, batch), so retrying after an injected timeout
# and resuming from a checkpoint must both land on the bit-identical forest.

_FOREST_FIELDS = ("parent", "rep", "pool_src", "pool_dst", "pool_valid",
                  "tree_mask", "dirty")


def _forest_stream(n_batches=12):
    stream = STREAMS["churn"](G.grid2d(8), batch=16, n_batches=n_batches,
                              seed=5)
    return init_state(stream), stream.batches


def _forest_step(state, batch):
    state, stats = replay_batch(state, batch)
    return state, {"deletes_found": stats["deletes_found"]}


def _assert_forests_equal(a, b):
    for f in _FOREST_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), err_msg=f)


def test_fault_loop_forest_retry_sound(tmp_path):
    """Injected StepTimeouts are retried; the forest matches a clean run."""
    state0, batches = _forest_stream()
    ref = state0
    for b in batches:
        ref, _ = _forest_step(ref, b)

    fail_left = {3: 1, 7: 2}               # step -> failing attempts
    attempts = {}

    def flaky(state, batch):
        i = attempts["cursor"]
        attempts[i] = attempts.get(i, 0) + 1
        if fail_left.get(i, 0) >= attempts[i]:
            raise StepTimeout(f"injected at step {i}")
        return _forest_step(state, batch)

    def data():
        for c, b in enumerate(batches):
            attempts["cursor"] = c
            yield c, b

    loop = FaultTolerantLoop(step_fn=flaky, state=state0, data_iter=data(),
                             ckpt_dir=tmp_path, ckpt_every=4, max_retries=2,
                             async_ckpt=False)
    loop.run(len(batches))
    assert loop.retries == 3
    _assert_forests_equal(loop.state, ref)


def test_fault_loop_forest_final_failure_checkpoints(tmp_path):
    """Retries exhausted -> last good forest is published, then re-raise."""
    state0, batches = _forest_stream()

    def doomed(state, batch):
        if attempts["cursor"] == 2:
            raise StepTimeout("injected permanent fault")
        return _forest_step(state, batch)

    attempts = {}

    def data():
        for c, b in enumerate(batches):
            attempts["cursor"] = c
            yield c, b

    loop = FaultTolerantLoop(step_fn=doomed, state=state0, data_iter=data(),
                             ckpt_dir=tmp_path, ckpt_every=100,
                             max_retries=1, async_ckpt=False)
    with pytest.raises(StepTimeout):
        loop.run(len(batches))
    assert loop.retries == 2               # max_retries + 1 attempts
    # The emergency checkpoint holds the last good (step-2) forest.
    assert ckpt.latest_step(tmp_path) == 2
    restored, manifest = ckpt.restore(tmp_path, state0)
    assert manifest["data_cursor"] == 2
    _assert_forests_equal(restored, loop.state)


def test_fault_loop_forest_kill_resume_identical(tmp_path):
    """Kill after 6 steps, resume from the step-4 checkpoint, replay the
    cursor — the final forest is bit-identical to an uninterrupted run."""
    state0, batches = _forest_stream()

    def data(start=0):
        for c, b in enumerate(batches):
            if c >= start:
                yield c, b

    ref = FaultTolerantLoop(step_fn=_forest_step, state=state0,
                            data_iter=data(), ckpt_dir=tmp_path / "ref",
                            ckpt_every=4, async_ckpt=False)
    ref.run(len(batches))

    dead = FaultTolerantLoop(step_fn=_forest_step, state=state0,
                             data_iter=data(), ckpt_dir=tmp_path / "b",
                             ckpt_every=4, async_ckpt=False)
    dead.run(6)                            # "killed": ckpt exists at step 4

    heir = FaultTolerantLoop(step_fn=_forest_step, state=state0,
                             data_iter=None, ckpt_dir=tmp_path / "b",
                             ckpt_every=4, async_ckpt=False)
    start = heir.resume()
    assert start == 4
    heir.data_iter = data(start)           # replay-exact cursor
    heir.run(len(batches))
    _assert_forests_equal(heir.state, ref.state)
