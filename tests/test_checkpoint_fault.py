"""Checkpoint save/restore, atomicity, retention, elastic restore, and the
fault-tolerant loop (resume + straggler log)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.train import checkpoint as ckpt
from repro.train.fault import FaultTolerantLoop


def _state(seed=0):
    k = jax.random.key(seed)
    return {"params": {"w": jax.random.normal(k, (8, 8)),
                       "b": jnp.zeros((8,), jnp.bfloat16)},
            "opt": {"step": jnp.int32(7)}}


def test_save_restore_roundtrip(tmp_path):
    s = _state()
    ckpt.save(tmp_path, s, step=3, data_cursor=42)
    restored, manifest = ckpt.restore(tmp_path, jax.eval_shape(lambda: s))
    assert manifest["step"] == 3 and manifest["data_cursor"] == 42
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_retention_keeps_last_n(tmp_path):
    s = _state()
    for step in range(6):
        ckpt.save(tmp_path, s, step=step, keep=2)
    assert ckpt.latest_step(tmp_path) == 5
    dirs = sorted(d.name for d in tmp_path.iterdir() if d.is_dir())
    assert dirs == ["step_0000000004", "step_0000000005"]


def test_tree_mismatch_rejected(tmp_path):
    ckpt.save(tmp_path, _state(), step=0)
    bad = {"params": {"w": jnp.zeros((8, 8))}, "opt": {"step": jnp.int32(0)}}
    with pytest.raises(ValueError, match="tree mismatch"):
        ckpt.restore(tmp_path, bad)


def test_async_save(tmp_path):
    t = ckpt.save(tmp_path, _state(), step=9, blocking=False)
    t.join()
    assert ckpt.latest_step(tmp_path) == 9


def test_elastic_restore_resharding(tmp_path):
    """Restore onto explicit (trivial-mesh) shardings — the elastic path."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import auto_axis_kwargs
    mesh = jax.make_mesh((1,), ("data",), **auto_axis_kwargs(1))
    s = _state()
    ckpt.save(tmp_path, s, step=1)
    shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), s)
    restored, _ = ckpt.restore(tmp_path, jax.eval_shape(lambda: s),
                               shardings=shardings)
    w = restored["params"]["w"]
    assert isinstance(w.sharding, NamedSharding)
    np.testing.assert_array_equal(np.asarray(w), np.asarray(s["params"]["w"]))


def test_fault_tolerant_loop_resume(tmp_path):
    """Train 10 steps w/ ckpt_every=4, kill, resume — continues from 8."""
    def step_fn(state, batch):
        s = state["opt"]["step"] + 1
        return ({"params": state["params"], "opt": {"step": s}},
                {"loss": jnp.float32(1.0) / s.astype(jnp.float32)})

    def data():
        c = 0
        while True:
            yield c, {"x": jnp.zeros(())}
            c += 1

    s0 = {"params": {"w": jnp.zeros((2,))}, "opt": {"step": jnp.int32(0)}}
    loop = FaultTolerantLoop(step_fn=step_fn, state=s0, data_iter=data(),
                             ckpt_dir=tmp_path, ckpt_every=4,
                             async_ckpt=False)
    loop.run(10)
    assert int(loop.state["opt"]["step"]) == 10

    loop2 = FaultTolerantLoop(step_fn=step_fn, state=s0, data_iter=data(),
                              ckpt_dir=tmp_path, ckpt_every=4,
                              async_ckpt=False)
    start = loop2.resume()
    assert start == 8                      # last multiple of ckpt_every
    loop2.run(12)
    assert int(loop2.state["opt"]["step"]) == 12


def test_straggler_detection(tmp_path):
    import time

    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        if calls["n"] == 5:
            time.sleep(0.25)               # synthetic straggler
        return state, {"loss": jnp.float32(0.0)}

    def data():
        c = 0
        while True:
            yield c, {}
            c += 1

    loop = FaultTolerantLoop(step_fn=step_fn, state={"x": jnp.zeros(())},
                             data_iter=data(), ckpt_dir=tmp_path,
                             ckpt_every=1000, straggler_factor=3.0)
    loop.run(8)
    assert len(loop.stragglers) >= 1
    assert loop.stragglers[0][0] == 4      # 0-indexed step of the slow call
