"""Pallas kernel sweeps: shapes × dtypes, assert_allclose vs ref oracles."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from numpy.testing import assert_allclose, assert_array_equal

rng = np.random.default_rng(42)


@pytest.mark.parametrize("n", [3, 64, 1024, 1025, 5000])
@pytest.mark.parametrize("k", [1, 3, 5])
def test_pointer_jump_sweep(n, k):
    from repro.kernels.pointer_jump.ops import pointer_jump_k
    from repro.kernels.pointer_jump.ref import pointer_jump_ref
    p = jnp.asarray(rng.integers(0, n, n), jnp.int32)
    assert_array_equal(np.asarray(pointer_jump_k(p, n_jumps=k)),
                       np.asarray(pointer_jump_ref(p, k)))


def test_pointer_jump_converges_deep_chain():
    from repro.kernels.pointer_jump.ops import pointer_jump_until_converged
    n = 3000
    p = jnp.asarray(np.maximum(np.arange(n) - 1, 0), jnp.int32)
    out = pointer_jump_until_converged(p)
    assert (np.asarray(out) == 0).all()


@pytest.mark.parametrize("n", [2, 129, 2048])
@pytest.mark.parametrize("k", [1, 5])
def test_list_rank_sweep(n, k):
    from repro.kernels.list_rank.ops import list_rank, list_rank_k
    from repro.kernels.list_rank.ref import (list_rank_full_ref,
                                             list_rank_steps_ref)
    perm = rng.permutation(n)
    succ = np.full(n, -1, np.int32)
    for a, b in zip(perm[:-1], perm[1:]):
        succ[a] = b
    succ = jnp.asarray(succ)
    valid = jnp.ones(n, bool)
    assert_array_equal(np.asarray(list_rank(succ, valid, n_steps=k)),
                       np.asarray(list_rank_full_ref(succ, valid)))
    d0 = jnp.where(succ != -1, 1, 0).astype(jnp.int32)
    s1, d1 = list_rank_k(succ, d0, n_steps=k)
    s2, d2 = list_rank_steps_ref(succ, d0, k)
    assert_array_equal(np.asarray(s1), np.asarray(s2))
    assert_array_equal(np.asarray(d1), np.asarray(d2))


@pytest.mark.parametrize("n", [3, 64, 1025, 5000])
@pytest.mark.parametrize("op", ["min", "max"])
@pytest.mark.parametrize("dtype", [jnp.int32, jnp.float32])
def test_segment_table_sweep(n, op, dtype):
    from repro.kernels.segment_table.ops import segment_table
    from repro.kernels.segment_table.ref import segment_table_ref
    if dtype == jnp.int32:
        v = jnp.asarray(rng.integers(-9999, 9999, n), dtype)
    else:
        v = jnp.asarray(rng.standard_normal(n), dtype)
    levels = max(1, (n - 1).bit_length())
    tab = segment_table(v, levels=levels, op=op)
    ref = segment_table_ref(v, levels=levels, op=op)
    assert_array_equal(np.asarray(tab), np.asarray(ref))


@pytest.mark.parametrize("n,e", [(10, 17), (300, 1111), (1024, 4096)])
@pytest.mark.parametrize("use_min", [True, False])
def test_hook_edges_sweep(n, e, use_min):
    from repro.kernels.hook_edges.ops import hook_edges
    from repro.kernels.hook_edges.ref import hook_edges_ref
    rep = jnp.asarray(rng.integers(0, n, n), jnp.int32)
    src = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    t1, v1 = hook_edges(src, dst, rep, use_min, n_nodes=n)
    t2, v2 = hook_edges_ref(src, dst, rep, use_min, n)
    assert_array_equal(np.asarray(t1), np.asarray(t2))
    assert_array_equal(np.asarray(v1), np.asarray(v2))


@pytest.mark.parametrize("n,e,level", [(50, 200, 0), (512, 2048, 3)])
def test_frontier_relax_sweep(n, e, level):
    from repro.kernels.frontier_relax.ops import frontier_relax
    from repro.kernels.frontier_relax.ref import INF32, frontier_relax_ref
    dist = jnp.asarray(np.where(rng.random(n) < 0.5,
                                rng.integers(0, 6, n), INF32), jnp.int32)
    src = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    assert_array_equal(np.asarray(frontier_relax(dist, src, dst, level)),
                       np.asarray(frontier_relax_ref(dist, src, dst, level)))


@pytest.mark.parametrize("b,hot,v,d", [(4, 3, 20, 18), (33, 8, 100, 128),
                                       (8, 1, 10, 300), (128, 16, 512, 64)])
@pytest.mark.parametrize("mean", [False, True])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embed_bag_sweep(b, hot, v, d, mean, dtype):
    from repro.kernels.embed_bag.ops import embed_bag
    from repro.kernels.embed_bag.ref import embed_bag_ref
    idx = jnp.asarray(rng.integers(0, v, (b, hot)), jnp.int32)
    w = jnp.asarray(rng.random((b, hot)), jnp.float32)
    tab = jnp.asarray(rng.standard_normal((v, d)), dtype)
    o1 = np.asarray(embed_bag(idx, tab, w, mean=mean), np.float32)
    o2 = np.asarray(embed_bag_ref(idx, w, tab, mean=mean), np.float32)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    assert_allclose(o1, o2, rtol=tol, atol=tol)


def test_embed_bag_vjp_matches_ref():
    from repro.kernels.embed_bag.ops import embed_bag
    from repro.kernels.embed_bag.ref import embed_bag_ref
    b, hot, v, d = 6, 4, 30, 20
    idx = jnp.asarray(rng.integers(0, v, (b, hot)), jnp.int32)
    w = jnp.asarray(rng.random((b, hot)), jnp.float32)
    tab = jnp.asarray(rng.standard_normal((v, d)), jnp.float32)
    for mean in (False, True):
        f1 = lambda t, ww: jnp.sum(jnp.sin(embed_bag(idx, t, ww, mean=mean)))
        f2 = lambda t, ww: jnp.sum(jnp.sin(embed_bag_ref(idx, ww, t, mean=mean)))
        g1 = jax.grad(f1, argnums=(0, 1))(tab, w)
        g2 = jax.grad(f2, argnums=(0, 1))(tab, w)
        for a, b_ in zip(g1, g2):
            assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-5,
                            atol=1e-6)
