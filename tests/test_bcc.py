"""Biconnectivity layer tests: goldens, networkx oracle, flavor invariance.

The acceptance bar: articulation points, bridges, and the per-edge BCC
partition from ``core.bcc`` match networkx on every generator in
``data/graphs.py``, identically for all three ``rst_flavor``s.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from oracles import edge_key as _edge
from oracles import nx_bcc_reference
from repro.core import Graph, bcc_batch, biconnectivity, tour_numbering
from repro.core.rst import METHODS
from repro.data import graphs as G


def _decompose(g, flavor, root=0):
    """Run biconnectivity; return (art set, bridge set, edge partition)."""
    res = biconnectivity(g, root, rst_flavor=flavor)
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    real = (src < g.n_nodes) & (dst < g.n_nodes)
    art = {v for v in range(g.n_nodes) if bool(res.articulation[v])}
    bridge_mask = np.asarray(res.bridge)
    bridges = {_edge(u, v) for u, v, e, ok in
               zip(src, dst, bridge_mask, real) if ok and e}
    labels = np.asarray(res.edge_bcc)
    blocks: dict[int, set] = {}
    for u, v, lab, ok in zip(src, dst, labels, real):
        if ok:
            blocks.setdefault(int(lab), set()).add(_edge(u, v))
    partition = frozenset(frozenset(b) for b in blocks.values())
    return art, bridges, partition, int(res.n_bcc)


def _assert_matches_nx(g, root=0):
    art_ref, bridges_ref, partition_ref = nx_bcc_reference(g)
    for flavor in METHODS:
        art, bridges, partition, n_bcc = _decompose(g, flavor, root)
        assert art == art_ref, (flavor, art ^ art_ref)
        assert bridges == bridges_ref, (flavor, bridges ^ bridges_ref)
        assert partition == partition_ref, flavor
        assert n_bcc == len(partition_ref), flavor


# ---------------------------------------------------------------- goldens

@pytest.mark.parametrize("flavor", METHODS)
def test_golden_bridge_path(flavor):
    """Path graph: every edge a bridge, every internal vertex a cut."""
    n = 9
    g = G.chain(n)
    art, bridges, partition, n_bcc = _decompose(g, flavor)
    assert art == set(range(1, n - 1))
    assert bridges == {_edge(i, i + 1) for i in range(n - 1)}
    assert n_bcc == n - 1 and len(partition) == n - 1


@pytest.mark.parametrize("flavor", METHODS)
def test_golden_cycle(flavor):
    """Cycle: one block, no bridges, no articulation points."""
    n = 7
    g = Graph.from_numpy_undirected(
        n, np.asarray([(i, (i + 1) % n) for i in range(n)]))
    art, bridges, partition, n_bcc = _decompose(g, flavor)
    assert art == set() and bridges == set()
    assert n_bcc == 1 and len(partition) == 1


@pytest.mark.parametrize("flavor", METHODS)
def test_golden_two_blocks_shared_cut_vertex(flavor):
    """Two triangles sharing vertex 2 (bowtie): 2 is the only cut vertex."""
    g = Graph.from_numpy_undirected(
        5, np.asarray([(0, 1), (1, 2), (2, 0), (2, 3), (3, 4), (4, 2)]))
    art, bridges, partition, n_bcc = _decompose(g, flavor)
    assert art == {2}
    assert bridges == set()
    assert n_bcc == 2
    assert partition == frozenset((
        frozenset((_edge(0, 1), _edge(1, 2), _edge(2, 0))),
        frozenset((_edge(2, 3), _edge(3, 4), _edge(4, 2)))))


@pytest.mark.parametrize("flavor", METHODS)
def test_golden_cycle_with_tail(flavor):
    """Cycle + pendant path: the attachment vertex cuts, tail edges bridge."""
    g = Graph.from_numpy_undirected(
        6, np.asarray([(0, 1), (1, 2), (2, 3), (3, 0), (0, 4), (4, 5)]))
    art, bridges, partition, n_bcc = _decompose(g, flavor)
    assert art == {0, 4}
    assert bridges == {_edge(0, 4), _edge(4, 5)}
    assert n_bcc == 3


# ------------------------------------------------------- networkx oracle

@pytest.mark.parametrize("name,factory,kwargs", [
    ("chain", G.chain, dict(n=33)),
    ("grid2d", G.grid2d, dict(side=6)),
    ("erdos_renyi", G.erdos_renyi, dict(n=72, avg_degree=3, seed=2)),
    ("rmat", G.rmat, dict(scale=5, edge_factor=2, seed=3)),
    ("pref_attach", G.pref_attach, dict(n=48, m_per=2, seed=4)),
])
def test_matches_networkx_all_generators(name, factory, kwargs):
    _assert_matches_nx(factory(**kwargs))


def test_matches_networkx_nonzero_root():
    _assert_matches_nx(G.erdos_renyi(50, avg_degree=3, seed=7), root=23)


# ----------------------------------------------------- flavor invariance

def test_flavors_identical():
    """The decomposition itself must be flavor-invariant (labels may not
    be — partitions and masks must)."""
    g = G.erdos_renyi(64, avg_degree=3, seed=11)
    ref = None
    for flavor in METHODS:
        got = _decompose(g, flavor)
        if ref is None:
            ref = got
        else:
            assert got == ref, flavor


def test_disconnected_forest_flavors_full_bfs_root_component():
    """Forest flavors decompose every component; bfs covers (exactly) the
    root's component, labelling everything else −1."""
    # triangle {0,1,2} + path 3-4-5 (cut vertex 4, two bridges)
    g = Graph.from_numpy_undirected(
        6, np.asarray([(0, 1), (1, 2), (2, 0), (3, 4), (4, 5)]))
    art_ref, bridges_ref, partition_ref = nx_bcc_reference(g)
    for flavor in ("gconn_euler", "pr_rst"):
        art, bridges, partition, n_bcc = _decompose(g, flavor)
        assert art == art_ref and bridges == bridges_ref
        assert partition == partition_ref and n_bcc == 3
    res = biconnectivity(g, 0, rst_flavor="bfs")
    src, dst = np.asarray(g.src), np.asarray(g.dst)
    in_root_comp = np.isin(src, (0, 1, 2)) & np.isin(dst, (0, 1, 2))
    labels = np.asarray(res.edge_bcc)
    assert (labels[~in_root_comp] == -1).all()
    assert (labels[in_root_comp] >= 0).all()
    assert not np.asarray(res.bridge).any()          # triangle: no bridges
    assert not np.asarray(res.articulation).any()    # 4 is outside coverage
    assert int(res.n_bcc) == 1


# ------------------------------------------------------------- numbering

def test_tour_numbering_intervals():
    """Preorder is dense and subtree(v) == [pre[v], pre[v] + size[v])."""
    g = G.erdos_renyi(40, avg_degree=4, seed=5)
    from repro.core import rooted_spanning_tree
    res = rooted_spanning_tree(g, 0, method="gconn_euler")
    tn = tour_numbering(res.parent)
    n = g.n_nodes
    pre = np.asarray(tn.pre)
    size = np.asarray(tn.size)
    par = np.asarray(tn.parent)
    assert sorted(pre.tolist()) == list(range(n))
    kids: list[list[int]] = [[] for _ in range(n)]
    for v in range(n):
        if par[v] != v:
            kids[par[v]].append(v)
            assert pre[par[v]] < pre[v]          # parent discovered first

    def subtree(v):
        out = {v}
        for c in kids[v]:
            out |= subtree(c)
        return out

    for v in range(n):
        s = subtree(v)
        assert size[v] == len(s)
        assert {int(pre[w]) for w in s} == set(
            range(int(pre[v]), int(pre[v]) + len(s)))


def test_tour_numbering_forest():
    """Disconnected input: components occupy contiguous preorder blocks."""
    edges = np.asarray([(0, 1), (1, 2), (4, 5), (5, 6), (6, 4)])
    g = Graph.from_numpy_undirected(8, edges)
    from repro.core import rooted_spanning_tree
    res = rooted_spanning_tree(g, 0, method="pr_rst")
    tn = tour_numbering(res.parent)
    pre = np.asarray(tn.pre)
    comp = np.asarray(tn.comp)
    assert sorted(pre.tolist()) == list(range(8))
    for c in set(comp.tolist()):
        block = sorted(int(pre[v]) for v in range(8) if comp[v] == c)
        assert block == list(range(block[0], block[0] + len(block)))


# ------------------------------------------------------------------ batch

def test_bcc_batch_matches_unbatched():
    """vmap path equals per-graph results (chains with a moving chord)."""
    n = 16
    base = [(i, i + 1) for i in range(n - 1)]
    gs = [Graph.from_numpy_undirected(n, np.asarray(base + [(0, j)]))
          for j in (5, 9, 14)]
    src = jnp.stack([g.src for g in gs])
    dst = jnp.stack([g.dst for g in gs])
    roots = jnp.zeros((len(gs),), jnp.int32)
    for flavor in METHODS:
        batched = bcc_batch(src, dst, roots, n_nodes=n, rst_flavor=flavor)
        for i, g in enumerate(gs):
            single = biconnectivity(g, 0, rst_flavor=flavor)
            for field in ("articulation", "bridge", "edge_bcc", "pre",
                          "size", "low", "high"):
                assert np.array_equal(
                    np.asarray(getattr(batched, field)[i]),
                    np.asarray(getattr(single, field))), (flavor, i, field)
            assert int(batched.n_bcc[i]) == int(single.n_bcc)


def test_unknown_flavor_raises():
    g = G.chain(4)
    with pytest.raises(ValueError, match="rst_flavor"):
        biconnectivity(g, 0, rst_flavor="dfs")
