"""Self-healing layer (DESIGN.md §11): chaos soak across every injector,
audit detection, scoped-repair/rebuild oracle agreement (networkx-checked),
sanitizer quarantine, polluted-stream serving, kill+resume bit-identity,
and the serve_stream --steps 0 regression."""
import numpy as np
import pytest

from oracles import canonical_partition as _canon
from oracles import nx_live_multigraph as _nx_graph
from repro.core.connectivity import connected_components
from repro.data import graphs as G
from repro.data.streams import STREAMS
from repro.dynamic import (INJECTORS, audit_forest, init_state, inject,
                           live_graph, merge_quarantine, pollute_stream,
                           rebuild_forest, recover, refresh_bcc,
                           refresh_tour, repair_forest, replay_batch,
                           sanitize_batch)
from repro.launch.resilient import ResilientStreamLoop

#: injector → does it corrupt forest structure (vs a cache snapshot)?
_STRUCTURAL = {name: name != "stale_bcc" for name in INJECTORS}


def _assert_matches_oracles(state, tn, bcc, tag):
    """Forest partition + BCC masks match networkx AND from-scratch."""
    lg = live_graph(state)
    nx, nxg = _nx_graph(lg)

    # Partition: rep vs networkx connected components vs GConn rebuild.
    labels = np.full(lg.n_nodes, -1)
    for i, comp in enumerate(nx.connected_components(nxg)):
        for v in comp:
            labels[v] = i
    assert np.array_equal(_canon(state.rep), _canon(labels)), tag
    rep_scratch, _, _ = connected_components(lg)
    assert np.array_equal(_canon(state.rep), _canon(rep_scratch)), tag

    if bcc is None:
        return
    # BCC: healed cache must equal a from-scratch recompute on the same
    # state bit-for-bit, and match networkx on the live graph.
    full = refresh_bcc(state, None, tour=tn, incremental=False)
    for f in ("articulation", "bridge", "n_bcc", "n_bridges"):
        assert np.array_equal(np.asarray(getattr(bcc, f)),
                              np.asarray(getattr(full, f))), (tag, f)
    assert np.array_equal(_canon(bcc.edge_bcc), _canon(full.edge_bcc)), tag
    art = {v for v in range(lg.n_nodes)
           if bool(np.asarray(bcc.articulation)[v])}
    assert art == set(nx.articulation_points(nxg)), tag
    n = state.n_nodes
    bridge = np.asarray(bcc.bridge)
    src = np.asarray(state.pool_src)
    dst = np.asarray(state.pool_dst)
    got = {frozenset((int(u), int(v))) for u, v, e in zip(src, dst, bridge)
           if e and u < n and v < n}
    assert got == {frozenset((int(u), int(v)))
                   for u, v in nx.bridges(nxg)}, tag


@pytest.fixture(scope="module")
def steady():
    """One churn steady state (multi-component, with live caches)."""
    g = G.grid2d(16)
    stream = STREAMS["churn"](g, batch=32, n_batches=8, seed=0)
    state = init_state(stream)
    for b in stream.batches:
        state, _ = replay_batch(state, b)
    tn, state = refresh_tour(state, None)
    bcc = refresh_bcc(state, None, tour=tn)
    report = audit_forest(state, tn, bcc)
    assert bool(report.healthy), "steady-state fixture must start healthy"
    return state, tn, bcc


@pytest.mark.parametrize("injector", sorted(INJECTORS))
@pytest.mark.parametrize("seed", [1, 2, 3])
def test_inject_detect_recover_oracle(steady, injector, seed):
    """Every injector × seed: the audit detects the fault, the recovery
    ladder restores the forest, and the result matches the oracles."""
    state, tn, bcc = steady
    bad, bad_bcc, desc = inject(injector, state, bcc, seed=seed)
    report = audit_forest(bad, tn, bad_bcc)
    assert not bool(report.healthy), (injector, seed, desc)
    if _STRUCTURAL[injector]:
        assert not bool(report.forest_ok), (injector, seed, desc)
    else:
        assert bool(report.forest_ok), (injector, seed, desc)
        assert not bool(report.bcc_fresh), (injector, seed, desc)

    fixed, tn2, bcc2, _, info = recover(bad, tn, bad_bcc)
    assert bool(audit_forest(fixed, tn2, bcc2).healthy), (injector, seed)
    expect = ("scoped", "full") if _STRUCTURAL[injector] else ("refresh",)
    assert info["mode"] in expect, (injector, seed, info)
    _assert_matches_oracles(fixed, tn2, bcc2, (injector, seed))


def test_scoped_repair_matches_rebuild(steady):
    """repair_forest and rebuild_forest converge to the same partition,
    and both pass a fresh audit."""
    state, _, bcc = steady
    for injector in ("parent_bitflip", "parent_cycle", "tree_mask_desync",
                     "pool_desync"):
        bad, _, _ = inject(injector, state, bcc, seed=7)
        report = audit_forest(bad)
        assert not bool(report.forest_ok), injector
        fixed, rstats = repair_forest(bad, report)
        rebuilt, bstats = rebuild_forest(bad)
        assert bool(audit_forest(fixed).forest_ok), injector
        assert bool(audit_forest(rebuilt).forest_ok), injector
        assert np.array_equal(_canon(fixed.rep), _canon(rebuilt.rep)), \
            injector
        assert int(rstats["sync_total"]) > 0
        assert int(bstats["sync_total"]) > 0


def test_recover_escalates_on_forged_odd_cycle(steady):
    """An odd parent cycle whose every link carries a forged tree bit
    evades the sever cut set (cover stays consistent, no self-fixed
    point) — recover must detect non-viability and escalate straight to
    the full rebuild instead of running the scoped path."""
    import dataclasses

    state, _, _ = steady
    n = state.n_nodes
    parent = np.asarray(state.parent).copy()
    src = np.asarray(state.pool_src)
    dst = np.asarray(state.pool_dst)
    valid = np.asarray(state.pool_valid)
    tree = np.asarray(state.tree_mask).copy()

    # Find a live path u - v - w and close it into a 3-cycle by forging
    # the w→u link onto a sacrificial live non-tree slot (all three
    # links end up tree-backed with a consistent cover).
    slot_of = {}
    for i, (a, b, ok) in enumerate(zip(src, dst, valid)):
        if ok:
            slot_of[(int(a), int(b))] = i
            slot_of[(int(b), int(a))] = i
    spare = np.flatnonzero(valid & ~tree)
    tri = None
    for (u, v), s1 in slot_of.items():
        if parent[u] != v:
            continue
        w = int(parent[v])
        if w in (u, v) or (v, w) not in slot_of:
            continue
        s2 = slot_of[(v, w)]
        forged = next((int(s) for s in spare if s not in (s1, s2)), None)
        if forged is None:
            continue
        tri = (u, v, w, s1, s2, forged)
        break
    assert tri is not None, "fixture lacks a forgeable path"
    u, v, w, s1, s2, forged = tri
    parent[w] = u                               # close the cycle
    src2, dst2 = src.copy(), dst.copy()
    src2[forged], dst2[forged] = w, u           # forge the closing edge
    tree[[s1, s2, forged]] = True
    bad = dataclasses.replace(
        state, parent=np.asarray(parent, np.int32),
        pool_src=src2, pool_dst=dst2, tree_mask=tree)

    report = audit_forest(bad)
    assert not bool(report.forest_ok)
    fixed, _, _, _, info = recover(bad)
    assert info["mode"] == "full", info
    assert bool(audit_forest(fixed).forest_ok)


def test_sanitizer_counters_and_safety():
    """sanitize_batch classifies malformed events per category, rewrites
    them to padding, and the sanitized batch applies cleanly."""
    from repro.data.streams import StreamBatch

    g = G.grid2d(8)
    stream = STREAMS["insert_heavy"](g, batch=16, seed=0)
    state = init_state(stream)
    n = g.n_nodes
    b = stream.batches[0]
    ins_u = np.asarray(b.ins_u).copy()
    ins_v = np.asarray(b.ins_v).copy()
    del_u = np.asarray(b.del_u).copy()
    del_v = np.asarray(b.del_v).copy()
    ins_u[0] = n + 7                            # out of range (not sentinel)
    ins_u[1] = ins_v[1] = 3                     # self-loop
    del_u[0], del_v[0] = -2, 5                  # negative endpoint
    dirty = StreamBatch(ins_u=ins_u, ins_v=ins_v, del_u=del_u, del_v=del_v)

    clean, q = sanitize_batch(dirty, n)
    assert q["ins_out_of_range"] == 1
    assert q["ins_self_loop"] == 1
    assert q["del_out_of_range"] == 1
    assert q["del_self_loop"] == 0
    cu = np.asarray(clean.ins_u)
    cv = np.asarray(clean.ins_v)
    assert cu[0] == n and cv[0] == n and cu[1] == n and cv[1] == n

    total = merge_quarantine({}, q)
    total = merge_quarantine(total, q)
    assert total["ins_out_of_range"] == 2

    state, _ = replay_batch(state, clean)
    assert bool(audit_forest(state).forest_ok)


def test_polluted_stream_served_with_sanitizer():
    """A stream hit by every polluter serves cleanly behind the
    sanitizer: events are quarantined, invariants hold, and the final
    partition matches the oracles."""
    g = G.grid2d(8)
    stream = STREAMS["churn"](g, batch=16, n_batches=6, seed=3)
    polluted = pollute_stream(
        stream, ["out_of_range", "self_loops", "phantom_deletes"], seed=3)
    loop = ResilientStreamLoop.from_stream(
        polluted, tour_mode="incremental", bcc_mode="incremental",
        tour_every=2, audit_every=2, sanitize=True)
    state = loop.run(list(polluted.batches))
    assert sum(loop.quarantine.values()) > 0
    assert loop.quarantine.get("ins_out_of_range", 0) > 0
    assert bool(audit_forest(state, loop.tn, loop.bcc).healthy)
    _assert_matches_oracles(state, loop.tn, loop.bcc, "polluted")


def test_chaos_serving_loop_recovers():
    """End-to-end: chaos on a cadence, audits repair the damage, and the
    final state passes the audit and the oracles."""
    g = G.grid2d(8)
    stream = STREAMS["churn"](g, batch=16, n_batches=8, seed=1)
    loop = ResilientStreamLoop.from_stream(
        stream, tour_mode="incremental", bcc_mode="incremental",
        tour_every=2, audit_every=2, chaos=("parent_cycle", "pool_desync"),
        chaos_every=3, chaos_seed=5)
    state = loop.run(list(stream.batches))
    assert len(loop.injected) >= 2
    assert len(loop.recoveries) >= 1
    assert bool(audit_forest(state, loop.tn, loop.bcc).healthy)
    _assert_matches_oracles(state, loop.tn, loop.bcc, "chaos loop")


def test_kill_resume_bit_identical(tmp_path):
    """A run killed mid-stream and resumed from its checkpoint converges
    to a final state bit-identical to the uninterrupted run — with chaos
    injection and audits active (seeds derive from (chaos_seed, step))."""
    g = G.grid2d(8)
    stream = STREAMS["churn"](g, batch=16, n_batches=12, seed=2)
    batches = list(stream.batches)
    config = dict(tour_mode="incremental", bcc_mode="incremental",
                  tour_every=4, audit_every=4,
                  chaos=("parent_cycle", "pool_desync"), chaos_every=3,
                  chaos_seed=9, async_ckpt=False)

    a = ResilientStreamLoop.from_stream(stream, **config)
    state_a = a.run(batches)

    b1 = ResilientStreamLoop.from_stream(
        stream, ckpt_dir=tmp_path / "ck", ckpt_every=4, **config)
    b1.run(batches[:8])                         # "killed" after batch 8
    b2 = ResilientStreamLoop.from_stream(
        stream, ckpt_dir=tmp_path / "ck", ckpt_every=4, **config)
    assert b2.resume() == 8
    state_b = b2.run(batches)
    assert [s for s, _ in b2.injected] == \
        [s for s, _ in a.injected if s >= 8]

    for f in ("parent", "rep", "pool_src", "pool_dst", "pool_valid",
              "tree_mask", "dirty"):
        assert np.array_equal(np.asarray(getattr(state_a, f)),
                              np.asarray(getattr(state_b, f))), f
    for f in ("pre", "size", "last", "comp"):
        assert np.array_equal(np.asarray(getattr(a.tn, f)),
                              np.asarray(getattr(b2.tn, f))), f
    assert np.array_equal(np.asarray(a.bcc.edge_bcc),
                          np.asarray(b2.bcc.edge_bcc))
    assert np.array_equal(np.asarray(a.bcc.bridge),
                          np.asarray(b2.bcc.bridge))


def test_serve_stream_zero_steps(capsys):
    """--steps 0 must report an empty run, not crash on percentiles."""
    from repro.launch import serve_stream

    serve_stream.main(["--graph", "chain_4k", "--stream", "churn",
                       "--batch", "16", "--steps", "0", "--tour", "off"])
    out = capsys.readouterr().out
    assert "no batches applied" in out


def test_audit_spanning_check(steady):
    """A live non-tree edge bridging two components (a redirect the
    tree-slot checks can't see) must fail the spanning verdict."""
    import dataclasses

    state, _, _ = steady
    rep = np.asarray(state.rep)
    src = np.asarray(state.pool_src).copy()
    dst = np.asarray(state.pool_dst).copy()
    valid = np.asarray(state.pool_valid)
    tree = np.asarray(state.tree_mask)
    roots = np.unique(rep)
    assert roots.size >= 2, "steady churn state should be multi-component"
    cand = np.flatnonzero(valid & ~tree)
    assert cand.size, "need a live non-tree slot to redirect"
    s = int(cand[0])
    other = roots[roots != rep[src[s]]][0]
    dst[s] = other                              # now bridges two comps
    bad = dataclasses.replace(state, pool_src=src, pool_dst=dst)
    report = audit_forest(bad)
    assert not bool(report.spanning_ok)
    assert not bool(report.forest_ok)
    fixed, _, _, _, info = recover(bad)
    assert bool(audit_forest(fixed).forest_ok)
    # The bridging edge is real connectivity: repaired partition must
    # treat the two claimed components as one.
    assert np.array_equal(
        _canon(fixed.rep),
        _canon(np.asarray(connected_components(live_graph(fixed))[0])))
