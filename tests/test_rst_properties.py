"""Property-based tests (hypothesis): RST invariants on random graphs."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (Graph, connected_components, rooted_spanning_tree)
from repro.core.euler import euler_tour_root, list_rank_dist_to_end
from repro.core.validate import components_reference, validate_rst


@st.composite
def random_graphs(draw, max_n=40, max_extra=60):
    n = draw(st.integers(2, max_n))
    n_extra = draw(st.integers(0, max_extra))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    # random spanning tree + extra edges → connected
    perm = rng.permutation(n)
    edges = [(int(perm[i]), int(perm[rng.integers(0, i)]))
             for i in range(1, n)]
    for _ in range(n_extra):
        u, v = rng.integers(0, n, 2)
        if u != v:
            edges.append((int(u), int(v)))
    root = draw(st.integers(0, n - 1))
    return Graph.from_numpy_undirected(n, np.asarray(edges)), root


@settings(max_examples=25, deadline=None)
@given(random_graphs())
def test_all_methods_produce_valid_rst(gr):
    g, root = gr
    for method in ("bfs", "gconn_euler", "pr_rst"):
        res = rooted_spanning_tree(g, root, method=method)
        v = validate_rst(g, res.parent, root)
        assert v["all_ok"], (method, v, np.asarray(res.parent))


@st.composite
def random_any_graphs(draw, max_n=30):
    """Possibly-disconnected graphs."""
    n = draw(st.integers(2, max_n))
    m = draw(st.integers(0, 2 * max_n))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    edges = np.stack([rng.integers(0, n, m), rng.integers(0, n, m)], 1) \
        if m else np.zeros((0, 2), np.int64)
    return Graph.from_numpy_undirected(n, edges)


@settings(max_examples=25, deadline=None)
@given(random_any_graphs())
def test_connectivity_partition_and_forest_size(g):
    rep, forest, _ = connected_components(g)
    ref = components_reference(g)
    rep_np = np.asarray(rep)
    n = g.n_nodes
    # identical partitions
    ref_of_rep = {}
    for v in range(n):
        r = rep_np[v]
        if r in ref_of_rep:
            assert ref_of_rep[r] == ref[v]
        else:
            ref_of_rep[r] = ref[v]
    assert len(ref_of_rep) == len(set(ref.tolist()))
    # forest has exactly n - n_components edges
    assert int(np.asarray(forest).sum()) == n - len(set(ref.tolist()))


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 200), st.integers(0, 2**31 - 1))
def test_list_ranking_permutation(n, seed):
    """Wyllie ranking on a random singly-linked list."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    succ = np.full(n, -1, np.int32)
    for a, b in zip(perm[:-1], perm[1:]):
        succ[a] = b
    d = list_rank_dist_to_end(jnp.asarray(succ), jnp.ones(n, bool))
    expect = np.empty(n, np.int64)
    expect[perm] = n - 1 - np.arange(n)
    assert np.array_equal(np.asarray(d), expect)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 25), st.integers(0, 2**31 - 1))
def test_euler_tour_roots_random_trees(n, seed):
    """Euler rooting of a random tree = exact parent array of that tree."""
    rng = np.random.default_rng(seed)
    parent_ref = np.zeros(n, np.int64)
    for v in range(1, n):
        parent_ref[v] = rng.integers(0, v)
    fu = jnp.asarray(np.arange(1, n), jnp.int32)
    fv = jnp.asarray(parent_ref[1:], jnp.int32)
    valid = jnp.ones(n - 1, bool)
    comp_root = jnp.zeros(n, jnp.int32)
    parent = np.asarray(euler_tour_root(n, fu, fv, valid, comp_root))
    assert parent[0] == 0
    assert np.array_equal(parent[1:], parent_ref[1:])
