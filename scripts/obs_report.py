#!/usr/bin/env python
"""Render an obs trace (DESIGN.md §14) as a serving post-mortem report.

    PYTHONPATH=src python scripts/obs_report.py trace.jsonl

Reads the JSONL span trace a serving loop wrote via ``--trace-out`` and
prints the three summaries an operator actually reaches for:

  * the sync budget per ledger phase (where the engine's convergence
    checks went — the device-independent cost signal);
  * wall-clock p50/p99 per span name (where the time went);
  * the incident log: every ``audit_violation`` and ``recovery`` event,
    i.e. what the self-healing ladder saw and what it decided.

Exits 0 on a well-formed trace (even an empty one); nonzero only on a
missing/corrupt file. ``scripts/obs_smoke.sh`` runs this in CI.
"""
from __future__ import annotations

import argparse
import sys


def _percentile(xs: list[float], q: float) -> float:
    xs = sorted(xs)
    i = min(len(xs) - 1, int(round(q / 100 * (len(xs) - 1))))
    return xs[i]


def report(records: list[dict], out=sys.stdout) -> None:
    spans = [r for r in records if r.get("type") == "span"]
    events = [r for r in records if r.get("type") == "event"]
    summaries = [r for r in records if r.get("type") == "summary"]

    print("== sync budget per phase ==", file=out)
    by_phase = summaries[-1]["sync_by_phase"] if summaries else {}
    if not by_phase:
        print("  (no ledger phases recorded)", file=out)
    total = sum(by_phase.values())
    for phase in sorted(by_phase):
        v = by_phase[phase]
        pct = 100.0 * v / total if total else 0.0
        print(f"  {phase:20s} {v:8d} syncs  ({pct:5.1f}%)", file=out)
    if by_phase:
        print(f"  {'total':20s} {total:8d} syncs", file=out)

    print("\n== span latency (p50/p99, ms) ==", file=out)
    names: dict[str, list] = {}
    for s in spans:
        names.setdefault(s["name"], []).append(s["dur"] / 1e3)
    if not names:
        print("  (no spans recorded)", file=out)
    for name in sorted(names):
        ms = names[name]
        syncs = sum(s.get("syncs", 0) for s in spans
                    if s["name"] == name)
        print(f"  {name:20s} n={len(ms):5d}  "
              f"p50 {_percentile(ms, 50):8.2f}  "
              f"p99 {_percentile(ms, 99):8.2f}  syncs={syncs}", file=out)

    print("\n== incidents ==", file=out)
    incidents = [e for e in events
                 if e["name"] in ("audit_violation", "recovery")]
    if not incidents:
        print("  (none)", file=out)
    for e in incidents:
        args = e.get("args", {})
        if e["name"] == "audit_violation":
            print(f"  audit_violation @{e['ts'] / 1e6:8.2f}s: "
                  f"{','.join(args.get('violations', []))} "
                  f"(n_violating={args.get('n_violating')}, "
                  f"syncs={args.get('syncs')})", file=out)
        else:
            print(f"  recovery        @{e['ts'] / 1e6:8.2f}s: "
                  f"mode={args.get('mode')} "
                  f"reason={args.get('reason')} "
                  f"(n_violating={args.get('n_violating')})", file=out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="JSONL trace from --trace-out")
    args = ap.parse_args(argv)
    try:
        from repro.obs import read_jsonl
        records = read_jsonl(args.trace)
    except (OSError, ValueError) as e:
        print(f"obs_report: cannot read {args.trace}: {e}",
              file=sys.stderr)
        return 1
    report(records)
    return 0


if __name__ == "__main__":
    sys.exit(main())
