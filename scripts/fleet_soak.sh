#!/usr/bin/env sh
# Bucketed-fleet soak (DESIGN.md §15): hundreds of tiny tenants routed
# through two shape buckets with far fewer slots than tenants, so the
# run leans hard on idle-LRU eviction, checkpoint-on-evict, async
# admission, and cross-tick carryover (--drain 2) — then --validate
# checks every tenant's final partition against a from-scratch RST.
# Tenant counts are tunable for longer soaks:
#
#   SOAK_SMALL=500 SOAK_LARGE=200 sh scripts/fleet_soak.sh
#
# Defaults keep the soak CI-sized (a few minutes on the XLA-CPU
# backend) while still rotating every slot many times over.
set -e
cd "$(dirname "$0")/.."
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}"
export PYTHONPATH

SOAK_SMALL="${SOAK_SMALL:-120}"   # chain_16 tenants (tiny schema)
SOAK_LARGE="${SOAK_LARGE:-80}"    # grid_8 tenants (wider schema)
SOAK_SLOTS="${SOAK_SLOTS:-8}"     # slots per bucket — tenants >> slots

EVICT_DIR=$(mktemp -d)
trap 'rm -rf "$EVICT_DIR"' EXIT

python -m repro.launch.serve_fleet \
    --buckets "chain_16:${SOAK_SMALL}:${SOAK_SLOTS},grid_8:${SOAK_LARGE}:${SOAK_SLOTS}" \
    --stream churn --batch 8 --steps 3 --drain 2 \
    --tour incremental --tour-every 2 \
    --evict-dir "$EVICT_DIR" \
    --validate

echo "fleet_soak: ok (${SOAK_SMALL}+${SOAK_LARGE} tenants through 2 buckets x ${SOAK_SLOTS} slots, validate green)"
