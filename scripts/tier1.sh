#!/usr/bin/env sh
# Tier-1 verify: run the test suite with PYTHONPATH set (see ROADMAP.md).
# Usage: scripts/tier1.sh [extra pytest args]
set -e
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" exec python -m pytest -x -q "$@"
