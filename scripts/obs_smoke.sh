#!/usr/bin/env sh
# Observability smoke (DESIGN.md §14): a short serve_fleet run must land
# a JSONL span trace + Chrome trace-event JSON + metrics registry file,
# the Chrome export must pass schema validation (loadable in Perfetto),
# and scripts/obs_report.py must render the trace with a nonzero
# per-phase sync budget — so the instrumented serving path can't
# silently stop exporting. Called from bench_smoke.sh.
set -e
cd "$(dirname "$0")/.."
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}"
export PYTHONPATH

OBS_DIR=$(mktemp -d)
trap 'rm -rf "$OBS_DIR"' EXIT

python -m repro.launch.serve_fleet \
    --graph grid_64 --stream churn --batch 256 --steps 4 \
    --tenants 3 --slots 2 --tour incremental --tour-every 2 \
    --read-ratio 0.2 \
    --trace-out "$OBS_DIR/trace.jsonl" \
    --metrics-out "$OBS_DIR/metrics.json"

for f in trace.jsonl trace.jsonl.chrome.json metrics.json; do
    if [ ! -s "$OBS_DIR/$f" ]; then
        echo "obs_smoke: $f missing or empty" >&2
        exit 1
    fi
done

python - "$OBS_DIR" <<'EOF'
import json, sys

d = sys.argv[1]

# Chrome trace-event schema: what Perfetto/chrome://tracing needs.
ch = json.load(open(f"{d}/trace.jsonl.chrome.json"))
assert isinstance(ch["traceEvents"], list) and ch["traceEvents"], \
    "no traceEvents"
for ev in ch["traceEvents"]:
    assert ev["ph"] in ("X", "i"), f"bad phase {ev['ph']!r}"
    assert isinstance(ev["name"], str) and isinstance(ev["ts"], int)
    if ev["ph"] == "X":
        assert isinstance(ev["dur"], int)
assert ch["otherData"]["sync_total"] > 0, "zero sync_total in otherData"
print(f"obs_smoke: chrome export ok "
      f"({len(ch['traceEvents'])} events, "
      f"sync_total={ch['otherData']['sync_total']})")

# Round trip: chrome export reconstructs the native records.
from repro.obs import chrome_to_records, read_jsonl
native = [r for r in read_jsonl(f"{d}/trace.jsonl")
          if r["type"] in ("span", "event")]
assert chrome_to_records(ch) == native, "chrome round-trip mismatch"

# Metrics registry: per-tenant labels landed.
m = json.load(open(f"{d}/metrics.json"))
names = {rec["name"] for rec in m["metrics"]}
assert "applied_events" in names and "batch_latency_ms" in names, names
tenants = {dict(rec["labels"]).get("tenant")
           for rec in m["metrics"] if rec["name"] == "applied_events"}
assert len(tenants) == 3, f"expected 3 tenant labels, got {tenants}"
print(f"obs_smoke: metrics ok ({len(m['metrics'])} series, "
      f"{len(tenants)} tenants)")
EOF

REPORT=$(python scripts/obs_report.py "$OBS_DIR/trace.jsonl")
echo "$REPORT"
if ! echo "$REPORT" | grep -q "fleet_apply.*[1-9][0-9]* syncs"; then
    echo "obs_smoke: obs_report shows no nonzero fleet_apply sync budget" >&2
    exit 1
fi

echo "obs_smoke: ok (trace + chrome + metrics land; report renders)"
