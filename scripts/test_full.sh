#!/usr/bin/env sh
# Full test sweep: tier-1 plus every test marked `slow` (the property
# sweeps tier1.sh skips). Extra args pass through to pytest.
set -eu
cd "$(dirname "$0")/.."
PYTHONPATH=src python -m pytest -q --run-slow "$@"
