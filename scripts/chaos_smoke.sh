#!/usr/bin/env sh
# Chaos soak for CI: serve a churn stream on grid_64 with every fault
# injector firing on a cadence, the O(log n) invariant audit + repair
# ladder running every 4 batches, and the final forest oracle-checked
# against a from-scratch build (--validate exits nonzero on any
# post-recovery mismatch — structure, partition, or spanning). A second
# pass drives the checkpointed crash-recovery path: the run is split at
# a checkpoint boundary and resumed, and must converge to the same
# oracle-checked final state (injections replay by (seed, step), so the
# resumed run sees the identical fault sequence).
set -e
cd "$(dirname "$0")/.."
PY="${PYTHON:-python}"
export PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}"

echo "chaos_smoke: full-ladder soak (all injectors, audit@4, sanitize)"
$PY -m repro.launch.serve_stream \
    --graph grid_64 --stream churn --batch 64 --steps 24 \
    --tour incremental --tour-every 4 --bcc incremental \
    --chaos all --chaos-every 4 --audit-every 4 --sanitize \
    --validate

echo "chaos_smoke: kill + resume under chaos (checkpoint at batch 8)"
CKPT=$(mktemp -d)
trap 'rm -rf "$CKPT"' EXIT
$PY -m repro.launch.serve_stream \
    --graph grid_64 --stream churn --batch 64 --steps 8 \
    --tour incremental --tour-every 4 --bcc incremental \
    --chaos parent_cycle,pool_desync --chaos-every 3 --audit-every 4 \
    --ckpt-dir "$CKPT" --ckpt-every 4
$PY -m repro.launch.serve_stream \
    --graph grid_64 --stream churn --batch 64 --steps 16 \
    --tour incremental --tour-every 4 --bcc incremental \
    --chaos parent_cycle,pool_desync --chaos-every 3 --audit-every 4 \
    --ckpt-dir "$CKPT" --ckpt-every 4 --resume \
    --validate

echo "chaos_smoke: ok (recovered forests pass the from-scratch oracle)"
