#!/usr/bin/env sh
# Chaos soak for CI: serve a churn stream on grid_64 with every fault
# injector firing on a cadence, the O(log n) invariant audit + repair
# ladder running every 4 batches, and the final forest oracle-checked
# against a from-scratch build (--validate exits nonzero on any
# post-recovery mismatch — structure, partition, or spanning). A second
# pass drives the checkpointed crash-recovery path: the run is split at
# a checkpoint boundary and resumed, and must converge to the same
# oracle-checked final state (injections replay by (seed, step), so the
# resumed run sees the identical fault sequence).
set -e
cd "$(dirname "$0")/.."
PY="${PYTHON:-python}"
export PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}"

echo "chaos_smoke: full-ladder soak (all injectors, audit@4, sanitize)"
OBS_DIR=$(mktemp -d)
$PY -m repro.launch.serve_stream \
    --graph grid_64 --stream churn --batch 64 --steps 24 \
    --tour incremental --tour-every 4 --bcc incremental \
    --chaos all --chaos-every 4 --audit-every 4 --sanitize \
    --trace-out "$OBS_DIR/trace.jsonl" \
    --validate

# The self-healing ladder's decisions are structured obs events
# (DESIGN.md §14) — assert the soak's trace shows the audit actually
# caught faults and every recovery carries a mode + escalation reason.
$PY - "$OBS_DIR/trace.jsonl" <<'EOF'
import sys
sys.path.insert(0, "src")
from repro.obs import read_jsonl

records = read_jsonl(sys.argv[1])
events = [r for r in records if r["type"] == "event"]
violations = [e for e in events if e["name"] == "audit_violation"]
recoveries = [e for e in events if e["name"] == "recovery"]
assert violations, "chaos soak trace has no audit_violation events"
assert recoveries, "chaos soak trace has no recovery events"
for e in violations:
    assert e["args"]["violations"], f"empty violation list: {e}"
    assert e["args"]["n_violating"] > 0, e
for e in recoveries:
    assert e["args"]["mode"] in ("scoped", "full", "refresh"), e
    assert e["args"]["reason"] in (
        "scoped_repair", "sever_insufficient", "reaudit_failed",
        "caches_stale"), e
print(f"chaos_smoke: trace ok ({len(violations)} audit_violation, "
      f"{len(recoveries)} recovery events; modes="
      f"{sorted({e['args']['mode'] for e in recoveries})})")
EOF
rm -rf "$OBS_DIR"

echo "chaos_smoke: kill + resume under chaos (checkpoint at batch 8)"
CKPT=$(mktemp -d)
trap 'rm -rf "$CKPT"' EXIT
$PY -m repro.launch.serve_stream \
    --graph grid_64 --stream churn --batch 64 --steps 8 \
    --tour incremental --tour-every 4 --bcc incremental \
    --chaos parent_cycle,pool_desync --chaos-every 3 --audit-every 4 \
    --ckpt-dir "$CKPT" --ckpt-every 4
$PY -m repro.launch.serve_stream \
    --graph grid_64 --stream churn --batch 64 --steps 16 \
    --tour incremental --tour-every 4 --bcc incremental \
    --chaos parent_cycle,pool_desync --chaos-every 3 --audit-every 4 \
    --ckpt-dir "$CKPT" --ckpt-every 4 --resume \
    --validate

echo "chaos_smoke: ok (recovered forests pass the from-scratch oracle)"
