#!/usr/bin/env sh
# Docs CI: every in-code `DESIGN.md §N` / `DESIGN §N` citation must resolve
# to a `## §N` section heading in DESIGN.md (the file is the contract the
# citations refer to — renumbering it without fixing callers fails here).
set -e
cd "$(dirname "$0")/.."

cited=$(grep -rhoE 'DESIGN(\.md)? §[0-9]+' \
            src benchmarks tests examples scripts README.md 2>/dev/null \
        | grep -oE '[0-9]+' | sort -un)
if [ -z "$cited" ]; then
    echo "check_docs: no DESIGN.md § citations found (suspicious)" >&2
    exit 1
fi

missing=0
for n in $cited; do
    if ! grep -qE "^## §$n( |$)" DESIGN.md; then
        echo "check_docs: DESIGN.md §$n is cited in code but has no" \
             "'## §$n' section in DESIGN.md" >&2
        missing=1
    fi
done

if [ "$missing" -eq 0 ]; then
    echo "check_docs: all cited DESIGN.md sections ($(echo "$cited" | tr '\n' ' ')) resolve"
fi
exit "$missing"
