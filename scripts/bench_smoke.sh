#!/usr/bin/env sh
# Fast perf-path exercise for CI: one tiny graph per fig/table + small
# microbenches, rows also written to BENCH_rst.json.
set -e
cd "$(dirname "$0")/.."
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    exec python benchmarks/run.py --smoke --json BENCH_rst.json "$@"
