#!/usr/bin/env sh
# Fast perf-path exercise for CI: one tiny graph per fig/table + small
# microbenches, rows also written to BENCH_rst.json. Asserts the
# biconnectivity rows (table3/*, DESIGN.md §4), the batch-dynamic rows
# (table4_dynamic/*, §9), and the incremental-BCC rows
# (table5_dynamic_bcc/*, §10), the self-healing rows
# (table6_robustness/*, §11), the query-serving rows
# (table7_queries/*, §12), the multi-tenant fleet rows
# (table8_fleet/*, §13), and the shape-bucketed fleet rows
# (table9_buckets/*, §15) actually landed so the downstream layers
# can't silently drop out of the perf trajectory — and asserts the
# *sync/round counts* of the incremental BCC refresh beat the full
# recompute on the chain-regime sliding_window rows, of the scoped
# fault repair beat the full rebuild on the single-fault (f1) rows,
# of the amortized query tables beat the per-read-batch recompute
# on the read-heavy table7 rows, of the vmapped fleet's per-event
# sync bill beat the sequential T-loop on every table8 pair, and of
# the bucketed fleet's per-event sync bill AND padded slot-work beat
# the equal-memory single-schema fleet on every table9 pair.
# Wall-clock on the XLA-CPU CI backend is volume-bound, so the sync
# counts are the device-independent advantage this guard keeps honest
# without a GPU.
set -e
cd "$(dirname "$0")/.."
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/run.py --smoke --json BENCH_rst.json "$@"

if ! grep -q '"name": "table3/' BENCH_rst.json; then
    echo "bench_smoke: no table3/* biconnectivity row in BENCH_rst.json" >&2
    exit 1
fi
if ! grep -q '"name": "table4_dynamic/' BENCH_rst.json; then
    echo "bench_smoke: no table4_dynamic/* batch-dynamic row in BENCH_rst.json" >&2
    exit 1
fi
if ! grep -q '"name": "table5_dynamic_bcc/' BENCH_rst.json; then
    echo "bench_smoke: no table5_dynamic_bcc/* incremental-BCC row in BENCH_rst.json" >&2
    exit 1
fi
if ! grep -q '"name": "table6_robustness/' BENCH_rst.json; then
    echo "bench_smoke: no table6_robustness/* self-healing row in BENCH_rst.json" >&2
    exit 1
fi
if ! grep -q '"name": "table7_queries/' BENCH_rst.json; then
    echo "bench_smoke: no table7_queries/* query-serving row in BENCH_rst.json" >&2
    exit 1
fi
if ! grep -q '"name": "table8_fleet/' BENCH_rst.json; then
    echo "bench_smoke: no table8_fleet/* multi-tenant fleet row in BENCH_rst.json" >&2
    exit 1
fi
if ! grep -q '"name": "table9_buckets/' BENCH_rst.json; then
    echo "bench_smoke: no table9_buckets/* shape-bucketed fleet row in BENCH_rst.json" >&2
    exit 1
fi

python - <<'EOF'
import json, re, sys

records = {r["name"]: r for r in json.load(open("BENCH_rst.json"))}

def sync_total(rec):
    m = re.search(r"sync_total=(\d+)", rec["derived"])
    assert m, f"no sync_total in {rec['name']}: {rec['derived']}"
    return int(m.group(1))

pairs = 0
for name, rec in records.items():
    if not name.startswith("table5_dynamic_bcc/"):
        continue
    if "/sliding_window/" not in name or "chain" not in name:
        continue
    if not name.endswith("/incremental"):
        continue
    full = records.get(name[: -len("incremental")] + "recompute")
    assert full is not None, f"missing recompute twin for {name}"
    si, sf = sync_total(rec), sync_total(full)
    if si >= sf:
        sys.exit(f"bench_smoke: incremental BCC sync count regressed: "
                 f"{name} has sync_total={si} >= recompute {sf}")
    print(f"bench_smoke: {name}: sync_total {si} < recompute {sf}")
    pairs += 1

if pairs == 0:
    sys.exit("bench_smoke: no chain-regime sliding_window table5 row pairs "
             "found to compare")

# Self-healing (DESIGN.md §11): on single-component faults the scoped
# repair must cost fewer engine syncs than the from-scratch rebuild.
t6_pairs = 0
for name, rec in records.items():
    if not name.startswith("table6_robustness/"):
        continue
    if not name.endswith("/f1/scoped"):
        continue
    full = records.get(name[: -len("scoped")] + "full")
    assert full is not None, f"missing full-rebuild twin for {name}"
    ss, sf = sync_total(rec), sync_total(full)
    if ss >= sf:
        sys.exit(f"bench_smoke: scoped repair sync count regressed: "
                 f"{name} has sync_total={ss} >= full rebuild {sf}")
    print(f"bench_smoke: {name}: sync_total {ss} < full rebuild {sf}")
    t6_pairs += 1

if t6_pairs == 0:
    sys.exit("bench_smoke: no f1 scoped/full table6 row pairs found "
             "to compare")

# Query serving (DESIGN.md §12): on read-heavy interleaves the amortized
# QueryTables path must charge fewer engine syncs per read batch than
# rebuilding the index for every batch.
def sync_per_read(rec):
    m = re.search(r"sync_per_read=([0-9.]+)", rec["derived"])
    assert m, f"no sync_per_read in {rec['name']}: {rec['derived']}"
    return float(m.group(1))

t7_pairs = 0
for name, rec in records.items():
    if not name.startswith("table7_queries/"):
        continue
    if "/read_heavy/" not in name or not name.endswith("/amortized"):
        continue
    full = records.get(name[: -len("amortized")] + "recompute")
    assert full is not None, f"missing recompute twin for {name}"
    sa, sr = sync_per_read(rec), sync_per_read(full)
    if sa >= sr:
        sys.exit(f"bench_smoke: amortized query sync count regressed: "
                 f"{name} has sync_per_read={sa} >= recompute {sr}")
    print(f"bench_smoke: {name}: sync_per_read {sa} < recompute {sr}")
    t7_pairs += 1

if t7_pairs == 0:
    sys.exit("bench_smoke: no read_heavy amortized/recompute table7 row "
             "pairs found to compare")

# Multi-tenant fleet (DESIGN.md §13): the vmapped (T, B) apply must
# charge fewer convergence checks per applied event than T sequential
# single-tenant loops over the same streams.
def sync_per_event(rec):
    m = re.search(r"sync_per_event=([0-9.]+)", rec["derived"])
    assert m, f"no sync_per_event in {rec['name']}: {rec['derived']}"
    return float(m.group(1))

t8_pairs = 0
for name, rec in records.items():
    if not name.startswith("table8_fleet/") or not name.endswith("/fleet"):
        continue
    seq = records.get(name[: -len("fleet")] + "sequential")
    assert seq is not None, f"missing sequential twin for {name}"
    sf, ss = sync_per_event(rec), sync_per_event(seq)
    if sf >= ss:
        sys.exit(f"bench_smoke: fleet sync amortization regressed: "
                 f"{name} has sync_per_event={sf} >= sequential {ss}")
    print(f"bench_smoke: {name}: sync_per_event {sf} < sequential {ss}")
    t8_pairs += 1

if t8_pairs == 0:
    sys.exit("bench_smoke: no fleet/sequential table8 row pairs found "
             "to compare")

# Shape-bucketed sub-fleets (DESIGN.md §15): at equal device-memory
# budget the bucketed fleet must beat the single wide schema on BOTH
# per-event convergence syncs and padded slot-work (int32-rows ticked).
def padded_rows(rec):
    m = re.search(r"padded_rows=(\d+)", rec["derived"])
    assert m, f"no padded_rows in {rec['name']}: {rec['derived']}"
    return int(m.group(1))

t9_pairs = 0
for name, rec in records.items():
    if not name.startswith("table9_buckets/"):
        continue
    if not name.endswith("/bucketed"):
        continue
    single = records.get(name[: -len("bucketed")] + "single_schema")
    assert single is not None, f"missing single_schema twin for {name}"
    sb, ss = sync_per_event(rec), sync_per_event(single)
    if sb >= ss:
        sys.exit(f"bench_smoke: bucketed sync amortization regressed: "
                 f"{name} has sync_per_event={sb} >= single-schema {ss}")
    pb, ps = padded_rows(rec), padded_rows(single)
    if pb >= ps:
        sys.exit(f"bench_smoke: bucketed padded slot-work regressed: "
                 f"{name} has padded_rows={pb} >= single-schema {ps}")
    print(f"bench_smoke: {name}: sync_per_event {sb} < single-schema "
          f"{ss}; padded_rows {pb} < {ps}")
    t9_pairs += 1

if t9_pairs == 0:
    sys.exit("bench_smoke: no bucketed/single_schema table9 row pairs "
             "found to compare")
EOF

# Provenance (DESIGN.md §14): every record must carry the meta stamp
# that makes a perf-trajectory point attributable to a commit + backend.
python - <<'EOF'
import json, sys

records = json.load(open("BENCH_rst.json"))
names = [r["name"] for r in records]
assert names == sorted(names), "BENCH_rst.json records not name-sorted"
for r in records:
    meta = r.get("meta")
    assert meta, f"record {r['name']} missing meta"
    for k in ("git_sha", "jax_version", "backend", "device_kind",
              "schema_version"):
        assert k in meta, f"record {r['name']} meta missing {k}"
print(f"bench_smoke: provenance meta on all {len(records)} records "
      f"(git_sha={records[0]['meta']['git_sha']}, "
      f"backend={records[0]['meta']['backend']})")
EOF

sh scripts/obs_smoke.sh

echo "bench_smoke: ok (table3 + table4_dynamic + table5_dynamic_bcc + table6_robustness + table7_queries + table8_fleet + table9_buckets rows present; incremental BCC, scoped-repair, amortized-query, fleet, and bucketed-fleet sync counts ahead; provenance meta + obs exports land)"
