#!/usr/bin/env sh
# Fast perf-path exercise for CI: one tiny graph per fig/table + small
# microbenches, rows also written to BENCH_rst.json. Asserts the
# biconnectivity rows (table3/*, DESIGN.md §4) actually landed so the
# downstream layer can't silently drop out of the perf trajectory.
set -e
cd "$(dirname "$0")/.."
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/run.py --smoke --json BENCH_rst.json "$@"

if ! grep -q '"name": "table3/' BENCH_rst.json; then
    echo "bench_smoke: no table3/* biconnectivity row in BENCH_rst.json" >&2
    exit 1
fi
if ! grep -q '"name": "table4_dynamic/' BENCH_rst.json; then
    echo "bench_smoke: no table4_dynamic/* batch-dynamic row in BENCH_rst.json" >&2
    exit 1
fi
echo "bench_smoke: ok (table3 + table4_dynamic smoke rows present)"
